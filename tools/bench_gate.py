#!/usr/bin/env python3
"""Bench-regression gate for the `nmc-tos-bench-v1` JSON emitted by
`cargo bench` (BENCH_tos.json / BENCH_stcf.json / BENCH_e2e.json /
BENCH_serving.json).

Dependency-free (stdlib only). Two kinds of checks:

* **Ratio metrics** — computed *within* one fresh file, so they are
  robust to machine speed: the dispatched golden kernel vs the scalar
  reference loop, the widest SIMD `kernel_*` row vs the `kernel_swar64`
  row (acceptance floor: >= 1.5x on full runs), and the vectorized STCF
  classifier vs its scalar reference. Ratios are also diffed against the
  committed baseline's ratios when one exists.
* **Tracked absolute rows** — `events_per_sec` of a fixed set of rows
  diffed against the committed baseline, failing on a regression beyond
  the tolerance. Absolute comparisons only run when the fresh and
  baseline files agree on `smoke` and `kernel` (numbers from different
  run modes or dispatch paths are not comparable).

A machine-readable diff is always written (`--out`, default
`bench_gate_diff.json`) so CI can upload it as an artifact. Missing
baseline files are a *pass* with a bootstrap notice: the first
toolchain-equipped run commits `bench/baseline/` and arms the gate.

Usage:
    python3 tools/bench_gate.py \
        --fresh-dir . --baseline-dir bench/baseline \
        --out bench_gate_diff.json [--tolerance 0.15] [--smoke-tolerance 0.40]

Exit status: 0 = pass (including bootstrap), 1 = regression or floor
violation, 2 = malformed input.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = "nmc-tos-bench-v1"

BENCH_FILES = [
    "BENCH_tos.json",
    "BENCH_stcf.json",
    "BENCH_e2e.json",
    "BENCH_serving.json",
]

# Rows whose absolute events_per_sec is gated against the baseline.
# Everything else in the files is report-only context in the diff.
TRACKED_ROWS = {
    "BENCH_tos.json": [
        "tos_update/davis240/p7/golden",
        "tos_update/davis240/p7/scalar_ref",
        "tos_update/davis240/golden/200k_events",
        "tos_update/davis240/sharded4/200k_events",
    ],
    "BENCH_stcf.json": [
        "stcf/scattered/r1/200k_events",
        "stcf/clustered/r1/200k_events",
    ],
    "BENCH_e2e.json": [
        "e2e/no_fbf/100k_events",
        "e2e/sink_recording/100k_events",
        "e2e/sink_stats1k/100k_events",
    ],
    "BENCH_serving.json": [
        "serve/golden/4streams/60k_each",
        "serve/sharded/4streams/60k_each",
    ],
}

# Tracked row-name prefixes (rows matching a prefix are gated when
# present in both files — kernel_* rows depend on the host ISA, so the
# exact set is not fixed).
TRACKED_PREFIXES = {
    "BENCH_tos.json": ["tos_update/davis240/p7/kernel_"],
}

SIMD_PATHS = ("avx2", "sse2", "neon")
SIMD_FLOOR = 1.5  # ISSUE 6 acceptance: widest SIMD >= 1.5x swar64 (full runs)


def load(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    rows = {r["name"]: float(r["events_per_sec"]) for r in doc.get("rows", [])}
    return doc, rows


def ratio(rows, num, den):
    """events_per_sec ratio num/den, or None if either row is absent."""
    if num in rows and den in rows and rows[den] > 0:
        return rows[num] / rows[den]
    return None


def ratio_metrics(fname, rows):
    """Within-file ratio metrics for one bench file: {metric: value}."""
    out = {}
    if fname == "BENCH_tos.json":
        r = ratio(rows, "tos_update/davis240/p7/golden", "tos_update/davis240/p7/scalar_ref")
        if r is not None:
            out["golden_vs_scalar"] = r
        swar = rows.get("tos_update/davis240/p7/kernel_swar64")
        simd = [
            rows[f"tos_update/davis240/p7/kernel_{p}"]
            for p in SIMD_PATHS
            if f"tos_update/davis240/p7/kernel_{p}" in rows
        ]
        if swar and simd:
            out["simd_vs_swar64"] = max(simd) / swar
    elif fname == "BENCH_stcf.json":
        pairs = [
            (n, n.rsplit("/", 1)[0] + "/scalar_ref")
            for n in rows
            if n.endswith("/200k_events")
        ]
        ratios = [ratio(rows, n, s) for n, s in pairs]
        ratios = [r for r in ratios if r is not None]
        if ratios:
            out["vectorized_vs_scalar_min"] = min(ratios)
    return out


def gate_file(fname, fresh_dir, baseline_dir, tol, smoke_tol):
    """Gate one bench file; returns (report_dict, failures: [str])."""
    fresh_path = os.path.join(fresh_dir, fname)
    base_path = os.path.join(baseline_dir, fname)
    report = {"file": fname, "status": "pass", "checks": [], "notes": []}
    failures = []

    if not os.path.exists(fresh_path):
        report["status"] = "missing-fresh"
        report["notes"].append(f"{fresh_path} not found — bench did not emit it")
        failures.append(f"{fname}: fresh results missing")
        return report, failures

    fresh_doc, fresh_rows = load(fresh_path)
    report["fresh"] = {
        "smoke": fresh_doc.get("smoke"),
        "kernel": fresh_doc.get("kernel"),
        "rows": len(fresh_rows),
    }
    fresh_ratios = ratio_metrics(fname, fresh_rows)
    report["ratios"] = fresh_ratios

    # Acceptance floor: only meaningful on full (non-smoke) runs — smoke
    # iteration counts are too small to trust.
    if fname == "BENCH_tos.json" and not fresh_doc.get("smoke"):
        simd = fresh_ratios.get("simd_vs_swar64")
        if simd is not None:
            ok = simd >= SIMD_FLOOR
            report["checks"].append(
                {
                    "check": "simd_floor",
                    "metric": "simd_vs_swar64",
                    "value": simd,
                    "floor": SIMD_FLOOR,
                    "ok": ok,
                }
            )
            if not ok:
                failures.append(
                    f"{fname}: widest SIMD kernel only {simd:.2f}x swar64 "
                    f"(floor {SIMD_FLOOR}x)"
                )

    if not os.path.exists(base_path):
        report["status"] = "bootstrap"
        report["notes"].append(
            f"no baseline at {base_path} — gate passes; commit this run's "
            f"JSON there to arm it"
        )
        return report, failures

    base_doc, base_rows = load(base_path)
    report["baseline"] = {
        "smoke": base_doc.get("smoke"),
        "kernel": base_doc.get("kernel"),
        "rows": len(base_rows),
    }
    base_ratios = ratio_metrics(fname, base_rows)

    effective_tol = smoke_tol if fresh_doc.get("smoke") else tol
    report["tolerance"] = effective_tol

    comparable = fresh_doc.get("smoke") == base_doc.get("smoke") and fresh_doc.get(
        "kernel"
    ) == base_doc.get("kernel")
    if not comparable:
        report["notes"].append(
            "smoke/kernel mismatch vs baseline "
            f"(fresh smoke={fresh_doc.get('smoke')} kernel={fresh_doc.get('kernel')}, "
            f"baseline smoke={base_doc.get('smoke')} kernel={base_doc.get('kernel')}) "
            "— absolute row and ratio diffs are report-only"
        )

    # Ratio diffs vs baseline (gated only when run modes match).
    for metric, fresh_v in sorted(fresh_ratios.items()):
        base_v = base_ratios.get(metric)
        if base_v is None or base_v <= 0:
            continue
        rel = fresh_v / base_v
        ok = (not comparable) or rel >= 1.0 - effective_tol
        report["checks"].append(
            {
                "check": "ratio",
                "metric": metric,
                "fresh": fresh_v,
                "baseline": base_v,
                "fresh_vs_baseline": rel,
                "gated": comparable,
                "ok": ok,
            }
        )
        if not ok:
            failures.append(
                f"{fname}: ratio {metric} regressed {fresh_v:.2f} vs "
                f"baseline {base_v:.2f} ({(1 - rel) * 100:.0f}% worse, "
                f"tolerance {effective_tol * 100:.0f}%)"
            )

    # Tracked absolute rows.
    tracked = set(TRACKED_ROWS.get(fname, []))
    for prefix in TRACKED_PREFIXES.get(fname, []):
        tracked.update(n for n in fresh_rows if n.startswith(prefix))
    for name in sorted(tracked):
        fresh_v = fresh_rows.get(name)
        base_v = base_rows.get(name)
        if fresh_v is None:
            report["notes"].append(f"tracked row {name} missing from fresh results")
            failures.append(f"{fname}: tracked row {name} disappeared")
            continue
        if base_v is None or base_v <= 0:
            report["notes"].append(f"tracked row {name} has no baseline — report-only")
            continue
        rel = fresh_v / base_v
        ok = (not comparable) or rel >= 1.0 - effective_tol
        report["checks"].append(
            {
                "check": "row",
                "row": name,
                "fresh_events_per_sec": fresh_v,
                "baseline_events_per_sec": base_v,
                "fresh_vs_baseline": rel,
                "gated": comparable,
                "ok": ok,
            }
        )
        if not ok:
            failures.append(
                f"{fname}: {name} regressed to {rel * 100:.0f}% of baseline "
                f"({fresh_v / 1e6:.2f}M vs {base_v / 1e6:.2f}M events/s, "
                f"tolerance {effective_tol * 100:.0f}%)"
            )

    if failures:
        report["status"] = "fail"
    return report, failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", default=".", help="dir with freshly emitted BENCH_*.json")
    ap.add_argument(
        "--baseline-dir", default="bench/baseline", help="dir with committed baseline JSON"
    )
    ap.add_argument("--out", default="bench_gate_diff.json", help="diff artifact path")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.15,
        help="max relative regression on full runs (default 0.15)",
    )
    ap.add_argument(
        "--smoke-tolerance",
        type=float,
        default=0.40,
        help="max relative regression on smoke runs (default 0.40; smoke "
        "iteration counts are tiny, so the band is wide)",
    )
    ap.add_argument(
        "--files",
        nargs="*",
        default=BENCH_FILES,
        help="bench files to gate (default: all four)",
    )
    args = ap.parse_args(argv)

    reports, failures = [], []
    try:
        for fname in args.files:
            rep, fails = gate_file(
                fname, args.fresh_dir, args.baseline_dir, args.tolerance, args.smoke_tolerance
            )
            reports.append(rep)
            failures.extend(fails)
    except (ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"bench_gate: malformed input: {e}", file=sys.stderr)
        return 2

    diff = {
        "schema": "nmc-tos-bench-gate-v1",
        "status": "fail" if failures else "pass",
        "tolerance": args.tolerance,
        "smoke_tolerance": args.smoke_tolerance,
        "failures": failures,
        "files": reports,
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(diff, f, indent=2)
        f.write("\n")

    for rep in reports:
        print(f"[{rep['status']:>9}] {rep['file']}", end="")
        if rep.get("ratios"):
            pretty = ", ".join(f"{k}={v:.2f}x" for k, v in sorted(rep["ratios"].items()))
            print(f"  ({pretty})", end="")
        print()
        for note in rep.get("notes", []):
            print(f"            - {note}")
    if failures:
        print("\nbench_gate: FAIL")
        for f_ in failures:
            print(f"  - {f_}")
        print(f"\ndiff written to {args.out}")
        return 1
    print(f"\nbench_gate: pass — diff written to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
