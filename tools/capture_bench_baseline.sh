#!/usr/bin/env bash
# Capture a full (non-smoke) bench baseline into bench/baseline/.
#
# Run this on the reference machine (or via the `bench-baseline`
# workflow_dispatch job in CI), review the numbers, then commit the
# four JSON files. The bench-regression gate (tools/bench_gate.py)
# stays in bootstrap/pass mode until these files exist.
#
# Usage: tools/capture_bench_baseline.sh
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== full cargo bench (this takes a few minutes) =="
cargo bench --bench tos_update
cargo bench --bench stcf_filter
cargo bench --bench end_to_end
cargo bench --bench serving

mkdir -p bench/baseline
for f in BENCH_tos.json BENCH_stcf.json BENCH_e2e.json BENCH_serving.json; do
    test -s "$f" || { echo "error: $f was not emitted" >&2; exit 1; }
    cp -v "$f" "bench/baseline/$f"
done

echo
echo "== sanity: gate the fresh run against the captured baseline =="
python3 tools/bench_gate.py --fresh-dir . --baseline-dir bench/baseline \
    --out bench_gate_diff.json

echo
echo "Baseline captured. Review bench/baseline/*.json and commit them:"
echo "    git add bench/baseline && git commit -m 'Capture bench baseline'"
