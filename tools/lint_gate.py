#!/usr/bin/env python3
"""Repo-specific unsafe/concurrency lint gate for the nmc-tos crate.

Dependency-free (stdlib only), same contract as bench_gate.py: run it
from the repo root, exit 0 = clean, 1 = violations (each printed with
file:line and a pointed message), 2 = misuse/malformed input.

Four invariants, calibrated to this codebase (see DESIGN.md
§Correctness tooling):

1. **SAFETY discipline** — every `unsafe {` block in the allowlisted
   modules carries a `// SAFETY:` comment in the lines immediately above
   it, and every `unsafe fn` carries a `/// # Safety` doc section.
2. **Unsafe allowlist** — the `unsafe` keyword appears only in
   `rust/src/tos/kernel.rs` and `rust/src/stcf/mod.rs` (the two
   explicit-SIMD modules). The crate root must pin `#![deny(unsafe_code)]`,
   the binary `#![forbid(unsafe_code)]`, and each allowlisted file must
   opt back in explicitly with `#![allow(unsafe_code)]`.
3. **Sync shim discipline** — the loom-modelled concurrent modules
   (`serve/mod.rs`, `serve/pool.rs`, `coordinator/mod.rs`,
   `coordinator/lut_worker.rs`, `tos/sharded.rs`) never name
   `std::sync` / `std::thread` directly; all primitives come from
   `crate::util::sync` so `--cfg loom` swaps them wholesale.
4. **Decode bounds** — in the wire-decode files (`serve/wire.rs`,
   `events/codec.rs`) every length-driven `with_capacity(...)` is
   preceded, within a few lines, by an `ensure!` against a `MAX_*` cap:
   untrusted counts must be validated before they size an allocation.

`--self-test` runs the rules against the committed negative fixtures in
`tools/fixtures/lint_gate/` and verifies each fails with the expected
pointed message (and that a clean fixture passes).

Usage:
    python3 tools/lint_gate.py [--root .] [--self-test]
"""

from __future__ import annotations

import argparse
import os
import re
import sys

# --- the repo-specific policy tables ---------------------------------------

# Modules allowed to contain the `unsafe` keyword (rule 2); each must
# opt in explicitly. Everything else in rust/src is unsafe-free.
UNSAFE_ALLOWLIST = {
    "rust/src/tos/kernel.rs",
    "rust/src/stcf/mod.rs",
}

# (file, required attribute) pairs pinning the crate-level posture.
REQUIRED_ATTRS = [
    ("rust/src/lib.rs", "#![deny(unsafe_code)]"),
    ("rust/src/main.rs", "#![forbid(unsafe_code)]"),
    ("rust/src/tos/kernel.rs", "#![allow(unsafe_code)]"),
    ("rust/src/stcf/mod.rs", "#![allow(unsafe_code)]"),
]

# Modules whose synchronization must come from crate::util::sync (rule 3).
SHIMMED = {
    "rust/src/serve/mod.rs",
    "rust/src/serve/pool.rs",
    "rust/src/coordinator/mod.rs",
    "rust/src/coordinator/lut_worker.rs",
    "rust/src/tos/sharded.rs",
}

# Files whose decode paths handle untrusted lengths (rule 4).
DECODE_FILES = {
    "rust/src/serve/wire.rs",
    "rust/src/events/codec.rs",
    "rust/src/events/codec/aedat4.rs",
    "rust/src/events/codec/evt.rs",
}

# How many lines above an `unsafe {` the `// SAFETY:` run may start, and
# how far above a `with_capacity` its `ensure!` cap check may sit.
SAFETY_WINDOW = 14
BOUNDS_WINDOW = 10

UNSAFE_KEYWORD = re.compile(r"\bunsafe\b")
STD_SYNC = re.compile(r"\bstd\s*::\s*(sync|thread)\b")
WITH_CAPACITY = re.compile(r"\bwith_capacity\s*\(")


def strip_code(text: str) -> list[str]:
    """Blank out comments and string literals, preserving line structure,
    so keyword scans don't trip on prose. Handles `//`, nested `/* */`,
    normal strings with escapes, and raw strings `r"..."`/`r#"..."#`."""
    out = []
    i, n = 0, len(text)
    depth = 0  # block-comment nesting
    while i < n:
        c = text[i]
        if depth > 0:
            if text.startswith("/*", i):
                depth += 1
                i += 2
            elif text.startswith("*/", i):
                depth -= 1
                i += 2
            else:
                out.append(c if c == "\n" else " ")
                i += 1
            continue
        if text.startswith("//", i):
            j = text.find("\n", i)
            i = n if j == -1 else j
            continue
        if text.startswith("/*", i):
            depth = 1
            i += 2
            continue
        if c == '"' or (c == "r" and i + 1 < n and text[i + 1 : i + 3].lstrip("#").startswith('"')):
            # string literal (possibly raw); blank to the matching close
            if c == "r":
                j = i + 1
                hashes = 0
                while j < n and text[j] == "#":
                    hashes += 1
                    j += 1
                close = '"' + "#" * hashes
                j = text.find(close, j + 1)
                i = n if j == -1 else j + len(close)
            else:
                j = i + 1
                while j < n:
                    if text[j] == "\\":
                        j += 2
                    elif text[j] == '"':
                        j += 1
                        break
                    else:
                        j += 1
                i = j
            out.append(" ")
            continue
        out.append(c)
        i += 1
    return "".join(out).split("\n")


def check_file(rel: str, text: str) -> list[str]:
    """All rule violations for one file, as `rel:line: message` strings."""
    errors = []
    raw_lines = text.split("\n")
    code_lines = strip_code(text)

    in_allowlist = rel in UNSAFE_ALLOWLIST

    # --- rules 1 + 2: unsafe keyword placement and discipline ----------
    for idx, code in enumerate(code_lines):
        if not UNSAFE_KEYWORD.search(code):
            continue
        line_no = idx + 1
        if not in_allowlist:
            errors.append(
                f"{rel}:{line_no}: `unsafe` outside the allowlisted SIMD modules "
                f"({', '.join(sorted(UNSAFE_ALLOWLIST))}) — move the unsafe code "
                "behind a safe API in an allowlisted module, or extend the "
                "allowlist in tools/lint_gate.py with a justification"
            )
            continue
        if re.search(r"\bunsafe\s+(?:extern\s+)?fn\b", code):
            # an `unsafe fn` must document its contract for callers
            has_safety_doc = any(
                re.search(r"#\s*Safety", raw_lines[j])
                for j in range(max(0, idx - SAFETY_WINDOW), idx)
            )
            if not has_safety_doc:
                errors.append(
                    f"{rel}:{line_no}: `unsafe fn` without a `/// # Safety` doc "
                    "section — document the caller contract directly above it"
                )
        elif re.search(r"\bunsafe\s*\{", code):
            has_safety_comment = any(
                raw_lines[j].lstrip().startswith("// SAFETY:")
                for j in range(max(0, idx - SAFETY_WINDOW), idx)
            )
            if not has_safety_comment:
                errors.append(
                    f"{rel}:{line_no}: `unsafe {{` block without a `// SAFETY:` "
                    "comment in the preceding lines — state why every operation "
                    "inside the block is sound"
                )
        # bare `unsafe` in other positions (e.g. `unsafe impl`) — flag it;
        # nothing in this crate should need one
        elif not re.search(r"\bunsafe\b\s*$", code):
            errors.append(
                f"{rel}:{line_no}: unexpected `unsafe` form (not a fn or block) — "
                "this crate's policy covers only `unsafe fn` and `unsafe {{}}`"
            )

    # --- rule 3: sync-shim discipline ----------------------------------
    if rel in SHIMMED:
        for idx, code in enumerate(code_lines):
            m = STD_SYNC.search(code)
            if m:
                errors.append(
                    f"{rel}:{idx + 1}: direct `std::{m.group(1)}` in a loom-modelled "
                    "module — import it from `crate::util::sync` instead, so the "
                    "`--cfg loom` build swaps in the model-checked primitives"
                )

    # --- rule 4: decode bounds -----------------------------------------
    if rel in DECODE_FILES:
        for idx, code in enumerate(code_lines):
            if not WITH_CAPACITY.search(code):
                continue
            # rustfmt may split the ensure! across lines, so scan the
            # preceding window as one blob for both tokens
            window = "\n".join(code_lines[max(0, idx - BOUNDS_WINDOW) : idx])
            guarded = "ensure!" in window and "MAX_" in window
            if not guarded:
                errors.append(
                    f"{rel}:{idx + 1}: `with_capacity` in a wire-decode path with no "
                    f"`ensure!(.. MAX_..)` cap within {BOUNDS_WINDOW} lines above — "
                    "an untrusted length must be validated before it sizes an "
                    "allocation"
                )

    return errors


def check_repo(root: str) -> list[str]:
    errors = []
    src_root = os.path.join(root, "rust", "src")
    if not os.path.isdir(src_root):
        print(f"lint_gate: no rust/src under {root!r}", file=sys.stderr)
        sys.exit(2)

    for attr_rel, attr in REQUIRED_ATTRS:
        path = os.path.join(root, attr_rel)
        if not os.path.isfile(path):
            errors.append(f"{attr_rel}:1: file missing but required to carry `{attr}`")
            continue
        with open(path, encoding="utf-8") as f:
            if attr not in f.read():
                errors.append(
                    f"{attr_rel}:1: missing `{attr}` — the crate-level unsafe "
                    "posture must be pinned in the source, not just in CI"
                )

    for dirpath, _dirnames, filenames in os.walk(src_root):
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                errors.extend(check_file(rel, f.read()))
    return errors


# --- self-test against the committed negative fixtures ---------------------

# fixture file -> (rule-path it impersonates, substring the violation must
# contain; None = must be clean)
FIXTURES = {
    "missing_safety_comment.rs": ("rust/src/tos/kernel.rs", "without a `// SAFETY:`"),
    "unsafe_in_forbidden_module.rs": ("rust/src/serve/mod.rs", "outside the allowlisted"),
    "unshimmed_std_sync.rs": ("rust/src/serve/pool.rs", "direct `std::sync`"),
    "unbounded_decode.rs": ("rust/src/serve/wire.rs", "no `ensure!(.. MAX_..)` cap"),
    "clean.rs": ("rust/src/tos/kernel.rs", None),
}


def self_test(root: str) -> int:
    fixture_dir = os.path.join(root, "tools", "fixtures", "lint_gate")
    if not os.path.isdir(fixture_dir):
        print(f"lint_gate --self-test: no fixtures at {fixture_dir}", file=sys.stderr)
        return 2
    failures = 0
    for name, (impersonate, want) in sorted(FIXTURES.items()):
        path = os.path.join(fixture_dir, name)
        if not os.path.isfile(path):
            print(f"SELF-TEST FAIL {name}: fixture file missing")
            failures += 1
            continue
        with open(path, encoding="utf-8") as f:
            errors = check_file(impersonate, f.read())
        if want is None:
            if errors:
                print(f"SELF-TEST FAIL {name}: expected clean, got: {errors[0]}")
                failures += 1
            else:
                print(f"self-test ok   {name}: clean as expected")
        elif not any(want in e for e in errors):
            got = errors[0] if errors else "(no violations at all)"
            print(f"SELF-TEST FAIL {name}: expected a violation containing "
                  f"{want!r}, got: {got}")
            failures += 1
        else:
            print(f"self-test ok   {name}: caught as expected")
    # the comment/string stripper must not eat real code
    probe = strip_code('let a = "unsafe {"; // unsafe {\nunsafe { x() }\n')
    if UNSAFE_KEYWORD.search(probe[0]) or not UNSAFE_KEYWORD.search(probe[1]):
        print("SELF-TEST FAIL stripper: comment/string stripping is wrong")
        failures += 1
    else:
        print("self-test ok   stripper: strings and comments are blanked")
    if failures:
        print(f"lint_gate self-test: {failures} FAILURE(S)")
        return 1
    print("lint_gate self-test: all fixtures behave")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".", help="repo root (default: cwd)")
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the rules against tools/fixtures/lint_gate/ and exit",
    )
    args = ap.parse_args()

    if args.self_test:
        return self_test(args.root)

    errors = check_repo(args.root)
    if errors:
        for e in errors:
            print(e)
        print(f"lint_gate: {len(errors)} violation(s)")
        return 1
    print("lint_gate: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
