"""Self-test of nmc-analyze: engine unit checks plus the per-rule
fixture suite under tools/fixtures/analyze/.

Every registered rule must ship one positive fixture tree (the rule
fires, unsuppressed) and one negative tree (a full-registry run is
completely clean — negatives double as false-positive regression nets).
Fixture trees are mini repos: the same walker that scans the real repo
loads them, so path-scoped rules see the paths they key on.

Also pins the findings-JSON schema (nmc-analyze-v1): key sets of the
report, finding, rule and count objects are asserted exactly, so a
schema change must touch this file and announce itself in review.
"""

from __future__ import annotations

import json
import os

import core

FIXTURES = os.path.join("tools", "fixtures", "analyze")

# Key sets pinned by the schema regression test. Extending the schema is
# fine — do it by bumping core.SCHEMA and updating these sets in the
# same change.
REPORT_KEYS = {"schema", "rules", "findings", "counts", "clean"}
FINDING_KEYS = {"rule", "file", "line", "message", "suppressed", "justification"}
RULE_KEYS = {"id", "summary"}
COUNT_KEYS = {"found", "suppressed"}


class Failure(Exception):
    pass


def check(cond: bool, what: str) -> None:
    if not cond:
        raise Failure(what)


# --- engine unit checks -----------------------------------------------------


def test_stripper() -> None:
    lines = core.strip_code('let x = 1; // unsafe in a comment\nlet s = "unsafe";')
    check("unsafe" not in lines[0], "line comment not blanked")
    check("unsafe" not in lines[1], "string literal not blanked")
    check("let x = 1;" in lines[0], "code before comment lost")

    lines = core.strip_code("a /* one /* two */ still comment */ b\nc")
    check("still" not in lines[0], "nested block comment not blanked")
    check(lines[0].startswith("a ") and lines[0].rstrip().endswith("b"), "code around block comment lost")
    check(lines[1] == "c", "line structure not preserved across block comment")

    lines = core.strip_code('let r = r#"unsafe " quote"#; tail')
    check("unsafe" not in lines[0], "raw string not blanked")
    check("tail" in lines[0], "code after raw string lost")

    lines = core.strip_code('let e = "esc \\" unsafe"; tail')
    check("unsafe" not in lines[0], "escaped-quote string not blanked")
    check("tail" in lines[0], "code after escaped string lost")

    check(
        len(core.strip_code("a\n/*\nmulti\n*/\nb")) == 5,
        "stripping changed the line count",
    )
    check(
        len(core.strip_code('let s = "line one \\\n    line two";\nafter')) == 3,
        "multi-line string literal collapsed line numbering",
    )
    check(
        len(core.strip_code('let r = r#"raw\nspans\nlines"#;\nafter')) == 4,
        "multi-line raw string collapsed line numbering",
    )


def test_suppressions() -> None:
    shim_line = "use std::sync::Mutex;"

    def pool(text: str) -> dict:
        return {"rust/src/serve/pool.rs": text}

    # unsuppressed baseline
    fs = core.run_rules(pool(shim_line))
    check(
        any(f.rule == "sync-shim" and not f.suppressed for f in fs),
        "baseline sync-shim finding missing",
    )

    # same-line, justified -> suppressed, and hygiene stays quiet
    fs = core.run_rules(
        pool(shim_line + " // nmc-analyze: allow(sync-shim) -- fixture exercises the engine")
    )
    check(
        all(f.suppressed for f in fs if f.rule == "sync-shim"),
        "same-line justified suppression did not suppress",
    )
    check(
        not any(f.rule == "suppression-hygiene" for f in fs),
        "used justified suppression flagged by hygiene",
    )

    # comment-above with next=2 covers two lines below
    fs = core.run_rules(
        pool(
            "// nmc-analyze: allow(sync-shim, next=2) -- fixture exercises span cover\n"
            "\n" + shim_line
        )
    )
    check(
        all(f.suppressed for f in fs if f.rule == "sync-shim"),
        "next=2 span did not cover line+2",
    )

    # default span (1) does NOT reach line+2
    fs = core.run_rules(
        pool(
            "// nmc-analyze: allow(sync-shim) -- fixture exercises default span\n"
            "\n" + shim_line
        )
    )
    check(
        any(f.rule == "sync-shim" and not f.suppressed for f in fs),
        "default span wrongly covered line+2",
    )
    check(
        any(f.rule == "suppression-hygiene" and "unused" in f.message for f in fs),
        "out-of-span suppression not reported unused",
    )

    # missing justification -> finding stays live + hygiene fires
    fs = core.run_rules(pool(shim_line + " // nmc-analyze: allow(sync-shim)"))
    check(
        any(f.rule == "sync-shim" and not f.suppressed for f in fs),
        "unjustified suppression suppressed a finding",
    )
    check(
        any(f.rule == "suppression-hygiene" and "justification" in f.message for f in fs),
        "unjustified suppression not reported",
    )

    # unknown rule -> hygiene fires, nothing suppressed
    fs = core.run_rules(
        pool(shim_line + " // nmc-analyze: allow(not-a-rule) -- long enough reason here")
    )
    check(
        any(f.rule == "suppression-hygiene" and "unknown rule" in f.message for f in fs),
        "unknown-rule suppression not reported",
    )
    check(
        any(f.rule == "sync-shim" and not f.suppressed for f in fs),
        "unknown-rule suppression suppressed a finding",
    )


def test_schema(root: str) -> None:
    # the suppression-hygiene negative tree carries a real suppressed
    # finding, so every schema field is exercised with live data
    tree = os.path.join(root, FIXTURES, "suppression-hygiene", "negative")
    files = core.collect_files(tree)
    check(bool(files), "schema fixture tree is empty")
    findings = core.run_rules(files)
    report = json.loads(json.dumps(core.report_json(findings)))

    check(set(report) == REPORT_KEYS, f"report keys drifted: {sorted(report)}")
    check(report["schema"] == core.SCHEMA, "schema id drifted")
    check(report["clean"] is True, "schema fixture tree is not clean")
    check(len(report["rules"]) >= 9, "fewer than 9 registered rules")
    for r in report["rules"]:
        check(set(r) == RULE_KEYS, f"rule keys drifted: {sorted(r)}")
    check(bool(report["findings"]), "schema fixture produced no findings")
    for f in report["findings"]:
        check(set(f) == FINDING_KEYS, f"finding keys drifted: {sorted(f)}")
        check(isinstance(f["line"], int) and f["line"] >= 1, "finding line not 1-based int")
    check(set(report["counts"]) == core.rule_ids(), "counts keys != registered rules")
    for c in report["counts"].values():
        check(set(c) == COUNT_KEYS, f"count keys drifted: {sorted(c)}")

    table = core.summary_table(findings)
    check(table.startswith("| rule |"), "summary table header drifted")
    check(all(f"`{rid}`" in table for rid in core.rule_ids()), "summary table misses a rule")


# --- the fixture suite ------------------------------------------------------


def run_fixture(root: str, rule_id: str, kind: str) -> None:
    tree = os.path.join(root, FIXTURES, rule_id, kind)
    check(os.path.isdir(tree), f"missing fixture tree {tree}")
    files = core.collect_files(tree)
    check(bool(files), f"fixture tree {tree} is empty")
    findings = core.run_rules(files)
    live = [f for f in findings if not f.suppressed]
    if kind == "positive":
        check(
            any(f.rule == rule_id for f in live),
            f"positive fixture for `{rule_id}` produced no unsuppressed "
            f"{rule_id} finding (got: {[f.render() for f in live] or 'clean'})",
        )
    else:
        check(
            not live,
            f"negative fixture for `{rule_id}` is not clean: "
            + "; ".join(f.render() for f in live),
        )


def run(root: str) -> int:
    failures = []
    unit_tests = [
        ("stripper", lambda: test_stripper()),
        ("suppressions", lambda: test_suppressions()),
        ("json-schema", lambda: test_schema(root)),
    ]
    results = []
    for name, fn in unit_tests:
        try:
            fn()
            results.append(f"  ok  engine::{name}")
        except Failure as e:
            failures.append(f"engine::{name}: {e}")
            results.append(f"FAIL  engine::{name}: {e}")
    for rule in core.REGISTRY:
        for kind in ("positive", "negative"):
            try:
                run_fixture(root, rule.id, kind)
                results.append(f"  ok  {rule.id}::{kind}")
            except Failure as e:
                failures.append(f"{rule.id}::{kind}: {e}")
                results.append(f"FAIL  {rule.id}::{kind}: {e}")
    print("\n".join(results))
    n = len(results)
    if failures:
        print(f"nmc-analyze --self-test: {len(failures)}/{n} checks FAILED")
        return 1
    print(f"nmc-analyze --self-test: {n} checks passed ({len(core.REGISTRY)} rules, all with fixtures)")
    return 0
