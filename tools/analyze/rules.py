"""The rule catalog of nmc-analyze.

Four rules are ported from tools/lint_gate.py (PR 7); the rest encode
the invariants behind the repo's headline claims — byte-identical
reports, bit-exact vector kernels, panic-free untrusted-input decoding,
single-sourced wire tags, and docs that match the binary. DESIGN.md
§Correctness tooling is the prose catalog (ID, invariant, rationale,
suppression policy); this file is the executable one.

Every checker takes the full file map so cross-file rules (oracle
coverage, doc drift) can see tests and docs. Scope tables below are
calibrated to this codebase on purpose — an analyzer that guesses scopes
generically would either miss these files or drown in false positives.
"""

from __future__ import annotations

import re

from core import Context, Finding, rule

# --- policy tables (carried over from lint_gate.py, plus the new scopes) ----

# Modules allowed to contain the `unsafe` keyword; each must opt in
# explicitly. Everything else in rust/src is unsafe-free.
UNSAFE_ALLOWLIST = {
    "rust/src/tos/kernel.rs",
    "rust/src/stcf/mod.rs",
}

# (file, required attribute) pairs pinning the crate-level posture.
REQUIRED_ATTRS = [
    ("rust/src/lib.rs", "#![deny(unsafe_code)]"),
    ("rust/src/main.rs", "#![forbid(unsafe_code)]"),
    ("rust/src/tos/kernel.rs", "#![allow(unsafe_code)]"),
    ("rust/src/stcf/mod.rs", "#![allow(unsafe_code)]"),
]

# Modules whose synchronization must come from crate::util::sync.
SHIMMED = {
    "rust/src/serve/mod.rs",
    "rust/src/serve/pool.rs",
    "rust/src/coordinator/mod.rs",
    "rust/src/coordinator/lut_worker.rs",
    "rust/src/tos/sharded.rs",
}

# Files whose decode paths handle untrusted lengths.
DECODE_FILES = {
    "rust/src/serve/wire.rs",
    "rust/src/events/codec.rs",
    "rust/src/events/codec/aedat4.rs",
    "rust/src/events/codec/evt.rs",
}

# Modules that emit the byte-identical JSON reports (vdd-sweep,
# dataset-eval, fig harnesses) or the machine-readable bench JSON.
DETERMINISM_PREFIXES = ("rust/src/eval/", "rust/src/datasets/", "rust/benches/")

# The bench harness measures wall time by design; `Instant::now` is its
# measurement primitive, not a determinism leak. Report modules get the
# stricter set.
WALL_CLOCK_EXEMPT_PREFIXES = ("rust/benches/",)

# Modules that decode bytes an attacker controls: a panic here is a
# remote DoS, so the error path must be Result all the way down.
ERROR_DISCIPLINE_FILES = {
    "rust/src/serve/wire.rs",
    "rust/src/events/codec.rs",
    "rust/src/events/codec/aedat4.rs",
    "rust/src/events/codec/evt.rs",
    "rust/src/datasets/public.rs",
}

WIRE_FILE = "rust/src/serve/wire.rs"

SAFETY_WINDOW = 14
BOUNDS_WINDOW = 10

UNSAFE_KEYWORD = re.compile(r"\bunsafe\b")
STD_SYNC = re.compile(r"\bstd\s*::\s*(sync|thread)\b")
WITH_CAPACITY = re.compile(r"\bwith_capacity\s*\(")
FN_DEF = re.compile(r"\bfn\s+([A-Za-z0-9_]+)")
SIMD_NAME = re.compile(r"(swar|sse2|avx2|neon|simd)", re.IGNORECASE)
PANIC_FAMILY = re.compile(r"\.unwrap\s*\(\)|\.expect\s*\(|\bpanic!\s*[({]|\bunreachable!\s*[({]|\btodo!\s*[({]|\bunimplemented!\s*[({]")
UNTRUSTED_INDEX = re.compile(
    r"\w+\s*\[[^\]\[]*\b(count|len|size|num|off|offset|idx|pos)[a-z0-9_]*\b[^\]\[]*\]"
)
TAG_BYTE_LITERAL = re.compile(r"\bb'[^']+'")
TAG_CONST_DEF = re.compile(r"\bconst\s+((?:MSG|ACK|WIRE_V)[A-Z0-9_]*|[A-Z0-9_]*MAGIC)\s*:")
CLI_FLAG_LOOKUP = re.compile(r"args\s*\.\s*(?:get|num|flag)\s*\(\s*\"([a-z0-9-]+)\"")
# only actual environment reads: a bare NMC_* identifier is usually a
# Rust const (e.g. NMC_MIN_THRESHOLD), not a knob
ENV_VAR = re.compile(r"env::var(?:_os)?\s*\(\s*\"(NMC_[A-Z][A-Z0-9_]*)\"")
FLOAT_FMT_UNSTABLE = re.compile(r"\{[^{}]*:[^{}]*(?:\.\*|\.[a-z_]+\$|[eE]\})")


# --- ported rule 1: SAFETY-comment discipline -------------------------------


@rule(
    "unsafe-safety-comment",
    "every `unsafe {}` carries `// SAFETY:` and every `unsafe fn` a `/// # Safety` section",
)
def check_safety_comments(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        if rel not in UNSAFE_ALLOWLIST:
            continue
        raw = ctx.raw_lines(rel)
        for idx, code in enumerate(ctx.stripped(rel)):
            if not UNSAFE_KEYWORD.search(code):
                continue
            if re.search(r"\bunsafe\s+(?:extern\s+)?fn\b", code):
                has_doc = any(
                    re.search(r"#\s*Safety", raw[j])
                    for j in range(max(0, idx - SAFETY_WINDOW), idx)
                )
                if not has_doc:
                    out.append(
                        Finding(
                            "unsafe-safety-comment",
                            rel,
                            idx + 1,
                            "`unsafe fn` without a `/// # Safety` doc section — "
                            "document the caller contract directly above it",
                        )
                    )
            elif re.search(r"\bunsafe\s*\{", code):
                has_comment = any(
                    raw[j].lstrip().startswith("// SAFETY:")
                    for j in range(max(0, idx - SAFETY_WINDOW), idx)
                )
                if not has_comment:
                    out.append(
                        Finding(
                            "unsafe-safety-comment",
                            rel,
                            idx + 1,
                            "`unsafe {` block without a `// SAFETY:` comment in the "
                            "preceding lines — state why every operation inside the "
                            "block is sound",
                        )
                    )
            elif not re.search(r"\bunsafe\b\s*$", code):
                out.append(
                    Finding(
                        "unsafe-safety-comment",
                        rel,
                        idx + 1,
                        "unexpected `unsafe` form (not a fn or block) — this crate's "
                        "policy covers only `unsafe fn` and `unsafe {}`",
                    )
                )
    return out


# --- ported rule 2: unsafe allowlist + crate posture ------------------------


@rule(
    "unsafe-allowlist",
    "`unsafe` only in the two SIMD modules; lib/main pin deny/forbid(unsafe_code)",
)
def check_unsafe_allowlist(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel, attr in REQUIRED_ATTRS:
        if rel not in files:
            # fixture mini-trees carry only the files under test; the repo
            # scan always has all four
            continue
        if attr not in files[rel]:
            out.append(
                Finding(
                    "unsafe-allowlist",
                    rel,
                    1,
                    f"missing `{attr}` — the crate-level unsafe posture must be "
                    "pinned in the source, not just in CI",
                )
            )
    for rel in sorted(files):
        if not rel.startswith("rust/src/") or rel in UNSAFE_ALLOWLIST:
            continue
        for idx, code in enumerate(ctx.stripped(rel)):
            if UNSAFE_KEYWORD.search(code):
                out.append(
                    Finding(
                        "unsafe-allowlist",
                        rel,
                        idx + 1,
                        "`unsafe` outside the allowlisted SIMD modules "
                        f"({', '.join(sorted(UNSAFE_ALLOWLIST))}) — move the unsafe "
                        "code behind a safe API in an allowlisted module, or extend "
                        "the allowlist in tools/analyze/rules.py with a justification",
                    )
                )
    return out


# --- ported rule 3: sync-shim discipline ------------------------------------


@rule(
    "sync-shim",
    "loom-modelled modules import synchronization only from crate::util::sync",
)
def check_sync_shim(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        if rel not in SHIMMED:
            continue
        for idx, code in enumerate(ctx.stripped(rel)):
            m = STD_SYNC.search(code)
            if m:
                out.append(
                    Finding(
                        "sync-shim",
                        rel,
                        idx + 1,
                        f"direct `std::{m.group(1)}` in a loom-modelled module — "
                        "import it from `crate::util::sync` instead, so the "
                        "`--cfg loom` build swaps in the model-checked primitives",
                    )
                )
    return out


# --- ported rule 4: decode bounds -------------------------------------------


@rule(
    "decode-bounds",
    "untrusted lengths pass an `ensure!(.. MAX_..)` cap before sizing any allocation",
)
def check_decode_bounds(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        if rel not in DECODE_FILES:
            continue
        code_lines = ctx.stripped(rel)
        for idx, code in enumerate(code_lines):
            if not WITH_CAPACITY.search(code):
                continue
            window = "\n".join(code_lines[max(0, idx - BOUNDS_WINDOW) : idx])
            if not ("ensure!" in window and "MAX_" in window):
                out.append(
                    Finding(
                        "decode-bounds",
                        rel,
                        idx + 1,
                        "`with_capacity` in a wire-decode path with no "
                        f"`ensure!(.. MAX_..)` cap within {BOUNDS_WINDOW} lines above "
                        "— an untrusted length must be validated before it sizes an "
                        "allocation",
                    )
                )
    return out


# --- new rule R1: report determinism ----------------------------------------


@rule(
    "report-determinism",
    "report-emitting modules use no HashMap/HashSet/SystemTime/wall-clock "
    "or unstable float formatting",
)
def check_report_determinism(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        if not rel.startswith(DETERMINISM_PREFIXES) or not rel.endswith(".rs"):
            continue
        wall_clock_ok = rel.startswith(WALL_CLOCK_EXEMPT_PREFIXES)
        for idx, code in enumerate(ctx.stripped(rel)):
            if ctx.in_test(rel, idx):
                break
            if re.search(r"\bHash(Map|Set)\b", code):
                out.append(
                    Finding(
                        "report-determinism",
                        rel,
                        idx + 1,
                        "HashMap/HashSet in a byte-identical-report module — "
                        "iteration order is randomized per process, which breaks "
                        "the `cmp`-gated determinism contract; use BTreeMap/BTreeSet",
                    )
                )
            if "SystemTime" in code or (not wall_clock_ok and "Instant::now" in code):
                out.append(
                    Finding(
                        "report-determinism",
                        rel,
                        idx + 1,
                        "wall-clock read in a deterministic-report module — reports "
                        "must be byte-identical across runs, so no timestamps may "
                        "reach them (the bench harness alone measures time)",
                    )
                )
        # format specs live inside string literals, so scan raw lines
        for idx, line in enumerate(ctx.raw_lines(rel)):
            if ctx.in_test(rel, idx):
                break
            if FLOAT_FMT_UNSTABLE.search(line):
                out.append(
                    Finding(
                        "report-determinism",
                        rel,
                        idx + 1,
                        "dynamic-precision or scientific float formatting in a "
                        "report module — render numbers through `util::json::Json` "
                        "(shortest-roundtrip, byte-stable) or a fixed `{:.N}` spec",
                    )
                )
    return out


# --- new rule R2: oracle coverage -------------------------------------------


@rule(
    "oracle-coverage",
    "every SIMD/SWAR kernel has a `_scalar` oracle, is wired into dispatch, "
    "and the oracle is exercised by tests",
)
def check_oracle_coverage(files: dict, ctx: Context) -> list[Finding]:
    out = []
    # all test text: trailing #[cfg(test)] regions plus rust/tests/
    test_blobs = []
    for rel, text in files.items():
        if rel.startswith("rust/tests/"):
            test_blobs.append(text)
        elif rel.endswith(".rs"):
            start = ctx.test_start(rel)
            lines = ctx.raw_lines(rel)
            if start < len(lines):
                test_blobs.append("\n".join(lines[start:]))
    test_text = "\n".join(test_blobs)

    for rel in sorted(files):
        if rel not in UNSAFE_ALLOWLIST:
            continue
        code_lines = ctx.stripped(rel)
        defs: dict = {}  # fn name -> 1-based def line (non-test only)
        for idx, code in enumerate(code_lines):
            if ctx.in_test(rel, idx):
                break
            m = FN_DEF.search(code)
            if m and m.group(1) not in defs:
                defs[m.group(1)] = idx + 1
        simd_fns = {n: ln for n, ln in defs.items() if SIMD_NAME.search(n)}
        scalar_fns = {n: ln for n, ln in defs.items() if n.endswith("_scalar")}
        if not simd_fns:
            continue
        if not scalar_fns:
            out.append(
                Finding(
                    "oracle-coverage",
                    rel,
                    min(simd_fns.values()),
                    "SIMD/SWAR kernels with no `*_scalar` oracle in the module — "
                    "keep the scalar reference form as the bit-exactness oracle "
                    "every vector path is tested against",
                )
            )
        body = "\n".join(code_lines)
        for name, ln in sorted(simd_fns.items(), key=lambda kv: kv[1]):
            refs = len(re.findall(rf"\b{re.escape(name)}\b", body + "\n" + test_text))
            if refs <= 1:  # only its own definition
                out.append(
                    Finding(
                        "oracle-coverage",
                        rel,
                        ln,
                        f"vector kernel `{name}` is never referenced outside its "
                        "definition — wire it into the dispatch layer and the "
                        "per-path equivalence tests, or delete it",
                    )
                )
        for name, ln in sorted(scalar_fns.items(), key=lambda kv: kv[1]):
            if not re.search(rf"\b{re.escape(name)}\b", test_text):
                out.append(
                    Finding(
                        "oracle-coverage",
                        rel,
                        ln,
                        f"scalar oracle `{name}` is not referenced by any test — "
                        "an oracle nothing compares against proves nothing; add "
                        "the vector-vs-scalar equivalence test",
                    )
                )
    return out


# --- new rule R3: error discipline ------------------------------------------


@rule(
    "error-discipline",
    "no unwrap/expect/panic!/untrusted indexing in the untrusted-input decode modules",
)
def check_error_discipline(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        if rel not in ERROR_DISCIPLINE_FILES:
            continue
        code_lines = ctx.stripped(rel)
        for idx, code in enumerate(code_lines):
            if ctx.in_test(rel, idx):
                break
            m = PANIC_FAMILY.search(code)
            if m:
                out.append(
                    Finding(
                        "error-discipline",
                        rel,
                        idx + 1,
                        f"`{m.group(0).strip()}` in an untrusted-input decode module "
                        "— a panic on attacker-controlled bytes is a remote DoS; "
                        "return the error (`ensure!`/`bail!`/`?`) instead",
                    )
                )
            mi = UNTRUSTED_INDEX.search(code)
            if mi:
                window = "\n".join(code_lines[max(0, idx - 8) : idx + 1])
                evidence = (
                    "ensure!" in window
                    or ".get(" in window
                    or ".min(" in window
                    or "checked_" in window
                )
                if not evidence:
                    out.append(
                        Finding(
                            "error-discipline",
                            rel,
                            idx + 1,
                            f"indexing by a length-like value (`{mi.group(0).strip()}`) "
                            "with no bounds evidence (`ensure!`/`.get(`/`.min(`/"
                            "`checked_*`) in the preceding lines — an untrusted "
                            "offset must be validated before it indexes a buffer",
                        )
                    )
    return out


# --- new rule R4: wire-tag single-source ------------------------------------


@rule(
    "wire-tag-const",
    "every wire tag/magic/version byte is a named const referenced by both "
    "encode and decode sides",
)
def check_wire_tag_const(files: dict, ctx: Context) -> list[Finding]:
    out = []
    for rel in sorted(files):
        # the repo has one wire module; fixtures impersonate the same path
        if rel != WIRE_FILE:
            continue
        code_lines = ctx.stripped(rel)
        consts: dict = {}
        for idx, code in enumerate(code_lines):
            if ctx.in_test(rel, idx):
                break
            m = TAG_CONST_DEF.search(code)
            if m:
                consts[m.group(1)] = idx + 1
            if TAG_CONST_DEF.search(code) is None and TAG_BYTE_LITERAL.search(code):
                out.append(
                    Finding(
                        "wire-tag-const",
                        rel,
                        idx + 1,
                        f"raw byte literal `{TAG_BYTE_LITERAL.search(code).group(0)}` "
                        "in the wire module — name it as a `const` so encode and "
                        "decode share one definition (a drifting tag is a silent "
                        "protocol fork)",
                    )
                )
        # count references across ALL non-test code: one side of a tag
        # exchange may live in serve/mod.rs or the coordinator, not in
        # the wire module itself
        blobs = []
        for other in sorted(files):
            if not other.endswith(".rs"):
                continue
            blobs.append("\n".join(ctx.stripped(other)[: ctx.test_start(other)]))
        body = "\n".join(blobs)
        for name, ln in sorted(consts.items(), key=lambda kv: kv[1]):
            refs = len(re.findall(rf"\b{re.escape(name)}\b", body)) - 1
            if refs < 2:
                out.append(
                    Finding(
                        "wire-tag-const",
                        rel,
                        ln,
                        f"wire const `{name}` referenced {refs} time(s) outside its "
                        "definition in non-test code — a protocol tag must be used "
                        "by both the encode and decode sides (>= 2 references), or "
                        "deleted",
                    )
                )
    return out


# --- new rule R5: doc drift -------------------------------------------------


@rule(
    "doc-drift",
    "every CLI flag is documented in README.md and every NMC_* env var in DESIGN.md",
)
def check_doc_drift(files: dict, ctx: Context) -> list[Finding]:
    out = []
    main = "rust/src/main.rs"
    readme = files.get("README.md", "")
    design = files.get("DESIGN.md", "")
    if main in files and "README.md" in files:
        lines = ctx.stripped(main)
        seen = set()
        for idx, code in enumerate(lines):
            for m in CLI_FLAG_LOOKUP.finditer(ctx.raw_lines(main)[idx]):
                flag = m.group(1)
                if flag in seen:
                    continue
                seen.add(flag)
                if f"--{flag}" not in readme:
                    out.append(
                        Finding(
                            "doc-drift",
                            main,
                            idx + 1,
                            f"CLI flag `--{flag}` is parsed here but never appears "
                            "in README.md — document it (README is the user-facing "
                            "flag reference; DESIGN.md mirrors the full index)",
                        )
                    )
    if "DESIGN.md" in files:
        seen = set()
        for rel in sorted(files):
            if not (rel.startswith("rust/") and rel.endswith(".rs")):
                continue
            for idx, line in enumerate(ctx.raw_lines(rel)):
                for m in ENV_VAR.finditer(line):
                    var = m.group(1)
                    if var in seen:
                        continue
                    seen.add(var)
                    if var not in design:
                        out.append(
                            Finding(
                                "doc-drift",
                                rel,
                                idx + 1,
                                f"env var `{var}` is read here but never documented "
                                "in DESIGN.md — every NMC_* knob must be in the "
                                "design doc's env-var table",
                            )
                        )
    return out


# --- new rule R6: cargo-deny ignore justification ---------------------------


@rule(
    "deny-ignore-justification",
    "deny.toml advisories are version-2 checked and every ignored RUSTSEC id "
    "carries a reason",
)
def check_deny_ignores(files: dict, ctx: Context) -> list[Finding]:
    out = []
    rel = "deny.toml"
    if rel not in files:
        return out
    text = files[rel]
    lines = text.split("\n")
    if "[advisories]" not in text:
        out.append(
            Finding(
                "deny-ignore-justification",
                rel,
                1,
                "deny.toml has no `[advisories]` section — the RUSTSEC audit "
                "lane must be configured, not implicit",
            )
        )
        return out
    in_adv = False
    in_ignore_list = False
    for idx, line in enumerate(lines):
        stripped = line.strip()
        if stripped.startswith("["):
            in_adv = stripped == "[advisories]"
            in_ignore_list = False
            continue
        if not in_adv:
            continue
        if re.match(r"ignore\s*=", stripped):
            in_ignore_list = "]" not in stripped
            # entries inline on the same line as `ignore = [ ... ]`
            entries = re.findall(r'"(RUSTSEC-[0-9-]+)"', stripped)
        elif in_ignore_list:
            in_ignore_list = "]" not in stripped
            entries = re.findall(r'"(RUSTSEC-[0-9-]+)"', stripped)
        else:
            continue
        for adv_id in entries:
            has_reason = (
                re.search(r'reason\s*=\s*"[^"]{12,}"', line)
                or re.search(r"#\s*\S.{11,}", line)
                or (idx > 0 and re.search(r"^\s*#\s*\S.{11,}", lines[idx - 1]))
            )
            if not has_reason:
                out.append(
                    Finding(
                        "deny-ignore-justification",
                        rel,
                        idx + 1,
                        f"advisory `{adv_id}` is ignored without a justification — "
                        'use `{ id = "...", reason = "why this is unreachable/'
                        'pending" }` or a comment, same policy as analyzer '
                        "suppressions",
                    )
                )
    return out


# --- the suppression-hygiene meta-rule (checked by the engine) --------------


@rule(
    "suppression-hygiene",
    "every `nmc-analyze: allow(...)` names a real rule, justifies itself, "
    "and covers an actual finding",
)
def check_suppression_hygiene(files: dict, ctx: Context) -> list[Finding]:
    # The engine computes these findings after applying suppressions
    # (core.hygiene_findings); registering the rule here gives it an ID,
    # a summary row, and a fixture slot like every other rule.
    return []
