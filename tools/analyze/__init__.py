"""nmc-analyze — repo-wide invariant analyzer for the nmc-tos crate.

Successor of tools/lint_gate.py (PR 7): the four original invariants are
ported as registered rules and joined by the repo-specific determinism,
oracle-coverage, error-discipline, wire-tag, doc-drift and
suppression-hygiene rules. Stdlib-only; run as `python3 tools/analyze`
from the repo root.

See tools/analyze/core.py for the engine (file scanning, suppression
syntax, JSON findings schema) and tools/analyze/rules.py for the rule
catalog. DESIGN.md §Correctness tooling documents every rule with its
rationale and suppression policy.
"""

SCHEMA = "nmc-analyze-v1"
