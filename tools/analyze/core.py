"""Engine of nmc-analyze: file scanning, Rust source stripping, the
inline-suppression syntax, the rule registry, and the JSON findings
schema.

Dependency-free (stdlib only). The contract mirrors bench_gate.py and
the old lint_gate.py: exit 0 = clean, 1 = unsuppressed findings (each
printed with file:line and a pointed message), 2 = misuse.

## Suppression syntax

A finding is suppressed by a justified inline comment on the same line
or on a line above it:

    // nmc-analyze: allow(<rule-id>[, next=N]) -- <justification>

The suppression covers its own line plus the next N lines (default 1).
The justification after `--` is mandatory and must say *why* the code
is sound, not just restate the rule; a suppression with a missing or
trivial justification, naming an unknown rule, or matching no finding
is itself reported by the `suppression-hygiene` rule.

## Findings JSON (schema nmc-analyze-v1)

    {
      "schema": "nmc-analyze-v1",
      "rules":    [{"id", "summary"}...],
      "findings": [{"rule", "file", "line", "message",
                    "suppressed", "justification"}...],
      "counts":   {"<rule-id>": {"found": N, "suppressed": M}, ...},
      "clean":    bool   # no unsuppressed findings
    }
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field

SCHEMA = "nmc-analyze-v1"

# Files the repo scan feeds to the rules. Fixture trees under
# tools/fixtures/analyze/<rule>/{positive,negative}/ mirror the same
# layout, so the self-test loads them with this same walker.
SCAN_DIRS = ("rust/src", "rust/tests", "rust/benches")
SCAN_FILES = ("README.md", "DESIGN.md", "deny.toml")

MIN_JUSTIFICATION_CHARS = 12

SUPPRESS_RE = re.compile(
    r"//\s*nmc-analyze:\s*allow\(\s*([a-z0-9-]+)\s*"
    r"(?:,\s*next\s*=\s*(\d+)\s*)?\)\s*(?:--\s*(.*?))?\s*$"
)


@dataclass
class Finding:
    """One rule violation at a source location."""

    rule: str
    file: str
    line: int
    message: str
    suppressed: bool = False
    justification: str | None = None

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.file}:{self.line}: [{self.rule}]{tag} {self.message}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "file": self.file,
            "line": self.line,
            "message": self.message,
            "suppressed": self.suppressed,
            "justification": self.justification,
        }


@dataclass
class Suppression:
    """A parsed `// nmc-analyze: allow(...)` comment."""

    file: str
    line: int  # 1-based line the comment sits on
    rule: str
    span: int  # lines covered below the comment line
    justification: str
    used: bool = False

    def covers(self, line: int) -> bool:
        return self.line <= line <= self.line + self.span


@dataclass
class Rule:
    """A registered invariant: id, one-line summary, and a checker taking
    the full file map (so cross-file rules see tests and docs)."""

    id: str
    summary: str
    check: object  # callable(files: dict[str, str], ctx: Context) -> list[Finding]


@dataclass
class Context:
    """Per-run caches shared by the rules."""

    files: dict  # rel path -> text
    _stripped: dict = field(default_factory=dict)
    _test_start: dict = field(default_factory=dict)

    def stripped(self, rel: str) -> list[str]:
        """Code lines of `rel` with comments/strings blanked."""
        if rel not in self._stripped:
            self._stripped[rel] = strip_code(self.files[rel])
        return self._stripped[rel]

    def raw_lines(self, rel: str) -> list[str]:
        return self.files[rel].split("\n")

    def test_start(self, rel: str) -> int:
        """0-based index of the first `#[cfg(test)]` line (everything from
        there to EOF is treated as test code), or len(lines) if none.
        Matches this repo's layout: unit tests sit in one trailing
        `#[cfg(test)] mod tests` block."""
        if rel not in self._test_start:
            lines = self.raw_lines(rel)
            start = len(lines)
            for i, ln in enumerate(lines):
                if "#[cfg(test)]" in ln:
                    start = i
                    break
            self._test_start[rel] = start
        return self._test_start[rel]

    def in_test(self, rel: str, idx: int) -> bool:
        """Is 0-based line `idx` inside the trailing test region?"""
        return idx >= self.test_start(rel)


REGISTRY: list[Rule] = []


def rule(rule_id: str, summary: str):
    """Decorator registering a checker in the rule registry."""

    def wrap(fn):
        REGISTRY.append(Rule(rule_id, summary, fn))
        return fn

    return wrap


def rule_ids() -> set[str]:
    return {r.id for r in REGISTRY}


# --- Rust source stripping (carried over from lint_gate.py) ----------------


def strip_code(text: str) -> list[str]:
    """Blank out comments and string literals, preserving line structure,
    so keyword scans don't trip on prose. Handles `//`, nested `/* */`,
    normal strings with escapes, and raw strings `r"..."`/`r#"..."#`."""
    out = []
    i, n = 0, len(text)
    depth = 0  # block-comment nesting
    while i < n:
        c = text[i]
        if depth > 0:
            if text.startswith("/*", i):
                depth += 1
                i += 2
            elif text.startswith("*/", i):
                depth -= 1
                i += 2
            else:
                out.append(c if c == "\n" else " ")
                i += 1
            continue
        if text.startswith("//", i):
            j = text.find("\n", i)
            i = n if j == -1 else j
            continue
        if text.startswith("/*", i):
            depth = 1
            i += 2
            continue
        if c == '"' or (c == "r" and i + 1 < n and text[i + 1 : i + 3].lstrip("#").startswith('"')):
            # string literal (possibly raw); blank to the matching close,
            # preserving interior newlines so line numbers stay aligned
            start = i
            if c == "r":
                j = i + 1
                hashes = 0
                while j < n and text[j] == "#":
                    hashes += 1
                    j += 1
                close = '"' + "#" * hashes
                j = text.find(close, j + 1)
                i = n if j == -1 else j + len(close)
            else:
                j = i + 1
                while j < n:
                    if text[j] == "\\":
                        j += 2
                    elif text[j] == '"':
                        j += 1
                        break
                    else:
                        j += 1
                i = min(j, n)
            out.extend("\n" if ch == "\n" else " " for ch in text[start:i])
            continue
        out.append(c)
        i += 1
    return "".join(out).split("\n")


# --- file collection --------------------------------------------------------


def collect_files(root: str) -> dict:
    """The file map a scan feeds to the rules: all tracked Rust sources
    plus the docs and the cargo-deny config, keyed by /-separated paths
    relative to `root`."""
    files = {}
    for d in SCAN_DIRS:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, _dirnames, filenames in os.walk(top):
            for name in sorted(filenames):
                if not name.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path, encoding="utf-8") as f:
                    files[rel] = f.read()
    for rel in SCAN_FILES:
        path = os.path.join(root, rel)
        if os.path.isfile(path):
            with open(path, encoding="utf-8") as f:
                files[rel] = f.read()
    return files


# --- suppression handling ---------------------------------------------------


def collect_suppressions(files: dict) -> list[Suppression]:
    sups = []
    for rel, text in files.items():
        if not rel.endswith((".rs", ".toml")):
            continue
        comment = "#" if rel.endswith(".toml") else "//"
        for idx, line in enumerate(text.split("\n")):
            m = SUPPRESS_RE.search(line.replace("# nmc-analyze", "// nmc-analyze", 1)
                                   if comment == "#" else line)
            if not m:
                continue
            sups.append(
                Suppression(
                    file=rel,
                    line=idx + 1,
                    rule=m.group(1),
                    span=int(m.group(2)) if m.group(2) else 1,
                    justification=(m.group(3) or "").strip(),
                )
            )
    return sups


def apply_suppressions(findings: list[Finding], sups: list[Suppression]) -> None:
    """Mark findings covered by a valid, justified suppression for their
    rule. Invalid suppressions never suppress (they are reported by the
    suppression-hygiene rule instead)."""
    by_file: dict = {}
    for s in sups:
        if len(s.justification) >= MIN_JUSTIFICATION_CHARS and s.rule in rule_ids():
            by_file.setdefault(s.file, []).append(s)
    for f in findings:
        for s in by_file.get(f.file, ()):
            if s.rule == f.rule and s.covers(f.line):
                f.suppressed = True
                f.justification = s.justification
                s.used = True
                break


def hygiene_findings(sups: list[Suppression]) -> list[Finding]:
    """The suppression-hygiene meta-rule: every suppression must name a
    registered rule, carry a real justification, and actually cover a
    finding (stale allows rot into blanket exemptions)."""
    out = []
    known = rule_ids()
    for s in sups:
        if s.rule not in known:
            out.append(
                Finding(
                    "suppression-hygiene",
                    s.file,
                    s.line,
                    f"suppression names unknown rule `{s.rule}` — registered rules: "
                    + ", ".join(sorted(known)),
                )
            )
        elif len(s.justification) < MIN_JUSTIFICATION_CHARS:
            out.append(
                Finding(
                    "suppression-hygiene",
                    s.file,
                    s.line,
                    "suppression without a justification — append "
                    "`-- <why this specific code is sound>` "
                    f"(>= {MIN_JUSTIFICATION_CHARS} chars)",
                )
            )
        elif not s.used:
            out.append(
                Finding(
                    "suppression-hygiene",
                    s.file,
                    s.line,
                    f"unused suppression for `{s.rule}` — the rule reports nothing "
                    "here; delete the stale allow",
                )
            )
    return out


# --- the run ----------------------------------------------------------------


def run_rules(files: dict, only: str | None = None) -> list[Finding]:
    """Run the registry (or one rule) over a file map, apply suppressions,
    and append the hygiene meta-findings. Returns all findings, suppressed
    ones included (the JSON report keeps them for audit)."""
    ctx = Context(files=files)
    findings: list[Finding] = []
    for r in REGISTRY:
        if r.id == "suppression-hygiene":
            continue  # runs last, below, over the suppression table
        if only is not None and r.id != only:
            continue
        findings.extend(r.check(files, ctx))
    sups = collect_suppressions(files)
    apply_suppressions(findings, sups)
    if only is None or only == "suppression-hygiene":
        findings.extend(hygiene_findings(sups))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings


def counts_by_rule(findings: list[Finding]) -> dict:
    counts = {r.id: {"found": 0, "suppressed": 0} for r in REGISTRY}
    for f in findings:
        c = counts.setdefault(f.rule, {"found": 0, "suppressed": 0})
        c["found"] += 1
        if f.suppressed:
            c["suppressed"] += 1
    return counts


def report_json(findings: list[Finding]) -> dict:
    counts = counts_by_rule(findings)
    return {
        "schema": SCHEMA,
        "rules": [{"id": r.id, "summary": r.summary} for r in REGISTRY],
        "findings": [f.to_json() for f in findings],
        "counts": counts,
        "clean": all(f.suppressed for f in findings),
    }


def summary_table(findings: list[Finding]) -> str:
    """Per-rule GitHub-flavored markdown summary (the CI step summary)."""
    counts = counts_by_rule(findings)
    lines = [
        "| rule | findings | suppressed | status |",
        "|---|---:|---:|---|",
    ]
    for r in REGISTRY:
        c = counts[r.id]
        live = c["found"] - c["suppressed"]
        status = "clean" if live == 0 else f"**{live} open**"
        lines.append(f"| `{r.id}` | {c['found']} | {c['suppressed']} | {status} |")
    return "\n".join(lines) + "\n"


def write_json(path: str, payload: dict) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")
