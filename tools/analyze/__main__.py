"""CLI of nmc-analyze. Run from the repo root:

    python3 tools/analyze                 # scan the repo, exit 1 on findings
    python3 tools/analyze --self-test     # run the fixture suite, exit 1 on failure
    python3 tools/analyze --json out.json # also write the findings JSON
    python3 tools/analyze --summary s.md  # also write the per-rule GFM table
    python3 tools/analyze --rule <id>     # run a single rule (debugging)

Exit codes: 0 clean, 1 unsuppressed findings / self-test failure, 2 misuse.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import core
import rules  # noqa: F401  -- import populates core.REGISTRY
import selftest


def main(argv: list[str]) -> int:
    parser = argparse.ArgumentParser(
        prog="nmc-analyze", description="repo-wide invariant analyzer"
    )
    parser.add_argument(
        "--root",
        default=os.getcwd(),
        help="repo root to scan (default: cwd)",
    )
    parser.add_argument(
        "--self-test",
        action="store_true",
        help="run the per-rule fixture suite and schema regression test",
    )
    parser.add_argument("--json", metavar="PATH", help="write findings JSON here")
    parser.add_argument(
        "--summary", metavar="PATH", help="write the per-rule markdown table here"
    )
    parser.add_argument(
        "--rule", metavar="ID", help="run only this rule (plus suppression handling)"
    )
    args = parser.parse_args(argv)

    if args.rule and args.rule not in core.rule_ids():
        print(
            f"nmc-analyze: unknown rule `{args.rule}`; registered: "
            + ", ".join(sorted(core.rule_ids())),
            file=sys.stderr,
        )
        return 2

    if args.self_test:
        return selftest.run(args.root)

    files = core.collect_files(args.root)
    if not files:
        print(
            f"nmc-analyze: nothing to scan under {args.root} "
            f"(expected {', '.join(core.SCAN_DIRS)})",
            file=sys.stderr,
        )
        return 2

    findings = core.run_rules(files, only=args.rule)
    report = core.report_json(findings)
    if args.json:
        core.write_json(args.json, report)
    if args.summary:
        with open(args.summary, "w", encoding="utf-8") as f:
            f.write(core.summary_table(findings))

    live = [f for f in findings if not f.suppressed]
    for f in findings:
        if f.suppressed:
            continue
        print(f.render())
    n_sup = sum(1 for f in findings if f.suppressed)
    scope = f"rule `{args.rule}`" if args.rule else f"{len(core.REGISTRY)} rules"
    if live:
        print(
            f"nmc-analyze: {len(live)} finding(s) from {scope} "
            f"over {len(files)} files ({n_sup} suppressed)",
            file=sys.stderr,
        )
        return 1
    print(
        f"nmc-analyze: clean — {scope} over {len(files)} files "
        f"({n_sup} suppressed finding(s))"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
