//! Positive fixture: everything lint_gate checks, done right. Must pass
//! when treated as an allowlisted SIMD module.

/// Reads the first byte without a bounds check.
///
/// # Safety
///
/// `data` must be non-empty; the caller guarantees it.
pub unsafe fn read_first_unchecked(data: &[u8]) -> u8 {
    // SAFETY: the caller guarantees `data` is non-empty (this fn's
    // contract), so reading one byte at the base pointer is in bounds.
    unsafe { *data.as_ptr() }
}

/// Safe wrapper: mentions "unsafe {" in a string and a comment, which
/// the gate's stripper must ignore.
pub fn read_first(data: &[u8]) -> u8 {
    assert!(!data.is_empty(), "refusing an unsafe { ... } style read");
    // not an unsafe block: the word unsafe here lives in a comment
    // SAFETY: `data` was just checked non-empty.
    unsafe { read_first_unchecked(data) }
}
