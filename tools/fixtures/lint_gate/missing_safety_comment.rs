//! Negative fixture: an `unsafe` block in an allowlisted module with no
//! `// SAFETY:` comment above it. lint_gate must flag it (rule 1).

pub fn read_first(data: &[u8]) -> u8 {
    assert!(!data.is_empty());
    unsafe { *data.as_ptr() }
}
