//! Negative fixture: a loom-modelled module importing `std::sync`
//! directly instead of `crate::util::sync`. lint_gate must flag it
//! (rule 3) — under `--cfg loom` this type would silently escape the
//! model checker.

use std::sync::Mutex;

pub struct Pool {
    inner: Mutex<Vec<u32>>,
}
