//! Negative fixture: a wire-decode path sizing an allocation from an
//! untrusted length with no `ensure!(.. MAX_..)` cap above it. lint_gate
//! must flag it (rule 4) — a hostile peer could demand gigabytes.

pub fn decode(header: &[u8]) -> Vec<u8> {
    let count = u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
    let mut out = Vec::with_capacity(count);
    out.resize(count.min(header.len()), 0);
    out
}
