//! Negative fixture: `unsafe` in a module that is not on the allowlist.
//! lint_gate must flag it regardless of SAFETY comments (rule 2).

pub fn sneaky(data: &[u8]) -> u8 {
    // SAFETY: documented, but this module may not contain unsafe at all.
    unsafe { *data.as_ptr() }
}
