// nmc-analyze: allow(sync-shim) -- fixture: exercises the suppression machinery end to end
use std::sync::Mutex;
pub struct Pool {
    inner: Mutex<u32>,
}
