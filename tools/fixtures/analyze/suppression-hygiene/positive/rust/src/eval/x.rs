// nmc-analyze: allow(no-such-rule) -- this rule id does not exist
pub fn f() {}
