use std::sync::Mutex;
pub struct Pool {
    inner: Mutex<u32>,
}
