use crate::util::sync::Mutex;
pub struct Pool {
    inner: Mutex<u32>,
}
