use std::collections::BTreeMap;
pub fn tally() -> BTreeMap<String, u32> {
    BTreeMap::new()
}
