use std::collections::HashMap;
pub fn tally() -> HashMap<String, u32> {
    HashMap::new()
}
