pub fn naughty(p: *mut u8) {
    // SAFETY: comments do not make this module allowlisted
    unsafe { p.write(0) }
}
