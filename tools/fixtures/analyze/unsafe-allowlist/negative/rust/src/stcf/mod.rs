#![allow(unsafe_code)]
pub struct Stcf;
