#![allow(unsafe_code)]
pub fn decrement(data: &mut [u8]) {
    for b in data.iter_mut() {
        *b = b.saturating_sub(1);
    }
}
