#![deny(unsafe_code)]
pub mod tos;
pub mod stcf;
