#![forbid(unsafe_code)]
fn main() {}
