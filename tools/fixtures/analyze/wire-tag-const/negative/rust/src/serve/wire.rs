pub const MSG_CORNERS: u8 = b'C';
pub fn encode(out: &mut Vec<u8>) {
    out.push(MSG_CORNERS);
}
pub fn decode(tag: u8) -> bool {
    tag == MSG_CORNERS
}
