pub const MSG_CORNERS: u8 = b'C';
pub fn encode(out: &mut Vec<u8>) {
    out.push(b'S');
    out.push(MSG_CORNERS);
}
