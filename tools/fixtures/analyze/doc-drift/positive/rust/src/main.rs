#![forbid(unsafe_code)]
fn main() {
    let args = parse();
    let _ = args.get("scene");
    let _ = std::env::var("NMC_FIXTURE_KNOB");
}
