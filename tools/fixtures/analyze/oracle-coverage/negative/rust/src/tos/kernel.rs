#![allow(unsafe_code)]
pub fn decrement_clamp(data: &mut [u8]) {
    decrement_clamp_swar(data);
}
pub fn decrement_clamp_swar(data: &mut [u8]) {
    decrement_clamp_scalar(data);
}
pub fn decrement_clamp_scalar(data: &mut [u8]) {
    for b in data.iter_mut() {
        *b = b.saturating_sub(1);
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn swar_matches_oracle() {
        let mut a = [3u8; 4];
        let mut b = a;
        decrement_clamp_swar(&mut a);
        decrement_clamp_scalar(&mut b);
        assert_eq!(a, b);
    }
}
