#![allow(unsafe_code)]
pub fn decrement_clamp_swar(data: &mut [u8]) {
    for b in data.iter_mut() {
        *b = b.saturating_sub(1);
    }
}
