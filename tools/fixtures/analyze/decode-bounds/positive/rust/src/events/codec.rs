pub fn decode(n: usize) -> Vec<u8> {
    Vec::with_capacity(n)
}
