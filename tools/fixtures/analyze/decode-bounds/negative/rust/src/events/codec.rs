const MAX_EVENTS: usize = 1024;
pub fn decode(n: usize) -> crate::Result<Vec<u8>> {
    ensure!(n <= MAX_EVENTS, "chunk too large");
    Ok(Vec::with_capacity(n))
}
