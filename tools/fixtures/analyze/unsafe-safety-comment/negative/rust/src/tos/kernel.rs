#![allow(unsafe_code)]
/// Writes a zero through `p`.
///
/// # Safety
/// `p` must be valid for a one-byte write.
pub unsafe fn helper(p: *mut u8) {
    // SAFETY: the caller contract above guarantees `p` is writable.
    unsafe { p.write(0) }
}
