#![allow(unsafe_code)]
pub fn helper(x: &mut [u8]) {
    unsafe { core::ptr::write(x.as_mut_ptr(), 0) }
}
