pub fn decode(rec: &[u8]) -> u32 {
    let count = rec[0] as usize;
    let v = u32::from_le_bytes(rec[1..5].try_into().unwrap());
    let _ = rec[count];
    v
}
