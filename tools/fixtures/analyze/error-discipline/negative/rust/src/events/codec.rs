pub fn decode(rec: &[u8]) -> crate::Result<u32> {
    ensure!(rec.len() >= 5, "short record");
    let count = rec[0] as usize;
    ensure!(count < rec.len(), "count out of range");
    let b = rec.get(1..5).ok_or(crate::Error::Truncated)?;
    let v = u32::from_le_bytes(b.try_into().map_err(|_| crate::Error::Truncated)?);
    let _ = rec[count];
    Ok(v)
}
