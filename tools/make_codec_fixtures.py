#!/usr/bin/env python3
"""Generate the golden camera-format fixtures under rust/tests/fixtures/.

One canonical 64x64 event stream is encoded into three real camera
container formats — AEDAT4, Prophesee EVT3, Prophesee EVT2 — plus the
two checked-in expected dumps (text + NMCTOSEV binary) that the Rust
conformance tests compare decoded streams against byte-for-byte, a
ground-truth corner-label file, and the dataset-eval manifest.

The script is deterministic (own LCG, no `random`, no clock) and
self-verifying: it re-decodes every encoded fixture with independent
Python decoders that mirror the Rust decoder semantics and asserts the
result equals the canonical stream, so a bug in an encoder cannot be
silently frozen into the golden files.

Stream design notes:

* Timestamps span 16.70 s .. 16.85 s so the EVT3 24-bit time base
  (TIME_HIGH<<12 | TIME_LOW) crosses its 2^24 = 16_777_216 us wraparound
  naturally — the committed EVT3 fixture exercises the resync path.
* Two moving corner trajectories emit 6-event bursts every 2 ms
  (spatio-temporally clustered so the STCF filter passes them), plus a
  horizontal 14-pixel run at a shared timestamp every 10 ms (encoded as
  EVT3 VECT_BASE_X + VECT_12/VECT_8 words), plus LCG noise events.
* All coordinates fit the 64x64 TEST64 geometry and the EVT 11-bit
  coordinate fields; every file stays well under 100 KB.

Usage: python3 tools/make_codec_fixtures.py  (from the repo root)
"""

import json
import os
import struct
import sys

FIXDIR = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")

WIDTH = 64
HEIGHT = 64
T0 = 16_700_000  # us — 77_216 us below the EVT3 2^24 wrap
STEP_US = 2_000
STEPS = 75  # last step at 16_848_000 us, past the wrap


# ---------------------------------------------------------------------------
# canonical stream
# ---------------------------------------------------------------------------


class Lcg:
    """Deterministic 64-bit LCG (constants from Knuth MMIX)."""

    def __init__(self, seed):
        self.s = seed & 0xFFFFFFFFFFFFFFFF

    def next(self):
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        return self.s >> 33


def corner_pos(step):
    """Float positions of the two synthetic corners at a step."""
    f = step / (STEPS - 1)
    ax = 8.0 + 40.0 * f
    ay = 8.0 + 40.0 * f
    bx = 50.0 - 40.0 * f
    by = 10.0 + 40.0 * f
    return (ax, ay), (bx, by)


def build_canonical():
    """Canonical event list [(t_us, x, y, p)] sorted by t (stable)."""
    rng = Lcg(0x5EED_CAFE)
    events = []
    gt_lines = []
    burst = [(0, 0), (1, 0), (0, 1), (1, 1), (-1, 0), (0, -1)]
    for k in range(STEPS):
        t_k = T0 + k * STEP_US
        (ax, ay), (bx, by) = corner_pos(k)
        gt_lines.append((t_k, ax, ay))
        gt_lines.append((t_k, bx, by))
        for cx, cy in ((ax, ay), (bx, by)):
            for j, (dx, dy) in enumerate(burst):
                x = int(round(cx)) + dx
                y = int(round(cy)) + dy
                if 0 <= x < WIDTH and 0 <= y < HEIGHT:
                    events.append((t_k + j * 37, x, y, j % 2))
        if k % 5 == 0:
            # horizontal run: EVT3 VECT material (same t, y, p; x ascending)
            t_run = t_k + 1_000
            for x in range(20, 34):
                events.append((t_run, x, 32, 1))
        for _ in range(2):
            x = rng.next() % WIDTH
            y = rng.next() % HEIGHT
            dt = rng.next() % STEP_US
            p = rng.next() % 2
            events.append((t_k + dt, x, y, p))
    events.sort(key=lambda e: e[0])  # Python sort is stable
    return events, gt_lines


# ---------------------------------------------------------------------------
# expected dumps (must match the Rust codecs byte-for-byte)
# ---------------------------------------------------------------------------


def write_expected_txt(path, events):
    # mirrors codec::write_text: "{t_s:.6} {x} {y} {p}\n" with t_s = t_us * 1e-6
    with open(path, "w", newline="\n") as f:
        for t, x, y, p in events:
            f.write("%.6f %d %d %d\n" % (t * 1e-6, x, y, p))


def write_expected_bin(path, events):
    # mirrors codec::write_binary: NMCTOSEV + version + u64 count + 13B records
    with open(path, "wb") as f:
        f.write(b"NMCTOSEV")
        f.write(bytes([1]))
        f.write(struct.pack("<Q", len(events)))
        for t, x, y, p in events:
            f.write(struct.pack("<HHQB", x, y, t, p))


# ---------------------------------------------------------------------------
# AEDAT4 encoder (uncompressed subset the Rust decoder accepts)
# ---------------------------------------------------------------------------

AEDAT4_MAGIC = b"#!AEDAT4.0\r\n"
PACKET_EVENTS = 512


def aedat4_ioheader():
    xml = (
        '<dv version="2.0"><node name="outInfo">'
        '<node name="0"><attr key="compression" type="string">NONE</attr>'
        '<node name="info"><attr key="sizeX" type="int">%d</attr>'
        '<attr key="sizeY" type="int">%d</attr></node></node></node></dv>'
        % (WIDTH, HEIGHT)
    )
    blob = struct.pack("<I", 8) + b"IOHE" + xml.encode()
    return struct.pack("<i", len(blob)) + blob


def aedat4_event_packet(events):
    """One EVTS flatbuffer payload for <= PACKET_EVENTS events."""
    body = bytearray()
    body += struct.pack("<I", 16)  # root table offset
    body += b"EVTS"  # file identifier
    body += struct.pack("<HHH", 6, 8, 4)  # vtable: vsize, tsize, field0 off
    body += b"\x00\x00"  # pad to 16
    body += struct.pack("<i", 8)  # table soffset -> vtable at 8
    body += struct.pack("<I", 4)  # field 0: vector offset (from here)
    body += struct.pack("<I", len(events))  # vector length
    for t, x, y, p in events:
        body += struct.pack("<qhhB3x", t, x, y, p)
    return bytes(body)


def write_aedat4(path, events):
    with open(path, "wb") as f:
        f.write(AEDAT4_MAGIC)
        f.write(aedat4_ioheader())
        for i in range(0, len(events), PACKET_EVENTS):
            payload = aedat4_event_packet(events[i : i + PACKET_EVENTS])
            f.write(struct.pack("<ii", 0, len(payload)))
            f.write(payload)


def decode_aedat4(path):
    """Independent verify-decoder mirroring the Rust AEDAT4 semantics."""
    data = open(path, "rb").read()
    assert data[:12] == AEDAT4_MAGIC, "bad AEDAT4 magic"
    hdr_len = struct.unpack_from("<i", data, 12)[0]
    assert 0 <= hdr_len <= len(data) - 16
    pos = 16 + hdr_len
    out = []
    while pos < len(data):
        _stream_id, size = struct.unpack_from("<ii", data, pos)
        pos += 8
        assert 0 < size <= len(data) - pos, "truncated packet"
        payload = data[pos : pos + size]
        pos += size
        if payload[4:8] != b"EVTS":
            continue
        root = struct.unpack_from("<I", payload, 0)[0]
        soff = struct.unpack_from("<i", payload, root)[0]
        vt = root - soff
        vsize, _tsize, f0 = struct.unpack_from("<HHH", payload, vt)
        if vsize < 6 or f0 == 0:
            continue
        voff = struct.unpack_from("<I", payload, root + f0)[0]
        vec = root + f0 + voff
        count = struct.unpack_from("<I", payload, vec)[0]
        for i in range(count):
            t, x, y, p = struct.unpack_from("<qhhB", payload, vec + 4 + 16 * i)
            assert t >= 0 and 0 <= x < WIDTH and 0 <= y < HEIGHT
            out.append((t, x, y, 1 if p else 0))
    return out


# ---------------------------------------------------------------------------
# EVT3 encoder (16-bit LE words)
# ---------------------------------------------------------------------------

EVT3_HEADER = (
    "% evt 3.0\n"
    "% format EVT3;height={h};width={w}\n"
    "% geometry {w}x{h}\n"
    "% end\n"
).format(w=WIDTH, h=HEIGHT)


def encode_evt3(events):
    words = []
    high = None  # full (unwrapped) TIME_HIGH value
    low = None
    y_state = None
    i = 0
    while i < len(events):
        t, x, y, p = events[i]
        h = t >> 12
        if high is None:
            high = h
            words.append((0x8 << 12) | (h & 0xFFF))
        while high < h:
            # step one TIME_HIGH at a time so wraparound appears as the
            # gradual increments a real sensor emits
            high += 1
            words.append((0x8 << 12) | (high & 0xFFF))
        lo = t & 0xFFF
        if low != lo:
            low = lo
            words.append((0x6 << 12) | lo)
        if y_state != y:
            y_state = y
            words.append((0x0 << 12) | y)
        # run-detect: same (t, y, p), x ascending by 1 -> VECT encoding
        j = i + 1
        while j < len(events):
            t2, x2, y2, p2 = events[j]
            if t2 == t and y2 == y and p2 == p and x2 == events[j - 1][1] + 1:
                j += 1
            else:
                break
        run = j - i
        if run >= 5:
            words.append((0x3 << 12) | (p << 11) | x)
            n = run
            while n >= 12:
                words.append((0x4 << 12) | 0xFFF)
                n -= 12
            if n > 8:
                words.append((0x4 << 12) | ((1 << n) - 1))
            elif n > 0:
                words.append((0x5 << 12) | ((1 << n) - 1))
            i = j
        else:
            words.append((0x2 << 12) | (p << 11) | x)
            i += 1
    return EVT3_HEADER.encode() + b"".join(struct.pack("<H", w) for w in words)


def decode_evt3(path):
    """Independent verify-decoder mirroring the Rust EVT3 semantics."""
    data = open(path, "rb").read()
    pos = data.index(b"% end\n") + len("% end\n")
    high = None  # full extended TIME_HIGH
    low = 0
    y = None
    vect_base = None
    vect_pol = 0
    out = []
    assert (len(data) - pos) % 2 == 0, "mid-word EOF"
    for off in range(pos, len(data), 2):
        w = struct.unpack_from("<H", data, off)[0]
        typ = w >> 12
        v = w & 0xFFF
        if typ == 0x8:
            if high is None:
                high = v
            else:
                cur_lo = high & 0xFFF
                base = high & ~0xFFF
                if v >= cur_lo:
                    high = base | v
                elif cur_lo - v >= 0x800:
                    high = (base + 0x1000) | v
                else:
                    raise AssertionError("TIME_HIGH rollback in fixture")
        elif typ == 0x6:
            low = v
        elif typ == 0x0:
            y = v & 0x7FF
        elif typ == 0x2:
            assert high is not None and y is not None
            out.append(((high << 12) | low, v & 0x7FF, y, (v >> 11) & 1))
        elif typ == 0x3:
            vect_base = v & 0x7FF
            vect_pol = (v >> 11) & 1
        elif typ in (0x4, 0x5):
            assert vect_base is not None and high is not None and y is not None
            nbits = 12 if typ == 0x4 else 8
            for b in range(nbits):
                if v & (1 << b):
                    out.append(((high << 12) | low, vect_base + b, y, vect_pol))
            vect_base += nbits
        else:
            raise AssertionError("unexpected word type 0x%X in fixture" % typ)
    return out


# ---------------------------------------------------------------------------
# EVT2 encoder (32-bit LE words)
# ---------------------------------------------------------------------------

EVT2_HEADER = (
    "% evt 2.0\n"
    "% format EVT2;height={h};width={w}\n"
    "% geometry {w}x{h}\n"
    "% end\n"
).format(w=WIDTH, h=HEIGHT)


def encode_evt2(events):
    words = []
    high = None  # t >> 6
    for t, x, y, p in events:
        assert t < (1 << 34), "EVT2 writer avoids TIME_HIGH wrap"
        h = t >> 6
        if high != h:
            high = h
            words.append((0x8 << 28) | (h & 0x0FFFFFFF))
        typ = 0x1 if p else 0x0
        words.append((typ << 28) | ((t & 0x3F) << 22) | (x << 11) | y)
    return EVT2_HEADER.encode() + b"".join(struct.pack("<I", w) for w in words)


def decode_evt2(path):
    """Independent verify-decoder mirroring the Rust EVT2 semantics."""
    data = open(path, "rb").read()
    pos = data.index(b"% end\n") + len("% end\n")
    high = None
    out = []
    assert (len(data) - pos) % 4 == 0, "mid-word EOF"
    for off in range(pos, len(data), 4):
        w = struct.unpack_from("<I", data, off)[0]
        typ = w >> 28
        if typ == 0x8:
            v = w & 0x0FFFFFFF
            if high is None:
                high = v
            else:
                cur_lo = high & 0x0FFFFFFF
                base = high & ~0x0FFFFFFF
                if v >= cur_lo:
                    high = base | v
                elif cur_lo - v >= (1 << 27):
                    high = (base + (1 << 28)) | v
                else:
                    raise AssertionError("EVT2 TIME_HIGH rollback in fixture")
        elif typ in (0x0, 0x1):
            assert high is not None
            ts_lsb = (w >> 22) & 0x3F
            x = (w >> 11) & 0x7FF
            y = w & 0x7FF
            assert x < WIDTH and y < HEIGHT
            out.append(((high << 6) | ts_lsb, x, y, typ))
        else:
            raise AssertionError("unexpected word type 0x%X in fixture" % typ)
    return out


# ---------------------------------------------------------------------------
# ground truth + manifest
# ---------------------------------------------------------------------------


def write_gt(path, gt_lines):
    with open(path, "w", newline="\n") as f:
        f.write("# t_seconds x y — synthetic corner trajectories (fixture)\n")
        for t, x, y in gt_lines:
            f.write("%.6f %.2f %.2f\n" % (t * 1e-6, x, y))


def write_manifest(path):
    manifest = {
        "datasets": [
            {
                "name": "fixture-aedat4",
                "recording": "../events.aedat4",
                "ground_truth": "corners_gt.txt",
                "width": WIDTH,
                "height": HEIGHT,
            },
            {
                "name": "fixture-evt2",
                "recording": "../events_evt2.raw",
                "ground_truth": "corners_gt.txt",
                "width": WIDTH,
                "height": HEIGHT,
            },
            {
                "name": "fixture-evt3",
                "recording": "../events_evt3.raw",
                "ground_truth": "corners_gt.txt",
                "width": WIDTH,
                "height": HEIGHT,
            },
        ]
    }
    with open(path, "w", newline="\n") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------


def main():
    events, gt_lines = build_canonical()
    wrap = sum(1 for t, _, _, _ in events if t >= 1 << 24)
    assert 0 < wrap < len(events), "stream must straddle the EVT3 2^24 wrap"

    fixdir = os.path.normpath(FIXDIR)
    os.makedirs(os.path.join(fixdir, "expected"), exist_ok=True)
    os.makedirs(os.path.join(fixdir, "datasets"), exist_ok=True)

    write_expected_txt(os.path.join(fixdir, "expected", "events.txt"), events)
    write_expected_bin(os.path.join(fixdir, "expected", "events.bin"), events)
    write_aedat4(os.path.join(fixdir, "events.aedat4"), events)
    with open(os.path.join(fixdir, "events_evt3.raw"), "wb") as f:
        f.write(encode_evt3(events))
    with open(os.path.join(fixdir, "events_evt2.raw"), "wb") as f:
        f.write(encode_evt2(events))
    write_gt(os.path.join(fixdir, "datasets", "corners_gt.txt"), gt_lines)
    write_manifest(os.path.join(fixdir, "datasets", "manifest.json"))

    # self-check: every encoding must decode back to the canonical stream
    for name, decoded in (
        ("aedat4", decode_aedat4(os.path.join(fixdir, "events.aedat4"))),
        ("evt3", decode_evt3(os.path.join(fixdir, "events_evt3.raw"))),
        ("evt2", decode_evt2(os.path.join(fixdir, "events_evt2.raw"))),
    ):
        assert decoded == events, "%s re-decode diverged (%d vs %d events)" % (
            name,
            len(decoded),
            len(events),
        )

    print("canonical events: %d (t %d..%d us, %d past 2^24)" % (
        len(events), events[0][0], events[-1][0], wrap))
    for rel in (
        "events.aedat4",
        "events_evt3.raw",
        "events_evt2.raw",
        "expected/events.txt",
        "expected/events.bin",
        "datasets/corners_gt.txt",
        "datasets/manifest.json",
    ):
        sz = os.path.getsize(os.path.join(fixdir, rel))
        assert sz < 100_000, "%s too big: %d" % (rel, sz)
        print("  %-28s %6d bytes" % (rel, sz))
    print("all fixtures verified against the canonical stream")


if __name__ == "__main__":
    sys.exit(main())
