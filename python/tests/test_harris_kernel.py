"""L1 correctness: Pallas Harris kernel vs the pure-jnp oracle.

Hypothesis sweeps image shapes and value ranges; assert_allclose against
``ref.harris_response_ref``.  This is the core correctness signal for the
kernel that ends up inside the AOT artifact.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import harris, ref

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def _rand_img(rng, h, w, scale=1.0):
    return (rng.random((h, w), dtype=np.float32) * scale).astype(np.float32)


@given(
    h=st.integers(min_value=12, max_value=96),
    w=st.integers(min_value=12, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_harris_matches_ref_random(h, w, seed):
    rng = np.random.default_rng(seed)
    img = _rand_img(rng, h, w)
    got = np.asarray(harris.harris_response(jnp.asarray(img)))
    want = np.asarray(ref.harris_response_ref(jnp.asarray(img)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)


@given(scale=st.sampled_from([0.0, 1.0, 255.0]))
def test_harris_matches_ref_scaled(scale):
    rng = np.random.default_rng(7)
    img = _rand_img(rng, 36, 60, scale=max(scale, 1.0) if scale else 0.0)
    if scale == 0.0:
        img = np.zeros_like(img)
    got = np.asarray(harris.harris_response(jnp.asarray(img)))
    want = np.asarray(ref.harris_response_ref(jnp.asarray(img)))
    atol = 2e-3 * max(scale, 1.0) ** 4  # response scales ~ intensity^4
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=atol)


def test_harris_flat_image_zero_response():
    img = np.full((40, 40), 0.5, dtype=np.float32)
    got = np.asarray(harris.harris_response(jnp.asarray(img)))
    interior = got[6:-6, 6:-6]  # away from the zero-pad border
    np.testing.assert_allclose(interior, 0.0, atol=1e-6)


def test_harris_corner_is_local_max():
    """A bright axis-aligned square: response peaks near its corners."""
    img = np.zeros((48, 48), dtype=np.float32)
    img[16:32, 16:32] = 1.0
    r = np.asarray(harris.harris_response(jnp.asarray(img)))
    corner = r[16, 16]
    edge_mid = r[16, 24]
    flat = r[8, 8]
    assert corner > edge_mid, "corner response must beat edge response"
    assert corner > flat, "corner response must beat flat response"
    assert edge_mid < corner  # edges suppressed by k*tr^2 term


def test_harris_dtype_and_shape():
    img = np.zeros((30, 50), dtype=np.float32)
    out = harris.harris_response(jnp.asarray(img))
    assert out.shape == (30, 50)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("h,w", [(180, 240), (260, 346), (64, 64)])
def test_harris_exported_resolutions(h, w):
    """The exact shapes that are AOT-exported must agree with the oracle."""
    rng = np.random.default_rng(h * 1000 + w)
    img = rng.random((h, w), dtype=np.float32)
    got = np.asarray(harris.harris_response(jnp.asarray(img)))
    want = np.asarray(ref.harris_response_ref(jnp.asarray(img)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)


@given(k=st.floats(min_value=0.01, max_value=0.1))
def test_harris_k_parameter(k):
    rng = np.random.default_rng(3)
    img = rng.random((24, 24), dtype=np.float32)
    got = np.asarray(harris.harris_response(jnp.asarray(img), k=float(k)))
    want = np.asarray(ref.harris_response_ref(jnp.asarray(img), k=float(k)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)


def test_pick_tile_h_divides():
    for h in range(1, 400):
        th = harris._pick_tile_h(h)
        assert h % th == 0
        assert 1 <= th <= 32
