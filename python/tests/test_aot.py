"""AOT path: HLO text artifacts are parseable, stable, and numerically
faithful to the jit path (executed through the same XlaComputation route
the Rust runtime uses)."""

import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model

ART_DIR = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_produces_entry_computation():
    text = aot.lower_harris(32, 32)
    assert "ENTRY" in text
    assert "f32[32,32]" in text


def test_lowering_is_deterministic():
    a = aot.lower_harris(24, 40)
    b = aot.lower_harris(24, 40)
    assert a == b


def test_hlo_text_roundtrip_numerics():
    """Compile the HLO *text* with the raw xla_client (the exact path the
    Rust PJRT client takes) and compare against the jit execution."""
    h, w = 32, 48
    spec = jax.ShapeDtypeStruct((h, w), jnp.float32)
    lowered = jax.jit(model.harris_lut).lower(spec)
    mlir_mod = str(lowered.compiler_ir("stablehlo"))
    backend = jax.devices("cpu")[0].client
    exe = backend.compile_and_load(mlir_mod, xc.DeviceList(tuple(jax.devices("cpu"))))
    rng = np.random.default_rng(0)
    frame = (rng.random((h, w)) * 255).astype(np.float32)
    res = exe.execute_sharded([backend.buffer_from_pyval(frame)])
    (out,) = res.disassemble_into_single_device_arrays()
    got = np.asarray(out[0])
    (want,) = model.harris_lut(jnp.asarray(frame))
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(not (ART_DIR / "meta.json").exists(), reason="run `make artifacts` first")
def test_artifacts_meta_consistent():
    meta = json.loads((ART_DIR / "meta.json").read_text())
    assert meta["format"] == "hlo-text"
    assert meta["return_tuple"] is True
    for name, (h, w) in model.RESOLUTIONS.items():
        entry = meta["artifacts"][name]
        assert entry["height"] == h and entry["width"] == w
        path = ART_DIR / entry["file"]
        assert path.exists(), f"missing artifact {path}"
        text = path.read_text()
        assert "ENTRY" in text
        assert f"f32[{h},{w}]" in text
