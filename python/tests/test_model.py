"""L2 correctness: the full harris_lut graph (Pallas path vs oracle path)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model

settings.register_profile("ci", max_examples=8, deadline=None)
settings.load_profile("ci")


def _tos_frame(rng, h, w):
    """Synthesize a TOS-like frame: mostly 0, a few high patches (224..255).

    The patch count scales with the area so small frames keep large empty
    regions — a frame that is ~uniform has a near-zero Harris response
    whose min-max normalization would just amplify float noise.
    """
    frame = np.zeros((h, w), dtype=np.float32)
    n = max(1, (h * w) // 800)
    for _ in range(n):
        y, x = rng.integers(0, h), rng.integers(0, w)
        v = rng.integers(224, 256)
        frame[max(0, y - 3) : y + 4, max(0, x - 3) : x + 4] = v
    return frame


@given(
    h=st.integers(min_value=16, max_value=80),
    w=st.integers(min_value=16, max_value=80),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_path_matches_ref_path(h, w, seed):
    rng = np.random.default_rng(seed)
    frame = _tos_frame(rng, h, w)
    (got,) = model.harris_lut(jnp.asarray(frame))
    (want,) = model.harris_lut_ref(jnp.asarray(frame))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-2, atol=1e-3)


def test_output_is_normalized():
    rng = np.random.default_rng(1)
    frame = _tos_frame(rng, 64, 64)
    (lut,) = model.harris_lut(jnp.asarray(frame))
    lut = np.asarray(lut)
    assert lut.min() >= 0.0 and lut.max() <= 1.0 + 1e-6
    assert abs(lut.max() - 1.0) < 1e-5  # min-max normalization hits 1


def test_flat_frame_maps_to_zeros():
    frame = np.zeros((64, 64), dtype=np.float32)
    (lut,) = model.harris_lut(jnp.asarray(frame))
    np.testing.assert_allclose(np.asarray(lut), 0.0, atol=1e-7)

    frame = np.full((64, 64), 255.0, dtype=np.float32)
    (lut,) = model.harris_lut(jnp.asarray(frame))
    # constant-255 frame: only border effects; normalized output still in [0,1]
    lut = np.asarray(lut)
    assert lut.min() >= 0.0 and lut.max() <= 1.0 + 1e-6


def test_resolutions_registry():
    assert model.RESOLUTIONS["davis240"] == (180, 240)
    assert model.RESOLUTIONS["davis346"] == (260, 346)
    for h, w in model.RESOLUTIONS.values():
        assert h >= 16 and w >= 16


def test_corner_hotspot_location():
    """The LUT must light up at geometric corners of a bright square."""
    frame = np.zeros((64, 64), dtype=np.float32)
    frame[20:40, 20:40] = 255.0
    (lut,) = model.harris_lut(jnp.asarray(frame))
    lut = np.asarray(lut)
    peak = np.unravel_index(np.argmax(lut), lut.shape)
    corners = np.array([[20, 20], [20, 39], [39, 20], [39, 39]])
    d = np.min(np.abs(corners - np.array(peak)).sum(axis=1))
    assert d <= 4, f"peak {peak} not near any corner"
