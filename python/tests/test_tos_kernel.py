"""L1 correctness: Pallas batched TOS-update kernel vs the oracle.

Sweeps surface shapes, event batches, patch sizes and thresholds with
hypothesis; also asserts the paper's Algorithm-1 invariants directly.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref, tos_update

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _events(rng, n, h, w):
    ev = np.stack(
        [rng.integers(0, w, n), rng.integers(0, h, n)], axis=1
    ).astype(np.int32)
    return ev


@given(
    h=st.integers(min_value=10, max_value=48),
    w=st.integers(min_value=10, max_value=48),
    n=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tos_batch_matches_ref(h, w, n, seed):
    rng = np.random.default_rng(seed)
    surf = rng.integers(0, 256, (h, w)).astype(np.int32)
    ev = _events(rng, n, h, w)
    got = np.asarray(tos_update.tos_update_batch(jnp.asarray(surf), jnp.asarray(ev)))
    want = np.asarray(ref.tos_update_ref(jnp.asarray(surf), jnp.asarray(ev)))
    np.testing.assert_array_equal(got, want)


@given(
    patch=st.sampled_from([3, 5, 7, 9]),
    threshold=st.integers(min_value=200, max_value=250),
)
def test_tos_batch_patch_threshold_sweep(patch, threshold):
    rng = np.random.default_rng(patch * 31 + threshold)
    surf = rng.integers(0, 256, (32, 32)).astype(np.int32)
    ev = _events(rng, 24, 32, 32)
    got = np.asarray(
        tos_update.tos_update_batch(
            jnp.asarray(surf), jnp.asarray(ev), patch=patch, threshold=threshold
        )
    )
    want = np.asarray(
        ref.tos_update_ref(jnp.asarray(surf), jnp.asarray(ev), patch=patch, threshold=threshold)
    )
    np.testing.assert_array_equal(got, want)


def test_tos_invariants():
    """Algorithm-1 invariants: range, centre=255, outside-patch untouched."""
    rng = np.random.default_rng(0)
    surf = rng.integers(0, 256, (40, 40)).astype(np.int32)
    ev = np.array([[20, 20]], dtype=np.int32)
    out = np.asarray(tos_update.tos_update_batch(jnp.asarray(surf), jnp.asarray(ev)))
    assert out.min() >= 0 and out.max() <= 255
    assert out[20, 20] == 255
    # outside the 7x7 patch nothing changed
    mask = np.ones_like(surf, dtype=bool)
    mask[17:24, 17:24] = False
    np.testing.assert_array_equal(out[mask], surf[mask])
    # inside: decremented or clamped to 0
    inside = surf[17:24, 17:24] - 1
    inside = np.where(inside < 224, 0, inside)
    inside[3, 3] = 255
    np.testing.assert_array_equal(out[17:24, 17:24], inside)


def test_tos_threshold_clamps_to_zero():
    surf = np.full((16, 16), 224, dtype=np.int32)  # exactly at TH, one decrement kills
    ev = np.array([[8, 8]], dtype=np.int32)
    out = np.asarray(tos_update.tos_update_batch(jnp.asarray(surf), jnp.asarray(ev)))
    assert (out[5:12, 5:12] == 0).sum() == 48  # all but the centre
    assert out[8, 8] == 255


def test_tos_border_clipping():
    """Events at the image corner must not wrap or crash."""
    surf = np.full((16, 16), 255, dtype=np.int32)
    ev = np.array([[0, 0], [15, 15]], dtype=np.int32)
    out = np.asarray(tos_update.tos_update_batch(jnp.asarray(surf), jnp.asarray(ev)))
    want = np.asarray(ref.tos_update_ref(jnp.asarray(surf), jnp.asarray(ev)))
    np.testing.assert_array_equal(out, want)
    assert out[0, 0] == 255 and out[15, 15] == 255


def test_tos_event_order_matters():
    """Two events at the same pixel: last one wins the 255 write; the first
    centre gets decremented by the second patch if adjacent."""
    surf = np.full((16, 16), 255, dtype=np.int32)
    ev = np.array([[5, 5], [6, 5]], dtype=np.int32)
    out = np.asarray(tos_update.tos_update_batch(jnp.asarray(surf), jnp.asarray(ev)))
    assert out[5, 6] == 255  # (x=6,y=5) centre written last
    assert out[5, 5] == 254  # first centre decremented by second event's patch
