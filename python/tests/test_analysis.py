"""L2 graph-quality gates: the compiled Harris module must stay fused and
transpose-free (the DESIGN.md §Perf L2 targets, enforced)."""

import pytest

from compile import analysis, model


@pytest.fixture(scope="module")
def info():
    return analysis.analyze("test64")


def test_everything_fuses(info):
    # the five stencils + score + normalize should collapse into a handful
    # of fusions — not dozens of loose elementwise ops
    assert info["fusions"] >= 1
    assert info["fusions"] <= 24, f"fusion blow-up: {info['ops']}"


def test_no_transposes(info):
    assert info["transposes"] == 0, "layout churn in the lowered module"


def test_normalize_reduces_present(info):
    # min-max normalization contributes the only reduces in the graph
    assert 1 <= info["reduces"] <= 6


def test_flop_estimate_scales_with_resolution():
    small = analysis.analyze("test64")
    big = analysis.analyze("davis240")
    ratio = big["est_mflops_per_frame"] / small["est_mflops_per_frame"]
    px_ratio = (180 * 240) / (64 * 64)
    assert abs(ratio - px_ratio) / px_ratio < 1e-6


def test_op_histogram_nonempty(info):
    assert sum(info["ops"].values()) > 0
    assert info["io_bytes_per_frame"] == 2 * 4 * 64 * 64


def test_resolutions_all_analyzable():
    for name in model.RESOLUTIONS:
        got = analysis.analyze(name)
        assert got["est_mflops_per_frame"] > 0
