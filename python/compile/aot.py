"""AOT compile path: lower the L2 Harris graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the HLO text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Produces one ``harris_<name>.hlo.txt`` per entry in ``model.RESOLUTIONS``
plus ``meta.json`` describing shapes so the Rust runtime can validate its
inputs without parsing HLO.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_harris(height: int, width: int) -> str:
    spec = jax.ShapeDtypeStruct((height, width), jnp.float32)
    lowered = jax.jit(model.harris_lut).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    meta: dict = {"artifacts": {}, "format": "hlo-text", "return_tuple": True}
    for name, (h, w) in model.RESOLUTIONS.items():
        text = lower_harris(h, w)
        fname = f"harris_{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        meta["artifacts"][name] = {
            "file": fname,
            "height": h,
            "width": w,
            "input": {"shape": [h, w], "dtype": "f32", "semantics": "TOS 0..255"},
            "output": {"shape": [h, w], "dtype": "f32", "semantics": "Harris LUT 0..1"},
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {out_dir / fname} ({len(text)} chars)")

    (out_dir / "meta.json").write_text(json.dumps(meta, indent=2) + "\n")
    print(f"wrote {out_dir / 'meta.json'}")


if __name__ == "__main__":
    main()
