"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *golden* implementations used by pytest to validate the
Pallas kernels (``harris.py``, ``tos_update.py``).  They deliberately use a
different code path (``lax.conv_general_dilated`` instead of shifted adds)
so that agreement between the two is a meaningful correctness signal.

All functions are pure and jittable.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Filter taps (single source of truth, shared with the Pallas kernel)
# ---------------------------------------------------------------------------

# 5-tap binomial smoother and central-difference derivative — the separable
# factors of the 5x5 Sobel operator used by luvHarris.
SMOOTH_5 = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0], dtype=jnp.float32) / 16.0
DERIV_5 = jnp.array([-1.0, -2.0, 0.0, 2.0, 1.0], dtype=jnp.float32) / 6.0

# 5-tap Gaussian (sigma ~= 1) used for the structure-tensor window.
GAUSS_5 = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0], dtype=jnp.float32)
GAUSS_5 = GAUSS_5 / jnp.sum(GAUSS_5)

HARRIS_K = 0.04
HALO = 4  # two chained 5x5 stencils => 2+2 pixels of halo per side


def _conv2d_valid(x: jnp.ndarray, kern2d: jnp.ndarray) -> jnp.ndarray:
    """2-D 'valid' correlation of a single-channel image with a 2-D kernel."""
    x4 = x[None, None, :, :]
    k4 = kern2d[None, None, :, :]
    y = lax.conv_general_dilated(
        x4,
        k4,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y[0, 0]


def sobel_kernels() -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return the full (non-separated) 5x5 Sobel-x and Sobel-y kernels."""
    kx = jnp.outer(SMOOTH_5, DERIV_5)  # smooth rows, differentiate cols
    ky = jnp.outer(DERIV_5, SMOOTH_5)  # differentiate rows, smooth cols
    return kx, ky


def gauss_kernel() -> jnp.ndarray:
    """Full 5x5 Gaussian window kernel."""
    return jnp.outer(GAUSS_5, GAUSS_5)


def harris_response_ref(img: jnp.ndarray, k: float = HARRIS_K) -> jnp.ndarray:
    """Reference Harris response map of a single-channel f32 image.

    Matches luvHarris: 5x5 Sobel gradients, 5x5 Gaussian-windowed structure
    tensor, R = det(M) - k * trace(M)^2.  Border handling: the *image* is
    zero-padded once by HALO and both stencils are computed 'valid', i.e.
    gradients are taken of the zero-padded image (identical semantics to
    the Pallas kernel's single pre-pad — NOT per-stage SAME padding, which
    would zero the *gradients* outside the image instead).
    """
    img = img.astype(jnp.float32)
    padded = jnp.pad(img, ((HALO, HALO), (HALO, HALO)))
    kx, ky = sobel_kernels()
    ix = _conv2d_valid(padded, kx)
    iy = _conv2d_valid(padded, ky)
    g = gauss_kernel()
    sxx = _conv2d_valid(ix * ix, g)
    syy = _conv2d_valid(iy * iy, g)
    sxy = _conv2d_valid(ix * iy, g)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    return det - k * tr * tr


def tos_update_ref(
    surface: jnp.ndarray,
    events_xy: jnp.ndarray,
    patch: int = 7,
    threshold: int = 224,
) -> jnp.ndarray:
    """Reference event-by-event TOS update (paper Algorithm 1).

    ``surface``  : (H, W) int32 TOS in [0, 255].
    ``events_xy``: (N, 2) int32 (x=col, y=row) coordinates, applied in order.
    For each event: decrement the P x P patch centred on it, clamp values
    that fall below ``threshold`` to 0, then set the centre pixel to 255.
    Patches are clipped at the image border (the hardware simply does not
    drive out-of-range rows/columns).
    """
    half = (patch - 1) // 2
    h, w = surface.shape
    ys = jnp.arange(h)[:, None]
    xs = jnp.arange(w)[None, :]

    def body(i, surf):
        ex = events_xy[i, 0]
        ey = events_xy[i, 1]
        in_patch = (
            (ys >= ey - half)
            & (ys <= ey + half)
            & (xs >= ex - half)
            & (xs <= ex + half)
        )
        dec = jnp.where(in_patch, surf - 1, surf)
        dec = jnp.where(in_patch & (dec < threshold), 0, dec)
        dec = jnp.maximum(dec, 0)
        return dec.at[ey, ex].set(255)

    return lax.fori_loop(0, events_xy.shape[0], body, surface.astype(jnp.int32))
