"""L1 Pallas kernel: batched event-by-event TOS update (paper Algorithm 1).

This mirrors what the NMC macro does in hardware — decrement a P x P patch,
threshold-clamp to zero, write 255 at the event pixel — for a *batch* of
events applied sequentially to the surface.  It exists for two reasons:

  1. It is the software golden model the paper used for its BER-injection
     study ("software simulation of the pipeline", SecV-C); the python
     tests cross-validate it against ``ref.tos_update_ref`` and the Rust
     golden model validates against the same vectors.
  2. It exercises integer Pallas semantics (masked scatter-style updates),
     complementing the float stencil kernel in ``harris.py``.

The events are applied with a ``fori_loop`` *inside* the kernel so the
surface stays resident in VMEM across the whole batch — the same
data-locality argument the paper makes for near-memory computing: move the
update to the memory instead of streaming the patch in and out per event.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tos_batch_kernel(
    surface_ref, events_ref, out_ref, *, patch: int, threshold: int, height: int, width: int
):
    half = (patch - 1) // 2
    ys = jax.lax.broadcasted_iota(jnp.int32, (height, width), 0)
    xs = jax.lax.broadcasted_iota(jnp.int32, (height, width), 1)
    n_events = events_ref.shape[0]

    def body(i, surf):
        ex = events_ref[i, 0]
        ey = events_ref[i, 1]
        in_patch = (
            (ys >= ey - half)
            & (ys <= ey + half)
            & (xs >= ex - half)
            & (xs <= ex + half)
        )
        dec = jnp.where(in_patch, surf - 1, surf)
        dec = jnp.where(in_patch & (dec < threshold), 0, dec)
        dec = jnp.maximum(dec, 0)
        centre = (ys == ey) & (xs == ex)
        return jnp.where(centre, 255, dec)

    out_ref[...] = jax.lax.fori_loop(0, n_events, body, surface_ref[...])


@functools.partial(jax.jit, static_argnames=("patch", "threshold"))
def tos_update_batch(
    surface: jnp.ndarray,
    events_xy: jnp.ndarray,
    patch: int = 7,
    threshold: int = 224,
) -> jnp.ndarray:
    """Apply a batch of events to an int32 TOS surface, in order.

    ``surface``: (H, W) int32 in [0, 255]; ``events_xy``: (N, 2) int32
    (x=col, y=row).  Returns the updated surface.
    """
    h, w = surface.shape
    kernel = functools.partial(
        _tos_batch_kernel, patch=patch, threshold=threshold, height=h, width=w
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.int32),
        interpret=True,
    )(surface.astype(jnp.int32), events_xy.astype(jnp.int32))
