"""L1 Pallas kernel: tiled Harris response over a TOS frame.

The kernel expresses the HBM->VMEM schedule explicitly: the output is
blocked into row-bands of ``tile_h`` rows (BlockSpec), while the input is
the zero-padded image held in ANY memory; each grid step loads one
halo-extended band (``tile_h + 2*HALO`` rows) into registers/VMEM with
``pl.load`` and computes gradients, the Gaussian-windowed structure tensor
and the Harris response for its band.

TPU mapping notes (DESIGN.md "Hardware adaptation"): the two chained 5x5
stencils are computed as separable shifted-adds, which XLA/Mosaic fuse into
vector ops on the VPU; a band of 16 rows x 248 cols of f32 with its halo is
~66 KB of VMEM-resident data, comfortably inside a TensorCore's VMEM. The
kernel is lowered with ``interpret=True`` so the same HLO runs on the CPU
PJRT client that the Rust coordinator embeds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DERIV_5, GAUSS_5, HALO, HARRIS_K, SMOOTH_5

# Pallas kernels cannot capture traced constants; bake the taps in as
# python floats (they are compile-time constants of the stencil).
_SMOOTH = tuple(float(v) for v in SMOOTH_5)
_DERIV = tuple(float(v) for v in DERIV_5)
_GAUSS = tuple(float(v) for v in GAUSS_5)


def _conv1d_rows(x: jnp.ndarray, taps: tuple) -> jnp.ndarray:
    """Valid 1-D correlation along axis 0 with a 5-tap filter (shifted adds)."""
    n = len(taps)
    out = taps[0] * x[0 : x.shape[0] - n + 1, :]
    for i in range(1, n):
        out = out + taps[i] * x[i : x.shape[0] - n + 1 + i, :]
    return out


def _conv1d_cols(x: jnp.ndarray, taps: tuple) -> jnp.ndarray:
    """Valid 1-D correlation along axis 1 with a 5-tap filter (shifted adds)."""
    n = len(taps)
    out = taps[0] * x[:, 0 : x.shape[1] - n + 1]
    for i in range(1, n):
        out = out + taps[i] * x[:, i : x.shape[1] - n + 1 + i]
    return out


def _sep_conv_valid(x: jnp.ndarray, row_taps, col_taps) -> jnp.ndarray:
    """Separable 5x5 valid correlation: rows then columns."""
    return _conv1d_cols(_conv1d_rows(x, row_taps), col_taps)


def _harris_band_kernel(img_ref, out_ref, *, tile_h: int, width: int, k: float):
    """Compute the Harris response for one row-band of the image.

    ``img_ref``: (H + 2*HALO, W + 2*HALO) zero-padded image (ANY memory).
    ``out_ref``: (tile_h, width) output band (blocked, VMEM).
    """
    band = pl.program_id(0)
    # Load the halo-extended band: rows [band*tile_h, band*tile_h + tile_h + 2*HALO)
    x = pl.load(
        img_ref,
        (pl.dslice(band * tile_h, tile_h + 2 * HALO), pl.dslice(0, width + 2 * HALO)),
    )
    # Sobel gradients: valid 5x5 -> (tile_h + 4, width + 4)
    ix = _sep_conv_valid(x, _SMOOTH, _DERIV)
    iy = _sep_conv_valid(x, _DERIV, _SMOOTH)
    # Gaussian-windowed structure tensor: valid 5x5 -> (tile_h, width)
    sxx = _sep_conv_valid(ix * ix, _GAUSS, _GAUSS)
    syy = _sep_conv_valid(iy * iy, _GAUSS, _GAUSS)
    sxy = _sep_conv_valid(ix * iy, _GAUSS, _GAUSS)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    out_ref[...] = det - k * tr * tr


def _pick_tile_h(h: int) -> int:
    """Largest divisor of ``h`` that is <= 32 (keeps the band in VMEM)."""
    for cand in range(min(32, h), 0, -1):
        if h % cand == 0:
            return cand
    return h


@functools.partial(jax.jit, static_argnames=("k",))
def harris_response(img: jnp.ndarray, k: float = HARRIS_K) -> jnp.ndarray:
    """Harris response of a single-channel f32 image via the Pallas kernel.

    Zero-pads by HALO on each side so border semantics match
    ``ref.harris_response_ref`` (which uses SAME/zero padding).
    """
    img = img.astype(jnp.float32)
    h, w = img.shape
    tile_h = _pick_tile_h(h)
    padded = jnp.pad(img, ((HALO, HALO), (HALO, HALO)))
    kernel = functools.partial(_harris_band_kernel, tile_h=tile_h, width=w, k=k)
    return pl.pallas_call(
        kernel,
        grid=(h // tile_h,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((tile_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(padded)
