"""L2 performance analysis: inspect the lowered Harris graph.

Produces the evidence behind DESIGN.md §Perf's L2 claims: fusion count,
op histogram, FLOP estimate and bytes-touched of the AOT artifact — run as

    cd python && python -m compile.analysis [resolution]

and exercised by pytest (`tests/test_analysis.py`).
"""

from __future__ import annotations

import re
import sys
from collections import Counter

import jax
import jax.numpy as jnp

from . import model


def hlo_text_for(name: str) -> str:
    """Lower the named resolution and return optimized HLO text."""
    h, w = model.RESOLUTIONS[name]
    spec = jax.ShapeDtypeStruct((h, w), jnp.float32)
    compiled = jax.jit(model.harris_lut).lower(spec).compile()
    return compiled.as_text()


def op_histogram(hlo: str) -> Counter:
    """Count HLO opcodes (one per instruction line `x = op(...)`)."""
    ops = Counter()
    for m in re.finditer(r"=\s+[\w\[\],{}]+\s+([a-z][\w-]*)\(", hlo):
        ops[m.group(1)] += 1
    return ops

def analyze(name: str) -> dict:
    """Summarize the compiled module."""
    hlo = hlo_text_for(name)
    ops = op_histogram(hlo)
    h, w = model.RESOLUTIONS[name]
    # FLOP estimate of the math: 5 separable 5x5 stencils (2 passes x 5
    # taps x 2 flops) + 3 products + score (4) + normalize (~3)
    flops_per_px = 5 * (2 * 5 * 2) + 3 + 4 + 3
    return {
        "name": name,
        "height": h,
        "width": w,
        "fusions": ops.get("fusion", 0),
        "convolutions": ops.get("convolution", 0),
        "transposes": ops.get("transpose", 0),
        "reduces": ops.get("reduce", 0),
        "ops": dict(ops),
        "est_mflops_per_frame": flops_per_px * h * w / 1e6,
        "io_bytes_per_frame": 2 * 4 * h * w,  # one f32 frame in, one out
    }


def main() -> None:
    name = sys.argv[1] if len(sys.argv) > 1 else "davis240"
    info = analyze(name)
    print(f"== L2 analysis: {name} ({info['height']}x{info['width']}) ==")
    print(f"fusion ops        : {info['fusions']}")
    print(f"convolution ops   : {info['convolutions']} (0 = stencils fused as elementwise)")
    print(f"transpose ops     : {info['transposes']}")
    print(f"reduce ops        : {info['reduces']} (min-max normalize)")
    print(f"est. compute      : {info['est_mflops_per_frame']:.1f} MFLOP/frame")
    print(f"I/O               : {info['io_bytes_per_frame'] / 1e3:.0f} kB/frame")
    print(f"arith intensity   : {info['est_mflops_per_frame'] * 1e6 / info['io_bytes_per_frame']:.0f} FLOP/byte")
    top = sorted(info["ops"].items(), key=lambda kv: -kv[1])[:8]
    print("op histogram      :", ", ".join(f"{k}x{v}" for k, v in top))


if __name__ == "__main__":
    main()
