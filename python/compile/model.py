"""L2: the frame-by-frame Harris-score graph of the corner-detection system.

This is the compute the paper delegates to a "modern CNN chip" (Sec. I):
given the current TOS frame, produce the Harris response map that the
coordinator uses as a corner lookup table.  It is written in JAX, calls the
L1 Pallas kernel for the stencil hot-spot, and is AOT-lowered once per
resolution by ``aot.py``; Python never runs on the request path.

Graph (matches luvHarris):

    u8 TOS (as f32, 0..255) --/255--> Sobel-5x5 gradients --> structure
    tensor --Gaussian-5x5--> R = det(M) - k tr(M)^2 --> minmax-normalized
    response in [0, 1]  (flat frames map to all-zeros).

The normalized map doubles as the "Harris LUT": the Rust side thresholds
it at a sweep of levels to draw precision-recall curves.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import harris as harris_kernel
from .kernels.ref import HARRIS_K, harris_response_ref

# Resolutions exported as AOT artifacts. (height, width).
#   davis240  — the paper's DAVIS240 sensor (two 180x120 NMC blocks);
#   davis346  — a DAVIS346 for the multi-block scaling study;
#   test64    — small shape for integration tests.
RESOLUTIONS = {
    "davis240": (180, 240),
    "davis346": (260, 346),
    "test64": (64, 64),
}


def _normalize01(r: jnp.ndarray) -> jnp.ndarray:
    """Min-max normalize to [0, 1]; an all-flat response maps to zeros."""
    lo = jnp.min(r)
    hi = jnp.max(r)
    span = hi - lo
    safe = jnp.where(span > 0, span, 1.0)
    return jnp.where(span > 0, (r - lo) / safe, jnp.zeros_like(r))


def harris_lut(tos_frame: jnp.ndarray, *, use_pallas: bool = True) -> tuple[jnp.ndarray]:
    """Full FBF Harris LUT computation from a raw TOS frame.

    ``tos_frame``: (H, W) f32 with values in [0, 255] (u8 TOS widened by the
    caller).  Returns a 1-tuple (AOT lowers with return_tuple=True) of the
    normalized (H, W) f32 response map in [0, 1].
    """
    x = tos_frame.astype(jnp.float32) * (1.0 / 255.0)
    if use_pallas:
        r = harris_kernel.harris_response(x, k=HARRIS_K)
    else:
        r = harris_response_ref(x, k=HARRIS_K)
    return (_normalize01(r),)


def harris_lut_ref(tos_frame: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Oracle variant of :func:`harris_lut` (pure jnp, no Pallas)."""
    return harris_lut(tos_frame, use_pallas=False)
