//! End-to-end validation driver (the repo's headline demo): run the full
//! three-layer system on both scene datasets, sweep all four detectors
//! (NMC-TOS/luvHarris, eHarris, eFAST, ARC*), and report the PR-AUC table
//! plus the simulated hardware cost — the system-level story of the paper
//! in one binary. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example corner_detection_e2e
//! ```

use nmc_tos::coordinator::{Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::detectors::{arc::Arc, eharris::EHarris, fast::EFast, EventScorer};
use nmc_tos::eval::PrCurve;
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::Resolution;

fn main() -> anyhow::Result<()> {
    let n_events = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000usize);

    for (name, cfg_fn) in [
        ("shapes_dof", SceneConfig::shapes_dof as fn() -> SceneConfig),
        ("dynamic_dof", SceneConfig::dynamic_dof as fn() -> SceneConfig),
    ] {
        println!("=== {name}: {n_events} events ===");
        let mut scene = cfg_fn().build(42);
        let (events, gt) = scene.generate_with_gt(n_events);
        let labels = gt.label_events(&events, 3.5);
        let base_rate =
            labels.iter().filter(|&&l| l).count() as f64 / labels.len() as f64;
        println!("corner-event base rate: {:.3}", base_rate);

        // --- the paper's system, fed through the streaming ingestion
        // path (bit-identical to load-all at any chunk size) --------------
        let t0 = std::time::Instant::now();
        let mut pipe = Pipeline::new(PipelineConfig::davis240())?;
        let report = pipe.run_stream(&mut SliceSource::new(&events, 32_768))?;
        let scored = report.scored_events(&gt, 3.5);
        let auc = PrCurve::from_scores(&scored, 101).auc();
        println!(
            "{:<14} AUC {:.3}   (host {:.2}s, sim busy {:.1} ms, sim energy {:.1} µJ)",
            "NMC-TOS",
            auc,
            t0.elapsed().as_secs_f64(),
            report.backend.busy_ns / 1e6,
            report.backend.energy_pj / 1e6,
        );

        // --- baselines (per-event scorers on the raw stream) -------------
        let mut baselines: Vec<Box<dyn EventScorer>> = vec![
            Box::new(EHarris::new(Resolution::DAVIS240)),
            Box::new(EFast::new(Resolution::DAVIS240)),
            Box::new(Arc::new(Resolution::DAVIS240)),
        ];
        for det in &mut baselines {
            let t0 = std::time::Instant::now();
            let scored: Vec<(f64, bool)> = events
                .iter()
                .zip(&labels)
                .map(|(e, &l)| (det.score(e), l))
                .collect();
            let auc = PrCurve::from_scores(&scored, 101).auc();
            println!(
                "{:<14} AUC {:.3}   (host {:.2}s, {:.0} ops/event -> {:.2} Meps @500 MHz)",
                det.name(),
                auc,
                t0.elapsed().as_secs_f64(),
                det.ops_per_event(),
                nmc_tos::detectors::max_throughput_eps(det.ops_per_event(), 500e6) / 1e6,
            );
        }
        println!();
    }
    println!("expected shape (paper Sec. II/V): NMC-TOS ~ eHarris accuracy,");
    println!("FAST/ARC lower AUC (noise-sensitive), but only NMC-TOS sustains >60 Meps.");
    Ok(())
}
