//! Quickstart: build a synthetic event scene, run the full NMC-TOS corner
//! detection pipeline (STCF -> NMC macro -> DVFS -> AOT Harris via PJRT),
//! and print what came out.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use nmc_tos::coordinator::{Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::eval::PrCurve;

fn main() -> anyhow::Result<()> {
    // 1. a scene: moving polygons over a DAVIS240, exact corner ground truth
    let mut scene = SceneConfig::shapes_dof().build(/*seed=*/ 42);
    let (events, gt) = scene.generate_with_gt(150_000);
    println!("generated {} events over {:.2} s", events.len(),
        events.last().unwrap().t as f64 * 1e-6);

    // 2. the pipeline of paper Fig. 2, all defaults
    let mut pipe = Pipeline::new(PipelineConfig::davis240())?;
    let report = pipe.run(&events)?;

    // 3. what happened
    println!("signal after STCF   : {}", report.events_signal);
    println!("corners tagged      : {}", report.corners.len());
    println!("Harris LUT refreshes: {}", report.lut_refreshes);
    println!("DVFS switches       : {}", report.dvfs_switches);
    println!("busy (simulated)    : {:.2} ms", report.backend.busy_ns / 1e6);
    println!("energy (simulated)  : {:.2} µJ", report.backend.energy_pj / 1e6);

    // 4. quality against ground truth
    let auc = PrCurve::from_scores(&report.scored_events(&gt, 3.5), 101).auc();
    println!("precision-recall AUC: {auc:.3}");

    // 5. a couple of tagged corner events
    for &i in report.corners.iter().take(5) {
        let e = report.signal_events[i];
        println!("  corner @ ({:>3},{:>3}) t={:>8} µs score={:.2}",
            e.x, e.y, e.t, report.scores[i]);
    }
    Ok(())
}
