//! Live corner streaming demo: a `StreamServer` on loopback TCP, a
//! protocol-v2 `feed` client, and a [`CornerSink`] that watches corners
//! and per-session stats arrive *while* the stream is still being sent —
//! the paper's event-rate output story, end to end over the wire. Runs
//! headless (eFAST detector), so no `make artifacts` needed.
//!
//! ```bash
//! cargo run --release --example live_corners
//! ```
//!
//! The same thing from the CLI, in two shells:
//!
//! ```bash
//! nmc-tos gen-data --events 500000 --out results/events.bin
//! nmc-tos serve --listen 127.0.0.1:7700 --stats-interval 100000 --sessions 1
//! nmc-tos feed --input results/events.bin --print-corners
//! ```

use std::net::{TcpListener, TcpStream};
use std::thread;

use nmc_tos::coordinator::{
    BackendKind, Corner, CornerSink, DetectorKind, LiveStats, PipelineConfig,
};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::Resolution;
use nmc_tos::serve::wire::{self, Hello};
use nmc_tos::serve::{ServeConfig, StreamServer};

const EVENTS: usize = 200_000;
const STATS_EVERY: u64 = 50_000;

/// Prints the first few corners, then a running count, plus every live
/// stats snapshot the server streams.
#[derive(Default)]
struct LivePrinter {
    corners: u64,
    stats: u64,
}

impl CornerSink for LivePrinter {
    fn on_corner(&mut self, c: &Corner) -> anyhow::Result<()> {
        self.corners += 1;
        if self.corners <= 5 {
            println!(
                "corner #{:<4} seq {:<8} at ({:>3},{:>3})  t {:>9} µs  score {:.3}",
                self.corners, c.seq, c.ev.x, c.ev.y, c.ev.t, c.score
            );
        } else if self.corners % 1_000 == 0 {
            println!("… {} corners received so far", self.corners);
        }
        Ok(())
    }

    fn on_stats(&mut self, s: &LiveStats) -> anyhow::Result<()> {
        self.stats += 1;
        println!(
            "live stats #{}: {} events in, {} signal, {} corners, {} DVFS switches",
            self.stats, s.events_in, s.events_signal, s.corners_total, s.dvfs_switches
        );
        Ok(())
    }
}

fn main() -> anyhow::Result<()> {
    // server policy: golden software backend, SAE detector, counters
    // only — results leave through the wire, not through RunReport
    let mut base = PipelineConfig::davis240();
    base.backend = BackendKind::Golden;
    base.detector = DetectorKind::Fast;
    base.record_per_event = false;
    base.stats_interval_events = Some(STATS_EVERY);
    let server = StreamServer::new(ServeConfig::new(base))?;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;

    let client = thread::spawn(move || -> anyhow::Result<(wire::Summary, LivePrinter)> {
        let scene = SceneConfig::shapes_dof().build(7);
        let mut source = scene.into_source(EVENTS, 16_384);
        let conn = TcpStream::connect(addr)?;
        let mut sink = LivePrinter::default();
        // a v2 hello: corners + stats stream back while we send
        let summary =
            wire::feed_with_sink(conn, Hello::v2(1, Resolution::DAVIS240), &mut source, &mut sink)?;
        Ok((summary, sink))
    });
    server.serve(&listener, Some(1))?;

    let (summary, sink) = client.join().expect("client thread panicked")?;
    println!("\n== session summary ==");
    println!("events sent      : {}", summary.events_in);
    println!("signal after STCF: {}", summary.events_signal);
    println!("corners (summary): {}", summary.corners_total);
    println!("corners (live)   : {}", sink.corners);
    println!("stats snapshots  : {}", sink.stats);
    assert_eq!(
        summary.corners_total, sink.corners,
        "every summarized corner was also streamed live"
    );
    assert_eq!(sink.stats, EVENTS as u64 / STATS_EVERY);

    let stats = server.shutdown();
    println!(
        "server: {} v2 session(s), {} corners streamed, {} stats frames",
        stats.sessions_v2, stats.corners_streamed, stats.stats_frames
    );
    Ok(())
}
