//! DVFS power study: integrate all five Table-I datasets, print the power
//! table, the voltage residency histograms, and an ablation of the DVFS
//! window size — the Fig. 8 / Table I scenario as a library consumer
//! would run it.
//!
//! ```bash
//! cargo run --release --example dvfs_power_study
//! ```

use nmc_tos::datasets::{profiles::RateProfile, DatasetKind};
use nmc_tos::dvfs::DvfsConfig;
use nmc_tos::power;

fn main() {
    println!("=== Table I: power with vs without DVFS ===");
    println!(
        "{:<14}{:>12}{:>12}{:>14}{:>14}{:>9}",
        "dataset", "peak Meps", "events M", "DVFS mW", "fixed mW", "saving"
    );
    for kind in DatasetKind::ALL {
        let p = RateProfile::for_dataset(kind);
        let r = power::integrate(&p, DvfsConfig::default(), 64);
        println!(
            "{:<14}{:>12.1}{:>12.1}{:>14.3}{:>14.3}{:>8.1}x",
            r.dataset,
            r.peak_rate / 1e6,
            r.events / 1e6,
            r.power_dvfs_mw,
            r.power_fixed_mw,
            r.power_fixed_mw / r.power_dvfs_mw
        );
    }

    println!("\n=== voltage residency (driving) ===");
    let p = RateProfile::for_dataset(DatasetKind::Driving);
    let r = power::integrate(&p, DvfsConfig::default(), 64);
    let total: f64 = r.residency.iter().map(|(_, s)| s).sum();
    for (vdd, secs) in &r.residency {
        let pct = secs / total * 100.0;
        println!("{vdd:>5.2} V  {secs:>7.2} s  {pct:>5.1} %  |{}", "#".repeat(pct as usize));
    }
    println!("DVFS switches: {}   event loss: {}", r.switches,
        if r.no_event_loss { "none" } else { "YES" });

    println!("\n=== ablation: DVFS window size (driving) ===");
    println!("{:>10} {:>12} {:>12} {:>10}", "TW (ms)", "DVFS mW", "switches", "loss?");
    for tw_ms in [2u64, 5, 10, 20, 50, 100] {
        let cfg = DvfsConfig { tw_us: tw_ms * 1000, ..DvfsConfig::default() };
        let r = power::integrate(&p, cfg, 1_000_000);
        println!(
            "{:>10} {:>12.3} {:>12} {:>10}",
            tw_ms,
            r.power_dvfs_mw,
            r.switches,
            if r.no_event_loss { "no" } else { "YES" }
        );
    }
    println!("\n(smaller windows track bursts tighter = lower power, but switch");
    println!(" more often and risk loss on fast rises — the paper's 10 ms is the");
    println!(" sweet spot for driving-class streams)");

    println!("\n=== ablation: headroom factor (driving) ===");
    println!("{:>10} {:>12} {:>10}", "headroom", "DVFS mW", "loss?");
    for headroom in [1.0, 1.1, 1.2, 1.5, 2.0] {
        let cfg = DvfsConfig { headroom, ..DvfsConfig::default() };
        let r = power::integrate(&p, cfg, 1_000_000);
        println!(
            "{:>10.1} {:>12.3} {:>10}",
            headroom,
            r.power_dvfs_mw,
            if r.no_event_loss { "no" } else { "YES" }
        );
    }
}
