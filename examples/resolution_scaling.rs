//! Multi-block scaling study (DESIGN.md §Extensions): how the NMC-TOS
//! macro tiles from DAVIS240 to an HD Prophesee sensor, and what the
//! patch-update bottleneck looks like at each resolution — the paper's
//! "high-resolution EBC" motivation quantified.
//!
//! ```bash
//! cargo run --release --example resolution_scaling
//! ```

use nmc_tos::conventional::ConventionalModel;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::nmc::{sram::BlockGrid, NmcConfig, NmcMacro, timing::TimingModel};
use nmc_tos::util::rng::Rng;

fn main() {
    println!("=== NMC block tiling across sensor resolutions ===");
    println!(
        "{:<12}{:>12}{:>9}{:>14}{:>16}",
        "sensor", "pixels", "blocks", "SRAM (kbit)", "storage (ms@10Meps)"
    );
    for (name, res) in [
        ("DAVIS240", Resolution::DAVIS240),
        ("DAVIS346", Resolution::DAVIS346),
        ("HD720", Resolution::HD720),
    ] {
        let grid = BlockGrid::for_resolution(res);
        println!(
            "{:<12}{:>12}{:>9}{:>14.0}{:>16.1}",
            name,
            res.pixels(),
            grid.block_count(),
            grid.total_bits() as f64 / 1000.0,
            // time to redraw the full surface at 10 Meps of events
            res.pixels() as f64 / 10e6 * 1000.0,
        );
    }

    // The key point of the paper: TOS update throughput is independent of
    // resolution (the patch is local), so one macro handles HD sensors that
    // overwhelm the conventional sequential implementation.
    println!("\n=== sustained event-rate capability (7x7 patches) ===");
    println!(
        "{:<10}{:>18}{:>18}{:>14}",
        "Vdd", "NMC+pipe (Meps)", "conventional", "speedup"
    );
    for mv in [600u32, 800, 1000, 1200] {
        let v = mv as f64 / 1000.0;
        let nmc = TimingModel::at(v).max_event_rate();
        let conv = ConventionalModel::at(v).max_event_rate();
        println!(
            "{:<10.2}{:>18.1}{:>18.2}{:>13.1}x",
            v,
            nmc / 1e6,
            conv / 1e6,
            nmc / conv
        );
    }

    // Simulated sanity check: events spread over an HD sensor exercise all
    // 44 blocks and the clipped-patch accounting still balances.
    println!("\n=== HD720 smoke run (400k events over 44 blocks) ===");
    let mut mac = NmcMacro::new(Resolution::HD720, NmcConfig::default()).expect("valid default config");
    let mut rng = Rng::seed_from(9);
    let t0 = std::time::Instant::now();
    for i in 0..400_000u64 {
        let e = Event::on(
            rng.below(1280) as u16,
            rng.below(720) as u16,
            i,
        );
        mac.process(&e);
    }
    let s = mac.stats();
    println!("blocks             : {}", mac.block_count());
    println!("events processed   : {}", s.events);
    println!("simulated busy     : {:.2} ms  ({:.1} Meps simulated capacity)",
        s.busy_ns / 1e6, s.events as f64 / (s.busy_ns * 1e-9) / 1e6);
    println!("simulated energy   : {:.1} µJ", s.energy_pj / 1e6);
    println!("host wall          : {:.2} s  ({:.2} M sim-events/s)",
        t0.elapsed().as_secs_f64(),
        s.events as f64 / t0.elapsed().as_secs_f64() / 1e6);
}
