//! Streaming ingestion demo: bounded-memory event processing from disk.
//!
//! Generates a recording, saves it in the binary AER container, then
//! streams it through the full pipeline in small chunks — peak
//! event-buffer memory stays O(chunk) regardless of recording length —
//! and verifies the result is bit-identical to the load-all path.
//! Runs headless (eFAST detector), so no `make artifacts` needed.
//!
//! ```bash
//! cargo run --release --example streaming_ingest
//! ```

use nmc_tos::coordinator::{DetectorKind, Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::codec::{self, BinaryStreamSource};

const CHUNK_EVENTS: usize = 16_384;

fn config() -> PipelineConfig {
    let mut cfg = PipelineConfig::davis240();
    cfg.detector = DetectorKind::Fast; // SAE detector: no PJRT engine
    cfg
}

fn main() -> anyhow::Result<()> {
    // 1. a recording on disk (stand-in for a camera dump)
    let mut scene = SceneConfig::shapes_dof().build(42);
    let events = scene.generate(300_000);
    let dir = std::env::temp_dir().join("nmc_tos_streaming_demo");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("events.bin");
    codec::save(&path, &events)?;
    println!("wrote {} events to {}", events.len(), path.display());

    // 2. baseline: the whole recording materialized in RAM
    let mut pipe = Pipeline::from_config_without_engine(config())?;
    let full = pipe.run(&events)?;

    // 3. streamed: decoded incrementally, chunks of CHUNK_EVENTS
    let mut pipe = Pipeline::from_config_without_engine(config())?;
    let mut src = BinaryStreamSource::new(std::fs::File::open(&path)?, CHUNK_EVENTS)?;
    let streamed = pipe.run_stream(&mut src)?;

    println!("load-all : {} signal, {} corners", full.events_signal, full.corners.len());
    println!(
        "streamed : {} signal, {} corners (chunks of {CHUNK_EVENTS})",
        streamed.events_signal,
        streamed.corners.len()
    );
    assert_eq!(full.final_tos, streamed.final_tos);
    assert_eq!(full.scores, streamed.scores);
    println!("bit-identical: final surface and all {} scores match", full.scores.len());

    // 4. unbounded-run mode: per-event recording off, the report holds
    //    only counters — this is the configuration for recordings that
    //    never fit in memory
    let mut cfg = config();
    cfg.record_per_event = false;
    let mut pipe = Pipeline::from_config_without_engine(cfg)?;
    let mut src = BinaryStreamSource::new(std::fs::File::open(&path)?, CHUNK_EVENTS)?;
    let lean = pipe.run_stream(&mut src)?;
    println!(
        "no-record: {} signal, {} corners, {} per-event vector entries retained",
        lean.events_signal,
        lean.corners_total,
        lean.scores.len() + lean.signal_events.len() + lean.corners.len()
    );
    Ok(())
}
