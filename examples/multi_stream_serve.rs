//! Multi-stream serving demo: one `StreamServer` driving several
//! concurrent camera streams — some submitted in-process, some arriving
//! over loopback TCP exactly as `nmc-tos feed` would send them — over a
//! shared engine pool. Runs headless (eFAST detector), so no
//! `make artifacts` needed.
//!
//! ```bash
//! cargo run --release --example multi_stream_serve
//! ```
//!
//! The same thing from the CLI, in two shells:
//!
//! ```bash
//! nmc-tos gen-data --events 500000 --out results/events.bin
//! nmc-tos serve --listen 127.0.0.1:7700 --max-streams 4 --sessions 2
//! nmc-tos feed --input results/events.bin --connect 127.0.0.1:7700
//! ```

use std::net::{TcpListener, TcpStream};
use std::thread;

use nmc_tos::coordinator::{BackendKind, DetectorKind, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::Resolution;
use nmc_tos::serve::wire::{self, Hello};
use nmc_tos::serve::{ServeConfig, StreamServer};

const LOCAL_STREAMS: u32 = 3;
const TCP_STREAMS: u32 = 2;
const EVENTS_PER_STREAM: usize = 120_000;

fn main() -> anyhow::Result<()> {
    // server policy: sharded software backend, SAE detector, counters only
    let mut base = PipelineConfig::davis240();
    base.backend = BackendKind::Sharded;
    base.detector = DetectorKind::Fast;
    base.record_per_event = false; // streams could be unbounded
    let mut cfg = ServeConfig::new(base);
    cfg.max_streams = 4;
    let server = StreamServer::new(cfg)?;

    // 1. in-process sessions: synthetic cameras handed straight to the
    //    worker pool as EventSources (the embedding-application path)
    let handles: Vec<_> = (0..LOCAL_STREAMS)
        .map(|i| {
            let scene = SceneConfig::shapes_dof().build(40 + i as u64);
            let source = scene.into_source(EVENTS_PER_STREAM, 16_384);
            server.submit(i, Resolution::DAVIS240, Box::new(source))
        })
        .collect::<anyhow::Result<_>>()?;

    // 2. TCP sessions: loopback clients speaking the `feed` wire protocol
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let clients: Vec<_> = (0..TCP_STREAMS)
        .map(|i| {
            thread::spawn(move || -> anyhow::Result<wire::Summary> {
                let scene = SceneConfig::dynamic_dof().build(90 + i as u64);
                let mut source = scene.into_source(EVENTS_PER_STREAM, 16_384);
                let conn = TcpStream::connect(addr)?;
                // summary-only v1 sessions; the live_corners example
                // shows the v2 streamed-results path
                let hello = Hello::v1(100 + i, Resolution::DAVIS240);
                wire::feed(conn, hello, &mut source)
            })
        })
        .collect();
    server.serve(&listener, Some(TCP_STREAMS as usize))?;

    for h in handles {
        let report = h.join()?;
        println!(
            "local stream : {} events -> {} signal, {} corners ({:.0} keps)",
            report.events_in,
            report.events_signal,
            report.corners_total,
            report.events_in as f64 / report.wall_s.max(1e-9) / 1e3
        );
    }
    for c in clients {
        let summary = c.join().expect("client thread panicked")?;
        println!(
            "tcp stream {} : {} events -> {} signal, {} corners ({:.3} s server time)",
            summary.stream_id,
            summary.events_in,
            summary.events_signal,
            summary.corners_total,
            summary.wall_us as f64 / 1e6
        );
    }

    let stats = server.shutdown();
    println!("\n== aggregate server stats ==");
    println!("sessions completed : {}", stats.sessions_completed);
    println!("events ingested    : {}", stats.events_in);
    println!("peak concurrency   : {}", stats.peak_concurrent);
    println!("mean ingest rate   : {:.0} keps", stats.events_per_sec() / 1e3);
    println!("worst realtime lag : {:+.3} s", stats.worst_lag_s);
    println!(
        "engines compiled/reused: {}/{}",
        stats.pool.engines_created, stats.pool.engines_reused
    );
    assert_eq!(stats.sessions_completed, (LOCAL_STREAMS + TCP_STREAMS) as u64);
    assert_eq!(stats.sessions_failed, 0);
    Ok(())
}
