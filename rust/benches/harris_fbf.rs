//! Bench: the FBF Harris LUT path through PJRT — the frame-rate side of
//! the luvHarris decoupling. The paper argues this side is NOT the
//! bottleneck (>1 kHz on a CNN accelerator); here we measure what the AOT
//! CPU artifact sustains, which bounds how fresh the LUT can be.
//!
//! Requires `make artifacts`.

mod common;

use nmc_tos::runtime::{default_artifact_dir, HarrisEngine, Manifest};
use nmc_tos::util::rng::Rng;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("meta.json").exists() {
        println!("SKIP harris_fbf: run `make artifacts` first");
        return;
    }
    println!("== bench: FBF Harris via PJRT CPU ==");
    let manifest = Manifest::load(&dir).unwrap();
    for name in ["test64", "davis240", "davis346"] {
        let mut engine = HarrisEngine::load(&manifest, name).unwrap();
        let mut rng = Rng::seed_from(6);
        let frame: Vec<f32> =
            (0..engine.height * engine.width).map(|_| (rng.below(256)) as f32).collect();
        let (med, mean) = common::measure(3, 20, || {
            let lut = engine.compute(&frame).unwrap();
            std::hint::black_box(&lut);
        });
        common::report(&format!("harris_fbf/{name}/1_frame"), med, mean, 1.0);
        println!(
            "    -> LUT refresh rate: {:.0} Hz (paper's CNN-chip estimate: >1 kHz)",
            1e9 / med
        );
    }
}
