//! Shared measurement harness for the plain (`harness = false`) benches:
//! warmup + N timed iterations, reporting median / mean / throughput.
//! (criterion is unavailable in this offline build; this keeps the same
//! shape of output so `cargo bench | tee bench_output.txt` stays useful.)
//!
//! [`Harness`] adds the machine-readable trajectory mode: benches that
//! construct one record their rows and dump `BENCH_*.json` at the repo
//! root on `finish()`, so every PR leaves a comparable perf data point.
//! Flags (after `--` on `cargo bench`): `--smoke` shrinks event counts /
//! iterations for CI, `--json PATH` overrides the output file;
//! `BENCH_SMOKE=1` in the environment also enables smoke mode.

#![allow(dead_code)] // each bench binary compiles its own copy of this module

use std::path::PathBuf;
use std::time::Instant;

use nmc_tos::util::json::Json;

/// Run `f` repeatedly, returning (median_ns, mean_ns) per iteration.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    (median, mean)
}

/// Print one bench row: name, median per iter, and items/s throughput.
pub fn report(name: &str, median_ns: f64, mean_ns: f64, items_per_iter: f64) {
    let per_item = median_ns / items_per_iter;
    let throughput = 1e9 / per_item;
    println!(
        "{name:<44} median {:>10.1} µs   mean {:>10.1} µs   {:>12.3} M items/s",
        median_ns / 1e3,
        mean_ns / 1e3,
        throughput / 1e6
    );
}

/// One recorded bench row.
struct Row {
    name: String,
    median_ns: f64,
    mean_ns: f64,
    items_per_iter: f64,
}

/// Bench harness with a machine-readable output mode (`BENCH_*.json`).
pub struct Harness {
    /// Shrunken run for CI (`--smoke` / `BENCH_SMOKE=1`): small event
    /// counts, minimal iterations — checks the harness itself, the
    /// numbers are not comparable to full runs (`"smoke": true` in the
    /// JSON marks them).
    pub smoke: bool,
    bench: &'static str,
    rows: Vec<Row>,
    out: PathBuf,
}

impl Harness {
    /// Parse bench flags; `default_out` is relative to the workspace root.
    pub fn new(bench: &'static str, default_out: &str) -> Self {
        let mut smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| v != "0" && !v.is_empty());
        let mut out = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(default_out);
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--smoke" => smoke = true,
                "--json" => {
                    if let Some(p) = args.next() {
                        out = PathBuf::from(p);
                    }
                }
                _ => {} // ignore cargo's own bench flags (--bench etc.)
            }
        }
        Self { smoke, bench, rows: Vec::new(), out }
    }

    /// Scale an event count for the active mode.
    pub fn events(&self, full: usize) -> usize {
        if self.smoke {
            (full / 20).clamp(1, full.max(1))
        } else {
            full
        }
    }

    /// Measure + print + record one row (`items` = items per iteration,
    /// for the events/s column). Warmup/iterations collapse in smoke mode.
    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, items: f64, f: F) {
        let (warmup, iters) =
            if self.smoke { (warmup.min(1), iters.min(2)) } else { (warmup, iters) };
        let (median_ns, mean_ns) = measure(warmup, iters, f);
        report(name, median_ns, mean_ns, items);
        self.rows.push(Row { name: name.to_string(), median_ns, mean_ns, items_per_iter: items });
    }

    /// Write the recorded rows as `BENCH_*.json` (schema: see DESIGN.md
    /// §Hot paths — one object per row with median/mean ns and events/s).
    pub fn finish(&self) {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("median_ns", Json::Num(r.median_ns)),
                    ("mean_ns", Json::Num(r.mean_ns)),
                    ("items_per_iter", Json::Num(r.items_per_iter)),
                    ("events_per_sec", Json::Num(r.items_per_iter / (r.median_ns.max(1.0) / 1e9))),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str("nmc-tos-bench-v1".into())),
            ("bench", Json::Str(self.bench.into())),
            ("smoke", Json::Bool(self.smoke)),
            // which decrement/clamp path the dispatcher selected on the
            // machine that produced these numbers — the regression gate
            // refuses to compare across different paths
            ("kernel", Json::Str(nmc_tos::tos::kernel::active_path().as_str().into())),
            ("rows", Json::Arr(rows)),
        ]);
        std::fs::write(&self.out, doc.render())
            .unwrap_or_else(|e| panic!("writing {}: {e}", self.out.display()));
        println!("\nwrote {}", self.out.display());
    }
}
