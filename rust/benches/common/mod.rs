//! Shared measurement harness for the plain (`harness = false`) benches:
//! warmup + N timed iterations, reporting median / mean / throughput.
//! (criterion is unavailable in this offline build; this keeps the same
//! shape of output so `cargo bench | tee bench_output.txt` stays useful.)

use std::time::Instant;

/// Run `f` repeatedly, returning (median_ns, mean_ns) per iteration.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    (median, mean)
}

/// Print one bench row: name, median per iter, and items/s throughput.
pub fn report(name: &str, median_ns: f64, mean_ns: f64, items_per_iter: f64) {
    let per_item = median_ns / items_per_iter;
    let throughput = 1e9 / per_item;
    println!(
        "{name:<44} median {:>10.1} µs   mean {:>10.1} µs   {:>12.3} M items/s",
        median_ns / 1e3,
        mean_ns / 1e3,
        throughput / 1e6
    );
}
