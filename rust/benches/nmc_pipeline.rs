//! Bench: the phase-level NMC macro simulator (Fig. 9/10 engine) —
//! pipelined vs unpipelined, with and without error injection, plus the
//! simulated-vs-host throughput ratio that gates experiment turnaround.

mod common;

use nmc_tos::events::{Event, Resolution};
use nmc_tos::nmc::{NmcConfig, NmcMacro};
use nmc_tos::util::rng::Rng;

fn events(res: Resolution, n: usize) -> Vec<Event> {
    let mut rng = Rng::seed_from(2);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64,
            )
        })
        .collect()
}

fn main() {
    println!("== bench: NMC macro simulator ==");
    let res = Resolution::DAVIS240;
    let evs = events(res, 50_000);

    for (label, pipelined, inject, vdd) in [
        ("pipelined/1.2V", true, false, 1.2),
        ("unpipelined/1.2V", false, false, 1.2),
        ("pipelined/0.6V+BER", true, true, 0.6),
    ] {
        let cfg = NmcConfig {
            pipelined,
            inject_errors: inject,
            vdd,
            seed: 3,
            ..NmcConfig::default()
        };
        let mut mac = NmcMacro::new(res, cfg).unwrap();
        let (med, mean) = common::measure(2, 10, || {
            mac.process_batch(&evs);
        });
        common::report(&format!("nmc_sim/{label}/50k_events"), med, mean, evs.len() as f64);
    }

    // DVFS voltage retarget cost (happens per switch, not per event)
    let mut mac = NmcMacro::new(res, NmcConfig::default()).unwrap();
    let (med, mean) = common::measure(10, 50, || {
        for mv in [600u32, 800, 1000, 1200] {
            mac.set_vdd(mv as f64 / 1000.0);
        }
    });
    common::report("nmc_sim/set_vdd/4_switches", med, mean, 4.0);

    // snapshot cost (runs once per LUT refresh)
    let (med, mean) = common::measure(3, 20, || {
        let s = mac.snapshot_u8();
        std::hint::black_box(&s);
    });
    common::report("nmc_sim/snapshot_u8/davis240", med, mean, 1.0);
}
