//! Bench: golden TOS update throughput (the software model of the paper's
//! hot path) across patch sizes and resolutions. This is the simulator's
//! own hot loop — EXPERIMENTS.md §Perf tracks it.

mod common;

use nmc_tos::events::{Event, Resolution};
use nmc_tos::tos::{TosConfig, TosSurface};
use nmc_tos::util::rng::Rng;

fn events(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64,
            )
        })
        .collect()
}

fn main() {
    println!("== bench: golden TOS update ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        for patch in [5u16, 7, 9] {
            let evs = events(res, 100_000, 1);
            let cfg = TosConfig { patch, threshold: 225 };
            let mut surf = TosSurface::new(res, cfg);
            let (med, mean) = common::measure(2, 10, || {
                surf.update_batch(&evs);
            });
            common::report(
                &format!("tos_update/{label}/p{patch}/100k_events"),
                med,
                mean,
                evs.len() as f64,
            );
        }
    }
}
