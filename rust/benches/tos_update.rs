//! Bench: TOS update throughput — the paper's hot path in software.
//!
//! Rows cover every kernel dispatch path the host can run (scalar, SWAR,
//! SSE2/AVX2/NEON — the `kernel_{path}` rows the bench-regression gate
//! tracks), the dispatched golden kernel against the scalar reference
//! loop (the pre-vectorization baseline, kept in-tree as
//! `decrement_clamp_scalar`), every backend at DAVIS240/HD720, and the
//! sharded parallel model against the single-threaded golden model.
//! Emits `BENCH_tos.json` at the repo root (see DESIGN.md §Hot paths) so
//! each PR records a comparable trajectory point; `--smoke` shrinks the
//! run for CI.

mod common;

use common::Harness;
use nmc_tos::conventional::ConventionalTos;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::nmc::{NmcConfig, NmcMacro};
use nmc_tos::tos::backend::{clip_patch, decrement_clamp_scalar};
use nmc_tos::tos::kernel::{active_path, available_paths, decrement_clamp_with};
use nmc_tos::tos::{ShardedTos, TosBackend, TosConfig, TosSurface};
use nmc_tos::util::rng::Rng;

fn events(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64,
            )
        })
        .collect()
}

fn main() {
    let mut h = Harness::new("tos_update", "BENCH_tos.json");

    // Every kernel path this host can dispatch, on the same stream: the
    // bench-regression gate tracks the widest SIMD row against the
    // swar64 row (ISSUE 6 acceptance: >= 1.5x) and golden against
    // scalar_ref. Each path is also cross-checked bit-exact right here on
    // its own bench stream.
    println!("== bench: decrement/clamp kernel per dispatch path ==");
    println!("   (startup-selected path: {})", active_path());
    {
        let res = Resolution::DAVIS240;
        let cfg = TosConfig::default();
        let n = h.events(100_000);
        let evs = events(res, n, 7);
        let width = res.width as usize;
        let mut reference: Option<Vec<u8>> = None;
        for path in available_paths() {
            let mut data = vec![0u8; res.pixels()];
            h.run(&format!("tos_update/davis240/p7/kernel_{path}"), 2, 10, n as f64, || {
                for ev in &evs {
                    let rect = clip_patch(res, ev.x, ev.y, cfg.half());
                    decrement_clamp_with(path, &mut data, width, 0, rect, cfg.threshold);
                    data[res.index(ev.x, ev.y)] = 255;
                }
            });
            match &reference {
                None => reference = Some(data),
                Some(r) => assert_eq!(r, &data, "kernel path {path} diverged on bench stream"),
            }
        }
    }

    println!("\n== bench: golden vs scalar-reference TOS update ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        for patch in [5u16, 7, 9] {
            let n = h.events(100_000);
            let evs = events(res, n, 1);
            let cfg = TosConfig { patch, threshold: 225 };
            let mut surf = TosSurface::new(res, cfg).unwrap();
            h.run(&format!("tos_update/{label}/p{patch}/golden"), 2, 10, n as f64, || {
                surf.update_batch(&evs);
            });
            // the exact pre-PR hot loop: clip + scalar decrement/clamp +
            // centre write on a flat surface
            let mut data = vec![0u8; res.pixels()];
            let width = res.width as usize;
            h.run(&format!("tos_update/{label}/p{patch}/scalar_ref"), 2, 10, n as f64, || {
                for ev in &evs {
                    let rect = clip_patch(res, ev.x, ev.y, cfg.half());
                    decrement_clamp_scalar(&mut data, width, 0, rect, cfg.threshold);
                    data[res.index(ev.x, ev.y)] = 255;
                }
            });
        }
    }

    println!("\n== bench: TOS update per backend ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        let n = h.events(100_000);
        let evs = events(res, n, 2);
        let cfg = TosConfig::default();
        let mut backends: Vec<(String, Box<dyn TosBackend>)> = vec![
            ("golden".into(), Box::new(TosSurface::new(res, cfg).unwrap())),
            ("conventional".into(), Box::new(ConventionalTos::new(res, cfg, 1.2).unwrap())),
            (
                "nmc".into(),
                Box::new(NmcMacro::new(res, NmcConfig { tos: cfg, ..Default::default() }).unwrap()),
            ),
            ("sharded4".into(), Box::new(ShardedTos::new(res, cfg, 4).unwrap())),
        ];
        for (name, backend) in &mut backends {
            h.run(&format!("tos_update/{label}/backend_{name}"), 1, 5, n as f64, || {
                backend.process_batch(&evs);
            });
        }
    }

    // The acceptance stream of the sharded backend: 200k events over a
    // DAVIS240 plane, batched through the row-band workers.
    println!("\n== bench: sharded vs golden (200k-event stream) ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        let cfg = TosConfig::default();
        let n = h.events(200_000);
        let evs = events(res, n, 3);
        let mut golden = TosSurface::new(res, cfg).unwrap();
        h.run(&format!("tos_update/{label}/golden/200k_events"), 2, 10, n as f64, || {
            golden.update_batch(&evs);
        });
        for shards in [2usize, 4, 8] {
            let mut sharded = ShardedTos::new(res, cfg, shards).unwrap();
            h.run(
                &format!("tos_update/{label}/sharded{shards}/200k_events"),
                2,
                10,
                n as f64,
                || {
                    sharded.process_batch(&evs);
                },
            );
        }
    }

    // bit-exactness spot check on the exact bench stream: dispatched
    // golden, scalar reference, and the sharded batch path must agree
    // (the full sweep lives in rust/tests/properties.rs and
    // rust/tests/kernel_dispatch.rs)
    let cfg = TosConfig::default();
    let n = h.events(200_000);
    let evs = events(Resolution::DAVIS240, n, 3);
    let res = Resolution::DAVIS240;
    let mut a = TosSurface::new(res, cfg).unwrap();
    a.update_batch(&evs);
    let mut b = ShardedTos::new(res, cfg, 4).unwrap();
    b.process_batch(&evs);
    assert_eq!(a.data(), b.data(), "sharded output diverged from golden");
    let mut c = vec![0u8; res.pixels()];
    for ev in &evs {
        let rect = clip_patch(res, ev.x, ev.y, cfg.half());
        decrement_clamp_scalar(&mut c, res.width as usize, 0, rect, cfg.threshold);
        c[res.index(ev.x, ev.y)] = 255;
    }
    assert_eq!(a.data(), &c[..], "dispatched kernel diverged from scalar reference");
    println!(
        "\ngolden ({}) == scalar reference == sharded on the bench stream: OK",
        active_path()
    );

    h.finish();
}
