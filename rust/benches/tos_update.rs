//! Bench: golden TOS update throughput (the software model of the paper's
//! hot path) across patch sizes and resolutions, plus the sharded parallel
//! software backend against the single-threaded golden model. This is the
//! simulator's own hot loop — EXPERIMENTS.md §Perf tracks it.

mod common;

use nmc_tos::events::{Event, Resolution};
use nmc_tos::tos::{ShardedTos, TosConfig, TosSurface};
use nmc_tos::util::rng::Rng;

fn events(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64,
            )
        })
        .collect()
}

fn main() {
    println!("== bench: golden TOS update ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        for patch in [5u16, 7, 9] {
            let evs = events(res, 100_000, 1);
            let cfg = TosConfig { patch, threshold: 225 };
            let mut surf = TosSurface::new(res, cfg).unwrap();
            let (med, mean) = common::measure(2, 10, || {
                surf.update_batch(&evs);
            });
            common::report(
                &format!("tos_update/{label}/p{patch}/100k_events"),
                med,
                mean,
                evs.len() as f64,
            );
        }
    }

    // The acceptance stream of the sharded backend: 200k events over a
    // DAVIS240 plane, batched through the row-band workers.
    println!("\n== bench: sharded vs golden (200k-event DAVIS240 stream) ==");
    for (label, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        let cfg = TosConfig::default();
        let evs = events(res, 200_000, 3);
        let mut golden = TosSurface::new(res, cfg).unwrap();
        let (golden_med, golden_mean) = common::measure(2, 10, || {
            golden.update_batch(&evs);
        });
        common::report(
            &format!("tos_update/{label}/golden/200k_events"),
            golden_med,
            golden_mean,
            evs.len() as f64,
        );
        for shards in [2usize, 4, 8] {
            let mut sharded = ShardedTos::new(res, cfg, shards).unwrap();
            let (med, mean) = common::measure(2, 10, || {
                sharded.process_batch(&evs);
            });
            common::report(
                &format!("tos_update/{label}/sharded{shards}/200k_events"),
                med,
                mean,
                evs.len() as f64,
            );
            println!("    -> {:.2}x vs golden", golden_med / med);
        }
    }

    // bit-exactness spot check on the exact bench stream (the full sweep
    // lives in rust/tests/properties.rs)
    let cfg = TosConfig::default();
    let evs = events(Resolution::DAVIS240, 200_000, 3);
    let mut a = TosSurface::new(Resolution::DAVIS240, cfg).unwrap();
    a.update_batch(&evs);
    let mut b = ShardedTos::new(Resolution::DAVIS240, cfg, 4).unwrap();
    b.process_batch(&evs);
    assert_eq!(a.data(), b.data(), "sharded output diverged from golden");
    println!("\nsharded output bit-exact vs golden on the 200k stream: OK");
}
