//! Bench: STCF denoising filter throughput on clustered vs scattered
//! streams (branch behaviour differs: clusters exit the support scan
//! early).

mod common;

use nmc_tos::events::{Event, Resolution};
use nmc_tos::stcf::{Stcf, StcfConfig};
use nmc_tos::util::rng::Rng;

fn scattered(res: Resolution, n: usize) -> Vec<Event> {
    let mut rng = Rng::seed_from(4);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64 * 50,
            )
        })
        .collect()
}

fn clustered(res: Resolution, n: usize) -> Vec<Event> {
    let mut rng = Rng::seed_from(5);
    let mut cx = 120i64;
    let mut cy = 90i64;
    (0..n)
        .map(|i| {
            if i % 64 == 0 {
                cx = rng.below(res.width as u64 - 8) as i64 + 4;
                cy = rng.below(res.height as u64 - 8) as i64 + 4;
            }
            Event::on(
                (cx + rng.range_i64(-2, 2)) as u16,
                (cy + rng.range_i64(-2, 2)) as u16,
                i as u64 * 2,
            )
        })
        .collect()
}

fn main() {
    println!("== bench: STCF filter ==");
    let res = Resolution::DAVIS240;
    for (label, evs) in
        [("scattered", scattered(res, 200_000)), ("clustered", clustered(res, 200_000))]
    {
        for radius in [1u16, 2] {
            let cfg = StcfConfig { radius, ..StcfConfig::default() };
            let mut f = Stcf::new(res, cfg);
            let (med, mean) = common::measure(2, 10, || {
                for e in &evs {
                    std::hint::black_box(f.check(e));
                }
            });
            common::report(
                &format!("stcf/{label}/r{radius}/200k_events"),
                med,
                mean,
                evs.len() as f64,
            );
        }
    }
}
