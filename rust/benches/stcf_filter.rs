//! Bench: STCF denoising filter throughput on clustered vs scattered
//! streams, vectorized masked-lane classifier vs the scalar early-exit
//! reference (branch behaviour differs: clusters exit the scalar support
//! scan early, while the vectorized count is branch-free either way).
//! Emits `BENCH_stcf.json`; the bench-regression gate tracks the
//! vectorized-vs-scalar ratio per stream shape.

mod common;

use common::Harness;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::stcf::{Stcf, StcfConfig};
use nmc_tos::util::rng::Rng;

fn scattered(res: Resolution, n: usize) -> Vec<Event> {
    let mut rng = Rng::seed_from(4);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64 * 50,
            )
        })
        .collect()
}

fn clustered(res: Resolution, n: usize) -> Vec<Event> {
    let mut rng = Rng::seed_from(5);
    let mut cx = 120i64;
    let mut cy = 90i64;
    (0..n)
        .map(|i| {
            if i % 64 == 0 {
                cx = rng.below(res.width as u64 - 8) as i64 + 4;
                cy = rng.below(res.height as u64 - 8) as i64 + 4;
            }
            Event::on(
                (cx + rng.range_i64(-2, 2)) as u16,
                (cy + rng.range_i64(-2, 2)) as u16,
                i as u64 * 2,
            )
        })
        .collect()
}

fn main() {
    let mut h = Harness::new("stcf_filter", "BENCH_stcf.json");

    println!("== bench: STCF filter (vectorized vs scalar reference) ==");
    let res = Resolution::DAVIS240;
    let n = h.events(200_000);
    for (label, evs) in [("scattered", scattered(res, n)), ("clustered", clustered(res, n))] {
        for radius in [1u16, 2] {
            let cfg = StcfConfig { radius, ..StcfConfig::default() };
            let mut f = Stcf::new(res, cfg);
            h.run(&format!("stcf/{label}/r{radius}/200k_events"), 2, 10, evs.len() as f64, || {
                for e in &evs {
                    std::hint::black_box(f.check(e));
                }
            });
            let mut s = Stcf::new(res, cfg);
            h.run(&format!("stcf/{label}/r{radius}/scalar_ref"), 2, 10, evs.len() as f64, || {
                for e in &evs {
                    std::hint::black_box(s.check_scalar(e));
                }
            });
        }
    }

    // equivalence spot check on the exact bench streams: per-event
    // verdicts and telemetry must agree (the randomized sweep lives in
    // rust/tests/properties.rs)
    for (label, evs) in [("scattered", scattered(res, n)), ("clustered", clustered(res, n))] {
        for radius in [1u16, 2] {
            let cfg = StcfConfig { radius, ..StcfConfig::default() };
            let mut v = Stcf::new(res, cfg);
            let mut s = Stcf::new(res, cfg);
            for e in &evs {
                assert_eq!(v.check(e), s.check_scalar(e), "{label} r{radius} diverged");
            }
            assert_eq!(v.stats(), s.stats(), "{label} r{radius} stats diverged");
        }
    }
    println!("\nvectorized == scalar reference on both bench streams: OK");

    h.finish();
}
