//! Bench: multi-stream serving throughput — aggregate events/s of a
//! `StreamServer` driving S concurrent synthetic streams through its
//! worker pool, across stream counts and backends. Emits
//! `BENCH_serving.json` at the repo root (see DESIGN.md §Hot paths);
//! `--smoke` shrinks the run for CI.
//!
//! Engine-less (eFAST detector), so the rows measure the serving fabric +
//! pipeline cost, not PJRT. Sessions are submitted in-process: the TCP
//! wire path adds codec + loopback cost and is covered by the
//! integration tests; here the question is how aggregate throughput
//! scales with concurrent streams per backend.

mod common;

use common::Harness;
use nmc_tos::coordinator::{BackendKind, DetectorKind, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::Resolution;
use nmc_tos::serve::{ServeConfig, StreamServer};

fn main() {
    let mut h = Harness::new("serving", "BENCH_serving.json");

    println!("== bench: multi-stream serving (in-process sessions) ==");
    let events_per_stream = h.events(60_000);

    for bk in [BackendKind::Golden, BackendKind::Sharded] {
        for streams in [1usize, 2, 4, 8] {
            let mut base = PipelineConfig::davis240();
            base.backend = bk;
            base.detector = DetectorKind::Fast;
            base.shards = 4;
            base.record_per_event = false;
            let mut cfg = ServeConfig::new(base);
            cfg.max_streams = streams;
            let server = StreamServer::new(cfg).unwrap();

            let total = (streams * events_per_stream) as f64;
            h.run(
                &format!("serve/{}/{streams}streams/60k_each", bk.label()),
                1,
                3,
                total,
                || {
                    let handles: Vec<_> = (0..streams)
                        .map(|i| {
                            let scene = SceneConfig::shapes_dof().build(10 + i as u64);
                            let source = scene.into_source(events_per_stream, 16_384);
                            server
                                .submit(i as u32, Resolution::DAVIS240, Box::new(source))
                                .unwrap()
                        })
                        .collect();
                    for handle in handles {
                        std::hint::black_box(handle.join().unwrap().events_signal);
                    }
                },
            );
            let stats = server.shutdown();
            assert_eq!(stats.sessions_failed, 0);
        }
    }

    h.finish();
}
