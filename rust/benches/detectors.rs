//! Bench: per-event cost of every detector baseline — the software
//! reality behind the Fig. 1(b) throughput comparison.

mod common;

use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::detectors::{arc::Arc, eharris::EHarris, fast::EFast, harris::HarrisDetector, EventScorer};
use nmc_tos::events::Resolution;

fn main() {
    println!("== bench: detector baselines (per-event scoring) ==");
    let mut scene = SceneConfig::shapes_dof().build(7);
    let events = scene.generate(50_000);
    let res = Resolution::DAVIS240;

    let mut lut_det = HarrisDetector::new(res);
    lut_det.refresh(&vec![0.25f32; res.pixels()]);
    let (med, mean) = common::measure(2, 10, || {
        for e in &events {
            std::hint::black_box(lut_det.score(e));
        }
    });
    common::report("detector/luvharris_lut/50k", med, mean, events.len() as f64);

    let mut fast = EFast::new(res);
    let (med, mean) = common::measure(1, 5, || {
        for e in &events {
            std::hint::black_box(fast.score(e));
        }
    });
    common::report("detector/efast/50k", med, mean, events.len() as f64);

    let mut arc = Arc::new(res);
    let (med, mean) = common::measure(1, 5, || {
        for e in &events {
            std::hint::black_box(arc.score(e));
        }
    });
    common::report("detector/arc/50k", med, mean, events.len() as f64);

    let mut eh = EHarris::new(res);
    let subset = &events[..10_000];
    let (med, mean) = common::measure(1, 5, || {
        for e in subset {
            std::hint::black_box(eh.score(e));
        }
    });
    common::report("detector/eharris/10k", med, mean, subset.len() as f64);

    // dense 5x5-stencil reference vs the separable form, on the same
    // surface state (score() above left the FIFO warm)
    let (med, mean) = common::measure(1, 5, || {
        for e in subset {
            std::hint::black_box(eh.harris_at(e.x as i32, e.y as i32));
        }
    });
    common::report("detector/eharris_separable/10k", med, mean, subset.len() as f64);
    let (med, mean) = common::measure(1, 5, || {
        for e in subset {
            std::hint::black_box(eh.harris_at_dense(e.x as i32, e.y as i32));
        }
    });
    common::report("detector/eharris_dense_ref/10k", med, mean, subset.len() as f64);

    // surface-window sweep (the `--eharris-window` knob)
    for window in [500usize, 2000, 8000] {
        let mut eh = EHarris::with_params(res, window, EHarris::DEFAULT_K);
        let (med, mean) = common::measure(1, 5, || {
            for e in subset {
                std::hint::black_box(eh.score(e));
            }
        });
        common::report(&format!("detector/eharris_w{window}/10k"), med, mean, subset.len() as f64);
    }

    println!("\nmodelled digital throughput at 500 MHz (Fig. 1b):");
    // eharris quotes the dense reference cost (the paper's anchor);
    // eharris_separable is what this port actually executes
    for (name, ops) in [
        ("luvharris_lut", lut_det.ops_per_event()),
        ("efast", fast.ops_per_event()),
        ("arc", arc.ops_per_event()),
        ("eharris", eh.ops_per_event()),
        ("eharris_separable", eh.ops_per_event_separable()),
    ] {
        println!(
            "  {name:<16} {:>8.0} ops/event  -> {:>8.3} Meps",
            ops,
            nmc_tos::detectors::max_throughput_eps(ops, 500e6) / 1e6
        );
    }
}
