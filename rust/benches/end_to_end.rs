//! Bench: the full pipeline (STCF + NMC sim + DVFS + PJRT Harris +
//! tagging) — events/s of the whole system model, sync vs async LUT
//! refresh. This is the number that gates how large an experiment the
//! repo can run; EXPERIMENTS.md §Perf tracks it.
//!
//! Requires `make artifacts`.

mod common;

use nmc_tos::coordinator::{Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::runtime::default_artifact_dir;

fn main() {
    if !default_artifact_dir().join("meta.json").exists() {
        println!("SKIP end_to_end: run `make artifacts` first");
        return;
    }
    println!("== bench: full pipeline end-to-end ==");
    let mut scene = SceneConfig::shapes_dof().build(8);
    let events = scene.generate(100_000);

    for (label, async_mode, refresh) in [
        ("sync/refresh2k", false, 2_000usize),
        ("sync/refresh500", false, 500),
        ("async", true, 2_000),
    ] {
        let mut cfg = PipelineConfig::davis240();
        cfg.async_refresh = async_mode;
        cfg.lut_refresh_events = refresh;
        // construct once: PJRT client + HLO compile are per-process costs,
        // not per-run costs (the coordinator keeps the executable loaded)
        let mut pipe = Pipeline::new(cfg).unwrap();
        let (med, mean) = common::measure(1, 5, || {
            let r = pipe.run(&events).unwrap();
            std::hint::black_box(r.corners.len());
        });
        common::report(&format!("e2e/{label}/100k_events"), med, mean, events.len() as f64);
    }

    // engine-less variant isolates the simulator cost from PJRT
    let mut cfg = PipelineConfig::davis240();
    cfg.lut_refresh_events = usize::MAX;
    let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
    let (med, mean) = common::measure(1, 5, || {
        let r = pipe.run(&events).unwrap();
        std::hint::black_box(r.events_signal);
    });
    common::report("e2e/no_fbf/100k_events", med, mean, events.len() as f64);
}
