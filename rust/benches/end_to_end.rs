//! Bench: the full pipeline (STCF + NMC sim + DVFS + PJRT Harris +
//! tagging) — events/s of the whole system model, sync vs async LUT
//! refresh, plus the streamed ingestion path. This is the number that
//! gates how large an experiment the repo can run; EXPERIMENTS.md §Perf
//! tracks it.
//!
//! The engine-less and streamed rows run standalone; the FBF rows need
//! `make artifacts`.

mod common;

use nmc_tos::coordinator::{Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::source::SliceSource;
use nmc_tos::runtime::default_artifact_dir;

fn main() {
    println!("== bench: full pipeline end-to-end ==");
    let mut scene = SceneConfig::shapes_dof().build(8);
    let events = scene.generate(100_000);

    // engine-less variant isolates the simulator cost from PJRT
    let mut cfg = PipelineConfig::davis240();
    cfg.lut_refresh_events = usize::MAX;
    let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
    let (med, mean) = common::measure(1, 5, || {
        let r = pipe.run(&events).unwrap();
        std::hint::black_box(r.events_signal);
    });
    common::report("e2e/no_fbf/100k_events", med, mean, events.len() as f64);

    // streamed ingestion: same work in bounded chunks, counters-only
    // report — the configuration for unbounded recordings
    for chunk in [4_096usize, 65_536] {
        let mut cfg = PipelineConfig::davis240();
        cfg.lut_refresh_events = usize::MAX;
        cfg.record_per_event = false;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let (med, mean) = common::measure(1, 5, || {
            let r = pipe.run_stream(&mut SliceSource::new(&events, chunk)).unwrap();
            std::hint::black_box(r.events_signal);
        });
        let label = format!("e2e/stream_chunk{chunk}/100k_events");
        common::report(&label, med, mean, events.len() as f64);
    }

    if !default_artifact_dir().join("meta.json").exists() {
        println!("SKIP FBF rows: run `make artifacts` first");
        return;
    }
    for (label, async_mode, refresh) in [
        ("sync/refresh2k", false, 2_000usize),
        ("sync/refresh500", false, 500),
        ("async", true, 2_000),
    ] {
        let mut cfg = PipelineConfig::davis240();
        cfg.async_refresh = async_mode;
        cfg.lut_refresh_events = refresh;
        // construct once: PJRT client + HLO compile are per-process costs,
        // not per-run costs (the coordinator keeps the executable loaded)
        let mut pipe = Pipeline::new(cfg).unwrap();
        let (med, mean) = common::measure(1, 5, || {
            let r = pipe.run(&events).unwrap();
            std::hint::black_box(r.corners.len());
        });
        common::report(&format!("e2e/{label}/100k_events"), med, mean, events.len() as f64);
    }
}
