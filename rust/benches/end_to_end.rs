//! Bench: the full pipeline (STCF + TOS backend + DVFS + detector +
//! tagging) — events/s of the whole system model across every backend x
//! detector combination and two resolutions, plus sync-vs-async LUT
//! refresh and the streamed ingestion path. Emits `BENCH_e2e.json` at the
//! repo root (see DESIGN.md §Hot paths); `--smoke` shrinks the run for CI.
//!
//! The engine-less rows run standalone; the FBF rows need
//! `make artifacts`.

mod common;

use common::Harness;
use nmc_tos::coordinator::{BackendKind, DetectorKind, Pipeline, PipelineConfig, RecordingSink};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::Resolution;
use nmc_tos::runtime::default_artifact_dir;

fn main() {
    let mut h = Harness::new("end_to_end", "BENCH_e2e.json");

    println!("== bench: full pipeline end-to-end ==");
    let mut scene = SceneConfig::shapes_dof().build(8);
    let events = scene.generate(h.events(100_000));

    // engine-less variant isolates the simulator cost from PJRT
    let mut cfg = PipelineConfig::davis240();
    cfg.lut_refresh_events = usize::MAX;
    let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
    h.run("e2e/no_fbf/100k_events", 1, 5, events.len() as f64, || {
        let r = pipe.run(&events).unwrap();
        std::hint::black_box(r.events_signal);
    });

    // streamed ingestion: same work in bounded chunks, counters-only
    // report — the configuration for unbounded recordings
    for chunk in [4_096usize, 65_536] {
        let mut cfg = PipelineConfig::davis240();
        cfg.lut_refresh_events = usize::MAX;
        cfg.record_per_event = false;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        h.run(&format!("e2e/stream_chunk{chunk}/100k_events"), 1, 5, events.len() as f64, || {
            let r = pipe.run_stream(&mut SliceSource::new(&events, chunk)).unwrap();
            std::hint::black_box(r.events_signal);
        });
    }

    // streamed real-format ingestion: the same events encoded as an
    // AEDAT4 container and decoded packet-by-packet on the hot path —
    // against stream_chunk above, this prices the format decoder itself
    {
        let mut aedat = Vec::new();
        nmc_tos::events::codec::aedat4::write_aedat4(&mut aedat, &events, Resolution::DAVIS240)
            .unwrap();
        let mut cfg = PipelineConfig::davis240();
        cfg.lut_refresh_events = usize::MAX;
        cfg.record_per_event = false;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        h.run("e2e/stream_aedat4/100k_events", 1, 5, events.len() as f64, || {
            let mut src =
                nmc_tos::events::codec::aedat4::Aedat4StreamSource::new(&aedat[..]).unwrap();
            let r = pipe.run_stream(&mut src).unwrap();
            std::hint::black_box(r.events_signal);
        });
    }

    // sink-based results path: an external RecordingSink (full per-event
    // recording through the observer API) and a stats-emitting run —
    // both against the counters-only rows above, so the sink dispatch
    // overhead on the hot path stays measured
    {
        let mut cfg = PipelineConfig::davis240();
        cfg.lut_refresh_events = usize::MAX;
        cfg.record_per_event = false;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        h.run("e2e/sink_recording/100k_events", 1, 5, events.len() as f64, || {
            let mut sink = RecordingSink::default();
            let r = pipe
                .run_stream_with(&mut SliceSource::new(&events, 65_536), &mut sink)
                .unwrap();
            std::hint::black_box((r.events_signal, sink.scores.len()));
        });
    }
    {
        let mut cfg = PipelineConfig::davis240();
        cfg.lut_refresh_events = usize::MAX;
        cfg.record_per_event = false;
        cfg.stats_interval_events = Some(1_000);
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        h.run("e2e/sink_stats1k/100k_events", 1, 5, events.len() as f64, || {
            let r = pipe.run_stream(&mut SliceSource::new(&events, 65_536)).unwrap();
            std::hint::black_box(r.events_signal);
        });
    }

    // backend x detector x resolution matrix (engine-less: the harris
    // detector runs with a zero LUT — its per-event tag cost is real,
    // only the FBF refresh is absent)
    println!("\n== bench: backend x detector x resolution (engine-less) ==");
    for (rlabel, res) in [("davis240", Resolution::DAVIS240), ("hd720", Resolution::HD720)] {
        let mut scene_cfg = SceneConfig::shapes_dof();
        scene_cfg.res = res;
        let mut scene = scene_cfg.build(9);
        let events = scene.generate(h.events(50_000));
        for bk in BackendKind::ALL {
            for dk in DetectorKind::ALL {
                let mut cfg = PipelineConfig::davis240();
                cfg.res = res;
                cfg.dvfs = None;
                cfg.backend = bk;
                cfg.detector = dk;
                cfg.shards = 4;
                cfg.record_per_event = false;
                let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
                h.run(
                    &format!("e2e/{rlabel}/{}/{}/50k_events", bk.label(), dk.label()),
                    1,
                    3,
                    events.len() as f64,
                    || {
                        let r = pipe.run(&events).unwrap();
                        std::hint::black_box(r.events_signal);
                    },
                );
            }
        }
    }

    if !default_artifact_dir().join("meta.json").exists() {
        println!("SKIP FBF rows: run `make artifacts` first");
        h.finish();
        return;
    }
    for (label, async_mode, refresh) in [
        ("sync/refresh2k", false, 2_000usize),
        ("sync/refresh500", false, 500),
        ("async", true, 2_000),
    ] {
        let mut cfg = PipelineConfig::davis240();
        cfg.async_refresh = async_mode;
        cfg.lut_refresh_events = refresh;
        // construct once: PJRT client + HLO compile are per-process costs,
        // not per-run costs (the coordinator keeps the executable loaded)
        let mut pipe = Pipeline::new(cfg).unwrap();
        h.run(&format!("e2e/{label}/100k_events"), 1, 5, events.len() as f64, || {
            let r = pipe.run(&events).unwrap();
            std::hint::black_box(r.corners.len());
        });
    }

    h.finish();
}
