//! Spatio-Temporal Correlation Filter (STCF) denoising — paper Sec. III-A,
//! after Guo & Delbruck's low-cost background-activity filter.
//!
//! Background-activity (BA) noise events are temporally/spatially isolated;
//! signal events arrive in correlated clumps.  The filter keeps, per pixel,
//! the timestamp of the most recent event; an incoming event is *signal*
//! iff at least `support` pixels in its `(2r+1)^2` neighbourhood (centre
//! excluded) fired within the trailing window `tw_us`.
//!
//! ## Vectorized support counting
//!
//! The per-neighbour test collapses to one unsigned compare: a pixel's
//! stored value is `s = t + 1` (`0` = never fired), and with
//! `lo = ev.t - tw + 1` (saturating at the bottom), *"fired within the
//! trailing window"* is exactly `s >= lo` — never-fired pixels fail
//! automatically because `lo >= 1`. [`Stcf::check`] therefore counts the
//! whole clipped neighbourhood with branch-free masked-lane compares
//! (AVX2 / NEON `u64` lanes when the TOS kernel dispatcher selected those
//! paths, a branch-free scalar sum otherwise — see
//! [`crate::tos::kernel`]), then subtracts the centre pixel's own
//! contribution instead of branching around it per lane.
//! [`Stcf::check_scalar`] keeps the original early-exit nested loop as the
//! behavioural oracle; `prop_stcf_vectorized_equals_scalar` feeds both the
//! same random streams.
//!
//! Under Miri the AVX2 lane path is compiled out (vendor intrinsics
//! cannot execute there) and [`count_in_window`] always takes the scalar
//! sum, matching the TOS kernel's `cfg(miri)` policy.

// One of the two modules allowed to use `unsafe` (with `tos::kernel`);
// the crate root carries `#![deny(unsafe_code)]` and the nmc-analyze gate
// pins the allowlist. Every block below carries a `// SAFETY:` run.
#![allow(unsafe_code)]

use crate::events::{Event, Resolution};
use crate::tos::kernel::{active_path, KernelPath};

/// STCF parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StcfConfig {
    /// Correlation time window TW_STCF (µs).
    pub tw_us: u64,
    /// Neighbourhood radius (1 => 3x3).
    pub radius: u16,
    /// Supporting neighbours required to classify as signal.
    pub support: u32,
    /// Count both polarities as support (the paper's filter does).
    pub any_polarity: bool,
}

impl Default for StcfConfig {
    fn default() -> Self {
        // Paper example: "if enough supporting events (e.g., 2) are present"
        Self { tw_us: 5_000, radius: 1, support: 2, any_polarity: true }
    }
}

/// Filter telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StcfStats {
    /// Events seen.
    pub seen: u64,
    /// Events passed as signal.
    pub passed: u64,
}

/// The streaming STCF filter.
#[derive(Debug, Clone)]
pub struct Stcf {
    cfg: StcfConfig,
    res: Resolution,
    /// Last event time per pixel, +1 so that 0 means "never fired".
    last_t: Vec<u64>,
    stats: StcfStats,
}

impl Stcf {
    /// Fresh filter for a sensor.
    pub fn new(res: Resolution, cfg: StcfConfig) -> Self {
        Self { cfg, res, last_t: vec![0; res.pixels()], stats: StcfStats::default() }
    }

    /// Classify an event as signal (`true`) or BA noise (`false`), and
    /// record it in the timestamp map either way.
    ///
    /// Vectorized: counts the whole clipped neighbourhood with branch-free
    /// `s >= lo` lane compares and subtracts the centre's own
    /// contribution. Bit-identical to [`Stcf::check_scalar`] (property
    /// tested), including stats and timestamp-map updates.
    pub fn check(&mut self, ev: &Event) -> bool {
        self.stats.seen += 1;
        let support = self.count_support(ev);
        self.last_t[self.res.index(ev.x, ev.y)] = ev.t + 1;
        let signal = support >= self.cfg.support;
        if signal {
            self.stats.passed += 1;
        }
        signal
    }

    /// Branch-free support count over the clipped neighbourhood, centre
    /// excluded.
    #[inline]
    fn count_support(&self, ev: &Event) -> u32 {
        // supports <=> s >= lo (module docs); lo overflows only for
        // ev.t == u64::MAX with tw == 0, where no stored s can qualify
        let lo = match ev.t.saturating_sub(self.cfg.tw_us).checked_add(1) {
            Some(lo) => lo,
            None => return 0,
        };
        let r = self.cfg.radius as i32;
        let (w, h) = (self.res.width as i32, self.res.height as i32);
        let (ex, ey) = (ev.x as i32, ev.y as i32);
        let x0 = (ex - r).max(0) as usize;
        let x1 = (ex + r).min(w - 1) as usize;
        let y0 = (ey - r).max(0) as usize;
        let y1 = (ey + r).min(h - 1) as usize;
        let width = w as usize;
        let path = active_path();
        let mut n = 0u32;
        for y in y0..=y1 {
            let row = &self.last_t[y * width + x0..=y * width + x1];
            n += count_in_window(path, row, lo);
        }
        // the centre was counted with its row; remove its contribution
        // instead of branching on it in every lane
        n - (self.last_t[self.res.index(ev.x, ev.y)] >= lo) as u32
    }

    /// The original early-exit nested-loop classifier, kept as the
    /// behavioural oracle for the vectorized [`Stcf::check`] (same
    /// observable effects: return value, stats, timestamp map).
    pub fn check_scalar(&mut self, ev: &Event) -> bool {
        self.stats.seen += 1;
        let r = self.cfg.radius as i32;
        let (w, h) = (self.res.width as i32, self.res.height as i32);
        let (ex, ey) = (ev.x as i32, ev.y as i32);
        let mut support = 0u32;
        let x0 = (ex - r).max(0);
        let x1 = (ex + r).min(w - 1);
        let y0 = (ey - r).max(0);
        let y1 = (ey + r).min(h - 1);
        'outer: for y in y0..=y1 {
            let row = y as usize * w as usize;
            for x in x0..=x1 {
                if x == ex && y == ey {
                    continue;
                }
                let t = self.last_t[row + x as usize];
                if t != 0 {
                    let t = t - 1;
                    if ev.t.saturating_sub(t) <= self.cfg.tw_us {
                        support += 1;
                        if support >= self.cfg.support {
                            break 'outer;
                        }
                    }
                }
            }
        }
        self.last_t[self.res.index(ev.x, ev.y)] = ev.t + 1;
        let signal = support >= self.cfg.support;
        if signal {
            self.stats.passed += 1;
        }
        signal
    }

    /// Filter a whole stream, returning only the signal events.
    pub fn filter(&mut self, events: &[Event]) -> Vec<Event> {
        events.iter().filter(|e| self.check(e)).copied().collect()
    }

    /// Telemetry.
    pub fn stats(&self) -> StcfStats {
        self.stats
    }

    /// Fraction of seen events classified as signal.
    pub fn pass_rate(&self) -> f64 {
        if self.stats.seen == 0 {
            return 0.0;
        }
        self.stats.passed as f64 / self.stats.seen as f64
    }
}

/// Count the values `s >= lo` in one neighbourhood row, through the lane
/// path the TOS dispatcher selected. SSE2 has no unsigned 64-bit compare,
/// so only the AVX2 and NEON paths vectorize here; every other path takes
/// the branch-free scalar sum (still no per-lane branches — the compare
/// result is accumulated arithmetically).
#[inline]
fn count_in_window(path: KernelPath, row: &[u64], lo: u64) -> u32 {
    match path {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        KernelPath::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: feature presence just checked.
            unsafe { count_in_window_avx2(row, lo) }
        }
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        KernelPath::Neon => count_in_window_neon(row, lo),
        _ => row.iter().map(|&s| (s >= lo) as u32).sum(),
    }
}

/// `[-1, -1, -1, -1, 0, 0, 0, 0]`: loading 4 lanes at offset `4 - rem`
/// yields a maskload mask enabling the first `rem` lanes; disabled lanes
/// read as 0, which never counts because `lo >= 1`.
#[cfg(all(target_arch = "x86_64", not(miri)))]
static TAIL64: [i64; 8] = [-1, -1, -1, -1, 0, 0, 0, 0];

/// Four `u64` lanes per compare; unsigned `>= lo` is done as signed
/// `> (lo - 1)` after flipping the sign bit of both operands (`lo >= 1`
/// always, so `lo - 1` cannot underflow).
///
/// # Safety
/// The CPU must support AVX2.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2")]
unsafe fn count_in_window_avx2(row: &[u64], lo: u64) -> u32 {
    use core::arch::x86_64::*;
    // SAFETY: the caller guarantees AVX2 (this fn's contract); full-lane
    // loads satisfy i + 4 <= row.len(), the tail maskload disables the
    // lanes past the slice (disabled lanes are never dereferenced), and
    // TAIL64 offsets stay within its 8 entries for rem in [1, 3].
    unsafe {
        let sign = _mm256_set1_epi64x(i64::MIN);
        let lov = _mm256_set1_epi64x(((lo - 1) ^ (1u64 << 63)) as i64);
        let mut n = 0u32;
        let mut i = 0;
        while i + 4 <= row.len() {
            let v = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
            let ge = _mm256_cmpgt_epi64(_mm256_xor_si256(v, sign), lov);
            n += (_mm256_movemask_pd(_mm256_castsi256_pd(ge)) as u32).count_ones();
            i += 4;
        }
        if i < row.len() {
            let rem = row.len() - i;
            let mask = _mm256_loadu_si256(TAIL64.as_ptr().add(4 - rem) as *const __m256i);
            let v = _mm256_maskload_epi64(row.as_ptr().add(i) as *const i64, mask);
            let ge = _mm256_cmpgt_epi64(_mm256_xor_si256(v, sign), lov);
            n += (_mm256_movemask_pd(_mm256_castsi256_pd(ge)) as u32).count_ones();
        }
        n
    }
}

/// Two `u64` lanes per compare (`vcgeq_u64` is a native unsigned >=);
/// each all-ones compare result is accumulated by lane subtraction
/// (`acc - (-1) = acc + 1`), with a scalar pickup for the odd tail lane.
#[cfg(all(target_arch = "aarch64", not(miri)))]
#[inline]
fn count_in_window_neon(row: &[u64], lo: u64) -> u32 {
    use core::arch::aarch64::*;
    // SAFETY: NEON is baseline on aarch64; loads are bounded by `row`.
    unsafe {
        let lov = vdupq_n_u64(lo);
        let mut acc = vdupq_n_u64(0);
        let mut i = 0;
        while i + 2 <= row.len() {
            acc = vsubq_u64(acc, vcgeq_u64(vld1q_u64(row.as_ptr().add(i)), lov));
            i += 2;
        }
        let mut n = (vgetq_lane_u64(acc, 0) + vgetq_lane_u64(acc, 1)) as u32;
        if i < row.len() {
            n += (row[i] >= lo) as u32;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filt() -> Stcf {
        Stcf::new(Resolution::TEST64, StcfConfig::default())
    }

    #[test]
    fn isolated_event_is_noise() {
        let mut f = filt();
        assert!(!f.check(&Event::on(30, 30, 1000)));
    }

    #[test]
    fn correlated_cluster_passes() {
        let mut f = filt();
        // two neighbours fire first
        f.check(&Event::on(30, 30, 1000));
        f.check(&Event::on(31, 30, 1010));
        // third event next to both has 2 supporters -> signal
        assert!(f.check(&Event::on(30, 31, 1020)));
    }

    #[test]
    fn support_threshold_enforced() {
        let mut f = filt();
        f.check(&Event::on(30, 30, 1000));
        // only ONE supporter in window -> still noise with support=2
        assert!(!f.check(&Event::on(31, 30, 1010)));
    }

    #[test]
    fn stale_neighbours_do_not_support() {
        let mut f = filt();
        f.check(&Event::on(30, 30, 0));
        f.check(&Event::on(31, 30, 10));
        // window is 5 ms; 10 ms later the neighbours are stale
        assert!(!f.check(&Event::on(30, 31, 10_020)));
    }

    #[test]
    fn border_events_handled() {
        let mut f = filt();
        f.check(&Event::on(0, 0, 0));
        f.check(&Event::on(1, 0, 5));
        assert!(f.check(&Event::on(0, 1, 10)));
    }

    #[test]
    fn pass_rate_tracks_noise_fraction() {
        let mut f = filt();
        // dense cluster at (10,10): mostly passes after warmup
        for i in 0..100u64 {
            f.check(&Event::on(10 + (i % 2) as u16, 10 + ((i / 2) % 2) as u16, i * 10));
        }
        // isolated scatter: all rejected
        for i in 0..100u64 {
            f.check(&Event::on((i * 7 % 60) as u16 , (i * 11 % 60) as u16, 1_000_000 + i * 100_000));
        }
        let s = f.stats();
        assert_eq!(s.seen, 200);
        assert!(s.passed > 80 && s.passed < 120, "passed {}", s.passed);
    }

    #[test]
    fn count_in_window_matches_scalar_on_every_path() {
        // window lengths 0..=9 x values straddling lo x every runnable
        // lane path, including the u64 extremes
        let values = [0u64, 1, 2, 99, 100, 101, 1_000, u64::MAX - 1, u64::MAX];
        for path in crate::tos::kernel::available_paths() {
            for len in 0usize..=9 {
                for salt in 0..values.len() {
                    let row: Vec<u64> =
                        (0..len).map(|i| values[(i + salt) % values.len()]).collect();
                    for lo in [1u64, 100, 101, u64::MAX] {
                        let want: u32 = row.iter().map(|&s| (s >= lo) as u32).sum();
                        assert_eq!(
                            count_in_window(path, &row, lo),
                            want,
                            "{path} len {len} salt {salt} lo {lo}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn vectorized_check_equals_scalar_reference() {
        // identical streams through both classifiers: same verdicts, same
        // stats, same timestamp map — including border pixels and stale
        // neighbourhoods
        // Miri interprets ~400x slower; 300 events still cross the
        // stale-window boundary (700 * 300 > 40_000 wraps several times)
        let n = if cfg!(miri) { 300u64 } else { 4_000 };
        for (radius, support) in [(1u16, 2u32), (2, 3), (1, 1), (3, 2)] {
            let cfg = StcfConfig { radius, support, ..StcfConfig::default() };
            let mut vec = Stcf::new(Resolution::TEST64, cfg);
            let mut scl = Stcf::new(Resolution::TEST64, cfg);
            for i in 0..n {
                let e = Event::on(
                    (i * 23 % 64) as u16,
                    (i * 41 % 64) as u16,
                    i * 700 % 40_000, // non-monotone: exercises future timestamps
                );
                assert_eq!(vec.check(&e), scl.check_scalar(&e), "r{radius} s{support} ev {i}");
            }
            assert_eq!(vec.stats(), scl.stats());
            assert_eq!(vec.last_t, scl.last_t);
        }
    }

    #[test]
    fn filter_batch_matches_check() {
        let evs: Vec<Event> = (0..50).map(|i| Event::on(20, 20 + (i % 3) as u16, i * 100)).collect();
        let mut a = filt();
        let va = a.filter(&evs);
        let mut b = filt();
        let vb: Vec<Event> = evs.iter().filter(|e| b.check(e)).copied().collect();
        assert_eq!(va, vb);
    }
}
