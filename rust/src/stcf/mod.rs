//! Spatio-Temporal Correlation Filter (STCF) denoising — paper Sec. III-A,
//! after Guo & Delbruck's low-cost background-activity filter.
//!
//! Background-activity (BA) noise events are temporally/spatially isolated;
//! signal events arrive in correlated clumps.  The filter keeps, per pixel,
//! the timestamp of the most recent event; an incoming event is *signal*
//! iff at least `support` pixels in its `(2r+1)^2` neighbourhood (centre
//! excluded) fired within the trailing window `tw_us`.



use crate::events::{Event, Resolution};

/// STCF parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StcfConfig {
    /// Correlation time window TW_STCF (µs).
    pub tw_us: u64,
    /// Neighbourhood radius (1 => 3x3).
    pub radius: u16,
    /// Supporting neighbours required to classify as signal.
    pub support: u32,
    /// Count both polarities as support (the paper's filter does).
    pub any_polarity: bool,
}

impl Default for StcfConfig {
    fn default() -> Self {
        // Paper example: "if enough supporting events (e.g., 2) are present"
        Self { tw_us: 5_000, radius: 1, support: 2, any_polarity: true }
    }
}

/// Filter telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StcfStats {
    /// Events seen.
    pub seen: u64,
    /// Events passed as signal.
    pub passed: u64,
}

/// The streaming STCF filter.
#[derive(Debug, Clone)]
pub struct Stcf {
    cfg: StcfConfig,
    res: Resolution,
    /// Last event time per pixel, +1 so that 0 means "never fired".
    last_t: Vec<u64>,
    stats: StcfStats,
}

impl Stcf {
    /// Fresh filter for a sensor.
    pub fn new(res: Resolution, cfg: StcfConfig) -> Self {
        Self { cfg, res, last_t: vec![0; res.pixels()], stats: StcfStats::default() }
    }

    /// Classify an event as signal (`true`) or BA noise (`false`), and
    /// record it in the timestamp map either way.
    pub fn check(&mut self, ev: &Event) -> bool {
        self.stats.seen += 1;
        let r = self.cfg.radius as i32;
        let (w, h) = (self.res.width as i32, self.res.height as i32);
        let (ex, ey) = (ev.x as i32, ev.y as i32);
        let mut support = 0u32;
        let x0 = (ex - r).max(0);
        let x1 = (ex + r).min(w - 1);
        let y0 = (ey - r).max(0);
        let y1 = (ey + r).min(h - 1);
        'outer: for y in y0..=y1 {
            let row = y as usize * w as usize;
            for x in x0..=x1 {
                if x == ex && y == ey {
                    continue;
                }
                let t = self.last_t[row + x as usize];
                if t != 0 {
                    let t = t - 1;
                    if ev.t.saturating_sub(t) <= self.cfg.tw_us {
                        support += 1;
                        if support >= self.cfg.support {
                            break 'outer;
                        }
                    }
                }
            }
        }
        self.last_t[self.res.index(ev.x, ev.y)] = ev.t + 1;
        let signal = support >= self.cfg.support;
        if signal {
            self.stats.passed += 1;
        }
        signal
    }

    /// Filter a whole stream, returning only the signal events.
    pub fn filter(&mut self, events: &[Event]) -> Vec<Event> {
        events.iter().filter(|e| self.check(e)).copied().collect()
    }

    /// Telemetry.
    pub fn stats(&self) -> StcfStats {
        self.stats
    }

    /// Fraction of seen events classified as signal.
    pub fn pass_rate(&self) -> f64 {
        if self.stats.seen == 0 {
            return 0.0;
        }
        self.stats.passed as f64 / self.stats.seen as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filt() -> Stcf {
        Stcf::new(Resolution::TEST64, StcfConfig::default())
    }

    #[test]
    fn isolated_event_is_noise() {
        let mut f = filt();
        assert!(!f.check(&Event::on(30, 30, 1000)));
    }

    #[test]
    fn correlated_cluster_passes() {
        let mut f = filt();
        // two neighbours fire first
        f.check(&Event::on(30, 30, 1000));
        f.check(&Event::on(31, 30, 1010));
        // third event next to both has 2 supporters -> signal
        assert!(f.check(&Event::on(30, 31, 1020)));
    }

    #[test]
    fn support_threshold_enforced() {
        let mut f = filt();
        f.check(&Event::on(30, 30, 1000));
        // only ONE supporter in window -> still noise with support=2
        assert!(!f.check(&Event::on(31, 30, 1010)));
    }

    #[test]
    fn stale_neighbours_do_not_support() {
        let mut f = filt();
        f.check(&Event::on(30, 30, 0));
        f.check(&Event::on(31, 30, 10));
        // window is 5 ms; 10 ms later the neighbours are stale
        assert!(!f.check(&Event::on(30, 31, 10_020)));
    }

    #[test]
    fn border_events_handled() {
        let mut f = filt();
        f.check(&Event::on(0, 0, 0));
        f.check(&Event::on(1, 0, 5));
        assert!(f.check(&Event::on(0, 1, 10)));
    }

    #[test]
    fn pass_rate_tracks_noise_fraction() {
        let mut f = filt();
        // dense cluster at (10,10): mostly passes after warmup
        for i in 0..100u64 {
            f.check(&Event::on(10 + (i % 2) as u16, 10 + ((i / 2) % 2) as u16, i * 10));
        }
        // isolated scatter: all rejected
        for i in 0..100u64 {
            f.check(&Event::on((i * 7 % 60) as u16 , (i * 11 % 60) as u16, 1_000_000 + i * 100_000));
        }
        let s = f.stats();
        assert_eq!(s.seen, 200);
        assert!(s.passed > 80 && s.passed < 120, "passed {}", s.passed);
    }

    #[test]
    fn filter_batch_matches_check() {
        let evs: Vec<Event> = (0..50).map(|i| Event::on(20, 20 + (i % 3) as u16, i * 100)).collect();
        let mut a = filt();
        let va = a.filter(&evs);
        let mut b = filt();
        let vb: Vec<Event> = evs.iter().filter(|e| b.check(e)).copied().collect();
        assert_eq!(va, vb);
    }
}
