//! The [`TosBackend`] abstraction over every TOS implementation the paper
//! compares (Figs. 1, 9, 10): one trait for the golden software model
//! ([`crate::tos::TosSurface`]), the conventional digital datapath
//! ([`crate::conventional::ConventionalTos`]), the NMC macro
//! ([`crate::nmc::NmcMacro`]) and the sharded parallel software model
//! ([`crate::tos::sharded::ShardedTos`]) — plus the single shared
//! Algorithm-1 patch core they all route through, which lives in
//! [`crate::tos::kernel`] behind a startup-selected SIMD dispatch and is
//! re-exported here for compatibility.
//!
//! The coordinator ([`crate::coordinator::Pipeline`]) is generic over
//! `B: TosBackend`, so every experiment harness (PR sweeps, DVFS traces,
//! BER studies) runs identically against any implementation; only the
//! cost/telemetry side differs. Bit-exactness of every backend against the
//! golden model is a property-test invariant (`rust/tests/properties.rs`).

use crate::events::{Event, Resolution};

use super::kernel::KernelPath;
use super::TosConfig;

pub use super::kernel::{decrement_clamp, decrement_clamp_scalar};

/// Unified telemetry every backend accumulates.
///
/// Pure-software backends (golden, sharded) have no hardware cost model:
/// their `busy_ns`/`energy_pj` stay zero and only the functional counters
/// advance. Hardware-model backends (NMC, conventional) fill everything.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BackendStats {
    /// Events processed.
    pub events: u64,
    /// Pixels updated (after border clipping).
    pub pixels: u64,
    /// Modelled busy time (ns); 0 for pure-software backends.
    pub busy_ns: f64,
    /// Modelled dynamic energy (pJ); 0 for pure-software backends.
    pub energy_pj: f64,
    /// Bits corrupted by Monte-Carlo read-error injection (NMC only).
    pub flipped_bits: u64,
    /// The decrement/clamp kernel the dispatcher selected at startup
    /// ([`crate::tos::kernel::active_path`]). Every backend — including
    /// the NMC macro under fault injection, whose fault-aware fast path
    /// rides the same kernel — reports the process-wide selection
    /// (override with `NMC_TOS_KERNEL`).
    pub kernel: KernelPath,
    /// Voltage-fault injection state (`None` = injection off). Only the
    /// NMC macro models read faults; every other backend reports `None`.
    pub faults: Option<FaultInfo>,
}

/// Snapshot of an active voltage-fault injector, surfaced through
/// [`BackendStats::faults`] so experiment harnesses and the serving layer
/// can see the fault mode a run actually executed under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultInfo {
    /// Supply voltage the current fault map was derived for.
    pub vdd: f64,
    /// Seed the static per-cell fault map derives from.
    pub seed: u64,
    /// Per-bit fault probability at `vdd` (0 at and above the paper's
    /// published-zero voltages — see `nmc::calib::BER_MC_FLOOR`).
    pub p_bit: f64,
    /// Cells with at least one faulty bit at `vdd`.
    pub faulty_cells: u64,
    /// Corrupted word reads so far.
    pub flipped_bits: u64,
    /// Total word reads so far.
    pub word_reads: u64,
}

/// A TOS implementation the coordinator can drive.
///
/// Functional contract: `process` applies Algorithm 1 bit-exactly (at
/// nominal voltage / without error injection) — `tos_view` of any two
/// backends fed the same stream must be identical.
///
/// ```
/// use nmc_tos::events::{Event, Resolution};
/// use nmc_tos::tos::{TosBackend, TosConfig, TosSurface};
///
/// let mut tos = TosSurface::new(Resolution::TEST64, TosConfig::default())?;
/// tos.process(&Event::on(10, 10, 0));
/// // Algorithm 1: the event pixel is written to 255
/// assert_eq!(tos.tos_view()[10 * 64 + 10], 255);
/// assert_eq!(tos.stats().events, 1);
/// # Ok::<(), nmc_tos::tos::TosConfigError>(())
/// ```
///
/// Snapshot ownership rules: [`TosBackend::tos_view`] is the zero-copy
/// accessor every hot path uses (the FBF refresh reads it straight into
/// the f32 frame); [`TosBackend::snapshot_into`] fills a caller-owned
/// buffer for handoffs that must outlive the borrow (the async LUT
/// worker's double-buffered scratch); [`TosBackend::snapshot_u8`] is the
/// allocating convenience kept for tests and one-per-run uses
/// (`RunReport::final_tos`) — never call it per event or per boundary.
pub trait TosBackend {
    /// Implementation name for reports.
    fn name(&self) -> &'static str;

    /// Sensor geometry this backend covers.
    fn resolution(&self) -> Resolution;

    /// Apply one event (Algorithm 1 semantics).
    fn process(&mut self, ev: &Event);

    /// Apply a batch of events in stream order. Backends with a faster
    /// batch path (sharding) override this.
    fn process_batch(&mut self, events: &[Event]) {
        for e in events {
            self.process(e);
        }
    }

    /// Does this backend have a real batch fast path? When `false` (the
    /// default) callers should feed events one at a time instead of paying
    /// to buffer them.
    fn prefers_batching(&self) -> bool {
        false
    }

    /// Borrowed view of the surface as an 8-bit row-major image (the FBF
    /// Harris stage input). No allocation, no copy.
    fn tos_view(&self) -> &[u8];

    /// Write the surface into a caller-owned buffer (resized as needed).
    /// Steady-state this never allocates: the buffer reaches frame size
    /// once and is reused.
    fn snapshot_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(self.tos_view());
    }

    /// Owned snapshot — allocating convenience for tests and
    /// once-per-run uses; hot paths use `tos_view` / `snapshot_into`.
    fn snapshot_u8(&self) -> Vec<u8> {
        self.tos_view().to_vec()
    }

    /// Retarget the supply voltage (DVFS transition). Pure-software
    /// backends have no voltage knob and ignore it.
    fn set_vdd(&mut self, _vdd: f64) {}

    /// Cumulative telemetry.
    fn stats(&self) -> BackendStats;

    /// Reset surface and telemetry to the initial state.
    fn reset(&mut self);
}

impl<T: TosBackend + ?Sized> TosBackend for Box<T> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn resolution(&self) -> Resolution {
        (**self).resolution()
    }
    fn process(&mut self, ev: &Event) {
        (**self).process(ev)
    }
    fn process_batch(&mut self, events: &[Event]) {
        (**self).process_batch(events)
    }
    fn prefers_batching(&self) -> bool {
        (**self).prefers_batching()
    }
    fn tos_view(&self) -> &[u8] {
        (**self).tos_view()
    }
    fn snapshot_into(&self, out: &mut Vec<u8>) {
        (**self).snapshot_into(out)
    }
    fn snapshot_u8(&self) -> Vec<u8> {
        (**self).snapshot_u8()
    }
    fn set_vdd(&mut self, vdd: f64) {
        (**self).set_vdd(vdd)
    }
    fn stats(&self) -> BackendStats {
        (**self).stats()
    }
    fn reset(&mut self) {
        (**self).reset()
    }
}

/// A patch rectangle after clipping at the sensor borders (inclusive
/// coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatchRect {
    /// Leftmost column.
    pub x0: u16,
    /// Rightmost column (inclusive).
    pub x1: u16,
    /// Topmost row.
    pub y0: u16,
    /// Bottommost row (inclusive).
    pub y1: u16,
}

impl PatchRect {
    /// Columns covered.
    #[inline]
    pub fn width(&self) -> usize {
        (self.x1 - self.x0 + 1) as usize
    }

    /// Rows covered.
    #[inline]
    pub fn height(&self) -> usize {
        (self.y1 - self.y0 + 1) as usize
    }

    /// Pixels covered.
    #[inline]
    pub fn pixels(&self) -> usize {
        self.width() * self.height()
    }
}

/// Clip the `P x P` patch around `(x, y)` at the sensor borders.
#[inline]
pub fn clip_patch(res: Resolution, x: u16, y: u16, half: i32) -> PatchRect {
    PatchRect {
        x0: (x as i32 - half).max(0) as u16,
        x1: (x as i32 + half).min(res.width as i32 - 1) as u16,
        y0: (y as i32 - half).max(0) as u16,
        y1: (y as i32 + half).min(res.height as i32 - 1) as u16,
    }
}

/// One full golden event update on a whole surface: decrement/clamp the
/// clipped patch, then write 255 at the event pixel. Returns the pixel
/// count of the clipped patch.
#[inline]
pub fn golden_update(data: &mut [u8], res: Resolution, cfg: TosConfig, ev: &Event) -> usize {
    let rect = clip_patch(res, ev.x, ev.y, cfg.half());
    decrement_clamp(data, res.width as usize, 0, rect, cfg.threshold);
    data[res.index(ev.x, ev.y)] = 255;
    rect.pixels()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_patch_interior_and_borders() {
        let res = Resolution::TEST64;
        let full = clip_patch(res, 32, 32, 3);
        assert_eq!((full.width(), full.height(), full.pixels()), (7, 7, 49));
        let corner = clip_patch(res, 0, 0, 3);
        assert_eq!((corner.x0, corner.x1, corner.y0, corner.y1), (0, 3, 0, 3));
        assert_eq!(corner.pixels(), 16);
        let far = clip_patch(res, 63, 63, 3);
        assert_eq!((far.x0, far.x1, far.y0, far.y1), (60, 63, 60, 63));
    }

    #[test]
    fn decrement_clamp_respects_row_window() {
        // a 4-wide, 3-row buffer representing sensor rows 10..13
        let mut data = vec![255u8; 12];
        let rect = PatchRect { x0: 1, x1: 2, y0: 11, y1: 11 };
        decrement_clamp(&mut data, 4, 10, rect, 225);
        assert_eq!(data[4], 255); // row 11, col 0 untouched
        assert_eq!(data[5], 254);
        assert_eq!(data[6], 254);
        assert_eq!(data[7], 255);
        assert!(data[..4].iter().all(|&v| v == 255));
        assert!(data[8..].iter().all(|&v| v == 255));
    }

    #[test]
    fn decrement_clamp_kills_below_threshold() {
        let mut data = vec![225u8; 4];
        let rect = PatchRect { x0: 0, x1: 3, y0: 0, y1: 0 };
        decrement_clamp(&mut data, 4, 0, rect, 225);
        assert!(data.iter().all(|&v| v == 0), "224 < TH must clamp to 0");
    }

    #[test]
    fn golden_update_matches_surface_semantics() {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut data = vec![0u8; res.pixels()];
        let px = golden_update(&mut data, res, cfg, &Event::on(10, 12, 0));
        assert_eq!(px, 49);
        assert_eq!(data[res.index(10, 12)], 255);
        let px = golden_update(&mut data, res, cfg, &Event::on(0, 0, 1));
        assert_eq!(px, 16);
        assert_eq!(data[0], 255);
    }

    #[test]
    fn boxed_backend_dispatches() {
        let surf = super::super::TosSurface::new(Resolution::TEST64, TosConfig::default()).unwrap();
        let mut b: Box<dyn TosBackend> = Box::new(surf);
        b.process(&Event::on(5, 5, 0));
        assert_eq!(b.stats().events, 1);
        assert_eq!(b.snapshot_u8()[Resolution::TEST64.index(5, 5)], 255);
        b.reset();
        assert_eq!(b.stats().events, 0);
        assert_eq!(b.name(), "golden-tos");
    }
}
