//! The [`TosBackend`] abstraction over every TOS implementation the paper
//! compares (Figs. 1, 9, 10): one trait for the golden software model
//! ([`crate::tos::TosSurface`]), the conventional digital datapath
//! ([`crate::conventional::ConventionalTos`]), the NMC macro
//! ([`crate::nmc::NmcMacro`]) and the sharded parallel software model
//! ([`crate::tos::sharded::ShardedTos`]) — plus the single shared
//! Algorithm-1 patch core they all route through.
//!
//! The coordinator ([`crate::coordinator::Pipeline`]) is generic over
//! `B: TosBackend`, so every experiment harness (PR sweeps, DVFS traces,
//! BER studies) runs identically against any implementation; only the
//! cost/telemetry side differs. Bit-exactness of every backend against the
//! golden model is a property-test invariant (`rust/tests/properties.rs`).

use crate::events::{Event, Resolution};

use super::TosConfig;

/// Unified telemetry every backend accumulates.
///
/// Pure-software backends (golden, sharded) have no hardware cost model:
/// their `busy_ns`/`energy_pj` stay zero and only the functional counters
/// advance. Hardware-model backends (NMC, conventional) fill everything.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BackendStats {
    /// Events processed.
    pub events: u64,
    /// Pixels updated (after border clipping).
    pub pixels: u64,
    /// Modelled busy time (ns); 0 for pure-software backends.
    pub busy_ns: f64,
    /// Modelled dynamic energy (pJ); 0 for pure-software backends.
    pub energy_pj: f64,
    /// Bits corrupted by Monte-Carlo read-error injection (NMC only).
    pub flipped_bits: u64,
}

/// A TOS implementation the coordinator can drive.
///
/// Functional contract: `process` applies Algorithm 1 bit-exactly (at
/// nominal voltage / without error injection) — `tos_view` of any two
/// backends fed the same stream must be identical.
///
/// ```
/// use nmc_tos::events::{Event, Resolution};
/// use nmc_tos::tos::{TosBackend, TosConfig, TosSurface};
///
/// let mut tos = TosSurface::new(Resolution::TEST64, TosConfig::default())?;
/// tos.process(&Event::on(10, 10, 0));
/// // Algorithm 1: the event pixel is written to 255
/// assert_eq!(tos.tos_view()[10 * 64 + 10], 255);
/// assert_eq!(tos.stats().events, 1);
/// # Ok::<(), nmc_tos::tos::TosConfigError>(())
/// ```
///
/// Snapshot ownership rules: [`TosBackend::tos_view`] is the zero-copy
/// accessor every hot path uses (the FBF refresh reads it straight into
/// the f32 frame); [`TosBackend::snapshot_into`] fills a caller-owned
/// buffer for handoffs that must outlive the borrow (the async LUT
/// worker's double-buffered scratch); [`TosBackend::snapshot_u8`] is the
/// allocating convenience kept for tests and one-per-run uses
/// (`RunReport::final_tos`) — never call it per event or per boundary.
pub trait TosBackend {
    /// Implementation name for reports.
    fn name(&self) -> &'static str;

    /// Sensor geometry this backend covers.
    fn resolution(&self) -> Resolution;

    /// Apply one event (Algorithm 1 semantics).
    fn process(&mut self, ev: &Event);

    /// Apply a batch of events in stream order. Backends with a faster
    /// batch path (sharding) override this.
    fn process_batch(&mut self, events: &[Event]) {
        for e in events {
            self.process(e);
        }
    }

    /// Does this backend have a real batch fast path? When `false` (the
    /// default) callers should feed events one at a time instead of paying
    /// to buffer them.
    fn prefers_batching(&self) -> bool {
        false
    }

    /// Borrowed view of the surface as an 8-bit row-major image (the FBF
    /// Harris stage input). No allocation, no copy.
    fn tos_view(&self) -> &[u8];

    /// Write the surface into a caller-owned buffer (resized as needed).
    /// Steady-state this never allocates: the buffer reaches frame size
    /// once and is reused.
    fn snapshot_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(self.tos_view());
    }

    /// Owned snapshot — allocating convenience for tests and
    /// once-per-run uses; hot paths use `tos_view` / `snapshot_into`.
    fn snapshot_u8(&self) -> Vec<u8> {
        self.tos_view().to_vec()
    }

    /// Retarget the supply voltage (DVFS transition). Pure-software
    /// backends have no voltage knob and ignore it.
    fn set_vdd(&mut self, _vdd: f64) {}

    /// Cumulative telemetry.
    fn stats(&self) -> BackendStats;

    /// Reset surface and telemetry to the initial state.
    fn reset(&mut self);
}

impl<T: TosBackend + ?Sized> TosBackend for Box<T> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn resolution(&self) -> Resolution {
        (**self).resolution()
    }
    fn process(&mut self, ev: &Event) {
        (**self).process(ev)
    }
    fn process_batch(&mut self, events: &[Event]) {
        (**self).process_batch(events)
    }
    fn prefers_batching(&self) -> bool {
        (**self).prefers_batching()
    }
    fn tos_view(&self) -> &[u8] {
        (**self).tos_view()
    }
    fn snapshot_into(&self, out: &mut Vec<u8>) {
        (**self).snapshot_into(out)
    }
    fn snapshot_u8(&self) -> Vec<u8> {
        (**self).snapshot_u8()
    }
    fn set_vdd(&mut self, vdd: f64) {
        (**self).set_vdd(vdd)
    }
    fn stats(&self) -> BackendStats {
        (**self).stats()
    }
    fn reset(&mut self) {
        (**self).reset()
    }
}

/// A patch rectangle after clipping at the sensor borders (inclusive
/// coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatchRect {
    /// Leftmost column.
    pub x0: u16,
    /// Rightmost column (inclusive).
    pub x1: u16,
    /// Topmost row.
    pub y0: u16,
    /// Bottommost row (inclusive).
    pub y1: u16,
}

impl PatchRect {
    /// Columns covered.
    #[inline]
    pub fn width(&self) -> usize {
        (self.x1 - self.x0 + 1) as usize
    }

    /// Rows covered.
    #[inline]
    pub fn height(&self) -> usize {
        (self.y1 - self.y0 + 1) as usize
    }

    /// Pixels covered.
    #[inline]
    pub fn pixels(&self) -> usize {
        self.width() * self.height()
    }
}

/// Clip the `P x P` patch around `(x, y)` at the sensor borders.
#[inline]
pub fn clip_patch(res: Resolution, x: u16, y: u16, half: i32) -> PatchRect {
    PatchRect {
        x0: (x as i32 - half).max(0) as u16,
        x1: (x as i32 + half).min(res.width as i32 - 1) as u16,
        y0: (y as i32 - half).max(0) as u16,
        y1: (y as i32 + half).min(res.height as i32 - 1) as u16,
    }
}

/// High bits of each byte lane (SWAR).
const H64: u64 = 0x8080_8080_8080_8080;
/// Low bits of each byte lane (SWAR); also the per-byte decrement operand.
const L64: u64 = 0x0101_0101_0101_0101;

/// Per-byte wrapping subtraction with no cross-byte borrow
/// (Hacker's Delight §2-18).
#[inline(always)]
fn packed_sub(x: u64, y: u64) -> u64 {
    ((x | H64).wrapping_sub(y & !H64)) ^ ((x ^ !y) & H64)
}

/// Eight pixels of Algorithm 1's decrement/clamp in one u64: per byte,
/// `saturating_sub(v, 1)` followed by the `< TH -> 0` clamp collapses to
/// `(v > TH) ? v - 1 : 0` (a zero byte can never exceed `TH`, and any
/// byte above `TH` is nonzero, so the saturation never fires separately).
/// `t` is the threshold broadcast to all lanes (`th * L64`).
///
/// The lane math: `borrow` marks the bytes where `t - v` underflows, i.e.
/// where `v > TH`; those lanes keep their decremented value, the rest
/// clamp to zero. Equivalence with the scalar loop is enforced
/// exhaustively over all `(v, TH)` pairs by `swar_word_matches_scalar`
/// and on random windows by `prop_vector_kernel_equals_scalar`.
#[inline(always)]
fn swar_dec_clamp(x: u64, t: u64) -> u64 {
    let z = packed_sub(t, x);
    let borrow = ((!t & x) | (!(t ^ x) & z)) & H64;
    let keep = (borrow >> 7).wrapping_mul(0xFF);
    packed_sub(x, L64) & keep
}

/// Scalar reference form of the decrement/clamp core. This is the exact
/// pre-vectorization hot loop; it stays as the bit-exactness oracle the
/// SWAR kernel is property-tested against, and as the fallback for row
/// windows too close to the end of a band slice for a full 8-byte load.
#[inline]
pub fn decrement_clamp_scalar(
    data: &mut [u8],
    width: usize,
    base_row: u16,
    rect: PatchRect,
    th: u8,
) {
    for y in rect.y0..=rect.y1 {
        let row = (y - base_row) as usize * width;
        scalar_row(&mut data[row + rect.x0 as usize..=row + rect.x1 as usize], th);
    }
}

/// Scalar decrement/clamp of one row window.
#[inline(always)]
fn scalar_row(row: &mut [u8], th: u8) {
    for v in row {
        let d = v.saturating_sub(1);
        *v = if d < th { 0 } else { d };
    }
}

/// SWAR decrement/clamp of one row window of at least 8 pixels: full
/// 8-byte lanes, then one overlapped window over the last 8 bytes whose
/// already-processed low lanes are blended back unchanged (the op is not
/// idempotent, so overlap must not re-apply).
#[inline]
fn swar_row_wide(row: &mut [u8], t: u64) {
    let w = row.len();
    let mut i = 0;
    while i + 8 <= w {
        let win: &mut [u8; 8] = (&mut row[i..i + 8]).try_into().unwrap();
        *win = swar_dec_clamp(u64::from_le_bytes(*win), t).to_le_bytes();
        i += 8;
    }
    if i < w {
        let off = w - 8;
        let done = i - off; // low bytes already processed: 1..=7
        let win: &mut [u8; 8] = (&mut row[off..off + 8]).try_into().unwrap();
        let x = u64::from_le_bytes(*win);
        let keep = (1u64 << (done * 8)) - 1;
        *win = ((swar_dec_clamp(x, t) & !keep) | (x & keep)).to_le_bytes();
    }
}

/// The shared Algorithm-1 decrement/clamp core over `rect`, restricted to
/// a row window: `data` holds consecutive rows starting at sensor row
/// `base_row` (`base_row = 0` for a full surface; a shard passes its
/// band's first row). `rect` must already be clipped to the rows `data`
/// holds. This is the one copy of the hot loop every software backend and
/// the conventional baseline share.
///
/// Vectorized: each row window runs in 8-pixel SWAR lanes
/// ([`swar_dec_clamp`]). Rows narrower than 8 pixels (the common 7-wide
/// patch) use a single 8-byte window whose out-of-rect bytes are blended
/// back unchanged — the window never extends past `data`, so a sharded
/// band can never touch another band's rows, and the rare narrow row at
/// the very end of `data` falls back to the scalar loop. Bit-exactness
/// against [`decrement_clamp_scalar`] is a test invariant.
#[inline]
pub fn decrement_clamp(data: &mut [u8], width: usize, base_row: u16, rect: PatchRect, th: u8) {
    let w = rect.width();
    let t = (th as u64).wrapping_mul(L64);
    for y in rect.y0..=rect.y1 {
        let start = (y - base_row) as usize * width + rect.x0 as usize;
        if w >= 8 {
            swar_row_wide(&mut data[start..start + w], t);
        } else if start + 8 <= data.len() {
            let win: &mut [u8; 8] = (&mut data[start..start + 8]).try_into().unwrap();
            let x = u64::from_le_bytes(*win);
            let keep = !0u64 << (w * 8); // bytes beyond the rect: unchanged
            *win = ((swar_dec_clamp(x, t) & !keep) | (x & keep)).to_le_bytes();
        } else {
            scalar_row(&mut data[start..start + w], th);
        }
    }
}

/// One full golden event update on a whole surface: decrement/clamp the
/// clipped patch, then write 255 at the event pixel. Returns the pixel
/// count of the clipped patch.
#[inline]
pub fn golden_update(data: &mut [u8], res: Resolution, cfg: TosConfig, ev: &Event) -> usize {
    let rect = clip_patch(res, ev.x, ev.y, cfg.half());
    decrement_clamp(data, res.width as usize, 0, rect, cfg.threshold);
    data[res.index(ev.x, ev.y)] = 255;
    rect.pixels()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_patch_interior_and_borders() {
        let res = Resolution::TEST64;
        let full = clip_patch(res, 32, 32, 3);
        assert_eq!((full.width(), full.height(), full.pixels()), (7, 7, 49));
        let corner = clip_patch(res, 0, 0, 3);
        assert_eq!((corner.x0, corner.x1, corner.y0, corner.y1), (0, 3, 0, 3));
        assert_eq!(corner.pixels(), 16);
        let far = clip_patch(res, 63, 63, 3);
        assert_eq!((far.x0, far.x1, far.y0, far.y1), (60, 63, 60, 63));
    }

    #[test]
    fn decrement_clamp_respects_row_window() {
        // a 4-wide, 3-row buffer representing sensor rows 10..13
        let mut data = vec![255u8; 12];
        let rect = PatchRect { x0: 1, x1: 2, y0: 11, y1: 11 };
        decrement_clamp(&mut data, 4, 10, rect, 225);
        assert_eq!(data[4], 255); // row 11, col 0 untouched
        assert_eq!(data[5], 254);
        assert_eq!(data[6], 254);
        assert_eq!(data[7], 255);
        assert!(data[..4].iter().all(|&v| v == 255));
        assert!(data[8..].iter().all(|&v| v == 255));
    }

    #[test]
    fn decrement_clamp_kills_below_threshold() {
        let mut data = vec![225u8; 4];
        let rect = PatchRect { x0: 0, x1: 3, y0: 0, y1: 0 };
        decrement_clamp(&mut data, 4, 0, rect, 225);
        assert!(data.iter().all(|&v| v == 0), "224 < TH must clamp to 0");
    }

    #[test]
    fn swar_word_matches_scalar_exhaustively() {
        // every (pixel value, threshold) pair through the 8-lane word,
        // with a different neighbour value in every other lane to catch
        // cross-byte borrow/carry contamination
        for th in 0u16..=255 {
            let t = (th as u64).wrapping_mul(super::L64);
            for base in (0u16..=255).step_by(8) {
                let lanes: [u8; 8] = std::array::from_fn(|i| (base as usize + i) as u8);
                let out = super::swar_dec_clamp(u64::from_le_bytes(lanes), t).to_le_bytes();
                for (i, &v) in lanes.iter().enumerate() {
                    let d = v.saturating_sub(1);
                    let want = if d < th as u8 { 0 } else { d };
                    assert_eq!(out[i], want, "lane {i} v {v} th {th}");
                }
            }
        }
    }

    #[test]
    fn vector_kernel_equals_scalar_all_alignments_widths_borders() {
        // all row widths x rect alignments x rect widths x threshold
        // classes, at every vertical position of a 3-row buffer (the last
        // row exercises the end-of-slice scalar fallback) plus the full
        // 3-row rect
        let thresholds = [0u8, 1, 2, 127, 128, 224, 225, 226, 254, 255];
        for width in 1usize..=24 {
            let data: Vec<u8> = (0..width * 3).map(|i| (i * 37 + 3) as u8).collect();
            for x0 in 0..width {
                for x1 in x0..width {
                    for (y0, y1) in [(0u16, 0u16), (1, 1), (2, 2), (0, 2)] {
                        let rect = PatchRect { x0: x0 as u16, x1: x1 as u16, y0, y1 };
                        for &th in &thresholds {
                            let mut a = data.clone();
                            let mut b = data.clone();
                            decrement_clamp(&mut a, width, 0, rect, th);
                            decrement_clamp_scalar(&mut b, width, 0, rect, th);
                            assert_eq!(a, b, "width {width} rect {rect:?} th {th} diverged");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn vector_kernel_respects_base_row_offset() {
        // a band slice starting at sensor row 100: both kernels must
        // address rows relative to the base
        let width = 13usize;
        let data: Vec<u8> = (0..width * 5).map(|i| (i * 29 + 1) as u8).collect();
        let rect = PatchRect { x0: 2, x1: 11, y0: 101, y1: 103 };
        let mut a = data.clone();
        let mut b = data;
        decrement_clamp(&mut a, width, 100, rect, 225);
        decrement_clamp_scalar(&mut b, width, 100, rect, 225);
        assert_eq!(a, b);
    }

    #[test]
    fn golden_update_matches_surface_semantics() {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut data = vec![0u8; res.pixels()];
        let px = golden_update(&mut data, res, cfg, &Event::on(10, 12, 0));
        assert_eq!(px, 49);
        assert_eq!(data[res.index(10, 12)], 255);
        let px = golden_update(&mut data, res, cfg, &Event::on(0, 0, 1));
        assert_eq!(px, 16);
        assert_eq!(data[0], 255);
    }

    #[test]
    fn boxed_backend_dispatches() {
        let surf = super::super::TosSurface::new(Resolution::TEST64, TosConfig::default()).unwrap();
        let mut b: Box<dyn TosBackend> = Box::new(surf);
        b.process(&Event::on(5, 5, 0));
        assert_eq!(b.stats().events, 1);
        assert_eq!(b.snapshot_u8()[Resolution::TEST64.index(5, 5)], 255);
        b.reset();
        assert_eq!(b.stats().events, 0);
        assert_eq!(b.name(), "golden-tos");
    }
}
