//! Explicit-SIMD dispatch layer for the Algorithm-1 decrement/clamp core.
//!
//! PR 3 vectorized the shared patch kernel as a SWAR `u64` word (8 pixels
//! per lane word). This module goes wider: 16-byte SSE2 / NEON and 32-byte
//! AVX2 implementations of the same per-byte operation
//! `(v > TH) ? v - 1 : 0`, behind a [`KernelPath`] selected **once at
//! startup** ([`active_path`]) and reported by every backend in
//! [`BackendStats::kernel`](super::backend::BackendStats::kernel).
//!
//! Dispatch contract (see DESIGN.md §Hot paths & memory traffic):
//!
//! * **Selection.** x86_64 picks AVX2 when the CPU reports it at runtime
//!   (`std::arch::is_x86_feature_detected!`), else SSE2 (baseline for the
//!   architecture, no detection needed); aarch64 picks NEON (baseline);
//!   everything else falls back to the SWAR word kernel. The
//!   `NMC_TOS_KERNEL` environment variable (`scalar`/`swar`/`sse2`/
//!   `avx2`/`neon`/`auto`) overrides selection for benchmarking and
//!   debugging; a path the host cannot run falls back to auto-detection.
//! * **Row-window rule.** A vector path never loads or stores outside the
//!   `data` slice it is handed. Rows at least one vector wide run full
//!   lanes plus one *overlapped* tail window whose already-processed low
//!   lanes are masked back unchanged (the op is not idempotent — overlap
//!   must never re-apply). Narrow rows and end-of-slice tails *slide the
//!   window backward* (`wstart = min(start, len - LANES)`) instead of
//!   falling back to scalar, so interior rows of a sharded band slice or a
//!   patch rect never pay the scalar loop; only a whole buffer narrower
//!   than one vector degrades, first to SWAR (8-byte windows), then to the
//!   scalar loop.
//! * **Oracle.** [`decrement_clamp_scalar`] is the bit-exactness oracle:
//!   every path is checked against it by the exhaustive
//!   alignment × width × threshold sweep below, the per-path sweep in
//!   `rust/tests/kernel_dispatch.rs`, and
//!   `prop_vector_kernel_equals_scalar` in `rust/tests/properties.rs`.
//!
//! Masked blends use [`lane mask tables`](self) built in const context, so
//! tail handling is branch-free (two unaligned mask loads + AND).
//!
//! **Miri.** Miri cannot execute vendor SIMD intrinsics, so under
//! `cfg(miri)` the SSE2/AVX2/NEON paths are compiled out entirely:
//! detection and `runnable()` stop at [`KernelPath::Swar64`] and the
//! dispatch arms fall through to SWAR. The SWAR kernel is plain integer
//! code, so the whole dispatch layer stays Miri-checkable; the vector
//! paths get their memory-safety coverage from the ASan CI lane instead
//! (see DESIGN.md §Correctness tooling).

// This module and `stcf` are the only places in the crate allowed to use
// `unsafe` (the crate root carries `#![deny(unsafe_code)]`, and
// the nmc-analyze `unsafe-allowlist` rule pins the allowlist); every block below carries a
// `// SAFETY:` justification, enforced by the same gate.
#![allow(unsafe_code)]

use std::sync::OnceLock;

use super::backend::PatchRect;

/// Which decrement/clamp implementation the startup dispatcher selected.
///
/// Reported by every backend in
/// [`BackendStats::kernel`](super::backend::BackendStats::kernel); the
/// NMC macro reports [`KernelPath::Scalar`] while Monte-Carlo error
/// injection forces its gate-level per-pixel walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Per-byte scalar loop — the bit-exactness oracle and last-resort
    /// fallback for buffers narrower than one SWAR word.
    #[default]
    Scalar,
    /// 8 pixels per `u64` word (Hacker's-Delight packed arithmetic, PR 3).
    Swar64,
    /// 16 pixels per `__m128i` (x86_64 baseline — always available there).
    Sse2,
    /// 32 pixels per `__m256i` (runtime-detected).
    Avx2,
    /// 16 pixels per `uint8x16_t` (aarch64 baseline).
    Neon,
}

impl KernelPath {
    /// Stable lowercase name (bench row labels, `BENCH_*.json`, CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Swar64 => "swar64",
            KernelPath::Sse2 => "sse2",
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
        }
    }

    /// Pixels processed per lane word / vector register.
    pub fn lanes(&self) -> usize {
        match self {
            KernelPath::Scalar => 1,
            KernelPath::Swar64 => 8,
            KernelPath::Sse2 | KernelPath::Neon => 16,
            KernelPath::Avx2 => 32,
        }
    }

    /// Parse a `NMC_TOS_KERNEL` / CLI spelling. `auto` (and anything
    /// unrecognised) yields `None`, which callers treat as "detect".
    pub fn parse(s: &str) -> Option<KernelPath> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelPath::Scalar),
            "swar" | "swar64" => Some(KernelPath::Swar64),
            "sse2" => Some(KernelPath::Sse2),
            "avx2" => Some(KernelPath::Avx2),
            "neon" => Some(KernelPath::Neon),
            _ => None,
        }
    }

    /// Can this host actually execute the path? (Under Miri only the
    /// integer paths are runnable — see the module docs.)
    pub fn runnable(&self) -> bool {
        match self {
            KernelPath::Scalar | KernelPath::Swar64 => true,
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            KernelPath::Sse2 => true,
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            KernelPath::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            KernelPath::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl std::fmt::Display for KernelPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Every path the current host can run, widest last (bench sweeps iterate
/// this so `BENCH_tos.json` records one row per dispatchable path).
pub fn available_paths() -> Vec<KernelPath> {
    [
        KernelPath::Scalar,
        KernelPath::Swar64,
        KernelPath::Sse2,
        KernelPath::Avx2,
        KernelPath::Neon,
    ]
    .into_iter()
    .filter(KernelPath::runnable)
    .collect()
}

/// Pick the widest path the host supports (SWAR under Miri — vendor
/// intrinsics cannot execute there).
fn detect() -> KernelPath {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            KernelPath::Avx2
        } else {
            KernelPath::Sse2
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        KernelPath::Neon
    }
    #[cfg(any(miri, not(any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        KernelPath::Swar64
    }
}

static ACTIVE: OnceLock<KernelPath> = OnceLock::new();

/// The path the dispatcher selected at startup: auto-detection, overridden
/// by `NMC_TOS_KERNEL` when set to a path this host can run. Computed once
/// and cached for the process lifetime — per-call dispatch is one
/// predictable load + match.
pub fn active_path() -> KernelPath {
    // Kani models neither environment reads nor feature detection; its
    // harnesses pin the portable SWAR path (and drive the others through
    // `decrement_clamp_with` explicitly).
    #[cfg(kani)]
    {
        KernelPath::Swar64
    }
    #[cfg(not(kani))]
    {
        *ACTIVE.get_or_init(|| match std::env::var("NMC_TOS_KERNEL") {
            Ok(v) => KernelPath::parse(&v).filter(KernelPath::runnable).unwrap_or_else(detect),
            Err(_) => detect(),
        })
    }
}

/// The shared Algorithm-1 decrement/clamp core over `rect`, restricted to
/// a row window: `data` holds consecutive rows starting at sensor row
/// `base_row` (`base_row = 0` for a full surface; a shard passes its
/// band's first row). `rect` must already be clipped to the rows `data`
/// holds. This is the one copy of the hot loop every software backend,
/// the conventional baseline and the NMC macro's error-free fast path
/// share; it dispatches to the [`active_path`] kernel.
#[inline]
pub fn decrement_clamp(data: &mut [u8], width: usize, base_row: u16, rect: PatchRect, th: u8) {
    decrement_clamp_with(active_path(), data, width, base_row, rect, th)
}

/// [`decrement_clamp`] through an explicit path (bench sweeps and the
/// per-path equivalence tests). A path the host cannot run (or a buffer
/// narrower than the path's vector) degrades to the next-narrower kernel;
/// the functional result is identical on every path by construction.
#[inline]
pub fn decrement_clamp_with(
    path: KernelPath,
    data: &mut [u8],
    width: usize,
    base_row: u16,
    rect: PatchRect,
    th: u8,
) {
    match path {
        KernelPath::Scalar => decrement_clamp_scalar(data, width, base_row, rect, th),
        KernelPath::Swar64 => decrement_clamp_swar(data, width, base_row, rect, th),
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        KernelPath::Sse2 => x86::decrement_clamp_sse2(data, width, base_row, rect, th),
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        KernelPath::Avx2 => {
            if std::arch::is_x86_feature_detected!("avx2") {
                // SAFETY: feature presence just checked.
                unsafe { x86::decrement_clamp_avx2(data, width, base_row, rect, th) }
            } else {
                x86::decrement_clamp_sse2(data, width, base_row, rect, th)
            }
        }
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        KernelPath::Neon => arm::decrement_clamp_neon(data, width, base_row, rect, th),
        // a path this build has no code for (foreign arch, or a vector
        // path under Miri): SWAR is always safe
        #[allow(unreachable_patterns)]
        _ => decrement_clamp_swar(data, width, base_row, rect, th),
    }
}

// ---------------------------------------------------------------------------
// scalar oracle
// ---------------------------------------------------------------------------

/// Scalar reference form of the decrement/clamp core. This is the exact
/// pre-vectorization hot loop; it stays as the bit-exactness oracle every
/// vector kernel is tested against, and as the fallback for buffers too
/// small for even one 8-byte SWAR window.
#[inline]
pub fn decrement_clamp_scalar(
    data: &mut [u8],
    width: usize,
    base_row: u16,
    rect: PatchRect,
    th: u8,
) {
    for y in rect.y0..=rect.y1 {
        let row = (y - base_row) as usize * width;
        scalar_row(&mut data[row + rect.x0 as usize..=row + rect.x1 as usize], th);
    }
}

/// Scalar decrement/clamp of one row window.
#[inline(always)]
fn scalar_row(row: &mut [u8], th: u8) {
    for v in row {
        let d = v.saturating_sub(1);
        *v = if d < th { 0 } else { d };
    }
}

// ---------------------------------------------------------------------------
// SWAR u64 (8 lanes) — PR 3's kernel, kept as the portable vector floor
// ---------------------------------------------------------------------------

/// High bits of each byte lane (SWAR).
const H64: u64 = 0x8080_8080_8080_8080;
/// Low bits of each byte lane (SWAR); also the per-byte decrement operand.
const L64: u64 = 0x0101_0101_0101_0101;

/// Per-byte wrapping subtraction with no cross-byte borrow
/// (Hacker's Delight §2-18).
#[inline(always)]
fn packed_sub(x: u64, y: u64) -> u64 {
    ((x | H64).wrapping_sub(y & !H64)) ^ ((x ^ !y) & H64)
}

/// Eight pixels of Algorithm 1's decrement/clamp in one u64: per byte,
/// `saturating_sub(v, 1)` followed by the `< TH -> 0` clamp collapses to
/// `(v > TH) ? v - 1 : 0` (a zero byte can never exceed `TH`, and any
/// byte above `TH` is nonzero, so the saturation never fires separately).
/// `t` is the threshold broadcast to all lanes (`th * L64`).
///
/// The lane math: `borrow` marks the bytes where `t - v` underflows, i.e.
/// where `v > TH`; those lanes keep their decremented value, the rest
/// clamp to zero.
#[inline(always)]
fn swar_dec_clamp(x: u64, t: u64) -> u64 {
    let z = packed_sub(t, x);
    let borrow = ((!t & x) | (!(t ^ x) & z)) & H64;
    let keep = (borrow >> 7).wrapping_mul(0xFF);
    packed_sub(x, L64) & keep
}

/// SWAR decrement/clamp of one row window of at least 8 pixels: full
/// 8-byte lanes, then one overlapped window over the last 8 bytes whose
/// already-processed low lanes are blended back unchanged (the op is not
/// idempotent, so overlap must not re-apply).
#[inline]
fn swar_row_wide(row: &mut [u8], t: u64) {
    let w = row.len();
    let mut i = 0;
    while i + 8 <= w {
        let win: &mut [u8; 8] = (&mut row[i..i + 8]).try_into().unwrap();
        *win = swar_dec_clamp(u64::from_le_bytes(*win), t).to_le_bytes();
        i += 8;
    }
    if i < w {
        let off = w - 8;
        let done = i - off; // low bytes already processed: 1..=7
        let win: &mut [u8; 8] = (&mut row[off..off + 8]).try_into().unwrap();
        let x = u64::from_le_bytes(*win);
        let keep = (1u64 << (done * 8)) - 1;
        *win = ((swar_dec_clamp(x, t) & !keep) | (x & keep)).to_le_bytes();
    }
}

/// The SWAR `u64` form of the core: 8-pixel lane words, narrow rows run
/// one blended window that slides backward at the end of `data`; only a
/// buffer shorter than 8 bytes falls back to the scalar loop.
#[inline]
pub fn decrement_clamp_swar(data: &mut [u8], width: usize, base_row: u16, rect: PatchRect, th: u8) {
    let w = rect.width();
    let t = (th as u64).wrapping_mul(L64);
    for y in rect.y0..=rect.y1 {
        let start = (y - base_row) as usize * width + rect.x0 as usize;
        if w >= 8 {
            swar_row_wide(&mut data[start..start + w], t);
        } else if start + 8 <= data.len() {
            let win: &mut [u8; 8] = (&mut data[start..start + 8]).try_into().unwrap();
            let x = u64::from_le_bytes(*win);
            let keep = !0u64 << (w * 8); // bytes beyond the rect: unchanged
            *win = ((swar_dec_clamp(x, t) & !keep) | (x & keep)).to_le_bytes();
        } else if data.len() >= 8 {
            // end-of-slice narrow row: slide the window backward so the
            // vector path still covers it (PR 3 fell back to scalar here)
            let off = data.len() - 8;
            let lo = start - off;
            let hi = lo + w;
            let win: &mut [u8; 8] = (&mut data[off..off + 8]).try_into().unwrap();
            let x = u64::from_le_bytes(*win);
            let hi_mask = if hi >= 8 { !0u64 } else { (1u64 << (hi * 8)) - 1 };
            let keep = hi_mask & (!0u64 << (lo * 8));
            *win = ((swar_dec_clamp(x, t) & keep) | (x & !keep)).to_le_bytes();
        } else {
            scalar_row(&mut data[start..start + w], th);
        }
    }
}

// ---------------------------------------------------------------------------
// lane-mask table shared by the 16/32-byte paths
// ---------------------------------------------------------------------------

/// `[0u8; 32] ++ [0xFF; 32] ++ [0u8; 32]`: loading `LANES` bytes at offset
/// `32 - lo` yields a mask selecting lanes `i >= lo`; at `64 - hi`, lanes
/// `i < hi`. ANDing the two selects exactly `[lo, hi)` with two unaligned
/// loads — branch-free tail blending.
static LANE_MASK: [u8; 96] = build_lane_mask();

const fn build_lane_mask() -> [u8; 96] {
    let mut m = [0u8; 96];
    let mut i = 32;
    while i < 64 {
        m[i] = 0xFF;
        i += 1;
    }
    m
}

// ---------------------------------------------------------------------------
// x86_64: SSE2 (baseline) and AVX2 (runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", not(miri)))]
mod x86 {
    use core::arch::x86_64::*;

    use super::{decrement_clamp_swar, PatchRect, LANE_MASK};

    /// 16-lane SSE2 decrement/clamp. SSE2 is part of the x86_64 baseline,
    /// so no feature detection is needed; the `unsafe` blocks are only for
    /// the raw-pointer loads/stores, which stay inside `data` by the
    /// window-clamping rule.
    #[inline]
    pub fn decrement_clamp_sse2(
        data: &mut [u8],
        width: usize,
        base_row: u16,
        rect: PatchRect,
        th: u8,
    ) {
        if data.len() < 16 {
            return decrement_clamp_swar(data, width, base_row, rect, th);
        }
        let w = rect.width();
        // SAFETY: every load/store below is bounded by `data` — full lanes
        // satisfy i + 16 <= start + w <= data.len(); tail windows clamp
        // wstart to data.len() - 16.
        unsafe {
            let ones = _mm_set1_epi8(1);
            let sign = _mm_set1_epi8(0x80u8 as i8);
            // unsigned v > th  <=>  signed (v ^ 0x80) > (th ^ 0x80)
            let thv = _mm_set1_epi8((th ^ 0x80) as i8);
            let ptr = data.as_mut_ptr();
            for y in rect.y0..=rect.y1 {
                let start = (y - base_row) as usize * width + rect.x0 as usize;
                let end = start + w;
                let mut i = start;
                while i + 16 <= end {
                    let p = ptr.add(i);
                    let v = _mm_loadu_si128(p as *const __m128i);
                    let dec = _mm_subs_epu8(v, ones);
                    let gt = _mm_cmpgt_epi8(_mm_xor_si128(v, sign), thv);
                    _mm_storeu_si128(p as *mut __m128i, _mm_and_si128(dec, gt));
                    i += 16;
                }
                if i < end {
                    let wstart = i.min(data.len() - 16);
                    let (lo, hi) = (i - wstart, end - wstart);
                    let p = ptr.add(wstart);
                    let v = _mm_loadu_si128(p as *const __m128i);
                    let dec = _mm_subs_epu8(v, ones);
                    let gt = _mm_cmpgt_epi8(_mm_xor_si128(v, sign), thv);
                    let r = _mm_and_si128(dec, gt);
                    let ge = _mm_loadu_si128(LANE_MASK.as_ptr().add(32 - lo) as *const __m128i);
                    let lt = _mm_loadu_si128(LANE_MASK.as_ptr().add(64 - hi) as *const __m128i);
                    let m = _mm_and_si128(ge, lt);
                    let blended = _mm_or_si128(_mm_and_si128(r, m), _mm_andnot_si128(m, v));
                    _mm_storeu_si128(p as *mut __m128i, blended);
                }
            }
        }
    }

    /// 32-lane AVX2 decrement/clamp.
    ///
    /// # Safety
    /// The CPU must support AVX2 (callers check
    /// `is_x86_feature_detected!("avx2")` first).
    #[target_feature(enable = "avx2")]
    pub unsafe fn decrement_clamp_avx2(
        data: &mut [u8],
        width: usize,
        base_row: u16,
        rect: PatchRect,
        th: u8,
    ) {
        if data.len() < 32 {
            return decrement_clamp_sse2(data, width, base_row, rect, th);
        }
        let w = rect.width();
        // SAFETY: the caller guarantees AVX2 (this fn's contract), and
        // every raw-pointer load/store is bounded by `data` — full lanes
        // satisfy i + 32 <= start + w <= data.len(); tail windows clamp
        // wstart to data.len() - 32; LANE_MASK offsets stay within its
        // 96 bytes for lo/hi in [0, 32].
        unsafe {
            let ones = _mm256_set1_epi8(1);
            let sign = _mm256_set1_epi8(0x80u8 as i8);
            let thv = _mm256_set1_epi8((th ^ 0x80) as i8);
            let ptr = data.as_mut_ptr();
            for y in rect.y0..=rect.y1 {
                let start = (y - base_row) as usize * width + rect.x0 as usize;
                let end = start + w;
                let mut i = start;
                while i + 32 <= end {
                    let p = ptr.add(i);
                    let v = _mm256_loadu_si256(p as *const __m256i);
                    let dec = _mm256_subs_epu8(v, ones);
                    let gt = _mm256_cmpgt_epi8(_mm256_xor_si256(v, sign), thv);
                    _mm256_storeu_si256(p as *mut __m256i, _mm256_and_si256(dec, gt));
                    i += 32;
                }
                if i < end {
                    let wstart = i.min(data.len() - 32);
                    let (lo, hi) = (i - wstart, end - wstart);
                    let p = ptr.add(wstart);
                    let v = _mm256_loadu_si256(p as *const __m256i);
                    let dec = _mm256_subs_epu8(v, ones);
                    let gt = _mm256_cmpgt_epi8(_mm256_xor_si256(v, sign), thv);
                    let r = _mm256_and_si256(dec, gt);
                    let ge = _mm256_loadu_si256(LANE_MASK.as_ptr().add(32 - lo) as *const __m256i);
                    let lt = _mm256_loadu_si256(LANE_MASK.as_ptr().add(64 - hi) as *const __m256i);
                    let m = _mm256_and_si256(ge, lt);
                    let blended =
                        _mm256_or_si256(_mm256_and_si256(r, m), _mm256_andnot_si256(m, v));
                    _mm256_storeu_si256(p as *mut __m256i, blended);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON (baseline)
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "aarch64", not(miri)))]
mod arm {
    use core::arch::aarch64::*;

    use super::{decrement_clamp_swar, PatchRect, LANE_MASK};

    /// 16-lane NEON decrement/clamp. NEON is part of the aarch64 baseline;
    /// the `unsafe` blocks are only for the raw-pointer loads/stores,
    /// bounded by the window-clamping rule.
    #[inline]
    pub fn decrement_clamp_neon(
        data: &mut [u8],
        width: usize,
        base_row: u16,
        rect: PatchRect,
        th: u8,
    ) {
        if data.len() < 16 {
            return decrement_clamp_swar(data, width, base_row, rect, th);
        }
        let w = rect.width();
        // SAFETY: loads/stores bounded by `data` exactly as in the SSE2
        // path; NEON intrinsics themselves are baseline on aarch64.
        unsafe {
            let ones = vdupq_n_u8(1);
            let thv = vdupq_n_u8(th);
            let ptr = data.as_mut_ptr();
            for y in rect.y0..=rect.y1 {
                let start = (y - base_row) as usize * width + rect.x0 as usize;
                let end = start + w;
                let mut i = start;
                while i + 16 <= end {
                    let p = ptr.add(i);
                    let v = vld1q_u8(p);
                    let r = vandq_u8(vqsubq_u8(v, ones), vcgtq_u8(v, thv));
                    vst1q_u8(p, r);
                    i += 16;
                }
                if i < end {
                    let wstart = i.min(data.len() - 16);
                    let (lo, hi) = (i - wstart, end - wstart);
                    let p = ptr.add(wstart);
                    let v = vld1q_u8(p);
                    let r = vandq_u8(vqsubq_u8(v, ones), vcgtq_u8(v, thv));
                    let ge = vld1q_u8(LANE_MASK.as_ptr().add(32 - lo));
                    let lt = vld1q_u8(LANE_MASK.as_ptr().add(64 - hi));
                    let m = vandq_u8(ge, lt);
                    vst1q_u8(p, vbslq_u8(m, r, v));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swar_word_matches_scalar_exhaustively() {
        // every (pixel value, threshold) pair through the 8-lane word,
        // with a different neighbour value in every other lane to catch
        // cross-byte borrow/carry contamination. Under Miri (~400x slower)
        // stride the threshold axis; 17 is coprime to 256 so repeated runs
        // still cover varied residues
        let th_step = if cfg!(miri) { 17 } else { 1 };
        for th in (0u16..=255).step_by(th_step) {
            let t = (th as u64).wrapping_mul(L64);
            for base in (0u16..=255).step_by(8) {
                let lanes: [u8; 8] = std::array::from_fn(|i| (base as usize + i) as u8);
                let out = swar_dec_clamp(u64::from_le_bytes(lanes), t).to_le_bytes();
                for (i, &v) in lanes.iter().enumerate() {
                    let d = v.saturating_sub(1);
                    let want = if d < th as u8 { 0 } else { d };
                    assert_eq!(out[i], want, "lane {i} v {v} th {th}");
                }
            }
        }
    }

    #[test]
    fn lane_mask_selects_half_open_ranges() {
        // the 32-lane sweep alone is ~17k assertions; one width suffices
        // under Miri (the table logic is identical at both widths)
        let widths: &[usize] = if cfg!(miri) { &[16] } else { &[16, 32] };
        for &lanes in widths {
            for lo in 0..lanes {
                for hi in lo + 1..=lanes {
                    let ge = &LANE_MASK[32 - lo..32 - lo + lanes];
                    let lt = &LANE_MASK[64 - hi..64 - hi + lanes];
                    for i in 0..lanes {
                        let m = ge[i] & lt[i];
                        let want = if i >= lo && i < hi { 0xFF } else { 0 };
                        assert_eq!(m, want, "lanes {lanes} lo {lo} hi {hi} i {i}");
                    }
                }
            }
        }
    }

    /// The exhaustive alignment × width × threshold sweep, per dispatch
    /// path: all row widths x rect alignments x rect widths x threshold
    /// classes, at every vertical position of a 3-row buffer (the last
    /// row exercises the backward-sliding end-of-slice window) plus the
    /// full 3-row rect.
    fn sweep_path(path: KernelPath) {
        // under Miri only scalar/SWAR paths exist; a width past one SWAR
        // word plus its slid tail (9) and the boundary thresholds cover
        // every branch, at ~1/50 the interpreted workload
        let thresholds: &[u8] = if cfg!(miri) {
            &[0, 224, 225, 255]
        } else {
            &[0, 1, 2, 127, 128, 224, 225, 226, 254, 255]
        };
        let max_width = if cfg!(miri) { 9 } else { 40 };
        for width in 1usize..=max_width {
            let data: Vec<u8> = (0..width * 3).map(|i| (i * 37 + 3) as u8).collect();
            for x0 in 0..width {
                for x1 in x0..width {
                    for (y0, y1) in [(0u16, 0u16), (1, 1), (2, 2), (0, 2)] {
                        let rect = PatchRect { x0: x0 as u16, x1: x1 as u16, y0, y1 };
                        for &th in thresholds {
                            let mut a = data.clone();
                            let mut b = data.clone();
                            decrement_clamp_with(path, &mut a, width, 0, rect, th);
                            decrement_clamp_scalar(&mut b, width, 0, rect, th);
                            assert_eq!(a, b, "{path} width {width} rect {rect:?} th {th}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn every_available_path_matches_scalar_exhaustively() {
        for path in available_paths() {
            sweep_path(path);
        }
    }

    #[test]
    fn dispatch_path_matches_scalar_on_band_offsets() {
        // a band slice starting at sensor row 100: every path must
        // address rows relative to the base
        for path in available_paths() {
            let width = 13usize;
            let data: Vec<u8> = (0..width * 5).map(|i| (i * 29 + 1) as u8).collect();
            let rect = PatchRect { x0: 2, x1: 11, y0: 101, y1: 103 };
            let mut a = data.clone();
            let mut b = data;
            decrement_clamp_with(path, &mut a, width, 100, rect, 225);
            decrement_clamp_scalar(&mut b, width, 100, rect, 225);
            assert_eq!(a, b, "{path}");
        }
    }

    #[test]
    fn narrow_buffer_degrades_without_touching_out_of_rect_bytes() {
        // a 4-wide, 3-row buffer (12 bytes: smaller than any vector) —
        // every path must leave out-of-rect bytes untouched
        for path in available_paths() {
            let mut data = vec![255u8; 12];
            let rect = PatchRect { x0: 1, x1: 2, y0: 11, y1: 11 };
            decrement_clamp_with(path, &mut data, 4, 10, rect, 225);
            assert_eq!(data[4], 255, "{path}");
            assert_eq!(data[5], 254, "{path}");
            assert_eq!(data[6], 254, "{path}");
            assert_eq!(data[7], 255, "{path}");
            assert!(data[..4].iter().all(|&v| v == 255), "{path}");
            assert!(data[8..].iter().all(|&v| v == 255), "{path}");
        }
    }

    #[test]
    fn selection_is_runnable_and_cached() {
        let p = active_path();
        assert!(p.runnable());
        assert_eq!(p, active_path(), "selection must be stable");
        assert!(available_paths().contains(&p));
        // scalar and SWAR are runnable everywhere
        assert!(KernelPath::Scalar.runnable() && KernelPath::Swar64.runnable());
    }

    #[test]
    fn parse_roundtrips_names() {
        for p in [
            KernelPath::Scalar,
            KernelPath::Swar64,
            KernelPath::Sse2,
            KernelPath::Avx2,
            KernelPath::Neon,
        ] {
            assert_eq!(KernelPath::parse(p.as_str()), Some(p));
        }
        assert_eq!(KernelPath::parse("auto"), None);
        assert_eq!(KernelPath::parse("swar"), Some(KernelPath::Swar64));
    }
}
