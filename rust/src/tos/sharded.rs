//! Sharded parallel software TOS: the sensor plane is tiled into
//! horizontal row bands and event batches are fanned out across worker
//! threads — the "pure software, but actually fast" point on the paper's
//! Fig. 1(b) axis, and the scale path for HD-class sensors when no NMC
//! macro is available.
//!
//! Routing: an event's clipped patch may straddle a band boundary, so the
//! event is routed to *every* band its patch intersects (the overlap
//! region); each band then applies only the rows it owns, and the 255
//! centre write is performed by the single band owning the event row.
//! Row ownership is disjoint and each band replays its bucket in stream
//! order, so the per-pixel operation sequence is identical to the
//! sequential golden model — bit-exactness at any shard count is enforced
//! by `prop_all_backends_bit_exact` in `rust/tests/properties.rs`.

use crate::events::{Event, Resolution};

use super::backend::{clip_patch, decrement_clamp, golden_update, BackendStats, PatchRect, TosBackend};
use super::{TosConfig, TosConfigError};

/// Row-band sharded software TOS backend.
#[derive(Debug, Clone)]
pub struct ShardedTos {
    res: Resolution,
    cfg: TosConfig,
    /// Rows owned by each band (the last band may be short).
    rows_per_band: usize,
    /// Band count implied by `rows_per_band`.
    bands: usize,
    /// Full row-major surface; bands own disjoint row slices of it.
    data: Vec<u8>,
    /// Per-band routing buffers (event + its pre-clipped patch, so
    /// workers don't redo the clip), reused across batches.
    buckets: Vec<Vec<(Event, PatchRect)>>,
    stats: BackendStats,
}

impl ShardedTos {
    /// Build with `shards` worker bands (clamped to the sensor row count).
    pub fn new(res: Resolution, cfg: TosConfig, shards: usize) -> Result<Self, TosConfigError> {
        cfg.validate()?;
        if shards == 0 {
            return Err(TosConfigError::ZeroShards);
        }
        let h = res.height as usize;
        let rows_per_band = h.div_ceil(shards.min(h));
        let bands = h.div_ceil(rows_per_band);
        Ok(Self {
            res,
            cfg,
            rows_per_band,
            bands,
            data: vec![0; res.pixels()],
            buckets: vec![Vec::new(); bands],
            stats: BackendStats::default(),
        })
    }

    /// Actual number of row bands (= worker parallelism of a batch).
    #[inline]
    pub fn bands(&self) -> usize {
        self.bands
    }

    /// Algorithm parameters.
    #[inline]
    pub fn config(&self) -> TosConfig {
        self.cfg
    }

    /// Raw row-major pixel data.
    #[inline]
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// Apply a batch in stream order, fanned out across the row bands.
    ///
    /// This is the fast path: routing is O(events), then every band walks
    /// only its own bucket against its own disjoint row slice. The two
    /// phases are the free functions [`route_into`] / [`apply_band`], so
    /// the overlap-region routing protocol can be checked independently
    /// of rayon (the `loom_tests` module runs `apply_band` on loom
    /// threads and compares against the sequential golden model).
    pub fn process_batch(&mut self, events: &[Event]) {
        if events.is_empty() {
            return;
        }
        let th = self.cfg.threshold;
        let w = self.res.width as usize;
        let rpb = self.rows_per_band;

        // --- route: an event goes to every band its clipped patch touches
        let pixels = route_into(&mut self.buckets, self.res, self.cfg.half(), rpb, events);
        self.stats.events += events.len() as u64;
        self.stats.pixels += pixels;

        // --- apply: one worker per band over its disjoint row slice
        rayon::scope(|s| {
            for (band, (chunk, bucket)) in
                self.data.chunks_mut(rpb * w).zip(&self.buckets).enumerate()
            {
                s.spawn(move |_| apply_band(chunk, w, (band * rpb) as u16, th, bucket));
            }
        });
    }
}

/// Routing phase of [`ShardedTos::process_batch`]: clear `buckets` and
/// push each event (with its pre-clipped patch, so workers don't redo
/// the clip) into the bucket of *every* band its patch intersects —
/// the overlap region. Returns the total patch pixels touched (the
/// [`BackendStats::pixels`] contribution).
fn route_into(
    buckets: &mut [Vec<(Event, PatchRect)>],
    res: Resolution,
    half: i32,
    rows_per_band: usize,
    events: &[Event],
) -> u64 {
    for bucket in buckets.iter_mut() {
        bucket.clear();
    }
    let mut pixels = 0u64;
    for ev in events {
        let rect = clip_patch(res, ev.x, ev.y, half);
        pixels += rect.pixels() as u64;
        let lo = rect.y0 as usize / rows_per_band;
        let hi = rect.y1 as usize / rows_per_band;
        for band in lo..=hi {
            buckets[band].push((*ev, rect));
        }
    }
    pixels
}

/// Apply phase of [`ShardedTos::process_batch`], for one band: replay
/// `bucket` in stream order against `chunk` (the band's disjoint row
/// slice, whose first row is sensor row `base`), decrementing only the
/// patch rows this band owns and writing the 255 centre only if the
/// event row falls inside the band. Bands touch disjoint rows, so
/// running every band concurrently is bit-exact with the sequential
/// golden model.
fn apply_band(chunk: &mut [u8], w: usize, base: u16, threshold: u8, bucket: &[(Event, PatchRect)]) {
    let top = base + (chunk.len() / w) as u16 - 1;
    for (ev, rect) in bucket {
        let sub = PatchRect { y0: rect.y0.max(base), y1: rect.y1.min(top), ..*rect };
        decrement_clamp(chunk, w, base, sub, threshold);
        if ev.y >= base && ev.y <= top {
            chunk[(ev.y - base) as usize * w + ev.x as usize] = 255;
        }
    }
}

impl TosBackend for ShardedTos {
    fn name(&self) -> &'static str {
        "sharded-tos"
    }

    fn resolution(&self) -> Resolution {
        self.res
    }

    /// Single-event path: identical to the golden model — parallelism only
    /// pays off on batches, so lone events skip routing entirely.
    fn process(&mut self, ev: &Event) {
        let px = golden_update(&mut self.data, self.res, self.cfg, ev);
        self.stats.events += 1;
        self.stats.pixels += px as u64;
    }

    fn process_batch(&mut self, events: &[Event]) {
        ShardedTos::process_batch(self, events)
    }

    fn prefers_batching(&self) -> bool {
        self.bands > 1
    }

    fn tos_view(&self) -> &[u8] {
        // bands own disjoint row slices of one contiguous row-major
        // buffer, so the snapshot view is the buffer itself — the band
        // layout needs no gather step
        &self.data
    }

    fn stats(&self) -> BackendStats {
        BackendStats { kernel: super::kernel::active_path(), ..self.stats }
    }

    fn reset(&mut self) {
        self.data.fill(0);
        self.stats = BackendStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tos::TosSurface;
    use crate::util::rng::Rng;

    fn stream(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
        let mut rng = Rng::seed_from(seed);
        (0..n)
            .map(|i| {
                Event::on(
                    rng.below(res.width as u64) as u16,
                    rng.below(res.height as u64) as u16,
                    i as u64,
                )
            })
            .collect()
    }

    #[test]
    fn batch_matches_golden_at_various_shard_counts() {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let events = stream(res, 4_000, 7);
        let mut golden = TosSurface::new(res, cfg).unwrap();
        golden.update_batch(&events);
        for shards in [1usize, 2, 3, 4, 7, 64, 200] {
            let mut sh = ShardedTos::new(res, cfg, shards).unwrap();
            sh.process_batch(&events);
            assert_eq!(golden.data(), sh.data(), "diverged at shards={shards}");
        }
    }

    #[test]
    fn border_and_boundary_patches_are_exact() {
        // bands of 2 rows with a 7x7 patch: every patch straddles bands
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut events = vec![
            Event::on(0, 0, 0),
            Event::on(63, 63, 1),
            Event::on(0, 63, 2),
            Event::on(63, 0, 3),
        ];
        // hammer one band boundary from both sides
        for i in 0..200u64 {
            events.push(Event::on((i % 64) as u16, 31 + (i % 3) as u16, 10 + i));
        }
        let mut golden = TosSurface::new(res, cfg).unwrap();
        golden.update_batch(&events);
        let mut sh = ShardedTos::new(res, cfg, 32).unwrap();
        sh.process_batch(&events);
        assert_eq!(golden.data(), sh.data());
    }

    #[test]
    fn interleaved_single_and_batch_processing_agree() {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let events = stream(res, 1_200, 11);
        let mut golden = TosSurface::new(res, cfg).unwrap();
        golden.update_batch(&events);
        let mut sh = ShardedTos::new(res, cfg, 4).unwrap();
        sh.process_batch(&events[..400]);
        for e in &events[400..800] {
            sh.process(e);
        }
        sh.process_batch(&events[800..]);
        assert_eq!(golden.data(), sh.data());
        assert_eq!(sh.stats().events, 1_200);
        assert_eq!(sh.stats().pixels, golden.stats().pixels);
    }

    #[test]
    fn shard_count_clamps_to_rows() {
        let sh = ShardedTos::new(Resolution::TEST64, TosConfig::default(), 10_000).unwrap();
        assert_eq!(sh.bands(), 64);
        assert!(ShardedTos::new(Resolution::TEST64, TosConfig::default(), 0).is_err());
    }

    #[test]
    fn reset_clears_surface_and_stats() {
        let mut sh = ShardedTos::new(Resolution::TEST64, TosConfig::default(), 4).unwrap();
        sh.process_batch(&stream(Resolution::TEST64, 100, 3));
        sh.reset();
        assert!(sh.data().iter().all(|&v| v == 0));
        let fresh =
            BackendStats { kernel: crate::tos::kernel::active_path(), ..Default::default() };
        assert_eq!(sh.stats(), fresh);
    }
}

/// Loom model of the overlap-region routing protocol: [`route_into`]
/// fans events out to every band their patch touches, then each band
/// applies its bucket on a *loom* thread over a band-owned buffer
/// (standing in for rayon's disjoint `chunks_mut` slices). Under every
/// schedule the reassembled surface must equal the sequential golden
/// model — i.e. band application is truly order-independent because row
/// ownership is disjoint. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_tests`.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::tos::TosSurface;
    use crate::util::sync::thread;

    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = loom::model::Builder::new();
        if b.preemption_bound.is_none() {
            b.preemption_bound = Some(3);
        }
        b.check(f);
    }

    /// Two bands, patches straddling the band boundary (overlap-region
    /// events land in both buckets), centre writes on both sides.
    #[test]
    fn loom_band_application_is_schedule_independent() {
        model(|| {
            let res = Resolution::TEST64;
            let cfg = TosConfig::default();
            let w = res.width as usize;
            let rpb = res.height as usize / 2; // 2 bands of 32 rows
            // events hammering the 31/32 boundary plus the corners
            let events = vec![
                Event::on(5, 31, 0),
                Event::on(5, 32, 1),
                Event::on(5, 30, 2),
                Event::on(0, 0, 3),
                Event::on(63, 63, 4),
                Event::on(5, 33, 5),
            ];

            let mut buckets: Vec<Vec<(Event, PatchRect)>> = vec![Vec::new(); 2];
            route_into(&mut buckets, res, cfg.half(), rpb, &events);
            // the boundary events must be in the overlap region: routed
            // to both bands, applied by each only within its rows
            assert!(buckets[0].len() > events.len() / 2 && buckets[1].len() > events.len() / 2);

            // one loom thread per band over a band-owned buffer (the
            // model-checker stand-in for rayon's disjoint chunks_mut)
            let th = cfg.threshold;
            let handles: Vec<_> = buckets
                .into_iter()
                .enumerate()
                .map(|(band, bucket)| {
                    thread::spawn(move || {
                        let mut chunk = vec![0u8; rpb * w];
                        apply_band(&mut chunk, w, (band * rpb) as u16, th, &bucket);
                        chunk
                    })
                })
                .collect();
            let mut surface = Vec::with_capacity(res.pixels());
            for h in handles {
                surface.extend(h.join().unwrap());
            }

            let mut golden = TosSurface::new(res, cfg).unwrap();
            golden.update_batch(&events);
            assert_eq!(golden.data(), &surface[..]);
        });
    }
}
