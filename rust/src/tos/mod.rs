//! Golden software model of the Threshold-Ordinal Surface (paper
//! Algorithm 1 / luvHarris Sec. III), plus the [`backend`] abstraction
//! that unifies every TOS implementation in the crate.
//!
//! The TOS is an `H x W` map of 8-bit "novelty" values.  Per event:
//! decrement the `P x P` patch around the event, clamp anything that falls
//! below `TH` to zero, then write 255 at the event pixel.  [`TosSurface`]
//! is the bit-exact reference against which the NMC macro simulator
//! ([`crate::nmc`]), the conventional baseline ([`crate::conventional`]),
//! the sharded parallel model ([`sharded::ShardedTos`]) and the Pallas
//! batch kernel (python tests) are all checked.

pub mod backend;
pub mod kernel;
pub mod sharded;

pub use backend::{BackendStats, FaultInfo, TosBackend};
pub use kernel::KernelPath;
pub use sharded::ShardedTos;

use crate::events::{Event, Resolution};

/// Threshold floor required by the 5-bit on-chip datapath (paper Sec. IV-A).
pub const NMC_MIN_THRESHOLD: u8 = 225;

/// Validation error for [`TosConfig`] / backend construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TosConfigError {
    /// Patch side must be odd (the patch is centred on the event pixel).
    PatchNotOdd(u16),
    /// Patch side must be at least 3.
    PatchTooSmall(u16),
    /// The NMC macro's 5-bit datapath requires `TH >= 225`. (The
    /// conventional/software backends store full 8-bit values and accept
    /// any threshold.)
    ThresholdBelowNmcMin(u8),
    /// The sharded backend needs at least one shard.
    ZeroShards,
}

impl std::fmt::Display for TosConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::PatchNotOdd(p) => write!(f, "patch must be odd, got {p}"),
            Self::PatchTooSmall(p) => write!(f, "patch must be >= 3, got {p}"),
            Self::ThresholdBelowNmcMin(t) => {
                write!(f, "5-bit datapath requires TH >= {NMC_MIN_THRESHOLD}, got {t}")
            }
            Self::ZeroShards => write!(f, "sharded backend needs at least one shard"),
        }
    }
}

impl std::error::Error for TosConfigError {}

/// TOS algorithm parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TosConfig {
    /// Patch side length `P` (odd).
    pub patch: u16,
    /// Threshold `TH` below which decremented values clamp to zero.
    /// The paper stores only 5 bits because `TH` "typically does not go
    /// below ~225"; `TH >= 225` also makes the 5-bit encoding injective
    /// (stored 0 uniquely means an erased pixel).
    pub threshold: u8,
}

impl Default for TosConfig {
    fn default() -> Self {
        // Paper: 7x7 patch, TH ~ 225 (=> 5-bit on-chip storage).
        Self { patch: 7, threshold: 225 }
    }
}

impl TosConfig {
    /// Half patch extent `(P-1)/2`.
    #[inline]
    pub fn half(&self) -> i32 {
        (self.patch as i32 - 1) / 2
    }

    /// Validate config invariants (odd patch of sane size).
    pub fn validate(&self) -> Result<(), TosConfigError> {
        if self.patch < 3 {
            return Err(TosConfigError::PatchTooSmall(self.patch));
        }
        if self.patch % 2 == 0 {
            return Err(TosConfigError::PatchNotOdd(self.patch));
        }
        Ok(())
    }

    /// Validate for the NMC macro's 5-bit datapath (adds the `TH` floor
    /// that makes the [`encoding`] injective).
    pub fn validate_nmc(&self) -> Result<(), TosConfigError> {
        self.validate()?;
        if self.threshold < NMC_MIN_THRESHOLD {
            return Err(TosConfigError::ThresholdBelowNmcMin(self.threshold));
        }
        Ok(())
    }
}

/// The Threshold-Ordinal Surface: an 8-bit novelty map.
#[derive(Debug, Clone, PartialEq)]
pub struct TosSurface {
    res: Resolution,
    cfg: TosConfig,
    data: Vec<u8>,
    stats: BackendStats,
}

impl TosSurface {
    /// Fresh all-zero surface. Fails on an invalid [`TosConfig`] instead
    /// of panicking so user-supplied configs propagate as errors.
    pub fn new(res: Resolution, cfg: TosConfig) -> Result<Self, TosConfigError> {
        cfg.validate()?;
        Ok(Self { res, cfg, data: vec![0; res.pixels()], stats: BackendStats::default() })
    }

    /// Sensor geometry.
    #[inline]
    pub fn resolution(&self) -> Resolution {
        self.res
    }

    /// Algorithm parameters.
    #[inline]
    pub fn config(&self) -> TosConfig {
        self.cfg
    }

    /// Raw row-major pixel data.
    #[inline]
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// Mutable raw access (used by the BER-injection study).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    /// Pixel accessor.
    #[inline]
    pub fn get(&self, x: u16, y: u16) -> u8 {
        self.data[self.res.index(x, y)]
    }

    /// Pixel mutator (tests / error injection).
    #[inline]
    pub fn set(&mut self, x: u16, y: u16, v: u8) {
        let i = self.res.index(x, y);
        self.data[i] = v;
    }

    /// Apply one event (Algorithm 1). Patches are clipped at the borders;
    /// returns the clipped patch's pixel count.
    ///
    /// This is the *hot path* of the whole system model; the shared core
    /// ([`backend::decrement_clamp`]) is kept allocation-free and
    /// branch-light (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn update(&mut self, ev: &Event) -> usize {
        let px = backend::golden_update(&mut self.data, self.res, self.cfg, ev);
        self.stats.events += 1;
        self.stats.pixels += px as u64;
        px
    }

    /// Apply a batch of events in order.
    pub fn update_batch(&mut self, events: &[Event]) {
        for e in events {
            self.update(e);
        }
    }

    /// Copy the surface into an `f32` frame (the Harris graph's input).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Copy into a caller-provided f32 buffer (no allocation on the FBF path).
    pub fn write_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        for (o, &v) in out.iter_mut().zip(&self.data) {
            *o = v as f32;
        }
    }

    /// Count of pixels currently holding "novel" (non-zero) values.
    pub fn active_pixels(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0).count()
    }

    /// Reset to all zeros (telemetry included).
    pub fn clear(&mut self) {
        self.data.fill(0);
        self.stats = BackendStats::default();
    }
}

impl TosBackend for TosSurface {
    fn name(&self) -> &'static str {
        "golden-tos"
    }

    fn resolution(&self) -> Resolution {
        self.res
    }

    fn process(&mut self, ev: &Event) {
        self.update(ev);
    }

    fn tos_view(&self) -> &[u8] {
        &self.data
    }

    fn stats(&self) -> BackendStats {
        BackendStats { kernel: kernel::active_path(), ..self.stats }
    }

    fn reset(&mut self) {
        self.clear();
    }
}

/// The 5-bit on-chip encoding (paper Sec. IV-A): since `TH >= 225`, live
/// values sit in `[225, 255]`, whose low 5 bits are `v - 224` in `[1, 31]`;
/// the high 3 bits (`0b111`) are implicit. Stored `0` uniquely encodes an
/// erased pixel (`TOS = 0`), which is what lets the write-back circuit
/// gate on "stored value is 0" without a separate valid flag.
pub mod encoding {
    /// Encode an 8-bit TOS value (0 or >= 225) into the 5 stored bits.
    #[inline]
    pub fn store(v: u8) -> u8 {
        debug_assert!(representable(v), "unrepresentable TOS value {v}");
        v & 0x1F
    }

    /// Decode the 5 stored bits back into the 8-bit domain.
    #[inline]
    pub fn load(bits5: u8) -> u8 {
        if bits5 == 0 {
            0
        } else {
            0xE0 | (bits5 & 0x1F)
        }
    }

    /// Values the TOS can actually hold with `TH >= 225`.
    #[inline]
    pub fn representable(v: u8) -> bool {
        v == 0 || v >= 225
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn surface() -> TosSurface {
        TosSurface::new(Resolution::TEST64, TosConfig::default()).unwrap()
    }

    #[test]
    fn single_event_writes_255() {
        let mut s = surface();
        s.update(&Event::on(10, 12, 0));
        assert_eq!(s.get(10, 12), 255);
        // rest of patch was 0 and stays 0 (0-1 clamps)
        assert_eq!(s.get(9, 12), 0);
        assert_eq!(s.active_pixels(), 1);
    }

    #[test]
    fn neighbours_decrement_until_threshold() {
        let mut s = surface();
        s.update(&Event::on(20, 20, 0));
        // 30 more events at a neighbouring pixel: the first pixel decays
        for i in 0..30 {
            s.update(&Event::on(21, 20, i + 1));
        }
        // 255 - 30 = 225 = TH, still alive
        assert_eq!(s.get(20, 20), 225);
        s.update(&Event::on(21, 20, 100));
        // one more decrement: 224 < TH -> 0
        assert_eq!(s.get(20, 20), 0);
    }

    #[test]
    fn border_clipping() {
        let mut s = surface();
        assert_eq!(s.update(&Event::on(0, 0, 0)), 16);
        assert_eq!(s.update(&Event::on(63, 63, 1)), 16);
        assert_eq!(s.get(0, 0), 255);
        assert_eq!(s.get(63, 63), 255);
    }

    #[test]
    fn values_stay_in_valid_domain() {
        // After arbitrary updates every value is 0 or >= TH (it's the
        // invariant that justifies the 5-bit storage).
        let mut s = surface();
        for i in 0..500u64 {
            s.update(&Event::on((i * 7 % 64) as u16, (i * 13 % 64) as u16, i));
        }
        for &v in s.data() {
            assert!(v == 0 || v >= s.config().threshold || v == 255);
        }
    }

    #[test]
    fn update_batch_equals_sequential() {
        let evs: Vec<Event> =
            (0..100).map(|i| Event::new((i % 60) as u16, (i % 50) as u16, i as u64, Polarity::On)).collect();
        let mut a = surface();
        let mut b = surface();
        a.update_batch(&evs);
        for e in &evs {
            b.update(e);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn to_f32_matches_data() {
        let mut s = surface();
        s.update(&Event::on(5, 5, 0));
        let f = s.to_f32();
        assert_eq!(f[s.resolution().index(5, 5)], 255.0);
        let mut buf = vec![0f32; s.data().len()];
        s.write_f32_into(&mut buf);
        assert_eq!(f, buf);
    }

    #[test]
    fn config_validation() {
        assert!(TosConfig { patch: 6, threshold: 224 }.validate().is_err());
        assert!(TosConfig { patch: 1, threshold: 224 }.validate().is_err());
        assert!(TosConfig { patch: 9, threshold: 200 }.validate().is_ok());
        // the hardware datapaths additionally require the TH floor
        assert_eq!(
            TosConfig { patch: 9, threshold: 200 }.validate_nmc(),
            Err(TosConfigError::ThresholdBelowNmcMin(200))
        );
        assert!(TosConfig::default().validate_nmc().is_ok());
    }

    #[test]
    fn invalid_config_is_an_error_not_a_panic() {
        let err = TosSurface::new(Resolution::TEST64, TosConfig { patch: 4, threshold: 225 });
        assert_eq!(err.unwrap_err(), TosConfigError::PatchNotOdd(4));
    }

    #[test]
    fn backend_trait_counts_events_and_pixels() {
        let mut s = surface();
        TosBackend::process(&mut s, &Event::on(32, 32, 0));
        TosBackend::process(&mut s, &Event::on(0, 0, 1));
        let st = TosBackend::stats(&s);
        assert_eq!(st.events, 2);
        assert_eq!(st.pixels, 49 + 16);
        // pure software model: no hardware cost
        assert_eq!(st.busy_ns, 0.0);
        assert_eq!(st.energy_pj, 0.0);
    }

    #[test]
    fn encoding_roundtrip() {
        for v in 0u16..=255 {
            let v = v as u8;
            if encoding::representable(v) {
                assert_eq!(encoding::load(encoding::store(v)), v, "value {v}");
            }
        }
    }

    #[test]
    fn encoding_is_injective_over_domain() {
        let mut seen = std::collections::HashMap::new();
        for v in 0u16..=255 {
            let v = v as u8;
            if encoding::representable(v) {
                if let Some(prev) = seen.insert(encoding::store(v), v) {
                    panic!("collision: {prev} and {v} both store as {}", encoding::store(v));
                }
            }
        }
    }

    #[test]
    fn clear_resets() {
        let mut s = surface();
        s.update(&Event::on(1, 1, 0));
        s.clear();
        assert_eq!(s.active_pixels(), 0);
        let fresh = BackendStats { kernel: kernel::active_path(), ..Default::default() };
        assert_eq!(TosBackend::stats(&s), fresh);
    }
}
