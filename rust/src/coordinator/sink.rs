//! The pipeline's output API: [`CornerSink`], an observer that receives
//! corners, scores, and live counters *at event rate* while a run is in
//! flight — instead of waiting for the end-of-run
//! [`RunReport`](super::RunReport).
//!
//! The paper's whole pitch is latency: NM-TOS exists so corner decisions
//! come out at event rate, not after buffering. The results path mirrors
//! that — [`Pipeline::run_stream_with`](super::Pipeline::run_stream_with)
//! drives a sink as it processes, so a consumer (a wire protocol, a
//! visualizer, a downstream tracker) sees each corner the moment it is
//! tagged. luvHarris frames practical event-camera corner detection as
//! exactly this kind of throughput pipeline with continuous consumers.
//!
//! Contract (enforced by the coordinator's run loops):
//!
//! * [`on_score`](CornerSink::on_score) fires once per **signal** event
//!   (post-STCF), in stream order. `seq` is the event's 0-based index
//!   among signal events — the same indexing
//!   [`RunReport::corners`](super::RunReport::corners) uses.
//! * [`on_corner`](CornerSink::on_corner) fires additionally, right
//!   after that event's `on_score`, when its score reaches the corner
//!   threshold.
//! * [`on_stats`](CornerSink::on_stats) fires every
//!   [`stats_interval_events`](super::PipelineConfig::stats_interval_events)
//!   **input** events (pre-STCF), so its cadence — like every per-event
//!   callback — is independent of source chunking.
//! * [`on_chunk_end`](CornerSink::on_chunk_end) fires after each source
//!   chunk is fully processed. This is the natural flush point for
//!   batching sinks; unlike the other callbacks its cadence *does*
//!   depend on how the source chunks the stream.
//!
//! Every callback is fallible, and that is the backpressure contract: a
//! sink error aborts the run with that error. A sink may also simply
//! block (a TCP writer with a full send buffer blocks in `on_corner`),
//! which stalls the pipeline — backpressure, not data loss. Sinks that
//! must never stall the event path should buffer internally and shed
//! load themselves.
//!
//! [`RunReport`](super::RunReport) recording is itself just a sink:
//! [`RecordingSink`] is what the coordinator drives internally when
//! [`record_per_event`](super::PipelineConfig::record_per_event) is on,
//! so the load-all, streamed, and served paths all share one recording
//! implementation.
//!
//! ```
//! use nmc_tos::coordinator::sink::{Corner, CornerSink};
//!
//! /// Counts corners; never blocks, never fails.
//! #[derive(Default)]
//! struct Counter {
//!     corners: u64,
//! }
//!
//! impl CornerSink for Counter {
//!     fn on_corner(&mut self, _c: &Corner) -> anyhow::Result<()> {
//!         self.corners += 1;
//!         Ok(())
//!     }
//! }
//!
//! # use nmc_tos::prelude::*;
//! let mut cfg = PipelineConfig::test64();
//! cfg.detector = DetectorKind::Fast; // SAE detector: no Harris engine
//! let mut pipe = Pipeline::from_config_without_engine(cfg)?;
//! let events = SceneConfig::test64().build(1).generate(2_000);
//! let mut sink = Counter::default();
//! let report = pipe.run_with(&events, &mut sink)?;
//! assert_eq!(sink.corners, report.corners_total);
//! # anyhow::Ok(())
//! ```

use anyhow::Result;

use crate::events::Event;

/// One corner decision, delivered at event rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Corner {
    /// 0-based index of this event among the stream's signal events
    /// (the index [`RunReport::corners`](super::RunReport::corners)
    /// would record).
    pub seq: u64,
    /// The event that was tagged.
    pub ev: Event,
    /// Its detector score (≥ the configured corner threshold).
    pub score: f64,
}

/// A live snapshot of the run counters, as of the emitting callback.
///
/// The counter fields are monotone over a run and match the corresponding
/// [`RunReport`](super::RunReport) counters at end of stream; `last_t_us`,
/// `degrade_level` and `vdd_mv` are instantaneous state. Every field is
/// derived from the event stream and pipeline state (never wall clock),
/// so snapshots emitted at [`on_stats`](CornerSink::on_stats) ticks are
/// chunking-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LiveStats {
    /// Events fed in so far (pre-STCF).
    pub events_in: u64,
    /// Events surviving STCF so far.
    pub events_signal: u64,
    /// Corners tagged so far.
    pub corners_total: u64,
    /// DVFS voltage switches so far.
    pub dvfs_switches: u64,
    /// Harris LUT refreshes consumed so far.
    pub lut_refreshes: u64,
    /// Timestamp of the most recent input event (µs; 0 before the first).
    pub last_t_us: u64,
    /// Degradation level reported by the session's governor (0 = nominal;
    /// see `serve::degrade::DegradationPolicy`). Always 0 without one.
    pub degrade_level: u64,
    /// Commanded backend supply voltage (mV), seeded from the starting
    /// operating point and tracking DVFS and governor retargets.
    /// Voltage-less backends (golden, sharded) ignore the commands but
    /// the commanded value is still reported.
    pub vdd_mv: u64,
}

/// Observer of a pipeline run's results (see the [module docs](self)
/// for the callback contract). Only [`on_corner`](CornerSink::on_corner)
/// is required; the other callbacks default to no-ops.
pub trait CornerSink {
    /// A signal event's score reached the corner threshold.
    fn on_corner(&mut self, corner: &Corner) -> Result<()>;

    /// A signal event was scored (fires for *every* signal event, corner
    /// or not, immediately before any `on_corner` for the same event).
    fn on_score(&mut self, seq: u64, ev: &Event, score: f64) -> Result<()> {
        let _ = (seq, ev, score);
        Ok(())
    }

    /// Periodic live counters, every
    /// [`stats_interval_events`](super::PipelineConfig::stats_interval_events)
    /// input events (never fires when that is `None`).
    fn on_stats(&mut self, stats: &LiveStats) -> Result<()> {
        let _ = stats;
        Ok(())
    }

    /// A source chunk was fully processed (batching sinks flush here).
    fn on_chunk_end(&mut self, stats: &LiveStats) -> Result<()> {
        let _ = stats;
        Ok(())
    }
}

impl<K: CornerSink + ?Sized> CornerSink for &mut K {
    fn on_corner(&mut self, corner: &Corner) -> Result<()> {
        (**self).on_corner(corner)
    }
    fn on_score(&mut self, seq: u64, ev: &Event, score: f64) -> Result<()> {
        (**self).on_score(seq, ev, score)
    }
    fn on_stats(&mut self, stats: &LiveStats) -> Result<()> {
        (**self).on_stats(stats)
    }
    fn on_chunk_end(&mut self, stats: &LiveStats) -> Result<()> {
        (**self).on_chunk_end(stats)
    }
}

impl<K: CornerSink + ?Sized> CornerSink for Box<K> {
    fn on_corner(&mut self, corner: &Corner) -> Result<()> {
        (**self).on_corner(corner)
    }
    fn on_score(&mut self, seq: u64, ev: &Event, score: f64) -> Result<()> {
        (**self).on_score(seq, ev, score)
    }
    fn on_stats(&mut self, stats: &LiveStats) -> Result<()> {
        (**self).on_stats(stats)
    }
    fn on_chunk_end(&mut self, stats: &LiveStats) -> Result<()> {
        (**self).on_chunk_end(stats)
    }
}

/// Discards everything. What [`run_stream`](super::Pipeline::run_stream)
/// drives when no external consumer is attached.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl CornerSink for NullSink {
    fn on_corner(&mut self, _corner: &Corner) -> Result<()> {
        Ok(())
    }
}

/// Records the full per-event result vectors — the sink behind
/// [`RunReport`](super::RunReport)'s `signal_events` / `scores` /
/// `corners` fields. Memory is O(stream); for unbounded streams attach a
/// bounded sink instead.
#[derive(Debug, Clone, Default)]
pub struct RecordingSink {
    /// Every signal event, in order (index-aligned with `scores`).
    pub signal_events: Vec<Event>,
    /// Per-signal-event corner score.
    pub scores: Vec<f64>,
    /// `seq` of each tagged corner (indices into `signal_events`).
    pub corners: Vec<usize>,
}

impl RecordingSink {
    /// A recorder with per-event vectors preallocated for `n` events.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            signal_events: Vec::with_capacity(n),
            scores: Vec::with_capacity(n),
            corners: Vec::new(),
        }
    }
}

impl CornerSink for RecordingSink {
    fn on_corner(&mut self, corner: &Corner) -> Result<()> {
        self.corners.push(corner.seq as usize);
        Ok(())
    }

    fn on_score(&mut self, _seq: u64, ev: &Event, score: f64) -> Result<()> {
        self.signal_events.push(*ev);
        self.scores.push(score);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_sink_records_in_order() {
        let mut rec = RecordingSink::with_capacity(4);
        let e0 = Event::on(1, 2, 10);
        let e1 = Event::on(3, 4, 20);
        rec.on_score(0, &e0, 0.1).unwrap();
        rec.on_score(1, &e1, 0.9).unwrap();
        rec.on_corner(&Corner { seq: 1, ev: e1, score: 0.9 }).unwrap();
        assert_eq!(rec.signal_events, vec![e0, e1]);
        assert_eq!(rec.scores, vec![0.1, 0.9]);
        assert_eq!(rec.corners, vec![1]);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let mut sink = NullSink;
        let ev = Event::on(0, 0, 0);
        sink.on_score(0, &ev, 1.0).unwrap();
        sink.on_corner(&Corner { seq: 0, ev, score: 1.0 }).unwrap();
        sink.on_stats(&LiveStats::default()).unwrap();
        sink.on_chunk_end(&LiveStats::default()).unwrap();
    }

    #[test]
    fn blanket_impls_forward_every_callback() {
        // boxed and borrowed sinks must forward on_score to the inner
        // recorder, not swallow it through the trait's provided default
        let mut rec = RecordingSink::default();
        let ev = Event::on(5, 6, 7);
        {
            let mut boxed: Box<&mut RecordingSink> = Box::new(&mut rec);
            boxed.on_score(0, &ev, 0.5).unwrap();
        }
        {
            let mut inner: &mut RecordingSink = &mut rec;
            let by_ref: &mut &mut RecordingSink = &mut inner;
            by_ref.on_score(1, &ev, 0.6).unwrap();
        }
        {
            let dynamic: &mut dyn CornerSink = &mut rec;
            dynamic.on_score(2, &ev, 0.7).unwrap();
        }
        assert_eq!(rec.scores, vec![0.5, 0.6, 0.7]);
    }
}
