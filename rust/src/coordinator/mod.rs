//! The system coordinator: wires STCF -> TOS backend -> DVFS -> FBF Harris
//! -> corner tagging into the full pipeline of paper Fig. 2.
//!
//! [`Pipeline`] is generic over the TOS backend (`B:`
//! [`TosBackend`]) and the per-event detector (`D:` [`EventScorer`]), so
//! every cross-implementation experiment of the paper — NM-TOS macro vs.
//! conventional digital datapath vs. pure software (Figs. 1, 9, 10) and
//! luvHarris-LUT vs. eHarris/eFAST/ARC* (Sec. II-B) — runs through one
//! code path. [`Pipeline::from_config`] builds any backend x detector
//! combination chosen at runtime (the CLI's `--backend` / `--detector`).
//!
//! Two execution modes:
//!
//! * **sync** — the Harris LUT is recomputed inline every
//!   `lut_refresh_events` signal events.  Deterministic; used by the PR /
//!   BER experiments so AUC comparisons are seed-stable.
//! * **async** — a worker thread owns its own PJRT engine and recomputes
//!   the LUT "as fast as possible" from TOS snapshots, exactly the
//!   luvHarris decoupling: the event path never blocks on the frame path;
//!   snapshots are dropped (not queued) when the worker is busy.
//!
//! Ingestion is streaming-first: [`Pipeline::run_stream`] consumes any
//! [`EventSource`] chunk by chunk with all pipeline state (DVFS windows,
//! STCF history, LUT-refresh counters, batch-flush buffers) carried
//! across chunk boundaries, so peak event-buffer memory is O(chunk) and
//! the result is bit-identical to the load-all [`Pipeline::run`] wrapper
//! at any chunk size. For unbounded runs, `record_per_event = false`
//! keeps the [`RunReport`] to O(1) counters.
//!
//! Results are streaming-first too: [`Pipeline::run_stream_with`] drives
//! a [`CornerSink`] observer at event rate — every corner, every score,
//! and periodic [`LiveStats`](sink::LiveStats) flow out while the run is
//! in flight. [`RunReport`] recording is itself just the built-in
//! [`RecordingSink`](sink::RecordingSink); the serving layer's wire
//! streaming is another sink (`serve::wire::WireSink`). See the
//! [`sink`] module for the callback contract.
//!
//! SAE-based detectors don't consume LUTs, so for them the FBF stage (and
//! the PJRT engine) is skipped entirely. Python never appears on any path
//! — the Harris graph was AOT-lowered at build time and runs through the
//! PJRT CPU client.

pub mod lut_worker;
pub mod sink;

use std::path::PathBuf;
use std::str::FromStr;
use std::time::Instant;

use anyhow::{Context, Result};

pub use lut_worker::LutWorker;
pub use sink::{Corner, CornerSink, LiveStats, NullSink, RecordingSink};

use crate::conventional::ConventionalTos;
use crate::detectors::arc::Arc as ArcDetector;
use crate::detectors::eharris::EHarris;
use crate::detectors::fast::EFast;
use crate::detectors::harris::HarrisDetector;
use crate::detectors::EventScorer;
use crate::dvfs::{DvfsConfig, DvfsController};
use crate::events::source::{DEFAULT_CHUNK_EVENTS, EventSource, SliceSource};
use crate::events::{Event, Resolution};
use crate::nmc::{NmcConfig, NmcMacro};
use crate::runtime::{default_artifact_dir, HarrisEngine, Manifest};
use crate::stcf::{Stcf, StcfConfig};
use crate::tos::{BackendStats, ShardedTos, TosBackend, TosConfig, TosSurface};

/// Which TOS implementation the pipeline drives (`--backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The paper's near-memory macro (phase-level timing/energy/BER model).
    Nmc,
    /// Conventional digital datapath baseline (golden surface + cost model).
    Conventional,
    /// Golden single-threaded software model (no cost model).
    Golden,
    /// Row-band sharded parallel software model.
    Sharded,
}

impl BackendKind {
    /// All variants, in CLI order.
    pub const ALL: [BackendKind; 4] =
        [BackendKind::Nmc, BackendKind::Conventional, BackendKind::Golden, BackendKind::Sharded];

    /// CLI spelling.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Nmc => "nmc",
            BackendKind::Conventional => "conventional",
            BackendKind::Golden => "golden",
            BackendKind::Sharded => "sharded",
        }
    }
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "nmc" => Ok(BackendKind::Nmc),
            "conventional" | "conv" => Ok(BackendKind::Conventional),
            "golden" => Ok(BackendKind::Golden),
            "sharded" => Ok(BackendKind::Sharded),
            other => anyhow::bail!("unknown backend `{other}` (nmc|conventional|golden|sharded)"),
        }
    }
}

/// Which per-event corner detector scores events (`--detector`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetectorKind {
    /// luvHarris-style LUT lookup (needs the FBF Harris engine).
    Harris,
    /// Vasco et al. per-event full Harris on a binary surface.
    EHarris,
    /// Mueggler et al. eFAST segment test on the SAE.
    Fast,
    /// Alzugaray & Chli ARC* arc-angle test on the SAE.
    Arc,
}

impl DetectorKind {
    /// All variants, in CLI order.
    pub const ALL: [DetectorKind; 4] =
        [DetectorKind::Harris, DetectorKind::EHarris, DetectorKind::Fast, DetectorKind::Arc];

    /// CLI spelling.
    pub fn label(self) -> &'static str {
        match self {
            DetectorKind::Harris => "harris",
            DetectorKind::EHarris => "eharris",
            DetectorKind::Fast => "fast",
            DetectorKind::Arc => "arc",
        }
    }
}

impl FromStr for DetectorKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "harris" | "luvharris" => Ok(DetectorKind::Harris),
            "eharris" => Ok(DetectorKind::EHarris),
            "fast" | "efast" => Ok(DetectorKind::Fast),
            "arc" => Ok(DetectorKind::Arc),
            other => anyhow::bail!("unknown detector `{other}` (harris|eharris|fast|arc)"),
        }
    }
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Sensor geometry (must match the artifact).
    pub res: Resolution,
    /// Artifact name in `artifacts/meta.json` (e.g. `davis240`).
    pub artifact: String,
    /// Artifact directory override (`None` = auto-discover).
    pub artifact_dir: Option<PathBuf>,
    /// TOS algorithm parameters.
    pub tos: TosConfig,
    /// TOS backend built by [`Pipeline::from_config`].
    pub backend: BackendKind,
    /// Detector built by [`Pipeline::from_config`].
    pub detector: DetectorKind,
    /// Worker shards for the sharded software backend.
    pub shards: usize,
    /// Use the pipelined NMC schedule.
    pub pipelined: bool,
    /// Inject Monte-Carlo read errors (BER tracks the DVFS voltage).
    pub inject_errors: bool,
    /// Error-injection seed.
    pub seed: u64,
    /// STCF denoising (`None` = bypass).
    pub stcf: Option<StcfConfig>,
    /// DVFS (`None` = pinned at `fixed_vdd`).
    pub dvfs: Option<DvfsConfig>,
    /// Supply voltage when DVFS is off.
    pub fixed_vdd: f64,
    /// Sync mode: recompute the Harris LUT every N signal events.
    pub lut_refresh_events: usize,
    /// eHarris binary-surface window (events kept); the paper's reference
    /// implementation uses 2000 (`--eharris-window`).
    pub eharris_window: usize,
    /// Use the async (threaded) LUT worker instead of inline refresh.
    pub async_refresh: bool,
    /// Engine-less FBF fallback: when no PJRT engine is available (or
    /// artifacts are absent), compute the Harris response map with the
    /// pure-Rust software stencil ([`crate::detectors::harris::response_map_into`])
    /// on the sync refresh cadence instead of leaving the LUT at zero.
    /// Slower than the AOT engine — meant for harnesses (the Vdd sweep)
    /// and CI, not the perf path.
    pub software_fbf: bool,
    /// Score threshold above which an event is tagged a corner.
    pub corner_threshold: f64,
    /// Record per-event data (`signal_events`, `scores`, `corners`) in
    /// the [`RunReport`]. Disable for unbounded streamed runs so the
    /// report holds only O(1) counters instead of O(stream) vectors.
    pub record_per_event: bool,
    /// Emit [`CornerSink::on_stats`] every this many *input* events
    /// (pre-STCF; `None` = never). The cadence is counted in events, not
    /// wall time, so stats emission is deterministic and independent of
    /// source chunking. `Some(0)` behaves like `Some(1)`.
    pub stats_interval_events: Option<u64>,
}

impl PipelineConfig {
    /// DAVIS240 defaults matching the paper's system.
    pub fn davis240() -> Self {
        Self {
            res: Resolution::DAVIS240,
            artifact: "davis240".into(),
            artifact_dir: None,
            tos: TosConfig::default(),
            backend: BackendKind::Nmc,
            detector: DetectorKind::Harris,
            shards: 4,
            pipelined: true,
            inject_errors: false,
            seed: 0,
            stcf: Some(StcfConfig::default()),
            dvfs: Some(DvfsConfig::default()),
            fixed_vdd: 1.2,
            lut_refresh_events: 2_000,
            eharris_window: 2_000,
            async_refresh: false,
            software_fbf: false,
            corner_threshold: 0.55,
            record_per_event: true,
            stats_interval_events: None,
        }
    }

    /// Small config for tests.
    pub fn test64() -> Self {
        Self {
            res: Resolution::TEST64,
            artifact: "test64".into(),
            ..Self::davis240()
        }
    }
}

/// Everything a run produces.
///
/// The per-event vectors (`signal_events`, `scores`, `corners`) are
/// populated only when [`PipelineConfig::record_per_event`] is on (the
/// default) — internally they are accumulated by a [`RecordingSink`]
/// driven through the same [`CornerSink`] callbacks as any caller sink;
/// counters (`events_in`, `events_signal`, `corners_total`) are always
/// exact, so unbounded streamed runs stay O(1) memory here. For results
/// *during* the run instead of after it, attach a sink via
/// [`Pipeline::run_stream_with`].
#[derive(Debug, Clone)]
pub struct RunReport {
    /// TOS backend that ran ([`TosBackend::name`]).
    pub backend_name: &'static str,
    /// Detector that scored events ([`EventScorer::name`]).
    pub detector_name: &'static str,
    /// Events fed in.
    pub events_in: usize,
    /// Events surviving STCF.
    pub events_signal: usize,
    /// The surviving events, in order (index-aligned with `scores`);
    /// empty when per-event recording is off.
    pub signal_events: Vec<Event>,
    /// Per-signal-event corner score; empty when recording is off.
    pub scores: Vec<f64>,
    /// Indices (into `signal_events`) tagged as corners; empty when
    /// recording is off.
    pub corners: Vec<usize>,
    /// Total corner tags, counted regardless of recording mode.
    pub corners_total: u64,
    /// Unified backend telemetry (latency/energy totals, bit flips).
    pub backend: BackendStats,
    /// Voltage switches performed by DVFS.
    pub dvfs_switches: u64,
    /// Harris LUT refreshes applied to the detector (sync and async mode
    /// count the same thing: LUTs the event path actually consumed).
    pub lut_refreshes: u64,
    /// Wall-clock seconds of the whole run (host side).
    pub wall_s: f64,
    /// Final TOS snapshot (for rendering).
    pub final_tos: Vec<u8>,
    /// Final LUT snapshot (empty for non-LUT detectors).
    pub final_lut: Vec<f32>,
}

impl RunReport {
    /// `(score, label)` pairs against ground truth, for PR curves.
    pub fn scored_events(
        &self,
        gt: &crate::datasets::gt::GroundTruth,
        radius_px: f32,
    ) -> Vec<(f64, bool)> {
        let labels = gt.label_events(&self.signal_events, radius_px);
        self.scores.iter().copied().zip(labels).collect()
    }
}

/// A load governor polled at source-chunk boundaries: it sees the live
/// counters and may retarget the backend supply voltage — the hook the
/// serving layer's adaptive degradation
/// (`serve::degrade::DegradationPolicy`) plugs into.
///
/// Polling happens after [`CornerSink::on_chunk_end`], so a governed
/// run's sink output up to any boundary is identical to an ungoverned
/// one with the same voltage trajectory. Plain runs have no governor.
pub trait Governor {
    /// Called after each source chunk. Returning `Some(vdd)` retargets
    /// the backend to that supply voltage (pending batches are flushed
    /// first, exactly like a DVFS switch).
    fn on_chunk_end(&mut self, stats: &LiveStats) -> Option<f64>;

    /// Current degradation level (0 = nominal), surfaced on
    /// [`LiveStats::degrade_level`].
    fn level(&self) -> u32 {
        0
    }
}

/// The assembled pipeline, generic over backend x detector.
pub struct Pipeline<B: TosBackend = NmcMacro, D: EventScorer = HarrisDetector> {
    cfg: PipelineConfig,
    engine: Option<HarrisEngine>,
    backend: B,
    stcf: Option<Stcf>,
    dvfs: Option<DvfsController>,
    detector: D,
    /// Chunk-boundary load governor (`None` for plain runs).
    governor: Option<Box<dyn Governor>>,
    /// Reused FBF buffers (no per-refresh allocation; poolable across
    /// serving sessions via [`Pipeline::into_parts`]).
    scratch: PipelineScratch,
}

/// Reusable per-pipeline scratch buffers for the FBF Harris path: the
/// u8 -> f32 conversion frame and the sync-mode LUT output buffer.
///
/// Both reach frame size once and are then reused for every refresh. A
/// serving host recycles them across sessions (together with the engine)
/// through [`Pipeline::into_parts`] /
/// [`Pipeline::with_parts_and_scratch`], so back-to-back streams at the
/// same resolution allocate nothing per session either.
#[derive(Debug, Default)]
pub struct PipelineScratch {
    /// u8 TOS -> f32 frame conversion buffer.
    frame: Vec<f32>,
    /// Sync-mode LUT output buffer ([`HarrisEngine::compute_into`]).
    lut: Vec<f32>,
}

/// A pipeline whose backend and detector were chosen at runtime.
pub type DynPipeline = Pipeline<Box<dyn TosBackend>, Box<dyn EventScorer>>;

/// Upper bound on events buffered before a forced backend flush.
///
/// The run loops hand the backend *batches* of signal events instead of
/// one event at a time: nothing observes the surface between snapshot
/// points (LUT refresh / DVFS retarget / final report), so deferring the
/// updates to those boundaries is behavior-preserving while letting
/// batch-optimized backends ([`ShardedTos`]) run their parallel path.
const BACKEND_BATCH_MAX: usize = 4096;

impl<B: TosBackend, D: EventScorer> std::fmt::Debug for Pipeline<B, D> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pipeline")
            .field("cfg", &self.cfg)
            .field("backend", &self.backend.name())
            .field("detector", &self.detector.name())
            .finish()
    }
}

/// Load and shape-check the AOT Harris engine for a config.
pub fn load_engine(cfg: &PipelineConfig) -> Result<HarrisEngine> {
    let dir = cfg.artifact_dir.clone().unwrap_or_else(default_artifact_dir);
    let manifest = Manifest::load(&dir)?;
    let engine = HarrisEngine::load(&manifest, &cfg.artifact)?;
    anyhow::ensure!(
        engine.height == cfg.res.height as usize && engine.width == cfg.res.width as usize,
        "artifact {}x{} does not match sensor {}x{}",
        engine.height,
        engine.width,
        cfg.res.height,
        cfg.res.width
    );
    Ok(engine)
}

/// The NMC macro configuration a pipeline config implies.
fn nmc_config(cfg: &PipelineConfig) -> NmcConfig {
    NmcConfig {
        tos: cfg.tos,
        pipelined: cfg.pipelined,
        vdd: cfg.fixed_vdd,
        inject_errors: cfg.inject_errors,
        seed: cfg.seed,
    }
}

/// Flush buffered signal events into the backend (batch path).
#[inline]
fn flush_pending<B: TosBackend>(backend: &mut B, pending: &mut Vec<Event>) {
    if !pending.is_empty() {
        backend.process_batch(pending);
        pending.clear();
    }
}

/// Millivolt rendering of a supply voltage for [`LiveStats::vdd_mv`].
#[inline]
fn to_mv(vdd: f64) -> u64 {
    (vdd * 1000.0).round() as u64
}

/// Build the backend a config asks for (`cfg.backend`).
pub fn make_backend(cfg: &PipelineConfig) -> Result<Box<dyn TosBackend>> {
    Ok(match cfg.backend {
        BackendKind::Nmc => Box::new(NmcMacro::new(cfg.res, nmc_config(cfg))?),
        BackendKind::Conventional => {
            Box::new(ConventionalTos::new(cfg.res, cfg.tos, cfg.fixed_vdd)?)
        }
        BackendKind::Golden => Box::new(TosSurface::new(cfg.res, cfg.tos)?),
        BackendKind::Sharded => Box::new(ShardedTos::new(cfg.res, cfg.tos, cfg.shards)?),
    })
}

/// Build the detector a config asks for (`cfg.detector`).
pub fn make_detector(cfg: &PipelineConfig) -> Box<dyn EventScorer> {
    match cfg.detector {
        DetectorKind::Harris => Box::new(HarrisDetector::new(cfg.res)),
        DetectorKind::EHarris => {
            Box::new(EHarris::with_params(cfg.res, cfg.eharris_window, EHarris::DEFAULT_K))
        }
        DetectorKind::Fast => Box::new(EFast::new(cfg.res)),
        DetectorKind::Arc => Box::new(ArcDetector::new(cfg.res)),
    }
}

impl Pipeline<NmcMacro, HarrisDetector> {
    /// Build the paper's default pipeline (NMC macro + luvHarris LUT
    /// detector) with the AOT Harris engine loaded and compiled.
    pub fn new(cfg: PipelineConfig) -> Result<Pipeline> {
        let engine = load_engine(&cfg)?;
        Self::new_with_engine(cfg, Some(engine))
    }

    /// Build the default pipeline without a PJRT engine (LUT stays zero
    /// unless refreshed externally) — used by timing/energy-only
    /// experiments and tests that don't need corner scores.
    pub fn new_without_engine(cfg: PipelineConfig) -> Result<Pipeline> {
        Self::new_with_engine(cfg, None)
    }

    fn new_with_engine(cfg: PipelineConfig, engine: Option<HarrisEngine>) -> Result<Pipeline> {
        let backend = NmcMacro::new(cfg.res, nmc_config(&cfg))?;
        let detector = HarrisDetector::new(cfg.res);
        Pipeline::with_parts(cfg, backend, detector, engine)
    }

    /// Build the backend x detector combination the config names
    /// (`cfg.backend` / `cfg.detector`). The PJRT engine is loaded only
    /// for LUT-consuming detectors; SAE detectors run fully headless.
    pub fn from_config(cfg: PipelineConfig) -> Result<DynPipeline> {
        let backend = make_backend(&cfg)?;
        let detector = make_detector(&cfg);
        let engine = if detector.wants_lut() { Some(load_engine(&cfg)?) } else { None };
        DynPipeline::with_parts(cfg, backend, detector, engine)
    }

    /// Like [`Pipeline::from_config`] but never loads the PJRT engine
    /// (LUT detectors score zero) — for engine-less tests and harnesses.
    pub fn from_config_without_engine(cfg: PipelineConfig) -> Result<DynPipeline> {
        let backend = make_backend(&cfg)?;
        let detector = make_detector(&cfg);
        DynPipeline::with_parts(cfg, backend, detector, None)
    }
}

impl<B: TosBackend, D: EventScorer> Pipeline<B, D> {
    /// Assemble a pipeline from explicit parts (any backend x detector).
    pub fn with_parts(
        cfg: PipelineConfig,
        backend: B,
        detector: D,
        engine: Option<HarrisEngine>,
    ) -> Result<Self> {
        Self::with_parts_and_scratch(cfg, backend, detector, engine, PipelineScratch::default())
    }

    /// Like [`Pipeline::with_parts`] but reusing scratch buffers from a
    /// previous session (see [`Pipeline::into_parts`]): a serving host
    /// recycling engine + scratch builds each session allocation-free.
    pub fn with_parts_and_scratch(
        cfg: PipelineConfig,
        backend: B,
        detector: D,
        engine: Option<HarrisEngine>,
        mut scratch: PipelineScratch,
    ) -> Result<Self> {
        anyhow::ensure!(
            backend.resolution() == cfg.res,
            "backend {}x{} does not match configured sensor {}x{}",
            backend.resolution().width,
            backend.resolution().height,
            cfg.res.width,
            cfg.res.height
        );
        let stcf = cfg.stcf.map(|c| Stcf::new(cfg.res, c));
        let dvfs = cfg.dvfs.map(DvfsController::new);
        scratch.frame.clear();
        scratch.frame.resize(cfg.res.pixels(), 0.0);
        Ok(Pipeline { cfg, engine, backend, stcf, dvfs, detector, governor: None, scratch })
    }

    /// Install a load [`Governor`], polled at source-chunk boundaries.
    pub fn set_governor(&mut self, governor: Box<dyn Governor>) {
        self.governor = Some(governor);
    }

    /// Tear the pipeline down into its poolable parts: the (expensive)
    /// compiled Harris engine and the FBF scratch buffers. The serving
    /// layer returns both to its per-resolution pool when a session ends.
    pub fn into_parts(self) -> (Option<HarrisEngine>, PipelineScratch) {
        (self.engine, self.scratch)
    }

    /// Pipeline configuration.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// The TOS backend (experiments poke at cost models / voltages).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// The detector.
    pub fn detector(&self) -> &D {
        &self.detector
    }

    /// Run the pipeline over a fully materialized, time-sorted event
    /// stream. Thin wrapper over [`Pipeline::run_stream`] (the slice is
    /// served in default-size chunks, which is bit-identical to any
    /// other chunking) — kept for tests, experiments and every caller
    /// that already holds the recording in memory.
    pub fn run(&mut self, events: &[Event]) -> Result<RunReport> {
        self.run_stream(&mut SliceSource::new(events, DEFAULT_CHUNK_EVENTS))
    }

    /// [`Pipeline::run`] with a [`CornerSink`] attached: corners, scores
    /// and live stats flow to `sink` while the slice is processed.
    pub fn run_with<K: CornerSink + ?Sized>(
        &mut self,
        events: &[Event],
        sink: &mut K,
    ) -> Result<RunReport> {
        self.run_stream_with(&mut SliceSource::new(events, DEFAULT_CHUNK_EVENTS), sink)
    }

    /// Run the pipeline over a streaming [`EventSource`], keeping peak
    /// event-buffer memory O(chunk): DVFS, STCF, LUT-refresh and
    /// batch-flush state all carry across chunk boundaries, so the
    /// result is bit-identical to [`Pipeline::run`] on the concatenated
    /// stream at any chunk size.
    pub fn run_stream<S: EventSource + ?Sized>(&mut self, source: &mut S) -> Result<RunReport> {
        self.run_stream_with(source, &mut NullSink)
    }

    /// Run a streaming source with a [`CornerSink`] observing results at
    /// event rate (see [`sink`] for the callback contract). The sink is
    /// *additive*: the returned [`RunReport`] is identical to a
    /// [`Pipeline::run_stream`] of the same source — per-event vectors
    /// still governed by [`PipelineConfig::record_per_event`] — and a
    /// sink error aborts the run with that error (the backpressure
    /// contract).
    pub fn run_stream_with<S, K>(&mut self, source: &mut S, sink: &mut K) -> Result<RunReport>
    where
        S: EventSource + ?Sized,
        K: CornerSink + ?Sized,
    {
        // Async mode only applies when there is an FBF stage to decouple:
        // a LUT-consuming detector AND an engine (engine-less pipelines
        // stay headless — the worker must not load artifacts behind the
        // caller's back).
        if self.cfg.async_refresh && self.detector.wants_lut() && self.engine.is_some() {
            self.run_stream_async(source, sink)
        } else {
            self.run_stream_sync(source, sink)
        }
    }

    /// Synchronous mode: inline LUT refresh every `lut_refresh_events`.
    fn run_stream_sync<S, K>(&mut self, source: &mut S, sink: &mut K) -> Result<RunReport>
    where
        S: EventSource + ?Sized,
        K: CornerSink + ?Sized,
    {
        let start = Instant::now();
        let mut st = StreamState::new(&self.cfg, reserve_hint(source));
        st.vdd_mv = to_mv(self.dvfs.as_ref().map_or(self.cfg.fixed_vdd, |c| c.operating_point().vdd));
        // without an FBF stage there is no refresh boundary — don't cap
        // the backend batches on a no-op schedule
        let refresh_enabled =
            (self.engine.is_some() || self.cfg.software_fbf) && self.detector.wants_lut();
        let batching = self.backend.prefers_batching();
        let mut chunk: Vec<Event> = Vec::new();

        loop {
            chunk.clear();
            if source.next_chunk(&mut chunk)? == 0 {
                break;
            }
            for ev in &chunk {
                st.events_in += 1;
                st.last_t_us = ev.t;
                // --- DVFS monitors the *raw* event rate (paper Fig. 2) ---
                if let Some(ctrl) = &mut self.dvfs {
                    if let Some(op) = ctrl.on_event(ev.t) {
                        // settle pending updates at the old voltage first
                        flush_pending(&mut self.backend, &mut st.pending);
                        self.backend.set_vdd(op.vdd);
                        st.dvfs_switches += 1;
                        st.vdd_mv = to_mv(op.vdd);
                    }
                }
                // --- STCF denoise ----------------------------------------
                let signal = match &mut self.stcf {
                    Some(f) => f.check(ev),
                    None => true,
                };
                if signal {
                    // --- TOS update (the hot path): batch-parallel
                    // backends get events buffered and flushed at snapshot
                    // boundaries; per-event backends are fed directly -----
                    if batching {
                        st.pending.push(*ev);
                        if st.pending.len() >= BACKEND_BATCH_MAX {
                            flush_pending(&mut self.backend, &mut st.pending);
                        }
                    } else {
                        self.backend.process(ev);
                    }
                    // --- FBF Harris refresh (inline in sync mode) --------
                    st.since_refresh += 1;
                    if refresh_enabled && st.since_refresh >= self.cfg.lut_refresh_events {
                        st.since_refresh = 0;
                        flush_pending(&mut self.backend, &mut st.pending);
                        if self.refresh_lut()? {
                            st.lut_refreshes += 1;
                        }
                    }
                    // --- tag ---------------------------------------------
                    let score = self.detector.score(ev);
                    st.tag(ev, score, self.cfg.corner_threshold, sink)?;
                }
                st.stats_tick(sink)?;
            }
            sink.on_chunk_end(&st.live_stats())?;
            // --- chunk-boundary load governor (serving layer) ------------
            if let Some(gov) = self.governor.as_deref_mut() {
                if let Some(vdd) = gov.on_chunk_end(&st.live_stats()) {
                    // settle pending updates at the old voltage first,
                    // exactly like a DVFS switch
                    flush_pending(&mut self.backend, &mut st.pending);
                    self.backend.set_vdd(vdd);
                    st.vdd_mv = to_mv(vdd);
                }
                st.degrade_level = gov.level() as u64;
            }
        }
        flush_pending(&mut self.backend, &mut st.pending);

        Ok(self.report(st, start.elapsed().as_secs_f64()))
    }

    /// Asynchronous mode: the LUT worker owns its own engine and consumes
    /// TOS snapshots through a depth-1 channel; busy -> snapshot dropped.
    fn run_stream_async<S, K>(&mut self, source: &mut S, sink: &mut K) -> Result<RunReport>
    where
        S: EventSource + ?Sized,
        K: CornerSink + ?Sized,
    {
        let start = Instant::now();
        let dir = self.cfg.artifact_dir.clone().unwrap_or_else(default_artifact_dir);
        let artifact = self.cfg.artifact.clone();

        // The double-buffered snapshot / LUT / recycle channel protocol
        // lives in [`LutWorker`] (loom-model checked there); the worker
        // loads its own engine so the event path shares nothing with the
        // frame path.
        let mut worker = LutWorker::spawn(move || {
            let manifest = Manifest::load(&dir)?;
            let mut engine = HarrisEngine::load(&manifest, &artifact)?;
            Ok(move |tos: &[u8], lut: &mut Vec<f32>| engine.compute_u8_into(tos, lut))
        });

        let mut st = StreamState::new(&self.cfg, reserve_hint(source));
        st.vdd_mv = to_mv(self.dvfs.as_ref().map_or(self.cfg.fixed_vdd, |c| c.operating_point().vdd));
        let mut since_snapshot = 0usize;
        let batching = self.backend.prefers_batching();
        // offer a snapshot at least this often (events); the worker decides
        // the actual refresh rate by how fast it drains the channel.
        let offer_every = (self.cfg.lut_refresh_events / 4).max(1);
        let mut chunk: Vec<Event> = Vec::new();

        loop {
            chunk.clear();
            if source.next_chunk(&mut chunk)? == 0 {
                break;
            }
            for ev in &chunk {
                st.events_in += 1;
                st.last_t_us = ev.t;
                if let Some(ctrl) = &mut self.dvfs {
                    if let Some(op) = ctrl.on_event(ev.t) {
                        flush_pending(&mut self.backend, &mut st.pending);
                        self.backend.set_vdd(op.vdd);
                        st.dvfs_switches += 1;
                        st.vdd_mv = to_mv(op.vdd);
                    }
                }
                let signal = match &mut self.stcf {
                    Some(f) => f.check(ev),
                    None => true,
                };
                if signal {
                    if batching {
                        st.pending.push(*ev);
                        if st.pending.len() >= BACKEND_BATCH_MAX {
                            flush_pending(&mut self.backend, &mut st.pending);
                        }
                    } else {
                        self.backend.process(ev);
                    }

                    // non-blocking LUT pickup; `lut_refreshes` counts LUTs
                    // the detector actually consumed, not what the worker
                    // computed (a final in-flight LUT may arrive after the
                    // last score)
                    st.lut_refreshes += worker.poll_luts(|lut| self.detector.refresh_lut(lut));
                    since_snapshot += 1;
                    if since_snapshot >= offer_every {
                        since_snapshot = 0;
                        flush_pending(&mut self.backend, &mut st.pending);
                        // a busy worker drops the offer (luvHarris "as fast
                        // as possible" semantics, no backpressure on events)
                        worker.offer_snapshot(|buf| self.backend.snapshot_into(buf));
                    }

                    let score = self.detector.score(ev);
                    st.tag(ev, score, self.cfg.corner_threshold, sink)?;
                }
                st.stats_tick(sink)?;
            }
            sink.on_chunk_end(&st.live_stats())?;
            // --- chunk-boundary load governor (serving layer) ------------
            if let Some(gov) = self.governor.as_deref_mut() {
                if let Some(vdd) = gov.on_chunk_end(&st.live_stats()) {
                    // settle pending updates at the old voltage first,
                    // exactly like a DVFS switch
                    flush_pending(&mut self.backend, &mut st.pending);
                    self.backend.set_vdd(vdd);
                    st.vdd_mv = to_mv(vdd);
                }
                st.degrade_level = gov.level() as u64;
            }
        }
        flush_pending(&mut self.backend, &mut st.pending);

        // shut the worker down and drain every remaining LUT into the
        // final detector state, so each counted refresh was actually
        // applied
        let (tail, computed) = worker.finish(|lut| self.detector.refresh_lut(lut))?;
        st.lut_refreshes += tail;
        debug_assert!(st.lut_refreshes <= computed);

        Ok(self.report(st, start.elapsed().as_secs_f64()))
    }

    /// Inline LUT refresh (sync mode). Returns whether a refresh ran.
    fn refresh_lut(&mut self) -> Result<bool> {
        if !self.detector.wants_lut() {
            return Ok(false);
        }
        match &mut self.engine {
            Some(engine) => {
                // borrow the surface straight into the reusable f32 frame —
                // the old path cloned a full u8 frame per refresh first
                for (f, &v) in self.scratch.frame.iter_mut().zip(self.backend.tos_view()) {
                    *f = v as f32;
                }
                // the response map lands in the reusable LUT scratch: the
                // whole sync refresh is allocation-free after the first
                // iteration
                engine
                    .compute_into(&self.scratch.frame, &mut self.scratch.lut)
                    .context("FBF Harris refresh")?;
                self.detector.refresh_lut(&self.scratch.lut);
                Ok(true)
            }
            None if self.cfg.software_fbf => {
                // engine-less fallback: pure-Rust Harris stencil (the Vdd
                // sweep / CI path — see [`PipelineConfig::software_fbf`])
                crate::detectors::harris::response_map_into(
                    self.backend.tos_view(),
                    self.cfg.res,
                    &mut self.scratch.lut,
                );
                self.detector.refresh_lut(&self.scratch.lut);
                Ok(true)
            }
            None => Ok(false), // engine-less pipelines skip the FBF stage
        }
    }

    fn report(&self, st: StreamState, wall_s: f64) -> RunReport {
        // recording was just another sink: its vectors become the report's
        let rec = st.recorder.unwrap_or_default();
        RunReport {
            backend_name: self.backend.name(),
            detector_name: self.detector.name(),
            events_in: st.events_in,
            events_signal: st.events_signal,
            signal_events: rec.signal_events,
            scores: rec.scores,
            corners: rec.corners,
            corners_total: st.corners_total,
            backend: self.backend.stats(),
            dvfs_switches: st.dvfs_switches,
            lut_refreshes: st.lut_refreshes,
            wall_s,
            final_tos: self.backend.snapshot_u8(),
            final_lut: self.detector.lut().map(<[f32]>::to_vec).unwrap_or_default(),
        }
    }
}

/// Mutable run state threaded across chunk boundaries: everything the
/// per-event loop accumulates lives here, so a streamed run is
/// bit-identical to a load-all run at any chunk size.
struct StreamState {
    /// The internal [`RecordingSink`] behind [`RunReport`]'s per-event
    /// vectors (`None` = counters only, O(1) memory). Driven through the
    /// same callbacks as the caller's sink.
    recorder: Option<RecordingSink>,
    corners_total: u64,
    events_in: usize,
    events_signal: usize,
    /// Signal events buffered for batch-preferring backends; flushed at
    /// snapshot boundaries and when `BACKEND_BATCH_MAX` is reached.
    pending: Vec<Event>,
    since_refresh: usize,
    dvfs_switches: u64,
    lut_refreshes: u64,
    /// Timestamp of the most recent input event (µs).
    last_t_us: u64,
    /// Current governor degradation level (0 without a governor).
    degrade_level: u64,
    /// Current backend supply voltage (mV), tracking DVFS / governor
    /// retargets; seeded by the run loops from the starting voltage.
    vdd_mv: u64,
    /// `on_stats` cadence in input events (`None` = never emit).
    stats_every: Option<u64>,
    /// Input events since the last `on_stats` emission.
    since_stats: u64,
}

/// Cap on speculative per-event-vector preallocation. Size hints can
/// originate from untrusted container headers
/// ([`EventSource::size_hint`]), so never reserve more than this many
/// events up front — the vectors still grow on demand past it.
const RESERVE_EVENTS_MAX: usize = 1 << 20;

/// Bounded preallocation hint for a source's per-event vectors.
fn reserve_hint<S: EventSource + ?Sized>(source: &S) -> usize {
    source.size_hint().unwrap_or(0).min(RESERVE_EVENTS_MAX)
}

impl StreamState {
    fn new(cfg: &PipelineConfig, reserve: usize) -> Self {
        Self {
            recorder: cfg.record_per_event.then(|| RecordingSink::with_capacity(reserve)),
            corners_total: 0,
            events_in: 0,
            events_signal: 0,
            pending: Vec::new(),
            since_refresh: 0,
            dvfs_switches: 0,
            lut_refreshes: 0,
            last_t_us: 0,
            degrade_level: 0,
            vdd_mv: 0,
            stats_every: cfg.stats_interval_events.map(|n| n.max(1)),
            since_stats: 0,
        }
    }

    /// Counters as of now, for [`CornerSink::on_stats`] /
    /// [`CornerSink::on_chunk_end`].
    fn live_stats(&self) -> LiveStats {
        LiveStats {
            events_in: self.events_in as u64,
            events_signal: self.events_signal as u64,
            corners_total: self.corners_total,
            dvfs_switches: self.dvfs_switches,
            lut_refreshes: self.lut_refreshes,
            last_t_us: self.last_t_us,
            degrade_level: self.degrade_level,
            vdd_mv: self.vdd_mv,
        }
    }

    /// The tag stage: count the scored signal event and deliver it to
    /// the internal recorder (if any) and the caller's sink.
    #[inline]
    fn tag<K: CornerSink + ?Sized>(
        &mut self,
        ev: &Event,
        score: f64,
        threshold: f64,
        sink: &mut K,
    ) -> Result<()> {
        let seq = self.events_signal as u64;
        if let Some(rec) = self.recorder.as_mut() {
            rec.on_score(seq, ev, score)?;
        }
        sink.on_score(seq, ev, score)?;
        if score >= threshold {
            self.corners_total += 1;
            let corner = Corner { seq, ev: *ev, score };
            if let Some(rec) = self.recorder.as_mut() {
                rec.on_corner(&corner)?;
            }
            sink.on_corner(&corner)?;
        }
        self.events_signal += 1;
        Ok(())
    }

    /// The `on_stats` cadence: called once per *input* event, after that
    /// event finished the pipeline stages (so the emitted counters
    /// include it).
    #[inline]
    fn stats_tick<K: CornerSink + ?Sized>(&mut self, sink: &mut K) -> Result<()> {
        if let Some(every) = self.stats_every {
            self.since_stats += 1;
            if self.since_stats >= every {
                self.since_stats = 0;
                sink.on_stats(&self.live_stats())?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::SceneConfig;

    // engine-less tests here; full-engine integration tests live in
    // rust/tests/ (they need `make artifacts` to have run).

    #[test]
    fn engineless_pipeline_runs_and_filters() {
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let mut scene = SceneConfig::test64().build(1);
        let events = scene.generate(20_000);
        let report = pipe.run(&events).unwrap();
        assert_eq!(report.events_in, 20_000);
        assert!(report.events_signal < report.events_in, "STCF must drop noise");
        assert!(report.events_signal > report.events_in / 4, "STCF too aggressive");
        assert_eq!(report.scores.len(), report.events_signal);
        // without an engine the LUT is all zeros -> no corners tagged
        assert!(report.corners.is_empty());
        assert!(report.backend.events as usize == report.events_signal);
        assert_eq!(report.backend_name, "nmc-tos");
        assert_eq!(report.detector_name, "luvHarris-LUT");
    }

    #[test]
    fn dvfs_reacts_to_synthetic_stream() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let mut scene = SceneConfig::test64().build(2);
        let events = scene.generate(50_000);
        let report = pipe.run(&events).unwrap();
        // test64 scene rate (~124 keps) is far below 4.9 Meps -> DVFS
        // settles at 0.6 V after the first window
        assert!(report.dvfs_switches >= 1);
        assert!((pipe.backend().vdd() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn stcf_disabled_passes_everything() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let mut scene = SceneConfig::test64().build(3);
        let events = scene.generate(5_000);
        let report = pipe.run(&events).unwrap();
        assert_eq!(report.events_signal, 5_000);
    }

    #[test]
    fn ber_injection_flips_bits_at_low_voltage() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        cfg.dvfs = None;
        cfg.fixed_vdd = 0.6;
        cfg.inject_errors = true;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let mut scene = SceneConfig::test64().build(4);
        let events = scene.generate(30_000);
        let report = pipe.run(&events).unwrap();
        assert!(report.backend.flipped_bits > 0);
    }

    #[test]
    fn report_scored_events_alignment() {
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let mut scene = SceneConfig::test64().build(5);
        let (events, gt) = scene.generate_with_gt(10_000);
        let report = pipe.run(&events).unwrap();
        let scored = report.scored_events(&gt, 3.0);
        assert_eq!(scored.len(), report.events_signal);
    }

    #[test]
    fn every_backend_and_detector_combination_runs() {
        let mut scene = SceneConfig::test64().build(9);
        let events = scene.generate(3_000);
        for bk in BackendKind::ALL {
            for dk in DetectorKind::ALL {
                let mut cfg = PipelineConfig::test64();
                cfg.dvfs = None;
                cfg.backend = bk;
                cfg.detector = dk;
                cfg.shards = 3;
                let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
                let report = pipe.run(&events).unwrap();
                assert!(report.events_signal > 0, "{bk:?}/{dk:?} dropped everything");
                assert_eq!(report.scores.len(), report.events_signal);
                assert_eq!(
                    report.backend.events as usize, report.events_signal,
                    "{bk:?}/{dk:?} backend event count"
                );
                assert!(!report.backend_name.is_empty());
                assert!(!report.detector_name.is_empty(), "{dk:?} unnamed");
            }
        }
    }

    #[test]
    fn all_backends_produce_identical_surfaces() {
        let mut scene = SceneConfig::test64().build(10);
        let events = scene.generate(8_000);
        let mut reference: Option<Vec<u8>> = None;
        for bk in BackendKind::ALL {
            let mut cfg = PipelineConfig::test64();
            cfg.dvfs = None; // pin the voltage: NMC at 1.2 V is error-free
            cfg.backend = bk;
            cfg.shards = 5;
            let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
            let report = pipe.run(&events).unwrap();
            match &reference {
                None => reference = Some(report.final_tos),
                Some(want) => {
                    assert_eq!(want, &report.final_tos, "{bk:?} diverged from nmc surface")
                }
            }
        }
    }

    #[test]
    fn streamed_chunks_bit_identical_to_load_all() {
        let mut scene = SceneConfig::test64().build(11);
        let events = scene.generate(12_000);
        let mut pipe = Pipeline::new_without_engine(PipelineConfig::test64()).unwrap();
        let want = pipe.run(&events).unwrap();
        for chunk in [1usize, 97, 4096] {
            let mut pipe = Pipeline::new_without_engine(PipelineConfig::test64()).unwrap();
            let got = pipe
                .run_stream(&mut crate::events::source::SliceSource::new(&events, chunk))
                .unwrap();
            assert_eq!(want.final_tos, got.final_tos, "chunk {chunk}");
            assert_eq!(want.scores, got.scores, "chunk {chunk}");
            assert_eq!(want.corners, got.corners, "chunk {chunk}");
            assert_eq!(want.events_in, got.events_in, "chunk {chunk}");
            assert_eq!(want.events_signal, got.events_signal, "chunk {chunk}");
            assert_eq!(want.dvfs_switches, got.dvfs_switches, "chunk {chunk}");
        }
    }

    #[test]
    fn no_record_mode_keeps_counters_only() {
        let mut scene = SceneConfig::test64().build(12);
        let events = scene.generate(10_000);
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg.clone()).unwrap();
        let full = pipe.run(&events).unwrap();

        cfg.record_per_event = false;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        let lean = pipe.run(&events).unwrap();

        assert!(lean.signal_events.is_empty());
        assert!(lean.scores.is_empty());
        assert!(lean.corners.is_empty());
        assert_eq!(lean.events_in, full.events_in);
        assert_eq!(lean.events_signal, full.events_signal);
        assert_eq!(lean.corners_total, full.corners_total);
        assert_eq!(full.corners_total as usize, full.corners.len());
        assert_eq!(lean.final_tos, full.final_tos);
    }

    #[test]
    fn external_recording_sink_matches_report_vectors() {
        // the caller's RecordingSink and the internal one ride the same
        // callbacks: their contents must be identical
        let mut scene = SceneConfig::test64().build(21);
        let events = scene.generate(9_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut sink = RecordingSink::default();
        let report = pipe.run_with(&events, &mut sink).unwrap();
        assert_eq!(sink.signal_events, report.signal_events);
        assert_eq!(sink.scores, report.scores);
        assert_eq!(sink.corners, report.corners);
        assert_eq!(report.corners_total as usize, sink.corners.len());
    }

    #[test]
    fn corner_callbacks_carry_seq_event_and_score() {
        struct Check {
            report_like: Vec<(u64, Event, f64)>,
        }
        impl CornerSink for Check {
            fn on_corner(&mut self, c: &Corner) -> Result<()> {
                self.report_like.push((c.seq, c.ev, c.score));
                Ok(())
            }
        }
        let mut scene = SceneConfig::test64().build(22);
        let events = scene.generate(6_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut sink = Check { report_like: Vec::new() };
        let report = pipe.run_with(&events, &mut sink).unwrap();
        assert_eq!(sink.report_like.len(), report.corners.len());
        for ((seq, ev, score), &idx) in sink.report_like.iter().zip(&report.corners) {
            assert_eq!(*seq as usize, idx);
            assert_eq!(*ev, report.signal_events[idx]);
            assert_eq!(score.to_bits(), report.scores[idx].to_bits());
        }
    }

    #[test]
    fn stats_cadence_is_deterministic_and_chunk_independent() {
        #[derive(Default)]
        struct Stats {
            seen: Vec<LiveStats>,
        }
        impl CornerSink for Stats {
            fn on_corner(&mut self, _c: &Corner) -> Result<()> {
                Ok(())
            }
            fn on_stats(&mut self, s: &LiveStats) -> Result<()> {
                self.seen.push(*s);
                Ok(())
            }
        }
        let mut scene = SceneConfig::test64().build(23);
        let events = scene.generate(5_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        cfg.stats_interval_events = Some(500);
        let mut runs = Vec::new();
        for chunk in [64usize, 997, 5_000] {
            let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
            let mut sink = Stats::default();
            pipe.run_stream_with(
                &mut crate::events::source::SliceSource::new(&events, chunk),
                &mut sink,
            )
            .unwrap();
            assert_eq!(sink.seen.len(), 10, "chunk {chunk}");
            for (i, s) in sink.seen.iter().enumerate() {
                assert_eq!(s.events_in, 500 * (i as u64 + 1), "chunk {chunk}");
            }
            // monotone counters
            for w in sink.seen.windows(2) {
                assert!(w[1].events_signal >= w[0].events_signal);
                assert!(w[1].corners_total >= w[0].corners_total);
            }
            runs.push(sink.seen);
        }
        // the cadence is counted in events, so the emitted snapshots are
        // identical whatever the source chunking
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0], runs[2]);
    }

    #[test]
    fn sink_error_aborts_the_run() {
        struct Failing {
            after: usize,
        }
        impl CornerSink for Failing {
            fn on_corner(&mut self, _c: &Corner) -> Result<()> {
                Ok(())
            }
            fn on_score(&mut self, seq: u64, _ev: &Event, _score: f64) -> Result<()> {
                anyhow::ensure!((seq as usize) < self.after, "sink full");
                Ok(())
            }
        }
        let mut scene = SceneConfig::test64().build(24);
        let events = scene.generate(4_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let err = pipe.run_with(&events, &mut Failing { after: 100 }).unwrap_err();
        assert!(format!("{err:#}").contains("sink full"), "{err:#}");
    }

    #[test]
    fn chunk_end_fires_once_per_source_chunk() {
        #[derive(Default)]
        struct Chunks {
            ends: usize,
        }
        impl CornerSink for Chunks {
            fn on_corner(&mut self, _c: &Corner) -> Result<()> {
                Ok(())
            }
            fn on_chunk_end(&mut self, _s: &LiveStats) -> Result<()> {
                self.ends += 1;
                Ok(())
            }
        }
        let mut scene = SceneConfig::test64().build(25);
        let events = scene.generate(1_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut sink = Chunks::default();
        pipe.run_stream_with(
            &mut crate::events::source::SliceSource::new(&events, 256),
            &mut sink,
        )
        .unwrap();
        assert_eq!(sink.ends, 4); // 256 + 256 + 256 + 232
    }

    #[test]
    fn governor_retargets_voltage_at_chunk_boundaries() {
        /// Steps the voltage down once, after the first chunk.
        struct StepDown {
            polls: u64,
        }
        impl Governor for StepDown {
            fn on_chunk_end(&mut self, _stats: &LiveStats) -> Option<f64> {
                self.polls += 1;
                (self.polls == 1).then_some(0.8)
            }
            fn level(&self) -> u32 {
                1
            }
        }
        #[derive(Default)]
        struct Ends {
            seen: Vec<LiveStats>,
        }
        impl CornerSink for Ends {
            fn on_corner(&mut self, _c: &Corner) -> Result<()> {
                Ok(())
            }
            fn on_chunk_end(&mut self, s: &LiveStats) -> Result<()> {
                self.seen.push(*s);
                Ok(())
            }
        }
        let mut scene = SceneConfig::test64().build(31);
        let events = scene.generate(3_000);
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg).unwrap();
        pipe.set_governor(Box::new(StepDown { polls: 0 }));
        let mut sink = Ends::default();
        pipe.run_stream_with(
            &mut crate::events::source::SliceSource::new(&events, 1_000),
            &mut sink,
        )
        .unwrap();
        // the governor runs *after* each on_chunk_end: the first snapshot
        // still shows nominal, later ones show the retargeted voltage and
        // the governor's level
        assert_eq!(sink.seen.len(), 3);
        assert_eq!((sink.seen[0].vdd_mv, sink.seen[0].degrade_level), (1_200, 0));
        assert_eq!((sink.seen[1].vdd_mv, sink.seen[1].degrade_level), (800, 1));
        assert!((pipe.backend().vdd() - 0.8).abs() < 1e-9);
        // event-time watermark reaches the last event
        assert_eq!(sink.seen[2].last_t_us, events.last().unwrap().t);
    }

    #[test]
    fn software_fbf_refreshes_without_engine() {
        let mut scene = SceneConfig::test64().build(32);
        let events = scene.generate(10_000);
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        cfg.software_fbf = true;
        cfg.lut_refresh_events = 500;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let report = pipe.run(&events).unwrap();
        assert!(report.lut_refreshes > 0, "software FBF must refresh the LUT");
        assert!(
            report.final_lut.iter().any(|&v| v > 0.0),
            "software Harris response must light up on the synthetic shapes"
        );
    }

    #[test]
    fn backend_and_detector_kinds_parse() {
        for bk in BackendKind::ALL {
            assert_eq!(bk.label().parse::<BackendKind>().unwrap(), bk);
        }
        for dk in DetectorKind::ALL {
            assert_eq!(dk.label().parse::<DetectorKind>().unwrap(), dk);
        }
        assert!("warp-drive".parse::<BackendKind>().is_err());
        assert!("warp-drive".parse::<DetectorKind>().is_err());
    }
}
