//! The system coordinator: wires STCF -> NMC-TOS -> DVFS -> FBF Harris ->
//! corner tagging into the full pipeline of paper Fig. 2.
//!
//! Two execution modes:
//!
//! * **sync** — the Harris LUT is recomputed inline every
//!   `lut_refresh_events` signal events.  Deterministic; used by the PR /
//!   BER experiments so AUC comparisons are seed-stable.
//! * **async** — a worker thread owns its own PJRT engine and recomputes
//!   the LUT "as fast as possible" from TOS snapshots, exactly the
//!   luvHarris decoupling: the event path never blocks on the frame path;
//!   snapshots are dropped (not queued) when the worker is busy.
//!
//! Python never appears on either path — the Harris graph was AOT-lowered
//! at build time and runs through the PJRT CPU client.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::detectors::harris::HarrisDetector;
use crate::dvfs::{DvfsConfig, DvfsController};
use crate::events::{Event, Resolution};
use crate::nmc::{NmcConfig, NmcMacro, NmcStats};
use crate::runtime::{default_artifact_dir, HarrisEngine, Manifest};
use crate::stcf::{Stcf, StcfConfig};
use crate::tos::TosConfig;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Sensor geometry (must match the artifact).
    pub res: Resolution,
    /// Artifact name in `artifacts/meta.json` (e.g. `davis240`).
    pub artifact: String,
    /// Artifact directory override (`None` = auto-discover).
    pub artifact_dir: Option<PathBuf>,
    /// TOS algorithm parameters.
    pub tos: TosConfig,
    /// Use the pipelined NMC schedule.
    pub pipelined: bool,
    /// Inject Monte-Carlo read errors (BER tracks the DVFS voltage).
    pub inject_errors: bool,
    /// Error-injection seed.
    pub seed: u64,
    /// STCF denoising (`None` = bypass).
    pub stcf: Option<StcfConfig>,
    /// DVFS (`None` = pinned at `fixed_vdd`).
    pub dvfs: Option<DvfsConfig>,
    /// Supply voltage when DVFS is off.
    pub fixed_vdd: f64,
    /// Sync mode: recompute the Harris LUT every N signal events.
    pub lut_refresh_events: usize,
    /// Use the async (threaded) LUT worker instead of inline refresh.
    pub async_refresh: bool,
    /// Score threshold above which an event is tagged a corner.
    pub corner_threshold: f64,
}

impl PipelineConfig {
    /// DAVIS240 defaults matching the paper's system.
    pub fn davis240() -> Self {
        Self {
            res: Resolution::DAVIS240,
            artifact: "davis240".into(),
            artifact_dir: None,
            tos: TosConfig::default(),
            pipelined: true,
            inject_errors: false,
            seed: 0,
            stcf: Some(StcfConfig::default()),
            dvfs: Some(DvfsConfig::default()),
            fixed_vdd: 1.2,
            lut_refresh_events: 2_000,
            async_refresh: false,
            corner_threshold: 0.55,
        }
    }

    /// Small config for tests.
    pub fn test64() -> Self {
        Self {
            res: Resolution::TEST64,
            artifact: "test64".into(),
            ..Self::davis240()
        }
    }
}

/// Everything a run produces.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Events fed in.
    pub events_in: usize,
    /// Events surviving STCF.
    pub events_signal: usize,
    /// The surviving events, in order (index-aligned with `scores`).
    pub signal_events: Vec<Event>,
    /// Per-signal-event corner score.
    pub scores: Vec<f64>,
    /// Indices (into `signal_events`) tagged as corners.
    pub corners: Vec<usize>,
    /// NMC macro telemetry (latency/energy totals, bit flips).
    pub nmc: NmcStats,
    /// Voltage switches performed by DVFS.
    pub dvfs_switches: u64,
    /// Harris LUT refreshes that completed.
    pub lut_refreshes: u64,
    /// Wall-clock seconds of the whole run (host side).
    pub wall_s: f64,
    /// Final TOS snapshot (for rendering).
    pub final_tos: Vec<u8>,
    /// Final LUT snapshot.
    pub final_lut: Vec<f32>,
}

impl RunReport {
    /// `(score, label)` pairs against ground truth, for PR curves.
    pub fn scored_events(
        &self,
        gt: &crate::datasets::gt::GroundTruth,
        radius_px: f32,
    ) -> Vec<(f64, bool)> {
        let labels = gt.label_events(&self.signal_events, radius_px);
        self.scores.iter().copied().zip(labels).collect()
    }
}

/// The assembled pipeline.
pub struct Pipeline {
    cfg: PipelineConfig,
    engine: Option<HarrisEngine>,
    nmc: NmcMacro,
    stcf: Option<Stcf>,
    dvfs: Option<DvfsController>,
    detector: HarrisDetector,
    /// Reused frame buffer for the FBF path (no per-refresh allocation).
    frame: Vec<f32>,
}

impl std::fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pipeline").field("cfg", &self.cfg).finish()
    }
}

impl Pipeline {
    /// Build the pipeline: load + compile the AOT Harris artifact, size
    /// the NMC macro, STCF and DVFS.
    pub fn new(cfg: PipelineConfig) -> Result<Pipeline> {
        let dir = cfg.artifact_dir.clone().unwrap_or_else(default_artifact_dir);
        let manifest = Manifest::load(&dir)?;
        let engine = HarrisEngine::load(&manifest, &cfg.artifact)?;
        anyhow::ensure!(
            engine.height == cfg.res.height as usize && engine.width == cfg.res.width as usize,
            "artifact {}x{} does not match sensor {}x{}",
            engine.height,
            engine.width,
            cfg.res.height,
            cfg.res.width
        );
        Ok(Self::with_engine(cfg, Some(engine)))
    }

    /// Build without a PJRT engine (LUT stays zero unless refreshed
    /// externally) — used by timing/energy-only experiments and tests
    /// that don't need corner scores.
    pub fn new_without_engine(cfg: PipelineConfig) -> Pipeline {
        Self::with_engine(cfg, None)
    }

    fn with_engine(cfg: PipelineConfig, engine: Option<HarrisEngine>) -> Pipeline {
        let nmc_cfg = NmcConfig {
            tos: cfg.tos,
            pipelined: cfg.pipelined,
            vdd: cfg.fixed_vdd,
            inject_errors: cfg.inject_errors,
            seed: cfg.seed,
        };
        let nmc = NmcMacro::new(cfg.res, nmc_cfg);
        let stcf = cfg.stcf.map(|c| Stcf::new(cfg.res, c));
        let dvfs = cfg.dvfs.map(DvfsController::new);
        let detector = HarrisDetector::new(cfg.res);
        let frame = vec![0.0f32; cfg.res.pixels()];
        Pipeline { cfg, engine, nmc, stcf, dvfs, detector, frame }
    }

    /// Pipeline configuration.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Run the pipeline over a time-sorted event stream.
    pub fn run(&mut self, events: &[Event]) -> Result<RunReport> {
        if self.cfg.async_refresh {
            self.run_async(events)
        } else {
            self.run_sync(events)
        }
    }

    /// Synchronous mode: inline LUT refresh every `lut_refresh_events`.
    fn run_sync(&mut self, events: &[Event]) -> Result<RunReport> {
        let start = Instant::now();
        let mut signal_events = Vec::with_capacity(events.len());
        let mut scores = Vec::with_capacity(events.len());
        let mut corners = Vec::new();
        let mut since_refresh = 0usize;
        let mut dvfs_switches = 0u64;

        for ev in events {
            // --- DVFS monitors the *raw* event rate (paper Fig. 2) -------
            if let Some(ctrl) = &mut self.dvfs {
                if let Some(op) = ctrl.on_event(ev.t) {
                    self.nmc.set_vdd(op.vdd);
                    dvfs_switches += 1;
                }
            }
            // --- STCF denoise --------------------------------------------
            if let Some(f) = &mut self.stcf {
                if !f.check(ev) {
                    continue;
                }
            }
            // --- NMC-TOS update (the hot path) ----------------------------
            self.nmc.process(ev);
            // --- FBF Harris refresh (inline in sync mode) -----------------
            since_refresh += 1;
            if since_refresh >= self.cfg.lut_refresh_events {
                since_refresh = 0;
                self.refresh_lut()?;
            }
            // --- tag ------------------------------------------------------
            let score = self.detector.score_at(ev.x, ev.y);
            if score >= self.cfg.corner_threshold {
                corners.push(signal_events.len());
            }
            scores.push(score);
            signal_events.push(*ev);
        }

        Ok(RunReport {
            events_in: events.len(),
            events_signal: signal_events.len(),
            signal_events,
            scores,
            corners,
            nmc: self.nmc.stats(),
            dvfs_switches,
            lut_refreshes: self.detector.refreshes,
            wall_s: start.elapsed().as_secs_f64(),
            final_tos: self.nmc.snapshot_u8(),
            final_lut: self.detector.lut().to_vec(),
        })
    }

    /// Asynchronous mode: the LUT worker owns its own engine and consumes
    /// TOS snapshots through a depth-1 channel; busy -> snapshot dropped.
    fn run_async(&mut self, events: &[Event]) -> Result<RunReport> {
        let start = Instant::now();
        let dir = self.cfg.artifact_dir.clone().unwrap_or_else(default_artifact_dir);
        let artifact = self.cfg.artifact.clone();

        let (snap_tx, snap_rx) = mpsc::sync_channel::<Vec<u8>>(1);
        let (lut_tx, lut_rx) = mpsc::channel::<Vec<f32>>();
        let worker = std::thread::spawn(move || -> Result<u64> {
            let manifest = Manifest::load(&dir)?;
            let mut engine = HarrisEngine::load(&manifest, &artifact)?;
            let mut refreshes = 0u64;
            while let Ok(tos) = snap_rx.recv() {
                let lut = engine.compute_u8(&tos)?;
                refreshes += 1;
                if lut_tx.send(lut).is_err() {
                    break;
                }
            }
            Ok(refreshes)
        });

        let mut signal_events = Vec::with_capacity(events.len());
        let mut scores = Vec::with_capacity(events.len());
        let mut corners = Vec::new();
        let mut dvfs_switches = 0u64;
        let mut since_snapshot = 0usize;
        // offer a snapshot at least this often (events); the worker decides
        // the actual refresh rate by how fast it drains the channel.
        let offer_every = (self.cfg.lut_refresh_events / 4).max(1);

        for ev in events {
            if let Some(ctrl) = &mut self.dvfs {
                if let Some(op) = ctrl.on_event(ev.t) {
                    self.nmc.set_vdd(op.vdd);
                    dvfs_switches += 1;
                }
            }
            if let Some(f) = &mut self.stcf {
                if !f.check(ev) {
                    continue;
                }
            }
            self.nmc.process(ev);

            // non-blocking LUT pickup
            while let Ok(lut) = lut_rx.try_recv() {
                self.detector.refresh(&lut);
            }
            since_snapshot += 1;
            if since_snapshot >= offer_every {
                since_snapshot = 0;
                // drop the snapshot if the worker is busy (luvHarris "as
                // fast as possible" semantics, no backpressure onto events)
                let _ = snap_tx.try_send(self.nmc.snapshot_u8());
            }

            let score = self.detector.score_at(ev.x, ev.y);
            if score >= self.cfg.corner_threshold {
                corners.push(signal_events.len());
            }
            scores.push(score);
            signal_events.push(*ev);
        }

        drop(snap_tx);
        // drain remaining LUTs
        while let Ok(lut) = lut_rx.try_recv() {
            self.detector.refresh(&lut);
        }
        let worker_refreshes =
            worker.join().map_err(|_| anyhow::anyhow!("LUT worker panicked"))??;

        Ok(RunReport {
            events_in: events.len(),
            events_signal: signal_events.len(),
            signal_events,
            scores,
            corners,
            nmc: self.nmc.stats(),
            dvfs_switches,
            lut_refreshes: worker_refreshes,
            wall_s: start.elapsed().as_secs_f64(),
            final_tos: self.nmc.snapshot_u8(),
            final_lut: self.detector.lut().to_vec(),
        })
    }

    /// Inline LUT refresh (sync mode).
    fn refresh_lut(&mut self) -> Result<()> {
        let Some(engine) = &mut self.engine else {
            return Ok(()); // engine-less pipelines skip the FBF stage
        };
        let tos = self.nmc.snapshot_u8();
        for (f, &v) in self.frame.iter_mut().zip(&tos) {
            *f = v as f32;
        }
        let lut = engine.compute(&self.frame).context("FBF Harris refresh")?;
        self.detector.refresh(&lut);
        Ok(())
    }

    /// Direct access to the macro (experiments).
    pub fn nmc(&self) -> &NmcMacro {
        &self.nmc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::SceneConfig;

    // engine-less tests here; full-engine integration tests live in
    // rust/tests/ (they need `make artifacts` to have run).

    #[test]
    fn engineless_pipeline_runs_and_filters() {
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg);
        let mut scene = SceneConfig::test64().build(1);
        let events = scene.generate(20_000);
        let report = pipe.run(&events).unwrap();
        assert_eq!(report.events_in, 20_000);
        assert!(report.events_signal < report.events_in, "STCF must drop noise");
        assert!(report.events_signal > report.events_in / 4, "STCF too aggressive");
        assert_eq!(report.scores.len(), report.events_signal);
        // without an engine the LUT is all zeros -> no corners tagged
        assert!(report.corners.is_empty());
        assert!(report.nmc.events as usize == report.events_signal);
    }

    #[test]
    fn dvfs_reacts_to_synthetic_stream() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        let mut pipe = Pipeline::new_without_engine(cfg);
        let mut scene = SceneConfig::test64().build(2);
        let events = scene.generate(50_000);
        let report = pipe.run(&events).unwrap();
        // test64 scene rate (~124 keps) is far below 4.9 Meps -> DVFS
        // settles at 0.6 V after the first window
        assert!(report.dvfs_switches >= 1);
        assert!((pipe.nmc().vdd() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn stcf_disabled_passes_everything() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg);
        let mut scene = SceneConfig::test64().build(3);
        let events = scene.generate(5_000);
        let report = pipe.run(&events).unwrap();
        assert_eq!(report.events_signal, 5_000);
    }

    #[test]
    fn ber_injection_flips_bits_at_low_voltage() {
        let mut cfg = PipelineConfig::test64();
        cfg.stcf = None;
        cfg.dvfs = None;
        cfg.fixed_vdd = 0.6;
        cfg.inject_errors = true;
        let mut pipe = Pipeline::new_without_engine(cfg);
        let mut scene = SceneConfig::test64().build(4);
        let events = scene.generate(30_000);
        let report = pipe.run(&events).unwrap();
        assert!(report.nmc.flipped_bits > 0);
    }

    #[test]
    fn report_scored_events_alignment() {
        let mut cfg = PipelineConfig::test64();
        cfg.dvfs = None;
        let mut pipe = Pipeline::new_without_engine(cfg);
        let mut scene = SceneConfig::test64().build(5);
        let (events, gt) = scene.generate_with_gt(10_000);
        let report = pipe.run(&events).unwrap();
        let scored = report.scored_events(&gt, 3.0);
        assert_eq!(scored.len(), report.events_signal);
    }
}
