//! The async-refresh LUT worker: the luvHarris frame-path decoupling as
//! a reusable, model-checkable protocol.
//!
//! [`Pipeline::run_stream_async`](super::Pipeline) used to inline this
//! machinery; it now lives here so the channel protocol can be loom-model
//! checked in isolation (see the `loom_tests` module and DESIGN.md
//! §Correctness tooling). The protocol, unchanged from PR 3/4:
//!
//! * **snapshot channel** (depth 1, `try_send`): the event loop *offers*
//!   TOS snapshots; a busy worker means the offer is dropped, never
//!   queued — luvHarris "as fast as possible" semantics, the event path
//!   never blocks on the frame path.
//! * **double-buffered snapshot scratch**: two owned buffers rotate
//!   through a recycle channel, so one can sit in the depth-1 channel
//!   while the worker computes from the other; a full channel skips the
//!   snapshot copy outright instead of cloning a frame to drop it.
//! * **LUT + LUT-recycle channels** (unbounded): finished LUTs flow back
//!   to the event loop, consumed LUT buffers flow forward for reuse —
//!   the whole refresh round-trip is allocation-free at steady state.
//!
//! All channel/thread primitives come from [`crate::util::sync`], so a
//! `--cfg loom` build checks every interleaving of offer / compute /
//! pickup / shutdown, including the final drain after `finish`.

use anyhow::Result;

use crate::util::sync::{mpsc, thread};

/// Handle to the background LUT-compute thread plus the event-loop side
/// of its channel protocol. Built by [`LutWorker::spawn`]; drive it with
/// [`offer_snapshot`](LutWorker::offer_snapshot) /
/// [`poll_luts`](LutWorker::poll_luts), and always end with
/// [`finish`](LutWorker::finish) (dropping the handle without finishing
/// leaves the thread to exit on its own but loses its error/count).
pub struct LutWorker {
    snap_tx: Option<mpsc::SyncSender<Vec<u8>>>,
    lut_rx: mpsc::Receiver<Vec<f32>>,
    recycle_rx: mpsc::Receiver<Vec<u8>>,
    lut_recycle_tx: mpsc::Sender<Vec<f32>>,
    /// Free snapshot buffers (the double-buffer pool).
    snap_bufs: Vec<Vec<u8>>,
    worker: Option<thread::JoinHandle<Result<u64>>>,
}

impl std::fmt::Debug for LutWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LutWorker").field("free_snap_bufs", &self.snap_bufs.len()).finish()
    }
}

impl LutWorker {
    /// Spawn the worker thread. `init` runs *on the worker* and builds
    /// the compute function (for the real pipeline: load the manifest +
    /// engine, returning a closure over `HarrisEngine::compute_u8_into`);
    /// an `init` error surfaces from [`finish`](LutWorker::finish), after
    /// the event loop completes — matching the old inline behaviour where
    /// a missing artifact failed the run at join time, not mid-stream.
    pub fn spawn<C, F>(init: F) -> LutWorker
    where
        C: FnMut(&[u8], &mut Vec<f32>) -> Result<()>,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        let (snap_tx, snap_rx) = mpsc::sync_channel::<Vec<u8>>(1);
        let (lut_tx, lut_rx) = mpsc::channel::<Vec<f32>>();
        let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<u8>>();
        let (lut_recycle_tx, lut_recycle_rx) = mpsc::channel::<Vec<f32>>();
        let worker = thread::spawn(move || -> Result<u64> {
            let mut compute = init()?;
            let mut computed = 0u64;
            while let Ok(tos) = snap_rx.recv() {
                // compute into a LUT buffer the event loop has finished
                // with (empty only for the first refreshes)
                let mut lut = lut_recycle_rx.try_recv().unwrap_or_default();
                compute(&tos, &mut lut)?;
                // hand the snapshot buffer back for reuse; if the event
                // loop already finished, the buffer just drops
                let _ = recycle_tx.send(tos);
                computed += 1;
                if lut_tx.send(lut).is_err() {
                    break;
                }
            }
            Ok(computed)
        });
        LutWorker {
            snap_tx: Some(snap_tx),
            lut_rx,
            recycle_rx,
            lut_recycle_tx,
            snap_bufs: vec![Vec::new(), Vec::new()],
            worker: Some(worker),
        }
    }

    /// Offer a snapshot to the worker: reclaim any buffers the worker
    /// has finished with, and only if one is free run `fill` on it and
    /// `try_send`. A full channel (worker busy) or a dead worker hands
    /// the buffer back to the pool — the offer is dropped, the caller
    /// never blocks. Returns whether the snapshot reached the channel.
    pub fn offer_snapshot(&mut self, fill: impl FnOnce(&mut Vec<u8>)) -> bool {
        while let Ok(buf) = self.recycle_rx.try_recv() {
            self.snap_bufs.push(buf);
        }
        let Some(mut buf) = self.snap_bufs.pop() else {
            return false;
        };
        fill(&mut buf);
        let tx = self.snap_tx.as_ref().expect("offer after finish");
        match tx.try_send(buf) {
            Ok(()) => true,
            Err(mpsc::TrySendError::Full(buf)) | Err(mpsc::TrySendError::Disconnected(buf)) => {
                // channel full (offer dropped) or worker exited early
                // (join surfaces the error); either way keep the buffer
                self.snap_bufs.push(buf);
                false
            }
        }
    }

    /// Non-blocking pickup of every LUT the worker has finished: `apply`
    /// each, then recycle its buffer for the next refresh. Returns how
    /// many were applied.
    pub fn poll_luts(&mut self, mut apply: impl FnMut(&[f32])) -> u64 {
        let mut applied = 0u64;
        while let Ok(lut) = self.lut_rx.try_recv() {
            apply(&lut);
            applied += 1;
            // return the consumed buffer for the next refresh
            let _ = self.lut_recycle_tx.send(lut);
        }
        applied
    }

    /// Close the snapshot channel, join the worker, and drain every
    /// remaining LUT into `apply` (no recycling — nobody left to reuse
    /// them). Returns `(tail_applied, computed)`: LUTs applied by this
    /// drain, and the worker's total compute count. Surfaces the
    /// worker's error (bad artifacts, compute failure) or panic.
    pub fn finish(mut self, mut apply: impl FnMut(&[f32])) -> Result<(u64, u64)> {
        drop(self.snap_tx.take()); // worker sees the channel close and exits
        let worker = self.worker.take().expect("finish called once");
        let computed = worker.join().map_err(|_| anyhow::anyhow!("LUT worker panicked"))??;
        let mut tail = 0u64;
        while let Ok(lut) = self.lut_rx.try_recv() {
            apply(&lut);
            tail += 1;
        }
        Ok((tail, computed))
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// The full offer → compute → pickup → finish round-trip with a
    /// trivial compute fn, including buffer recycling accounting.
    #[test]
    fn round_trip_applies_every_computed_lut() {
        let mut worker = LutWorker::spawn(|| {
            Ok(|tos: &[u8], lut: &mut Vec<f32>| {
                lut.clear();
                lut.extend(tos.iter().map(|&v| v as f32));
                Ok(())
            })
        });
        let mut offered = 0u64;
        for round in 0u8..20 {
            if worker.offer_snapshot(|buf| {
                buf.clear();
                buf.extend_from_slice(&[round, round, round]);
            }) {
                offered += 1;
            }
            thread::yield_now();
        }
        let mut applied = 0u64;
        for _ in 0..200 {
            applied += worker.poll_luts(|lut| assert_eq!(lut.len(), 3));
            thread::yield_now();
        }
        let (tail, computed) = worker.finish(|lut| assert_eq!(lut.len(), 3)).unwrap();
        assert_eq!(computed, offered, "every accepted snapshot is computed");
        assert_eq!(applied + tail, computed, "every computed LUT is applied");
    }

    /// An init error (e.g. missing artifacts) surfaces from finish, not
    /// mid-stream; offers in between are dropped cleanly.
    #[test]
    fn init_error_surfaces_at_finish() {
        let mut worker = LutWorker::spawn(
            || -> Result<fn(&[u8], &mut Vec<f32>) -> Result<()>> {
                anyhow::bail!("no artifacts here")
            },
        );
        for _ in 0..4 {
            let _ = worker.offer_snapshot(|buf| buf.push(1));
        }
        let err = worker.finish(|_| {}).unwrap_err();
        assert!(err.to_string().contains("no artifacts"), "{err:#}");
    }

    /// A compute error also surfaces at finish.
    #[test]
    fn compute_error_surfaces_at_finish() {
        let mut worker =
            LutWorker::spawn(|| Ok(|_: &[u8], _: &mut Vec<f32>| anyhow::bail!("engine died")));
        // keep offering until one lands (the worker may not have started)
        while !worker.offer_snapshot(|buf| buf.push(1)) {
            thread::yield_now();
        }
        let err = worker.finish(|_| {}).unwrap_err();
        assert!(err.to_string().contains("engine died"), "{err:#}");
    }
}

/// Loom models of the double-buffered snapshot/recycle protocol: offers
/// racing the worker's recv/compute/recycle cycle, pickup racing the
/// final drain, and shutdown while a snapshot is in flight. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_tests`.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = loom::model::Builder::new();
        if b.preemption_bound.is_none() {
            b.preemption_bound = Some(3);
        }
        b.check(f);
    }

    /// Under every schedule: no offered buffer is lost or duplicated
    /// (the pool + channels always account for exactly two), every
    /// accepted snapshot is computed, and every computed LUT is applied
    /// either by pickup or by the finish drain — the invariant behind
    /// the pipeline's `lut_refreshes <= computed` debug assert.
    #[test]
    fn loom_offer_compute_pickup_finish_conserves_buffers() {
        model(|| {
            let mut worker = LutWorker::spawn(|| {
                Ok(|tos: &[u8], lut: &mut Vec<f32>| {
                    lut.clear();
                    lut.push(tos.len() as f32);
                    Ok(())
                })
            });
            let mut offered = 0u64;
            let mut applied = 0u64;
            for round in 0u8..2 {
                if worker.offer_snapshot(|buf| {
                    buf.clear();
                    buf.push(round);
                }) {
                    offered += 1;
                }
                applied += worker.poll_luts(|lut| assert_eq!(lut.len(), 1));
            }
            let (tail, computed) = worker.finish(|lut| assert_eq!(lut.len(), 1)).unwrap();
            assert_eq!(computed, offered, "accepted snapshots all computed");
            assert_eq!(applied + tail, computed, "computed LUTs all applied");
            assert!(applied + tail <= offered);
        });
    }

    /// Shutdown with a snapshot possibly still in the depth-1 channel:
    /// the worker must drain it (or see the close) and exit; finish must
    /// never deadlock and the final counts must still balance.
    #[test]
    fn loom_finish_races_inflight_snapshot() {
        model(|| {
            let mut worker =
                LutWorker::spawn(|| Ok(|_: &[u8], lut: &mut Vec<f32>| {
                    lut.clear();
                    lut.push(0.0);
                    Ok(())
                }));
            let accepted = worker.offer_snapshot(|buf| buf.push(7));
            let (tail, computed) = worker.finish(|_| {}).unwrap();
            assert_eq!(computed, accepted as u64);
            assert!(tail <= computed);
        });
    }
}
