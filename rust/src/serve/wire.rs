//! The `nmc-tos serve` wire protocol: handshake, event frames, streamed
//! results (protocol v2), and the end-of-stream summary.
//!
//! A session is one TCP connection carrying one event stream:
//!
//! ```text
//! client -> server   Hello     "NMCTOSRV" | version u8 (1 or 2)
//!                              | stream_id u32 | width u16 | height u16
//! server -> client   Ack       status u8 (0 = accepted); when the Hello
//!                              asked for v2, an accepted ack carries one
//!                              more byte: the negotiated version
//! client -> server   frames    u32 payload length, then the payload:
//!                              one complete binary event container
//!                              (`events::codec::write_binary` format).
//!                              A zero-length frame is end of stream.
//! ```
//!
//! **v1 sessions** (summary-only): after the client's end-of-stream
//! frame the server answers a single `Summary` and the session is over.
//! A v1 client against a v2 server gets exactly the v1 byte stream — the
//! ack stays one byte, nothing is interleaved.
//!
//! **v2 sessions** stream results back *while* the client is still
//! sending events. Every server→client message is tagged with one kind
//! byte:
//!
//! ```text
//! server -> client   'C' CornerBatch   u32 count, then per corner:
//!                                      seq u64 | x u16 | y u16 | t u64
//!                                      | p u8 | score f64-bits u64
//!                    'S' Stats         events_in, events_signal,
//!                                      corners_total, dvfs_switches,
//!                                      lut_refreshes   (all u64)
//!                    'R' Summary       the v1 summary block, verbatim
//! ```
//!
//! **v3 sessions** are v2 with three more u64 fields appended to every
//! `Stats` message — `last_t_us`, `degrade_level`, `vdd_mv` — so a
//! client can watch the server's adaptive degradation (voltage
//! step-downs, detector swaps — see `serve::degrade`) live per session.
//! Everything else is byte-identical to v2, and v2 clients keep
//! receiving the 5-field stats message.
//!
//! All integers little-endian. Corner scores travel as raw `f64` bits,
//! so a v2 client reassembles corners **bit-identical** to what a
//! sequential `run_stream` with a
//! [`RecordingSink`](crate::coordinator::RecordingSink) records
//! (`rust/tests/serve_integration.rs` proves it). `CornerBatch` cadence
//! follows the pipeline's chunk boundaries (plus a
//! [`MAX_CORNER_BATCH`] cap); `Stats` cadence is the server's
//! `--stats-interval` (see
//! [`PipelineConfig::stats_interval_events`](crate::coordinator::PipelineConfig::stats_interval_events)).
//!
//! Each event frame decodes to one pipeline chunk
//! ([`FramedStreamSource`](crate::events::source::FramedStreamSource)),
//! so the sender's frame size is the server's per-stream memory bound;
//! frames above [`MAX_FRAME_BYTES`](crate::events::source::MAX_FRAME_BYTES)
//! are rejected, and a `CornerBatch` count (also untrusted input on the
//! client side) above [`MAX_CORNER_BATCH`] is rejected before any
//! allocation. The container format inside each event frame is exactly
//! the on-disk codec, so a recording can be relayed without re-encoding.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::sink::{Corner, CornerSink, LiveStats, NullSink};
use crate::coordinator::RunReport;
use crate::events::codec::write_binary;
use crate::events::source::{EventSource, MAX_FRAME_BYTES};
use crate::events::{Event, Polarity, Resolution};

/// Handshake magic (client -> server).
pub const HELLO_MAGIC: &[u8; 8] = b"NMCTOSRV";
/// Summary magic (server -> client).
pub const SUMMARY_MAGIC: &[u8; 8] = b"NMCTOSRP";
/// Protocol v1: event frames in, one summary back at end of stream.
pub const WIRE_V1: u8 = 1;
/// Protocol v2: v1 plus server→client `CornerBatch`/`Stats` messages
/// interleaved while the stream runs.
pub const WIRE_V2: u8 = 2;
/// Protocol v3: v2 with the session's degradation state (`last_t_us`,
/// `degrade_level`, `vdd_mv`) appended to every `Stats` message.
pub const WIRE_V3: u8 = 3;
/// Newest protocol version this build speaks (what negotiation caps at).
pub const WIRE_VERSION: u8 = WIRE_V3;

/// Ack status: session accepted.
pub const ACK_OK: u8 = 0;
/// Ack status: handshake rejected (bad resolution / unsupported config).
pub const ACK_REJECTED: u8 = 1;

/// v2 server→client message kind: a batch of corner decisions.
pub const MSG_CORNERS: u8 = b'C';
/// v2 server→client message kind: a live per-session stats snapshot.
pub const MSG_STATS: u8 = b'S';
/// v2 server→client message kind: the end-of-session summary.
pub const MSG_SUMMARY: u8 = b'R';

/// Most corners one `CornerBatch` message may carry. The server flushes
/// before exceeding it; the client rejects counts above it (the count is
/// untrusted input and must never size an allocation).
pub const MAX_CORNER_BATCH: usize = 1 << 16;

/// Bytes of one wire corner record (`seq | x | y | t | p | score bits`).
const CORNER_RECORD_BYTES: usize = 8 + 2 + 2 + 8 + 1 + 8;

/// Default socket read/write timeout [`feed`] installs when the caller
/// has not set one: generous enough for a server chewing through a long
/// v1 stream before its summary, finite so a hung server is a clean
/// error instead of a forever-blocked client.
pub const FEED_IO_TIMEOUT: Duration = Duration::from_secs(300);

/// The client's session declaration: a caller-chosen stream id (echoed in
/// the summary and used to label server-side reports), the sensor
/// geometry of the events that will follow, and the protocol version the
/// client wants to speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    /// Caller-chosen stream label (not required to be unique).
    pub stream_id: u32,
    /// Sensor geometry of the stream's events.
    pub res: Resolution,
    /// Requested protocol version ([`WIRE_V1`] or [`WIRE_V2`]); the
    /// server may negotiate down, never up.
    pub version: u8,
}

impl Hello {
    /// A summary-only v1 session.
    pub fn v1(stream_id: u32, res: Resolution) -> Self {
        Self { stream_id, res, version: WIRE_V1 }
    }

    /// A v2 session with streamed corners and stats.
    pub fn v2(stream_id: u32, res: Resolution) -> Self {
        Self { stream_id, res, version: WIRE_V2 }
    }

    /// A v3 session: v2 plus degradation state on every stats message.
    pub fn v3(stream_id: u32, res: Resolution) -> Self {
        Self { stream_id, res, version: WIRE_V3 }
    }
}

/// Write the handshake.
pub fn write_hello<W: Write>(w: &mut W, hello: &Hello) -> Result<()> {
    ensure!(
        hello.version >= WIRE_V1 && hello.version <= WIRE_VERSION,
        "unsupported wire version {}",
        hello.version
    );
    w.write_all(HELLO_MAGIC)?;
    w.write_all(&[hello.version])?;
    w.write_all(&hello.stream_id.to_le_bytes())?;
    w.write_all(&hello.res.width.to_le_bytes())?;
    w.write_all(&hello.res.height.to_le_bytes())?;
    Ok(())
}

/// Read and validate the handshake (server side). Accepts any version
/// this build speaks (v1 and v2).
pub fn read_hello<R: Read>(r: &mut R) -> Result<Hello> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("truncated handshake")?;
    if &magic != HELLO_MAGIC {
        bail!("bad handshake magic: {magic:?}");
    }
    let mut ver = [0u8; 1];
    r.read_exact(&mut ver).context("truncated handshake")?;
    if ver[0] < WIRE_V1 || ver[0] > WIRE_VERSION {
        bail!("unsupported wire version {}", ver[0]);
    }
    let mut id = [0u8; 4];
    r.read_exact(&mut id).context("truncated handshake")?;
    let mut dim = [0u8; 2];
    r.read_exact(&mut dim).context("truncated handshake")?;
    let width = u16::from_le_bytes(dim);
    r.read_exact(&mut dim).context("truncated handshake")?;
    let height = u16::from_le_bytes(dim);
    ensure!(width > 0 && height > 0, "degenerate resolution {width}x{height}");
    Ok(Hello {
        stream_id: u32::from_le_bytes(id),
        res: Resolution::new(width, height),
        version: ver[0],
    })
}

/// Write a bare v1 handshake ack (`ACK_OK` / `ACK_REJECTED`).
pub fn write_ack<W: Write>(w: &mut W, status: u8) -> Result<()> {
    w.write_all(&[status])?;
    Ok(())
}

/// Write the ack matching a client's `Hello`: the status byte, and — only
/// when the client asked for v2 *and* was accepted — the negotiated
/// version byte. A v1 client therefore sees exactly the v1 ack, and a
/// rejected client of either version sees just the status.
pub fn write_ack_for<W: Write>(w: &mut W, status: u8, hello_version: u8) -> Result<()> {
    w.write_all(&[status])?;
    if status == ACK_OK && hello_version >= WIRE_V2 {
        w.write_all(&[hello_version.min(WIRE_VERSION)])?;
    }
    Ok(())
}

/// Read a v1 handshake ack; a non-OK status is an error.
pub fn read_ack<R: Read>(r: &mut R) -> Result<()> {
    read_ack_negotiated(r, WIRE_V1).map(|_| ())
}

/// Read the ack for a `Hello` that requested `sent_version` and return
/// the version the server will speak. Rejection is an error (including
/// the rejection an old v1-only server gives a v2 hello — retry with
/// [`Hello::v1`] to talk to such servers).
pub fn read_ack_negotiated<R: Read>(r: &mut R, sent_version: u8) -> Result<u8> {
    let mut status = [0u8; 1];
    read_exact_or_closed(r, &mut status, "waiting for the handshake ack")?;
    ensure!(
        status[0] == ACK_OK,
        "server rejected the stream (status {}){}",
        status[0],
        if sent_version >= WIRE_V2 {
            " — a v1-only server rejects v2 hellos; retry with wire version 1"
        } else {
            ""
        }
    );
    if sent_version < WIRE_V2 {
        return Ok(WIRE_V1);
    }
    let mut ver = [0u8; 1];
    read_exact_or_closed(r, &mut ver, "waiting for the negotiated version")?;
    ensure!(
        ver[0] >= WIRE_V1 && ver[0] <= sent_version.min(WIRE_VERSION),
        "server negotiated impossible wire version {}",
        ver[0]
    );
    Ok(ver[0])
}

/// Write one event frame: length prefix + binary container. `scratch` is
/// a recycled encode buffer (reaches frame size once, then reused).
pub fn write_frame<W: Write>(w: &mut W, scratch: &mut Vec<u8>, events: &[Event]) -> Result<()> {
    scratch.clear();
    write_binary(&mut *scratch, events)?;
    ensure!(
        scratch.len() <= MAX_FRAME_BYTES,
        "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap — send smaller chunks",
        scratch.len()
    );
    w.write_all(&(scratch.len() as u32).to_le_bytes())?;
    w.write_all(scratch)?;
    Ok(())
}

/// Write the end-of-stream marker (a zero-length frame).
pub fn write_eos<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(&0u32.to_le_bytes())?;
    Ok(())
}

/// The counters a served session reports back to its client.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Summary {
    /// Stream id echoed from the handshake.
    pub stream_id: u32,
    /// Events received.
    pub events_in: u64,
    /// Events surviving STCF.
    pub events_signal: u64,
    /// Corner tags.
    pub corners_total: u64,
    /// DVFS voltage switches.
    pub dvfs_switches: u64,
    /// Harris LUT refreshes consumed.
    pub lut_refreshes: u64,
    /// Server-side wall time (µs).
    pub wall_us: u64,
}

impl Summary {
    /// Condense a server-side [`RunReport`] into the wire summary.
    pub fn from_report(stream_id: u32, report: &RunReport) -> Self {
        Summary {
            stream_id,
            events_in: report.events_in as u64,
            events_signal: report.events_signal as u64,
            corners_total: report.corners_total,
            dvfs_switches: report.dvfs_switches,
            lut_refreshes: report.lut_refreshes,
            wall_us: (report.wall_s * 1e6) as u64,
        }
    }
}

/// Write the end-of-session summary (v1 encoding; v2 prefixes it with
/// [`MSG_SUMMARY`] — see [`WireSink::finish`]).
pub fn write_summary<W: Write>(w: &mut W, s: &Summary) -> Result<()> {
    w.write_all(SUMMARY_MAGIC)?;
    w.write_all(&s.stream_id.to_le_bytes())?;
    for v in [
        s.events_in,
        s.events_signal,
        s.corners_total,
        s.dvfs_switches,
        s.lut_refreshes,
        s.wall_us,
    ] {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the end-of-session summary.
pub fn read_summary<R: Read>(r: &mut R) -> Result<Summary> {
    let mut magic = [0u8; 8];
    read_exact_or_closed(r, &mut magic, "waiting for the end-of-stream summary")?;
    if &magic != SUMMARY_MAGIC {
        bail!("bad summary magic: {magic:?}");
    }
    let mut id = [0u8; 4];
    read_exact_or_closed(r, &mut id, "reading the summary")?;
    let mut field = || -> Result<u64> {
        let mut b = [0u8; 8];
        read_exact_or_closed(r, &mut b, "reading the summary")?;
        Ok(u64::from_le_bytes(b))
    };
    Ok(Summary {
        stream_id: u32::from_le_bytes(id),
        events_in: field()?,
        events_signal: field()?,
        corners_total: field()?,
        dvfs_switches: field()?,
        lut_refreshes: field()?,
        wall_us: field()?,
    })
}

/// Write one v2 `CornerBatch` message (at most [`MAX_CORNER_BATCH`]
/// corners — the server-side [`WireSink`] flushes before exceeding it).
pub fn write_corner_batch<W: Write>(w: &mut W, corners: &[Corner]) -> Result<()> {
    ensure!(
        corners.len() <= MAX_CORNER_BATCH,
        "corner batch of {} exceeds the {MAX_CORNER_BATCH} cap",
        corners.len()
    );
    w.write_all(&[MSG_CORNERS])?;
    w.write_all(&(corners.len() as u32).to_le_bytes())?;
    for c in corners {
        w.write_all(&c.seq.to_le_bytes())?;
        w.write_all(&c.ev.x.to_le_bytes())?;
        w.write_all(&c.ev.y.to_le_bytes())?;
        w.write_all(&c.ev.t.to_le_bytes())?;
        w.write_all(&[c.ev.p.bit()])?;
        // raw bits: the client reassembles the exact f64 the detector
        // produced (the bit-equivalence contract of the v2 protocol)
        w.write_all(&c.score.to_bits().to_le_bytes())?;
    }
    Ok(())
}

/// Write one `Stats` message; `version` selects the field set (v3
/// appends `last_t_us`, `degrade_level`, `vdd_mv`).
pub fn write_stats_msg<W: Write>(w: &mut W, s: &LiveStats, version: u8) -> Result<()> {
    w.write_all(&[MSG_STATS])?;
    for v in [s.events_in, s.events_signal, s.corners_total, s.dvfs_switches, s.lut_refreshes] {
        w.write_all(&v.to_le_bytes())?;
    }
    if version >= WIRE_V3 {
        for v in [s.last_t_us, s.degrade_level, s.vdd_mv] {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// One tagged server→client message of a v2 session.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// A batch of corner decisions, in stream order.
    Corners(Vec<Corner>),
    /// A live per-session stats snapshot.
    Stats(LiveStats),
    /// The end-of-session summary; no further messages follow.
    Summary(Summary),
}

/// Read the next tagged server→client message of a v2/v3 session;
/// `version` is the session's negotiated protocol version (it sets the
/// `Stats` field count — a v2 decode leaves the v3-only [`LiveStats`]
/// fields at zero).
pub fn read_server_msg<R: Read>(r: &mut R, version: u8) -> Result<ServerMsg> {
    let mut kind = [0u8; 1];
    read_exact_or_closed(r, &mut kind, "waiting for the next server message")?;
    match kind[0] {
        MSG_CORNERS => {
            let mut len = [0u8; 4];
            read_exact_or_closed(r, &mut len, "reading a corner batch")?;
            let count = u32::from_le_bytes(len) as usize;
            // untrusted count: validate before it sizes anything
            ensure!(
                count <= MAX_CORNER_BATCH,
                "corner batch of {count} exceeds the {MAX_CORNER_BATCH} cap"
            );
            let mut corners = Vec::with_capacity(count);
            let mut rec = [0u8; CORNER_RECORD_BYTES];
            for _ in 0..count {
                read_exact_or_closed(r, &mut rec, "reading a corner batch")?;
                // nmc-analyze: allow(error-discipline, next=9) -- every try_into below slices a fixed range of the [u8; CORNER_RECORD_BYTES] buffer, so the conversions are infallible
                corners.push(Corner {
                    seq: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
                    ev: Event {
                        x: u16::from_le_bytes(rec[8..10].try_into().unwrap()),
                        y: u16::from_le_bytes(rec[10..12].try_into().unwrap()),
                        t: u64::from_le_bytes(rec[12..20].try_into().unwrap()),
                        p: Polarity::from_bit(rec[20]),
                    },
                    score: f64::from_bits(u64::from_le_bytes(rec[21..29].try_into().unwrap())),
                });
            }
            Ok(ServerMsg::Corners(corners))
        }
        MSG_STATS => {
            let mut field = || -> Result<u64> {
                let mut b = [0u8; 8];
                read_exact_or_closed(r, &mut b, "reading a stats message")?;
                Ok(u64::from_le_bytes(b))
            };
            let mut s = LiveStats {
                events_in: field()?,
                events_signal: field()?,
                corners_total: field()?,
                dvfs_switches: field()?,
                lut_refreshes: field()?,
                ..LiveStats::default()
            };
            if version >= WIRE_V3 {
                s.last_t_us = field()?;
                s.degrade_level = field()?;
                s.vdd_mv = field()?;
            }
            Ok(ServerMsg::Stats(s))
        }
        MSG_SUMMARY => Ok(ServerMsg::Summary(read_summary(r)?)),
        other => bail!("unknown server message kind {other:#04x}"),
    }
}

/// `read_exact` with client-grade error reporting: a connection the peer
/// closed mid-protocol is reported as exactly that (the most common
/// failure — the server failed the session and dropped the socket), and
/// a socket-timeout expiry is distinguished from other I/O errors.
fn read_exact_or_closed<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf).map_err(|e| match e.kind() {
        std::io::ErrorKind::UnexpectedEof => anyhow!(
            "server closed the connection while {what} — the session likely failed \
             server-side (rejected events, I/O timeout, or server shutdown); check the \
             server log"
        ),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            anyhow!("timed out while {what} — no data from the server within the read timeout")
        }
        _ => anyhow::Error::new(e).context(format!("while {what}")),
    })
}

/// The server side of v2 result streaming: a [`CornerSink`] that encodes
/// corners and stats onto the session's connection as the pipeline runs.
///
/// Corners are buffered and flushed as one `CornerBatch` per pipeline
/// chunk (`on_chunk_end`) and whenever [`MAX_CORNER_BATCH`] is reached;
/// stats messages flush immediately (they exist to be timely). The
/// writer is typically a `BufWriter<TcpStream>` with a write timeout:
/// a client that stops draining results eventually stalls the socket,
/// the write errors, and the session fails — the fallible-backpressure
/// contract protecting the server's workers.
#[derive(Debug)]
pub struct WireSink<W: Write> {
    w: W,
    /// Negotiated session protocol version (selects the stats field set).
    version: u8,
    batch: Vec<Corner>,
    corners_sent: u64,
    stats_sent: u64,
}

impl<W: Write> WireSink<W> {
    /// A sink encoding onto `w` (wrap sockets in a `BufWriter`) speaking
    /// the session's negotiated protocol `version` (≥ [`WIRE_V2`]).
    pub fn new(w: W, version: u8) -> Self {
        Self { w, version, batch: Vec::new(), corners_sent: 0, stats_sent: 0 }
    }

    /// Corners encoded so far (including the buffered, unflushed tail).
    pub fn corners_sent(&self) -> u64 {
        self.corners_sent + self.batch.len() as u64
    }

    /// Stats messages sent so far.
    pub fn stats_sent(&self) -> u64 {
        self.stats_sent
    }

    fn flush_batch(&mut self) -> Result<()> {
        if !self.batch.is_empty() {
            write_corner_batch(&mut self.w, &self.batch)?;
            self.corners_sent += self.batch.len() as u64;
            self.batch.clear();
        }
        Ok(())
    }

    /// Flush everything, send the tagged end-of-session summary, and
    /// return `(corners_sent, stats_sent)`.
    pub fn finish(mut self, summary: &Summary) -> Result<(u64, u64)> {
        self.flush_batch()?;
        self.w.write_all(&[MSG_SUMMARY])?;
        write_summary(&mut self.w, summary)?;
        self.w.flush()?;
        Ok((self.corners_sent, self.stats_sent))
    }
}

impl<W: Write> CornerSink for WireSink<W> {
    fn on_corner(&mut self, corner: &Corner) -> Result<()> {
        self.batch.push(*corner);
        if self.batch.len() >= MAX_CORNER_BATCH {
            self.flush_batch()?;
        }
        Ok(())
    }

    fn on_stats(&mut self, stats: &LiveStats) -> Result<()> {
        // corners first, so a stats snapshot never counts corners the
        // client has not yet been sent
        self.flush_batch()?;
        write_stats_msg(&mut self.w, stats, self.version)?;
        self.w.flush()?;
        self.stats_sent += 1;
        Ok(())
    }

    fn on_chunk_end(&mut self, _stats: &LiveStats) -> Result<()> {
        // the chunk boundary bounds corner latency: nothing sits in the
        // batch buffer longer than one pipeline chunk
        self.flush_batch()?;
        self.w.flush()?;
        Ok(())
    }
}

/// Stream every chunk of `source` as one frame, then the end-of-stream
/// marker.
fn send_all_frames<W: Write, S: EventSource + ?Sized>(w: &mut W, source: &mut S) -> Result<()> {
    let mut chunk: Vec<Event> = Vec::new();
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        chunk.clear();
        if source.next_chunk(&mut chunk)? == 0 {
            break;
        }
        write_frame(w, &mut scratch, &chunk)?;
    }
    write_eos(w)?;
    w.flush()?;
    Ok(())
}

/// Client side of a served session: handshake at `hello.version`, stream
/// every chunk of `source` as one frame, and return the server's
/// summary. Results streamed back by a v2 session are discarded — use
/// [`feed_with_sink`] to observe them. This is what `nmc-tos feed` runs;
/// tests drive it against a loopback
/// [`StreamServer`](super::StreamServer).
pub fn feed<S: EventSource + ?Sized>(
    stream: TcpStream,
    hello: Hello,
    source: &mut S,
) -> Result<Summary> {
    feed_with_sink(stream, hello, source, &mut NullSink)
}

/// [`feed`] with a [`CornerSink`] observing the session's streamed
/// results: every v2 `CornerBatch` corner arrives through
/// `sink.on_corner` (in stream order) and every `Stats` message through
/// `sink.on_stats`, while the events are still being sent (`on_score` /
/// `on_chunk_end` never fire client-side — the wire only carries
/// corners). For v1 sessions — requested or negotiated down — the sink
/// sees nothing and only the summary returns.
///
/// Reading and writing run concurrently (a reader thread drains the
/// server while the stream is sent), so a corner-dense session cannot
/// deadlock on two full socket buffers. If the caller has not set socket
/// timeouts, [`FEED_IO_TIMEOUT`] is installed so a hung server is a
/// clean error; a server that closes the connection mid-stream (its
/// session failed) is likewise reported as that, not as a bare EOF.
pub fn feed_with_sink<S, K>(
    stream: TcpStream,
    hello: Hello,
    source: &mut S,
    sink: &mut K,
) -> Result<Summary>
where
    S: EventSource + ?Sized,
    K: CornerSink + Send + ?Sized,
{
    stream.set_nodelay(true).ok();
    if stream.read_timeout().unwrap_or(None).is_none() {
        stream.set_read_timeout(Some(FEED_IO_TIMEOUT)).ok();
    }
    if stream.write_timeout().unwrap_or(None).is_none() {
        stream.set_write_timeout(Some(FEED_IO_TIMEOUT)).ok();
    }
    let mut w = BufWriter::new(stream.try_clone().context("cloning connection")?);
    let mut r = BufReader::new(stream);
    write_hello(&mut w, &hello)?;
    w.flush()?;
    let negotiated = read_ack_negotiated(&mut r, hello.version)?;

    if negotiated < WIRE_V2 {
        // summary-only session: write everything, then one read
        send_all_frames(&mut w, source)?;
        return read_summary(&mut r);
    }

    // v2: drain server messages concurrently with sending, so corner
    // traffic cannot fill both socket buffers and deadlock the session
    std::thread::scope(|scope| {
        let recv = scope.spawn(move || -> Result<Summary> {
            let result: Result<Summary> = (|| loop {
                match read_server_msg(&mut r, negotiated)? {
                    ServerMsg::Corners(batch) => {
                        for c in &batch {
                            sink.on_corner(c)?;
                        }
                    }
                    ServerMsg::Stats(stats) => sink.on_stats(&stats)?,
                    ServerMsg::Summary(summary) => return Ok(summary),
                }
            })();
            if result.is_err() {
                // unblock the sending side right away: without this the
                // writer would keep streaming into an undrained socket
                // until the server's own I/O timeout killed the session
                let _ = r.get_ref().shutdown(std::net::Shutdown::Both);
            }
            result
        });
        let sent = send_all_frames(&mut w, source);
        let received = recv.join().map_err(|_| anyhow!("feed reader thread panicked"))?;
        match (sent, received) {
            // the summary arrived: the server saw the whole stream
            (_, Ok(summary)) => Ok(summary),
            (Ok(()), Err(e)) => Err(e),
            // sending failed too (the usual cause: the server failed the
            // session and closed); the read-side error is the informative
            // one, keep the send error as context
            (Err(send_err), Err(recv_err)) => {
                Err(recv_err.context(format!("while also failing to send events: {send_err:#}")))
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip_both_versions() {
        for hello in [
            Hello::v1(42, Resolution::DAVIS240),
            Hello::v2(43, Resolution::TEST64),
            Hello::v3(44, Resolution::TEST64),
        ] {
            let mut buf = Vec::new();
            write_hello(&mut buf, &hello).unwrap();
            assert_eq!(read_hello(&mut &buf[..]).unwrap(), hello);
        }
    }

    #[test]
    fn hello_rejects_garbage() {
        assert!(read_hello(&mut &b"XXXXXXXX\x01\0\0\0\0\xf0\0\xb4\0"[..]).is_err());
        // right magic, wrong version — on the wire and at write time
        let mut buf = Vec::new();
        write_hello(&mut buf, &Hello::v1(0, Resolution::TEST64)).unwrap();
        buf[8] = 9;
        assert!(read_hello(&mut &buf[..]).is_err());
        let bad = Hello { stream_id: 0, res: Resolution::TEST64, version: 4 };
        assert!(write_hello(&mut Vec::new(), &bad).is_err());
        // degenerate resolution
        let mut buf = Vec::new();
        write_hello(&mut buf, &Hello::v1(0, Resolution::new(0, 64))).unwrap();
        assert!(read_hello(&mut &buf[..]).is_err());
    }

    #[test]
    fn ack_roundtrip_v1() {
        let mut buf = Vec::new();
        write_ack(&mut buf, ACK_OK).unwrap();
        assert!(read_ack(&mut &buf[..]).is_ok());
        let mut buf = Vec::new();
        write_ack(&mut buf, ACK_REJECTED).unwrap();
        assert!(read_ack(&mut &buf[..]).is_err());
    }

    #[test]
    fn ack_negotiation_v1_and_v2() {
        // v1 hello -> v1 single-byte ack, negotiated version 1
        let mut buf = Vec::new();
        write_ack_for(&mut buf, ACK_OK, WIRE_V1).unwrap();
        assert_eq!(buf.len(), 1, "v1 ack must stay one byte");
        assert_eq!(read_ack_negotiated(&mut &buf[..], WIRE_V1).unwrap(), WIRE_V1);

        // v2 hello -> status + negotiated version byte
        let mut buf = Vec::new();
        write_ack_for(&mut buf, ACK_OK, WIRE_V2).unwrap();
        assert_eq!(buf.len(), 2);
        assert_eq!(read_ack_negotiated(&mut &buf[..], WIRE_V2).unwrap(), WIRE_V2);

        // rejection carries no version byte for either hello version
        for hv in [WIRE_V1, WIRE_V2] {
            let mut buf = Vec::new();
            write_ack_for(&mut buf, ACK_REJECTED, hv).unwrap();
            assert_eq!(buf.len(), 1);
            assert!(read_ack_negotiated(&mut &buf[..], hv).is_err());
        }

        // a server that claims a version above what the client asked for
        // is a protocol violation
        let buf = [ACK_OK, 3u8];
        assert!(read_ack_negotiated(&mut &buf[..], WIRE_V2).is_err());

        // a v3 hello against this build negotiates v3
        let mut buf = Vec::new();
        write_ack_for(&mut buf, ACK_OK, WIRE_V3).unwrap();
        assert_eq!(read_ack_negotiated(&mut &buf[..], WIRE_V3).unwrap(), WIRE_V3);
        // ...and a v2 server answering a v3 hello negotiates down to v2
        let buf = [ACK_OK, WIRE_V2];
        assert_eq!(read_ack_negotiated(&mut &buf[..], WIRE_V3).unwrap(), WIRE_V2);
    }

    #[test]
    fn summary_roundtrip() {
        let s = Summary {
            stream_id: 7,
            events_in: 1,
            events_signal: 2,
            corners_total: 3,
            dvfs_switches: 4,
            lut_refreshes: 5,
            wall_us: 6,
        };
        let mut buf = Vec::new();
        write_summary(&mut buf, &s).unwrap();
        assert_eq!(read_summary(&mut &buf[..]).unwrap(), s);
        buf.truncate(buf.len() - 1);
        assert!(read_summary(&mut &buf[..]).is_err());
    }

    #[test]
    fn truncated_summary_reports_server_close() {
        // the satellite fix: a dropped connection is a clean "server
        // closed" error, not a bare failed-to-fill-buffer EOF
        let err = read_summary(&mut &b"NMCTOSR"[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("server closed the connection"), "{msg}");
    }

    #[test]
    fn corner_batch_roundtrip_is_bit_exact() {
        let corners = vec![
            Corner { seq: 0, ev: Event::on(0, 0, 0), score: 0.0 },
            Corner { seq: 7, ev: Event::off(239, 179, u64::MAX / 3), score: -1.25e-300 },
            Corner { seq: u64::MAX, ev: Event::on(1, 2, 3), score: f64::MIN_POSITIVE },
            Corner { seq: 9, ev: Event::on(63, 63, 1_000_000), score: 0.1 + 0.2 },
        ];
        let mut buf = Vec::new();
        write_corner_batch(&mut buf, &corners).unwrap();
        match read_server_msg(&mut &buf[..], WIRE_V3).unwrap() {
            ServerMsg::Corners(got) => {
                assert_eq!(got.len(), corners.len());
                for (g, w) in got.iter().zip(&corners) {
                    assert_eq!(g.seq, w.seq);
                    assert_eq!(g.ev, w.ev);
                    assert_eq!(g.score.to_bits(), w.score.to_bits(), "score bits");
                }
            }
            other => panic!("expected corners, got {other:?}"),
        }
    }

    #[test]
    fn stats_msg_roundtrip() {
        let s = LiveStats {
            events_in: 10,
            events_signal: 8,
            corners_total: 3,
            dvfs_switches: 1,
            lut_refreshes: 2,
            last_t_us: 1_234_567,
            degrade_level: 2,
            vdd_mv: 800,
        };
        // v3 carries every field
        let mut buf = Vec::new();
        write_stats_msg(&mut buf, &s, WIRE_V3).unwrap();
        assert_eq!(buf.len(), 1 + 8 * 8);
        assert_eq!(read_server_msg(&mut &buf[..], WIRE_V3).unwrap(), ServerMsg::Stats(s));
        // a v2 session stays byte-compatible: 5 fields on the wire, the
        // v3-only fields decode as zero
        let mut buf = Vec::new();
        write_stats_msg(&mut buf, &s, WIRE_V2).unwrap();
        assert_eq!(buf.len(), 1 + 5 * 8);
        let want = LiveStats { last_t_us: 0, degrade_level: 0, vdd_mv: 0, ..s };
        assert_eq!(read_server_msg(&mut &buf[..], WIRE_V2).unwrap(), ServerMsg::Stats(want));
    }

    #[test]
    fn server_msg_rejects_garbage() {
        // unknown kind byte
        assert!(read_server_msg(&mut &[0xFFu8, 0, 0][..], WIRE_V3).is_err());
        // corner batch with a count beyond the cap must error before
        // allocating
        let mut buf = vec![MSG_CORNERS];
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_server_msg(&mut &buf[..], WIRE_V3).is_err());
        // oversized batch refused at write time too
        let big = vec![Corner { seq: 0, ev: Event::on(0, 0, 0), score: 0.0 }; MAX_CORNER_BATCH + 1];
        assert!(write_corner_batch(&mut Vec::new(), &big).is_err());
    }

    #[test]
    fn wire_sink_batches_per_chunk_and_orders_stats_after_corners() {
        let mut buf = Vec::new();
        {
            let mut sink = WireSink::new(&mut buf, WIRE_V3);
            let c = |seq| Corner { seq, ev: Event::on(1, 1, seq), score: 1.0 };
            sink.on_corner(&c(0)).unwrap();
            sink.on_corner(&c(1)).unwrap();
            assert_eq!(sink.corners_sent(), 2, "buffered corners count");
            sink.on_chunk_end(&LiveStats::default()).unwrap(); // flush: batch of 2
            sink.on_corner(&c(2)).unwrap();
            let stats = LiveStats { corners_total: 3, ..LiveStats::default() };
            sink.on_stats(&stats).unwrap(); // flush: batch of 1, then stats
            let (corners, stats_n) = sink
                .finish(&Summary { stream_id: 5, ..Summary::default() })
                .unwrap();
            assert_eq!((corners, stats_n), (3, 1));
        }
        let mut r = &buf[..];
        match read_server_msg(&mut r, WIRE_V3).unwrap() {
            ServerMsg::Corners(b) => assert_eq!(b.len(), 2),
            other => panic!("expected first batch, got {other:?}"),
        }
        match read_server_msg(&mut r, WIRE_V3).unwrap() {
            ServerMsg::Corners(b) => assert_eq!(b.len(), 1),
            other => panic!("expected second batch, got {other:?}"),
        }
        assert!(matches!(read_server_msg(&mut r, WIRE_V3).unwrap(), ServerMsg::Stats(_)));
        match read_server_msg(&mut r, WIRE_V3).unwrap() {
            ServerMsg::Summary(s) => assert_eq!(s.stream_id, 5),
            other => panic!("expected summary, got {other:?}"),
        }
        assert!(r.is_empty(), "no trailing bytes");
    }

    #[test]
    fn frames_decode_through_framed_source() {
        use crate::events::source::FramedStreamSource;
        let events: Vec<Event> =
            (0..500).map(|i| Event::on((i % 60) as u16, (i % 40) as u16, i as u64)).collect();
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        for chunk in events.chunks(123) {
            write_frame(&mut wire, &mut scratch, chunk).unwrap();
        }
        write_eos(&mut wire).unwrap();
        let mut src = FramedStreamSource::new(&wire[..]);
        let mut out = Vec::new();
        while src.next_chunk(&mut out).unwrap() > 0 {}
        assert_eq!(out, events);
    }
}
