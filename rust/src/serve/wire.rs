//! The `nmc-tos serve` wire protocol: handshake, event frames, and the
//! end-of-stream summary.
//!
//! A session is one TCP connection carrying one event stream:
//!
//! ```text
//! client -> server   Hello     "NMCTOSRV" | version u8 | stream_id u32
//!                              | width u16 | height u16      (all LE)
//! server -> client   Ack       status u8 (0 = accepted)
//! client -> server   frames    u32 payload length, then the payload:
//!                              one complete binary event container
//!                              (`events::codec::write_binary` format).
//!                              A zero-length frame is end of stream.
//! server -> client   Summary   "NMCTOSRP" | stream_id u32 | events_in,
//!                              events_signal, corners_total,
//!                              dvfs_switches, lut_refreshes, wall_us
//!                              (all u64 LE)
//! ```
//!
//! Each frame decodes to one pipeline chunk
//! ([`FramedStreamSource`](crate::events::source::FramedStreamSource)),
//! so the sender's frame size is the server's per-stream memory bound;
//! frames above [`MAX_FRAME_BYTES`](crate::events::source::MAX_FRAME_BYTES)
//! are rejected. The container format inside each frame is exactly the
//! on-disk codec, so a recording can be relayed without re-encoding.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::RunReport;
use crate::events::codec::write_binary;
use crate::events::source::{EventSource, MAX_FRAME_BYTES};
use crate::events::{Event, Resolution};

/// Handshake magic (client -> server).
pub const HELLO_MAGIC: &[u8; 8] = b"NMCTOSRV";
/// Summary magic (server -> client).
pub const SUMMARY_MAGIC: &[u8; 8] = b"NMCTOSRP";
/// Protocol version negotiated by the handshake.
pub const WIRE_VERSION: u8 = 1;

/// Ack status: session accepted.
pub const ACK_OK: u8 = 0;
/// Ack status: handshake rejected (bad resolution / unsupported config).
pub const ACK_REJECTED: u8 = 1;

/// The client's session declaration: a caller-chosen stream id (echoed in
/// the summary and used to label server-side reports) and the sensor
/// geometry of the events that will follow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    /// Caller-chosen stream label (not required to be unique).
    pub stream_id: u32,
    /// Sensor geometry of the stream's events.
    pub res: Resolution,
}

/// Write the handshake.
pub fn write_hello<W: Write>(w: &mut W, hello: &Hello) -> Result<()> {
    w.write_all(HELLO_MAGIC)?;
    w.write_all(&[WIRE_VERSION])?;
    w.write_all(&hello.stream_id.to_le_bytes())?;
    w.write_all(&hello.res.width.to_le_bytes())?;
    w.write_all(&hello.res.height.to_le_bytes())?;
    Ok(())
}

/// Read and validate the handshake.
pub fn read_hello<R: Read>(r: &mut R) -> Result<Hello> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("truncated handshake")?;
    if &magic != HELLO_MAGIC {
        bail!("bad handshake magic: {magic:?}");
    }
    let mut ver = [0u8; 1];
    r.read_exact(&mut ver).context("truncated handshake")?;
    if ver[0] != WIRE_VERSION {
        bail!("unsupported wire version {}", ver[0]);
    }
    let mut id = [0u8; 4];
    r.read_exact(&mut id).context("truncated handshake")?;
    let mut dim = [0u8; 2];
    r.read_exact(&mut dim).context("truncated handshake")?;
    let width = u16::from_le_bytes(dim);
    r.read_exact(&mut dim).context("truncated handshake")?;
    let height = u16::from_le_bytes(dim);
    ensure!(width > 0 && height > 0, "degenerate resolution {width}x{height}");
    Ok(Hello { stream_id: u32::from_le_bytes(id), res: Resolution::new(width, height) })
}

/// Write the handshake ack (`ACK_OK` / `ACK_REJECTED`).
pub fn write_ack<W: Write>(w: &mut W, status: u8) -> Result<()> {
    w.write_all(&[status])?;
    Ok(())
}

/// Read the handshake ack; a non-OK status is an error.
pub fn read_ack<R: Read>(r: &mut R) -> Result<()> {
    let mut status = [0u8; 1];
    r.read_exact(&mut status).context("connection closed before ack")?;
    ensure!(status[0] == ACK_OK, "server rejected the stream (status {})", status[0]);
    Ok(())
}

/// Write one event frame: length prefix + binary container. `scratch` is
/// a recycled encode buffer (reaches frame size once, then reused).
pub fn write_frame<W: Write>(w: &mut W, scratch: &mut Vec<u8>, events: &[Event]) -> Result<()> {
    scratch.clear();
    write_binary(&mut *scratch, events)?;
    ensure!(
        scratch.len() <= MAX_FRAME_BYTES,
        "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap — send smaller chunks",
        scratch.len()
    );
    w.write_all(&(scratch.len() as u32).to_le_bytes())?;
    w.write_all(scratch)?;
    Ok(())
}

/// Write the end-of-stream marker (a zero-length frame).
pub fn write_eos<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(&0u32.to_le_bytes())?;
    Ok(())
}

/// The counters a served session reports back to its client.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Summary {
    /// Stream id echoed from the handshake.
    pub stream_id: u32,
    /// Events received.
    pub events_in: u64,
    /// Events surviving STCF.
    pub events_signal: u64,
    /// Corner tags.
    pub corners_total: u64,
    /// DVFS voltage switches.
    pub dvfs_switches: u64,
    /// Harris LUT refreshes consumed.
    pub lut_refreshes: u64,
    /// Server-side wall time (µs).
    pub wall_us: u64,
}

impl Summary {
    /// Condense a server-side [`RunReport`] into the wire summary.
    pub fn from_report(stream_id: u32, report: &RunReport) -> Self {
        Summary {
            stream_id,
            events_in: report.events_in as u64,
            events_signal: report.events_signal as u64,
            corners_total: report.corners_total,
            dvfs_switches: report.dvfs_switches,
            lut_refreshes: report.lut_refreshes,
            wall_us: (report.wall_s * 1e6) as u64,
        }
    }
}

/// Write the end-of-session summary.
pub fn write_summary<W: Write>(w: &mut W, s: &Summary) -> Result<()> {
    w.write_all(SUMMARY_MAGIC)?;
    w.write_all(&s.stream_id.to_le_bytes())?;
    for v in [
        s.events_in,
        s.events_signal,
        s.corners_total,
        s.dvfs_switches,
        s.lut_refreshes,
        s.wall_us,
    ] {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the end-of-session summary.
pub fn read_summary<R: Read>(r: &mut R) -> Result<Summary> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("connection closed before summary")?;
    if &magic != SUMMARY_MAGIC {
        bail!("bad summary magic: {magic:?}");
    }
    let mut id = [0u8; 4];
    r.read_exact(&mut id).context("truncated summary")?;
    let mut field = || -> Result<u64> {
        let mut b = [0u8; 8];
        r.read_exact(&mut b).context("truncated summary")?;
        Ok(u64::from_le_bytes(b))
    };
    Ok(Summary {
        stream_id: u32::from_le_bytes(id),
        events_in: field()?,
        events_signal: field()?,
        corners_total: field()?,
        dvfs_switches: field()?,
        lut_refreshes: field()?,
        wall_us: field()?,
    })
}

/// Client side of a served session: handshake, stream every chunk of
/// `source` as one frame, and return the server's summary. This is what
/// `nmc-tos feed` runs; tests drive it against a loopback
/// [`StreamServer`](super::StreamServer).
pub fn feed<S: EventSource + ?Sized>(
    stream: TcpStream,
    hello: Hello,
    source: &mut S,
) -> Result<Summary> {
    stream.set_nodelay(true).ok();
    let mut w = BufWriter::new(stream.try_clone().context("cloning connection")?);
    let mut r = BufReader::new(stream);
    write_hello(&mut w, &hello)?;
    w.flush()?;
    read_ack(&mut r)?;

    let mut chunk: Vec<Event> = Vec::new();
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        chunk.clear();
        if source.next_chunk(&mut chunk)? == 0 {
            break;
        }
        write_frame(&mut w, &mut scratch, &chunk)?;
    }
    write_eos(&mut w)?;
    w.flush()?;
    read_summary(&mut r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip() {
        let hello = Hello { stream_id: 42, res: Resolution::DAVIS240 };
        let mut buf = Vec::new();
        write_hello(&mut buf, &hello).unwrap();
        assert_eq!(read_hello(&mut &buf[..]).unwrap(), hello);
    }

    #[test]
    fn hello_rejects_garbage() {
        assert!(read_hello(&mut &b"XXXXXXXX\x01\0\0\0\0\xf0\0\xb4\0"[..]).is_err());
        // right magic, wrong version
        let mut buf = Vec::new();
        write_hello(&mut buf, &Hello { stream_id: 0, res: Resolution::TEST64 }).unwrap();
        buf[8] = 9;
        assert!(read_hello(&mut &buf[..]).is_err());
        // degenerate resolution
        let mut buf = Vec::new();
        write_hello(&mut buf, &Hello { stream_id: 0, res: Resolution::new(0, 64) }).unwrap();
        assert!(read_hello(&mut &buf[..]).is_err());
    }

    #[test]
    fn ack_roundtrip() {
        let mut buf = Vec::new();
        write_ack(&mut buf, ACK_OK).unwrap();
        assert!(read_ack(&mut &buf[..]).is_ok());
        let mut buf = Vec::new();
        write_ack(&mut buf, ACK_REJECTED).unwrap();
        assert!(read_ack(&mut &buf[..]).is_err());
    }

    #[test]
    fn summary_roundtrip() {
        let s = Summary {
            stream_id: 7,
            events_in: 1,
            events_signal: 2,
            corners_total: 3,
            dvfs_switches: 4,
            lut_refreshes: 5,
            wall_us: 6,
        };
        let mut buf = Vec::new();
        write_summary(&mut buf, &s).unwrap();
        assert_eq!(read_summary(&mut &buf[..]).unwrap(), s);
        buf.truncate(buf.len() - 1);
        assert!(read_summary(&mut &buf[..]).is_err());
    }

    #[test]
    fn frames_decode_through_framed_source() {
        use crate::events::source::FramedStreamSource;
        let events: Vec<Event> =
            (0..500).map(|i| Event::on((i % 60) as u16, (i % 40) as u16, i as u64)).collect();
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        for chunk in events.chunks(123) {
            write_frame(&mut wire, &mut scratch, chunk).unwrap();
        }
        write_eos(&mut wire).unwrap();
        let mut src = FramedStreamSource::new(&wire[..]);
        let mut out = Vec::new();
        while src.next_chunk(&mut out).unwrap() > 0 {}
        assert_eq!(out, events);
    }
}
