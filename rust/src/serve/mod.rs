//! Multi-stream serving: one process driving many concurrent event
//! streams through the Fig. 2 pipeline over a shared engine pool.
//!
//! [`StreamServer`] owns a pool of worker threads (one active session per
//! worker, `max_streams` total) and two front doors:
//!
//! * **TCP** ([`StreamServer::serve`]) — each connection is one session:
//!   a handshake declaring the stream's resolution and protocol version
//!   ([`wire::Hello`]), then length-prefixed binary event frames
//!   (the on-disk codec, relayed without re-encoding), answered with a
//!   counters [`wire::Summary`] when the stream ends. Protocol-v2
//!   sessions additionally receive corner batches and live per-session
//!   stats *while* the stream runs — a [`wire::WireSink`] attached to
//!   the session's pipeline (`--stats-interval` sets the stats cadence);
//!   v1 clients get the summary-only session unchanged. `nmc-tos feed`
//!   is the matching client for both versions.
//! * **in-process** ([`StreamServer::submit`]) — tests, benches and
//!   embedding applications hand the server an [`EventSource`] directly
//!   and get the full [`RunReport`] back through a [`SessionHandle`].
//!
//! Every session runs the exact same `run_stream` machinery as a
//! single-shot `nmc-tos run`: a served stream's report is bit-identical
//! to running the same events sequentially (the integration test in
//! `rust/tests/serve_integration.rs` proves it for concurrent sessions).
//! Expensive state that does not depend on stream *content* — compiled
//! Harris engines and FBF scratch buffers — lives in a per-resolution
//! [`EnginePool`] shared by all workers, so N streams don't pay N engine
//! setups; per-stream state is just the pipeline itself (surface + STCF
//! history + DVFS counters), which is what keeps many streams resident
//! on one box.
//!
//! Failure isolation: a session that errors (dropped connection, corrupt
//! frame, handshake garbage) is counted in [`ServerStats::sessions_failed`],
//! its worker moves on to the next session, and nothing shared is
//! poisoned.

pub mod degrade;
pub mod pool;
pub mod wire;

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::sink::{CornerSink, NullSink};
use crate::coordinator::{make_backend, make_detector, DynPipeline, PipelineConfig, RunReport};
use crate::events::source::{EventSource, TcpStreamSource};
use crate::events::{Event, Resolution};
// every sync primitive comes from the shim so the loom models below (and
// in pool.rs) check the exact code production runs — see util::sync docs
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{mpsc, run_isolated, thread, Arc, Mutex};

pub use degrade::{DegradationPolicy, DegradeConfig, SwitchableDetector};
pub use pool::{EnginePool, PoolStats};
pub use wire::{Hello, Summary, WireSink};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Per-session pipeline template. A session clones it and overrides
    /// `res` with the handshake's geometry; `async_refresh` is forced off
    /// (the async worker loads a private engine, which would bypass the
    /// shared pool). For unbounded streams keep `record_per_event` off.
    /// `base.stats_interval_events` sets the cadence of the live `Stats`
    /// messages v2 sessions stream back (`serve --stats-interval`).
    pub base: PipelineConfig,
    /// Worker count = max concurrent sessions. Further connections queue
    /// in the listener backlog until a worker frees up (no event loss —
    /// backpressure, not drops).
    pub max_streams: usize,
    /// Retain every session's full [`RunReport`] (keyed by stream id) for
    /// [`StreamServer::take_reports`]. Tests and short-lived servers
    /// only — reports hold per-event vectors when recording is on.
    pub keep_reports: bool,
    /// Per-connection socket read/write timeout (default 30 s). A client
    /// that stays silent longer — live feeds with sparse traffic send
    /// keep-alive frames (empty containers) — fails its session and
    /// frees the worker; without a timeout, `max_streams` idle
    /// connections would pin every worker forever. `None` blocks
    /// indefinitely (trusted peers only).
    pub io_timeout: Option<Duration>,
    /// Adaptive degradation under overload (`None` = off). When set,
    /// every session gets a [`degrade::DegradationPolicy`] watching its
    /// real-time lag at chunk boundaries, stepping the backend voltage
    /// down and finally swapping to the cheaper fallback detector
    /// before the session would have to be dropped; rate-driven DVFS is
    /// disabled for governed sessions (the governor owns the voltage
    /// knob). Degradation state streams to v3 clients on every stats
    /// frame and aggregates into the `degrade_*` [`ServerStats`]
    /// counters.
    pub degrade: Option<degrade::DegradeConfig>,
}

impl ServeConfig {
    /// Serve `base` with default worker count (4), no report retention,
    /// and a 30 s connection timeout.
    pub fn new(base: PipelineConfig) -> Self {
        Self {
            base,
            max_streams: 4,
            keep_reports: false,
            io_timeout: Some(Duration::from_secs(30)),
            degrade: None,
        }
    }
}

/// Aggregate serving telemetry (monotonic counters over the server's
/// lifetime; a snapshot, not a live view).
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Sessions handed to workers (TCP accepts + in-process submissions).
    pub sessions_accepted: u64,
    /// Sessions that ran to a clean end of stream.
    pub sessions_completed: u64,
    /// Sessions that died mid-stream (dropped connection, bad handshake,
    /// corrupt frame, pipeline error). Their worker cleaned up and moved
    /// on.
    pub sessions_failed: u64,
    /// Events ingested across completed sessions.
    pub events_in: u64,
    /// Events surviving STCF across completed sessions.
    pub events_signal: u64,
    /// Corner tags across completed sessions.
    pub corners_total: u64,
    /// Summed session wall time (s). `events_in / busy_s` is the mean
    /// per-worker throughput; it exceeds single-stream throughput times
    /// worker count only if sessions overlapped.
    pub busy_s: f64,
    /// Most concurrently active sessions observed.
    pub peak_concurrent: usize,
    /// Worst per-stream real-time lag (s): session wall time minus the
    /// stream's own event-time span. Positive = that stream fell behind
    /// a live sensor; negative = processed faster than real time. 0
    /// until the first session completes.
    pub worst_lag_s: f64,
    /// Completed TCP sessions that negotiated protocol v2 or newer
    /// (streamed results).
    pub sessions_v2: u64,
    /// Corners streamed to v2 clients in `CornerBatch` messages.
    pub corners_streamed: u64,
    /// Live `Stats` messages sent to v2 clients
    /// (`--stats-interval` cadence).
    pub stats_frames: u64,
    /// Degradation voltage step-downs across sessions
    /// ([`ServeConfig::degrade`]).
    pub degrade_vdd_steps: u64,
    /// Degradation detector swaps to the fallback across sessions.
    pub degrade_detector_swaps: u64,
    /// Sessions that degraded and fully recovered to nominal.
    pub degrade_recoveries: u64,
    /// Sessions that degraded at least once.
    pub sessions_degraded: u64,
    /// Engine-pool counters (cold compiles vs pooled reuses).
    pub pool: PoolStats,
}

impl ServerStats {
    /// Mean ingest rate over busy time (events/s); 0 before any session.
    pub fn events_per_sec(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.events_in as f64 / self.busy_s
        } else {
            0.0
        }
    }
}

/// One queued session (TCP connection or in-process source).
enum Session {
    Tcp(TcpStream),
    Local {
        stream_id: u32,
        res: Resolution,
        source: Box<dyn EventSource + Send>,
        reply: mpsc::Sender<Result<RunReport>>,
    },
}

/// Handle to an in-process session: resolves to the session's full
/// [`RunReport`] when the stream ends.
#[derive(Debug)]
pub struct SessionHandle {
    rx: mpsc::Receiver<Result<RunReport>>,
}

impl SessionHandle {
    /// Block until the session finishes and return its report.
    pub fn join(self) -> Result<RunReport> {
        self.rx.recv().context("server shut down before the session finished")?
    }
}

/// State shared between the accept loop, workers, and the public API.
struct Shared {
    cfg: ServeConfig,
    pool: EnginePool,
    stats: Mutex<ServerStats>,
    active: AtomicUsize,
    reports: Mutex<Vec<(u32, RunReport)>>,
    engine_warned: AtomicBool,
}

/// Multi-stream server: a worker pool driving concurrent pipeline
/// sessions over a shared [`EnginePool`]. See the [module docs](self)
/// for the serving model and `nmc-tos serve` for the CLI front end.
pub struct StreamServer {
    shared: Arc<Shared>,
    tx: Option<mpsc::SyncSender<Session>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for StreamServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamServer")
            .field("max_streams", &self.shared.cfg.max_streams)
            .field("active", &self.shared.active.load(Ordering::Relaxed))
            .finish()
    }
}

impl StreamServer {
    /// Spawn the worker pool (`cfg.max_streams` threads, each running one
    /// session at a time). The engine pool reads artifacts from
    /// `cfg.base.artifact_dir` (or auto-discovers).
    pub fn new(cfg: ServeConfig) -> Result<StreamServer> {
        anyhow::ensure!(cfg.max_streams >= 1, "max_streams must be >= 1");
        let pool = EnginePool::new(cfg.base.artifact_dir.clone());
        let shared = Arc::new(Shared {
            cfg,
            pool,
            stats: Mutex::new(ServerStats::default()),
            active: AtomicUsize::new(0),
            reports: Mutex::new(Vec::new()),
            engine_warned: AtomicBool::new(false),
        });
        // rendezvous channel: a session is accepted exactly when a worker
        // is ready to run it — everything else waits in the OS backlog
        let (tx, rx) = mpsc::sync_channel::<Session>(0);
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..shared.cfg.max_streams)
            .map(|_| {
                let shared = Arc::clone(&shared);
                let rx = Arc::clone(&rx);
                thread::spawn(move || worker_loop(&shared, &rx))
            })
            .collect();
        Ok(StreamServer { shared, tx: Some(tx), workers })
    }

    /// Enqueue an in-process session (blocks until a worker picks it up).
    /// The returned handle resolves to the session's full [`RunReport`].
    pub fn submit(
        &self,
        stream_id: u32,
        res: Resolution,
        source: Box<dyn EventSource + Send>,
    ) -> Result<SessionHandle> {
        let (reply, rx) = mpsc::channel();
        self.shared.stats.lock().unwrap().sessions_accepted += 1;
        self.tx
            .as_ref()
            .expect("server already shut down")
            .send(Session::Local { stream_id, res, source, reply })
            .map_err(|_| anyhow::anyhow!("server workers have shut down"))?;
        Ok(SessionHandle { rx })
    }

    /// Accept loop: hand each connection to the worker pool as one
    /// session. With `max_sessions = Some(n)` the loop returns after
    /// accepting `n` connections (scripted demos, tests); `None` serves
    /// until the process exits.
    pub fn serve(&self, listener: &TcpListener, max_sessions: Option<usize>) -> Result<()> {
        let tx = self.tx.as_ref().expect("server already shut down");
        let mut accepted = 0usize;
        for conn in listener.incoming() {
            let conn = conn.context("accepting connection")?;
            self.shared.stats.lock().unwrap().sessions_accepted += 1;
            tx.send(Session::Tcp(conn))
                .map_err(|_| anyhow::anyhow!("server workers have shut down"))?;
            accepted += 1;
            if max_sessions.is_some_and(|n| accepted >= n) {
                break;
            }
        }
        Ok(())
    }

    /// Snapshot of the aggregate serving telemetry.
    pub fn stats(&self) -> ServerStats {
        let mut stats = self.shared.stats.lock().unwrap().clone();
        stats.pool = self.shared.pool.stats();
        stats
    }

    /// Sessions currently running.
    pub fn active_sessions(&self) -> usize {
        self.shared.active.load(Ordering::Relaxed)
    }

    /// Drain the retained `(stream_id, report)` pairs
    /// ([`ServeConfig::keep_reports`]; empty when retention is off).
    pub fn take_reports(&self) -> Vec<(u32, RunReport)> {
        std::mem::take(&mut *self.shared.reports.lock().unwrap())
    }

    /// Stop accepting sessions, wait for in-flight ones to finish, and
    /// return the final stats. (Dropping the server does the same minus
    /// the stats.)
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown_workers();
        self.stats()
    }

    fn shutdown_workers(&mut self) {
        drop(self.tx.take()); // workers see the channel close and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for StreamServer {
    fn drop(&mut self) {
        self.shutdown_workers();
    }
}

/// Worker: run queued sessions until the channel closes.
fn worker_loop(shared: &Shared, rx: &Mutex<mpsc::Receiver<Session>>) {
    loop {
        // take the lock only to dequeue, never while running a session
        let session = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return, // server shut down
        };
        let active = shared.active.fetch_add(1, Ordering::SeqCst) + 1;
        {
            let mut stats = shared.stats.lock().unwrap();
            stats.peak_concurrent = stats.peak_concurrent.max(active);
        }
        // a panicking session must not take its worker (and a slice of
        // server capacity) down with it: catch the unwind, count it as a
        // failed session, and keep serving
        let outcome = run_isolated(|| match session {
            Session::Tcp(stream) => run_tcp_session(shared, stream),
            Session::Local { stream_id, res, mut source, reply } => {
                let result = run_session(shared, stream_id, res, &mut source, &mut NullSink);
                match result {
                    Ok((report, lag_s)) => {
                        record_completion(shared, stream_id, &report, lag_s);
                        let _ = reply.send(Ok(report));
                        Ok(())
                    }
                    Err(e) => {
                        let _ = reply.send(Err(anyhow::anyhow!("{e:#}")));
                        Err(e)
                    }
                }
            }
        });
        shared.active.fetch_sub(1, Ordering::SeqCst);
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                shared.stats.lock().unwrap().sessions_failed += 1;
                eprintln!("serve: session failed: {e:#}");
            }
            Err(_) => {
                shared.stats.lock().unwrap().sessions_failed += 1;
                eprintln!("serve: session panicked; worker continues");
            }
        }
    }
}

/// Largest pixel count a TCP handshake may declare (a 4K-class sensor).
/// The resolution sizes real allocations (surface, STCF history, f32
/// frames), so like the frame length prefix it is untrusted input: a
/// bogus `Hello` gets `ACK_REJECTED`, not a multi-GB allocation.
const MAX_SESSION_PIXELS: usize = 4096 * 4096;

/// One TCP session: handshake (negotiating the protocol version),
/// stream — with results flowing back through a [`WireSink`] for v2
/// clients — then the summary. Any error mid-way drops the connection;
/// the caller counts it as failed.
fn run_tcp_session(shared: &Shared, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    // a silent peer must not pin this worker forever: reads and writes
    // give up after the configured timeout and fail the session — for v2
    // sessions that includes a client that stops draining its corner
    // batches (the write stalls, times out, and frees the worker)
    stream.set_read_timeout(shared.cfg.io_timeout).ok();
    stream.set_write_timeout(shared.cfg.io_timeout).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    let hello = match wire::read_hello(&mut reader) {
        Ok(h) if h.res.pixels() > MAX_SESSION_PIXELS => {
            let _ = wire::write_ack(&mut &stream, wire::ACK_REJECTED);
            anyhow::bail!(
                "handshake: resolution {}x{} exceeds the {MAX_SESSION_PIXELS}-pixel cap",
                h.res.width,
                h.res.height
            );
        }
        Ok(h) => h,
        Err(e) => {
            let _ = wire::write_ack(&mut &stream, wire::ACK_REJECTED);
            return Err(e.context("handshake"));
        }
    };
    wire::write_ack_for(&mut &stream, wire::ACK_OK, hello.version)?;
    (&stream).flush()?;

    let framed: TcpStreamSource = crate::events::source::FramedStreamSource::new(reader);
    let mut source = BoundsCheckedSource { inner: framed, res: hello.res };
    let negotiated = hello.version.min(wire::WIRE_VERSION);
    if negotiated >= wire::WIRE_V2 {
        // v2/v3: a WireSink rides the pipeline, streaming corner batches
        // at chunk boundaries and stats at the configured interval; the
        // tagged summary goes through the same writer so ordering holds
        let writer = BufWriter::new(stream.try_clone().context("cloning connection")?);
        let mut sink = WireSink::new(writer, negotiated);
        let (report, lag_s) =
            run_session(shared, hello.stream_id, hello.res, &mut source, &mut sink)?;
        let (corners_streamed, stats_frames) =
            sink.finish(&wire::Summary::from_report(hello.stream_id, &report))?;
        record_completion(shared, hello.stream_id, &report, lag_s);
        let mut stats = shared.stats.lock().unwrap();
        stats.sessions_v2 += 1;
        stats.corners_streamed += corners_streamed;
        stats.stats_frames += stats_frames;
    } else {
        // v1: summary-only, byte-compatible with pre-v2 servers
        let (report, lag_s) =
            run_session(shared, hello.stream_id, hello.res, &mut source, &mut NullSink)?;
        wire::write_summary(&mut &stream, &wire::Summary::from_report(hello.stream_id, &report))?;
        (&stream).flush()?;
        record_completion(shared, hello.stream_id, &report, lag_s);
    }
    Ok(())
}

/// Build a pipeline for one session (engine + scratch from the pool),
/// run the stream — driving `sink` with corners, scores and live stats
/// at event rate — and return the report plus the session's real-time
/// lag (wall seconds minus event-time span).
fn run_session<S: EventSource + ?Sized>(
    shared: &Shared,
    stream_id: u32,
    res: Resolution,
    source: &mut S,
    sink: &mut dyn CornerSink,
) -> Result<(RunReport, f64)> {
    let mut cfg = shared.cfg.base.clone();
    cfg.res = res;
    // sync refresh only: the async worker loads a private engine, which
    // would bypass the pool and double-load artifacts per session
    cfg.async_refresh = false;
    if shared.cfg.degrade.is_some() {
        // the degradation governor owns the voltage knob — rate-driven
        // DVFS would fight its retargets
        cfg.dvfs = None;
    }

    let backend = make_backend(&cfg).with_context(|| format!("stream {stream_id}: backend"))?;
    let mut detector = make_detector(&cfg);
    // degradation: wrap the detector so the governor can swap it for the
    // cheaper fallback mid-stream; the Rc'd state stays on this worker
    let degrade_state = if let Some(dc) = &shared.cfg.degrade {
        let state = std::rc::Rc::new(degrade::DegradeShared::default());
        let mut fcfg = cfg.clone();
        fcfg.detector = dc.fallback;
        let fallback = make_detector(&fcfg);
        detector =
            Box::new(SwitchableDetector::new(detector, fallback, std::rc::Rc::clone(&state)));
        Some(state)
    } else {
        None
    };
    let engine = if detector.wants_lut() {
        match shared.pool.checkout_engine(res) {
            Ok(engine) => Some(engine),
            Err(e) => {
                // no artifacts / no PJRT runtime: serve engine-less (LUT
                // scores stay zero) instead of refusing streams, and say
                // so once rather than once per session
                if !shared.engine_warned.swap(true, Ordering::Relaxed) {
                    eprintln!("serve: running engine-less ({e:#})");
                }
                None
            }
        }
    } else {
        None
    };
    let scratch = shared.pool.checkout_scratch(res);

    let nominal_vdd = cfg.fixed_vdd;
    let mut pipe = DynPipeline::with_parts_and_scratch(cfg, backend, detector, engine, scratch)?;
    if let (Some(dc), Some(state)) = (&shared.cfg.degrade, &degrade_state) {
        pipe.set_governor(Box::new(DegradationPolicy::new(
            dc.clone(),
            std::rc::Rc::clone(state),
            nominal_vdd,
        )));
    }
    let mut tracked = SpanSource::new(source);
    let result = pipe.run_stream_with(&mut tracked, sink);
    let span_s = tracked.span_s();
    // engine + scratch go back to the pool whether the run succeeded or
    // not — a failed stream must not leak the shared engine
    let (engine, scratch) = pipe.into_parts();
    if let Some(engine) = engine {
        shared.pool.checkin_engine(engine);
    }
    shared.pool.checkin_scratch(res, scratch);

    // fold the session's degradation activity into the aggregate
    // counters (success or failure — shed work happened either way)
    if let Some(state) = &degrade_state {
        let mut stats = shared.stats.lock().unwrap();
        stats.degrade_vdd_steps += state.vdd_steps();
        stats.degrade_detector_swaps += state.detector_swaps();
        stats.degrade_recoveries += state.recoveries();
        stats.sessions_degraded += state.was_degraded() as u64;
    }

    let report = result.with_context(|| format!("stream {stream_id}"))?;
    let lag_s = report.wall_s - span_s;
    Ok((report, lag_s))
}

/// Fold a finished session into the aggregate stats (and retained
/// reports, if enabled).
fn record_completion(shared: &Shared, stream_id: u32, report: &RunReport, lag_s: f64) {
    let mut stats = shared.stats.lock().unwrap();
    stats.sessions_completed += 1;
    stats.events_in += report.events_in as u64;
    stats.events_signal += report.events_signal as u64;
    stats.corners_total += report.corners_total;
    stats.busy_s += report.wall_s;
    // the first session seeds the value so faster-than-realtime fleets
    // report their true (negative) worst lag instead of flooring at 0
    stats.worst_lag_s =
        if stats.sessions_completed == 1 { lag_s } else { stats.worst_lag_s.max(lag_s) };
    drop(stats);
    if shared.cfg.keep_reports {
        shared.reports.lock().unwrap().push((stream_id, report.clone()));
    }
}

/// [`EventSource`] adapter rejecting events outside the session's
/// declared resolution. Frame payloads are untrusted remote input: an
/// out-of-range `y` would index past the surface/STCF arrays (worker
/// panic), and an out-of-range `x` with in-range `y` would alias into
/// the next row (silent corruption) — neither may reach the pipeline.
struct BoundsCheckedSource<S> {
    inner: S,
    res: Resolution,
}

impl<S: EventSource> EventSource for BoundsCheckedSource<S> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        let start = out.len();
        let n = self.inner.next_chunk(out)?;
        for ev in &out[start..] {
            anyhow::ensure!(
                ev.x < self.res.width && ev.y < self.res.height,
                "event at ({}, {}) outside the declared {}x{} sensor",
                ev.x,
                ev.y,
                self.res.width,
                self.res.height
            );
        }
        Ok(n)
    }

    fn size_hint(&self) -> Option<usize> {
        self.inner.size_hint()
    }
}

/// [`EventSource`] adapter recording the stream's event-time span (first
/// to last timestamp) for the per-stream real-time lag metric.
struct SpanSource<'a, S: ?Sized> {
    inner: &'a mut S,
    first_t: Option<u64>,
    last_t: u64,
}

impl<'a, S: EventSource + ?Sized> SpanSource<'a, S> {
    fn new(inner: &'a mut S) -> Self {
        Self { inner, first_t: None, last_t: 0 }
    }

    /// Event-time span in seconds (0 for empty streams).
    fn span_s(&self) -> f64 {
        match self.first_t {
            Some(first) => (self.last_t.saturating_sub(first)) as f64 * 1e-6,
            None => 0.0,
        }
    }
}

impl<S: EventSource + ?Sized> EventSource for SpanSource<'_, S> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        let start = out.len();
        let n = self.inner.next_chunk(out)?;
        if n > 0 {
            // the stream is time-sorted: first/last of the chunk suffice
            if self.first_t.is_none() {
                self.first_t = Some(out[start].t);
            }
            self.last_t = out[out.len() - 1].t;
        }
        Ok(n)
    }

    fn size_hint(&self) -> Option<usize> {
        self.inner.size_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendKind, DetectorKind, Pipeline};
    use crate::datasets::synthetic::SceneConfig;

    fn base_cfg() -> PipelineConfig {
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast; // engine-less: no artifacts needed
        cfg
    }

    #[test]
    fn local_session_matches_sequential_run() {
        let events = SceneConfig::test64().build(31).generate(6_000);
        let mut pipe = Pipeline::from_config_without_engine(base_cfg()).unwrap();
        let want = pipe.run(&events).unwrap();

        let server = StreamServer::new(ServeConfig::new(base_cfg())).unwrap();
        let source = SceneConfig::test64().build(31).into_source(6_000, 512);
        let got = server.submit(7, Resolution::TEST64, Box::new(source)).unwrap().join().unwrap();

        assert_eq!(want.final_tos, got.final_tos);
        assert_eq!(want.scores, got.scores);
        assert_eq!(want.corners, got.corners);
        assert_eq!(want.events_in, got.events_in);

        let stats = server.shutdown();
        assert_eq!(stats.sessions_accepted, 1);
        assert_eq!(stats.sessions_completed, 1);
        assert_eq!(stats.sessions_failed, 0);
        assert_eq!(stats.events_in, 6_000);
    }

    #[test]
    fn many_local_sessions_share_one_server() {
        let mut cfg = base_cfg();
        cfg.backend = BackendKind::Sharded;
        cfg.shards = 2;
        let mut serve_cfg = ServeConfig::new(cfg);
        serve_cfg.max_streams = 3;
        let server = StreamServer::new(serve_cfg).unwrap();

        let handles: Vec<SessionHandle> = (0..6u32)
            .map(|i| {
                let source = SceneConfig::test64().build(100 + i as u64).into_source(2_000, 257);
                server.submit(i, Resolution::TEST64, Box::new(source)).unwrap()
            })
            .collect();
        for h in handles {
            let report = h.join().unwrap();
            assert_eq!(report.events_in, 2_000);
        }
        let stats = server.shutdown();
        assert_eq!(stats.sessions_completed, 6);
        assert!(stats.peak_concurrent >= 1);
        assert!(stats.events_per_sec() > 0.0);
    }

    #[test]
    fn failed_session_is_counted_and_isolated() {
        /// A source that errors mid-stream (a dropped connection).
        struct Dying(usize);
        impl EventSource for Dying {
            fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
                if self.0 == 0 {
                    anyhow::bail!("simulated connection drop");
                }
                self.0 -= 1;
                out.push(Event::on(1, 1, 1));
                Ok(1)
            }
        }

        let server = StreamServer::new(ServeConfig::new(base_cfg())).unwrap();
        let err = server.submit(1, Resolution::TEST64, Box::new(Dying(3))).unwrap().join();
        assert!(err.is_err());

        // the worker that ran the failed session still serves new ones
        let source = SceneConfig::test64().build(5).into_source(1_000, 128);
        let ok = server.submit(2, Resolution::TEST64, Box::new(source)).unwrap().join();
        assert!(ok.is_ok());

        let stats = server.shutdown();
        assert_eq!(stats.sessions_failed, 1);
        assert_eq!(stats.sessions_completed, 1);
    }

    #[test]
    fn keep_reports_retains_by_stream_id() {
        let mut serve_cfg = ServeConfig::new(base_cfg());
        serve_cfg.keep_reports = true;
        let server = StreamServer::new(serve_cfg).unwrap();
        for id in [11u32, 22] {
            let source = SceneConfig::test64().build(id as u64).into_source(1_500, 300);
            server.submit(id, Resolution::TEST64, Box::new(source)).unwrap().join().unwrap();
        }
        let mut reports = server.take_reports();
        reports.sort_by_key(|(id, _)| *id);
        let ids: Vec<u32> = reports.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![11, 22]);
        assert!(server.take_reports().is_empty(), "take drains");
    }

    #[test]
    fn span_source_tracks_event_time() {
        let events = vec![
            Event::on(0, 0, 1_000_000),
            Event::on(1, 1, 1_500_000),
            Event::on(2, 2, 3_000_000),
        ];
        let mut inner = crate::events::source::SliceSource::new(&events, 2);
        let mut span = SpanSource::new(&mut inner);
        let mut out = Vec::new();
        while span.next_chunk(&mut out).unwrap() > 0 {}
        assert!((span.span_s() - 2.0).abs() < 1e-9);
    }
}

/// Loom models of the server's synchronization protocol: the rendezvous
/// session handoff, shutdown racing an in-flight session, failure
/// isolation, and two workers contending for the shared queue. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_tests`
/// (see DESIGN.md §Correctness tooling). Every sync primitive these
/// paths touch — including the shim's own rendezvous channel — comes
/// from `util::sync`, so loom explores the real lock/wait protocol.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::coordinator::DetectorKind;

    /// Bounded loom exploration: `LOOM_MAX_PREEMPTIONS` wins when set
    /// (the CI lane sets it); otherwise bound preemptions so a local
    /// `--cfg loom` run finishes in seconds, not hours.
    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = loom::model::Builder::new();
        if b.preemption_bound.is_none() {
            b.preemption_bound = Some(2);
        }
        b.check(f);
    }

    fn base_cfg() -> PipelineConfig {
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast; // engine-less: no artifacts, no FS
        cfg
    }

    /// A tiny owned one-chunk source (loom threads need 'static data).
    struct Burst(Vec<Event>);

    impl EventSource for Burst {
        fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
            let n = self.0.len();
            out.append(&mut self.0);
            Ok(n)
        }
    }

    fn burst(n: u16) -> Box<Burst> {
        Box::new(Burst((0..n).map(|i| Event::on(i % 8, i % 8, i as u64)).collect()))
    }

    /// A source that fails on first read (a dropped connection).
    struct Dying;

    impl EventSource for Dying {
        fn next_chunk(&mut self, _out: &mut Vec<Event>) -> Result<usize> {
            anyhow::bail!("simulated connection drop")
        }
    }

    /// The core serving interleaving: a rendezvous submit completes only
    /// when the worker takes the session, shutdown may overtake the
    /// in-flight session (tx dropped while the worker is mid-run), and
    /// the reply must still reach the handle afterwards.
    #[test]
    fn loom_rendezvous_handoff_then_shutdown_races_inflight_session() {
        model(|| {
            let mut cfg = ServeConfig::new(base_cfg());
            cfg.max_streams = 1;
            let server = StreamServer::new(cfg).unwrap();
            let handle = server.submit(1, Resolution::TEST64, burst(3)).unwrap();
            // shutdown before join: drops the queue while the session may
            // still be running; must block until the worker drains it
            let stats = server.shutdown();
            let report = handle.join().unwrap();
            assert_eq!(report.events_in, 3);
            assert_eq!(stats.sessions_accepted, 1);
            assert_eq!(stats.sessions_completed, 1);
            assert_eq!(stats.sessions_failed, 0);
        });
    }

    /// A failing session must not wedge the worker, leak `active`, or
    /// poison anything shared; the next session runs normally.
    #[test]
    fn loom_failed_session_frees_worker() {
        model(|| {
            let mut cfg = ServeConfig::new(base_cfg());
            cfg.max_streams = 1;
            let server = StreamServer::new(cfg).unwrap();
            let bad = server.submit(1, Resolution::TEST64, Box::new(Dying)).unwrap();
            assert!(bad.join().is_err());
            let good = server.submit(2, Resolution::TEST64, burst(1)).unwrap();
            assert_eq!(good.join().unwrap().events_in, 1);
            let stats = server.shutdown();
            assert_eq!(stats.sessions_failed, 1);
            assert_eq!(stats.sessions_completed, 1);
            assert_eq!(stats.active_check(), 0);
        });
    }

    /// Two workers contend for the shared queue receiver: one blocks in
    /// `recv` *while holding the queue's outer mutex* (the inner condvar
    /// wait must release only the inner lock), the other blocks on the
    /// outer mutex. Both sessions must complete under every schedule.
    #[test]
    fn loom_two_workers_share_the_queue() {
        model(|| {
            let mut cfg = ServeConfig::new(base_cfg());
            cfg.max_streams = 2;
            let server = StreamServer::new(cfg).unwrap();
            let a = server.submit(1, Resolution::TEST64, burst(1)).unwrap();
            let b = server.submit(2, Resolution::TEST64, burst(2)).unwrap();
            assert_eq!(a.join().unwrap().events_in, 1);
            assert_eq!(b.join().unwrap().events_in, 2);
            let stats = server.shutdown();
            assert_eq!(stats.sessions_completed, 2);
        });
    }

    impl ServerStats {
        /// Loom-only probe: completed + failed must cover accepted once
        /// shutdown returns (no session lost in the handoff).
        fn active_check(&self) -> u64 {
            self.sessions_accepted - self.sessions_completed - self.sessions_failed
        }
    }
}
