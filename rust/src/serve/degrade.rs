//! Adaptive degradation for served streams: shed load *gracefully*
//! before dropping a session.
//!
//! A live event stream has a real-time contract — fall behind the sensor
//! and the backlog grows without bound. When a serving worker cannot
//! keep up (overload spike, noisy scene, slow disk), the conventional
//! answers are to drop events or drop the session. This module does what
//! the paper's DVFS story suggests instead: spend *fidelity* before
//! availability. The [`DegradationPolicy`] watches the session's
//! real-time lag at every source-chunk boundary (the coordinator's
//! [`Governor`] hook) and, when the lag crosses the shed threshold,
//! degrades in small steps:
//!
//! 1. **Voltage step-down** — retarget the backend supply toward
//!    `vdd_min_v` one [`DegradeConfig::vdd_step_v`] at a time. On the
//!    NMC backend this trades read-fidelity (the seeded fault map — see
//!    `nmc::montecarlo`) for energy, exactly the paper's Vdd/BER
//!    trade-off, while every result stays deterministically derived from
//!    `(seed, vdd)`.
//! 2. **Detector swap** — once at the voltage floor, switch the session
//!    to the cheaper [`DegradeConfig::fallback`] detector via
//!    [`SwitchableDetector`]; while swapped the FBF/LUT refresh stage is
//!    shed too ([`SwitchableDetector::wants_lut`] turns false). The
//!    swapped-in SAE detector starts cold and warms its surface from the
//!    events it scores.
//!
//! Recovery is the exact mirror with hysteresis: only after
//! [`DegradeConfig::recover_polls`] consecutive calm polls (lag below
//! `lag_recover_s`, which is well below `lag_shed_s`) does the policy
//! undo one move — detector first, then voltage — one move per poll, so
//! a marginal session cannot oscillate. A full return to nominal counts
//! one recovery.
//!
//! All shared state is `Rc<Cell<_>>`-grade: the policy, the switchable
//! detector and the session runner all live on one worker thread, so no
//! sync primitives are needed (and none are used — this module stays out
//! of the loom-shimmed set). Wall-clock time is intentionally part of
//! the model: degradation reacts to *real* lag, so governed sessions are
//! not bit-reproducible across machines — which is why the policy only
//! exists in `serve` and the deterministic harnesses (`run`, `eval`,
//! `vdd-sweep`) never install one.

use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

use crate::coordinator::sink::LiveStats;
use crate::coordinator::{DetectorKind, Governor};
use crate::detectors::EventScorer;
use crate::events::Event;

/// Degradation thresholds and steps (`serve --degrade*` flags).
#[derive(Debug, Clone)]
pub struct DegradeConfig {
    /// Real-time lag (s) above which the policy sheds one step per poll.
    pub lag_shed_s: f64,
    /// Lag (s) below which a poll counts as calm; must be well under
    /// `lag_shed_s` (the hysteresis band).
    pub lag_recover_s: f64,
    /// Consecutive calm polls required before each recovery move.
    pub recover_polls: u32,
    /// Polls to skip between consecutive shed moves, letting the
    /// previous step take effect before judging it insufficient.
    pub cooldown_polls: u32,
    /// Supply-voltage decrement per shed step (V).
    pub vdd_step_v: f64,
    /// Voltage floor (V); at the floor the next shed move is the
    /// detector swap.
    pub vdd_min_v: f64,
    /// Cheaper detector swapped in at the final degradation step.
    pub fallback: DetectorKind,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        Self {
            lag_shed_s: 0.25,
            lag_recover_s: 0.05,
            recover_polls: 2,
            cooldown_polls: 1,
            vdd_step_v: 0.2,
            vdd_min_v: 0.6,
            fallback: DetectorKind::Fast,
        }
    }
}

/// Single-threaded state shared between a session's
/// [`DegradationPolicy`], its [`SwitchableDetector`], and the session
/// runner (which folds the counters into `ServerStats` at session end).
#[derive(Debug, Default)]
pub struct DegradeShared {
    /// Voltage step-downs performed.
    vdd_steps: Cell<u64>,
    /// Detector swaps to the fallback performed.
    detector_swaps: Cell<u64>,
    /// Full recoveries back to nominal.
    recoveries: Cell<u64>,
    /// Route scores to the fallback detector?
    use_cheap: Cell<bool>,
    /// Active degradation moves (0 = nominal).
    level: Cell<u32>,
    /// Did this session ever degrade?
    was_degraded: Cell<bool>,
}

impl DegradeShared {
    /// Voltage step-downs performed over the session.
    pub fn vdd_steps(&self) -> u64 {
        self.vdd_steps.get()
    }

    /// Detector swaps performed over the session.
    pub fn detector_swaps(&self) -> u64 {
        self.detector_swaps.get()
    }

    /// Full recoveries to nominal over the session.
    pub fn recoveries(&self) -> u64 {
        self.recoveries.get()
    }

    /// Current degradation level (0 = nominal).
    pub fn level(&self) -> u32 {
        self.level.get()
    }

    /// Did the session degrade at least once?
    pub fn was_degraded(&self) -> bool {
        self.was_degraded.get()
    }
}

/// The per-session load governor: watches real-time lag at chunk
/// boundaries and walks the degradation ladder described in the
/// [module docs](self).
#[derive(Debug)]
pub struct DegradationPolicy {
    cfg: DegradeConfig,
    shared: Rc<DegradeShared>,
    /// Voltage to recover back up to.
    nominal_vdd: f64,
    /// Voltage currently commanded.
    current_vdd: f64,
    /// Wall-clock and event-time origin, fixed at the first poll.
    start: Option<(Instant, u64)>,
    /// Consecutive calm polls seen.
    calm: u32,
    /// Polls left before the next shed move is allowed.
    cooldown: u32,
}

impl DegradationPolicy {
    /// A policy starting nominal at `nominal_vdd`, publishing through
    /// `shared` (hand clones of it to the [`SwitchableDetector`] and the
    /// session runner).
    pub fn new(cfg: DegradeConfig, shared: Rc<DegradeShared>, nominal_vdd: f64) -> Self {
        Self {
            cfg,
            shared,
            nominal_vdd,
            current_vdd: nominal_vdd,
            start: None,
            calm: 0,
            cooldown: 0,
        }
    }

    /// One decision of the state machine against a measured lag — pure
    /// (no clocks), so every transition is unit-testable. Returns the
    /// voltage to retarget to, if this decision moves the voltage.
    pub fn step(&mut self, lag_s: f64) -> Option<f64> {
        if lag_s > self.cfg.lag_shed_s {
            self.calm = 0;
            if self.cooldown > 0 {
                self.cooldown -= 1;
                return None;
            }
            self.cooldown = self.cfg.cooldown_polls;
            return self.shed();
        }
        if lag_s < self.cfg.lag_recover_s {
            self.cooldown = 0;
            if self.shared.level.get() == 0 {
                return None;
            }
            self.calm += 1;
            if self.calm >= self.cfg.recover_polls {
                return self.recover();
            }
        } else {
            // inside the hysteresis band: hold position
            self.calm = 0;
        }
        None
    }

    /// Apply one shed move: voltage down until the floor, then the
    /// detector swap; beyond that there is nothing left to shed.
    fn shed(&mut self) -> Option<f64> {
        let bump = |c: &Cell<u64>| c.set(c.get() + 1);
        if self.current_vdd > self.cfg.vdd_min_v + 1e-9 {
            self.current_vdd =
                (self.current_vdd - self.cfg.vdd_step_v).max(self.cfg.vdd_min_v);
            bump(&self.shared.vdd_steps);
            self.mark_shed();
            return Some(self.current_vdd);
        }
        if !self.shared.use_cheap.get() {
            self.shared.use_cheap.set(true);
            bump(&self.shared.detector_swaps);
            self.mark_shed();
        }
        None
    }

    fn mark_shed(&mut self) {
        self.shared.level.set(self.shared.level.get() + 1);
        self.shared.was_degraded.set(true);
        self.calm = 0;
    }

    /// Undo one move (detector first, then voltage); a full return to
    /// nominal counts one recovery.
    fn recover(&mut self) -> Option<f64> {
        let retarget = if self.shared.use_cheap.get() {
            self.shared.use_cheap.set(false);
            None
        } else {
            self.current_vdd = (self.current_vdd + self.cfg.vdd_step_v).min(self.nominal_vdd);
            Some(self.current_vdd)
        };
        let level = self.shared.level.get().saturating_sub(1);
        self.shared.level.set(level);
        self.calm = 0;
        if level == 0 {
            self.shared.recoveries.set(self.shared.recoveries.get() + 1);
        }
        retarget
    }
}

impl Governor for DegradationPolicy {
    fn on_chunk_end(&mut self, stats: &LiveStats) -> Option<f64> {
        let now = Instant::now();
        // the first poll fixes both clocks' origin, so lag compares the
        // wall time spent to the event time covered *since then*
        let (wall0, t0) = *self.start.get_or_insert((now, stats.last_t_us));
        let wall_s = now.duration_since(wall0).as_secs_f64();
        let span_s = stats.last_t_us.saturating_sub(t0) as f64 * 1e-6;
        self.step(wall_s - span_s)
    }

    fn level(&self) -> u32 {
        self.shared.level.get()
    }
}

/// An [`EventScorer`] that routes between the session's primary detector
/// and the cheaper fallback under the policy's control. Both detectors
/// see *every* event they are asked to score (no replay on swap): the
/// fallback starts cold when swapped in and warms its SAE from the
/// events it scores — a few-ms accuracy dip, which is the accepted price
/// of keeping the session alive.
///
/// While degraded, [`wants_lut`](EventScorer::wants_lut) reports `false`
/// so the coordinator sheds the FBF refresh work too; LUT refreshes that
/// do run always land in the primary detector, which resumes with a
/// current-enough LUT on swap-back.
pub struct SwitchableDetector {
    primary: Box<dyn EventScorer>,
    fallback: Box<dyn EventScorer>,
    shared: Rc<DegradeShared>,
}

impl std::fmt::Debug for SwitchableDetector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SwitchableDetector")
            .field("primary", &self.primary.name())
            .field("fallback", &self.fallback.name())
            .field("degraded", &self.shared.use_cheap.get())
            .finish()
    }
}

impl SwitchableDetector {
    /// Wrap `primary` with a cold `fallback`, both controlled through
    /// the policy's `shared` state.
    pub fn new(
        primary: Box<dyn EventScorer>,
        fallback: Box<dyn EventScorer>,
        shared: Rc<DegradeShared>,
    ) -> Self {
        Self { primary, fallback, shared }
    }
}

impl EventScorer for SwitchableDetector {
    fn score(&mut self, ev: &Event) -> f64 {
        if self.shared.use_cheap.get() {
            self.fallback.score(ev)
        } else {
            self.primary.score(ev)
        }
    }

    fn name(&self) -> &'static str {
        if self.shared.use_cheap.get() {
            self.fallback.name()
        } else {
            self.primary.name()
        }
    }

    fn ops_per_event(&self) -> f64 {
        if self.shared.use_cheap.get() {
            self.fallback.ops_per_event()
        } else {
            self.primary.ops_per_event()
        }
    }

    fn wants_lut(&self) -> bool {
        // degraded sessions shed the FBF refresh stage along with the
        // primary detector
        self.primary.wants_lut() && !self.shared.use_cheap.get()
    }

    fn refresh_lut(&mut self, lut: &[f32]) {
        self.primary.refresh_lut(lut);
    }

    fn lut(&self) -> Option<&[f32]> {
        self.primary.lut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::make_detector;
    use crate::coordinator::PipelineConfig;

    fn policy(cfg: DegradeConfig) -> (DegradationPolicy, Rc<DegradeShared>) {
        let shared = Rc::new(DegradeShared::default());
        (DegradationPolicy::new(cfg, Rc::clone(&shared), 1.2), shared)
    }

    fn fast_cfg() -> DegradeConfig {
        // no cooldown / single-poll recovery: each step() is one move
        DegradeConfig { cooldown_polls: 0, recover_polls: 1, ..DegradeConfig::default() }
    }

    #[test]
    fn sheds_voltage_then_detector_then_nothing() {
        let (mut p, s) = policy(fast_cfg());
        // 1.2 -> 1.0 -> 0.8 -> 0.6, each one poll
        assert_eq!(p.step(1.0), Some(1.0));
        assert_eq!(p.step(1.0), Some(0.8));
        assert_eq!(p.step(1.0), Some(0.6));
        assert_eq!(s.vdd_steps(), 3);
        assert!(!s.use_cheap.get());
        // at the floor: swap the detector...
        assert_eq!(p.step(1.0), None);
        assert!(s.use_cheap.get());
        assert_eq!(s.detector_swaps(), 1);
        assert_eq!(s.level(), 4);
        // ...and with nothing left to shed, further overload is a no-op
        assert_eq!(p.step(1.0), None);
        assert_eq!(s.level(), 4);
        assert_eq!(s.detector_swaps(), 1);
        assert!(s.was_degraded());
    }

    #[test]
    fn cooldown_spaces_shed_moves() {
        let (mut p, s) = policy(DegradeConfig { cooldown_polls: 2, ..fast_cfg() });
        assert_eq!(p.step(1.0), Some(1.0));
        // two polls of cooldown absorb the continuing overload
        assert_eq!(p.step(1.0), None);
        assert_eq!(p.step(1.0), None);
        assert_eq!(p.step(1.0), Some(0.8));
        assert_eq!(s.vdd_steps(), 2);
    }

    #[test]
    fn recovery_mirrors_with_hysteresis() {
        let (mut p, s) = policy(DegradeConfig { recover_polls: 2, ..fast_cfg() });
        // degrade fully: 3 voltage steps + swap
        for _ in 0..4 {
            p.step(1.0);
        }
        assert_eq!(s.level(), 4);
        // lag inside the hysteresis band: hold, no recovery
        assert_eq!(p.step(0.1), None);
        assert_eq!(s.level(), 4);
        // two calm polls per move: detector swaps back first (no
        // voltage change)...
        assert_eq!(p.step(0.0), None);
        assert_eq!(p.step(0.0), None);
        assert!(!s.use_cheap.get());
        assert_eq!(s.level(), 3);
        // ...then the voltage walks back up
        assert_eq!(p.step(0.0), None);
        assert_eq!(p.step(0.0), Some(0.8));
        assert_eq!(p.step(0.0), None);
        assert_eq!(p.step(0.0), Some(1.0));
        assert_eq!(p.step(0.0), None);
        assert_eq!(p.step(0.0), Some(1.2));
        assert_eq!(s.level(), 0);
        assert_eq!(s.recoveries(), 1);
        // nominal and calm: nothing to do
        assert_eq!(p.step(0.0), None);
        assert_eq!(s.recoveries(), 1);
    }

    #[test]
    fn overload_resets_calm_progress() {
        let (mut p, s) = policy(DegradeConfig { recover_polls: 2, ..fast_cfg() });
        p.step(1.0); // one voltage step down
        assert_eq!(s.level(), 1);
        assert_eq!(p.step(0.0), None); // calm 1/2
        p.step(1.0); // overload again: calm resets, another step sheds
        assert_eq!(s.level(), 2);
        assert_eq!(p.step(0.0), None); // calm 1/2 (fresh count)
        assert_eq!(p.step(0.0), Some(1.0)); // calm 2/2 -> recover one
        assert_eq!(s.level(), 1);
    }

    #[test]
    fn switchable_detector_routes_and_sheds_lut() {
        let cfg = PipelineConfig::test64();
        let primary = make_detector(&cfg); // harris: wants_lut
        let mut fcfg = cfg.clone();
        fcfg.detector = DetectorKind::Fast;
        let fallback = make_detector(&fcfg);
        let shared = Rc::new(DegradeShared::default());
        let mut sw = SwitchableDetector::new(primary, fallback, Rc::clone(&shared));

        assert_eq!(sw.name(), "luvHarris-LUT");
        assert!(sw.wants_lut());
        // a refreshed LUT scores through the primary
        let res = cfg.res;
        let mut lut = vec![0.0f32; res.pixels()];
        lut[res.index(5, 5)] = 0.9;
        sw.refresh_lut(&lut);
        assert!((sw.score(&Event::on(5, 5, 0)) - 0.9).abs() < 1e-6);

        // degraded: routes to the fallback, sheds the LUT stage, but the
        // primary's LUT survives for swap-back
        shared.use_cheap.set(true);
        assert_eq!(sw.name(), "eFAST");
        assert!(!sw.wants_lut());
        let _ = sw.score(&Event::on(5, 5, 1));
        shared.use_cheap.set(false);
        assert!(sw.wants_lut());
        assert!((sw.score(&Event::on(5, 5, 2)) - 0.9).abs() < 1e-6);
    }
}
