//! Shared expensive state for the serving layer: compiled Harris engines
//! and per-session scratch buffers, pooled by resolution.
//!
//! An FBF Harris engine is the most expensive piece of per-stream state
//! (artifact manifest read + HLO parse + PJRT compile), and it is only
//! needed while a LUT-consuming session is actually running. The pool
//! checks engines out to sessions and back in when they end, so N
//! concurrent streams at the same resolution pay for at most
//! min(N, max concurrent LUT streams) engine setups — and a stream
//! arriving after another finished pays for none. The artifact manifest
//! itself is parsed once per pool. [`PipelineScratch`] buffers (two f32
//! frames per session) are recycled the same way, so steady-state
//! serving allocates nothing per session beyond the pipeline's own
//! surface.
//!
//! Engines are matched to sessions by *resolution*, not artifact name:
//! the manifest records each artifact's frame geometry, and a session's
//! handshake declares its sensor size, so the pool picks whichever
//! artifact fits.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

// the pool's lock comes from the std/loom shim so the loom models below
// can check the checkout/checkin protocol — see util::sync docs
use crate::util::sync::Mutex;

use crate::coordinator::PipelineScratch;
use crate::events::Resolution;
use crate::runtime::{default_artifact_dir, HarrisEngine, Manifest};

/// Counters describing how well engine sharing is working.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Engines compiled from artifacts (cold checkouts).
    pub engines_created: u64,
    /// Checkouts served from an idle pooled engine.
    pub engines_reused: u64,
    /// Engines currently idle in the pool.
    pub engines_idle: usize,
}

#[derive(Default)]
struct Inner {
    /// Manifest, parsed once per pool (`None` until first engine checkout).
    manifest: Option<Manifest>,
    /// Idle engines keyed by `(width, height)`.
    engines: HashMap<(u16, u16), Vec<HarrisEngine>>,
    /// Idle scratch buffers keyed by `(width, height)`.
    scratch: HashMap<(u16, u16), Vec<PipelineScratch>>,
    created: u64,
    reused: u64,
}

/// Pool of compiled Harris engines + pipeline scratch, keyed by
/// resolution. All methods are `&self` (internal mutex), so one pool is
/// shared by every server worker.
pub struct EnginePool {
    dir: Option<PathBuf>,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for EnginePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("EnginePool").field("dir", &self.dir).field("stats", &stats).finish()
    }
}

impl EnginePool {
    /// A pool loading artifacts from `dir` (`None` = auto-discover, same
    /// rules as [`default_artifact_dir`]).
    pub fn new(dir: Option<PathBuf>) -> Self {
        Self { dir, inner: Mutex::new(Inner::default()) }
    }

    /// Check an engine out for a session at `res`: an idle pooled engine
    /// if one fits, otherwise a fresh compile of whichever manifest
    /// artifact matches the resolution. Errors if no artifact fits or the
    /// runtime is unavailable (callers typically degrade to an
    /// engine-less session).
    pub fn checkout_engine(&self, res: Resolution) -> Result<HarrisEngine> {
        let key = (res.width, res.height);
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(engine) = inner.engines.get_mut(&key).and_then(Vec::pop) {
                inner.reused += 1;
                return Ok(engine);
            }
        }
        // manifest parse + engine compile happen outside the lock: a cold
        // checkout must not stall other sessions checking buffers in/out
        let dir = self.dir.clone().unwrap_or_else(default_artifact_dir);
        let cached = self.inner.lock().unwrap().manifest.clone();
        let manifest = match cached {
            Some(m) => m,
            None => {
                let loaded = Manifest::load(&dir)?;
                // a racing checkout may have cached one meanwhile — keep it
                self.inner.lock().unwrap().manifest.get_or_insert(loaded).clone()
            }
        };
        let info = manifest
            .artifacts
            .iter()
            .find(|a| a.width == res.width as usize && a.height == res.height as usize)
            .with_context(|| {
                format!("no artifact for {}x{} in {}", res.width, res.height, dir.display())
            })?;
        let name = info.name.clone();
        let engine = HarrisEngine::load(&manifest, &name)?;
        self.inner.lock().unwrap().created += 1;
        Ok(engine)
    }

    /// Return a session's engine to the pool.
    pub fn checkin_engine(&self, engine: HarrisEngine) {
        let key = (engine.width as u16, engine.height as u16);
        self.inner.lock().unwrap().engines.entry(key).or_default().push(engine);
    }

    /// Check out scratch buffers for a session at `res` (fresh, empty
    /// buffers if none are pooled — they grow to frame size on first use).
    pub fn checkout_scratch(&self, res: Resolution) -> PipelineScratch {
        let key = (res.width, res.height);
        self.inner
            .lock()
            .unwrap()
            .scratch
            .get_mut(&key)
            .and_then(Vec::pop)
            .unwrap_or_default()
    }

    /// Return a session's scratch buffers to the pool.
    pub fn checkin_scratch(&self, res: Resolution, scratch: PipelineScratch) {
        let key = (res.width, res.height);
        self.inner.lock().unwrap().scratch.entry(key).or_default().push(scratch);
    }

    /// Sharing counters.
    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            engines_created: inner.created,
            engines_reused: inner.reused,
            engines_idle: inner.engines.values().map(Vec::len).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_roundtrips_through_pool() {
        let pool = EnginePool::new(None);
        let res = Resolution::TEST64;
        let a = pool.checkout_scratch(res);
        pool.checkin_scratch(res, a);
        // the returned buffer is handed back out before a fresh one
        let _b = pool.checkout_scratch(res);
        // different resolution -> different bucket
        let _c = pool.checkout_scratch(Resolution::DAVIS240);
        assert_eq!(pool.stats(), PoolStats::default());
    }

    #[test]
    fn engine_checkout_without_artifacts_is_clean_error() {
        let dir = std::env::temp_dir().join("nmc_tos_empty_pool_dir");
        std::fs::create_dir_all(&dir).unwrap();
        let pool = EnginePool::new(Some(dir));
        // no meta.json there: a helpful error, not a panic
        assert!(pool.checkout_engine(Resolution::TEST64).is_err());
        assert_eq!(pool.stats().engines_created, 0);
    }
}

/// Loom models of the pool's checkout/checkin protocol: concurrent
/// scratch roundtrips (including the "session failed, buffer still goes
/// back" path run_session guarantees), a cold engine checkout racing a
/// stats read (manifest load happens *outside* the lock), and the
/// manifest double-checked caching dance. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_tests`.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::util::sync::{thread, Arc};

    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = loom::model::Builder::new();
        if b.preemption_bound.is_none() {
            b.preemption_bound = Some(3);
        }
        b.check(f);
    }

    /// Two sessions checking scratch out and back in concurrently — one
    /// of them "failing" mid-session (checkin still happens, as
    /// `run_session` does on the error path). Under every schedule the
    /// pool must end consistent: no lost or duplicated buffers, stats
    /// lock never deadlocks against the scratch lock path.
    #[test]
    fn loom_scratch_checkout_checkin_across_session_failure() {
        model(|| {
            let pool = Arc::new(EnginePool::new(None));
            let res = Resolution::TEST64;
            let ok = {
                let pool = Arc::clone(&pool);
                thread::spawn(move || {
                    let scratch = pool.checkout_scratch(res);
                    pool.checkin_scratch(res, scratch);
                })
            };
            let failing = {
                let pool = Arc::clone(&pool);
                thread::spawn(move || {
                    let scratch = pool.checkout_scratch(res);
                    // the session "fails" here; the buffer still returns
                    pool.checkin_scratch(res, scratch);
                    let _ = pool.stats();
                })
            };
            ok.join().unwrap();
            failing.join().unwrap();
            // both buffers are back: two checkouts drain the pool exactly
            let inner = pool.inner.lock().unwrap();
            assert_eq!(inner.scratch.get(&(res.width, res.height)).map(Vec::len), Some(2));
        });
    }

    /// A cold engine checkout (manifest load outside the lock — here it
    /// errors, no artifacts) racing a stats read must neither deadlock
    /// nor count a phantom engine.
    #[test]
    fn loom_cold_checkout_races_stats() {
        model(|| {
            let dir = std::env::temp_dir().join("nmc_tos_loom_empty_dir");
            std::fs::create_dir_all(&dir).unwrap();
            let pool = Arc::new(EnginePool::new(Some(dir)));
            let checkout = {
                let pool = Arc::clone(&pool);
                thread::spawn(move || {
                    assert!(pool.checkout_engine(Resolution::TEST64).is_err());
                })
            };
            let stats = pool.stats();
            assert_eq!(stats.engines_idle, 0);
            checkout.join().unwrap();
            assert_eq!(pool.stats().engines_created, 0);
        });
    }
}
