//! System power integration: run a rate profile through the DVFS
//! controller and integrate macro power over time — the engine behind
//! Table I, Fig. 8 and Fig. 10(b).
//!
//! For Table-I-scale datasets (10^8 events) the integrator consumes the
//! profile *per half-window* instead of per event: the DVFS counters see
//! the same counts they would see event-by-event, and the energy integral
//! uses the per-event patch energy at whichever voltage each window ran
//! at.  This is exact for the paper's metric (average power) because both
//! DVFS decisions and patch energy depend on events only through counts
//! and voltage.

use crate::datasets::profiles::RateProfile;
use crate::dvfs::{DvfsConfig, DvfsController};
use crate::nmc::energy::{ConventionalEnergy, EnergyModel};

/// Result of integrating one dataset's power.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Dataset name.
    pub dataset: &'static str,
    /// Peak 10 ms event rate seen (events/s).
    pub peak_rate: f64,
    /// Total events integrated.
    pub events: f64,
    /// Average NMC power with DVFS (mW).
    pub power_dvfs_mw: f64,
    /// Average NMC power pinned at 1.2 V (mW).
    pub power_fixed_mw: f64,
    /// Average conventional-digital power at 1.2 V (mW).
    pub power_conv_mw: f64,
    /// Voltage residency: (vdd, seconds) pairs.
    pub residency: Vec<(f64, f64)>,
    /// Time series for Fig. 8: (t_s, measured rate, vdd, max rate at vdd).
    pub trace: Vec<(f64, f64, f64, f64)>,
    /// DVFS voltage switches.
    pub switches: u64,
    /// True iff the rate never exceeded the capacity at the chosen voltage.
    pub no_event_loss: bool,
}

/// Integrate a rate profile with and without DVFS.
///
/// `trace_stride` controls how many half-windows apart Fig. 8 samples are
/// recorded (1 = every window).
pub fn integrate(profile: &RateProfile, dvfs_cfg: DvfsConfig, trace_stride: usize) -> PowerReport {
    let mut ctrl = DvfsController::new(dvfs_cfg);
    let half_s = dvfs_cfg.tw_us as f64 * 1e-6 / 2.0;
    let duration = profile.spec.duration_s;
    let nominal = EnergyModel::at(1.2);
    let conv = ConventionalEnergy::at(1.2);

    let mut energy_dvfs_pj = 0.0;
    let mut energy_fixed_pj = 0.0;
    let mut energy_conv_pj = 0.0;
    let mut leak_dvfs_mj = 0.0; // mW * s
    let mut residency: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut trace = Vec::new();
    let mut peak_rate: f64 = 0.0;
    let mut events_total = 0.0;
    let mut no_event_loss = true;

    let half_us = dvfs_cfg.tw_us / 2;
    let n_windows = (duration / half_s).ceil() as u64;
    for win in 0..n_windows {
        // exact integer window boundaries so every window triggers exactly
        // one counter rotation (float accumulation would occasionally slip
        // a boundary and complete an empty counter)
        let t = (win * half_us) as f64 * 1e-6;
        let hi = (((win + 1) * half_us) as f64 * 1e-6).min(duration);
        if hi <= t {
            break;
        }
        let count = profile.events_between(t, hi);
        let rate = count / (hi - t);
        peak_rate = peak_rate.max(rate);
        events_total += count;

        // The operating point in force during this window was chosen at
        // the previous boundary; the counters then see this window's
        // events and the controller retargets at its end.
        let op = ctrl.operating_point();
        ctrl.advance_window((win + 1) * half_us, count.round() as u64);
        let e_dvfs = EnergyModel::at(op.vdd);

        energy_dvfs_pj += count * e_dvfs.patch_pj;
        energy_fixed_pj += count * nominal.patch_pj;
        energy_conv_pj += count * conv.patch_pj;
        leak_dvfs_mj += e_dvfs.leak_mw * (hi - t);
        *residency.entry((op.vdd * 1000.0).round() as u64).or_insert(0.0) += hi - t;
        if rate > op.max_rate {
            no_event_loss = false;
        }
        if win as usize % trace_stride == 0 {
            trace.push((t, rate, op.vdd, op.max_rate));
        }
    }

    let power = |e_pj: f64, leak_mw: f64| e_pj * 1e-12 / duration * 1e3 + leak_mw;
    PowerReport {
        dataset: profile.spec.kind.name(),
        peak_rate,
        events: events_total,
        power_dvfs_mw: power(energy_dvfs_pj, leak_dvfs_mj / duration),
        power_fixed_mw: power(energy_fixed_pj, nominal.leak_mw),
        power_conv_mw: power(energy_conv_pj, conv.leak_mw),
        residency: residency.into_iter().map(|(mv, s)| (mv as f64 / 1000.0, s)).collect(),
        trace,
        switches: ctrl.switches,
        no_event_loss,
    }
}

/// Fig. 10(b): average power vs (constant) event rate for the three
/// configurations. Returns rows of (rate, conv, nmc-fixed, nmc-dvfs) mW.
pub fn power_vs_rate(rates: &[f64]) -> Vec<(f64, f64, f64, f64)> {
    let lut = crate::dvfs::build_lut(&DvfsConfig::default());
    rates
        .iter()
        .map(|&r| {
            let conv = ConventionalEnergy::at(1.2).power_mw(r);
            let fixed = EnergyModel::at(1.2).power_mw(r);
            // DVFS at a constant rate settles at the lowest sustaining V
            let op = lut
                .iter()
                .find(|op| op.max_rate >= r * DvfsConfig::default().headroom)
                .unwrap_or(lut.last().unwrap());
            let dvfs = EnergyModel::at(op.vdd).power_mw(r);
            (r, conv, fixed, dvfs)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::DatasetKind;

    #[test]
    fn dvfs_saves_power_on_every_dataset() {
        for kind in DatasetKind::ALL {
            let p = RateProfile::for_dataset(kind);
            let r = integrate(&p, DvfsConfig::default(), 16);
            assert!(
                r.power_dvfs_mw < r.power_fixed_mw,
                "{}: dvfs {} !< fixed {}",
                r.dataset,
                r.power_dvfs_mw,
                r.power_fixed_mw
            );
            assert!(r.no_event_loss, "{}: event loss", r.dataset);
        }
    }

    #[test]
    fn driving_power_matches_table1_scale() {
        let p = RateProfile::for_dataset(DatasetKind::Driving);
        let r = integrate(&p, DvfsConfig::default(), 16);
        // Table I: 0.44 mW with DVFS, 1.24 mW without. Shapes are synthetic,
        // so allow a generous band — the *ratio* is the reproduced claim.
        assert!((r.power_fixed_mw - 1.24).abs() / 1.24 < 0.15, "fixed {}", r.power_fixed_mw);
        let saving = r.power_fixed_mw / r.power_dvfs_mw;
        assert!(saving > 1.8 && saving < 4.5, "saving {saving}");
    }

    #[test]
    fn residency_sums_to_duration() {
        let p = RateProfile::for_dataset(DatasetKind::ShapesDof);
        let r = integrate(&p, DvfsConfig::default(), 16);
        let total: f64 = r.residency.iter().map(|(_, s)| s).sum();
        assert!((total - p.spec.duration_s).abs() < 0.05);
    }

    #[test]
    fn quiet_dataset_lives_at_low_voltage() {
        let p = RateProfile::for_dataset(DatasetKind::ShapesDof);
        let r = integrate(&p, DvfsConfig::default(), 16);
        let low: f64 =
            r.residency.iter().filter(|(v, _)| *v <= 0.66).map(|(_, s)| s).sum();
        let total: f64 = r.residency.iter().map(|(_, s)| s).sum();
        assert!(low / total > 0.5, "low-V residency {}", low / total);
    }

    #[test]
    fn power_vs_rate_ordering() {
        let rows = power_vs_rate(&[1e6, 10e6, 45e6]);
        for (r, conv, fixed, dvfs) in rows {
            assert!(conv > fixed, "rate {r}: conv {conv} fixed {fixed}");
            assert!(fixed >= dvfs - 1e-12, "rate {r}: fixed {fixed} dvfs {dvfs}");
        }
        // paper: at 45 Meps NMC ~1.2x below conventional
        let (_, conv, fixed, _) = power_vs_rate(&[45e6])[0];
        assert!((conv / fixed - 1.23).abs() < 0.05);
    }

    #[test]
    fn trace_is_time_ordered_and_covers_run() {
        let p = RateProfile::for_dataset(DatasetKind::Spinner);
        let r = integrate(&p, DvfsConfig::default(), 4);
        assert!(r.trace.len() > 10);
        assert!(r.trace.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(r.trace.last().unwrap().0 <= p.spec.duration_s);
    }
}
