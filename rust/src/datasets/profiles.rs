//! Rate-profile generators for the Prophesee recordings (driving, laser,
//! spinner) and the two Mueggler scenes at Table-I scale.
//!
//! These experiments (Fig. 8, Table I) consume only the *event-rate time
//! series*, not pixel positions, so the profile is a deterministic smooth
//! function `rate(t)` whose peak / mean / duration reproduce the published
//! statistics.  A profile can be (a) sampled per window for the DVFS/power
//! integrators — which is how the 111.4M-event driving run stays cheap —
//! or (b) materialized into a real (position-carrying) event stream at
//! reduced scale for end-to-end runs.

use crate::events::{Event, Polarity};
use crate::util::rng::Rng;

use super::{DatasetKind, DatasetSpec};

/// A deterministic event-rate time series for one dataset.
#[derive(Debug, Clone)]
pub struct RateProfile {
    /// The dataset statistics this profile reproduces.
    pub spec: DatasetSpec,
    /// Bump centres/widths/amplitudes of the mixture (internal shape).
    bumps: Vec<(f64, f64, f64)>,
    /// Constant floor rate (events/s).
    floor: f64,
}

impl RateProfile {
    /// Build the canonical profile of a dataset (deterministic per kind).
    pub fn for_dataset(kind: DatasetKind) -> Self {
        let spec = kind.spec();
        let mut rng = Rng::seed_from(0xDA7A_0000 ^ kind as u64);
        let d = spec.duration_s;
        // Shape family per dataset: laser = near-constant high; spinner =
        // near-constant moderate; driving & scenes = bursty mixture.
        let (floor_frac, n_bumps, burstiness) = match kind {
            DatasetKind::Laser => (0.93, 3, 0.08),
            DatasetKind::Spinner => (0.90, 4, 0.10),
            DatasetKind::Driving => (0.10, 9, 1.0),
            DatasetKind::DynamicDof => (0.35, 10, 0.9),
            DatasetKind::ShapesDof => (0.40, 8, 0.8),
        };
        let mut bumps = Vec::new();
        for _ in 0..n_bumps {
            let centre = rng.range_f64(0.06 * d, 0.94 * d);
            let width = rng.range_f64(0.012 * d, 0.05 * d).max(0.25);
            let amp = rng.range_f64(0.3, 1.0) * burstiness;
            bumps.push((centre, width, amp));
        }
        let mut p = Self { spec, bumps, floor: floor_frac };
        p.calibrate();
        p
    }

    /// Raw (uncalibrated) shape value at time `t_s`.
    fn shape(&self, t_s: f64) -> f64 {
        let mut v = self.floor;
        for &(c, w, a) in &self.bumps {
            let z = (t_s - c) / w;
            v += a * (-0.5 * z * z).exp();
        }
        v
    }

    /// Calibrate so that max(rate) == peak_rate and the integral over
    /// the duration == total events: alternate (a) rescaling everything to
    /// pin the peak with (b) shifting the floor to pin the total.
    fn calibrate(&mut self) {
        let n = 4000;
        let d = self.spec.duration_s;
        let sample = |p: &Self| -> (f64, f64) {
            let mut max_v: f64 = 0.0;
            let mut sum_v = 0.0;
            for i in 0..n {
                let v = p.shape(d * i as f64 / n as f64);
                max_v = max_v.max(v);
                sum_v += v;
            }
            (max_v, sum_v / n as f64 * d)
        };
        for _ in 0..300 {
            let (max_v, total) = sample(self);
            // (a) pin the peak
            let s = self.spec.peak_rate / max_v;
            self.floor *= s;
            for b in &mut self.bumps {
                b.2 *= s;
            }
            // (b) pin the total by shifting the floor
            let (_, total2) = sample(self);
            let delta = (self.spec.events - total2) / d;
            self.floor = (self.floor + 0.8 * delta).max(0.0);
            // floor pinned at zero but total still too high: the bursts
            // themselves carry too much mass — narrow them.
            if self.floor == 0.0 && delta < 0.0 {
                for b in &mut self.bumps {
                    b.1 = (b.1 * 0.93).max(0.25);
                }
            }
            let peak_err = (max_v * s - self.spec.peak_rate).abs() / self.spec.peak_rate;
            let tot_err = (total - self.spec.events).abs() / self.spec.events;
            if peak_err < 2e-3 && tot_err < 2e-3 {
                break;
            }
        }
    }

    /// Event rate (events/s) at time `t_s`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        self.shape(t_s).max(0.0)
    }

    /// Integrate events over `[t0, t1]` (s).
    pub fn events_between(&self, t0: f64, t1: f64) -> f64 {
        let steps = (((t1 - t0) / 1e-3).ceil() as usize).clamp(1, 100_000);
        let dt = (t1 - t0) / steps as f64;
        let mut sum = 0.0;
        for i in 0..steps {
            sum += self.rate_at(t0 + (i as f64 + 0.5) * dt);
        }
        sum * dt
    }

    /// Total events over the recording (should approximate the spec).
    pub fn total_events(&self) -> f64 {
        self.events_between(0.0, self.spec.duration_s)
    }

    /// Measured peak rate (events/s) over `window_s` windows.
    pub fn peak_rate_measured(&self, window_s: f64) -> f64 {
        let d = self.spec.duration_s;
        let mut peak: f64 = 0.0;
        let mut t = 0.0;
        while t < d {
            let hi = (t + window_s).min(d);
            peak = peak.max(self.events_between(t, hi) / (hi - t));
            t += window_s * 0.5;
        }
        peak
    }

    /// Materialize a *scaled-down* event stream: positions from a few
    /// random-walking hot spots, timestamps by thinning `rate(t) * scale`.
    /// Used by end-to-end demos where per-event positions matter but the
    /// full 100M-event recording would be wasteful.
    pub fn materialize(&self, scale: f64, seed: u64) -> Vec<Event> {
        let mut rng = Rng::seed_from(seed);
        let res = self.spec.res;
        let mut events = Vec::new();
        let step_us: u64 = 1000;
        let step_s = step_us as f64 * 1e-6;
        // random walkers = activity clusters (car edges / laser dot / disk)
        let mut walkers: Vec<(f64, f64, f64, f64)> = (0..6)
            .map(|_| {
                (
                    rng.range_f64(10.0, res.width as f64 - 10.0),
                    rng.range_f64(10.0, res.height as f64 - 10.0),
                    rng.range_f64(-80.0, 80.0),
                    rng.range_f64(-80.0, 80.0),
                )
            })
            .collect();
        let duration_us = (self.spec.duration_s * 1e6) as u64;
        let mut t_us = 0u64;
        while t_us < duration_us {
            let lambda = self.rate_at(t_us as f64 * 1e-6) * scale * step_s;
            let n = rng.poisson(lambda);
            for _ in 0..n {
                let w = walkers[rng.below(walkers.len() as u64) as usize];
                let x = (w.0 + rng.normal(0.0, 4.0)).clamp(0.0, res.width as f64 - 1.0);
                let y = (w.1 + rng.normal(0.0, 4.0)).clamp(0.0, res.height as f64 - 1.0);
                let pol = if rng.chance(0.5) { Polarity::On } else { Polarity::Off };
                events.push(Event::new(x as u16, y as u16, t_us + rng.below(step_us), pol));
            }
            for w in &mut walkers {
                w.0 = (w.0 + w.2 * step_s).clamp(5.0, res.width as f64 - 5.0);
                w.1 = (w.1 + w.3 * step_s).clamp(5.0, res.height as f64 - 5.0);
                if w.0 <= 5.0 || w.0 >= res.width as f64 - 5.0 {
                    w.2 = -w.2;
                }
                if w.1 <= 5.0 || w.1 >= res.height as f64 - 5.0 {
                    w.3 = -w.3;
                }
            }
            t_us += step_us;
        }
        events.sort_by_key(|e| e.t);
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_reproduce_published_statistics() {
        for kind in DatasetKind::ALL {
            let p = RateProfile::for_dataset(kind);
            let spec = p.spec;
            let peak = p.peak_rate_measured(0.01);
            let total = p.total_events();
            let peak_err = (peak - spec.peak_rate).abs() / spec.peak_rate;
            let tot_err = (total - spec.events).abs() / spec.events;
            assert!(peak_err < 0.05, "{}: peak {} vs {}", kind.name(), peak, spec.peak_rate);
            assert!(tot_err < 0.10, "{}: total {} vs {}", kind.name(), total, spec.events);
        }
    }

    #[test]
    fn rate_is_nonnegative_everywhere() {
        let p = RateProfile::for_dataset(DatasetKind::Driving);
        for i in 0..500 {
            let t = p.spec.duration_s * i as f64 / 500.0;
            assert!(p.rate_at(t) >= 0.0);
        }
    }

    #[test]
    fn driving_is_bursty_laser_is_flat() {
        let drv = RateProfile::for_dataset(DatasetKind::Driving);
        let las = RateProfile::for_dataset(DatasetKind::Laser);
        let cv = |p: &RateProfile| {
            let n = 300;
            let mut vals = Vec::with_capacity(n);
            for i in 0..n {
                vals.push(p.rate_at(p.spec.duration_s * i as f64 / n as f64));
            }
            let mean = vals.iter().sum::<f64>() / n as f64;
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
            var.sqrt() / mean
        };
        assert!(cv(&drv) > 2.0 * cv(&las), "drv cv {} las cv {}", cv(&drv), cv(&las));
    }

    #[test]
    fn never_exceeds_nmc_max_rate() {
        // paper Fig. 8: "the event rate never reached the maximum operating
        // frequency of 63.1 Meps at 1.2 V" — true for every dataset here.
        for kind in DatasetKind::ALL {
            let p = RateProfile::for_dataset(kind);
            assert!(p.peak_rate_measured(0.01) < 63.1e6, "{}", kind.name());
        }
    }

    #[test]
    fn materialize_scales_down() {
        let p = RateProfile::for_dataset(DatasetKind::ShapesDof);
        let evs = p.materialize(0.01, 1);
        let expect = p.total_events() * 0.01;
        let err = (evs.len() as f64 - expect).abs() / expect;
        assert!(err < 0.1, "materialized {} expect {}", evs.len(), expect);
        assert!(evs.windows(2).all(|w| w[0].t <= w[1].t));
        for e in evs.iter().take(1000) {
            assert!(p.spec.res.contains(e.x as i32, e.y as i32));
        }
    }

    #[test]
    fn deterministic_profiles() {
        let a = RateProfile::for_dataset(DatasetKind::Spinner);
        let b = RateProfile::for_dataset(DatasetKind::Spinner);
        assert_eq!(a.rate_at(1.0), b.rate_at(1.0));
    }
}
