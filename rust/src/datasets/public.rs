//! Public-dataset harness: real recordings + file-backed corner labels.
//!
//! The synthetic scenes in [`super::synthetic`] know their exact ground
//! truth; real recordings (`shapes_6dof`, Prophesee CD streams, ...)
//! instead ship with hand-labelled corner annotations in a sidecar text
//! file.  This module provides:
//!
//! * [`CornerLabels`] — sparse `(t, x, y)` corner annotations loaded from
//!   a text file, answering [`CornerOracle`] queries with a ±2 ms time
//!   window (the same slack [`GroundTruth`](super::gt::GroundTruth)
//!   hardcodes for its interpolated tracks).
//! * [`Manifest`] / [`PublicDataset`] — a JSON manifest declaring which
//!   recordings to evaluate, their geometry, and where the files live.
//!   **No network code**: a manifest may carry a `url` per dataset, but it
//!   is only echoed in the error message when the file is missing, as a
//!   manual-download hint.  Everything the harness reads comes from disk.
//!
//! Manifest format (paths are resolved relative to the manifest file):
//!
//! ```json
//! {
//!   "datasets": [
//!     {
//!       "name": "fixture-aedat4",
//!       "recording": "../events.aedat4",
//!       "ground_truth": "corners_gt.txt",
//!       "width": 64,
//!       "height": 64,
//!       "url": "https://example.org/events.aedat4"
//!     }
//!   ]
//! }
//! ```

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::gt::CornerOracle;
use crate::events::Resolution;
use crate::util::json::Json;

/// Time window (µs) around each label within which an event counts as
/// "at" that corner.  Matches the 2 ms slack `GroundTruth::near_corner`
/// hardcodes for synthetic tracks.
pub const LABEL_SLACK_US: u64 = 2_000;

/// Sparse corner annotations: parallel `(t_us, x, y)` columns sorted by
/// time.  Loaded from a text file with one `t_seconds x y` triple per
/// line (`#`-prefixed lines and blank lines are comments).
#[derive(Debug, Clone, Default)]
pub struct CornerLabels {
    t_us: Vec<u64>,
    x: Vec<f32>,
    y: Vec<f32>,
}

impl CornerLabels {
    /// Parse labels from text.  Input need not be time-sorted; labels are
    /// stably sorted by timestamp after parsing.
    pub fn parse(text: &str) -> Result<Self> {
        let mut rows: Vec<(u64, f32, f32)> = Vec::new();
        for (idx, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_ascii_whitespace();
            let (ts, xs, ys) = match (it.next(), it.next(), it.next()) {
                (Some(t), Some(x), Some(y)) => (t, x, y),
                _ => bail!("label line {}: expected `t_seconds x y`, got {:?}", idx + 1, line),
            };
            ensure!(
                it.next().is_none(),
                "label line {}: trailing fields after `t_seconds x y`",
                idx + 1
            );
            let t_s: f64 = ts
                .parse()
                .with_context(|| format!("label line {}: bad timestamp {:?}", idx + 1, ts))?;
            ensure!(
                t_s.is_finite() && t_s >= 0.0,
                "label line {}: timestamp {} out of range",
                idx + 1,
                t_s
            );
            let x: f32 = xs
                .parse()
                .with_context(|| format!("label line {}: bad x {:?}", idx + 1, xs))?;
            let y: f32 = ys
                .parse()
                .with_context(|| format!("label line {}: bad y {:?}", idx + 1, ys))?;
            ensure!(
                x.is_finite() && y.is_finite(),
                "label line {}: non-finite coordinates",
                idx + 1
            );
            rows.push(((t_s * 1e6).round() as u64, x, y));
        }
        rows.sort_by_key(|r| r.0);
        let mut out = CornerLabels::default();
        for (t, x, y) in rows {
            out.t_us.push(t);
            out.x.push(x);
            out.y.push(y);
        }
        Ok(out)
    }

    /// Load labels from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading corner labels {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing corner labels {}", path.display()))
    }

    /// Number of labels.
    pub fn len(&self) -> usize {
        self.t_us.len()
    }

    /// True when there are no labels at all.
    pub fn is_empty(&self) -> bool {
        self.t_us.is_empty()
    }
}

impl CornerOracle for CornerLabels {
    fn is_corner(&self, x: f32, y: f32, t: u64, radius_px: f32) -> bool {
        let r2 = radius_px * radius_px;
        let lo_t = t.saturating_sub(LABEL_SLACK_US);
        let hi_t = t.saturating_add(LABEL_SLACK_US);
        let lo = self.t_us.partition_point(|&lt| lt < lo_t);
        for i in lo..self.t_us.len() {
            if self.t_us[i] > hi_t {
                break;
            }
            let dx = self.x[i] - x;
            let dy = self.y[i] - y;
            if dx * dx + dy * dy <= r2 {
                return true;
            }
        }
        false
    }
}

/// One manifest entry: a recording plus its corner labels and geometry.
#[derive(Debug, Clone)]
pub struct PublicDataset {
    /// Unique short name, used as the report key.
    pub name: String,
    /// Event recording (any format `source::open` can sniff).
    pub recording: PathBuf,
    /// Corner-label sidecar (see [`CornerLabels::parse`]).
    pub ground_truth: PathBuf,
    /// Declared sensor geometry.
    pub res: Resolution,
    /// Optional download hint, echoed when files are missing.  Never
    /// fetched by this crate.
    pub url: Option<String>,
}

impl PublicDataset {
    /// Verify both files exist on disk.  This harness performs no
    /// downloads; the error names the missing file and, when the manifest
    /// provides one, the URL to fetch it from manually.
    pub fn ensure_local(&self) -> Result<()> {
        for (what, path) in [("recording", &self.recording), ("ground truth", &self.ground_truth)]
        {
            if !path.is_file() {
                let hint = match &self.url {
                    Some(u) => format!(" (download it manually, e.g. from {u})"),
                    None => String::new(),
                };
                bail!(
                    "dataset {:?}: {} file {} not found{}",
                    self.name,
                    what,
                    path.display(),
                    hint
                );
            }
        }
        Ok(())
    }
}

/// A parsed dataset manifest: the evaluation set, sorted by name.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Declared datasets, sorted by `name` (names are unique).
    pub datasets: Vec<PublicDataset>,
}

impl Manifest {
    /// Parse a manifest from JSON text; relative paths are resolved
    /// against `base_dir` (normally the manifest's directory).
    pub fn parse(text: &str, base_dir: &Path) -> Result<Self> {
        let json = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let arr = json
            .get("datasets")
            .and_then(Json::as_arr)
            .context("manifest: missing `datasets` array")?;
        ensure!(!arr.is_empty(), "manifest: `datasets` is empty");
        let mut datasets = Vec::new();
        for (i, d) in arr.iter().enumerate() {
            let field = |k: &str| -> Result<&str> {
                d.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("manifest dataset {i}: missing string `{k}`"))
            };
            let dim = |k: &str| -> Result<u16> {
                let v = d
                    .get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("manifest dataset {i}: missing number `{k}`"))?;
                ensure!(
                    v.fract() == 0.0 && v >= 1.0 && v <= u16::MAX as f64,
                    "manifest dataset {i}: `{k}` = {v} is not a sensor dimension"
                );
                Ok(v as u16)
            };
            let name = field("name")?.to_string();
            ensure!(!name.is_empty(), "manifest dataset {i}: empty `name`");
            datasets.push(PublicDataset {
                name,
                recording: base_dir.join(field("recording")?),
                ground_truth: base_dir.join(field("ground_truth")?),
                res: Resolution::new(dim("width")?, dim("height")?),
                url: d.get("url").and_then(Json::as_str).map(str::to_string),
            });
        }
        datasets.sort_by(|a, b| a.name.cmp(&b.name));
        for w in datasets.windows(2) {
            ensure!(w[0].name != w[1].name, "manifest: duplicate dataset name {:?}", w[0].name);
        }
        Ok(Manifest { datasets })
    }

    /// Load and parse a manifest file; relative paths inside it are
    /// resolved against the manifest's own directory.
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading dataset manifest {}", path.display()))?;
        let base = path.parent().unwrap_or_else(|| Path::new("."));
        Self::parse(&text, base).with_context(|| format!("parsing manifest {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_parse_sort_and_skip_comments() {
        let text = "# corner labels\n\n0.002 10.0 5.0\n0.001 3.5 4.5\n";
        let l = CornerLabels::parse(text).unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l.t_us, vec![1_000, 2_000]);
        assert_eq!(l.x, vec![3.5, 10.0]);
    }

    #[test]
    fn labels_reject_malformed_lines() {
        let e = CornerLabels::parse("0.1 1.0\n").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
        let e = CornerLabels::parse("ok\n0.1 1 2 3\n").map(|_| ()).unwrap_err();
        let e = format!("{e:#}");
        assert!(e.contains("line 1"), "{e}");
        let e = CornerLabels::parse("0.1 nope 2\n").map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("bad x"));
        let e = CornerLabels::parse("-0.1 1 2\n").map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("out of range"));
    }

    #[test]
    fn oracle_window_and_radius() {
        let l = CornerLabels::parse("0.010 20.0 20.0\n").unwrap();
        // Inside radius, inside the ±2 ms window.
        assert!(l.is_corner(20.5, 20.0, 10_000, 1.0));
        assert!(l.is_corner(20.0, 20.0, 10_000 + LABEL_SLACK_US, 1.0));
        assert!(l.is_corner(20.0, 20.0, 10_000 - LABEL_SLACK_US, 1.0));
        // Just outside the window.
        assert!(!l.is_corner(20.0, 20.0, 10_000 + LABEL_SLACK_US + 1, 1.0));
        assert!(!l.is_corner(20.0, 20.0, 10_000 - LABEL_SLACK_US - 1, 1.0));
        // Outside the radius.
        assert!(!l.is_corner(25.0, 20.0, 10_000, 1.0));
        assert!(l.is_corner(25.0, 20.0, 10_000, 5.0));
        // Empty oracle says no.
        assert!(!CornerLabels::default().is_corner(0.0, 0.0, 0, 100.0));
    }

    fn manifest_text() -> &'static str {
        r#"{
          "datasets": [
            {"name": "b", "recording": "rec/b.raw", "ground_truth": "b_gt.txt",
             "width": 640, "height": 480, "url": "https://example.org/b.raw"},
            {"name": "a", "recording": "a.aedat4", "ground_truth": "a_gt.txt",
             "width": 64, "height": 64}
          ]
        }"#
    }

    #[test]
    fn manifest_parses_sorts_and_joins_paths() {
        let m = Manifest::parse(manifest_text(), Path::new("/data")).unwrap();
        assert_eq!(m.datasets.len(), 2);
        assert_eq!(m.datasets[0].name, "a");
        assert_eq!(m.datasets[1].name, "b");
        assert_eq!(m.datasets[0].recording, Path::new("/data/a.aedat4"));
        assert_eq!(m.datasets[1].ground_truth, Path::new("/data/b_gt.txt"));
        assert_eq!(m.datasets[1].res, Resolution::new(640, 480));
        assert_eq!(m.datasets[1].url.as_deref(), Some("https://example.org/b.raw"));
        assert!(m.datasets[0].url.is_none());
    }

    #[test]
    fn manifest_rejects_bad_shapes() {
        let base = Path::new(".");
        let e = Manifest::parse("{}", base).map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("datasets"));
        let e = Manifest::parse(r#"{"datasets": []}"#, base).map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("empty"));
        let dup = r#"{"datasets": [
            {"name": "x", "recording": "r", "ground_truth": "g", "width": 2, "height": 2},
            {"name": "x", "recording": "r", "ground_truth": "g", "width": 2, "height": 2}
        ]}"#;
        let e = Manifest::parse(dup, base).map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"));
        let bad_dim = r#"{"datasets": [
            {"name": "x", "recording": "r", "ground_truth": "g", "width": 0, "height": 2}
        ]}"#;
        let e = Manifest::parse(bad_dim, base).map(|_| ()).unwrap_err();
        assert!(format!("{e:#}").contains("width"));
        let frac = r#"{"datasets": [
            {"name": "x", "recording": "r", "ground_truth": "g", "width": 2.5, "height": 2}
        ]}"#;
        assert!(Manifest::parse(frac, base).is_err());
    }

    #[test]
    fn ensure_local_reports_missing_with_url_hint() {
        let ds = PublicDataset {
            name: "ghost".into(),
            recording: PathBuf::from("/nonexistent/ghost.raw"),
            ground_truth: PathBuf::from("/nonexistent/ghost_gt.txt"),
            res: Resolution::TEST64,
            url: Some("https://example.org/ghost.raw".into()),
        };
        let e = ds.ensure_local().unwrap_err().to_string();
        assert!(e.contains("ghost.raw"), "{e}");
        assert!(e.contains("https://example.org/ghost.raw"), "{e}");
        let no_url = PublicDataset { url: None, ..ds };
        let e = no_url.ensure_local().unwrap_err().to_string();
        assert!(!e.contains("download"), "{e}");
    }
}
