//! Dataset substrate: synthetic stand-ins for the event-camera recordings
//! the paper evaluates on (none of which ship with silicon papers).
//!
//! Two generator families (see DESIGN.md substitution table):
//!
//! * [`synthetic`] — *scene* generators: moving polygons rendered into
//!   event streams with **exact corner ground truth** (the vertices).
//!   Stand-ins for `shapes_dof` / `dynamic_dof` (Mueggler et al.), used by
//!   the PR/AUC experiments (Fig. 11).
//! * [`profiles`] — *rate-profile* generators reproducing the published
//!   statistics (max rate, event count, duration) of the Prophesee
//!   `driving`, `laser` and `spinner` recordings, used by the DVFS/power
//!   experiments (Fig. 8, Table I) where only the rate time-series
//!   matters.
//!
//! [`scenarios`] composes the scene generators into an enumerative
//! {motion x rate x noise x resolution x Vdd} grid for the voltage-fault
//! and overload robustness harnesses.

pub mod gt;
pub mod profiles;
pub mod public;
pub mod scenarios;
pub mod synthetic;

use crate::events::Resolution;

/// The five datasets of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// Mueggler `shapes_6dof`: B&W geometric shapes, moderate motion.
    ShapesDof,
    /// Mueggler `dynamic_6dof`: office scene with a moving person.
    DynamicDof,
    /// Prophesee `driving`: car-mounted HD sensor.
    Driving,
    /// Prophesee `laser`: laser-pointer spot, very high instantaneous rate.
    Laser,
    /// Prophesee `spinner`: rotating disk.
    Spinner,
}

impl DatasetKind {
    /// All five, in the paper's Table I order.
    pub const ALL: [DatasetKind; 5] = [
        DatasetKind::Driving,
        DatasetKind::Laser,
        DatasetKind::Spinner,
        DatasetKind::DynamicDof,
        DatasetKind::ShapesDof,
    ];

    /// Display name matching the paper.
    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::ShapesDof => "shapes_dof",
            DatasetKind::DynamicDof => "dynamic_dof",
            DatasetKind::Driving => "driving",
            DatasetKind::Laser => "laser",
            DatasetKind::Spinner => "spinner",
        }
    }

    /// Published stream statistics this generator must reproduce
    /// (Table I: max event rate in Meps, total events in M; duration is
    /// derived from the power-model fit, see DESIGN.md).
    pub fn spec(self) -> DatasetSpec {
        match self {
            DatasetKind::Driving => DatasetSpec {
                kind: self,
                res: Resolution::HD720,
                duration_s: 12.5,
                peak_rate: 25.9e6,
                events: 111.4e6,
            },
            DatasetKind::Laser => DatasetSpec {
                kind: self,
                res: Resolution::HD720,
                duration_s: 1.5,
                peak_rate: 39.5e6,
                events: 57.6e6,
            },
            DatasetKind::Spinner => DatasetSpec {
                kind: self,
                res: Resolution::HD720,
                duration_s: 5.0,
                peak_rate: 11.4e6,
                events: 54.1e6,
            },
            DatasetKind::DynamicDof => DatasetSpec {
                kind: self,
                res: Resolution::DAVIS240,
                duration_s: 61.0,
                peak_rate: 4.5e6,
                events: 57.1e6,
            },
            DatasetKind::ShapesDof => DatasetSpec {
                kind: self,
                res: Resolution::DAVIS240,
                duration_s: 62.5,
                peak_rate: 1.9e6,
                events: 18.0e6,
            },
        }
    }
}

/// Published statistics of one dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    /// Which dataset.
    pub kind: DatasetKind,
    /// Sensor resolution.
    pub res: Resolution,
    /// Recording length (s).
    pub duration_s: f64,
    /// Peak event rate (events/s) over 10 ms windows.
    pub peak_rate: f64,
    /// Total events in the recording.
    pub events: f64,
}

impl DatasetSpec {
    /// Mean event rate (events/s).
    pub fn mean_rate(&self) -> f64 {
        self.events / self.duration_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table1() {
        let d = DatasetKind::Driving.spec();
        assert_eq!(d.events, 111.4e6);
        assert_eq!(d.peak_rate, 25.9e6);
        let l = DatasetKind::Laser.spec();
        assert_eq!(l.events, 57.6e6);
        let s = DatasetKind::ShapesDof.spec();
        assert_eq!(s.events, 18.0e6);
        assert_eq!(s.peak_rate, 1.9e6);
    }

    #[test]
    fn mean_rate_below_peak() {
        for kind in DatasetKind::ALL {
            let s = kind.spec();
            assert!(
                s.mean_rate() <= s.peak_rate * 1.001,
                "{}: mean {} > peak {}",
                kind.name(),
                s.mean_rate(),
                s.peak_rate
            );
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<_> = DatasetKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 5);
    }
}
