//! Corner ground truth: vertex trajectories of the synthetic scenes and
//! event labeling against them.
//!
//! A detection experiment needs, per event, a binary label "is this event
//! at a real corner?".  The synthetic scenes know exactly where their
//! polygon vertices project at every instant, so the label is: the event
//! lies within `radius_px` of any vertex position interpolated at the
//! event's timestamp.  This mirrors how luvHarris scores detectors against
//! hand-labelled ground truth, but with perfect labels.

use crate::events::Event;

/// Anything that can answer "is `(x, y)` at time `t` a true corner?".
///
/// Implemented by the synthetic scenes' exact [`GroundTruth`] and by the
/// file-backed [`CornerLabels`](super::public::CornerLabels) of real
/// public recordings, so the evaluation machinery
/// ([`ScoredSink`](crate::eval::ScoredSink)) scores both the same way.
pub trait CornerOracle {
    /// Is there a true corner within `radius_px` of `(x, y)` at time `t`?
    fn is_corner(&self, x: f32, y: f32, t: u64, radius_px: f32) -> bool;
}

impl CornerOracle for GroundTruth {
    fn is_corner(&self, x: f32, y: f32, t: u64, radius_px: f32) -> bool {
        self.near_corner(x, y, t, radius_px)
    }
}

/// One corner's trajectory: time-ordered (t_us, x, y) samples.
#[derive(Debug, Clone, Default)]
pub struct CornerTrack {
    /// Sample timestamps (µs), ascending.
    pub t_us: Vec<u64>,
    /// Sub-pixel x per sample.
    pub x: Vec<f32>,
    /// Sub-pixel y per sample.
    pub y: Vec<f32>,
}

impl CornerTrack {
    /// Interpolated position at `t` (clamped at the ends); `None` if the
    /// track is empty or `t` is outside the track by more than `slack_us`.
    pub fn position_at(&self, t: u64, slack_us: u64) -> Option<(f32, f32)> {
        if self.t_us.is_empty() {
            return None;
        }
        let first = self.t_us[0];
        let last = *self.t_us.last().unwrap();
        if t + slack_us < first || t > last + slack_us {
            return None;
        }
        let i = match self.t_us.binary_search(&t) {
            Ok(i) => return Some((self.x[i], self.y[i])),
            Err(i) => i,
        };
        if i == 0 {
            return Some((self.x[0], self.y[0]));
        }
        if i >= self.t_us.len() {
            return Some((*self.x.last().unwrap(), *self.y.last().unwrap()));
        }
        let (t0, t1) = (self.t_us[i - 1], self.t_us[i]);
        let f = if t1 > t0 { (t - t0) as f32 / (t1 - t0) as f32 } else { 0.0 };
        Some((
            self.x[i - 1] + f * (self.x[i] - self.x[i - 1]),
            self.y[i - 1] + f * (self.y[i] - self.y[i - 1]),
        ))
    }
}

/// Full ground truth of a scene: all corner tracks.
#[derive(Debug, Clone, Default)]
pub struct GroundTruth {
    /// One track per polygon vertex.
    pub tracks: Vec<CornerTrack>,
}

impl GroundTruth {
    /// Is there a true corner within `radius_px` of `(x, y)` at time `t`?
    pub fn near_corner(&self, x: f32, y: f32, t: u64, radius_px: f32) -> bool {
        let r2 = radius_px * radius_px;
        self.tracks.iter().any(|tr| {
            tr.position_at(t, 2_000)
                .map(|(cx, cy)| {
                    let dx = cx - x;
                    let dy = cy - y;
                    dx * dx + dy * dy <= r2
                })
                .unwrap_or(false)
        })
    }

    /// Label a batch of events: `true` = corner event.
    pub fn label_events(&self, events: &[Event], radius_px: f32) -> Vec<bool> {
        events
            .iter()
            .map(|e| self.near_corner(e.x as f32, e.y as f32, e.t, radius_px))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn track() -> CornerTrack {
        CornerTrack {
            t_us: vec![0, 1000, 2000],
            x: vec![10.0, 20.0, 30.0],
            y: vec![5.0, 5.0, 15.0],
        }
    }

    #[test]
    fn interpolates_linearly() {
        let tr = track();
        let (x, y) = tr.position_at(500, 0).unwrap();
        assert!((x - 15.0).abs() < 1e-5 && (y - 5.0).abs() < 1e-5);
        let (x, y) = tr.position_at(1500, 0).unwrap();
        assert!((x - 25.0).abs() < 1e-5 && (y - 10.0).abs() < 1e-5);
    }

    #[test]
    fn exact_sample_hit() {
        let tr = track();
        assert_eq!(tr.position_at(1000, 0).unwrap(), (20.0, 5.0));
    }

    #[test]
    fn clamps_within_slack_rejects_beyond() {
        let tr = track();
        assert_eq!(tr.position_at(2100, 500).unwrap(), (30.0, 15.0));
        assert!(tr.position_at(10_000, 500).is_none());
    }

    #[test]
    fn near_corner_radius() {
        let gt = GroundTruth { tracks: vec![track()] };
        assert!(gt.near_corner(10.5, 5.0, 0, 1.0));
        assert!(!gt.near_corner(14.0, 5.0, 0, 1.0));
        assert!(gt.near_corner(14.0, 5.0, 0, 5.0));
    }

    #[test]
    fn label_events_matches_near_corner() {
        let gt = GroundTruth { tracks: vec![track()] };
        let evs = vec![Event::on(10, 5, 0), Event::on(60, 60, 0), Event::on(20, 5, 1000)];
        assert_eq!(gt.label_events(&evs, 2.0), vec![true, false, true]);
    }

    #[test]
    fn empty_ground_truth_labels_all_false() {
        let gt = GroundTruth::default();
        let evs = vec![Event::on(1, 1, 0)];
        assert_eq!(gt.label_events(&evs, 3.0), vec![false]);
    }
}
