//! Enumerative scenario grid for robustness harnesses: the cartesian
//! product {motion x event rate x noise x resolution x Vdd}, each point a
//! fully-specified synthetic scene plus an operating voltage.
//!
//! The grid drives the two fault-fidelity harnesses:
//!
//! * the `vdd-sweep` AUC-vs-voltage reproduction ([`crate::eval`]), which
//!   holds the scene axes fixed and walks the Vdd axis, and
//! * the serve-overload integration test, which picks an `Overload` rate
//!   point to force realtime lag and a `Nominal` one to recover.
//!
//! Enumeration order is fixed (resolution, motion, rate, noise, then
//! Vdd — outermost to innermost), so scenario lists are deterministic and
//! stable across runs; nothing here consults a clock or ambient RNG.

use crate::events::Resolution;

use super::synthetic::{Scene, SceneConfig};

/// Shape-motion regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Motion {
    /// Base translation/rotation rates of the underlying preset.
    Slow,
    /// 3x linear speed, 2.5x spin — stresses TOS decay and LUT staleness.
    Fast,
}

impl Motion {
    /// Grid-name fragment.
    pub fn label(self) -> &'static str {
        match self {
            Motion::Slow => "slow",
            Motion::Fast => "fast",
        }
    }
}

/// Event-rate regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RateLevel {
    /// The preset's signal rate.
    Nominal,
    /// 4x the preset's signal rate — enough to outrun a realtime budget
    /// and trip the serving layer's degradation governor.
    Overload,
}

impl RateLevel {
    /// Grid-name fragment.
    pub fn label(self) -> &'static str {
        match self {
            RateLevel::Nominal => "nominal",
            RateLevel::Overload => "overload",
        }
    }
}

/// Background-activity noise regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NoiseLevel {
    /// No background activity (STCF sees pure signal).
    Clean,
    /// The preset's background-activity rate.
    Noisy,
}

impl NoiseLevel {
    /// Grid-name fragment.
    pub fn label(self) -> &'static str {
        match self {
            NoiseLevel::Clean => "clean",
            NoiseLevel::Noisy => "noisy",
        }
    }
}

/// One grid point: a concrete scene plus the supply voltage to run it at.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scene-axes key, voltage excluded (e.g. `slow-nominal-noisy-64x64`).
    /// Scenarios sharing a key differ only in `vdd`, so harnesses can
    /// generate the event stream once per key and replay it per voltage.
    pub key: String,
    /// Supply voltage (V) this point runs the backend at.
    pub vdd: f64,
    /// Fully-resolved scene parameters.
    pub scene: SceneConfig,
}

impl Scenario {
    /// Full display label including the voltage (`<key>@600mV`).
    pub fn label(&self) -> String {
        format!("{}@{}mV", self.key, (self.vdd * 1000.0).round() as u64)
    }

    /// Instantiate the scene with a seed (see [`SceneConfig::build`]).
    pub fn build(&self, seed: u64) -> Scene {
        self.scene.clone().build(seed)
    }
}

/// Axis values for the enumerative grid.
#[derive(Debug, Clone)]
pub struct ScenarioGrid {
    /// Motion axis.
    pub motions: Vec<Motion>,
    /// Event-rate axis.
    pub rates: Vec<RateLevel>,
    /// Noise axis.
    pub noises: Vec<NoiseLevel>,
    /// Resolution axis (each maps to a scene preset, see [`base_scene`]).
    pub resolutions: Vec<Resolution>,
    /// Supply-voltage axis (V).
    pub vdds: Vec<f64>,
}

impl ScenarioGrid {
    /// The full robustness grid: every axis populated, voltages spanning
    /// the paper's fault ladder (published-zero 1.2/0.8/0.62 V down to
    /// the 0.61/0.60 V nonzero-BER points).
    pub fn full() -> Self {
        Self {
            motions: vec![Motion::Slow, Motion::Fast],
            rates: vec![RateLevel::Nominal, RateLevel::Overload],
            noises: vec![NoiseLevel::Clean, NoiseLevel::Noisy],
            resolutions: vec![Resolution::TEST64, Resolution::DAVIS240],
            vdds: vec![0.60, 0.61, 0.62, 0.8, 1.2],
        }
    }

    /// The paper-shaped sweep: one DAVIS240 `shapes_dof`-like scene, the
    /// five-voltage fault ladder (Fig. 11 / Sec. V-C operating points).
    pub fn paper() -> Self {
        Self {
            motions: vec![Motion::Slow],
            rates: vec![RateLevel::Nominal],
            noises: vec![NoiseLevel::Noisy],
            resolutions: vec![Resolution::DAVIS240],
            vdds: vec![0.60, 0.61, 0.62, 0.8, 1.2],
        }
    }

    /// CI smoke grid: one small scene, four voltages bracketing the
    /// BER knee — fast enough for a per-push lane.
    pub fn smoke() -> Self {
        Self {
            motions: vec![Motion::Slow],
            rates: vec![RateLevel::Nominal],
            noises: vec![NoiseLevel::Noisy],
            resolutions: vec![Resolution::TEST64],
            vdds: vec![0.60, 0.61, 0.62, 1.2],
        }
    }

    /// Number of grid points.
    pub fn len(&self) -> usize {
        self.motions.len()
            * self.rates.len()
            * self.noises.len()
            * self.resolutions.len()
            * self.vdds.len()
    }

    /// Whether any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate every grid point in the fixed (resolution, motion, rate,
    /// noise, Vdd) order.
    pub fn enumerate(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        for &res in &self.resolutions {
            for &motion in &self.motions {
                for &rate in &self.rates {
                    for &noise in &self.noises {
                        let scene = scene_for(res, motion, rate, noise);
                        let key = format!(
                            "{}-{}-{}-{}x{}",
                            motion.label(),
                            rate.label(),
                            noise.label(),
                            res.width,
                            res.height
                        );
                        for &vdd in &self.vdds {
                            out.push(Scenario { key: key.clone(), vdd, scene: scene.clone() });
                        }
                    }
                }
            }
        }
        out
    }
}

/// Scene preset for a resolution: `TEST64` -> [`SceneConfig::test64`],
/// `DAVIS240` -> [`SceneConfig::shapes_dof`]; any other geometry reuses
/// the test preset with the resolution substituted.
pub fn base_scene(res: Resolution) -> SceneConfig {
    if res == Resolution::DAVIS240 {
        SceneConfig::shapes_dof()
    } else {
        SceneConfig { res, ..SceneConfig::test64() }
    }
}

/// Apply the motion/rate/noise axes to the resolution's base preset.
fn scene_for(res: Resolution, motion: Motion, rate: RateLevel, noise: NoiseLevel) -> SceneConfig {
    let mut scene = base_scene(res);
    if motion == Motion::Fast {
        scene.speed = (scene.speed.0 * 3.0, scene.speed.1 * 3.0);
        scene.omega = (scene.omega.0 * 2.5, scene.omega.1 * 2.5);
    }
    if rate == RateLevel::Overload {
        scene.signal_rate *= 4.0;
    }
    if noise == NoiseLevel::Clean {
        scene.noise_rate = 0.0;
    }
    scene
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_has_product_cardinality() {
        let g = ScenarioGrid::full();
        let scenarios = g.enumerate();
        assert_eq!(scenarios.len(), g.len());
        assert_eq!(scenarios.len(), 2 * 2 * 2 * 2 * 5);
        assert!(!g.is_empty());
    }

    #[test]
    fn labels_are_unique_and_deterministic() {
        let a: Vec<String> = ScenarioGrid::full().enumerate().iter().map(|s| s.label()).collect();
        let b: Vec<String> = ScenarioGrid::full().enumerate().iter().map(|s| s.label()).collect();
        assert_eq!(a, b, "enumeration order is fixed");
        let mut dedup = a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "every grid point labels uniquely");
    }

    #[test]
    fn axes_change_the_scene() {
        let base = scene_for(Resolution::TEST64, Motion::Slow, RateLevel::Nominal, NoiseLevel::Noisy);
        let fast = scene_for(Resolution::TEST64, Motion::Fast, RateLevel::Nominal, NoiseLevel::Noisy);
        assert!(fast.speed.0 > base.speed.0 && fast.omega.1 > base.omega.1);
        let over = scene_for(Resolution::TEST64, Motion::Slow, RateLevel::Overload, NoiseLevel::Noisy);
        assert_eq!(over.signal_rate, base.signal_rate * 4.0);
        let clean = scene_for(Resolution::TEST64, Motion::Slow, RateLevel::Nominal, NoiseLevel::Clean);
        assert_eq!(clean.noise_rate, 0.0);
        assert!(base.noise_rate > 0.0);
    }

    #[test]
    fn key_groups_share_the_scene_and_differ_in_vdd() {
        let scenarios = ScenarioGrid::smoke().enumerate();
        assert_eq!(scenarios.len(), 4);
        assert!(scenarios.windows(2).all(|w| w[0].key == w[1].key));
        let vdds: Vec<f64> = scenarios.iter().map(|s| s.vdd).collect();
        assert_eq!(vdds, vec![0.60, 0.61, 0.62, 1.2]);
        // shared key => shared stream: building any two with one seed is
        // bit-identical
        let a = scenarios[0].build(11).generate(2_000);
        let b = scenarios[3].build(11).generate(2_000);
        assert_eq!(a, b);
    }

    #[test]
    fn davis240_maps_to_the_shapes_preset() {
        let s = base_scene(Resolution::DAVIS240);
        assert_eq!(s.res, Resolution::DAVIS240);
        assert_eq!(s.shapes, SceneConfig::shapes_dof().shapes);
        let t = base_scene(Resolution::TEST64);
        assert_eq!(t.res, Resolution::TEST64);
    }
}
