//! Scene-based synthetic event generation with exact corner ground truth.
//!
//! The generator animates rigid polygons (squares, triangles, 5-point
//! stars) over the sensor plane with smooth translation + rotation and
//! emits contrast events along their boundaries: a boundary pixel fires
//! when the edge sweeps across it, with polarity given by the sign of the
//! normal velocity (leading edge brightens, trailing edge darkens — ON/OFF
//! as in a real DVS looking at dark shapes on white paper, the exact
//! setting of the `shapes_6dof` recording).  Isolated background-activity
//! noise is mixed in at a configurable rate so the STCF stage has real
//! work to do.
//!
//! Ground truth: every polygon vertex contributes a [`gt::CornerTrack`]
//! sampled at each animation step.

use crate::events::source::EventSource;
use crate::events::{Event, Polarity, Resolution};
use crate::util::rng::Rng;

use super::gt::{CornerTrack, GroundTruth};

/// One rigid polygon in the scene.
#[derive(Debug, Clone)]
struct Shape {
    /// Vertex offsets from the centre at angle 0 (sub-pixel).
    verts: Vec<(f32, f32)>,
    /// Centre position at t=0.
    centre: (f32, f32),
    /// Linear velocity (px/s).
    vel: (f32, f32),
    /// Sinusoidal wander amplitude (px) and angular frequency (rad/s).
    wander: (f32, f32),
    /// Rotation rate (rad/s).
    omega: f32,
}

impl Shape {
    /// Centre at time `t_s`, bouncing softly inside the sensor.
    fn centre_at(&self, t_s: f32, res: Resolution) -> (f32, f32) {
        let (w, h) = (res.width as f32, res.height as f32);
        let margin = 14.0;
        let bounce = |p0: f32, v: f32, lo: f32, hi: f32| -> f32 {
            let span = (hi - lo).max(1.0);
            let raw = p0 - lo + v * t_s;
            // reflect: triangle wave over [0, 2*span)
            let m = raw.rem_euclid(2.0 * span);
            lo + if m < span { m } else { 2.0 * span - m }
        };
        let wx = self.wander.0 * (self.wander.1 * t_s).sin();
        let wy = self.wander.0 * (self.wander.1 * t_s * 0.7 + 1.3).cos();
        (
            bounce(self.centre.0 + wx, self.vel.0, margin, w - margin),
            bounce(self.centre.1 + wy, self.vel.1, margin, h - margin),
        )
    }

    /// Vertex positions at time `t_s`.
    fn verts_at(&self, t_s: f32, res: Resolution) -> Vec<(f32, f32)> {
        let (cx, cy) = self.centre_at(t_s, res);
        let a = self.omega * t_s;
        let (s, c) = a.sin_cos();
        self.verts
            .iter()
            .map(|&(vx, vy)| (cx + vx * c - vy * s, cy + vx * s + vy * c))
            .collect()
    }
}

/// Scene parameters.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    /// Sensor geometry.
    pub res: Resolution,
    /// Number of shapes.
    pub shapes: usize,
    /// Shape circumradius range (px).
    pub size_px: (f32, f32),
    /// Linear speed range (px/s).
    pub speed: (f32, f32),
    /// Rotation rate range (rad/s).
    pub omega: (f32, f32),
    /// Mean signal event rate (events/s) the generator thins to.
    pub signal_rate: f64,
    /// Background-activity noise rate (events/s over the whole array).
    pub noise_rate: f64,
    /// Animation step (µs).
    pub step_us: u64,
}

impl SceneConfig {
    /// `shapes_6dof` analogue: a handful of large slow shapes, low rate.
    pub fn shapes_dof() -> Self {
        Self {
            res: Resolution::DAVIS240,
            shapes: 4,
            size_px: (16.0, 26.0),
            speed: (30.0, 90.0),
            omega: (0.3, 1.2),
            signal_rate: 280_000.0,
            noise_rate: 8_000.0,
            step_us: 500,
        }
    }

    /// `dynamic_6dof` analogue: more, faster, smaller shapes (cluttered
    /// office scene), higher rate.
    pub fn dynamic_dof() -> Self {
        Self {
            res: Resolution::DAVIS240,
            shapes: 9,
            size_px: (8.0, 18.0),
            speed: (80.0, 240.0),
            omega: (0.8, 3.0),
            signal_rate: 900_000.0,
            noise_rate: 30_000.0,
            step_us: 500,
        }
    }

    /// Small fast scene for tests.
    pub fn test64() -> Self {
        Self {
            res: Resolution::TEST64,
            shapes: 2,
            size_px: (8.0, 12.0),
            speed: (40.0, 120.0),
            omega: (0.5, 2.0),
            signal_rate: 120_000.0,
            noise_rate: 4_000.0,
            step_us: 500,
        }
    }

    /// Instantiate the scene with a seed.
    pub fn build(self, seed: u64) -> Scene {
        let mut rng = Rng::seed_from(seed);
        let mut shapes = Vec::with_capacity(self.shapes);
        for i in 0..self.shapes {
            let r = rng.range_f64(self.size_px.0 as f64, self.size_px.1 as f64) as f32;
            let n_verts = match i % 3 {
                0 => 4, // square
                1 => 3, // triangle
                _ => 5, // pentagon/star
            };
            let phase = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
            let verts: Vec<(f32, f32)> = (0..n_verts)
                .map(|k| {
                    let a = phase + k as f32 * std::f32::consts::TAU / n_verts as f32;
                    (r * a.cos(), r * a.sin())
                })
                .collect();
            let speed = rng.range_f64(self.speed.0 as f64, self.speed.1 as f64) as f32;
            let dir = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
            shapes.push(Shape {
                verts,
                centre: (
                    rng.range_f64(20.0, self.res.width as f64 - 20.0) as f32,
                    rng.range_f64(20.0, self.res.height as f64 - 20.0) as f32,
                ),
                vel: (speed * dir.cos(), speed * dir.sin()),
                wander: (
                    rng.range_f64(2.0, 8.0) as f32,
                    rng.range_f64(0.5, 2.0) as f32,
                ),
                omega: rng.range_f64(self.omega.0 as f64, self.omega.1 as f64) as f32,
            });
        }
        Scene { cfg: self, shapes, rng }
    }
}

/// An instantiated scene ready to generate events.
#[derive(Debug, Clone)]
pub struct Scene {
    cfg: SceneConfig,
    shapes: Vec<Shape>,
    rng: Rng,
}

impl Scene {
    /// Scene parameters.
    pub fn config(&self) -> &SceneConfig {
        &self.cfg
    }

    /// Advance the animation by one step at `t_us`, appending the step's
    /// events (unsorted; all timestamps in `[t_us, t_us + step_us)`).
    /// When `tracks` is given, ground-truth corner positions are sampled
    /// into it (indexed as in [`GroundTruth::tracks`]). The RNG call
    /// sequence is identical with or without tracks, so streamed and
    /// batch generation stay bit-identical per seed.
    fn step(
        &mut self,
        t_us: u64,
        events: &mut Vec<Event>,
        mut tracks: Option<&mut Vec<CornerTrack>>,
    ) {
        let res = self.cfg.res;
        let step_us = self.cfg.step_us;
        let step_s = step_us as f64 * 1e-6;
        let signal_per_step = self.cfg.signal_rate * step_s;
        let noise_per_step = self.cfg.noise_rate * step_s;
        let t_s = t_us as f32 * 1e-6;
        // --- ground truth sampling + boundary event emission ----------
        let mut boundary: Vec<(f32, f32, Polarity)> = Vec::with_capacity(512);
        let mut track_idx = 0usize;
        for shape in &self.shapes {
            let verts = shape.verts_at(t_s, res);
            let verts_next = shape.verts_at(t_s + step_s as f32, res);
            if let Some(tracks) = tracks.as_deref_mut() {
                for (vi, &(vx, vy)) in verts.iter().enumerate() {
                    let tr = &mut tracks[track_idx + vi];
                    tr.t_us.push(t_us);
                    tr.x.push(vx);
                    tr.y.push(vy);
                }
            }
            // walk each edge, sample boundary points, polarity from the
            // sign of normal motion
            let k = verts.len();
            for i in 0..k {
                let a = verts[i];
                let b = verts[(i + 1) % k];
                let a2 = verts_next[i];
                let len = ((b.0 - a.0).powi(2) + (b.1 - a.1).powi(2)).sqrt();
                let samples = (len.ceil() as usize).max(2);
                // edge normal (outward-ish; sign only matters for ON/OFF)
                let nx = b.1 - a.1;
                let ny = a.0 - b.0;
                let mvx = a2.0 - a.0;
                let mvy = a2.1 - a.1;
                let lead = nx * mvx + ny * mvy >= 0.0;
                for s in 0..samples {
                    let f = s as f32 / samples as f32;
                    let px = a.0 + f * (b.0 - a.0);
                    let py = a.1 + f * (b.1 - a.1);
                    boundary.push((px, py, if lead { Polarity::On } else { Polarity::Off }));
                }
            }
            track_idx += k;
        }
        // thin boundary samples to the target signal rate
        let want_signal = self.rng.poisson(signal_per_step) as usize;
        if !boundary.is_empty() {
            for _ in 0..want_signal {
                let &(px, py, pol) = &boundary[self.rng.below(boundary.len() as u64) as usize];
                // sub-pixel jitter models edge thickness
                let x = px + self.rng.normal(0.0, 0.5) as f32;
                let y = py + self.rng.normal(0.0, 0.5) as f32;
                if res.contains(x as i32, y as i32) && x >= 0.0 && y >= 0.0 {
                    let jitter = self.rng.below(step_us.max(1)) as u64;
                    events.push(Event::new(x as u16, y as u16, t_us + jitter, pol));
                }
            }
        }
        // BA noise: uniform isolated events
        let want_noise = self.rng.poisson(noise_per_step) as usize;
        for _ in 0..want_noise {
            let x = self.rng.below(res.width as u64) as u16;
            let y = self.rng.below(res.height as u64) as u16;
            let jitter = self.rng.below(step_us.max(1)) as u64;
            let pol = if self.rng.chance(0.5) { Polarity::On } else { Polarity::Off };
            events.push(Event::new(x, y, t_us + jitter, pol));
        }
    }

    /// Generate `n` events (time-sorted) together with ground truth.
    pub fn generate_with_gt(&mut self, n: usize) -> (Vec<Event>, GroundTruth) {
        let mut events: Vec<Event> = Vec::with_capacity(n + n / 8);
        let mut tracks: Vec<CornerTrack> =
            vec![CornerTrack::default(); self.shapes.iter().map(|s| s.verts.len()).sum()];
        let mut t_us: u64 = 0;
        while events.len() < n {
            self.step(t_us, &mut events, Some(&mut tracks));
            t_us += self.cfg.step_us;
        }
        events.sort_by_key(|e| e.t);
        events.truncate(n);
        (events, GroundTruth { tracks })
    }

    /// Generate `n` events without keeping ground truth.
    pub fn generate(&mut self, n: usize) -> Vec<Event> {
        self.generate_with_gt(n).0
    }

    /// Turn the scene into a bounded-memory [`EventSource`] yielding
    /// `total_events` events in chunks of `chunk_events`.
    pub fn into_source(self, total_events: usize, chunk_events: usize) -> SceneSource {
        SceneSource::new(self, total_events, chunk_events)
    }
}

/// Stream a synthetic scene as bounded chunks without materializing the
/// whole recording: the scene is stepped on demand and each step's
/// events are sorted locally (step time ranges are disjoint, so the
/// concatenation is globally time-sorted). The emitted stream is
/// bit-identical to [`Scene::generate`] with the same seed and total.
///
/// The chunk bound is strict: a scene step that produces more events
/// than the chunk has room for is split across chunks (the remainder is
/// carried in the step buffer), so `next_chunk` never appends more than
/// `chunk_events` — a high-rate scene config cannot blow the caller's
/// O(chunk) memory budget.
#[derive(Debug, Clone)]
pub struct SceneSource {
    scene: Scene,
    remaining: usize,
    chunk_events: usize,
    t_us: u64,
    step_buf: Vec<Event>,
    /// Next unconsumed event in `step_buf` (a step split across chunks).
    step_pos: usize,
}

impl SceneSource {
    /// Stream `total_events` events from `scene`, `chunk_events` at a time.
    pub fn new(scene: Scene, total_events: usize, chunk_events: usize) -> Self {
        Self {
            scene,
            remaining: total_events,
            chunk_events: chunk_events.max(1),
            t_us: 0,
            step_buf: Vec::new(),
            step_pos: 0,
        }
    }
}

impl EventSource for SceneSource {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> anyhow::Result<usize> {
        let start = out.len();
        while out.len() - start < self.chunk_events && self.remaining > 0 {
            // drain the current step first (it may span several chunks)
            if self.step_pos < self.step_buf.len() {
                let room = self.chunk_events - (out.len() - start);
                let avail = self.step_buf.len() - self.step_pos;
                let take = room.min(avail).min(self.remaining);
                out.extend_from_slice(&self.step_buf[self.step_pos..self.step_pos + take]);
                self.step_pos += take;
                self.remaining -= take;
                continue;
            }
            // step the animation for the next batch of events
            self.step_buf.clear();
            self.step_pos = 0;
            self.scene.step(self.t_us, &mut self.step_buf, None);
            self.t_us += self.scene.cfg.step_us;
            self.step_buf.sort_by_key(|e| e.t);
        }
        Ok(out.len() - start)
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::stream;

    #[test]
    fn generates_requested_count_sorted_and_in_bounds() {
        let mut scene = SceneConfig::test64().build(1);
        let (evs, _gt) = scene.generate_with_gt(20_000);
        assert_eq!(evs.len(), 20_000);
        stream::validate(&evs, Resolution::TEST64).unwrap();
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SceneConfig::test64().build(7).generate(5_000);
        let b = SceneConfig::test64().build(7).generate(5_000);
        assert_eq!(a, b);
        let c = SceneConfig::test64().build(8).generate(5_000);
        assert_ne!(a, c);
    }

    #[test]
    fn ground_truth_tracks_cover_stream_duration() {
        let mut scene = SceneConfig::test64().build(2);
        let (evs, gt) = scene.generate_with_gt(30_000);
        assert_eq!(gt.tracks.len(), 4 + 3); // square(4) + triangle(3)
        let t_end = evs.last().unwrap().t;
        for tr in &gt.tracks {
            assert!(*tr.t_us.last().unwrap() + 1000 >= t_end);
            // positions stay within the (margin-padded) sensor
            for (&x, &y) in tr.x.iter().zip(&tr.y) {
                assert!(x > -30.0 && x < 94.0 && y > -30.0 && y < 94.0);
            }
        }
    }

    #[test]
    fn events_cluster_near_shape_boundaries() {
        // Signal events must be spatially correlated: the mean distance of
        // an event to its nearest GT *edge* is small. We proxy with corner
        // proximity: a noticeable fraction of events lies near corners.
        let mut scene = SceneConfig::test64().build(3);
        let (evs, gt) = scene.generate_with_gt(20_000);
        let labels = gt.label_events(&evs, 4.0);
        let frac = labels.iter().filter(|&&b| b).count() as f64 / labels.len() as f64;
        assert!(frac > 0.08, "corner-adjacent fraction {frac}");
        assert!(frac < 0.9, "everything near corners is suspicious {frac}");
    }

    #[test]
    fn both_polarities_present() {
        let mut scene = SceneConfig::test64().build(4);
        let evs = scene.generate(10_000);
        let on = evs.iter().filter(|e| e.p == Polarity::On).count();
        assert!(on > 1000 && on < 9000, "ON count {on}");
    }

    #[test]
    fn mean_rate_tracks_config() {
        let cfg = SceneConfig::test64();
        let target = cfg.signal_rate + cfg.noise_rate;
        let mut scene = cfg.build(5);
        let evs = scene.generate(50_000);
        let s = stream::stats(&evs, 0.01);
        assert!(
            (s.mean_rate - target).abs() / target < 0.15,
            "mean {} vs target {}",
            s.mean_rate,
            target
        );
    }

    #[test]
    fn scene_source_matches_batch_generation() {
        let want = SceneConfig::test64().build(9).generate(5_000);
        for chunk in [1usize, 333, 5_000, 9_999] {
            let mut src = SceneConfig::test64().build(9).into_source(5_000, chunk);
            assert_eq!(src.size_hint(), Some(5_000));
            let mut got = Vec::new();
            while src.next_chunk(&mut got).unwrap() > 0 {}
            assert_eq!(got, want, "chunk {chunk}");
            assert_eq!(src.size_hint(), Some(0));
        }
    }

    #[test]
    fn scene_source_chunk_bound_is_strict() {
        // one test64 scene step emits ~62 events ((120k+4k) eps x 500 µs),
        // so chunk sizes below that force every step to split across
        // chunks; the source must still be bit-identical to batch
        // generation while never over-filling a chunk
        let want = SceneConfig::test64().build(21).generate(3_000);
        for chunk in [1usize, 7, 50] {
            let mut src = SceneConfig::test64().build(21).into_source(3_000, chunk);
            let mut got = Vec::new();
            loop {
                let before = got.len();
                let n = src.next_chunk(&mut got).unwrap();
                assert!(n <= chunk, "chunk {chunk}: appended {n}");
                assert_eq!(got.len() - before, n);
                if n == 0 {
                    break;
                }
            }
            assert_eq!(got, want, "chunk {chunk}");
        }
    }

    #[test]
    fn shapes_dof_and_dynamic_dof_presets_differ() {
        let a = SceneConfig::shapes_dof();
        let b = SceneConfig::dynamic_dof();
        assert!(b.signal_rate > a.signal_rate);
        assert!(b.shapes > a.shapes);
        assert_eq!(a.res, Resolution::DAVIS240);
    }
}
