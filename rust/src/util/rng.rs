//! Deterministic PRNG: xoshiro256** seeded via SplitMix64, plus Gaussian
//! (Box-Muller) and Poisson samplers. Replaces `rand`/`rand_distr` in this
//! offline build; statistical quality is far beyond what the simulations
//! need and every experiment is reproducible from its seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller deviate.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically (any u64, including 0).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s, gauss_spare: None }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) (Lemire-style rejection-free for our needs).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform i64 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * v;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal deviate with mean/σ.
    #[inline]
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gauss()
    }

    /// Poisson deviate (Knuth for small λ, normal approximation above 64 —
    /// the event generators only need counts, not exact tails).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(43);
        assert_ne!(Rng::seed_from(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed_from(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from(3);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gauss();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = Rng::seed_from(4);
        for &lam in &[0.5, 4.0, 200.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lam) as f64;
            }
            let mean = sum / n as f64;
            assert!((mean - lam).abs() / lam < 0.05, "lambda {lam} mean {mean}");
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_helpers() {
        let mut r = Rng::seed_from(6);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = r.range_f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&f));
        }
    }
}
