//! Tiny randomized property-test harness (offline stand-in for `proptest`).
//!
//! `check(seed, cases, f)` runs `f` against `cases` independently seeded
//! RNGs; on failure it retries with the same sub-seed to confirm and then
//! panics with the reproducing seed, so failures are one-line reproducible:
//! `check_one(SEED, f)`.

use super::rng::Rng;

/// Run a randomized property `cases` times. The closure receives a fresh
/// deterministic RNG per case and should panic (assert) on violation.
pub fn check<F: Fn(&mut Rng)>(seed: u64, cases: u32, f: F) {
    for case in 0..cases {
        let sub = sub_seed(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from(sub);
            f(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (reproduce with check_one({sub:#x}, ..)): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported sub-seed.
pub fn check_one<F: Fn(&mut Rng)>(sub_seed: u64, f: F) {
    let mut rng = Rng::seed_from(sub_seed);
    f(&mut rng);
}

/// Derive the per-case seed (stable across runs).
pub fn sub_seed(seed: u64, case: u32) -> u64 {
    seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u32;
        // interior mutability via a Cell to count invocations
        let cell = std::cell::Cell::new(0u32);
        check(7, 25, |rng| {
            let _ = rng.f64();
            cell.set(cell.get() + 1);
        });
        count += cell.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_seed() {
        check(7, 50, |rng| {
            // fails whenever the draw is below 0.5 — quickly
            assert!(rng.f64() >= 0.5, "draw too small");
        });
    }

    #[test]
    fn sub_seed_is_stable_and_distinct() {
        assert_eq!(sub_seed(1, 0), sub_seed(1, 0));
        assert_ne!(sub_seed(1, 0), sub_seed(1, 1));
        assert_ne!(sub_seed(1, 5), sub_seed(2, 5));
    }
}
