//! Minimal JSON emitter + parser for experiment outputs and the artifact
//! manifest. Replaces `serde_json` in this offline build.
//!
//! The emitter builds a [`Json`] tree and renders it; the parser handles
//! the full JSON grammar minus exotic escapes — enough for
//! `artifacts/meta.json` and the result files this crate itself writes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// boolean
    Bool(bool),
    /// number (f64 covers all our uses)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object with stable (sorted) key order
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object builder.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object keys (sorted), if an object.
    pub fn keys(&self) -> Option<Vec<&str>> {
        match self {
            Json::Obj(m) => Some(m.keys().map(|s| s.as_str()).collect()),
            _ => None,
        }
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or("bad escape")? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                    self.i += 1;
                }
                _ => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("davis240".into())),
            ("height", Json::Num(180.0)),
            ("ok", Json::Bool(true)),
            ("list", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested_meta_like_document() {
        let text = r#"{
          "artifacts": {"davis240": {"file": "harris_davis240.hlo.txt", "height": 180, "width": 240}},
          "format": "hlo-text", "return_tuple": true
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let a = j.get("artifacts").unwrap().get("davis240").unwrap();
        assert_eq!(a.get("height").unwrap().as_f64().unwrap(), 180.0);
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        let text = j.render();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("42").unwrap().render(), "42");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"µW/naïve\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "µW/naïve");
    }
}
