//! Small in-tree utilities that replace external crates in this offline
//! build: a fast deterministic PRNG with Gaussian/Poisson samplers, a JSON
//! emitter for experiment outputs, a randomized property-test harness,
//! and the std/loom synchronization shim the concurrent layers build on.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod sync;
