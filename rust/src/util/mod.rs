//! Small in-tree utilities that replace external crates in this offline
//! build: a fast deterministic PRNG with Gaussian/Poisson samplers, a JSON
//! emitter for experiment outputs, and a randomized property-test harness.

pub mod json;
pub mod proptest;
pub mod rng;
