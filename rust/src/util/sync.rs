//! Synchronization shim: `std` primitives normally, [`loom`] mock
//! primitives under `--cfg loom`, so the concurrent layers (`serve`,
//! `serve::pool`, `coordinator::lut_worker`, `tos::sharded`) can be
//! model-checked without forking their code.
//!
//! Those modules import **only** from here — never `std::sync` /
//! `std::thread` directly (the `sync-shim` rule of `tools/analyze` enforces it). A normal
//! build re-exports the std types unchanged, so the shim costs nothing;
//! a `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_tests`
//! build swaps in `loom`'s instrumented types and the loom models in
//! each shimmed module explore every interleaving/reordering the memory
//! model allows (see DESIGN.md §Correctness tooling).
//!
//! ## The loom-mode mpsc
//!
//! `loom` ships `Mutex`/`Condvar`/atomics/threads but no `mpsc`, and the
//! serving layer leans on channel semantics that matter: the session
//! queue is a **rendezvous** `sync_channel(0)` (a send completes only
//! when a worker takes the session — that is the backpressure contract),
//! and the LUT worker offers snapshots with `try_send` on a depth-1
//! channel (busy worker ⇒ offer dropped, never blocked). Under
//! `cfg(loom)` this module therefore provides its own [`mpsc`] built on
//! the loom `Mutex` + `Condvar`, implementing the exact std surface the
//! shimmed modules use (`channel`, `sync_channel` incl. depth 0,
//! `send`/`try_send`/`recv`/`try_recv`, disconnect errors). The loom
//! models thus check the channel implementation *and* its callers as one
//! lock-level protocol — which is the scary part (a worker blocks in
//! `recv` while holding the queue's outer `Mutex`, relying on the inner
//! `Condvar` wait to release only the inner lock).
//!
//! One documented divergence: with *multiple* threads blocked in a
//! rendezvous `send` at once, a sender may stay blocked until items
//! pushed after its own are also consumed (std unblocks each sender as
//! its own message is taken). The loom models only ever send from one
//! thread per channel, so no explored schedule hits the divergence.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex};

/// Atomic types routed through the shim (`std::sync::atomic` or
/// `loom::sync::atomic`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
}

/// Thread spawn/join routed through the shim (`std::thread` or
/// `loom::thread`).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
}

/// Run `f`, isolating panics in production builds
/// (`std::panic::catch_unwind`) but letting them propagate under loom:
/// loom uses panics for its own bookkeeping (deadlock detection,
/// illegal-access reports), and swallowing one inside a model would turn
/// a found bug into a bogus "session failed" outcome.
///
/// Loom models therefore do not exercise the serve layer's
/// panic-isolation path; that path is covered by
/// `failed_session_is_counted_and_isolated` under the real scheduler.
pub fn run_isolated<T>(f: impl FnOnce() -> T) -> std::thread::Result<T> {
    #[cfg(not(loom))]
    {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
    }
    #[cfg(loom)]
    {
        Ok(f())
    }
}

#[cfg(not(loom))]
pub use std::sync::mpsc;

/// Loom-mode mpsc: the std channel surface the shimmed modules use,
/// built on the loom `Mutex` + `Condvar` so every blocking edge is
/// visible to the model checker. See the module docs for why this exists
/// and the one rendezvous divergence.
#[cfg(loom)]
pub mod mpsc {
    use std::collections::VecDeque;
    use std::fmt;

    use super::{Arc, Condvar, Mutex};

    /// `send` on a channel whose receiver is gone (mirrors
    /// `std::sync::mpsc::SendError`).
    pub struct SendError<T>(pub T);

    /// `recv` on a channel whose senders are all gone (mirrors
    /// `std::sync::mpsc::RecvError`).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct RecvError;

    impl fmt::Display for RecvError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("receiving on an empty and disconnected channel")
        }
    }

    impl std::error::Error for RecvError {}

    /// `try_send` outcome (mirrors `std::sync::mpsc::TrySendError`).
    pub enum TrySendError<T> {
        /// The channel is at capacity; the value is handed back.
        Full(T),
        /// The receiver is gone; the value is handed back.
        Disconnected(T),
    }

    /// `try_recv` outcome (mirrors `std::sync::mpsc::TryRecvError`).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum TryRecvError {
        /// Nothing queued right now.
        Empty,
        /// Nothing queued and every sender is gone.
        Disconnected,
    }

    struct State<T> {
        queue: VecDeque<T>,
        /// `None` = unbounded, `Some(0)` = rendezvous, `Some(k)` = bounded.
        cap: Option<usize>,
        senders: usize,
        rx_alive: bool,
        /// Receivers currently blocked in `recv` (0 or 1 — one Receiver).
        rx_waiting: usize,
    }

    struct Chan<T> {
        state: Mutex<State<T>>,
        cv: Condvar,
    }

    impl<T> Chan<T> {
        fn new(cap: Option<usize>) -> Arc<Self> {
            Arc::new(Chan {
                state: Mutex::new(State {
                    queue: VecDeque::new(),
                    cap,
                    senders: 1,
                    rx_alive: true,
                    rx_waiting: 0,
                }),
                cv: Condvar::new(),
            })
        }
    }

    /// Asynchronous (unbounded) sender half.
    pub struct Sender<T>(Arc<Chan<T>>);

    /// Synchronous (bounded / rendezvous) sender half.
    pub struct SyncSender<T>(Arc<Chan<T>>);

    /// Receiver half (single consumer; share via an outer `Mutex` as the
    /// serve worker pool does).
    pub struct Receiver<T>(Arc<Chan<T>>);

    impl<T> fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("Receiver { .. }")
        }
    }

    /// Unbounded channel (mirrors `std::sync::mpsc::channel`).
    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let chan = Chan::new(None);
        (Sender(Arc::clone(&chan)), Receiver(chan))
    }

    /// Bounded channel; `bound == 0` is a rendezvous channel (mirrors
    /// `std::sync::mpsc::sync_channel`).
    pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
        let chan = Chan::new(Some(bound));
        (SyncSender(Arc::clone(&chan)), Receiver(chan))
    }

    fn clone_sender<T>(chan: &Arc<Chan<T>>) -> Arc<Chan<T>> {
        chan.state.lock().unwrap().senders += 1;
        Arc::clone(chan)
    }

    fn drop_sender<T>(chan: &Chan<T>) {
        let mut st = chan.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            chan.cv.notify_all();
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            Sender(clone_sender(&self.0))
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> Self {
            SyncSender(clone_sender(&self.0))
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            drop_sender(&self.0);
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            drop_sender(&self.0);
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            let mut st = self.0.state.lock().unwrap();
            st.rx_alive = false;
            self.0.cv.notify_all();
        }
    }

    impl<T> Sender<T> {
        /// Queue a value; fails only if the receiver is gone.
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let mut st = self.0.state.lock().unwrap();
            if !st.rx_alive {
                return Err(SendError(value));
            }
            st.queue.push_back(value);
            self.0.cv.notify_all();
            Ok(())
        }
    }

    impl<T> SyncSender<T> {
        /// Blocking send: waits for queue space (capacity ≥ 1) or, on a
        /// rendezvous channel, until a receiver has taken the value.
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let cap = {
                let st = self.0.state.lock().unwrap();
                st.cap.expect("SyncSender on an unbounded channel")
            };
            if cap == 0 {
                return self.send_rendezvous(value);
            }
            let mut st = self.0.state.lock().unwrap();
            loop {
                if !st.rx_alive {
                    return Err(SendError(value));
                }
                if st.queue.len() < cap {
                    st.queue.push_back(value);
                    self.0.cv.notify_all();
                    return Ok(());
                }
                st = self.0.cv.wait(st).unwrap();
            }
        }

        fn send_rendezvous(&self, value: T) -> Result<(), SendError<T>> {
            let mut st = self.0.state.lock().unwrap();
            // wait for the single handoff slot
            while st.rx_alive && !st.queue.is_empty() {
                st = self.0.cv.wait(st).unwrap();
            }
            if !st.rx_alive {
                return Err(SendError(value));
            }
            st.queue.push_back(value);
            self.0.cv.notify_all();
            // rendezvous: the send completes only once a receiver took it
            while st.rx_alive && !st.queue.is_empty() {
                st = self.0.cv.wait(st).unwrap();
            }
            if !st.queue.is_empty() {
                // receiver died without taking it — hand the value back
                let value = st.queue.pop_front().expect("nonempty");
                return Err(SendError(value));
            }
            Ok(())
        }

        /// Non-blocking send: `Full` when at capacity (for rendezvous,
        /// when no receiver is blocked waiting), `Disconnected` when the
        /// receiver is gone.
        pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
            let mut st = self.0.state.lock().unwrap();
            if !st.rx_alive {
                return Err(TrySendError::Disconnected(value));
            }
            let cap = st.cap.expect("SyncSender on an unbounded channel");
            let room = if cap == 0 {
                st.rx_waiting > 0 && st.queue.is_empty()
            } else {
                st.queue.len() < cap
            };
            if room {
                st.queue.push_back(value);
                self.0.cv.notify_all();
                Ok(())
            } else {
                Err(TrySendError::Full(value))
            }
        }
    }

    impl<T> Receiver<T> {
        /// Blocking receive; errors once the queue is drained and every
        /// sender is gone.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut st = self.0.state.lock().unwrap();
            loop {
                if let Some(value) = st.queue.pop_front() {
                    // wake blocked senders (space freed / rendezvous done)
                    self.0.cv.notify_all();
                    return Ok(value);
                }
                if st.senders == 0 {
                    return Err(RecvError);
                }
                st.rx_waiting += 1;
                st = self.0.cv.wait(st).unwrap();
                st.rx_waiting -= 1;
            }
        }

        /// Non-blocking receive.
        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut st = self.0.state.lock().unwrap();
            if let Some(value) = st.queue.pop_front() {
                self.0.cv.notify_all();
                return Ok(value);
            }
            if st.senders == 0 {
                Err(TryRecvError::Disconnected)
            } else {
                Err(TryRecvError::Empty)
            }
        }
    }
}
