//! # nmc-tos
//!
//! Full-system reproduction of *"Near-Memory Architecture for
//! Threshold-Ordinal Surface-Based Corner Detection of Event Cameras"*
//! (Shang et al., cs.AR 2025) — grown into a servable event-camera
//! corner-detection library.
//!
//! The crate simulates the complete corner-detection system of the paper's
//! Fig. 2 — STCF denoising, the NMC-TOS near-memory macro (phase-level
//! timing + energy + Monte-Carlo bit errors), DVFS, and the frame-by-frame
//! Harris lookup-table detector — together with every baseline the paper
//! compares against (conventional digital TOS, eHarris, eFAST, ARC*).
//!
//! ## Architecture
//!
//! Three traits carry the whole system; everything else plugs into them:
//!
//! * [`tos::TosBackend`] — a TOS implementation (golden software,
//!   conventional digital, NMC macro, row-band sharded parallel). All are
//!   bit-exact against each other; only cost/telemetry differ.
//! * [`detectors::EventScorer`] — a per-event corner scorer (luvHarris
//!   LUT, eHarris, eFAST, ARC*).
//! * [`events::source::EventSource`] — chunked, fallible event ingestion:
//!   in-memory slices, binary/text recordings decoded incrementally,
//!   synthetic scenes stepped on demand, and framed TCP streams.
//!
//! [`coordinator::Pipeline`] is generic over backend x detector and runs
//! any [`EventSource`](events::source::EventSource) with bounded memory
//! ([`run_stream`](coordinator::Pipeline::run_stream)); results are
//! bit-identical at any chunk size. [`serve::StreamServer`] drives many
//! concurrent pipelines over a worker pool and a shared per-resolution
//! engine pool — the multi-stream serving layer behind `nmc-tos serve`.
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — event-by-event coordination, circuit simulation,
//!   datasets, evaluation, serving, CLI.
//! * **L2/L1 (python, build-time only)** — the Harris-score graph + Pallas
//!   stencil kernel, AOT-lowered to `artifacts/*.hlo.txt` and executed
//!   from [`runtime`] through the PJRT CPU client. Python never runs on
//!   the event path.
//!
//! ## Quickstart
//!
//! Engine-less end-to-end run (no artifacts needed — an SAE detector):
//!
//! ```
//! use nmc_tos::prelude::*;
//!
//! // synthetic scene standing in for a DAVIS240 recording
//! let mut scene = SceneConfig::test64().build(42);
//! let events = scene.generate(5_000);
//!
//! let mut cfg = PipelineConfig::test64();
//! cfg.detector = DetectorKind::Fast; // SAE detector: no Harris engine
//! let mut pipe = Pipeline::from_config_without_engine(cfg)?;
//! let report = pipe.run(&events)?;
//! assert_eq!(report.events_in, 5_000);
//! println!("corners: {}", report.corners_total);
//! # anyhow::Ok(())
//! ```
//!
//! The same pipeline consumes unbounded streams chunk by chunk — see
//! [`coordinator::Pipeline::run_stream`] — emits results at event rate
//! to any [`CornerSink`](coordinator::CornerSink) observer
//! ([`coordinator::Pipeline::run_stream_with`], also streamed over the
//! wire by the serving layer's protocol v2), and serves many streams at
//! once through [`serve::StreamServer`]. The paper's default combination
//! (NMC macro + luvHarris LUT) needs the AOT artifacts: `Pipeline::new(
//! PipelineConfig::davis240())` after `make artifacts`.

#![warn(missing_docs)]
// `unsafe` is denied crate-wide; only `tos::kernel` and `stcf` (the two
// explicit-SIMD modules) opt back in with `#![allow(unsafe_code)]`, and
// every block there carries a `// SAFETY:` comment. The nmc-analyze
// gate (`python3 tools/analyze`) enforces the allowlist and the comment
// discipline; `deny` (not `forbid`) is what makes the per-module
// opt-in possible.
#![deny(unsafe_code)]

pub mod conventional;
pub mod util;
pub mod coordinator;
pub mod datasets;
pub mod detectors;
pub mod dvfs;
pub mod eval;
pub mod events;
pub mod nmc;
pub mod power;
pub mod runtime;
pub mod serve;
pub mod stcf;
pub mod tos;
pub mod verify;

/// Convenient glob-import of the most used types.
pub mod prelude {
    pub use crate::conventional::ConventionalTos;
    pub use crate::coordinator::{
        BackendKind, Corner, CornerSink, DetectorKind, DynPipeline, LiveStats, NullSink, Pipeline,
        PipelineConfig, PipelineScratch, RecordingSink, RunReport,
    };
    pub use crate::datasets::{synthetic::SceneConfig, synthetic::SceneSource, DatasetKind};
    pub use crate::detectors::{harris::HarrisDetector, EventScorer};
    pub use crate::dvfs::{DvfsController, DvfsConfig};
    pub use crate::events::source::{EventSource, FramedStreamSource, SliceSource};
    pub use crate::events::{Event, Polarity, Resolution};
    pub use crate::eval::{PrCurve, PrPoint};
    pub use crate::nmc::{calib, NmcMacro, NmcConfig};
    pub use crate::serve::{ServeConfig, ServerStats, SessionHandle, StreamServer};
    pub use crate::stcf::{Stcf, StcfConfig};
    pub use crate::tos::{
        BackendStats, KernelPath, ShardedTos, TosBackend, TosConfig, TosConfigError, TosSurface,
    };
}
