//! # nmc-tos
//!
//! Full-system reproduction of *"Near-Memory Architecture for
//! Threshold-Ordinal Surface-Based Corner Detection of Event Cameras"*
//! (Shang et al., CS.AR 2025).
//!
//! The crate simulates the complete corner-detection system of the paper's
//! Fig. 2 — STCF denoising, the NMC-TOS near-memory macro (phase-level
//! timing + energy + Monte-Carlo bit errors), DVFS, and the frame-by-frame
//! Harris lookup-table detector — together with every baseline the paper
//! compares against (conventional digital TOS, eHarris, FAST, ARC).
//!
//! Every TOS implementation sits behind the [`tos::TosBackend`] trait
//! (golden software, conventional digital, NMC macro, and a row-band
//! sharded parallel software model), and [`coordinator::Pipeline`] is
//! generic over backend x detector, so any combination runs through the
//! same system loop (`Pipeline::from_config`, or `--backend`/`--detector`
//! on the CLI).
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — event-by-event coordination, circuit simulation,
//!   datasets, evaluation, CLI.
//! * **L2/L1 (python, build-time only)** — the Harris-score graph + Pallas
//!   stencil kernel, AOT-lowered to `artifacts/*.hlo.txt` and executed
//!   from [`runtime`] through the PJRT CPU client. Python never runs on
//!   the event path.
//!
//! Quickstart:
//! ```no_run
//! use nmc_tos::prelude::*;
//!
//! let mut scene = nmc_tos::datasets::synthetic::SceneConfig::shapes_dof().build(42);
//! let events = scene.generate(200_000);
//! let mut pipe = nmc_tos::coordinator::Pipeline::new(
//!     nmc_tos::coordinator::PipelineConfig::davis240(),
//! ).unwrap();
//! let report = pipe.run(&events).unwrap();
//! println!("corners: {}", report.corners.len());
//! ```

pub mod conventional;
pub mod util;
pub mod coordinator;
pub mod datasets;
pub mod detectors;
pub mod dvfs;
pub mod eval;
pub mod events;
pub mod nmc;
pub mod power;
pub mod runtime;
pub mod stcf;
pub mod tos;

/// Convenient glob-import of the most used types.
pub mod prelude {
    pub use crate::conventional::ConventionalTos;
    pub use crate::coordinator::{
        BackendKind, DetectorKind, DynPipeline, Pipeline, PipelineConfig, RunReport,
    };
    pub use crate::datasets::{synthetic::SceneConfig, synthetic::SceneSource, DatasetKind};
    pub use crate::detectors::{harris::HarrisDetector, EventScorer};
    pub use crate::dvfs::{DvfsController, DvfsConfig};
    pub use crate::events::source::{EventSource, SliceSource};
    pub use crate::events::{Event, Polarity, Resolution};
    pub use crate::eval::{PrCurve, PrPoint};
    pub use crate::nmc::{calib, NmcMacro, NmcConfig};
    pub use crate::stcf::{Stcf, StcfConfig};
    pub use crate::tos::{
        BackendStats, ShardedTos, TosBackend, TosConfig, TosConfigError, TosSurface,
    };
}
