//! Conventional digital TOS baseline (paper Sec. I & Fig. 9).
//!
//! A synthesized datapath that reads, decrements, thresholds and writes
//! back one pixel per clock: `O(P^2)` cycles per event at 500 MHz / 1.2 V
//! (392 ns per 7x7 patch => 2.6 Meps).  Functionally identical to the
//! golden TOS; only the cost model differs from [`crate::nmc`].

use crate::events::{Event, Resolution};
use crate::nmc::calib;
use crate::nmc::energy::ConventionalEnergy;
use crate::tos::backend::{BackendStats, TosBackend};
use crate::tos::{TosConfig, TosConfigError, TosSurface};

/// Cost/latency model of the conventional implementation at a voltage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConventionalModel {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Clock frequency at this voltage (Hz).
    pub clock_hz: f64,
    /// Energy model.
    pub energy: ConventionalEnergy,
}

impl ConventionalModel {
    /// Model at a voltage; the clock scales with the same alpha-power-law
    /// factor as the NMC macro (same process corner).
    pub fn at(vdd: f64) -> Self {
        Self {
            vdd,
            clock_hz: calib::CONV_CLOCK_NOM_HZ / calib::delay_factor(vdd),
            energy: ConventionalEnergy::at(vdd),
        }
    }

    /// Latency of an event whose clipped patch covers `pixels` pixels (ns).
    ///
    /// 4 cycles of address setup + `pixels` read-modify-write cycles —
    /// 4 + 4*49 = 200... the paper's 392 ns at 500 MHz corresponds to
    /// `CONV_CYCLES_PER_PATCH` = 196 cycles for the full 49-pixel patch:
    /// 4 cycles/pixel (RD, DEC+CMP, WR, ptr) at 1 px/cycle *per phase*.
    #[inline]
    pub fn event_latency_ns(&self, pixels: usize) -> f64 {
        let cycles = calib::CONV_CYCLES_PER_PATCH * pixels as f64
            / (calib::PATCH * calib::PATCH) as f64;
        cycles / self.clock_hz * 1e9
    }

    /// Max sustainable event rate with full patches (events/s).
    pub fn max_event_rate(&self) -> f64 {
        1e9 / self.event_latency_ns(calib::PATCH * calib::PATCH)
    }
}

/// The conventional baseline engine: golden TOS + digital cost model.
///
/// Event/pixel counters live in the inner surface (one source of truth);
/// this struct only accumulates what the cost model adds on top.
#[derive(Debug)]
pub struct ConventionalTos {
    surface: TosSurface,
    model: ConventionalModel,
    busy_ns: f64,
    energy_pj: f64,
}

impl ConventionalTos {
    /// Build at a resolution / TOS config / voltage.
    pub fn new(res: Resolution, tos: TosConfig, vdd: f64) -> Result<Self, TosConfigError> {
        Ok(Self {
            surface: TosSurface::new(res, tos)?,
            model: ConventionalModel::at(vdd),
            busy_ns: 0.0,
            energy_pj: 0.0,
        })
    }

    /// Process one event, returning its latency in ns.
    pub fn process(&mut self, ev: &Event) -> f64 {
        let cfg = self.surface.config();
        let pixels = self.surface.update(ev);
        let lat = self.model.event_latency_ns(pixels);
        let full = (cfg.patch as usize).pow(2);
        self.busy_ns += lat;
        self.energy_pj += self.model.energy.patch_pj * pixels as f64 / full as f64;
        lat
    }

    /// Retarget the supply voltage (DVFS transition): clock and energy
    /// scale together, exactly as for the NMC macro.
    pub fn set_vdd(&mut self, vdd: f64) {
        self.model = ConventionalModel::at(vdd);
    }

    /// Underlying surface (identical semantics to the golden model).
    pub fn surface(&self) -> &TosSurface {
        &self.surface
    }

    /// Cost model.
    pub fn model(&self) -> ConventionalModel {
        self.model
    }

    /// Telemetry: unified [`BackendStats`] — event/pixel counters come
    /// from the inner surface, cost totals from the model.
    pub fn stats(&self) -> BackendStats {
        BackendStats {
            busy_ns: self.busy_ns,
            energy_pj: self.energy_pj,
            ..TosBackend::stats(&self.surface)
        }
    }
}

impl TosBackend for ConventionalTos {
    fn name(&self) -> &'static str {
        "conventional-tos"
    }

    fn resolution(&self) -> Resolution {
        self.surface.resolution()
    }

    fn process(&mut self, ev: &Event) {
        ConventionalTos::process(self, ev);
    }

    fn tos_view(&self) -> &[u8] {
        self.surface.data()
    }

    fn set_vdd(&mut self, vdd: f64) {
        ConventionalTos::set_vdd(self, vdd);
    }

    fn stats(&self) -> BackendStats {
        ConventionalTos::stats(self)
    }

    fn reset(&mut self) {
        self.surface.clear();
        self.busy_ns = 0.0;
        self.energy_pj = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_392ns_2p6meps() {
        let m = ConventionalModel::at(1.2);
        let lat = m.event_latency_ns(49);
        assert!((lat - 392.0).abs() < 1e-9, "latency {lat}");
        let rate = m.max_event_rate() / 1e6;
        assert!((rate - 2.55).abs() < 0.1, "rate {rate}");
    }

    #[test]
    fn scales_with_voltage_like_nmc() {
        let hi = ConventionalModel::at(1.2);
        let lo = ConventionalModel::at(0.6);
        let ratio = lo.event_latency_ns(49) / hi.event_latency_ns(49);
        assert!((ratio - calib::delay_factor(0.6)).abs() < 1e-9);
    }

    #[test]
    fn functional_equivalence_with_golden() {
        let res = Resolution::TEST64;
        let mut conv = ConventionalTos::new(res, TosConfig::default(), 1.2).unwrap();
        let mut golden = TosSurface::new(res, TosConfig::default()).unwrap();
        for i in 0..1000u64 {
            let e = Event::on((i * 23 % 64) as u16, (i * 41 % 64) as u16, i);
            conv.process(&e);
            golden.update(&e);
        }
        assert_eq!(conv.surface().data(), golden.data());
    }

    #[test]
    fn clipped_patches_cost_less() {
        let mut conv =
            ConventionalTos::new(Resolution::TEST64, TosConfig::default(), 1.2).unwrap();
        let full = conv.process(&Event::on(32, 32, 0));
        let corner = conv.process(&Event::on(0, 0, 1));
        assert!(corner < full);
        assert_eq!(conv.stats().pixels, 49 + 16);
    }

    #[test]
    fn dvfs_retarget_scales_latency() {
        let mut conv =
            ConventionalTos::new(Resolution::TEST64, TosConfig::default(), 1.2).unwrap();
        let hi = conv.process(&Event::on(30, 30, 0));
        conv.set_vdd(0.6);
        let lo = conv.process(&Event::on(30, 30, 1));
        assert!((lo / hi - calib::delay_factor(0.6)).abs() < 1e-9);
    }

    #[test]
    fn nmc_speedup_vs_conventional_is_24_7x() {
        let conv = ConventionalModel::at(1.2).event_latency_ns(49);
        let nmc = crate::nmc::timing::TimingModel::at(1.2).patch_latency_pipelined_ns(7);
        let speedup = conv / nmc;
        assert!((speedup - 24.7).abs() < 0.2, "speedup {speedup}");
    }
}
