//! Kani bounded proof harnesses for the bit-level kernels.
//!
//! Everything here is `#[cfg(kani)]`: under a plain `cargo check`/`cargo
//! build` this module compiles to its documentation and nothing else, so
//! it can live in-tree without a dependency. Under `cargo kani` (the CI
//! `kani` job, best-effort) each `#[kani::proof]` function is a bounded
//! model check: every value produced by `kani::any()` is symbolic, so a
//! passing harness is a proof over *all* inputs within the stated bounds
//! — not a sampled property test.
//!
//! What is proven, and how it complements the runtime suites:
//!
//! - **`swar_decrement_clamp_equals_scalar`** — the SWAR kernel equals
//!   the scalar oracle for every buffer content, width, rect, base row
//!   and threshold up to the bounds. The proptest sweeps in
//!   `tos::kernel` sample this space; the harness closes it.
//! - **`narrow_window_never_touches_outside_rect`** — the backward-
//!   sliding narrow-row window (widths < 8, the `LANE_MASK` blend) never
//!   *writes* outside the rect, and Kani's built-in checks prove it
//!   never *reads* out of bounds either — the exact hazard the
//!   window-rebase trick courts.
//! - **`stcf_check_matches_scalar_oracle`** — `Stcf::check` (branch-free
//!   counting) and `Stcf::check_scalar` (early-exit loop) agree on the
//!   verdict, the stats, and — via a second probe event — the timestamp
//!   map, for symbolic event histories on a small sensor.
//! - **`fault_sets_nest_monotonically_in_p`** — for any two fault
//!   probabilities `p1 <= p2`, a cell's fault mask at `p1` is a subset
//!   of its mask at `p2`, and stuck values agree on the common bits.
//!   Since `calib::bit_error_probability` is monotone decreasing in Vdd
//!   (pinned by the runtime test `ber_monotone_in_vdd` — the curve
//!   itself is transcendental, outside Kani's reach), this is exactly
//!   "lowering Vdd only ever adds faults, never moves or removes one".
//! - **`floor_clamp_is_exact_zero`** — the Monte-Carlo floor maps every
//!   probability below `calib::BER_MC_FLOOR` to *exactly* `0.0` and is
//!   the identity above it: the nominal-voltage region of a vdd-sweep
//!   report is bit-clean by construction, not by luck.
//!
//! Bounds are deliberately small (buffers ≤ 24 bytes, 3×3 sensors):
//! the kernels branch on alignment and width, not on magnitude, so a
//! proof over every alignment/width class at small size is the claim
//! that matters. Widening a bound only grows solver time.

#[cfg(kani)]
mod harnesses {
    use crate::events::{Event, Polarity, Resolution};
    use crate::nmc::calib;
    use crate::nmc::montecarlo::{cell_faults_at, clamp_p_to_floor};
    use crate::stcf::{Stcf, StcfConfig};
    use crate::tos::backend::PatchRect;
    use crate::tos::kernel::{decrement_clamp_with, KernelPath};

    /// A symbolic in-bounds rect over `width` columns and rows
    /// `base_row .. base_row + rows`, matching the `decrement_clamp`
    /// contract (rect pre-clipped, `data` holds `rows` rows from
    /// `base_row`).
    fn any_rect(width: usize, rows: usize, base_row: u16) -> PatchRect {
        let x0: u16 = kani::any();
        let x1: u16 = kani::any();
        let y0: u16 = kani::any();
        let y1: u16 = kani::any();
        kani::assume(x0 <= x1 && (x1 as usize) < width);
        kani::assume(y0 >= base_row && y0 <= y1);
        kani::assume(((y1 - base_row) as usize) < rows);
        PatchRect { x0, x1, y0, y1 }
    }

    /// SWAR == scalar for all data, widths 1..=10, 1-2 rows, all rects,
    /// thresholds and base rows. Covers all three SWAR branches: the
    /// wide row path (w >= 8 with the re-based overlap window), the
    /// masked 8-byte window, and the backward-sliding narrow window.
    #[kani::proof]
    #[kani::unwind(24)]
    fn swar_decrement_clamp_equals_scalar() {
        const MAX_W: usize = 10;
        const MAX_ROWS: usize = 2;
        let width: usize = kani::any();
        let rows: usize = kani::any();
        kani::assume(width >= 1 && width <= MAX_W);
        kani::assume(rows >= 1 && rows <= MAX_ROWS);
        let len = width * rows;

        let base_row: u16 = kani::any();
        kani::assume(base_row <= 3);
        let rect = any_rect(width, rows, base_row);
        let th: u8 = kani::any();

        let seed: [u8; MAX_W * MAX_ROWS] = kani::any();
        let mut swar = seed;
        let mut scalar = seed;

        decrement_clamp_with(KernelPath::Swar64, &mut swar[..len], width, base_row, rect, th);
        decrement_clamp_with(KernelPath::Scalar, &mut scalar[..len], width, base_row, rect, th);
        assert_eq!(swar, scalar);
    }

    /// The narrow-row backward-sliding window (widths < 8 over a buffer
    /// long enough to rebase into neighbouring rows) writes only inside
    /// the rect. Out-of-bounds *reads* are caught by Kani's intrinsic
    /// memory checks on the same run.
    #[kani::proof]
    #[kani::unwind(24)]
    fn narrow_window_never_touches_outside_rect() {
        const MAX_W: usize = 7;
        const MAX_ROWS: usize = 3;
        let width: usize = kani::any();
        let rows: usize = kani::any();
        kani::assume(width >= 1 && width < 8);
        kani::assume(rows >= 2 && rows <= MAX_ROWS);
        let len = width * rows;
        kani::assume(len >= 8); // forces the backward-sliding branch

        let rect = any_rect(width, rows, 0);
        let th: u8 = kani::any();

        let seed: [u8; MAX_W * MAX_ROWS] = kani::any();
        let mut data = seed;
        decrement_clamp_with(KernelPath::Swar64, &mut data[..len], width, 0, rect, th);

        let mut i = 0;
        while i < len {
            let (x, y) = (i % width, i / width);
            let inside = x >= rect.x0 as usize
                && x <= rect.x1 as usize
                && y >= rect.y0 as usize
                && y <= rect.y1 as usize;
            if !inside {
                assert_eq!(data[i], seed[i], "narrow window leaked outside the rect");
            }
            i += 1;
        }
    }

    /// An event on a small sensor with a representable `t + 1` (both
    /// classifiers store `t + 1` in the timestamp map; `u64::MAX` would
    /// overflow in either, so it is outside the filter's domain).
    fn any_event(res: Resolution) -> Event {
        let x: u16 = kani::any();
        let y: u16 = kani::any();
        let t: u64 = kani::any();
        kani::assume(x < res.width && y < res.height);
        kani::assume(t < u64::MAX);
        let p = if kani::any() { Polarity::On } else { Polarity::Off };
        Event::new(x, y, t, p)
    }

    /// Vectorized STCF == scalar oracle: same verdicts, same stats, and
    /// (observed through a second probe) the same timestamp map, for a
    /// symbolic seeded history on a 3x3 sensor.
    #[kani::proof]
    #[kani::unwind(16)]
    fn stcf_check_matches_scalar_oracle() {
        let res = Resolution::new(3, 3);
        let tw_us: u64 = kani::any();
        let support: u32 = kani::any();
        kani::assume(support >= 1 && support <= 3);
        let cfg = StcfConfig { tw_us, radius: 1, support, any_polarity: true };

        // symbolic prior history, applied once and cloned so both
        // classifiers start from the identical state
        let mut seeded = Stcf::new(res, cfg);
        seeded.check(&any_event(res));
        let mut vectorized = seeded.clone();
        let mut oracle = seeded;

        let probe = any_event(res);
        assert_eq!(vectorized.check(&probe), oracle.check_scalar(&probe));
        assert_eq!(vectorized.stats(), oracle.stats());

        // a second probe observes any timestamp-map divergence the first
        // comparison could have missed
        let probe2 = any_event(res);
        assert_eq!(vectorized.check(&probe2), oracle.check_scalar(&probe2));
        assert_eq!(vectorized.stats(), oracle.stats());
    }

    /// Fault-set nesting: for `p1 <= p2` the mask at `p1` is a subset of
    /// the mask at `p2`, stuck bits only appear under the mask, and the
    /// stuck values agree wherever both masks fault. With the BER curve
    /// monotone decreasing in Vdd, this is voltage-nesting of fault maps.
    #[kani::proof]
    #[kani::unwind(8)]
    fn fault_sets_nest_monotonically_in_p() {
        let p1: f64 = kani::any();
        let p2: f64 = kani::any();
        kani::assume(p1 >= 0.0 && p2 >= 0.0); // excludes NaN too
        kani::assume(p1 <= p2 && p2 <= 1.0);
        let seed: u64 = kani::any();
        let cell: usize = kani::any();
        kani::assume(cell <= u32::MAX as usize);

        let (m1, s1) = cell_faults_at(seed, cell, p1);
        let (m2, s2) = cell_faults_at(seed, cell, p2);

        assert_eq!(m1 & !m2, 0, "raising p removed a fault");
        assert_eq!(s1 & !m1, 0, "stuck bit outside the p1 mask");
        assert_eq!(s2 & !m2, 0, "stuck bit outside the p2 mask");
        assert_eq!(s1 & m1, s2 & m1, "a shared fault changed its stuck value");
    }

    /// The Monte-Carlo floor is exact: below `BER_MC_FLOOR` the injected
    /// probability is literally `0.0`; at or above it, untouched.
    #[kani::proof]
    fn floor_clamp_is_exact_zero() {
        let p: f64 = kani::any();
        kani::assume(p >= 0.0); // excludes NaN
        let c = clamp_p_to_floor(p);
        if p < calib::BER_MC_FLOOR {
            assert!(c == 0.0);
        } else {
            assert!(c == p);
        }
    }
}
