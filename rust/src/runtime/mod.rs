//! PJRT runtime: load the AOT-compiled Harris graph (`artifacts/*.hlo.txt`)
//! and execute it from the frame-by-frame path.
//!
//! This is the only place the crate touches XLA.  The artifact was lowered
//! by `python/compile/aot.py` (jax -> StableHLO -> HLO *text*; text is the
//! interchange format because xla_extension 0.5.1 rejects jax >= 0.5's
//! 64-bit-id protos).  Compilation happens once at load; execution is a
//! buffer-in/buffer-out call with no Python anywhere near it.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub mod xla_stub;
// The offline build has no `xla` crate (it needs the XLA C++ library at
// build time). The stub mirrors the exact API surface used below and
// fails fast at client creation; drop this alias and add the `xla`
// dependency to restore the real PJRT path.
use self::xla_stub as xla;

/// Description of one artifact from `artifacts/meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Logical name (e.g. `davis240`).
    pub name: String,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Input/output frame height.
    pub height: usize,
    /// Input/output frame width.
    pub width: usize,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// All artifacts by name.
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `meta.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        if j.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("unsupported artifact format");
        }
        let arts = j.get("artifacts").context("meta.json missing `artifacts`")?;
        let mut artifacts = Vec::new();
        for name in arts.keys().context("`artifacts` not an object")? {
            let a = arts.get(name).unwrap();
            artifacts.push(ArtifactInfo {
                name: name.to_string(),
                file: a
                    .get("file")
                    .and_then(|f| f.as_str())
                    .context("artifact missing file")?
                    .to_string(),
                height: a.get("height").and_then(|v| v.as_f64()).context("missing height")? as usize,
                width: a.get("width").and_then(|v| v.as_f64()).context("missing width")? as usize,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }
}

/// A compiled Harris engine: one PJRT executable per model variant.
pub struct HarrisEngine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Executions performed (telemetry).
    pub executions: u64,
    /// Reusable u8 -> f32 conversion scratch for [`HarrisEngine::compute_u8`]
    /// (the async LUT worker calls it once per snapshot; without this it
    /// allocated a full f32 frame per refresh).
    frame_scratch: Vec<f32>,
}

impl std::fmt::Debug for HarrisEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HarrisEngine")
            .field("height", &self.height)
            .field("width", &self.width)
            .field("executions", &self.executions)
            .finish()
    }
}

impl HarrisEngine {
    /// Load + compile an artifact by name from a manifest.
    pub fn load(manifest: &Manifest, name: &str) -> Result<HarrisEngine> {
        let info = manifest.find(name)?;
        let path = manifest.dir.join(&info.file);
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO")?;
        Ok(HarrisEngine {
            client,
            exe,
            height: info.height,
            width: info.width,
            executions: 0,
            frame_scratch: Vec::new(),
        })
    }

    /// Compute the Harris LUT of one TOS frame.
    ///
    /// `frame` is row-major `height*width` f32 in `[0, 255]`; returns the
    /// normalized response map in `[0, 1]`. Allocating convenience over
    /// [`HarrisEngine::compute_into`].
    pub fn compute(&mut self, frame: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.compute_into(frame, &mut out)?;
        Ok(out)
    }

    /// Compute the Harris LUT of one TOS frame into a caller-owned buffer
    /// (resized to `height*width`). Steady-state this allocates nothing:
    /// the refresh paths hand the same buffer back each time, so the
    /// response map is read straight out of the PJRT literal into it
    /// (`Literal::copy_raw_to` — the same primitive `to_vec` wraps, minus
    /// the fresh allocation).
    pub fn compute_into(&mut self, frame: &[f32], out: &mut Vec<f32>) -> Result<()> {
        if frame.len() != self.height * self.width {
            bail!("frame size {} != {}x{}", frame.len(), self.height, self.width);
        }
        let input = xla::Literal::vec1(frame)
            .reshape(&[self.height as i64, self.width as i64])
            .context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[input]).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple
        let lit = result.to_tuple1().context("unwrapping result tuple")?;
        out.resize(self.height * self.width, 0.0);
        lit.copy_raw_to::<f32>(out).context("reading result values")?;
        self.executions += 1;
        Ok(())
    }

    /// Compute from a u8 TOS snapshot. The u8 -> f32 conversion goes
    /// through a reusable scratch buffer (no per-call frame allocation).
    pub fn compute_u8(&mut self, tos: &[u8]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.compute_u8_into(tos, &mut out)?;
        Ok(out)
    }

    /// Compute from a u8 TOS snapshot into a caller-owned LUT buffer: the
    /// fully recycled refresh path (the async LUT worker sends consumed
    /// LUT buffers back over a recycle channel and computes the next map
    /// into them — zero per-refresh f32 allocation on either side).
    pub fn compute_u8_into(&mut self, tos: &[u8], out: &mut Vec<f32>) -> Result<()> {
        let mut frame = std::mem::take(&mut self.frame_scratch);
        frame.clear();
        frame.extend(tos.iter().map(|&v| v as f32));
        let result = self.compute_into(&frame, out);
        self.frame_scratch = frame;
        result
    }

    /// PJRT platform string (telemetry / sanity).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Locate the artifact directory: `$NMC_TOS_ARTIFACTS` or `./artifacts`
/// relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("NMC_TOS_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // try cwd and its parents (tests run from target subdirs)
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("meta.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: engine-level integration tests (load + execute + numerics
    // against the golden CPU implementation) live in rust/tests/ because
    // they need the artifacts built; these unit tests cover the manifest
    // parser and dir discovery logic.

    #[test]
    fn manifest_parses_generated_meta() {
        let dir = default_artifact_dir();
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let d = m.find("davis240").unwrap();
        assert_eq!((d.height, d.width), (180, 240));
        let t = m.find("test64").unwrap();
        assert_eq!((t.height, t.width), (64, 64));
        assert!(m.find("nonexistent").is_err());
    }

    #[test]
    fn manifest_rejects_bad_format() {
        let tmp = std::env::temp_dir().join(format!("nmc_tos_meta_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("meta.json"), r#"{"format":"protobuf","artifacts":{}}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_meta_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
