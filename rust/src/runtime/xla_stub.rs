//! Offline stand-in for the `xla` crate (xla-rs bindings to
//! xla_extension), which needs the XLA C++ library at build time and is
//! not available in this offline build.
//!
//! The API surface mirrors exactly what [`super::HarrisEngine`] uses, so
//! swapping the real crate back in is a one-line change in `runtime/mod.rs`
//! (drop the `use xla_stub as xla;` alias and add the `xla` dependency).
//! Every entry point fails fast at `PjRtClient::cpu()` with a clear
//! message; nothing downstream is reachable. Engine-less pipelines, all
//! simulators, and every SAE detector are unaffected — the artifact-gated
//! integration tests and benches skip themselves when no engine can load.

/// Error returned by every stubbed PJRT entry point.
#[derive(Debug, Clone, Copy)]
pub struct XlaUnavailable;

impl std::fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT/XLA runtime not built into this binary (offline build without the `xla` \
             crate); the FBF Harris engine is unavailable — use an engine-less pipeline or an \
             SAE detector (--detector eharris|fast|arc)"
        )
    }
}

impl std::error::Error for XlaUnavailable {}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the offline build.
    pub fn cpu() -> Result<PjRtClient, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable (no client can be constructed).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable (no client can be constructed).
    pub fn platform_name(&self) -> String {
        "unavailable".into()
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Unreachable (no executable can be compiled).
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaUnavailable> {
        Err(XlaUnavailable)
    }
}

/// Stub of the PJRT device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Unreachable.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaUnavailable> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails in the offline build.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaUnavailable> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Trivially constructible (real work happens at compile()).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Trivially constructible (real work happens at execute()).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Unreachable.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable.
    pub fn to_tuple1(&self) -> Result<Literal, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable. Mirrors `xla::Literal::copy_raw_to` (the zero-extra-
    /// allocation read path `to_vec` is built on): copies the literal's
    /// elements into a caller-owned slice.
    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<(), XlaUnavailable> {
        Err(XlaUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_helpful_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("offline build"), "{msg}");
        assert!(msg.contains("--detector"), "{msg}");
    }
}
