//! Timing model of the NMC macro: per-phase delays vs supply voltage and
//! the pipelined / unpipelined row schedules of paper Fig. 4(b).
//!
//! One patch update touches up to `P` rows; each row passes through four
//! phases — precharge (PCH, t1), minus-one (MO, t2), compare (CMP, t3) and
//! write-back (WR, t4).  With the read/write-decoupled 8T cell the next
//! row's PCH+MO can overlap the previous row's CMP+WR:
//!
//! * unpipelined patch latency: `rows * (t1 + t2 + t3 + t4)`
//! * pipelined   patch latency: `rows * (t1 + t2) + t3 + t4`
//!
//! All absolute numbers derive from [`calib`].



use super::calib;

/// The four phases of one row operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Precharge the read bitlines of the type-A array.
    Pch,
    /// Sense + minus-one logic.
    Mo,
    /// NOR-compare against TH in the type-B rows + custom FA.
    Cmp,
    /// Write-back (TOS-1 / 0 / 255) through the decoupled write port.
    Wr,
}

impl Phase {
    /// All phases in schedule order.
    pub const ALL: [Phase; 4] = [Phase::Pch, Phase::Mo, Phase::Cmp, Phase::Wr];

    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            Phase::Pch => "PCH",
            Phase::Mo => "MO",
            Phase::Cmp => "CMP",
            Phase::Wr => "WR",
        }
    }

    /// Index into [`calib::PHASE_SHARE`].
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Phase::Pch => 0,
            Phase::Mo => 1,
            Phase::Cmp => 2,
            Phase::Wr => 3,
        }
    }
}

/// Timing model at a fixed supply voltage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Full row time t1+t2+t3+t4 (ns).
    pub row_ns: f64,
}

impl TimingModel {
    /// Build the model at a voltage; all delays scale with the
    /// alpha-power-law factor from [`calib`].
    pub fn at(vdd: f64) -> Self {
        // Pipelined patch latency at this voltage for the calibration
        // patch size P: rows*(s1+s2)*T + (s3+s4)*T = anchor * factor.
        let patch_ns = calib::PATCH_LATENCY_NOM_NS * calib::delay_factor(vdd);
        let p = calib::PATCH as f64;
        let s12 = calib::PHASE_SHARE[0] + calib::PHASE_SHARE[1];
        let s34 = calib::PHASE_SHARE[2] + calib::PHASE_SHARE[3];
        let row_ns = patch_ns / (p * s12 + s34);
        Self { vdd, row_ns }
    }

    /// Delay of one phase (ns).
    #[inline]
    pub fn phase_ns(&self, phase: Phase) -> f64 {
        calib::PHASE_SHARE[phase.index()] * self.row_ns
    }

    /// Pipelined latency of a patch touching `rows` SRAM rows (ns).
    #[inline]
    pub fn patch_latency_pipelined_ns(&self, rows: usize) -> f64 {
        let s12 = calib::PHASE_SHARE[0] + calib::PHASE_SHARE[1];
        let s34 = calib::PHASE_SHARE[2] + calib::PHASE_SHARE[3];
        (rows as f64 * s12 + s34) * self.row_ns
    }

    /// Unpipelined latency of a patch touching `rows` rows (ns).
    #[inline]
    pub fn patch_latency_unpipelined_ns(&self, rows: usize) -> f64 {
        rows as f64 * self.row_ns
    }

    /// Maximum sustainable event rate with pipelining, full `P`-row
    /// patches (events/s).
    pub fn max_event_rate(&self) -> f64 {
        1e9 / self.patch_latency_pipelined_ns(calib::PATCH)
    }

    /// NMC clock frequency: the clock period is set by the slowest phase
    /// (MO), which is one cycle (Hz).
    pub fn clock_hz(&self) -> f64 {
        let t_cyc_ns = self.phase_ns(Phase::Mo);
        1e9 / t_cyc_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_latencies_reproduced() {
        let t = TimingModel::at(calib::VDD_NOM);
        let l = t.patch_latency_pipelined_ns(calib::PATCH);
        assert!((l - calib::PATCH_LATENCY_NOM_NS).abs() < 1e-9, "{l}");
        let t = TimingModel::at(calib::VDD_MIN);
        let l = t.patch_latency_pipelined_ns(calib::PATCH);
        assert!((l - calib::PATCH_LATENCY_MIN_NS).abs() < 1e-6, "{l}");
    }

    #[test]
    fn pipeline_beats_unpipelined_by_about_2x() {
        let t = TimingModel::at(1.2);
        let pipe = t.patch_latency_pipelined_ns(7);
        let nopipe = t.patch_latency_unpipelined_ns(7);
        let ratio = nopipe / pipe;
        assert!(ratio > 1.8 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn paper_speedup_ratios() {
        // Fig. 9(b): conventional -> NMC (no pipe) = 13.0x, -> +pipe = 24.7x.
        let conv_ns = calib::CONV_CYCLES_PER_PATCH / calib::CONV_CLOCK_NOM_HZ * 1e9;
        let t = TimingModel::at(1.2);
        let x_nopipe = conv_ns / t.patch_latency_unpipelined_ns(7);
        let x_pipe = conv_ns / t.patch_latency_pipelined_ns(7);
        assert!((x_pipe - 24.7).abs() < 0.2, "pipe {x_pipe}");
        assert!((x_nopipe - 12.9).abs() < 0.5, "nopipe {x_nopipe}");
    }

    #[test]
    fn max_event_rates_match_paper() {
        // 63.1 Meps @1.2 V, 4.9 Meps @0.6 V.
        let hi = TimingModel::at(1.2).max_event_rate() / 1e6;
        let lo = TimingModel::at(0.6).max_event_rate() / 1e6;
        assert!((hi - 63.1).abs() < 0.2, "hi {hi}");
        assert!((lo - 4.93).abs() < 0.1, "lo {lo}");
    }

    #[test]
    fn phase_shares_at_0v6_match_fig10c() {
        let t = TimingModel::at(0.6);
        let total: f64 = Phase::ALL.iter().map(|&p| t.phase_ns(p)).sum();
        let share = |p: Phase| t.phase_ns(p) / total;
        assert!((share(Phase::Mo) - 0.306 / 1.001).abs() < 0.01);
        assert!((share(Phase::Pch) - 0.139 / 1.001).abs() < 0.01);
    }

    #[test]
    fn fewer_rows_is_faster() {
        let t = TimingModel::at(0.8);
        assert!(t.patch_latency_pipelined_ns(4) < t.patch_latency_pipelined_ns(7));
        assert!(t.patch_latency_pipelined_ns(1) > 0.0);
    }

    #[test]
    fn clock_scales_with_voltage() {
        let f_hi = TimingModel::at(1.2).clock_hz();
        let f_lo = TimingModel::at(0.6).clock_hz();
        assert!(f_hi > 5.0 * f_lo);
        assert!(f_hi > 100e6 && f_hi < 2e9, "f_hi {f_hi}");
    }
}
