//! Per-event patch schedule: drives one event through the PCH/MO/CMP/WR
//! phases row by row (paper Fig. 4(b) & Fig. 7) against the type-A array,
//! with optional pipelining and read-error injection.
//!
//! The functional outcome is bit-exact Algorithm 1 (verified against
//! [`crate::tos::TosSurface`] by property tests); the *timing* and
//! *energy* of the traversal come from [`super::timing`] / [`super::energy`].

use crate::events::Event;
use crate::tos::backend::{clip_patch, decrement_clamp, PatchRect};
use crate::tos::encoding;

use super::cmp::compare_geq;
use super::energy::EnergyModel;
use super::mol::minus_one_gate;
use super::montecarlo::ErrorInjector;
use super::sram::TypeAArray;
use super::timing::TimingModel;
use super::wr::{write_back, WriteBack};

/// Memoized write-back datapath: for a fixed threshold, the outcome of
/// MOL -> CMP -> WR for a non-centre pixel is a pure function of the 5-bit
/// stored word.  The table is built by evaluating the *gate-level* models
/// once per word (so it is the same datapath, not a reimplementation) and
/// turns three bit-ripple loops per pixel into one load on the hot path
/// (EXPERIMENTS.md §Perf iteration 6).
#[derive(Debug, Clone, Copy)]
pub struct WbTable {
    /// `entry[stored] = Some(bits_to_write)` or `None` for write-disabled.
    entry: [Option<u8>; 32],
}

impl WbTable {
    /// Build from the gate-level MOL/CMP/WR models for a threshold.
    pub fn build(threshold: u8) -> Self {
        debug_assert!(threshold >= 225);
        let th5 = threshold & 0x1F;
        let mut entry = [None; 32];
        for stored in 0u8..32 {
            let mol = minus_one_gate(stored);
            let cmp = compare_geq(mol.sum, th5);
            entry[stored as usize] = match write_back(stored, mol, cmp, false) {
                WriteBack::Disabled => None,
                WriteBack::Value(v) => Some(v),
            };
        }
        Self { entry }
    }

    /// Write-back outcome for a non-centre pixel.
    #[inline]
    pub fn lookup(&self, stored: u8) -> Option<u8> {
        self.entry[stored as usize]
    }
}

/// Cost record of one event's patch update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PatchCost {
    /// Latency of the update (ns) at the voltage it ran at.
    pub latency_ns: f64,
    /// Dynamic energy spent (pJ).
    pub energy_pj: f64,
    /// SRAM rows touched (after border clipping).
    pub rows: usize,
    /// Pixels touched (after border clipping).
    pub pixels: usize,
}

/// Run one event through the macro datapath.
///
/// `patch`/`threshold` are the Algorithm-1 parameters (threshold in the
/// 8-bit domain, `>= 225`); `pipelined` selects the Fig. 4(b) schedule;
/// `injector` (if any) corrupts every word read per the BER model.
///
/// The per-pixel gate-level walk is skipped on every path: the functional
/// outcome of an error-free patch update is exactly Algorithm 1 on the
/// decoded 8-bit mirror (the gate-level datapath is bit-exact against the
/// golden model, a property-test invariant), and the [`PatchCost`] depends
/// only on the clipped rect's geometry — so the fast path runs the shared
/// SIMD kernel on the mirror and resyncs the 5-bit words
/// ([`encoding::store`]). With an injector attached, faults are applied
/// *after* the kernel by patching only the cells the static fault map
/// marks faulty (the per-pixel write-back is independent, so correcting
/// the sparse faulty subset reproduces the gate walk bit-exactly —
/// including the `flipped_bits`/`word_reads` telemetry; pinned by
/// `faulty_fast_path_equals_gate_level` below).
/// [`process_event_gate_level`] survives as the reference datapath.
#[allow(clippy::too_many_arguments)]
pub fn process_event(
    array: &mut TypeAArray,
    ev: &Event,
    patch: u16,
    threshold: u8,
    pipelined: bool,
    timing: &TimingModel,
    energy: &EnergyModel,
    injector: Option<&mut ErrorInjector>,
    table: Option<&WbTable>,
) -> PatchCost {
    debug_assert!(threshold >= 225, "5-bit datapath requires TH >= 225");
    let rect = match injector {
        None => fast_update(array, ev, patch, threshold),
        Some(inj) if inj.p_bit() <= 0.0 => {
            // every cell of the patch is read once (MO phase) even when no
            // fault can fire — keep the read telemetry gate-accurate
            let rect = fast_update(array, ev, patch, threshold);
            inj.word_reads += rect.pixels() as u64;
            rect
        }
        Some(inj) => fast_update_faulty(array, ev, patch, threshold, inj, table),
    };
    cost_of(rect.height(), rect.pixels(), pipelined, timing, energy)
}

/// The error-free Algorithm-1 fast-path body: SIMD decrement/clamp over
/// the decoded mirror, centre write, 5-bit word resync. Returns the
/// clipped rect for costing.
#[inline]
fn fast_update(array: &mut TypeAArray, ev: &Event, patch: u16, threshold: u8) -> PatchRect {
    let res = array.grid().res;
    let half = (patch as i32 - 1) / 2;
    let rect = clip_patch(res, ev.x, ev.y, half);
    let (words, decoded, width) = array.split_mut();
    decrement_clamp(decoded, width, 0, rect, threshold);
    decoded[ev.y as usize * width + ev.x as usize] = 255;
    for y in rect.y0..=rect.y1 {
        let row = y as usize * width;
        for i in row + rect.x0 as usize..=row + rect.x1 as usize {
            words[i] = encoding::store(decoded[i]);
        }
    }
    rect
}

/// The fault-aware fast path: run the SIMD kernel on the decoded mirror,
/// then overwrite the (sparse) faulty cells with the gate-level outcome
/// of their corrupted reads.
///
/// Correctness argument: each patch pixel is read once and written at
/// most once per event, so pixels are independent — non-faulty cells get
/// exactly the kernel result (bit-exact vs the gate walk, pinned by
/// `fast_path_equals_gate_level`), and faulty cells get the gate
/// semantics recomputed here from the *pre-update* word, which `words[]`
/// still holds because the kernel only touches the decoded mirror before
/// resync. Telemetry parity: the gate walk calls `corrupt` once per
/// pixel, so `word_reads` advances by the patch size and `flipped_bits`
/// by the number of cells whose corrupted read differs — both reproduced
/// exactly.
fn fast_update_faulty(
    array: &mut TypeAArray,
    ev: &Event,
    patch: u16,
    threshold: u8,
    inj: &mut ErrorInjector,
    table: Option<&WbTable>,
) -> PatchRect {
    let owned_table;
    let table = match table {
        Some(t) => t,
        None => {
            owned_table = WbTable::build(threshold);
            &owned_table
        }
    };
    let res = array.grid().res;
    let half = (patch as i32 - 1) / 2;
    let rect = clip_patch(res, ev.x, ev.y, half);
    let (words, decoded, width) = array.split_mut();
    decrement_clamp(decoded, width, 0, rect, threshold);
    let centre = ev.y as usize * width + ev.x as usize;
    decoded[centre] = 255;
    inj.word_reads += rect.pixels() as u64;
    for y in rect.y0..=rect.y1 {
        let row = y as usize * width;
        for i in row + rect.x0 as usize..=row + rect.x1 as usize {
            let (mask, stuck) = inj.cell_fault(i);
            if mask == 0 {
                continue;
            }
            let raw = words[i];
            let stored = (raw & !mask) | (stuck & mask);
            if stored != raw {
                inj.flipped_bits += 1;
            }
            // the WR phase ignores the corrupted read for the centre
            // (driven to 0x1F) and for an erased cell (write disabled —
            // error containment, paper Sec. V-C)
            if i == centre || raw == 0 {
                continue;
            }
            decoded[i] = if stored == 0 {
                // corrupted to all-zeros: MOL wraps, WR erases (no 255 wrap)
                0
            } else {
                match table.lookup(stored) {
                    Some(bits) => encoding::load(bits),
                    // write-back disabled: the cell keeps its stored word
                    None => encoding::load(raw),
                }
            };
        }
    }
    for y in rect.y0..=rect.y1 {
        let row = y as usize * width;
        for i in row + rect.x0 as usize..=row + rect.x1 as usize {
            words[i] = encoding::store(decoded[i]);
        }
    }
    rect
}

/// The reference per-pixel gate-level walk (MO -> CMP -> WR phase per
/// pixel, paper Fig. 7). No production path routes here anymore — the
/// fast path handles both the error-free and the fault-injected cases —
/// but it remains the oracle: `fast_path_equals_gate_level` and
/// `faulty_fast_path_equals_gate_level` below pin the fast paths
/// bit-exact (surfaces, words, costs, and injector telemetry) against
/// this walk, and the backend property tests pin it against the golden
/// model.
#[allow(clippy::too_many_arguments)]
pub fn process_event_gate_level(
    array: &mut TypeAArray,
    ev: &Event,
    patch: u16,
    threshold: u8,
    pipelined: bool,
    timing: &TimingModel,
    energy: &EnergyModel,
    mut injector: Option<&mut ErrorInjector>,
    table: Option<&WbTable>,
) -> PatchCost {
    debug_assert!(threshold >= 225, "5-bit datapath requires TH >= 225");
    let owned_table;
    let table = match table {
        Some(t) => t,
        None => {
            owned_table = WbTable::build(threshold);
            &owned_table
        }
    };
    let res = array.grid().res;
    let half = (patch as i32 - 1) / 2;
    let ex = ev.x as i32;
    let ey = ev.y as i32;
    let rect = clip_patch(res, ev.x, ev.y, half);

    let width = res.width as usize;
    for y in rect.y0..=rect.y1 {
        for x in rect.x0..=rect.x1 {
            // --- MO phase: read + minus-one -------------------------------
            let raw = array.read(x, y);
            let stored = match injector.as_deref_mut() {
                Some(inj) => inj.corrupt(raw, y as usize * width + x as usize),
                None => raw,
            };
            let is_centre = x as i32 == ex && y as i32 == ey;
            // --- CMP + WR phases via the memoized gate-level datapath ------
            // Error containment (paper Sec. V-C): "when the value stored in
            // the original TOS memory is 0, the write-back is disabled" —
            // the gate looks at the *cell state*, so a stuck-at bit on an
            // erased pixel cannot resurrect it.
            if is_centre {
                array.write(x, y, 0x1F);
            } else if raw == 0 {
                // write port not driven
            } else if stored == 0 {
                // a live cell whose read was corrupted to all-zeros: the
                // MOL wraps (no carry-out), so the WR mux selects the erase
                // value — the pixel dies early, it does not wrap to 255.
                array.write(x, y, 0);
            } else if let Some(bits) = table.lookup(stored) {
                array.write(x, y, bits);
            }
        }
    }

    cost_of(rect.height(), rect.pixels(), pipelined, timing, energy)
}

/// Latency/energy of a patch update — a pure function of the clipped
/// rect's geometry (rows drive the phase schedule, pixels the energy),
/// which is what makes the error-free fast path's cost identical to the
/// gate-level walk's.
#[inline]
fn cost_of(
    rows: usize,
    pixels: usize,
    pipelined: bool,
    timing: &TimingModel,
    energy: &EnergyModel,
) -> PatchCost {
    let latency_ns = if pipelined {
        timing.patch_latency_pipelined_ns(rows)
    } else {
        timing.patch_latency_unpipelined_ns(rows)
    };
    PatchCost { latency_ns, energy_pj: energy.patch_energy_pj(pixels), rows, pixels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wb_table_matches_gate_level_exhaustively() {
        for threshold in [225u8, 230, 240, 250] {
            let table = WbTable::build(threshold);
            let th5 = threshold & 0x1F;
            for stored in 0u8..32 {
                let mol = minus_one_gate(stored);
                let cmp = compare_geq(mol.sum, th5);
                let gate = match write_back(stored, mol, cmp, false) {
                    WriteBack::Disabled => None,
                    WriteBack::Value(v) => Some(v),
                };
                assert_eq!(table.lookup(stored), gate, "TH {threshold} stored {stored}");
            }
        }
    }
    use crate::events::{Event, Resolution};
    use crate::tos::{TosConfig, TosSurface};

    fn run_both(events: &[Event]) -> (Vec<u8>, Vec<u8>) {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut golden = TosSurface::new(res, cfg).unwrap();
        let mut array = TypeAArray::new(res);
        let timing = TimingModel::at(1.2);
        let energy = EnergyModel::at(1.2);
        for e in events {
            golden.update(e);
            process_event(&mut array, e, cfg.patch, cfg.threshold, true, &timing, &energy, None, None);
        }
        (golden.data().to_vec(), array.snapshot_u8())
    }

    #[test]
    fn matches_golden_tos_simple() {
        let evs = vec![Event::on(10, 10, 0), Event::on(12, 10, 1), Event::on(11, 11, 2)];
        let (g, n) = run_both(&evs);
        assert_eq!(g, n);
    }

    #[test]
    fn matches_golden_tos_dense_stream() {
        // shrunk under Miri (~400x slower); 300 events still saturate and
        // re-touch pixels through the full decrement range
        let n = if cfg!(miri) { 300 } else { 2000 };
        let evs: Vec<Event> = (0..n)
            .map(|i| Event::on((i * 17 % 64) as u16, (i * 29 % 64) as u16, i as u64))
            .collect();
        let (g, n) = run_both(&evs);
        assert_eq!(g, n);
    }

    #[test]
    fn matches_golden_at_borders() {
        let evs = vec![
            Event::on(0, 0, 0),
            Event::on(63, 0, 1),
            Event::on(0, 63, 2),
            Event::on(63, 63, 3),
            Event::on(1, 1, 4),
        ];
        let (g, n) = run_both(&evs);
        assert_eq!(g, n);
    }

    #[test]
    fn cost_accounts_for_clipping() {
        let res = Resolution::TEST64;
        let mut array = TypeAArray::new(res);
        let timing = TimingModel::at(1.2);
        let energy = EnergyModel::at(1.2);
        let full = process_event(
            &mut array, &Event::on(30, 30, 0), 7, 225, true, &timing, &energy, None, None,
        );
        assert_eq!((full.rows, full.pixels), (7, 49));
        let corner = process_event(
            &mut array, &Event::on(0, 0, 1), 7, 225, true, &timing, &energy, None, None,
        );
        assert_eq!((corner.rows, corner.pixels), (4, 16));
        assert!(corner.latency_ns < full.latency_ns);
        assert!(corner.energy_pj < full.energy_pj);
    }

    #[test]
    fn pipelined_is_faster() {
        let res = Resolution::TEST64;
        let mut array = TypeAArray::new(res);
        let timing = TimingModel::at(0.8);
        let energy = EnergyModel::at(0.8);
        let a = process_event(&mut array, &Event::on(30, 30, 0), 7, 225, true, &timing, &energy, None, None);
        let b = process_event(&mut array, &Event::on(30, 30, 1), 7, 225, false, &timing, &energy, None, None);
        assert!(a.latency_ns < b.latency_ns);
        let ratio = b.latency_ns / a.latency_ns;
        assert!(ratio > 1.8 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn fast_path_equals_gate_level() {
        // the error-free SIMD fast path and the per-pixel gate-level walk
        // must agree on surface contents, the 5-bit words AND the cost
        // record, event by event
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let timing = TimingModel::at(1.2);
        let energy = EnergyModel::at(1.2);
        let table = WbTable::build(cfg.threshold);
        let mut fast = TypeAArray::new(res);
        let mut gate = TypeAArray::new(res);
        let n = if cfg!(miri) { 250 } else { 2000 };
        for i in 0..n {
            let e = Event::on((i * 17 % 64) as u16, (i * 29 % 64) as u16, i);
            let a = process_event(
                &mut fast, &e, cfg.patch, cfg.threshold, true, &timing, &energy, None,
                Some(&table),
            );
            let b = process_event_gate_level(
                &mut gate, &e, cfg.patch, cfg.threshold, true, &timing, &energy, None,
                Some(&table),
            );
            assert_eq!(a, b, "cost diverged at event {i}");
        }
        assert_eq!(fast.snapshot_u8(), gate.snapshot_u8());
        // the fast path's word resync must leave words/mirror consistent
        let (words, decoded, _) = fast.split_mut();
        for (i, (&w, &d)) in words.iter().zip(decoded.iter()).enumerate() {
            assert_eq!(w, crate::tos::encoding::store(d), "pixel {i}");
        }
    }

    #[test]
    fn faulty_fast_path_equals_gate_level() {
        // with an injector attached the fast path must reproduce the
        // gate-level walk bit-exactly: surface, 5-bit words, cost record,
        // AND the injector telemetry (flipped_bits / word_reads)
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let table = WbTable::build(cfg.threshold);
        for vdd in [0.60, 0.61, 0.62] {
            let timing = TimingModel::at(vdd);
            let energy = EnergyModel::at(vdd);
            let mut fast = TypeAArray::new(res);
            let mut gate = TypeAArray::new(res);
            let mut inj_fast = ErrorInjector::new_sized(vdd, 13, res.pixels());
            let mut inj_gate = ErrorInjector::new_sized(vdd, 13, res.pixels());
            let n: u64 = if cfg!(miri) { 200 } else { 2000 };
            for i in 0..n {
                let e = Event::on((i * 17 % 64) as u16, (i * 29 % 64) as u16, i);
                let a = process_event(
                    &mut fast, &e, cfg.patch, cfg.threshold, true, &timing, &energy,
                    Some(&mut inj_fast), Some(&table),
                );
                let b = process_event_gate_level(
                    &mut gate, &e, cfg.patch, cfg.threshold, true, &timing, &energy,
                    Some(&mut inj_gate), Some(&table),
                );
                assert_eq!(a, b, "vdd {vdd}: cost diverged at event {i}");
            }
            assert_eq!(fast.snapshot_u8(), gate.snapshot_u8(), "vdd {vdd}: surface");
            assert_eq!(inj_fast.flipped_bits, inj_gate.flipped_bits, "vdd {vdd}: flips");
            assert_eq!(inj_fast.word_reads, inj_gate.word_reads, "vdd {vdd}: reads");
            if vdd < 0.615 {
                assert!(inj_fast.flipped_bits > 0, "vdd {vdd}: no faults fired");
            }
            let (fw, fd, _) = fast.split_mut();
            let (gw, gd, _) = gate.split_mut();
            assert_eq!(fw, gw, "vdd {vdd}: words");
            assert_eq!(fd, gd, "vdd {vdd}: mirrors");
        }
    }

    #[test]
    fn injector_at_nominal_is_transparent() {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut golden = TosSurface::new(res, cfg).unwrap();
        let mut array = TypeAArray::new(res);
        let timing = TimingModel::at(1.2);
        let energy = EnergyModel::at(1.2);
        let mut inj = ErrorInjector::new(1.2, 9);
        let n: u64 = if cfg!(miri) { 120 } else { 500 };
        for i in 0..n {
            let e = Event::on((i * 13 % 64) as u16, (i * 7 % 64) as u16, i);
            golden.update(&e);
            process_event(
                &mut array, &e, cfg.patch, cfg.threshold, true, &timing, &energy, Some(&mut inj), None,
            );
        }
        assert_eq!(golden.data().to_vec(), array.snapshot_u8());
        assert_eq!(inj.flipped_bits, 0);
    }

    #[test]
    fn injector_at_low_vdd_corrupts_some_values() {
        let res = Resolution::TEST64;
        let mut array = TypeAArray::new(res);
        let timing = TimingModel::at(0.6);
        let energy = EnergyModel::at(0.6);
        let mut inj = ErrorInjector::new(0.6, 13);
        // enough low-Vdd reads to make flips overwhelmingly likely even at
        // the Miri-shrunk count (BER at 0.6 V is ~1e-2 per bit read)
        let n: u64 = if cfg!(miri) { 400 } else { 2000 };
        for i in 0..n {
            let e = Event::on((i * 13 % 64) as u16, (i * 7 % 64) as u16, i);
            process_event(&mut array, &e, 7, 225, true, &timing, &energy, Some(&mut inj), None);
        }
        assert!(inj.flipped_bits > 0, "expected corrupted reads at 0.6 V");
        // all snapshot values are still in the representable domain
        for &v in &array.snapshot_u8() {
            assert!(crate::tos::encoding::representable(v), "value {v}");
        }
    }
}
