//! The NMC-TOS macro: the paper's core hardware contribution, simulated at
//! phase level.
//!
//! [`NmcMacro`] owns the type-A SRAM blocks covering the sensor, the
//! timing/energy models at the current DVFS voltage, and (optionally) the
//! Monte-Carlo read-error injector.  Feeding it an event stream yields a
//! TOS identical to the golden software model at nominal voltage, plus the
//! latency/energy telemetry every Fig. 9/10 harness consumes.

pub mod calib;
pub mod cmp;
pub mod energy;
pub mod floorplan;
pub mod mol;
pub mod montecarlo;
pub mod pipeline;
pub mod sram;
pub mod timing;
pub mod waveform;
pub mod wr;



use crate::events::{Event, Resolution};
use crate::tos::backend::{BackendStats, TosBackend};
use crate::tos::{TosConfig, TosConfigError};

use energy::EnergyModel;
use montecarlo::ErrorInjector;
use pipeline::{process_event, PatchCost, WbTable};
use sram::TypeAArray;
use timing::TimingModel;

/// Configuration of the macro instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NmcConfig {
    /// Algorithm parameters (patch size, threshold).
    pub tos: TosConfig,
    /// Use the 8T read/write-decoupled pipeline schedule (paper Fig. 4(b)).
    pub pipelined: bool,
    /// Initial supply voltage (V).
    pub vdd: f64,
    /// Inject Monte-Carlo read errors (BER follows the voltage).
    pub inject_errors: bool,
    /// RNG seed for error injection.
    pub seed: u64,
}

impl Default for NmcConfig {
    fn default() -> Self {
        Self {
            tos: TosConfig::default(),
            pipelined: true,
            vdd: calib::VDD_NOM,
            inject_errors: false,
            seed: 0,
        }
    }
}

/// Cumulative telemetry of a macro instance.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NmcStats {
    /// Events processed.
    pub events: u64,
    /// Total busy time (ns).
    pub busy_ns: f64,
    /// Total dynamic energy (pJ).
    pub energy_pj: f64,
    /// Total pixels updated.
    pub pixels: u64,
    /// Bits corrupted by the injector.
    pub flipped_bits: u64,
}

/// Phase-level simulator of the NMC-TOS macro.
#[derive(Debug)]
pub struct NmcMacro {
    cfg: NmcConfig,
    array: TypeAArray,
    timing: TimingModel,
    energy: EnergyModel,
    injector: Option<ErrorInjector>,
    /// Memoized gate-level write-back datapath (fixed per threshold).
    wb_table: WbTable,
    stats: NmcStats,
}

impl NmcMacro {
    /// Build a macro covering `res`. Fails on an invalid [`TosConfig`]
    /// (the 5-bit datapath additionally requires `TH >= 225`) instead of
    /// panicking, so user-supplied configs propagate as errors.
    pub fn new(res: Resolution, cfg: NmcConfig) -> Result<Self, TosConfigError> {
        cfg.tos.validate_nmc()?;
        Ok(Self {
            cfg,
            array: TypeAArray::new(res),
            timing: TimingModel::at(cfg.vdd),
            energy: EnergyModel::at(cfg.vdd),
            injector: cfg
                .inject_errors
                .then(|| ErrorInjector::new_sized(cfg.vdd, cfg.seed, res.pixels())),
            wb_table: WbTable::build(cfg.tos.threshold),
            stats: NmcStats::default(),
        })
    }

    /// Current supply voltage (V).
    #[inline]
    pub fn vdd(&self) -> f64 {
        self.timing.vdd
    }

    /// Retarget the voltage (DVFS transition). Timing, energy and BER all
    /// move together.
    pub fn set_vdd(&mut self, vdd: f64) {
        self.timing = TimingModel::at(vdd);
        self.energy = EnergyModel::at(vdd);
        if let Some(inj) = &mut self.injector {
            inj.set_vdd(vdd);
        }
    }

    /// Max sustainable event rate at the current voltage (events/s).
    #[inline]
    pub fn max_event_rate(&self) -> f64 {
        if self.cfg.pipelined {
            self.timing.max_event_rate()
        } else {
            1e9 / self.timing.patch_latency_unpipelined_ns(calib::PATCH)
        }
    }

    /// Process one event; returns the latency/energy record.
    pub fn process(&mut self, ev: &Event) -> PatchCost {
        let cost = process_event(
            &mut self.array,
            ev,
            self.cfg.tos.patch,
            self.cfg.tos.threshold,
            self.cfg.pipelined,
            &self.timing,
            &self.energy,
            self.injector.as_mut(),
            Some(&self.wb_table),
        );
        self.stats.events += 1;
        self.stats.busy_ns += cost.latency_ns;
        self.stats.energy_pj += cost.energy_pj;
        self.stats.pixels += cost.pixels as u64;
        if let Some(inj) = &self.injector {
            self.stats.flipped_bits = inj.flipped_bits;
        }
        cost
    }

    /// Process a batch of events in order.
    pub fn process_batch(&mut self, events: &[Event]) {
        for e in events {
            self.process(e);
        }
    }

    /// Snapshot the TOS as an 8-bit image (for the FBF Harris stage).
    pub fn snapshot_u8(&self) -> Vec<u8> {
        self.array.snapshot_u8()
    }

    /// Cumulative telemetry.
    #[inline]
    pub fn stats(&self) -> NmcStats {
        self.stats
    }

    /// Sensor geometry.
    #[inline]
    pub fn resolution(&self) -> Resolution {
        self.array.grid().res
    }

    /// Number of SRAM blocks (paper: 2 for DAVIS240).
    #[inline]
    pub fn block_count(&self) -> usize {
        self.array.grid().block_count()
    }

    /// Reset surface and telemetry.
    pub fn reset(&mut self) {
        self.array.clear();
        self.stats = NmcStats::default();
        let vdd = self.vdd();
        let n = self.resolution().pixels();
        if let Some(inj) = &mut self.injector {
            *inj = ErrorInjector::new_sized(vdd, self.cfg.seed, n);
            self.stats.flipped_bits = 0;
        }
    }
}

impl TosBackend for NmcMacro {
    fn name(&self) -> &'static str {
        "nmc-tos"
    }

    fn resolution(&self) -> Resolution {
        NmcMacro::resolution(self)
    }

    fn process(&mut self, ev: &Event) {
        NmcMacro::process(self, ev);
    }

    fn process_batch(&mut self, events: &[Event]) {
        NmcMacro::process_batch(self, events)
    }

    fn tos_view(&self) -> &[u8] {
        self.array.decoded()
    }

    fn set_vdd(&mut self, vdd: f64) {
        NmcMacro::set_vdd(self, vdd)
    }

    fn stats(&self) -> BackendStats {
        let s = NmcMacro::stats(self);
        BackendStats {
            events: s.events,
            pixels: s.pixels,
            busy_ns: s.busy_ns,
            energy_pj: s.energy_pj,
            flipped_bits: s.flipped_bits,
            // the fault-aware fast path rides the same SIMD kernel as the
            // error-free one, so the macro always reports the process-wide
            // selection; the active fault mode is explicit in `faults`
            kernel: crate::tos::kernel::active_path(),
            faults: self.injector.as_ref().map(|inj| crate::tos::FaultInfo {
                vdd: inj.vdd(),
                seed: inj.seed(),
                p_bit: inj.p_bit(),
                faulty_cells: inj.faulty_cells(),
                flipped_bits: inj.flipped_bits,
                word_reads: inj.word_reads,
            }),
        }
    }

    fn reset(&mut self) {
        NmcMacro::reset(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tos::TosSurface;

    #[test]
    fn equals_golden_model_at_nominal() {
        let res = Resolution::TEST64;
        let mut mac = NmcMacro::new(res, NmcConfig::default()).unwrap();
        let mut golden = TosSurface::new(res, TosConfig::default()).unwrap();
        for i in 0..3000u64 {
            let e = Event::on((i * 31 % 64) as u16, (i * 11 % 64) as u16, i);
            mac.process(&e);
            golden.update(&e);
        }
        assert_eq!(mac.snapshot_u8(), golden.data().to_vec());
    }

    #[test]
    fn stats_accumulate() {
        let mut mac = NmcMacro::new(Resolution::TEST64, NmcConfig::default()).unwrap();
        mac.process(&Event::on(30, 30, 0));
        mac.process(&Event::on(0, 0, 1));
        let s = mac.stats();
        assert_eq!(s.events, 2);
        assert_eq!(s.pixels, 49 + 16);
        assert!(s.busy_ns > 0.0 && s.energy_pj > 0.0);
    }

    #[test]
    fn dvfs_retarget_scales_latency() {
        let mut mac = NmcMacro::new(Resolution::TEST64, NmcConfig::default()).unwrap();
        let hi = mac.process(&Event::on(30, 30, 0)).latency_ns;
        mac.set_vdd(0.6);
        let lo = mac.process(&Event::on(30, 30, 1)).latency_ns;
        assert!((lo / hi - calib::delay_factor(0.6)).abs() < 1e-9);
    }

    #[test]
    fn max_rate_matches_paper_endpoints() {
        let mut mac = NmcMacro::new(Resolution::DAVIS240, NmcConfig::default()).unwrap();
        assert!((mac.max_event_rate() / 1e6 - 63.1).abs() < 0.2);
        mac.set_vdd(0.6);
        assert!((mac.max_event_rate() / 1e6 - 4.93).abs() < 0.1);
        assert_eq!(mac.block_count(), 2);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut mac = NmcMacro::new(Resolution::TEST64, NmcConfig::default()).unwrap();
        mac.process(&Event::on(5, 5, 0));
        mac.reset();
        assert_eq!(mac.stats().events, 0);
        assert!(mac.snapshot_u8().iter().all(|&v| v == 0));
    }

    #[test]
    fn dvfs_retarget_switches_fault_map_deterministically() {
        // a mid-run DVFS retarget must swap the fault map to the new
        // voltage deterministically: the post-retarget surface equals the
        // surface of a macro that ran the same tail at that voltage from
        // a matching pre-state, and BackendStats::faults tracks the move
        use crate::tos::TosBackend as _;
        let res = Resolution::TEST64;
        let cfg = NmcConfig { inject_errors: true, seed: 77, ..Default::default() };
        let mk = || NmcMacro::new(res, cfg).unwrap();
        let events: Vec<Event> = (0..600u64)
            .map(|i| Event::on((i * 13 % 64) as u16, (i * 7 % 64) as u16, i))
            .collect();

        let mut a = mk();
        let mut b = mk();
        for e in &events[..300] {
            a.process(e);
            b.process(e);
        }
        // nominal so far: no faults, and the fault mode is reported
        let fa = TosBackend::stats(&a).faults.expect("injection on");
        assert_eq!(fa.seed, 77);
        assert_eq!((fa.p_bit, fa.flipped_bits), (0.0, 0));
        assert!((fa.vdd - 1.2).abs() < 1e-12);
        assert_eq!(TosBackend::stats(&a).kernel, crate::tos::kernel::active_path());

        a.set_vdd(0.6);
        b.set_vdd(0.6);
        for e in &events[300..] {
            a.process(e);
            b.process(e);
        }
        // deterministic: both instances saw the same fault map post-switch
        assert_eq!(a.snapshot_u8(), b.snapshot_u8());
        let fa = TosBackend::stats(&a).faults.unwrap();
        let fb = TosBackend::stats(&b).faults.unwrap();
        assert_eq!(fa, fb);
        assert!((fa.vdd - 0.6).abs() < 1e-12);
        assert!(fa.p_bit > 0.02);
        assert!(fa.faulty_cells > 0);
        assert!(fa.flipped_bits > 0, "expected corrupted reads at 0.6 V");

        // retargeting back up re-derives the nominal (empty) fault map
        a.set_vdd(1.2);
        let fa = TosBackend::stats(&a).faults.unwrap();
        assert_eq!((fa.p_bit, fa.faulty_cells), (0.0, 0));
    }

    #[test]
    fn rejects_low_threshold_as_error() {
        let cfg = NmcConfig { tos: TosConfig { patch: 7, threshold: 200 }, ..Default::default() };
        assert_eq!(
            NmcMacro::new(Resolution::TEST64, cfg).unwrap_err(),
            crate::tos::TosConfigError::ThresholdBelowNmcMin(200)
        );
    }
}
