//! Comparison (CMP) module — paper Fig. 6.
//!
//! Two rows of type-B 8T SRAM hold the MOL result (`SUM = TOS-1`) and the
//! threshold `TH`.  Discharging both rows onto a private read bitline
//! implements a per-bit NOR: `RBL_i` stays high iff `SUM_i = TH_i = 0`.
//! The inverter readout gives `(SUM_i, TH_i, NOR_i)` triples from which a
//! chain of *customized* full adders computes the carry of `SUM + ~TH + 1`,
//! i.e. the predicate `SUM >= TH` that decides clamp-to-zero.
//!
//! The model is bit/gate-accurate so tests can verify the NOR-based
//! comparator against plain integer comparison for every input pair.

use super::calib::BITS_PER_WORD;

/// Per-bit signals the CMP array produces (for waveform-level tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CmpBit {
    /// Stored SUM bit.
    pub sum: bool,
    /// Stored TH bit.
    pub th: bool,
    /// The NOR-computed bitline state: `!(sum | th)`.
    pub nor: bool,
}

/// Output of the CMP stage for one word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CmpOutput {
    /// `SUM >= TH` — carry-out of `SUM + ~TH + 1`.
    pub geq: bool,
    /// Per-bit signals (LSB first).
    pub bits: [CmpBit; BITS_PER_WORD],
}

/// Evaluate the CMP module on a 5-bit `sum` and 5-bit `th`.
///
/// The customized FA exploits that per bit only three input patterns are
/// distinguishable from the NOR readout — `(0,0)`, `(1,0)/(0,1)`, `(1,1)`:
/// carry propagation is `c_{i+1} = sum_i` when bits differ, `c_{i+1} = c_i`
/// when equal (standard borrow-lookahead identity for `sum >= th`).
pub fn compare_geq(sum: u8, th: u8) -> CmpOutput {
    debug_assert!(sum < (1 << BITS_PER_WORD) && th < (1 << BITS_PER_WORD));
    let mut bits = [CmpBit { sum: false, th: false, nor: false }; BITS_PER_WORD];
    let mut carry = true; // +1 of the two's complement
    for i in 0..BITS_PER_WORD {
        let s = (sum >> i) & 1 == 1;
        let t = (th >> i) & 1 == 1;
        bits[i] = CmpBit { sum: s, th: t, nor: !(s | t) };
        // full adder on (s, !t, carry): carry-out = maj(s, !t, carry)
        let nt = !t;
        carry = (s && nt) || (s && carry) || (nt && carry);
    }
    CmpOutput { geq: carry, bits }
}

/// Gate depth of the customized-FA carry chain (one mux per bit).
pub const CMP_DEPTH_GATES: usize = BITS_PER_WORD;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_integer_comparison_exhaustively() {
        for s in 0u8..32 {
            for t in 0u8..32 {
                let out = compare_geq(s, t);
                assert_eq!(out.geq, s >= t, "s={s} t={t}");
            }
        }
    }

    #[test]
    fn nor_bitline_semantics() {
        let out = compare_geq(0b01010, 0b00110);
        for (i, b) in out.bits.iter().enumerate() {
            let s = (0b01010 >> i) & 1 == 1;
            let t = (0b00110 >> i) & 1 == 1;
            assert_eq!(b.nor, !(s | t), "bit {i}");
        }
    }

    #[test]
    fn equal_inputs_are_geq() {
        for v in 0u8..32 {
            assert!(compare_geq(v, v).geq);
        }
    }

    #[test]
    fn rbl_full_swing_only_when_both_zero() {
        // the paper's point: RBL stays high (nor=1) only for (0,0) bits
        let out = compare_geq(0, 0);
        assert!(out.bits.iter().all(|b| b.nor));
        let out = compare_geq(0x1F, 0x1F);
        assert!(out.bits.iter().all(|b| !b.nor));
    }
}
