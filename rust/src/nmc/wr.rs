//! Write-back (WR) module — the DFF + mux that selects what goes back into
//! the type-A array (paper Sec. IV-D).
//!
//! The value latched by the DFF is one of `TOS-1`, `0`, or `255`, selected
//! by the MOL carry-out and the CMP result:
//!
//! * stored word was 0 (erased pixel)      -> write **disabled** (the
//!   paper's error-containment property: BER can only corrupt pixels that
//!   hold valid values);
//! * pixel is the event centre             -> write 255 (stored 0x1F);
//! * `TOS-1 >= TH`                         -> write `TOS-1`;
//! * otherwise                             -> write 0 (erase).

use super::cmp::CmpOutput;
use super::mol::MolOutput;

/// What the WR stage decided for one pixel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteBack {
    /// Write port not driven; cell keeps its value.
    Disabled,
    /// Drive the 5-bit word onto WBL.
    Value(u8),
}

/// Evaluate the write-back mux for one pixel of the patch.
///
/// `stored` is the 5-bit word read in the MO phase, `mol`/`cmp` the
/// outputs of the two compute stages, `is_centre` whether this pixel is
/// the event location.
pub fn write_back(stored: u8, mol: MolOutput, cmp: CmpOutput, is_centre: bool) -> WriteBack {
    if is_centre {
        // centre always becomes 255 (stored 0x1F), even if it was erased
        return WriteBack::Value(0x1F);
    }
    if stored == 0 {
        // erased pixel: 0-1 would wrap; hardware gates WWL off instead.
        return WriteBack::Disabled;
    }
    debug_assert!(mol.cout, "non-zero stored word must produce carry-out");
    if cmp.geq {
        WriteBack::Value(mol.sum)
    } else {
        WriteBack::Value(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmc::{cmp::compare_geq, mol::minus_one_gate};

    const TH5: u8 = 1; // TH = 225 in 5-bit space

    fn step(stored: u8, centre: bool) -> WriteBack {
        let mol = minus_one_gate(stored);
        let cmp = compare_geq(mol.sum, TH5);
        write_back(stored, mol, cmp, centre)
    }

    #[test]
    fn centre_always_writes_255() {
        assert_eq!(step(0, true), WriteBack::Value(0x1F));
        assert_eq!(step(0x10, true), WriteBack::Value(0x1F));
    }

    #[test]
    fn erased_pixel_write_disabled() {
        assert_eq!(step(0, false), WriteBack::Disabled);
    }

    #[test]
    fn live_pixel_decrements() {
        // stored 31 (=255) -> 30 (=254)
        assert_eq!(step(0x1F, false), WriteBack::Value(0x1E));
        // stored 2 (=226) -> 1 (=225), still >= TH
        assert_eq!(step(2, false), WriteBack::Value(1));
    }

    #[test]
    fn below_threshold_clamps_to_zero() {
        // stored 1 (=225) -> 0 (=224) < TH -> erase
        assert_eq!(step(1, false), WriteBack::Value(0));
    }

    #[test]
    fn matches_golden_8bit_semantics_exhaustively() {
        // For every representable TOS value, the 5-bit datapath must agree
        // with the 8-bit golden update rule.
        for v in 0u16..=255 {
            let v = v as u8;
            if !crate::tos::encoding::representable(v) {
                continue;
            }
            let stored = crate::tos::encoding::store(v);
            let golden = {
                let d = v.saturating_sub(1);
                if d < 225 {
                    0
                } else {
                    d
                }
            };
            match step(stored, false) {
                WriteBack::Disabled => assert_eq!(golden, 0, "v={v}"),
                WriteBack::Value(bits) => {
                    assert_eq!(crate::tos::encoding::load(bits), golden, "v={v}")
                }
            }
        }
    }
}
