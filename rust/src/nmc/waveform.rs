//! Signal-level waveform generator: the control-signal timeline of one row
//! operation (paper Fig. 7), derived from the calibrated phase delays.
//!
//! Beyond documentation value, the waveform model enforces the *timing
//! contracts* the circuit description states — SA clock strobes after the
//! RBL has developed, the CMP precharge overlaps the MO phase, write-back
//! never overlaps a read of the same row — and the tests check those
//! contracts at every supply voltage, which is what "the pipeline is
//! legal" means at circuit level.

use super::timing::{Phase, TimingModel};

/// One control signal's activity window within a row operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pulse {
    /// Signal name (paper Fig. 7 labels).
    pub signal: Signal,
    /// Assertion time relative to row start (ns).
    pub t_start: f64,
    /// De-assertion time (ns).
    pub t_end: f64,
}

/// The control signals of Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Signal {
    /// Active-low precharge of the type-A read bitlines.
    PreB,
    /// Read word line of the selected type-A row.
    Rwl,
    /// Sense-amp strobe (latches the RBL differential).
    SaCk,
    /// Write word line of the CMP module's SUM row.
    WwlCmp,
    /// Active-low precharge of the CMP module's bitlines.
    PreCmpB,
    /// CMP evaluate enable (active low in the paper).
    CmpEnB,
    /// DFF clock latching the write-back value.
    WrCk,
    /// Write word line of the type-A array (write-back).
    Wwl,
}

impl Signal {
    /// Display label matching Fig. 7.
    pub fn label(self) -> &'static str {
        match self {
            Signal::PreB => "PRE_b",
            Signal::Rwl => "RWL",
            Signal::SaCk => "SA_CK",
            Signal::WwlCmp => "WWL_CMP",
            Signal::PreCmpB => "PRE_CMP_b",
            Signal::CmpEnB => "CMP_ENb",
            Signal::WrCk => "WR_CK",
            Signal::Wwl => "WWL",
        }
    }
}

/// The full waveform of one row operation at a voltage.
#[derive(Debug, Clone)]
pub struct RowWaveform {
    /// Supply voltage.
    pub vdd: f64,
    /// All pulses, in assertion order.
    pub pulses: Vec<Pulse>,
    /// Total row time (ns).
    pub row_ns: f64,
}

/// SA setup margin as a fraction of the MO phase: the strobe arrives this
/// far into the phase so the bitline has developed ("SA clock arrives
/// slightly later to ensure setup time").
const SA_SETUP_FRAC: f64 = 0.6;

/// Generate the Fig. 7 waveform for one row at a voltage.
pub fn row_waveform(vdd: f64) -> RowWaveform {
    let t = TimingModel::at(vdd);
    let t1 = t.phase_ns(Phase::Pch);
    let t2 = t.phase_ns(Phase::Mo);
    let t3 = t.phase_ns(Phase::Cmp);
    let t4 = t.phase_ns(Phase::Wr);
    let mo_start = t1;
    let cmp_start = t1 + t2;
    let wr_start = t1 + t2 + t3;
    let row_ns = t1 + t2 + t3 + t4;
    let pulses = vec![
        // PCH: active-low precharge pulse over the whole first phase
        Pulse { signal: Signal::PreB, t_start: 0.0, t_end: t1 },
        // MO: read word line up for the whole MO phase
        Pulse { signal: Signal::Rwl, t_start: mo_start, t_end: cmp_start },
        // SA strobes after the bitline developed
        Pulse {
            signal: Signal::SaCk,
            t_start: mo_start + SA_SETUP_FRAC * t2,
            t_end: cmp_start,
        },
        // the MO result is written into the CMP SUM row while MO completes
        Pulse {
            signal: Signal::WwlCmp,
            t_start: mo_start + SA_SETUP_FRAC * t2,
            t_end: cmp_start,
        },
        // CMP bitline precharge overlaps MO (it has its own bitlines)
        Pulse { signal: Signal::PreCmpB, t_start: mo_start, t_end: mo_start + 0.5 * t2 },
        // CMP evaluation
        Pulse { signal: Signal::CmpEnB, t_start: cmp_start, t_end: wr_start },
        // WR: DFF latches, then the type-A write port drives
        Pulse { signal: Signal::WrCk, t_start: wr_start, t_end: wr_start + 0.2 * t4 },
        Pulse { signal: Signal::Wwl, t_start: wr_start + 0.2 * t4, t_end: row_ns },
    ];
    RowWaveform { vdd, pulses, row_ns }
}

impl RowWaveform {
    /// Find a signal's pulse.
    pub fn pulse(&self, s: Signal) -> Pulse {
        *self.pulses.iter().find(|p| p.signal == s).expect("signal present")
    }

    /// Render an ASCII timing diagram (Fig. 7 stand-in), `cols` wide.
    pub fn render_ascii(&self, cols: usize) -> String {
        let mut out = String::new();
        for p in &self.pulses {
            let a = (p.t_start / self.row_ns * cols as f64) as usize;
            let b = ((p.t_end / self.row_ns * cols as f64) as usize).min(cols);
            let mut line = format!("{:<10}", p.signal.label());
            for i in 0..cols {
                line.push(if i >= a && i < b { '#' } else { '_' });
            }
            line.push('\n');
            out.push_str(&line);
        }
        out
    }

    /// Check the circuit timing contracts; returns a violation description
    /// or `Ok(())`.
    pub fn check_contracts(&self) -> Result<(), String> {
        let pre = self.pulse(Signal::PreB);
        let rwl = self.pulse(Signal::Rwl);
        let sa = self.pulse(Signal::SaCk);
        let wwl_cmp = self.pulse(Signal::WwlCmp);
        let pre_cmp = self.pulse(Signal::PreCmpB);
        let cmp_en = self.pulse(Signal::CmpEnB);
        let wr_ck = self.pulse(Signal::WrCk);
        let wwl = self.pulse(Signal::Wwl);

        // 1. precharge must fully precede the read
        if pre.t_end > rwl.t_start + 1e-12 {
            return Err("PRE overlaps RWL".into());
        }
        // 2. SA strobe must come strictly after RWL rises (setup time)
        if sa.t_start <= rwl.t_start {
            return Err("SA_CK has no setup margin".into());
        }
        // 3. the CMP SUM row write happens while its precharge is done
        if wwl_cmp.t_start < pre_cmp.t_end {
            return Err("WWL_CMP collides with CMP precharge".into());
        }
        // 4. CMP evaluates only after the SUM row was written
        if cmp_en.t_start < wwl_cmp.t_end - 1e-12 {
            return Err("CMP_ENb before SUM write completed".into());
        }
        // 5. write-back value is latched before WWL drives the array
        if wwl.t_start < wr_ck.t_end - 1e-12 {
            return Err("WWL before WR_CK latched".into());
        }
        // 6. read and write ports of type A never overlap within one row op
        if wwl.t_start < rwl.t_end {
            return Err("type-A write overlaps its read".into());
        }
        Ok(())
    }

    /// The pipeline legality condition (Fig. 4): the next row's PCH+MO may
    /// overlap this row's CMP+WR because they touch disjoint resources
    /// (read port + SA vs CMP block + write port). Returns the earliest
    /// legal start offset of the next row (ns).
    pub fn next_row_offset_ns(&self) -> f64 {
        // next row may begin once the SA has latched this row's value,
        // i.e. after PCH+MO
        self.pulse(Signal::Rwl).t_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmc::calib;

    #[test]
    fn contracts_hold_across_voltage_range() {
        let mut v = 0.6;
        while v <= 1.201 {
            let w = row_waveform(v);
            w.check_contracts().unwrap_or_else(|e| panic!("{e} at {v} V"));
            v += 0.01;
        }
    }

    #[test]
    fn pipeline_offset_matches_phase_split() {
        let w = row_waveform(1.2);
        let t = TimingModel::at(1.2);
        let expect = t.phase_ns(Phase::Pch) + t.phase_ns(Phase::Mo);
        assert!((w.next_row_offset_ns() - expect).abs() < 1e-9);
        // and P rows pipelined at this offset reproduce the patch latency
        let p = calib::PATCH as f64;
        let total = (p - 1.0) * w.next_row_offset_ns()
            + w.row_ns;
        let anchor = t.patch_latency_pipelined_ns(calib::PATCH);
        assert!((total - anchor).abs() < 1e-9, "{total} vs {anchor}");
    }

    #[test]
    fn waveform_scales_with_voltage() {
        let hi = row_waveform(1.2);
        let lo = row_waveform(0.6);
        let ratio = lo.row_ns / hi.row_ns;
        assert!((ratio - calib::delay_factor(0.6)).abs() < 1e-9);
        // pulse order identical at both voltages
        let order = |w: &RowWaveform| w.pulses.iter().map(|p| p.signal).collect::<Vec<_>>();
        assert_eq!(order(&hi), order(&lo));
    }

    #[test]
    fn ascii_render_has_all_signals() {
        let w = row_waveform(0.8);
        let art = w.render_ascii(60);
        for s in [
            Signal::PreB,
            Signal::Rwl,
            Signal::SaCk,
            Signal::WwlCmp,
            Signal::PreCmpB,
            Signal::CmpEnB,
            Signal::WrCk,
            Signal::Wwl,
        ] {
            assert!(art.contains(s.label()), "{} missing", s.label());
        }
        assert_eq!(art.lines().count(), 8);
    }

    #[test]
    fn sa_strobe_has_setup_margin() {
        let w = row_waveform(1.0);
        let rwl = w.pulse(Signal::Rwl);
        let sa = w.pulse(Signal::SaCk);
        let margin = sa.t_start - rwl.t_start;
        let t2 = TimingModel::at(1.0).phase_ns(Phase::Mo);
        assert!((margin / t2 - SA_SETUP_FRAC).abs() < 1e-9);
    }
}
