//! Energy + power model of the NMC macro.
//!
//! Per-patch dynamic energy follows the calibrated `E(V) = E_nom (V/1.2)^γ`
//! law of [`calib`]; the static (leakage) component is a small
//! voltage-dependent floor.  The module also exposes the Fig. 10(a)
//! per-module breakdown and the Fig. 10(b) power-vs-event-rate curves.



use super::calib;

/// Leakage power at nominal voltage (mW). SRAM-macro scale leakage in
/// 65 nm: a few µW — small against dynamic power at Meps rates but keeps
/// idle power non-zero in Table I.
pub const LEAK_NOM_MW: f64 = 0.004;

/// Energy model at a fixed supply voltage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Dynamic energy of one full-P patch update (pJ).
    pub patch_pj: f64,
    /// Leakage power (mW).
    pub leak_mw: f64,
}

impl EnergyModel {
    /// Build the model at a voltage.
    pub fn at(vdd: f64) -> Self {
        let patch_pj = calib::PATCH_ENERGY_NOM_PJ * calib::energy_factor(vdd);
        // Leakage scales roughly linearly with Vdd (DIBL-dominated region).
        let leak_mw = LEAK_NOM_MW * vdd / calib::VDD_NOM;
        Self { vdd, patch_pj, leak_mw }
    }

    /// Energy of a patch that touches `pixels` of the full `P*P` patch
    /// (border-clipped patches switch fewer bitlines).
    #[inline]
    pub fn patch_energy_pj(&self, pixels: usize) -> f64 {
        let full = (calib::PATCH * calib::PATCH) as f64;
        self.patch_pj * pixels as f64 / full
    }

    /// Average power at a sustained event rate (mW).
    pub fn power_mw(&self, events_per_s: f64) -> f64 {
        self.patch_pj * 1e-12 * events_per_s * 1e3 + self.leak_mw
    }

    /// Per-module energy breakdown of one full patch (pJ), in
    /// [`calib::ENERGY_SHARE_LABELS`] order.
    pub fn breakdown_pj(&self) -> [f64; 4] {
        let mut out = [0.0; 4];
        for (o, s) in out.iter_mut().zip(calib::ENERGY_SHARE) {
            *o = self.patch_pj * s;
        }
        out
    }
}

/// Conventional-digital energy model (for Fig. 9(c)/10(b) baselines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConventionalEnergy {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Dynamic energy of one patch (pJ).
    pub patch_pj: f64,
    /// Leakage power (mW). A synthesized digital datapath leaks a bit more
    /// than a dense SRAM macro.
    pub leak_mw: f64,
}

impl ConventionalEnergy {
    /// Build the conventional-baseline model at a voltage.
    pub fn at(vdd: f64) -> Self {
        let patch_pj =
            calib::CONV_ENERGY_RATIO * calib::PATCH_ENERGY_NOM_PJ * calib::energy_factor(vdd);
        Self { vdd, patch_pj, leak_mw: 1.5 * LEAK_NOM_MW * vdd / calib::VDD_NOM }
    }

    /// Average power at a sustained event rate (mW).
    pub fn power_mw(&self, events_per_s: f64) -> f64 {
        self.patch_pj * 1e-12 * events_per_s * 1e3 + self.leak_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors() {
        assert!((EnergyModel::at(1.2).patch_pj - 139.0).abs() < 1e-9);
        assert!((EnergyModel::at(0.6).patch_pj - 26.0).abs() < 1e-9);
    }

    #[test]
    fn clipped_patch_scales_energy() {
        let e = EnergyModel::at(1.2);
        assert!((e.patch_energy_pj(49) - 139.0).abs() < 1e-9);
        let corner = e.patch_energy_pj(16); // 4x4 corner clip
        assert!((corner - 139.0 * 16.0 / 49.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_matches_fig10a() {
        let b = EnergyModel::at(1.2).breakdown_pj();
        let total: f64 = b.iter().sum();
        assert!((b[0] / total - 0.459).abs() < 1e-6); // peripheral
        assert!((b[1] / total - 0.319).abs() < 1e-6); // array
        assert!((b[2] / total - 0.116).abs() < 1e-6); // driver
        assert!((b[3] / total - 0.106).abs() < 1e-6); // SA
    }

    #[test]
    fn power_at_45meps_matches_fig10b_ratio() {
        // Paper: at 45 Meps NMC cuts power 1.2x vs conventional.
        let nmc = EnergyModel::at(1.2).power_mw(45e6);
        let conv = ConventionalEnergy::at(1.2).power_mw(45e6);
        let ratio = conv / nmc;
        assert!((ratio - 1.23).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn energy_reduction_6p6x_conventional_to_nmc_dvfs() {
        let conv = ConventionalEnergy::at(1.2).patch_pj;
        let nmc_low = EnergyModel::at(0.6).patch_pj;
        let r = conv / nmc_low;
        assert!((r - 6.6).abs() < 0.05, "{r}");
    }

    #[test]
    fn leakage_small_but_positive() {
        let e = EnergyModel::at(0.6);
        assert!(e.leak_mw > 0.0 && e.leak_mw < 0.01);
        assert!(e.power_mw(0.0) == e.leak_mw);
    }

    #[test]
    fn power_monotone_in_rate_and_voltage() {
        let e = EnergyModel::at(1.0);
        assert!(e.power_mw(2e6) < e.power_mw(4e6));
        assert!(EnergyModel::at(0.8).power_mw(1e6) < EnergyModel::at(1.2).power_mw(1e6));
    }
}
