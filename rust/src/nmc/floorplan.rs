//! Area / floorplan model of the NMC-TOS macro in the paper's 65 nm
//! process: transistor counts per circuit block scaled by standard 65 nm
//! layout densities.  Not a paper table per se, but the area story is what
//! makes "near-memory" credible for an edge device, and the ablation
//! harness uses it to cost alternative configurations (e.g. 28T FAs vs
//! the simplified MOL, or 6T storage without the pipeline).

use super::calib::{BITS_PER_WORD, BLOCK_COLS_PX, BLOCK_ROWS};
use crate::events::Resolution;
use super::sram::BlockGrid;

/// Approximate layout area of one minimum transistor in a 65 nm SRAM-style
/// layout (µm²), calibrated so a 6T bitcell lands at the published 65 nm
/// bitcell area of ~0.52 µm².
pub const UM2_PER_SRAM_TRANSISTOR: f64 = 0.52 / 6.0;
/// Logic transistors lay out looser than bitcells.
pub const UM2_PER_LOGIC_TRANSISTOR: f64 = 0.23;

/// Transistor counts of the circuit blocks (paper Figs. 4-6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CircuitInventory {
    /// Type-A cells (8T) in the storage array.
    pub type_a_cells: usize,
    /// Type-B cells (8T) in the CMP module (2 rows per block).
    pub type_b_cells: usize,
    /// Sense amps (one per column pair of 5-bit word => per bit column).
    pub sense_amps: usize,
    /// Simplified MOL slices (per bit column).
    pub mol_slices: usize,
    /// Customized FA slices in the CMP chain (per bit column).
    pub cmp_fa_slices: usize,
    /// Write-back DFF+mux slices (per bit column).
    pub wr_slices: usize,
}

/// Per-slice transistor counts.
const T_PER_8T_CELL: usize = 8;
const T_PER_SA: usize = 12; // latched SA
const T_PER_MOL: usize = 10; // XNOR + OR vs 28T FA
const T_PER_28T_FA: usize = 28;
const T_PER_CMP_FA: usize = 16; // customized FA + inverter readout
const T_PER_WR: usize = 22; // DFF (16T) + 3:1 mux

impl CircuitInventory {
    /// Inventory for a sensor resolution (tiled into 180x120 blocks).
    pub fn for_resolution(res: Resolution) -> Self {
        let grid = BlockGrid::for_resolution(res);
        let blocks = grid.block_count();
        let bit_cols = BLOCK_COLS_PX * BITS_PER_WORD; // 600 per block
        Self {
            type_a_cells: blocks * BLOCK_ROWS * bit_cols,
            type_b_cells: blocks * 2 * bit_cols,
            sense_amps: blocks * bit_cols,
            mol_slices: blocks * bit_cols,
            cmp_fa_slices: blocks * bit_cols,
            wr_slices: blocks * bit_cols,
        }
    }

    /// Total transistors.
    pub fn transistors(&self) -> usize {
        self.type_a_cells * T_PER_8T_CELL
            + self.type_b_cells * T_PER_8T_CELL
            + self.sense_amps * T_PER_SA
            + self.mol_slices * T_PER_MOL
            + self.cmp_fa_slices * T_PER_CMP_FA
            + self.wr_slices * T_PER_WR
    }

    /// Estimated area (mm²): array at bitcell density, periphery at logic
    /// density.
    pub fn area_mm2(&self) -> f64 {
        let array_t = (self.type_a_cells + self.type_b_cells) * T_PER_8T_CELL;
        let peri_t = self.transistors() - array_t;
        (array_t as f64 * UM2_PER_SRAM_TRANSISTOR + peri_t as f64 * UM2_PER_LOGIC_TRANSISTOR)
            / 1e6
    }

    /// Area of the hypothetical design that keeps 28T FAs everywhere
    /// instead of the simplified MOL + customized CMP FA (the ablation the
    /// paper's Figs. 5(b)/6(b) argue against).
    pub fn area_mm2_with_28t_fas(&self) -> f64 {
        let array_t = (self.type_a_cells + self.type_b_cells) * T_PER_8T_CELL;
        let peri_t = self.sense_amps * T_PER_SA
            + self.mol_slices * T_PER_28T_FA
            + self.cmp_fa_slices * T_PER_28T_FA
            + self.wr_slices * T_PER_WR;
        (array_t as f64 * UM2_PER_SRAM_TRANSISTOR + peri_t as f64 * UM2_PER_LOGIC_TRANSISTOR)
            / 1e6
    }

    /// Array fraction of total area (the "near-memory" figure of merit:
    /// most silicon is the memory itself).
    pub fn array_fraction(&self) -> f64 {
        let array_t = ((self.type_a_cells + self.type_b_cells) * T_PER_8T_CELL) as f64
            * UM2_PER_SRAM_TRANSISTOR;
        array_t / 1e6 / self.area_mm2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn davis240_inventory_matches_fig3() {
        let inv = CircuitInventory::for_resolution(Resolution::DAVIS240);
        // 2 blocks x 180 rows x 600 bit-columns
        assert_eq!(inv.type_a_cells, 2 * 180 * 600);
        assert_eq!(inv.type_b_cells, 2 * 2 * 600);
        assert_eq!(inv.sense_amps, 2 * 600);
    }

    #[test]
    fn area_is_sub_mm2_for_davis240() {
        // a 216-kbit macro + periphery in 65 nm must land well below 2 mm²
        let inv = CircuitInventory::for_resolution(Resolution::DAVIS240);
        let a = inv.area_mm2();
        assert!(a > 0.05 && a < 2.0, "area {a} mm2");
    }

    #[test]
    fn array_dominates_area() {
        let inv = CircuitInventory::for_resolution(Resolution::DAVIS240);
        assert!(inv.array_fraction() > 0.35, "array fraction {}", inv.array_fraction());
    }

    #[test]
    fn simplified_logic_saves_area() {
        let inv = CircuitInventory::for_resolution(Resolution::DAVIS240);
        assert!(inv.area_mm2() < inv.area_mm2_with_28t_fas());
    }

    #[test]
    fn area_scales_with_resolution() {
        let small = CircuitInventory::for_resolution(Resolution::DAVIS240).area_mm2();
        let big = CircuitInventory::for_resolution(Resolution::HD720).area_mm2();
        // 44 blocks vs 2 blocks
        assert!(big / small > 15.0 && big / small < 30.0, "ratio {}", big / small);
    }
}
