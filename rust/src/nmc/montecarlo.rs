//! Monte-Carlo variation model: sense-amp offset + bitcell read-current
//! mismatch -> read bit errors at low supply voltage (paper Sec. V-C).
//!
//! Physical picture: during the MO phase the RBL develops a differential
//! swing proportional to the cell read current over the SA strobe window;
//! the latched SA resolves correctly iff the developed swing exceeds its
//! input offset.  Both the per-read swing and the per-read offset carry
//! Gaussian mismatch, so the upset probability of one bit-read is
//! `Q((V - V0)/sigma)` with `(V0, sigma)` fitted in [`calib::ber_params`]
//! to the paper's published BER points.
//!
//! The module provides (a) a Monte-Carlo *measurement* harness that
//! estimates BER by simulating individual reads — this regenerates the
//! paper's MC table — and (b) a fast error-injection sampler used by the
//! system-level pipeline for the Fig. 11 study.

use crate::util::rng::Rng;


use super::calib;

/// One voltage point of the Monte-Carlo sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BerPoint {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Bit reads simulated.
    pub reads: u64,
    /// Upsets observed.
    pub errors: u64,
    /// Measured bit error rate.
    pub ber: f64,
    /// Analytic model value, for reference.
    pub model_ber: f64,
}

/// Simulate `reads` single-bit reads at `vdd` and count upsets.
///
/// Each read draws the developed swing margin `m ~ N(V - V0, sigma)`; the
/// SA resolves wrongly when `m < 0`.
pub fn measure_ber(vdd: f64, reads: u64, seed: u64) -> BerPoint {
    let (v0, sigma) = calib::ber_params();
    let mut rng = Rng::seed_from(seed);
    let mean = vdd - v0;
    let mut errors = 0u64;
    for _ in 0..reads {
        let m = rng.normal(mean, sigma);
        if m < 0.0 {
            errors += 1;
        }
    }
    BerPoint {
        vdd,
        reads,
        errors,
        ber: errors as f64 / reads as f64,
        model_ber: calib::bit_error_probability(vdd),
    }
}

/// Sweep BER over a voltage range (the paper's MC table).
pub fn ber_sweep(voltages: &[f64], reads: u64, seed: u64) -> Vec<BerPoint> {
    voltages
        .iter()
        .enumerate()
        .map(|(i, &v)| measure_ber(v, reads, seed ^ (i as u64).wrapping_mul(0x9E37_79B9)))
        .collect()
}

/// Static-fault error injector for the system pipeline.
///
/// Monte-Carlo mismatch is *per device*, not per access: a given SA/cell
/// pair either has enough margin at a voltage or it does not.  So the
/// injector derives, deterministically from `(seed, cell, bit)`, a margin
/// percentile `u ~ U(0,1)`; the bit is faulty at voltage `V` iff
/// `u < p_bit(V)` — the worst cells fail first, and the faulty set at
/// 0.61 V is a subset of the one at 0.60 V, exactly like silicon.  A
/// faulty bit reads *stuck* at a (deterministic) random value.
#[derive(Debug, Clone)]
pub struct ErrorInjector {
    /// Per-bit fault probability at the current voltage, floored to
    /// exactly 0 below [`calib::BER_MC_FLOOR`] (the paper's MC table
    /// reports "0" at and above 0.62 V).
    p_bit: f64,
    /// Supply voltage the current fault map was derived for.
    vdd: f64,
    seed: u64,
    /// Precomputed per-cell fault map at the current voltage:
    /// `(mask, stuck)` per cell — faulty bits in `mask` read as the
    /// corresponding bits of `stuck`. Rebuilt on DVFS retarget (rare);
    /// turns the hot-path corrupt() into two byte ops
    /// (EXPERIMENTS.md §Perf iteration 7).
    map: Vec<(u8, u8)>,
    /// Cells with at least one faulty bit in the sized portion of the
    /// map (kept in sync by `rebuild_map` and on-demand growth).
    faulty_cells: u64,
    /// Total corrupted word reads so far (telemetry).
    pub flipped_bits: u64,
    /// Total word reads seen (telemetry).
    pub word_reads: u64,
}

#[inline]
fn mix(mut z: u64) -> u64 {
    // splitmix64 finalizer: cheap, stateless, well distributed
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The Monte-Carlo floor rule on a raw per-bit probability: values below
/// [`calib::BER_MC_FLOOR`] inject as exactly `0.0` (not merely "small" —
/// the vdd-sweep report schema relies on the nominal region being
/// bit-clean). Split from [`injected_p_bit`] so the floor semantics are
/// provable in isolation (`verify::floor_clamp_is_exact_zero`) without
/// dragging in the transcendental BER curve.
#[inline]
pub(crate) fn clamp_p_to_floor(p: f64) -> f64 {
    if p < calib::BER_MC_FLOOR {
        0.0
    } else {
        p
    }
}

/// [`calib::bit_error_probability`] with the Monte-Carlo floor applied.
#[inline]
fn injected_p_bit(vdd: f64) -> f64 {
    clamp_p_to_floor(calib::bit_error_probability(vdd))
}

/// Derive the (mask, stuck) pair of one cell from the seed and a per-bit
/// fault probability. The per-bit uniform draw depends only on
/// `(seed, cell, bit)` — never on `p_bit` — which is what makes fault
/// sets *nested* across voltages: lowering Vdd only raises the threshold
/// the same fixed draws are compared against
/// (`verify::fault_sets_nest_monotonically_in_p`).
#[inline]
pub(crate) fn cell_faults_at(seed: u64, cell: usize, p_bit: f64) -> (u8, u8) {
    let mut mask = 0u8;
    let mut stuck = 0u8;
    for bit in 0..calib::BITS_PER_WORD {
        let h = mix(seed ^ ((cell as u64) << 3) ^ bit as u64);
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < p_bit {
            mask |= 1 << bit;
            stuck |= (((h >> 7) & 1) as u8) << bit;
        }
    }
    (mask, stuck)
}

impl ErrorInjector {
    /// Injector at a fixed supply voltage covering `n_cells` pixels.
    pub fn new_sized(vdd: f64, seed: u64, n_cells: usize) -> Self {
        let mut inj = Self {
            p_bit: injected_p_bit(vdd),
            vdd,
            seed,
            map: Vec::new(),
            faulty_cells: 0,
            flipped_bits: 0,
            word_reads: 0,
        };
        inj.rebuild_map(n_cells);
        inj
    }

    /// Injector with a lazily-unsized map (tests / ad-hoc use): the map is
    /// grown on demand in `corrupt`.
    pub fn new(vdd: f64, seed: u64) -> Self {
        Self::new_sized(vdd, seed, 0)
    }

    /// Derive the (mask, stuck) pair of one cell at the current threshold.
    fn cell_faults(&self, cell: usize) -> (u8, u8) {
        cell_faults_at(self.seed, cell, self.p_bit)
    }

    fn rebuild_map(&mut self, n_cells: usize) {
        self.map.clear();
        self.map.reserve(n_cells);
        self.faulty_cells = 0;
        for cell in 0..n_cells {
            let f = self.cell_faults(cell);
            self.faulty_cells += (f.0 != 0) as u64;
            self.map.push(f);
        }
    }

    /// Retarget the injector when DVFS moves the voltage (the fault *map*
    /// is fixed silicon; only the margin threshold moves, so the map is
    /// re-derived for the new threshold).
    pub fn set_vdd(&mut self, vdd: f64) {
        self.p_bit = injected_p_bit(vdd);
        self.vdd = vdd;
        let n = self.map.len();
        self.rebuild_map(n);
    }

    /// Current per-bit fault probability (floored below
    /// [`calib::BER_MC_FLOOR`]).
    #[inline]
    pub fn p_bit(&self) -> f64 {
        self.p_bit
    }

    /// Supply voltage the current fault map was derived for.
    #[inline]
    pub fn vdd(&self) -> f64 {
        self.vdd
    }

    /// Seed the fault map derives from.
    #[inline]
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Cells with at least one faulty bit at the current voltage (within
    /// the sized fault map).
    #[inline]
    pub fn faulty_cells(&self) -> u64 {
        self.faulty_cells
    }

    /// The `(mask, stuck)` fault pair of one cell, growing the map on
    /// demand. Unlike [`ErrorInjector::corrupt`] this does *not* count a
    /// word read — callers doing their own bulk accounting (the
    /// fault-aware fast path) use it to apply faults in place.
    #[inline]
    pub fn cell_fault(&mut self, cell: usize) -> (u8, u8) {
        if cell >= self.map.len() {
            for c in self.map.len()..=cell {
                let f = self.cell_faults(c);
                self.faulty_cells += (f.0 != 0) as u64;
                self.map.push(f);
            }
        }
        self.map[cell]
    }

    /// Corrupt the 5-bit word read from cell index `cell` (a stable
    /// per-pixel identifier). Stuck bits override the stored value.
    #[inline]
    pub fn corrupt(&mut self, word: u8, cell: usize) -> u8 {
        self.word_reads += 1;
        if self.p_bit <= 0.0 {
            return word;
        }
        // grow on demand (tests); system paths size the map up front
        let (mask, stuck) = self.cell_fault(cell);
        let out = (word & !mask) | (stuck & mask);
        if out != word {
            self.flipped_bits += 1;
        }
        out
    }

    /// Fraction of bits faulty at the current voltage over `n` cells
    /// (diagnostics; converges to `p_bit`).
    pub fn fault_fraction(&self, n_cells: usize) -> f64 {
        let mut faulty = 0usize;
        for cell in 0..n_cells {
            for bit in 0..calib::BITS_PER_WORD {
                let h = mix(self.seed ^ ((cell as u64) << 3) ^ bit as u64);
                let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                if u < self.p_bit {
                    faulty += 1;
                }
            }
        }
        faulty as f64 / (n_cells * calib::BITS_PER_WORD) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mc_reproduces_published_points() {
        let p = measure_ber(0.60, 200_000, 42);
        assert!((p.ber - 0.025).abs() < 0.003, "ber@0.6 {}", p.ber);
        let p = measure_ber(0.61, 500_000, 43);
        assert!((p.ber - 0.002).abs() < 0.0006, "ber@0.61 {}", p.ber);
        let p = measure_ber(0.63, 100_000, 44);
        assert_eq!(p.errors, 0, "expected zero errors at 0.63 V");
    }

    #[test]
    fn mc_matches_analytic_model() {
        for &v in &[0.60, 0.605, 0.61] {
            let p = measure_ber(v, 400_000, 7);
            let rel = (p.ber - p.model_ber).abs() / p.model_ber;
            assert!(rel < 0.25, "v={v} mc={} model={}", p.ber, p.model_ber);
        }
    }

    #[test]
    fn sweep_is_monotone_modulo_noise() {
        let pts = ber_sweep(&[0.58, 0.60, 0.62], 100_000, 1);
        assert!(pts[0].ber > pts[1].ber);
        assert!(pts[1].ber >= pts[2].ber);
    }

    #[test]
    fn injector_zero_at_nominal() {
        let mut inj = ErrorInjector::new(1.2, 5);
        for w in 0u8..32 {
            assert_eq!(inj.corrupt(w, w as usize), w);
        }
        assert_eq!(inj.flipped_bits, 0);
    }

    #[test]
    fn injector_fault_fraction_tracks_p_bit() {
        let inj = ErrorInjector::new(0.6, 11);
        let frac = inj.fault_fraction(100_000);
        assert!((frac - inj.p_bit()).abs() / inj.p_bit() < 0.1, "{frac}");
    }

    #[test]
    fn injector_faults_are_static_per_cell() {
        let mut inj = ErrorInjector::new(0.6, 13);
        // the same cell reads the same (possibly corrupted) value every time
        for cell in 0..500usize {
            let a = inj.corrupt(0x15, cell);
            let b = inj.corrupt(0x15, cell);
            assert_eq!(a, b, "cell {cell} not deterministic");
        }
    }

    #[test]
    fn injector_fault_sets_nest_with_voltage() {
        // every bit faulty at 0.61 V is also faulty at 0.60 V
        let mut hi = ErrorInjector::new(0.61, 17);
        let mut lo = ErrorInjector::new(0.60, 17);
        let mut nested = true;
        for cell in 0..20_000usize {
            let a = hi.corrupt(0x0A, cell);
            let b = lo.corrupt(0x0A, cell);
            // every bit corrupted at 0.61 V must be corrupted identically
            // at 0.60 V (0.60 V may corrupt *additional* bits)
            nested &= (a ^ b) & (a ^ 0x0A) == 0;
        }
        assert!(nested);
        assert!(lo.flipped_bits >= hi.flipped_bits);
    }

    #[test]
    fn injector_voltage_retarget() {
        let mut inj = ErrorInjector::new(1.2, 3);
        assert_eq!(inj.p_bit(), inj.p_bit().max(0.0)); // ~0
        assert!((inj.vdd() - 1.2).abs() < 1e-12);
        inj.set_vdd(0.6);
        assert!(inj.p_bit() > 0.02);
        assert!((inj.vdd() - 0.6).abs() < 1e-12);
        assert_eq!(inj.seed(), 3);
    }

    #[test]
    fn injector_floors_published_zero_voltages() {
        // the paper's MC table says BER = 0 at and above 0.62 V: the
        // injector must be exactly transparent there even though the
        // analytic tail is still (barely) positive
        for &v in &[0.62, 0.65, 1.2] {
            let mut inj = ErrorInjector::new_sized(v, 21, 50_000);
            assert_eq!(inj.p_bit(), 0.0, "p_bit not floored at {v} V");
            assert_eq!(inj.faulty_cells(), 0, "faulty cells at {v} V");
            for cell in 0..50_000usize {
                assert_eq!(inj.corrupt(0x15, cell), 0x15);
            }
            assert_eq!(inj.flipped_bits, 0);
        }
        // just below the knee, faults appear
        let inj = ErrorInjector::new_sized(0.61, 21, 50_000);
        assert!(inj.p_bit() > 0.0);
        assert!(inj.faulty_cells() > 0);
    }

    #[test]
    fn cell_fault_agrees_with_corrupt() {
        let mut a = ErrorInjector::new(0.6, 29);
        let mut b = ErrorInjector::new(0.6, 29);
        for cell in 0..5_000usize {
            let (mask, stuck) = a.cell_fault(cell);
            let want = (0x0Au8 & !mask) | (stuck & mask);
            assert_eq!(b.corrupt(0x0A, cell), want, "cell {cell}");
        }
        // cell_fault does not count reads; corrupt does
        assert_eq!(a.word_reads, 0);
        assert_eq!(b.word_reads, 5_000);
        // both grew the same map with the same faulty-cell census
        assert_eq!(a.faulty_cells(), b.faulty_cells());
    }
}
