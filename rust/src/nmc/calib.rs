//! Calibration of the analytical circuit models against the paper's
//! published 65 nm SPICE anchor numbers.
//!
//! Everything the latency/energy/BER models output is derived from the
//! constants in this file — there is exactly one source of truth, and the
//! experiment harnesses (Fig. 9/10, Table I) *derive* their curves from
//! these models instead of transcribing paper values.
//!
//! Anchors (paper Sec. V):
//! * pipelined 7x7 patch update: 15.85 ns @1.2 V (=> 63.1 Meps) and
//!   203 ns @0.6 V (=> 4.9 Meps);
//! * patch energy: 139 pJ @1.2 V, 26 pJ @0.6 V;
//! * conventional digital: 392 ns per 7x7 patch @500 MHz/1.2 V (2.6 Meps),
//!   1.2x the NMC energy at equal voltage;
//! * phase-delay split @0.6 V: PCH 13.9 %, MO 30.6 %, CMP 27.8 %, WR 27.8 %;
//! * energy breakdown @1.2 V: peripherals 45.9 %, array 31.9 %,
//!   driver 11.6 %, SA 10.6 %;
//! * Monte-Carlo BER: 2.5 % @0.6 V, 0.2 % @0.61 V, 0 above 0.62 V.

/// Nominal supply voltage (V).
pub const VDD_NOM: f64 = 1.2;
/// Minimum DVFS supply voltage (V).
pub const VDD_MIN: f64 = 0.6;
/// NMOS threshold voltage assumed by the alpha-power-law delay model (V).
pub const VTH: f64 = 0.35;

/// Patch side length the macro is sized for.
pub const PATCH: usize = 7;

/// Pipelined 7x7-patch update latency at `VDD_NOM` (ns).
/// (1 / 63.1 Meps = 15.85 ns; the paper rounds to 16 ns.)
pub const PATCH_LATENCY_NOM_NS: f64 = 15.85;
/// Pipelined 7x7-patch update latency at `VDD_MIN` (ns).
pub const PATCH_LATENCY_MIN_NS: f64 = 203.0;

/// Patch update energy at `VDD_NOM` (pJ).
pub const PATCH_ENERGY_NOM_PJ: f64 = 139.0;
/// Patch update energy at `VDD_MIN` (pJ).
pub const PATCH_ENERGY_MIN_PJ: f64 = 26.0;

/// Conventional digital implementation: clock at `VDD_NOM` (Hz) and the
/// cycles needed per 7x7 patch (1 px/cycle sequential read-modify-write,
/// plus the paper's 392 ns => 196 cycles at 500 MHz).
pub const CONV_CLOCK_NOM_HZ: f64 = 500.0e6;
/// Cycles per 7x7 patch on the conventional datapath (see
/// [`CONV_CLOCK_NOM_HZ`]).
pub const CONV_CYCLES_PER_PATCH: f64 = 196.0;
/// Conventional-vs-NMC energy ratio at equal voltage (paper: "1.2x",
/// pinned so that E_conv(1.2 V) / E_nmc(0.6 V) = 6.6x as reported).
pub const CONV_ENERGY_RATIO: f64 = 1.235;

/// Phase-delay shares of one row operation (PCH, MO, CMP, WR), measured by
/// the paper at 0.6 V and constant in cycle counts across voltage.
pub const PHASE_SHARE: [f64; 4] = [0.139, 0.306, 0.278, 0.278];

/// Energy breakdown shares at 1.2 V (peripherals, array, driver, SA).
pub const ENERGY_SHARE: [f64; 4] = [0.459, 0.319, 0.116, 0.106];
/// Labels matching [`ENERGY_SHARE`].
pub const ENERGY_SHARE_LABELS: [&str; 4] = ["peripheral", "array", "driver", "sense-amp"];

/// SRAM block geometry (paper Fig. 3): one block stores 180 x 120 pixels
/// as 180 rows x 600 columns of 5-bit words.
pub const BLOCK_ROWS: usize = 180;
/// Pixels per SRAM block row (see [`BLOCK_ROWS`]).
pub const BLOCK_COLS_PX: usize = 120;
/// Bits per pixel word in the SRAM array (see [`BLOCK_ROWS`]).
pub const BITS_PER_WORD: usize = 5;

/// DAVIS240 peak bus bandwidth used in Fig. 1(b) (events/s).
pub const DAVIS240_BANDWIDTH_EPS: f64 = 12.0e6;

// ---------------------------------------------------------------------------
// Alpha-power-law delay model, fit through the two latency anchors.
// ---------------------------------------------------------------------------

/// Alpha exponent of the delay model, solved from
/// `L(0.6)/L(1.2) = (0.6/1.2) * ((1.2-Vth)/(0.6-Vth))^alpha`.
pub fn alpha() -> f64 {
    let ratio = PATCH_LATENCY_MIN_NS / PATCH_LATENCY_NOM_NS;
    let vr = (VDD_NOM - VTH) / (VDD_MIN - VTH);
    ((ratio * VDD_NOM / VDD_MIN).ln()) / vr.ln()
}

/// Relative delay factor `d(V)/d(VDD_NOM)` from the alpha-power law.
pub fn delay_factor(vdd: f64) -> f64 {
    assert!(vdd > VTH, "vdd {vdd} below threshold {VTH}");
    let a = alpha();
    let d = |v: f64| v / (v - VTH).powf(a);
    d(vdd) / d(VDD_NOM)
}

// ---------------------------------------------------------------------------
// Energy model: single-exponent fit through the two energy anchors.
// E(V) = E_nom * (V / VDD_NOM)^gamma  with gamma ~ 2.42 (super-quadratic:
// short-circuit + sense-amp currents shrink faster than CV^2 at low Vdd).
// ---------------------------------------------------------------------------

/// Energy exponent solved from the two anchors.
pub fn gamma() -> f64 {
    (PATCH_ENERGY_NOM_PJ / PATCH_ENERGY_MIN_PJ).ln() / (VDD_NOM / VDD_MIN).ln()
}

/// Relative energy factor `E(V)/E(VDD_NOM)`.
pub fn energy_factor(vdd: f64) -> f64 {
    (vdd / VDD_NOM).powf(gamma())
}

// ---------------------------------------------------------------------------
// Monte-Carlo BER calibration: per-bit read-upset probability is
// Q((V - V0)/sigma), fit through (0.6 V, 2.5 %) and (0.61 V, 0.2 %).
// ---------------------------------------------------------------------------

/// Gaussian tail function Q(z) = 1 - Phi(z).
pub fn q_tail(z: f64) -> f64 {
    0.5 * erfc_scalar(z / std::f64::consts::SQRT_2)
}

/// Inverse of [`q_tail`] (bisection; used only at calibration time).
pub fn q_tail_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 0.5);
    let (mut lo, mut hi) = (0.0f64, 10.0f64);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if q_tail(mid) > p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// (V0, sigma) of the per-bit upset model.
pub fn ber_params() -> (f64, f64) {
    let z60 = q_tail_inv(0.025);
    let z61 = q_tail_inv(0.002);
    let sigma = 0.01 / (z61 - z60);
    let v0 = 0.60 - z60 * sigma;
    (v0, sigma)
}

/// Analytic per-bit upset probability at a supply voltage.
pub fn bit_error_probability(vdd: f64) -> f64 {
    let (v0, sigma) = ber_params();
    q_tail((vdd - v0) / sigma)
}

/// Monte-Carlo resolution floor of the paper's BER table: the published
/// numbers report "0" at and above 0.62 V, where the analytic model still
/// gives a small positive tail (~7e-5 at 0.62 V). Fault *injection*
/// treats probabilities below this floor as exactly zero so injected runs
/// reproduce the published curve (zero faults at >= 0.62 V); the analytic
/// [`bit_error_probability`] itself is left unclamped for the MC harness.
pub const BER_MC_FLOOR: f64 = 1.5e-4;

/// Scalar complementary error function (Abramowitz & Stegun 7.1.26,
/// |err| < 1.5e-7 — plenty for a BER model spanning 1e-1..1e-9).
pub fn erfc_scalar(x: f64) -> f64 {
    let sign_neg = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let y = poly * (-x * x).exp();
    if sign_neg {
        2.0 - y
    } else {
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_reproduces_anchor_latencies() {
        assert!((delay_factor(VDD_NOM) - 1.0).abs() < 1e-12);
        let l06 = PATCH_LATENCY_NOM_NS * delay_factor(VDD_MIN);
        assert!((l06 - PATCH_LATENCY_MIN_NS).abs() < 1e-6, "got {l06}");
    }

    #[test]
    fn alpha_in_plausible_range() {
        let a = alpha();
        assert!(a > 1.0 && a < 4.0, "alpha {a}");
    }

    #[test]
    fn delay_monotone_decreasing_in_vdd() {
        let mut last = f64::INFINITY;
        let mut v = VDD_MIN;
        while v <= VDD_NOM + 1e-9 {
            let d = delay_factor(v);
            assert!(d < last, "delay not monotone at {v}");
            last = d;
            v += 0.01;
        }
    }

    #[test]
    fn energy_reproduces_anchors() {
        assert!((energy_factor(VDD_NOM) - 1.0).abs() < 1e-12);
        let e06 = PATCH_ENERGY_NOM_PJ * energy_factor(VDD_MIN);
        assert!((e06 - PATCH_ENERGY_MIN_PJ).abs() < 1e-9, "got {e06}");
    }

    #[test]
    fn gamma_superquadratic() {
        let g = gamma();
        assert!(g > 2.0 && g < 3.0, "gamma {g}");
    }

    #[test]
    fn phase_and_energy_shares_sum_to_one() {
        let s: f64 = PHASE_SHARE.iter().sum();
        assert!((s - 1.001).abs() < 0.01, "phase shares sum {s}"); // paper rounds
        let e: f64 = ENERGY_SHARE.iter().sum();
        assert!((e - 1.0).abs() < 0.01, "energy shares sum {e}");
    }

    #[test]
    fn ber_hits_published_points() {
        assert!((bit_error_probability(0.60) - 0.025).abs() < 1e-6);
        assert!((bit_error_probability(0.61) - 0.002).abs() < 1e-4);
        // "zero" at and above 0.62 V = below Monte-Carlo resolution
        assert!(bit_error_probability(0.62) < 1.5e-4);
        assert!(bit_error_probability(0.65) < 1e-9);
    }

    #[test]
    fn ber_monotone_in_vdd() {
        let mut last = 1.0;
        for i in 0..20 {
            let v = 0.58 + i as f64 * 0.005;
            let p = bit_error_probability(v);
            assert!(p <= last + 1e-15);
            last = p;
        }
    }

    #[test]
    fn q_tail_sanity() {
        assert!((q_tail(0.0) - 0.5).abs() < 1e-7);
        assert!((q_tail(1.96) - 0.025).abs() < 2e-4);
        assert!((q_tail_inv(0.025) - 1.96).abs() < 2e-2);
    }

    #[test]
    fn erfc_matches_known_values() {
        assert!((erfc_scalar(0.0) - 1.0).abs() < 1e-9);
        assert!((erfc_scalar(1.0) - 0.157_299_2).abs() < 1e-6);
        assert!((erfc_scalar(-1.0) - 1.842_700_8).abs() < 1e-6);
    }

    #[test]
    fn headline_ratios_fall_out() {
        // 24.7x latency at 1.2 V (conventional 392 ns vs pipelined 15.85 ns)
        let conv_ns = CONV_CYCLES_PER_PATCH / CONV_CLOCK_NOM_HZ * 1e9;
        let speedup = conv_ns / PATCH_LATENCY_NOM_NS;
        assert!((speedup - 24.7).abs() < 0.1, "speedup {speedup}");
        // 6.6x energy: conventional @1.2 V vs NMC @0.6 V
        let e_ratio = CONV_ENERGY_RATIO * PATCH_ENERGY_NOM_PJ / PATCH_ENERGY_MIN_PJ;
        assert!((e_ratio - 6.6).abs() < 0.05, "energy ratio {e_ratio}");
    }
}
