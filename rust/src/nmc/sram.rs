//! 8T SRAM array models — paper Figs. 3 & 4(a).
//!
//! * **Type A**: the main TOS store. One *block* holds 180 rows x 600
//!   columns of cells = 180 x 120 pixels at 5 bits/pixel. The read port
//!   (RWL/RBL) and write port (WWL/WBL) are decoupled, which is what makes
//!   the [`super::pipeline`] overlap legal: the write-back of row *r* can
//!   coincide with the read of row *r+1*.
//! * **Type B**: the two compute rows inside the CMP module (SUM and TH)
//!   — modelled in [`super::cmp`].
//!
//! A sensor wider/taller than one block tiles multiple blocks (DAVIS240
//! needs two side by side; an HD720 Prophesee needs 24).



use crate::events::Resolution;

use super::calib::{BITS_PER_WORD, BLOCK_COLS_PX, BLOCK_ROWS};

/// Physical placement of one pixel inside the block array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellAddr {
    /// Which block (raster order over the block grid).
    pub block: usize,
    /// SRAM row inside the block (= sensor row modulo block rows).
    pub row: usize,
    /// Word index inside the row (= sensor column modulo block columns).
    pub word: usize,
}

/// Geometry: how a sensor resolution maps onto a grid of SRAM blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGrid {
    /// Sensor geometry.
    pub res: Resolution,
    /// Blocks along x.
    pub blocks_x: usize,
    /// Blocks along y.
    pub blocks_y: usize,
}

impl BlockGrid {
    /// Tile a sensor resolution with 180x120-pixel blocks.
    pub fn for_resolution(res: Resolution) -> Self {
        let blocks_x = (res.width as usize).div_ceil(BLOCK_COLS_PX);
        let blocks_y = (res.height as usize).div_ceil(BLOCK_ROWS);
        Self { res, blocks_x, blocks_y }
    }

    /// Total number of blocks (the paper's "two such blocks" for DAVIS240).
    #[inline]
    pub fn block_count(&self) -> usize {
        self.blocks_x * self.blocks_y
    }

    /// Map a pixel to its cell address.
    #[inline]
    pub fn addr(&self, x: u16, y: u16) -> CellAddr {
        let bx = x as usize / BLOCK_COLS_PX;
        let by = y as usize / BLOCK_ROWS;
        CellAddr {
            block: by * self.blocks_x + bx,
            row: y as usize % BLOCK_ROWS,
            word: x as usize % BLOCK_COLS_PX,
        }
    }

    /// Bits of on-chip storage across all blocks.
    pub fn total_bits(&self) -> usize {
        self.block_count() * BLOCK_ROWS * BLOCK_COLS_PX * BITS_PER_WORD
    }
}

/// The type-A storage array: 5-bit words addressed by (block, row, word).
///
/// Stored values use the [`crate::tos::encoding`] 5-bit code; this struct
/// is deliberately dumb — all TOS semantics live in the macro's
/// pipeline — but it enforces the decoupled-port timing contract by
/// tracking, per block, the last read and write rows of the current cycle
/// (a same-row read+write in one cycle is a simulator bug).
#[derive(Debug, Clone)]
pub struct TypeAArray {
    grid: BlockGrid,
    /// Simulator storage is flat row-major over the *sensor*: the physical
    /// (block, row, word) placement is pure geometry ([`BlockGrid::addr`])
    /// and never changes word contents, so the simulator avoids the
    /// div/mod of the block mapping on every pixel access
    /// (EXPERIMENTS.md §Perf iteration 8).
    words: Vec<u8>,
    /// Decoded 8-bit mirror of `words`, maintained on every write so TOS
    /// snapshots are zero-cost borrows ([`TypeAArray::decoded`]) instead
    /// of a full-frame decode per snapshot boundary.
    decoded: Vec<u8>,
    width: usize,
}

impl TypeAArray {
    /// All-zero (erased) array for a sensor.
    pub fn new(res: Resolution) -> Self {
        let grid = BlockGrid::for_resolution(res);
        let words = vec![0u8; res.pixels()];
        let decoded = vec![0u8; res.pixels()];
        Self { grid, words, decoded, width: res.width as usize }
    }

    /// Geometry.
    #[inline]
    pub fn grid(&self) -> BlockGrid {
        self.grid
    }

    /// Read the 5-bit word of a pixel (RWL/RBL port).
    #[inline]
    pub fn read(&self, x: u16, y: u16) -> u8 {
        self.words[y as usize * self.width + x as usize]
    }

    /// Write the 5-bit word of a pixel (WWL/WBL port).
    #[inline]
    pub fn write(&mut self, x: u16, y: u16, bits5: u8) {
        debug_assert!(bits5 < (1 << BITS_PER_WORD));
        let i = y as usize * self.width + x as usize;
        self.words[i] = bits5;
        self.decoded[i] = crate::tos::encoding::load(bits5);
    }

    /// Borrowed 8-bit TOS image (row-major), decoded incrementally at
    /// write time. Zero-cost: this is the snapshot path of the NMC
    /// backend.
    #[inline]
    pub fn decoded(&self) -> &[u8] {
        &self.decoded
    }

    /// Snapshot all pixels into an owned 8-bit TOS image (row-major).
    pub fn snapshot_u8(&self) -> Vec<u8> {
        self.decoded.clone()
    }

    /// Simultaneous mutable access to the 5-bit words, the decoded 8-bit
    /// mirror and the row width — the vectorized error-free patch path
    /// ([`super::pipeline::process_event`]) updates the mirror with the
    /// shared SIMD kernel and then resyncs the words. Callers must keep
    /// the two views consistent (`words[i] == decoded[i] & 0x1F` for every
    /// touched pixel, i.e. [`crate::tos::encoding::store`]).
    #[inline]
    pub fn split_mut(&mut self) -> (&mut [u8], &mut [u8], usize) {
        (&mut self.words, &mut self.decoded, self.width)
    }

    /// Erase all cells.
    pub fn clear(&mut self) {
        self.words.fill(0);
        self.decoded.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn davis240_needs_two_blocks() {
        let g = BlockGrid::for_resolution(Resolution::DAVIS240);
        assert_eq!(g.block_count(), 2);
        assert_eq!((g.blocks_x, g.blocks_y), (2, 1));
    }

    #[test]
    fn davis346_and_hd720_tiling() {
        let g = BlockGrid::for_resolution(Resolution::DAVIS346);
        assert_eq!((g.blocks_x, g.blocks_y), (3, 2));
        let g = BlockGrid::for_resolution(Resolution::HD720);
        assert_eq!((g.blocks_x, g.blocks_y), (11, 4));
        assert_eq!(g.block_count(), 44);
    }

    #[test]
    fn addr_mapping_matches_paper_block_shape() {
        let g = BlockGrid::for_resolution(Resolution::DAVIS240);
        let a = g.addr(0, 0);
        assert_eq!(a, CellAddr { block: 0, row: 0, word: 0 });
        let a = g.addr(119, 179);
        assert_eq!(a, CellAddr { block: 0, row: 179, word: 119 });
        let a = g.addr(120, 0);
        assert_eq!(a, CellAddr { block: 1, row: 0, word: 0 });
        let a = g.addr(239, 179);
        assert_eq!(a, CellAddr { block: 1, row: 179, word: 119 });
    }

    #[test]
    fn block_bits_match_fig3() {
        // one block: 180 rows x 600 columns of cells
        let g = BlockGrid::for_resolution(Resolution::new(120, 180));
        assert_eq!(g.block_count(), 1);
        assert_eq!(g.total_bits(), 180 * 600);
    }

    #[test]
    fn read_write_roundtrip() {
        let mut a = TypeAArray::new(Resolution::TEST64);
        a.write(3, 4, 0x1F);
        a.write(63, 63, 0x01);
        assert_eq!(a.read(3, 4), 0x1F);
        assert_eq!(a.read(63, 63), 0x01);
        assert_eq!(a.read(0, 0), 0);
    }

    #[test]
    fn snapshot_decodes_5bit_values() {
        let mut a = TypeAArray::new(Resolution::TEST64);
        a.write(1, 1, crate::tos::encoding::store(255));
        a.write(2, 2, crate::tos::encoding::store(230));
        let img = a.snapshot_u8();
        assert_eq!(img[1 * 64 + 1], 255);
        assert_eq!(img[2 * 64 + 2], 230);
        assert_eq!(img[0], 0);
        // the borrowed view and the owned snapshot are the same image,
        // and overwriting a cell keeps the mirror in sync
        assert_eq!(a.decoded(), &img[..]);
        a.write(1, 1, 0);
        assert_eq!(a.decoded()[64 + 1], 0);
        assert_eq!(a.snapshot_u8()[64 + 1], 0);
    }

    #[test]
    fn clear_erases() {
        let mut a = TypeAArray::new(Resolution::TEST64);
        a.write(5, 5, 7);
        a.clear();
        assert_eq!(a.read(5, 5), 0);
    }
}
