//! Simplified Minus-One Logic (MOL) — paper Fig. 5.
//!
//! The MO module adds the constant −1 (all-ones in two's complement) to the
//! 5-bit word read out of the type-A array.  Because the addend is fixed,
//! the 28T full adder collapses to a borrow-ripple of inverter + AND gates
//! (the truth table of Fig. 5(c)); this module models it *gate by gate* so
//! the test suite can check the simplification against plain arithmetic,
//! and so the logic-depth accounting used in DESIGN.md §Perf is grounded.

use super::calib::BITS_PER_WORD;

/// Result of the minus-one stage for one word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MolOutput {
    /// The 5-bit difference `A - 1` (wraps if A == 0, as hardware does).
    pub sum: u8,
    /// Carry-out of the MSB position: 1 unless the input was 0
    /// (i.e. borrow did not propagate past the MSB).
    pub cout: bool,
}

/// Gate-level simplified minus-one over a 5-bit word.
///
/// Per bit *i* (with `b0 = 1` the initial borrow):
/// `s_i = a_i XNOR b_i` is what a full adder with addend-bit 1 degenerates
/// to: `s_i = a_i XOR 1 XOR c_i`; the carry chain `c_{i+1} = a_i OR
/// (1 AND c_i)`… with all addend bits 1, `c_{i+1} = a_i | c_i`? — no:
/// `c_{i+1} = majority(a_i, 1, c_i) = a_i | c_i`. Starting carry c_0 = 0
/// for A + 0b11111: s_i = a_i ^ 1 ^ c_i, c_{i+1} = a_i | c_i.
pub fn minus_one_gate(a: u8) -> MolOutput {
    debug_assert!(a < (1 << BITS_PER_WORD));
    let mut carry = false; // c_0
    let mut sum = 0u8;
    for i in 0..BITS_PER_WORD {
        let ai = (a >> i) & 1 == 1;
        // full adder with constant addend bit 1:
        let s = ai ^ true ^ carry;
        let c_next = ai || carry; // maj(ai, 1, carry)
        if s {
            sum |= 1 << i;
        }
        carry = c_next;
    }
    MolOutput { sum, cout: carry }
}

/// Logic depth (in gate stages) of the simplified MOL ripple — used by the
/// perf model to justify the MO-phase share relative to a 28T-FA ripple.
pub const MOL_DEPTH_GATES: usize = BITS_PER_WORD; // one OR per bit on the carry path

/// Logic depth of the conventional 28T full-adder ripple it replaces
/// (two gate stages per bit on the carry path).
pub const FA28T_DEPTH_GATES: usize = 2 * BITS_PER_WORD;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_arithmetic_for_all_words() {
        for a in 0u8..(1 << BITS_PER_WORD) {
            let out = minus_one_gate(a);
            let want = a.wrapping_sub(1) & 0x1F;
            assert_eq!(out.sum, want, "a={a}");
            // carry-out is 1 iff no borrow past MSB, i.e. a != 0
            assert_eq!(out.cout, a != 0, "a={a}");
        }
    }

    #[test]
    fn zero_wraps_like_hardware() {
        let out = minus_one_gate(0);
        assert_eq!(out.sum, 0x1F);
        assert!(!out.cout);
    }

    #[test]
    fn simplification_halves_carry_depth() {
        assert!(MOL_DEPTH_GATES * 2 == FA28T_DEPTH_GATES);
    }
}
