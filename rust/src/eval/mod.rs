//! Detection-quality evaluation: precision-recall curves and AUC over
//! threshold sweeps (paper Sec. V-C / Fig. 11(d,e), following luvHarris).
//!
//! Input: per-event `(score, is_true_corner)` pairs — the detector's
//! continuous score and the ground-truth label.  Sweeping a threshold over
//! the score produces the PR curve; the area under it (trapezoid over
//! recall) is the headline AUC metric whose degradation under BER the
//! paper reports.
//!
//! The pairs can come from a finished
//! [`RunReport::scored_events`](crate::coordinator::RunReport::scored_events)
//! (needs `record_per_event`, O(stream) event+score vectors) or be
//! labelled on the fly by a [`ScoredSink`] attached to
//! [`run_stream_with`](crate::coordinator::Pipeline::run_stream_with) —
//! the evaluation path for streamed runs, which keeps only the
//! `(score, label)` pairs themselves.

use anyhow::Result;

use crate::coordinator::sink::{Corner, CornerSink};
use crate::datasets::gt::GroundTruth;
use crate::events::Event;

/// A [`CornerSink`] that labels every scored signal event against
/// ground truth as it streams past, accumulating the `(score, label)`
/// pairs [`PrCurve::from_scores`] consumes — AUC without a recorded
/// [`RunReport`](crate::coordinator::RunReport).
///
/// Labelling order and values are identical to
/// [`RunReport::scored_events`](crate::coordinator::RunReport::scored_events)
/// on the same run, so both evaluation paths produce the same curve.
#[derive(Debug)]
pub struct ScoredSink<'a> {
    gt: &'a GroundTruth,
    radius_px: f32,
    /// Accumulated `(score, is_true_corner)` pairs, in stream order.
    pub scored: Vec<(f64, bool)>,
}

impl<'a> ScoredSink<'a> {
    /// Label against `gt` with the paper's match radius (px).
    pub fn new(gt: &'a GroundTruth, radius_px: f32) -> Self {
        Self { gt, radius_px, scored: Vec::new() }
    }

    /// The PR curve of everything scored so far.
    pub fn curve(&self, n_thresholds: usize) -> PrCurve {
        PrCurve::from_scores(&self.scored, n_thresholds)
    }
}

impl CornerSink for ScoredSink<'_> {
    fn on_corner(&mut self, _corner: &Corner) -> Result<()> {
        Ok(()) // the per-score callback below already saw this event
    }

    fn on_score(&mut self, _seq: u64, ev: &Event, score: f64) -> Result<()> {
        let label = self.gt.near_corner(ev.x as f32, ev.y as f32, ev.t, self.radius_px);
        self.scored.push((score, label));
        Ok(())
    }
}

/// One point of a PR curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrPoint {
    /// Score threshold that produced this point.
    pub threshold: f64,
    /// Precision TP/(TP+FP); 1.0 when nothing is detected.
    pub precision: f64,
    /// Recall TP/(TP+FN).
    pub recall: f64,
    /// True/false positives and false negatives at this threshold.
    pub tp: u64,
    /// False positives.
    pub fp: u64,
    /// False negatives.
    pub fn_: u64,
}

/// A full precision-recall curve (thresholds descending, recall ascending).
#[derive(Debug, Clone, Default)]
pub struct PrCurve {
    /// Curve points.
    pub points: Vec<PrPoint>,
}

impl PrCurve {
    /// Build a PR curve from `(score, label)` pairs by sweeping
    /// `n_thresholds` equally spaced quantiles of the score distribution.
    pub fn from_scores(scored: &[(f64, bool)], n_thresholds: usize) -> PrCurve {
        assert!(n_thresholds >= 2);
        if scored.is_empty() {
            return PrCurve::default();
        }
        let positives = scored.iter().filter(|(_, l)| *l).count() as u64;
        // sort scores descending once; sweep thresholds down the sorted list
        let mut sorted: Vec<(f64, bool)> = scored.to_vec();
        sorted.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        let lo = sorted.last().unwrap().0;
        let hi = sorted[0].0;
        let mut points = Vec::with_capacity(n_thresholds);
        let mut idx = 0usize;
        let mut tp = 0u64;
        let mut fp = 0u64;
        for k in 0..n_thresholds {
            // thresholds from hi down to lo inclusive
            let th = hi - (hi - lo) * k as f64 / (n_thresholds - 1) as f64;
            while idx < sorted.len() && sorted[idx].0 >= th {
                if sorted[idx].1 {
                    tp += 1;
                } else {
                    fp += 1;
                }
                idx += 1;
            }
            let det = tp + fp;
            let precision = if det == 0 { 1.0 } else { tp as f64 / det as f64 };
            let recall = if positives == 0 { 0.0 } else { tp as f64 / positives as f64 };
            points.push(PrPoint { threshold: th, precision, recall, tp, fp, fn_: positives - tp });
        }
        PrCurve { points }
    }

    /// Area under the PR curve (trapezoid over recall).
    pub fn auc(&self) -> f64 {
        if self.points.len() < 2 {
            return 0.0;
        }
        let mut area = 0.0;
        for w in self.points.windows(2) {
            let dr = w[1].recall - w[0].recall;
            area += dr * 0.5 * (w[0].precision + w[1].precision);
        }
        area
    }

    /// Best F1 over the curve (secondary metric for the ablations).
    pub fn best_f1(&self) -> f64 {
        self.points
            .iter()
            .map(|p| {
                if p.precision + p.recall == 0.0 {
                    0.0
                } else {
                    2.0 * p.precision * p.recall / (p.precision + p.recall)
                }
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_classifier_auc_one() {
        let mut scored = Vec::new();
        for i in 0..500 {
            scored.push((1.0 + i as f64 * 1e-3, true));
            scored.push((-1.0 - i as f64 * 1e-3, false));
        }
        let curve = PrCurve::from_scores(&scored, 101);
        assert!(curve.auc() > 0.99, "auc {}", curve.auc());
        assert!(curve.best_f1() > 0.99);
    }

    #[test]
    fn random_classifier_auc_near_base_rate() {
        let mut rng = Rng::seed_from(1);
        let base = 0.2;
        let scored: Vec<(f64, bool)> =
            (0..20_000).map(|_| (rng.f64(), rng.chance(base))).collect();
        let auc = PrCurve::from_scores(&scored, 101).auc();
        assert!((auc - base).abs() < 0.05, "auc {auc}");
    }

    #[test]
    fn recall_monotone_as_threshold_drops() {
        let mut rng = Rng::seed_from(2);
        let scored: Vec<(f64, bool)> =
            (0..5_000).map(|_| (rng.f64(), rng.chance(0.3))).collect();
        let curve = PrCurve::from_scores(&scored, 51);
        for w in curve.points.windows(2) {
            assert!(w[1].recall >= w[0].recall - 1e-12);
            assert!(w[1].threshold <= w[0].threshold);
        }
        // final point captures everything
        let last = curve.points.last().unwrap();
        assert!((last.recall - 1.0).abs() < 1e-9);
    }

    #[test]
    fn degraded_scores_lower_auc() {
        // a noisy version of a good classifier must not beat the original
        let mut rng = Rng::seed_from(3);
        let mut clean = Vec::new();
        for _ in 0..5000 {
            let label = rng.chance(0.3);
            let score = if label { rng.normal(1.0, 0.5) } else { rng.normal(-1.0, 0.5) };
            clean.push((score, label));
        }
        let noisy: Vec<(f64, bool)> =
            clean.iter().map(|&(s, l)| (s + rng.normal(0.0, 2.0), l)).collect();
        let a_clean = PrCurve::from_scores(&clean, 101).auc();
        let a_noisy = PrCurve::from_scores(&noisy, 101).auc();
        assert!(a_clean > a_noisy + 0.05, "clean {a_clean} noisy {a_noisy}");
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(PrCurve::from_scores(&[], 11).points.len(), 0);
        let all_same = vec![(0.5, true), (0.5, false)];
        let c = PrCurve::from_scores(&all_same, 11);
        assert!(!c.points.is_empty());
        assert!(c.auc().is_finite());
    }

    #[test]
    fn scored_sink_matches_report_scored_events() {
        // the streamed evaluation path must label exactly like the
        // RunReport one: same pairs, same order, same AUC
        use crate::coordinator::{DetectorKind, Pipeline, PipelineConfig};
        use crate::datasets::synthetic::SceneConfig;

        let mut scene = SceneConfig::test64().build(31);
        let (events, gt) = scene.generate_with_gt(6_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;

        let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
        let report = pipe.run(&events).unwrap();
        let want = report.scored_events(&gt, 3.0);

        cfg.record_per_event = false; // the sink path needs no vectors
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut sink = ScoredSink::new(&gt, 3.0);
        let lean = pipe.run_with(&events, &mut sink).unwrap();
        assert!(lean.signal_events.is_empty());
        assert_eq!(sink.scored, want);
        let a = PrCurve::from_scores(&want, 51).auc();
        let b = sink.curve(51).auc();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn counts_are_consistent() {
        let scored =
            vec![(0.9, true), (0.8, false), (0.7, true), (0.2, false), (0.1, true)];
        let curve = PrCurve::from_scores(&scored, 21);
        for p in &curve.points {
            assert_eq!(p.tp + p.fn_, 3, "positives preserved");
            assert!(p.tp + p.fp <= 5);
        }
    }
}
