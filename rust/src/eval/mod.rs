//! Detection-quality evaluation: precision-recall curves and AUC over
//! threshold sweeps (paper Sec. V-C / Fig. 11(d,e), following luvHarris).
//!
//! Input: per-event `(score, is_true_corner)` pairs — the detector's
//! continuous score and the ground-truth label.  Sweeping a threshold over
//! the score produces the PR curve; the area under it (trapezoid over
//! recall) is the headline AUC metric whose degradation under BER the
//! paper reports.
//!
//! The pairs can come from a finished
//! [`RunReport::scored_events`](crate::coordinator::RunReport::scored_events)
//! (needs `record_per_event`, O(stream) event+score vectors) or be
//! labelled on the fly by a [`ScoredSink`] attached to
//! [`run_stream_with`](crate::coordinator::Pipeline::run_stream_with) —
//! the evaluation path for streamed runs, which keeps only the
//! `(score, label)` pairs themselves.
//!
//! Two harnesses compose this machinery into end-to-end experiments:
//!
//! * [`run_vdd_sweep`] — voltage-fault fidelity: detector quality as a
//!   function of supply voltage with the seeded fault injector live in
//!   the TOS hot path (`nmc-tos vdd-sweep`).
//! * [`run_dataset_eval`] — real-recording quality: every manifest
//!   dataset streamed through the sniffing decoders
//!   (AEDAT4/EVT2/EVT3/binary/text) and scored against file-backed
//!   corner labels (`nmc-tos dataset-eval`).

// This module writes the byte-identical reports, so it carries the
// promoted `clippy::pedantic` tier (ISSUE 10). Every allow below is a
// deliberate opt-out with a reason, not a deferral; the `-D warnings`
// clippy lane keeps the remainder at zero.
#![warn(clippy::pedantic)]
#![allow(
    // counter-to-ratio math casts u64 tallies into f64 on purpose; the
    // counts are far below 2^52, so the casts are value-preserving
    clippy::cast_precision_loss,
    // threshold sweeps index by `(frac * n) as usize` on values already
    // clamped to range — truncation is the intended floor()
    clippy::cast_possible_truncation,
    clippy::cast_sign_loss,
    // u16 coordinates widen with `as` to match the surrounding kernel
    // idiom (`from` would be noise in arithmetic expressions)
    clippy::cast_lossless,
    // the crate documents error/panic contracts at the type level
    // (anyhow::Result + missing_docs); per-fn `# Errors` sections would
    // duplicate the rustdoc one line down
    clippy::missing_errors_doc,
    clippy::missing_panics_doc,
    // builder-style config constructors are used for their side effect
    // of being assigned; a must_use attribute adds nothing
    clippy::must_use_candidate,
    // `PrCurve`/`PrPoint` etc. deliberately repeat the module stem —
    // they are re-exported from the crate root where the stem is needed
    clippy::module_name_repetitions,
    // prose rustdoc mentions identifiers (luvHarris, Vdd) that are not
    // code items; backticking them all hurts readability
    clippy::doc_markdown,
    // `use super::*` in the trailing test module is the repo-wide idiom
    clippy::wildcard_imports,
    // sweep loops use (p, r, t) in tight numeric code on purpose
    clippy::many_single_char_names,
    clippy::similar_names,
    // long-but-linear experiment harnesses read top-to-bottom; splitting
    // them hides the protocol order the docs describe
    clippy::too_many_lines,
    // trailing-unit style: stylistic, and inconsistent with the
    // surrounding early-return error idiom
    clippy::semicolon_if_nothing_returned,
    clippy::uninlined_format_args,
    clippy::items_after_statements,
    clippy::unreadable_literal,
    clippy::match_same_arms,
    clippy::single_match_else,
    clippy::if_not_else,
    clippy::redundant_closure_for_method_calls,
    clippy::map_unwrap_or,
    clippy::explicit_iter_loop,
    clippy::needless_pass_by_value,
    clippy::return_self_not_must_use,
    clippy::range_plus_one,
    clippy::manual_let_else,
    clippy::ignored_unit_patterns,
    clippy::struct_field_names,
    clippy::float_cmp
)]

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::sink::{Corner, CornerSink};
use crate::coordinator::{BackendKind, DetectorKind, Pipeline, PipelineConfig};
use crate::datasets::gt::{CornerOracle, GroundTruth};
use crate::datasets::public::{CornerLabels, Manifest};
use crate::datasets::scenarios::{Scenario, ScenarioGrid};
use crate::events::source::{self, TakeSource, DEFAULT_CHUNK_EVENTS};
use crate::events::{Event, Resolution};
use crate::nmc::calib;
use crate::util::json::Json;

/// A [`CornerSink`] that labels every scored signal event against
/// ground truth as it streams past, accumulating the `(score, label)`
/// pairs [`PrCurve::from_scores`] consumes — AUC without a recorded
/// [`RunReport`](crate::coordinator::RunReport).
///
/// Labelling order and values are identical to
/// [`RunReport::scored_events`](crate::coordinator::RunReport::scored_events)
/// on the same run, so both evaluation paths produce the same curve.
///
/// Generic over the [`CornerOracle`] supplying labels: the synthetic
/// scenes' exact [`GroundTruth`] (the default, so existing call sites
/// read unchanged) or the file-backed
/// [`CornerLabels`](crate::datasets::public::CornerLabels) of a real
/// recording.
#[derive(Debug)]
pub struct ScoredSink<'a, O: CornerOracle + ?Sized = GroundTruth> {
    gt: &'a O,
    radius_px: f32,
    /// Accumulated `(score, is_true_corner)` pairs, in stream order.
    pub scored: Vec<(f64, bool)>,
}

impl<'a, O: CornerOracle + ?Sized> ScoredSink<'a, O> {
    /// Label against `gt` with the paper's match radius (px).
    pub fn new(gt: &'a O, radius_px: f32) -> Self {
        Self { gt, radius_px, scored: Vec::new() }
    }

    /// The PR curve of everything scored so far.
    pub fn curve(&self, n_thresholds: usize) -> PrCurve {
        PrCurve::from_scores(&self.scored, n_thresholds)
    }
}

impl<O: CornerOracle + ?Sized> CornerSink for ScoredSink<'_, O> {
    fn on_corner(&mut self, _corner: &Corner) -> Result<()> {
        Ok(()) // the per-score callback below already saw this event
    }

    fn on_score(&mut self, _seq: u64, ev: &Event, score: f64) -> Result<()> {
        let label = self.gt.is_corner(ev.x as f32, ev.y as f32, ev.t, self.radius_px);
        self.scored.push((score, label));
        Ok(())
    }
}

/// One point of a PR curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrPoint {
    /// Score threshold that produced this point.
    pub threshold: f64,
    /// Precision TP/(TP+FP); 1.0 when nothing is detected.
    pub precision: f64,
    /// Recall TP/(TP+FN).
    pub recall: f64,
    /// True/false positives and false negatives at this threshold.
    pub tp: u64,
    /// False positives.
    pub fp: u64,
    /// False negatives.
    pub fn_: u64,
}

/// A full precision-recall curve (thresholds descending, recall ascending).
#[derive(Debug, Clone, Default)]
pub struct PrCurve {
    /// Curve points.
    pub points: Vec<PrPoint>,
}

impl PrCurve {
    /// Build a PR curve from `(score, label)` pairs by sweeping
    /// `n_thresholds` equally spaced quantiles of the score distribution.
    pub fn from_scores(scored: &[(f64, bool)], n_thresholds: usize) -> PrCurve {
        assert!(n_thresholds >= 2);
        if scored.is_empty() {
            return PrCurve::default();
        }
        let positives = scored.iter().filter(|(_, l)| *l).count() as u64;
        // sort scores descending once; sweep thresholds down the sorted list
        let mut sorted: Vec<(f64, bool)> = scored.to_vec();
        sorted.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        let lo = sorted.last().unwrap().0;
        let hi = sorted[0].0;
        let mut points = Vec::with_capacity(n_thresholds);
        let mut idx = 0usize;
        let mut tp = 0u64;
        let mut fp = 0u64;
        for k in 0..n_thresholds {
            // thresholds from hi down to lo inclusive
            let th = hi - (hi - lo) * k as f64 / (n_thresholds - 1) as f64;
            while idx < sorted.len() && sorted[idx].0 >= th {
                if sorted[idx].1 {
                    tp += 1;
                } else {
                    fp += 1;
                }
                idx += 1;
            }
            let det = tp + fp;
            let precision = if det == 0 { 1.0 } else { tp as f64 / det as f64 };
            let recall = if positives == 0 { 0.0 } else { tp as f64 / positives as f64 };
            points.push(PrPoint { threshold: th, precision, recall, tp, fp, fn_: positives - tp });
        }
        PrCurve { points }
    }

    /// Area under the PR curve (trapezoid over recall).
    pub fn auc(&self) -> f64 {
        if self.points.len() < 2 {
            return 0.0;
        }
        let mut area = 0.0;
        for w in self.points.windows(2) {
            let dr = w[1].recall - w[0].recall;
            area += dr * 0.5 * (w[0].precision + w[1].precision);
        }
        area
    }

    /// Best F1 over the curve (secondary metric for the ablations).
    pub fn best_f1(&self) -> f64 {
        self.points
            .iter()
            .map(|p| {
                if p.precision + p.recall == 0.0 {
                    0.0
                } else {
                    2.0 * p.precision * p.recall / (p.precision + p.recall)
                }
            })
            .fold(0.0, f64::max)
    }
}

// ---------------------------------------------------------------------------
// Voltage-fault fidelity sweep (`nmc-tos vdd-sweep`)

/// Configuration of one [`run_vdd_sweep`] experiment.
///
/// The scenario list usually comes from a [`ScenarioGrid`]; scenarios
/// sharing a [`Scenario::key`] reuse one generated event stream, so the
/// voltage axis varies *only* the fault map — any quality delta between
/// two points of a key is attributable to injected read faults alone.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Grid points to run (see [`ScenarioGrid::enumerate`]).
    pub scenarios: Vec<Scenario>,
    /// TOS backends to run every scenario under. Only the NMC macro
    /// models voltage faults; software backends report zero-fault points
    /// and serve as the error-free reference row.
    pub backends: Vec<BackendKind>,
    /// Detector scoring the events.
    pub detector: DetectorKind,
    /// Events generated per scenario key.
    pub events: usize,
    /// Scene-generation seed (shared by every key).
    pub scene_seed: u64,
    /// Fault-map seed handed to the injector ([`PipelineConfig::seed`]).
    pub fault_seed: u64,
    /// Ground-truth corner match radius (px).
    pub radius_px: f32,
    /// PR-curve threshold count.
    pub thresholds: usize,
}

impl SweepConfig {
    /// The paper-shaped sweep: `shapes_dof`-like DAVIS240 scene, NMC
    /// backend, luvHarris detector, the five-voltage fault ladder.
    pub fn paper() -> Self {
        Self {
            scenarios: ScenarioGrid::paper().enumerate(),
            backends: vec![BackendKind::Nmc],
            detector: DetectorKind::Harris,
            events: 400_000,
            scene_seed: 42,
            fault_seed: 7,
            radius_px: 3.5,
            thresholds: 101,
        }
    }

    /// CI smoke sweep: one small scene, four voltages around the BER
    /// knee, few enough events for a per-push lane.
    pub fn smoke() -> Self {
        Self {
            scenarios: ScenarioGrid::smoke().enumerate(),
            backends: vec![BackendKind::Nmc],
            detector: DetectorKind::Harris,
            events: 40_000,
            scene_seed: 42,
            fault_seed: 7,
            radius_px: 4.0,
            thresholds: 101,
        }
    }
}

/// One (scenario, backend, voltage) measurement of a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Scenario label including the voltage ([`Scenario::label`]).
    pub scenario: String,
    /// Scene key ([`Scenario::key`]) — the group sharing an event stream.
    pub key: String,
    /// Backend name the point ran under.
    pub backend: &'static str,
    /// Supply voltage (V).
    pub vdd: f64,
    /// Unclamped model bit-error probability at `vdd`
    /// ([`calib::bit_error_probability`]).
    pub model_ber: f64,
    /// Per-bit fault probability actually injected (0.0 under the
    /// Monte-Carlo floor — the published-zero voltages).
    pub injected_p_bit: f64,
    /// Distinct faulty cells the run touched.
    pub faulty_cells: u64,
    /// Bits observed flipped across all reads.
    pub flipped_bits: u64,
    /// Word reads performed.
    pub word_reads: u64,
    /// Measured read error rate: `flipped_bits / word_reads`.
    pub read_error_rate: f64,
    /// PR-AUC against exact corner ground truth.
    pub auc: f64,
    /// AUC minus the same (key, backend) group's highest-voltage AUC —
    /// the paper's dAUC metric.
    pub auc_delta: f64,
    /// Corners tagged.
    pub corners: u64,
    /// Events surviving STCF.
    pub events_signal: u64,
}

/// A finished sweep: points in scenario-list x backend order.
///
/// Everything in the report derives from seeds, event content and model
/// equations — no wall clock, no host state — so rendering
/// [`SweepReport::to_json`] for the same [`SweepConfig`] is
/// byte-identical across runs, machines and backends.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Detector name.
    pub detector: &'static str,
    /// Events generated per scenario key.
    pub events_per_scene: usize,
    /// Scene-generation seed.
    pub scene_seed: u64,
    /// Fault-map seed.
    pub fault_seed: u64,
    /// Per-(key, backend) baseline AUC (the group's highest-voltage
    /// point), keyed `"<key>/<backend>"`.
    pub baselines: BTreeMap<String, f64>,
    /// All measurements.
    pub points: Vec<SweepPoint>,
}

impl SweepReport {
    /// Render the machine-readable report (deterministic key order and
    /// float formatting — byte-identical for identical configs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("harness", Json::Str("vdd-sweep".into())),
            ("detector", Json::Str(self.detector.into())),
            ("events_per_scene", Json::Num(self.events_per_scene as f64)),
            ("scene_seed", Json::Num(self.scene_seed as f64)),
            ("fault_seed", Json::Num(self.fault_seed as f64)),
            (
                "baselines",
                Json::Obj(
                    self.baselines
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v)))
                        .collect(),
                ),
            ),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("scenario", Json::Str(p.scenario.clone())),
                                ("key", Json::Str(p.key.clone())),
                                ("backend", Json::Str(p.backend.into())),
                                ("vdd", Json::Num(p.vdd)),
                                ("model_ber", Json::Num(p.model_ber)),
                                ("injected_p_bit", Json::Num(p.injected_p_bit)),
                                ("faulty_cells", Json::Num(p.faulty_cells as f64)),
                                ("flipped_bits", Json::Num(p.flipped_bits as f64)),
                                ("word_reads", Json::Num(p.word_reads as f64)),
                                ("read_error_rate", Json::Num(p.read_error_rate)),
                                ("auc", Json::Num(p.auc)),
                                ("auc_delta", Json::Num(p.auc_delta)),
                                ("corners", Json::Num(p.corners as f64)),
                                ("events_signal", Json::Num(p.events_signal as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the voltage-fault fidelity sweep: every (scenario, backend) pair
/// through the full pipeline — STCF, fault-injecting TOS backend pinned
/// at the scenario's Vdd, software-FBF Harris refresh, per-event scoring
/// against exact ground truth — reporting BER observables and PR-AUC per
/// point (the Sec. V-C / Fig. 11 reproduction, generalized to a grid).
pub fn run_vdd_sweep(cfg: &SweepConfig) -> Result<SweepReport> {
    anyhow::ensure!(!cfg.scenarios.is_empty(), "vdd sweep needs at least one scenario");
    anyhow::ensure!(!cfg.backends.is_empty(), "vdd sweep needs at least one backend");
    // one generated stream per scenario key, shared across its voltages
    let mut streams: BTreeMap<String, (Vec<Event>, GroundTruth)> = BTreeMap::new();
    let mut points = Vec::with_capacity(cfg.scenarios.len() * cfg.backends.len());
    let mut detector_name = "";
    for scenario in &cfg.scenarios {
        if !streams.contains_key(&scenario.key) {
            let (events, gt) = scenario.build(cfg.scene_seed).generate_with_gt(cfg.events);
            streams.insert(scenario.key.clone(), (events, gt));
        }
        let (events, gt) = &streams[&scenario.key];
        for &backend in &cfg.backends {
            let mut pcfg = if scenario.scene.res == Resolution::TEST64 {
                PipelineConfig::test64()
            } else {
                PipelineConfig::davis240()
            };
            pcfg.res = scenario.scene.res;
            pcfg.backend = backend;
            pcfg.detector = cfg.detector;
            pcfg.dvfs = None; // the voltage axis is the experiment
            pcfg.fixed_vdd = scenario.vdd;
            pcfg.inject_errors = true;
            pcfg.seed = cfg.fault_seed;
            pcfg.record_per_event = false;
            pcfg.software_fbf = true; // engine-less FBF keeps the sweep hermetic
            let mut pipe = Pipeline::from_config_without_engine(pcfg)?;
            let mut sink = ScoredSink::new(gt, cfg.radius_px);
            let report = pipe.run_with(events, &mut sink)?;
            detector_name = report.detector_name;
            let faults = report.backend.faults;
            let (injected_p_bit, faulty_cells, flipped_bits, word_reads) = match faults {
                Some(f) => (f.p_bit, f.faulty_cells, f.flipped_bits, f.word_reads),
                None => (0.0, 0, 0, 0),
            };
            points.push(SweepPoint {
                scenario: scenario.label(),
                key: scenario.key.clone(),
                backend: report.backend_name,
                vdd: scenario.vdd,
                model_ber: calib::bit_error_probability(scenario.vdd),
                injected_p_bit,
                faulty_cells,
                flipped_bits,
                word_reads,
                read_error_rate: flipped_bits as f64 / word_reads.max(1) as f64,
                auc: sink.curve(cfg.thresholds).auc(),
                auc_delta: 0.0, // filled against the group baseline below
                corners: report.corners_total as u64,
                events_signal: report.events_signal as u64,
            });
        }
    }
    // baseline = each (key, backend) group's highest-voltage point
    let mut baselines: BTreeMap<String, f64> = BTreeMap::new();
    for p in &points {
        let group = format!("{}/{}", p.key, p.backend);
        let slot = baselines.entry(group).or_insert(f64::NEG_INFINITY);
        let best_vdd = points
            .iter()
            .filter(|q| q.key == p.key && q.backend == p.backend)
            .map(|q| q.vdd)
            .fold(f64::NEG_INFINITY, f64::max);
        if (p.vdd - best_vdd).abs() < 1e-12 {
            *slot = p.auc;
        }
    }
    for p in &mut points {
        p.auc_delta = p.auc - baselines[&format!("{}/{}", p.key, p.backend)];
    }
    Ok(SweepReport {
        detector: detector_name,
        events_per_scene: cfg.events,
        scene_seed: cfg.scene_seed,
        fault_seed: cfg.fault_seed,
        baselines,
        points,
    })
}

// ---------------------------------------------------------------------------
// Public-dataset AUC harness (`nmc-tos dataset-eval`)

/// Configuration of one [`run_dataset_eval`] experiment: which manifest
/// to read and which detector x backend grid to score every declared
/// recording under.
#[derive(Debug, Clone)]
pub struct DatasetEvalConfig {
    /// Dataset manifest path (see
    /// [`Manifest`](crate::datasets::public::Manifest) for the format).
    pub manifest: PathBuf,
    /// Backends to run every dataset under.
    pub backends: Vec<BackendKind>,
    /// Detectors to run every dataset under.
    pub detectors: Vec<DetectorKind>,
    /// Corner-label match radius (px).
    pub radius_px: f32,
    /// PR-curve threshold count.
    pub thresholds: usize,
    /// Streaming chunk size fed to the format decoders.
    pub chunk_events: usize,
    /// Optional cap on events read per recording (`None` = whole file).
    pub max_events: Option<usize>,
    /// Harris LUT refresh period (signal events) for the software-FBF
    /// pipeline the harness runs.
    pub lut_refresh_events: usize,
}

impl DatasetEvalConfig {
    /// The full evaluation: NMC backend, luvHarris detector, whole
    /// recordings, paper match radius.
    pub fn new(manifest: impl Into<PathBuf>) -> Self {
        Self {
            manifest: manifest.into(),
            backends: vec![BackendKind::Nmc],
            detectors: vec![DetectorKind::Harris],
            radius_px: 3.5,
            thresholds: 101,
            chunk_events: DEFAULT_CHUNK_EVENTS,
            max_events: None,
            lut_refresh_events: 2_000,
        }
    }

    /// CI smoke preset: two backends x two detectors, small chunks (so
    /// the streamed decoders refill repeatedly even on tiny fixtures), a
    /// hard event cap, and a fast LUT refresh.
    pub fn smoke(manifest: impl Into<PathBuf>) -> Self {
        Self {
            manifest: manifest.into(),
            backends: vec![BackendKind::Golden, BackendKind::Nmc],
            detectors: vec![DetectorKind::Harris, DetectorKind::Fast],
            radius_px: 4.0,
            thresholds: 101,
            chunk_events: 4096,
            max_events: Some(50_000),
            lut_refresh_events: 500,
        }
    }
}

/// One (dataset, backend, detector) measurement.
#[derive(Debug, Clone)]
pub struct DatasetEvalPoint {
    /// Dataset name from the manifest.
    pub dataset: String,
    /// Backend name the point ran under.
    pub backend: &'static str,
    /// Detector name the point ran under.
    pub detector: &'static str,
    /// Events decoded from the recording (post `max_events` cap).
    pub events_in: u64,
    /// Events surviving STCF.
    pub events_signal: u64,
    /// Corners tagged.
    pub corners: u64,
    /// `(score, label)` pairs accumulated (== `events_signal`).
    pub scored: u64,
    /// Pairs labelled true-corner by the ground-truth oracle.
    pub positives: u64,
    /// PR-AUC against the file-backed labels.
    pub auc: f64,
    /// Best F1 over the same curve.
    pub best_f1: f64,
}

/// A finished dataset evaluation: points in dataset x backend x detector
/// order (datasets already name-sorted by the manifest parser).
///
/// Like [`SweepReport`], everything derives from file content and
/// configuration — no wall clock, no host state — so
/// [`DatasetEvalReport::to_json`] renders byte-identically across repeat
/// runs of the same config (the CI `dataset-smoke` lane `cmp`s two runs).
#[derive(Debug, Clone)]
pub struct DatasetEvalReport {
    /// Corner-label match radius (px).
    pub radius_px: f32,
    /// PR-curve threshold count.
    pub thresholds: usize,
    /// Event cap per recording, if any.
    pub max_events: Option<usize>,
    /// Ground-truth label count per dataset name.
    pub labels: BTreeMap<String, u64>,
    /// All measurements.
    pub points: Vec<DatasetEvalPoint>,
}

impl DatasetEvalReport {
    /// Render the machine-readable report (deterministic key order and
    /// float formatting — byte-identical for identical configs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("harness", Json::Str("dataset-eval".into())),
            ("radius_px", Json::Num(self.radius_px as f64)),
            ("thresholds", Json::Num(self.thresholds as f64)),
            (
                "max_events",
                match self.max_events {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ),
            (
                "labels",
                Json::Obj(
                    self.labels.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
                ),
            ),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("dataset", Json::Str(p.dataset.clone())),
                                ("backend", Json::Str(p.backend.into())),
                                ("detector", Json::Str(p.detector.into())),
                                ("events_in", Json::Num(p.events_in as f64)),
                                ("events_signal", Json::Num(p.events_signal as f64)),
                                ("corners", Json::Num(p.corners as f64)),
                                ("scored", Json::Num(p.scored as f64)),
                                ("positives", Json::Num(p.positives as f64)),
                                ("auc", Json::Num(p.auc)),
                                ("best_f1", Json::Num(p.best_f1)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Score real recordings against their corner-label sidecars: for every
/// manifest dataset x backend x detector, stream the recording through
/// the full pipeline (format sniffed by
/// [`source::open`](crate::events::source::open)) with a [`ScoredSink`]
/// labelling each surviving event against the dataset's
/// [`CornerLabels`], and report PR-AUC per point.
///
/// No faults are injected and DVFS is off: this harness measures
/// detector quality on real data, not voltage response — compose with
/// [`run_vdd_sweep`] for that axis.
pub fn run_dataset_eval(cfg: &DatasetEvalConfig) -> Result<DatasetEvalReport> {
    anyhow::ensure!(!cfg.backends.is_empty(), "dataset eval needs at least one backend");
    anyhow::ensure!(!cfg.detectors.is_empty(), "dataset eval needs at least one detector");
    let manifest = Manifest::load(&cfg.manifest)?;
    let mut labels_per_ds: BTreeMap<String, u64> = BTreeMap::new();
    let mut points = Vec::new();
    for ds in &manifest.datasets {
        ds.ensure_local()?;
        let labels = CornerLabels::load(&ds.ground_truth)?;
        anyhow::ensure!(
            !labels.is_empty(),
            "dataset {:?}: ground truth {} has no labels",
            ds.name,
            ds.ground_truth.display()
        );
        labels_per_ds.insert(ds.name.clone(), labels.len() as u64);
        for &backend in &cfg.backends {
            for &detector in &cfg.detectors {
                let mut pcfg = if ds.res == Resolution::TEST64 {
                    PipelineConfig::test64()
                } else {
                    PipelineConfig::davis240()
                };
                pcfg.res = ds.res;
                pcfg.backend = backend;
                pcfg.detector = detector;
                pcfg.dvfs = None;
                pcfg.inject_errors = false;
                pcfg.record_per_event = false;
                pcfg.software_fbf = true; // engine-less: hermetic + deterministic
                pcfg.lut_refresh_events = cfg.lut_refresh_events;
                let mut pipe = Pipeline::from_config_without_engine(pcfg)?;
                let mut sink = ScoredSink::new(&labels, cfg.radius_px);
                let mut src = source::open(&ds.recording, cfg.chunk_events)?;
                let report = match cfg.max_events {
                    Some(cap) => {
                        pipe.run_stream_with(&mut TakeSource::new(src, cap), &mut sink)?
                    }
                    None => pipe.run_stream_with(&mut src, &mut sink)?,
                };
                let positives = sink.scored.iter().filter(|(_, l)| *l).count() as u64;
                let curve = sink.curve(cfg.thresholds);
                points.push(DatasetEvalPoint {
                    dataset: ds.name.clone(),
                    backend: report.backend_name,
                    detector: report.detector_name,
                    events_in: report.events_in as u64,
                    events_signal: report.events_signal as u64,
                    corners: report.corners_total as u64,
                    scored: sink.scored.len() as u64,
                    positives,
                    auc: curve.auc(),
                    best_f1: curve.best_f1(),
                });
            }
        }
    }
    Ok(DatasetEvalReport {
        radius_px: cfg.radius_px,
        thresholds: cfg.thresholds,
        max_events: cfg.max_events,
        labels: labels_per_ds,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_classifier_auc_one() {
        let mut scored = Vec::new();
        for i in 0..500 {
            scored.push((1.0 + i as f64 * 1e-3, true));
            scored.push((-1.0 - i as f64 * 1e-3, false));
        }
        let curve = PrCurve::from_scores(&scored, 101);
        assert!(curve.auc() > 0.99, "auc {}", curve.auc());
        assert!(curve.best_f1() > 0.99);
    }

    #[test]
    fn random_classifier_auc_near_base_rate() {
        let mut rng = Rng::seed_from(1);
        let base = 0.2;
        let scored: Vec<(f64, bool)> =
            (0..20_000).map(|_| (rng.f64(), rng.chance(base))).collect();
        let auc = PrCurve::from_scores(&scored, 101).auc();
        assert!((auc - base).abs() < 0.05, "auc {auc}");
    }

    #[test]
    fn recall_monotone_as_threshold_drops() {
        let mut rng = Rng::seed_from(2);
        let scored: Vec<(f64, bool)> =
            (0..5_000).map(|_| (rng.f64(), rng.chance(0.3))).collect();
        let curve = PrCurve::from_scores(&scored, 51);
        for w in curve.points.windows(2) {
            assert!(w[1].recall >= w[0].recall - 1e-12);
            assert!(w[1].threshold <= w[0].threshold);
        }
        // final point captures everything
        let last = curve.points.last().unwrap();
        assert!((last.recall - 1.0).abs() < 1e-9);
    }

    #[test]
    fn degraded_scores_lower_auc() {
        // a noisy version of a good classifier must not beat the original
        let mut rng = Rng::seed_from(3);
        let mut clean = Vec::new();
        for _ in 0..5000 {
            let label = rng.chance(0.3);
            let score = if label { rng.normal(1.0, 0.5) } else { rng.normal(-1.0, 0.5) };
            clean.push((score, label));
        }
        let noisy: Vec<(f64, bool)> =
            clean.iter().map(|&(s, l)| (s + rng.normal(0.0, 2.0), l)).collect();
        let a_clean = PrCurve::from_scores(&clean, 101).auc();
        let a_noisy = PrCurve::from_scores(&noisy, 101).auc();
        assert!(a_clean > a_noisy + 0.05, "clean {a_clean} noisy {a_noisy}");
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(PrCurve::from_scores(&[], 11).points.len(), 0);
        let all_same = vec![(0.5, true), (0.5, false)];
        let c = PrCurve::from_scores(&all_same, 11);
        assert!(!c.points.is_empty());
        assert!(c.auc().is_finite());
    }

    #[test]
    fn scored_sink_matches_report_scored_events() {
        // the streamed evaluation path must label exactly like the
        // RunReport one: same pairs, same order, same AUC
        use crate::coordinator::{DetectorKind, Pipeline, PipelineConfig};
        use crate::datasets::synthetic::SceneConfig;

        let mut scene = SceneConfig::test64().build(31);
        let (events, gt) = scene.generate_with_gt(6_000);
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;

        let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
        let report = pipe.run(&events).unwrap();
        let want = report.scored_events(&gt, 3.0);

        cfg.record_per_event = false; // the sink path needs no vectors
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut sink = ScoredSink::new(&gt, 3.0);
        let lean = pipe.run_with(&events, &mut sink).unwrap();
        assert!(lean.signal_events.is_empty());
        assert_eq!(sink.scored, want);
        let a = PrCurve::from_scores(&want, 51).auc();
        let b = sink.curve(51).auc();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn counts_are_consistent() {
        let scored =
            vec![(0.9, true), (0.8, false), (0.7, true), (0.2, false), (0.1, true)];
        let curve = PrCurve::from_scores(&scored, 21);
        for p in &curve.points {
            assert_eq!(p.tp + p.fn_, 3, "positives preserved");
            assert!(p.tp + p.fp <= 5);
        }
    }

    /// Small-but-real smoke sweep shared by the harness tests below.
    fn tiny_sweep() -> SweepConfig {
        let mut cfg = SweepConfig::smoke();
        cfg.events = if cfg!(miri) { 1_500 } else { 25_000 };
        cfg
    }

    #[test]
    fn vdd_sweep_report_is_byte_reproducible() {
        let cfg = tiny_sweep();
        let a = run_vdd_sweep(&cfg).unwrap().to_json().render();
        let b = run_vdd_sweep(&cfg).unwrap().to_json().render();
        assert_eq!(a, b, "same config must render the same bytes");
        // the seeds are load-bearing: a different fault seed must show up
        let mut other = cfg;
        other.fault_seed += 1;
        let c = run_vdd_sweep(&other).unwrap().to_json().render();
        assert_ne!(a, c);
    }

    #[test]
    fn vdd_sweep_reproduces_the_paper_curve_shape() {
        let rep = run_vdd_sweep(&tiny_sweep()).unwrap();
        assert_eq!(rep.points.len(), 4, "smoke grid: one scene, four voltages");
        assert_eq!(rep.detector, "luvHarris-LUT");
        let base = rep.baselines["slow-nominal-noisy-64x64/nmc-tos"];
        assert!(base > 0.15, "baseline detector must actually detect (AUC {base})");
        for p in &rep.points {
            assert!(p.word_reads > 0, "{}: the hot path must count reads", p.scenario);
            if p.vdd >= 0.62 {
                // published-zero voltages: the MC floor clamps injection off
                assert_eq!(p.injected_p_bit, 0.0, "{}", p.scenario);
                assert_eq!(p.flipped_bits, 0, "{}", p.scenario);
                assert_eq!(p.faulty_cells, 0, "{}", p.scenario);
                assert_eq!(p.read_error_rate, 0.0, "{}", p.scenario);
            } else {
                // 0.61/0.60 V: small but strictly nonzero error rates
                assert!(p.injected_p_bit > 0.0, "{}", p.scenario);
                assert!(p.flipped_bits > 0, "{}", p.scenario);
                assert!(p.read_error_rate > 0.0, "{}", p.scenario);
            }
            assert!(p.model_ber > 0.0, "the unclamped model is never exactly zero");
            // bounded AUC loss, and faults never *help* beyond noise
            assert!(p.auc <= base + 0.05, "{}: AUC {} vs base {base}", p.scenario, p.auc);
            assert!(base - p.auc <= 0.5, "{}: unbounded AUC collapse", p.scenario);
            assert_eq!(p.auc_delta, p.auc - base);
        }
        // fault observables grow monotonically as the voltage drops
        // (points are enumerated voltage-ascending within the key)
        for w in rep.points.windows(2) {
            assert!(w[0].vdd < w[1].vdd);
            assert!(w[0].faulty_cells >= w[1].faulty_cells, "fault sets nest with Vdd");
            assert!(w[0].read_error_rate >= w[1].read_error_rate);
            assert!(w[0].model_ber > w[1].model_ber);
        }
        // the baseline row is the highest-voltage point by construction
        assert_eq!(rep.points.last().unwrap().auc_delta, 0.0);
    }

    #[test]
    fn vdd_sweep_software_backend_reports_zero_faults() {
        // the golden backend has no voltage-fault model: every point of
        // its row is an error-free reference regardless of Vdd
        let mut cfg = tiny_sweep();
        cfg.backends = vec![BackendKind::Golden];
        let rep = run_vdd_sweep(&cfg).unwrap();
        for p in &rep.points {
            assert_eq!(p.flipped_bits, 0, "{}", p.scenario);
            assert_eq!(p.faulty_cells, 0, "{}", p.scenario);
            assert_eq!(p.auc_delta, 0.0, "identical stream + no faults = identical AUC");
        }
    }

    #[test]
    fn vdd_sweep_rejects_empty_axes() {
        let mut cfg = tiny_sweep();
        cfg.scenarios.clear();
        assert!(run_vdd_sweep(&cfg).is_err());
        let mut cfg = tiny_sweep();
        cfg.backends.clear();
        assert!(run_vdd_sweep(&cfg).is_err());
    }

    #[test]
    fn dataset_eval_rejects_empty_axes_and_missing_manifest() {
        let mut cfg = DatasetEvalConfig::new("/nonexistent/manifest.json");
        cfg.backends.clear();
        assert!(run_dataset_eval(&cfg).is_err());
        let mut cfg = DatasetEvalConfig::new("/nonexistent/manifest.json");
        cfg.detectors.clear();
        assert!(run_dataset_eval(&cfg).is_err());
        let cfg = DatasetEvalConfig::new("/nonexistent/manifest.json");
        let e = format!("{:#}", run_dataset_eval(&cfg).map(|_| ()).unwrap_err());
        assert!(e.contains("manifest"), "{e}");
    }

    #[test]
    fn dataset_eval_scores_a_recording_and_renders_reproducibly() {
        use crate::datasets::synthetic::SceneConfig;
        use std::fmt::Write as _;
        use std::fs;

        // Build a real dataset on disk: a synthetic scene dumped as a
        // text recording, its vertex tracks dumped as a label sidecar.
        let dir = std::env::temp_dir()
            .join(format!("nmc-tos-dataset-eval-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let mut scene = SceneConfig::test64().build(9);
        let n = if cfg!(miri) { 800 } else { 8_000 };
        let (events, gt) = scene.generate_with_gt(n);
        let mut rec = Vec::new();
        crate::events::codec::write_text(&mut rec, &events).unwrap();
        fs::write(dir.join("rec.txt"), &rec).unwrap();
        let mut gt_txt = String::from("# corner labels\n");
        let mut n_labels = 0u64;
        for tr in &gt.tracks {
            for i in 0..tr.t_us.len() {
                writeln!(
                    gt_txt,
                    "{:.6} {:.3} {:.3}",
                    tr.t_us[i] as f64 * 1e-6,
                    tr.x[i],
                    tr.y[i]
                )
                .unwrap();
                n_labels += 1;
            }
        }
        fs::write(dir.join("gt.txt"), gt_txt).unwrap();
        let manifest = concat!(
            r#"{"datasets": [{"name": "synthetic-test64", "recording": "rec.txt","#,
            r#" "ground_truth": "gt.txt", "width": 64, "height": 64}]}"#,
        );
        let mpath = dir.join("manifest.json");
        fs::write(&mpath, manifest).unwrap();

        let cfg = DatasetEvalConfig::smoke(&mpath);
        let rep = run_dataset_eval(&cfg).unwrap();
        assert_eq!(rep.points.len(), 4, "1 dataset x 2 backends x 2 detectors");
        assert_eq!(rep.labels["synthetic-test64"], n_labels);
        for p in &rep.points {
            assert_eq!(p.dataset, "synthetic-test64");
            assert!(p.events_in > 0);
            assert_eq!(p.scored, p.events_signal, "one pair per surviving event");
            assert!(p.positives > 0, "{}/{}: labels must match events", p.backend, p.detector);
            assert!(p.positives <= p.scored);
            assert!(p.auc.is_finite() && p.auc >= 0.0 && p.auc <= 1.0);
            assert!(p.best_f1 > 0.0, "recall reaches 1 at the lowest threshold");
        }
        // Byte-reproducible across repeat runs, like the vdd-sweep report.
        let a = rep.to_json().render();
        let b = run_dataset_eval(&cfg).unwrap().to_json().render();
        assert_eq!(a, b);
        assert!(a.contains("\"harness\":\"dataset-eval\""));
        fs::remove_dir_all(&dir).ok();
    }
}
