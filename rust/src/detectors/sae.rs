//! Surface of Active Events (SAE): per-pixel last-event timestamps, split
//! by polarity — the substrate of the eFAST / ARC baselines and the
//! Fig. 11(a) visualization.

use crate::events::{Event, Polarity, Resolution};

/// Polarity-split timestamp surface.
#[derive(Debug, Clone)]
pub struct Sae {
    res: Resolution,
    /// Last ON timestamp + 1 per pixel (0 = never).
    on: Vec<u64>,
    /// Last OFF timestamp + 1 per pixel (0 = never).
    off: Vec<u64>,
}

impl Sae {
    /// Fresh surface.
    pub fn new(res: Resolution) -> Self {
        Self { res, on: vec![0; res.pixels()], off: vec![0; res.pixels()] }
    }

    /// Sensor geometry.
    pub fn resolution(&self) -> Resolution {
        self.res
    }

    /// Record an event.
    #[inline]
    pub fn update(&mut self, ev: &Event) {
        let i = self.res.index(ev.x, ev.y);
        match ev.p {
            Polarity::On => self.on[i] = ev.t + 1,
            Polarity::Off => self.off[i] = ev.t + 1,
        }
    }

    /// Timestamp of the most recent event of `pol` at `(x, y)`;
    /// `None` if that pixel never fired with that polarity.
    #[inline]
    pub fn last_t(&self, x: i32, y: i32, pol: Polarity) -> Option<u64> {
        if !self.res.contains(x, y) {
            return None;
        }
        let i = self.res.index(x as u16, y as u16);
        let v = match pol {
            Polarity::On => self.on[i],
            Polarity::Off => self.off[i],
        };
        if v == 0 {
            None
        } else {
            Some(v - 1)
        }
    }

    /// Timestamp of the most recent event of either polarity.
    #[inline]
    pub fn last_t_any(&self, x: i32, y: i32) -> Option<u64> {
        if !self.res.contains(x, y) {
            return None;
        }
        let i = self.res.index(x as u16, y as u16);
        let v = self.on[i].max(self.off[i]);
        if v == 0 {
            None
        } else {
            Some(v - 1)
        }
    }

    /// Render the any-polarity SAE as an 8-bit image: newest = 255, pixels
    /// older than `window_us` (or never fired) = 0 (Fig. 11(a)).
    pub fn render_u8(&self, now_us: u64, window_us: u64) -> Vec<u8> {
        let (w, h) = (self.res.width as usize, self.res.height as usize);
        let mut out = vec![0u8; w * h];
        for y in 0..h {
            for x in 0..w {
                if let Some(t) = self.last_t_any(x as i32, y as i32) {
                    let age = now_us.saturating_sub(t);
                    if age < window_us {
                        let v = 255.0 * (1.0 - age as f64 / window_us as f64);
                        out[y * w + x] = v as u8;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_per_polarity() {
        let mut s = Sae::new(Resolution::TEST64);
        s.update(&Event::on(3, 4, 100));
        s.update(&Event::off(3, 4, 200));
        assert_eq!(s.last_t(3, 4, Polarity::On), Some(100));
        assert_eq!(s.last_t(3, 4, Polarity::Off), Some(200));
        assert_eq!(s.last_t_any(3, 4), Some(200));
        assert_eq!(s.last_t(5, 5, Polarity::On), None);
    }

    #[test]
    fn t_zero_event_is_recorded() {
        let mut s = Sae::new(Resolution::TEST64);
        s.update(&Event::on(0, 0, 0));
        assert_eq!(s.last_t(0, 0, Polarity::On), Some(0));
    }

    #[test]
    fn out_of_bounds_returns_none() {
        let s = Sae::new(Resolution::TEST64);
        assert_eq!(s.last_t(-1, 0, Polarity::On), None);
        assert_eq!(s.last_t(64, 0, Polarity::On), None);
        assert_eq!(s.last_t_any(0, 64), None);
    }

    #[test]
    fn render_fades_with_age() {
        let mut s = Sae::new(Resolution::TEST64);
        s.update(&Event::on(1, 1, 0));
        s.update(&Event::on(2, 2, 90_000));
        let img = s.render_u8(100_000, 100_000);
        let old = img[64 + 1];
        let new = img[2 * 64 + 2];
        assert!(new > old, "new {new} old {old}");
        assert_eq!(img[0], 0);
    }
}
