//! The luvHarris-style LUT corner detector (paper Fig. 1(a)): events are
//! tagged by looking up the last frame-by-frame Harris response map, which
//! a decoupled worker recomputes "as fast as possible" from the TOS.
//!
//! The lookup takes the 3x3 neighbourhood max so that an event landing one
//! pixel off a response peak (sub-pixel corner motion between LUT
//! refreshes) still scores high — the same trick luvHarris uses.

use crate::events::{Event, Resolution};

use super::EventScorer;

/// Scoring LUT + per-event tagger.
#[derive(Debug, Clone)]
pub struct HarrisDetector {
    res: Resolution,
    /// Latest Harris response map in [0,1] (row-major), all-zero until the
    /// first refresh.
    lut: Vec<f32>,
    /// LUT refreshes seen over the detector's lifetime (cumulative across
    /// runs; `RunReport::lut_refreshes` counts per run instead).
    pub refreshes: u64,
    /// Events scored.
    pub scored: u64,
}

impl HarrisDetector {
    /// Detector with an all-zero LUT.
    pub fn new(res: Resolution) -> Self {
        Self { res, lut: vec![0.0; res.pixels()], refreshes: 0, scored: 0 }
    }

    /// Install a freshly computed response map.
    pub fn refresh(&mut self, lut: &[f32]) {
        assert_eq!(lut.len(), self.res.pixels(), "LUT size mismatch");
        self.lut.copy_from_slice(lut);
        self.refreshes += 1;
    }

    /// Current LUT (for rendering / inspection).
    pub fn lut(&self) -> &[f32] {
        &self.lut
    }

    /// Score = 3x3 neighbourhood max of the LUT at the event pixel.
    #[inline]
    pub fn score_at(&self, x: u16, y: u16) -> f64 {
        let w = self.res.width as i32;
        let h = self.res.height as i32;
        let mut best = 0.0f32;
        for dy in -1i32..=1 {
            let yy = y as i32 + dy;
            if yy < 0 || yy >= h {
                continue;
            }
            let row = yy as usize * w as usize;
            for dx in -1i32..=1 {
                let xx = x as i32 + dx;
                if xx < 0 || xx >= w {
                    continue;
                }
                best = best.max(self.lut[row + xx as usize]);
            }
        }
        best as f64
    }
}

impl EventScorer for HarrisDetector {
    fn score(&mut self, ev: &Event) -> f64 {
        self.scored += 1;
        self.score_at(ev.x, ev.y)
    }

    fn name(&self) -> &'static str {
        "luvHarris-LUT"
    }

    fn ops_per_event(&self) -> f64 {
        // 9 loads + 9 max ops: the tag path is trivially cheap — the cost
        // of luvHarris is the *TOS update*, which is exactly the paper's
        // point.
        18.0
    }

    fn wants_lut(&self) -> bool {
        true
    }

    fn refresh_lut(&mut self, lut: &[f32]) {
        self.refresh(lut);
    }

    fn lut(&self) -> Option<&[f32]> {
        Some(&self.lut)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_lut_scores_zero() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        assert_eq!(d.score(&Event::on(10, 10, 0)), 0.0);
    }

    #[test]
    fn neighbourhood_max_lookup() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        let mut lut = vec![0.0f32; 64 * 64];
        lut[20 * 64 + 20] = 0.8;
        d.refresh(&lut);
        // exact hit
        assert!((d.score_at(20, 20) - 0.8).abs() < 1e-6);
        // one pixel off still sees the peak
        assert!((d.score_at(21, 20) - 0.8).abs() < 1e-6);
        assert!((d.score_at(21, 21) - 0.8).abs() < 1e-6);
        // two pixels off does not
        assert_eq!(d.score_at(22, 22), 0.0);
    }

    #[test]
    fn border_lookup_is_safe() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        let mut lut = vec![0.0f32; 64 * 64];
        lut[0] = 0.5;
        d.refresh(&lut);
        assert!((d.score_at(0, 0) - 0.5).abs() < 1e-6);
        assert!((d.score_at(63, 63) - 0.0).abs() < 1e-6);
    }

    #[test]
    fn refresh_replaces_lut() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        d.refresh(&vec![0.3f32; 64 * 64]);
        d.refresh(&vec![0.6f32; 64 * 64]);
        assert_eq!(d.refreshes, 2);
        assert!((d.score_at(5, 5) - 0.6).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "LUT size mismatch")]
    fn refresh_validates_size() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        d.refresh(&[0.0; 10]);
    }
}
