//! The luvHarris-style LUT corner detector (paper Fig. 1(a)): events are
//! tagged by looking up the last frame-by-frame Harris response map, which
//! a decoupled worker recomputes "as fast as possible" from the TOS.
//!
//! The lookup takes the 3x3 neighbourhood max so that an event landing one
//! pixel off a response peak (sub-pixel corner motion between LUT
//! refreshes) still scores high — the same trick luvHarris uses.

use crate::events::{Event, Resolution};

use super::EventScorer;

/// Scoring LUT + per-event tagger.
#[derive(Debug, Clone)]
pub struct HarrisDetector {
    res: Resolution,
    /// Latest Harris response map in [0,1] (row-major), all-zero until the
    /// first refresh.
    lut: Vec<f32>,
    /// LUT refreshes seen over the detector's lifetime (cumulative across
    /// runs; `RunReport::lut_refreshes` counts per run instead).
    pub refreshes: u64,
    /// Events scored.
    pub scored: u64,
}

impl HarrisDetector {
    /// Detector with an all-zero LUT.
    pub fn new(res: Resolution) -> Self {
        Self { res, lut: vec![0.0; res.pixels()], refreshes: 0, scored: 0 }
    }

    /// Install a freshly computed response map.
    pub fn refresh(&mut self, lut: &[f32]) {
        assert_eq!(lut.len(), self.res.pixels(), "LUT size mismatch");
        self.lut.copy_from_slice(lut);
        self.refreshes += 1;
    }

    /// Current LUT (for rendering / inspection).
    pub fn lut(&self) -> &[f32] {
        &self.lut
    }

    /// Score = 3x3 neighbourhood max of the LUT at the event pixel.
    #[inline]
    pub fn score_at(&self, x: u16, y: u16) -> f64 {
        let w = self.res.width as i32;
        let h = self.res.height as i32;
        let mut best = 0.0f32;
        for dy in -1i32..=1 {
            let yy = y as i32 + dy;
            if yy < 0 || yy >= h {
                continue;
            }
            let row = yy as usize * w as usize;
            for dx in -1i32..=1 {
                let xx = x as i32 + dx;
                if xx < 0 || xx >= w {
                    continue;
                }
                best = best.max(self.lut[row + xx as usize]);
            }
        }
        best as f64
    }
}

/// Harris corner constant `k` used by the software response stencil —
/// the same value the AOT-lowered FBF graph bakes in.
const HARRIS_K: f32 = 0.04;

/// Pure-Rust frame-by-frame Harris response map over a TOS snapshot — the
/// engine-less FBF fallback behind
/// [`PipelineConfig::software_fbf`](crate::coordinator::PipelineConfig::software_fbf).
///
/// Pipeline: 3x3 Sobel gradients -> 3x3 box-summed structure tensor ->
/// `R = det(M) - k·tr(M)²` -> normalized to `[0, 1]` by the max positive
/// response (all zeros when the frame has no positive response). The
/// outermost pixel ring is left at zero (no gradient support there).
///
/// This is a harness/CI path, not a perf path: it allocates a scratch
/// gradient buffer and runs scalar code. The AOT PJRT engine computes the
/// same quantity; both land in the detector through
/// [`HarrisDetector::refresh`], so the tag stage cannot tell them apart.
pub fn response_map_into(tos: &[u8], res: Resolution, out: &mut Vec<f32>) {
    let (w, h) = (res.width as usize, res.height as usize);
    assert_eq!(tos.len(), w * h, "TOS size mismatch");
    out.clear();
    out.resize(w * h, 0.0);
    if w < 3 || h < 3 {
        return;
    }
    // 3x3 Sobel gradients, interior pixels only
    let mut gx = vec![0.0f32; w * h];
    let mut gy = vec![0.0f32; w * h];
    let at = |x: usize, y: usize| tos[y * w + x] as f32;
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            let (a, b, c) = (at(x - 1, y - 1), at(x, y - 1), at(x + 1, y - 1));
            let (d, f) = (at(x - 1, y), at(x + 1, y));
            let (g, hh, i) = (at(x - 1, y + 1), at(x, y + 1), at(x + 1, y + 1));
            gx[y * w + x] = (c + 2.0 * f + i) - (a + 2.0 * d + g);
            gy[y * w + x] = (g + 2.0 * hh + i) - (a + 2.0 * b + c);
        }
    }
    // 3x3-windowed structure tensor -> Harris response; track the max
    // positive response for normalization
    let mut max_r = 0.0f32;
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            let (mut sxx, mut syy, mut sxy) = (0.0f32, 0.0, 0.0);
            for yy in y - 1..=y + 1 {
                for xx in x - 1..=x + 1 {
                    let (dx, dy) = (gx[yy * w + xx], gy[yy * w + xx]);
                    sxx += dx * dx;
                    syy += dy * dy;
                    sxy += dx * dy;
                }
            }
            let det = sxx * syy - sxy * sxy;
            let tr = sxx + syy;
            let r = det - HARRIS_K * tr * tr;
            out[y * w + x] = r;
            max_r = max_r.max(r);
        }
    }
    if max_r <= 0.0 {
        out.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    // clamp negatives (edges) to zero, scale peaks into [0, 1]
    for v in out.iter_mut() {
        *v = (*v / max_r).max(0.0);
    }
}

impl EventScorer for HarrisDetector {
    fn score(&mut self, ev: &Event) -> f64 {
        self.scored += 1;
        self.score_at(ev.x, ev.y)
    }

    fn name(&self) -> &'static str {
        "luvHarris-LUT"
    }

    fn ops_per_event(&self) -> f64 {
        // 9 loads + 9 max ops: the tag path is trivially cheap — the cost
        // of luvHarris is the *TOS update*, which is exactly the paper's
        // point.
        18.0
    }

    fn wants_lut(&self) -> bool {
        true
    }

    fn refresh_lut(&mut self, lut: &[f32]) {
        self.refresh(lut);
    }

    fn lut(&self) -> Option<&[f32]> {
        Some(&self.lut)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_lut_scores_zero() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        assert_eq!(d.score(&Event::on(10, 10, 0)), 0.0);
    }

    #[test]
    fn neighbourhood_max_lookup() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        let mut lut = vec![0.0f32; 64 * 64];
        lut[20 * 64 + 20] = 0.8;
        d.refresh(&lut);
        // exact hit
        assert!((d.score_at(20, 20) - 0.8).abs() < 1e-6);
        // one pixel off still sees the peak
        assert!((d.score_at(21, 20) - 0.8).abs() < 1e-6);
        assert!((d.score_at(21, 21) - 0.8).abs() < 1e-6);
        // two pixels off does not
        assert_eq!(d.score_at(22, 22), 0.0);
    }

    #[test]
    fn border_lookup_is_safe() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        let mut lut = vec![0.0f32; 64 * 64];
        lut[0] = 0.5;
        d.refresh(&lut);
        assert!((d.score_at(0, 0) - 0.5).abs() < 1e-6);
        assert!((d.score_at(63, 63) - 0.0).abs() < 1e-6);
    }

    #[test]
    fn refresh_replaces_lut() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        d.refresh(&vec![0.3f32; 64 * 64]);
        d.refresh(&vec![0.6f32; 64 * 64]);
        assert_eq!(d.refreshes, 2);
        assert!((d.score_at(5, 5) - 0.6).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "LUT size mismatch")]
    fn refresh_validates_size() {
        let mut d = HarrisDetector::new(Resolution::TEST64);
        d.refresh(&[0.0; 10]);
    }

    #[test]
    fn software_response_flat_frame_is_zero() {
        let res = Resolution::TEST64;
        let mut out = Vec::new();
        response_map_into(&vec![255u8; res.pixels()], res, &mut out);
        assert_eq!(out.len(), res.pixels());
        assert!(out.iter().all(|&v| v == 0.0), "flat frame has no corners");
    }

    #[test]
    fn software_response_peaks_at_square_corners() {
        // a bright 20x20 square on black: corners must out-score both the
        // edge midpoints and the flat interior
        let res = Resolution::TEST64;
        let w = res.width as usize;
        let mut tos = vec![0u8; res.pixels()];
        for y in 20..40 {
            for x in 20..40 {
                tos[y * w + x] = 255;
            }
        }
        let mut out = Vec::new();
        response_map_into(&tos, res, &mut out);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)), "normalized range");
        assert!(out.iter().any(|&v| v == 1.0), "max positive response scales to 1");
        let near = |cx: usize, cy: usize| -> f32 {
            let mut best = 0.0f32;
            for y in cy.saturating_sub(2)..=(cy + 2).min(w - 1) {
                for x in cx.saturating_sub(2)..=(cx + 2).min(w - 1) {
                    best = best.max(out[y * w + x]);
                }
            }
            best
        };
        let corner = near(20, 20).min(near(39, 20)).min(near(20, 39)).min(near(39, 39));
        let edge = near(30, 20).max(near(20, 30));
        let flat = near(30, 30);
        assert!(corner > 0.5, "square corners must respond strongly ({corner})");
        assert!(corner > edge, "corner {corner} must beat edge {edge}");
        assert!(corner > flat, "corner {corner} must beat interior {flat}");
    }

    #[test]
    fn software_response_is_deterministic() {
        let res = Resolution::TEST64;
        let mut tos = vec![0u8; res.pixels()];
        for (i, v) in tos.iter_mut().enumerate() {
            *v = ((i * 2654435761) >> 24) as u8;
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        response_map_into(&tos, res, &mut a);
        response_map_into(&tos, res, &mut b);
        assert_eq!(a, b);
    }
}
