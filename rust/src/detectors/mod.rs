//! Event-camera corner detectors: the paper's luvHarris-style LUT detector
//! plus every baseline it is compared against (Sec. II-B).
//!
//! * [`harris`]  — the system under study: per-event lookup into the last
//!   FBF-computed Harris response map of the TOS.
//! * [`eharris`] — Vasco et al.: full Harris computed *per event* on a
//!   binary surface (accurate, prohibitively slow — the Fig. 1(b) anchor).
//! * [`fast`]    — Mueggler et al. eFAST: circular-segment test on the SAE.
//! * [`arc`]     — Alzugaray & Chli ARC*: arc-angle test on the SAE.
//!
//! All detectors implement [`EventScorer`] so the PR harness can sweep them
//! uniformly.

pub mod arc;
pub mod eharris;
pub mod fast;
pub mod harris;
pub mod sae;

use crate::events::Event;

/// A detector that assigns each event a continuous corner score.
///
/// Binary detectors (FAST/ARC) return {0, 1}; continuous ones return the
/// Harris response.  Higher = more corner-like.
///
/// The LUT-refresh hooks let the generic coordinator drive any detector:
/// SAE-based detectors (eHarris/eFAST/ARC*) keep their own surfaces and
/// ignore them, while the luvHarris-style LUT detector consumes the FBF
/// Harris maps the pipeline computes from TOS snapshots.
pub trait EventScorer {
    /// Process the event (update internal surfaces) and return its score.
    fn score(&mut self, ev: &Event) -> f64;

    /// Detector name for reports.
    fn name(&self) -> &'static str;

    /// Estimated datapath operations per event (drives the Fig. 1(b)
    /// throughput model for software/digital implementations).
    fn ops_per_event(&self) -> f64;

    /// Does this detector consume frame-by-frame Harris LUT refreshes?
    /// When `false`, the coordinator skips the whole FBF/PJRT stage.
    fn wants_lut(&self) -> bool {
        false
    }

    /// Install a freshly computed response map (LUT detectors only).
    fn refresh_lut(&mut self, _lut: &[f32]) {}

    /// Current response map, if the detector keeps one.
    fn lut(&self) -> Option<&[f32]> {
        None
    }
}

impl<T: EventScorer + ?Sized> EventScorer for Box<T> {
    fn score(&mut self, ev: &Event) -> f64 {
        (**self).score(ev)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn ops_per_event(&self) -> f64 {
        (**self).ops_per_event()
    }
    fn wants_lut(&self) -> bool {
        (**self).wants_lut()
    }
    fn refresh_lut(&mut self, lut: &[f32]) {
        (**self).refresh_lut(lut)
    }
    fn lut(&self) -> Option<&[f32]> {
        (**self).lut()
    }
}

/// Throughput model for a digital/software implementation executing
/// `ops_per_event` at `clock_hz` with one op per cycle (the conservative
/// single-issue model the paper's Fig. 1(b) uses for eHarris/luvHarris).
pub fn max_throughput_eps(ops_per_event: f64, clock_hz: f64) -> f64 {
    clock_hz / ops_per_event.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_model_sanity() {
        // 196 ops at 500 MHz = 2.55 Meps (conventional luvHarris TOS anchor)
        let t = max_throughput_eps(196.0, 500e6);
        assert!((t / 1e6 - 2.55).abs() < 0.01);
        assert_eq!(max_throughput_eps(0.0, 500e6), 500e6);
    }
}
