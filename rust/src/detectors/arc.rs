//! ARC* baseline (Alzugaray & Chli 2018): arc-angle test on the SAE.
//!
//! Like eFAST it inspects the radius-3 Bresenham circle, but instead of a
//! fixed segment-length window it finds the *longest* contiguous arc of
//! pixels newer than all others and classifies the event as a corner when
//! that arc subtends an angle in [A_min, 180°] (the paper's ~90° rule:
//! a corner's wavefront covers about a quarter-to-half of the circle;
//! a passing edge covers more than half, noise covers less).

use crate::events::{Event, Resolution};

use super::fast::CIRCLE3;
use super::sae::Sae;
use super::EventScorer;

/// ARC* detector.
#[derive(Debug)]
pub struct Arc {
    sae: Sae,
    /// Minimum arc length (pixels of the 16-px circle) to call a corner.
    pub min_arc: usize,
    /// Maximum arc length.
    pub max_arc: usize,
}

impl Arc {
    /// Defaults: arcs of 4..8 sixteenths, i.e. 90°..180°.
    pub fn new(res: Resolution) -> Self {
        Self { sae: Sae::new(res), min_arc: 4, max_arc: 8 }
    }

    /// Length of the longest contiguous arc that strictly dominates (is
    /// newer than) every pixel outside it; 0 if none exists.
    pub fn longest_dominant_arc(ts: &[Option<u64>]) -> usize {
        let n = ts.len();
        let mut best = 0usize;
        for len in (1..n).rev() {
            'start: for s in 0..n {
                let mut min_in = u64::MAX;
                for k in 0..len {
                    match ts[(s + k) % n] {
                        Some(t) => min_in = min_in.min(t),
                        None => continue 'start,
                    }
                }
                for (k, t) in ts.iter().enumerate() {
                    let inside = (k + n - s) % n < len;
                    if !inside {
                        if let Some(t) = t {
                            if *t >= min_in {
                                continue 'start;
                            }
                        }
                    }
                }
                best = len;
                return best;
            }
        }
        best
    }
}

impl EventScorer for Arc {
    fn score(&mut self, ev: &Event) -> f64 {
        self.sae.update(ev);
        let ts: Vec<Option<u64>> = CIRCLE3
            .iter()
            .map(|&(dx, dy)| self.sae.last_t(ev.x as i32 + dx, ev.y as i32 + dy, ev.p))
            .collect();
        let arc = Self::longest_dominant_arc(&ts);
        if (self.min_arc..=self.max_arc).contains(&arc) {
            1.0
        } else {
            0.0
        }
    }

    fn name(&self) -> &'static str {
        "ARC*"
    }

    fn ops_per_event(&self) -> f64 {
        // 16 SAE loads + longest-arc scan (~16 starts * 16 compares * ~8 lens)
        16.0 + 16.0 * 16.0 * 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn circle_with_newest(positions: &[usize]) -> Vec<Option<u64>> {
        let mut ts = vec![Some(10u64); 16];
        for (i, &p) in positions.iter().enumerate() {
            ts[p] = Some(100 + i as u64);
        }
        ts
    }

    #[test]
    fn longest_arc_simple() {
        let ts = circle_with_newest(&[0, 1, 2, 3]);
        assert_eq!(Arc::longest_dominant_arc(&ts), 4);
    }

    #[test]
    fn longest_arc_wrapping() {
        let ts = circle_with_newest(&[14, 15, 0, 1, 2]);
        assert_eq!(Arc::longest_dominant_arc(&ts), 5);
    }

    #[test]
    fn no_arc_when_flat_or_empty() {
        assert_eq!(Arc::longest_dominant_arc(&vec![Some(5u64); 16]), 0);
        assert_eq!(Arc::longest_dominant_arc(&vec![None; 16]), 0);
    }

    #[test]
    fn edge_like_arc_rejected_corner_arc_accepted() {
        let res = Resolution::TEST64;
        let mut d = Arc::new(res);
        // corner-ish: 5 of 16 newest
        let ts = circle_with_newest(&[0, 1, 2, 3, 4]);
        let arc = Arc::longest_dominant_arc(&ts);
        assert!((d.min_arc..=d.max_arc).contains(&arc));
        // edge-like: 12 of 16 newest -> rejected
        let ts = circle_with_newest(&[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
        let arc = Arc::longest_dominant_arc(&ts);
        assert!(arc > d.max_arc);
        // noise-like: 2 newest -> rejected
        let ts = circle_with_newest(&[0, 1]);
        let arc = Arc::longest_dominant_arc(&ts);
        assert!(arc < d.min_arc);
        // plumb through score() once for the state machinery
        let _ = d.score(&Event::on(30, 30, 1));
    }

    #[test]
    fn score_is_binary() {
        let mut d = Arc::new(Resolution::TEST64);
        for i in 0..50u64 {
            let s = d.score(&Event::on((i % 60) as u16, (i % 40) as u16, i));
            assert!(s == 0.0 || s == 1.0);
        }
    }
}
