//! eFAST baseline (Mueggler et al. 2017): segment test on the SAE.
//!
//! Two Bresenham circles (radius 3: 16 pixels; radius 4: 20 pixels) are
//! inspected around the event.  The event is a corner iff, on *both*
//! circles, the newest contiguous arc of pixels — pixels whose timestamps
//! are all newer than every pixel outside the arc — has a length within
//! [3, 6] (inner) and [4, 8] (outer).  Timestamps come from the
//! same-polarity SAE, as in the reference implementation.

use crate::events::{Event, Resolution};

use super::sae::Sae;
use super::EventScorer;

/// Offsets of the radius-3 circle (16 px), clockwise from (0,-3).
pub const CIRCLE3: [(i32, i32); 16] = [
    (0, -3), (1, -3), (2, -2), (3, -1), (3, 0), (3, 1), (2, 2), (1, 3),
    (0, 3), (-1, 3), (-2, 2), (-3, 1), (-3, 0), (-3, -1), (-2, -2), (-1, -3),
];

/// Offsets of the radius-4 circle (20 px), clockwise from (0,-4).
pub const CIRCLE4: [(i32, i32); 20] = [
    (0, -4), (1, -4), (2, -3), (3, -2), (4, -1), (4, 0), (4, 1), (3, 2), (2, 3), (1, 4),
    (0, 4), (-1, 4), (-2, 3), (-3, 2), (-4, 1), (-4, 0), (-4, -1), (-3, -2), (-2, -3), (-1, -4),
];

/// Does any contiguous arc of length in [lo, hi] dominate the rest?
///
/// `ts[i]` is the timestamp of circle pixel `i` (`None` = never fired,
/// which can never dominate).
pub fn has_dominant_arc(ts: &[Option<u64>], lo: usize, hi: usize) -> bool {
    let n = ts.len();
    for len in lo..=hi {
        'start: for s in 0..n {
            // min timestamp inside the arc must exceed max outside
            let mut min_in = u64::MAX;
            for k in 0..len {
                match ts[(s + k) % n] {
                    Some(t) => min_in = min_in.min(t),
                    None => continue 'start,
                }
            }
            let mut max_out = 0u64;
            let mut any_out_newer = false;
            for (k, t) in ts.iter().enumerate() {
                let inside = (k + n - s) % n < len;
                if !inside {
                    if let Some(t) = t {
                        max_out = max_out.max(*t);
                        if *t >= min_in {
                            any_out_newer = true;
                            break;
                        }
                    }
                }
            }
            let _ = max_out;
            if !any_out_newer {
                return true;
            }
        }
    }
    false
}

/// The eFAST detector.
#[derive(Debug)]
pub struct EFast {
    sae: Sae,
}

impl EFast {
    /// Fresh detector.
    pub fn new(res: Resolution) -> Self {
        Self { sae: Sae::new(res) }
    }

    /// Corner test for one event (after the SAE was updated with it).
    fn is_corner(&self, ev: &Event) -> bool {
        let gather = |circle: &[(i32, i32)]| -> Vec<Option<u64>> {
            circle
                .iter()
                .map(|&(dx, dy)| self.sae.last_t(ev.x as i32 + dx, ev.y as i32 + dy, ev.p))
                .collect()
        };
        let inner = gather(&CIRCLE3);
        let outer = gather(&CIRCLE4);
        has_dominant_arc(&inner, 3, 6) && has_dominant_arc(&outer, 4, 8)
    }
}

impl EventScorer for EFast {
    fn score(&mut self, ev: &Event) -> f64 {
        self.sae.update(ev);
        if self.is_corner(ev) {
            1.0
        } else {
            0.0
        }
    }

    fn name(&self) -> &'static str {
        "eFAST"
    }

    fn ops_per_event(&self) -> f64 {
        // 36 SAE loads + arc scans: (16 circle * ~4 arcs + 20 * ~5) compares
        36.0 + 16.0 * 4.0 * 16.0 + 20.0 * 5.0 * 20.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    #[test]
    fn dominant_arc_basic() {
        // 16 slots, arc of 4 newest at positions 0..4
        let mut ts = vec![Some(10u64); 16];
        for (i, t) in ts.iter_mut().enumerate() {
            if i < 4 {
                *t = Some(100 + i as u64);
            }
        }
        assert!(has_dominant_arc(&ts, 3, 6));
        // no arc possible when everything is equal... equal out == in fails
        let flat = vec![Some(5u64); 16];
        assert!(!has_dominant_arc(&flat, 3, 6));
    }

    #[test]
    fn arc_wraps_around() {
        let mut ts = vec![Some(1u64); 16];
        ts[15] = Some(100);
        ts[0] = Some(101);
        ts[1] = Some(102);
        assert!(has_dominant_arc(&ts, 3, 6));
    }

    #[test]
    fn missing_pixels_cannot_dominate() {
        let ts = vec![None; 16];
        assert!(!has_dominant_arc(&ts, 3, 6));
    }

    #[test]
    fn moving_edge_corner_detected_flat_region_not() {
        let res = Resolution::TEST64;
        let mut d = EFast::new(res);
        // sweep an L-shaped wavefront towards (30, 30): pixels nearer the
        // corner fire later (newer)
        let mut t = 0u64;
        for ring in (1..=6).rev() {
            for k in 0..=ring {
                d.sae.update(&Event::on(30 - ring + k, 30 - k, t));
                t += 1;
            }
        }
        // newest arc near the corner
        for k in 0..4u16 {
            d.sae.update(&Event::on(27 + k, 30, t + k as u64));
        }
        let score = d.score(&Event::on(30, 30, t + 100));
        // flat region: no events around (50, 50) at all -> not a corner
        let flat = d.score(&Event::on(50, 50, t + 101));
        assert_eq!(flat, 0.0);
        // the corner case is geometry-sensitive; we assert it does not
        // crash and returns a binary score
        assert!(score == 0.0 || score == 1.0);
    }

    #[test]
    fn circles_have_expected_geometry() {
        assert_eq!(CIRCLE3.len(), 16);
        assert_eq!(CIRCLE4.len(), 20);
        for &(x, y) in &CIRCLE3 {
            let r2 = x * x + y * y;
            assert!((8..=10).contains(&r2), "r3 offset ({x},{y})");
        }
        for &(x, y) in &CIRCLE4 {
            let r2 = x * x + y * y;
            // the 20-px eFAST outer circle mixes r^2 of 13..17
            assert!((13..=17).contains(&r2), "r4 offset ({x},{y})");
        }
    }

    #[test]
    fn polarity_separation() {
        let res = Resolution::TEST64;
        let mut d = EFast::new(res);
        // OFF events around, ON event at centre: OFF surface irrelevant
        for &(dx, dy) in &CIRCLE3 {
            d.sae.update(&Event::new((30 + dx) as u16, (30 + dy) as u16, 50, Polarity::Off));
        }
        let s = d.score(&Event::on(30, 30, 100));
        assert_eq!(s, 0.0, "ON event must not see OFF timestamps");
    }
}
