//! eHarris baseline (Vasco et al. 2016): a full Harris score computed *per
//! event* over a binary surface of the most recent events.
//!
//! Accuracy is good, but the per-event cost — two 5x5 Sobel stencils plus
//! a windowed structure tensor over an LxL neighbourhood — is what caps
//! its throughput at well under 1 Meps in Fig. 1(b).
//!
//! The stencils run in *separable* form (vertical smooth/deriv passes,
//! then horizontal deriv/smooth): the 5x5 Sobel taps factor as
//! `kx = smooth ⊗ deriv` and `ky = deriv ⊗ smooth`, cutting the per-event
//! multiply count from `2·G²·25` dense MACs to `2·(G·L + G·G)·5`
//! (1250 → 700 for L=9, G=5). The dense form is kept as
//! [`EHarris::harris_at_dense`] — the equivalence oracle for tests and
//! benches (scores agree within f32 tolerance, corner ordering identical)
//! and the cost model behind [`EventScorer::ops_per_event`]: the paper's
//! Fig. 1(b) throughput anchor quotes the *published* eHarris (dense
//! stencils), not this port's separable optimization — see
//! [`EHarris::ops_per_event_separable`] for the optimized cost.

use std::collections::VecDeque;

use crate::events::{Event, Resolution};

use super::EventScorer;

/// Window size of the binary surface neighbourhood (9x9 as in the paper's
/// reference implementation: 5x5 Sobel valid over a 9x9 patch leaves a 5x5
/// gradient patch for the structure tensor).
const L: usize = 9;
/// Gradient patch side after valid 5x5 Sobel.
const G: usize = L - 4;
/// Sobel tap count.
const K: usize = 5;

/// Normalized 1-D binomial smoothing taps (`[1,4,6,4,1] / 16`).
const SMOOTH: [f32; K] = [1.0 / 16.0, 4.0 / 16.0, 6.0 / 16.0, 4.0 / 16.0, 1.0 / 16.0];
/// Normalized 1-D derivative taps (`[-1,-2,0,2,1] / 6`).
const DERIV: [f32; K] = [-1.0 / 6.0, -2.0 / 6.0, 0.0, 2.0 / 6.0, 1.0 / 6.0];

/// 5x5 Sobel taps (binomial smooth x central difference), row-major — the
/// dense outer-product form of [`SMOOTH`] / [`DERIV`], used by the
/// reference implementation only.
fn sobel5() -> ([[f32; 5]; 5], [[f32; 5]; 5]) {
    let smooth = [1.0f32, 4.0, 6.0, 4.0, 1.0];
    let deriv = [-1.0f32, -2.0, 0.0, 2.0, 1.0];
    let mut kx = [[0.0; 5]; 5];
    let mut ky = [[0.0; 5]; 5];
    for r in 0..5 {
        for c in 0..5 {
            kx[r][c] = smooth[r] / 16.0 * deriv[c] / 6.0;
            ky[r][c] = deriv[r] / 6.0 * smooth[c] / 16.0;
        }
    }
    (kx, ky)
}

/// eHarris detector state: binary surface of the last `window` events.
#[derive(Debug)]
pub struct EHarris {
    res: Resolution,
    /// Per-pixel flag: is this pixel among the most recent `window` events?
    surface: Vec<u8>,
    /// FIFO of the active pixels.
    fifo: VecDeque<usize>,
    /// Number of events kept on the binary surface.
    window: usize,
    /// Harris k.
    k: f32,
    /// Reusable scratch: the gathered LxL binary patch (zeros outside the
    /// sensor); rewritten per event, never reallocated.
    patch: [[f32; L]; L],
    /// Reusable scratch: vertical smooth / deriv passes (G rows x L cols).
    vsmooth: [[f32; L]; G],
    vderiv: [[f32; L]; G],
}

impl EHarris {
    /// The standard Harris sensitivity constant.
    pub const DEFAULT_K: f32 = 0.04;

    /// Detector with the standard 2000-event binary surface.
    pub fn new(res: Resolution) -> Self {
        Self::with_params(res, 2000, Self::DEFAULT_K)
    }

    /// Detector with an explicit surface window (events kept, >= 1) and
    /// Harris `k` — the bench sweep varies the window
    /// (`--eharris-window` on the CLI).
    pub fn with_params(res: Resolution, window: usize, k: f32) -> Self {
        let window = window.max(1);
        Self {
            res,
            surface: vec![0; res.pixels()],
            fifo: VecDeque::with_capacity(window + 1),
            window,
            k,
            patch: [[0.0; L]; L],
            vsmooth: [[0.0; L]; G],
            vderiv: [[0.0; L]; G],
        }
    }

    /// Surface window currently configured.
    #[inline]
    pub fn window(&self) -> usize {
        self.window
    }

    /// Datapath operations per event of the *separable* implementation
    /// this port actually runs ([`EHarris::harris_at`]): vertical passes
    /// `2·(G·L·K)` MACs, horizontal `2·(G·G·K)`, the structure tensor
    /// `3·G²`, plus the LxL gather and the score — 866 for L=9.
    /// [`EventScorer::ops_per_event`] instead quotes the dense reference
    /// cost, which is what the paper's Fig. 1(b) compares against.
    pub fn ops_per_event_separable(&self) -> f64 {
        let vertical = (G * L * K) as f64 * 2.0;
        let horizontal = (G * G * K) as f64 * 2.0;
        let tensor = (G * G) as f64 * 3.0;
        let gather = (L * L) as f64;
        vertical + horizontal + tensor + gather + 10.0
    }

    /// Gather the LxL binary patch around `(ex, ey)` into the scratch.
    /// Interior events (the overwhelmingly common case) copy row slices
    /// without per-pixel bounds tests; border events zero-pad.
    fn gather(&mut self, ex: i32, ey: i32) {
        let half = (L as i32 - 1) / 2;
        let w = self.res.width as i32;
        let h = self.res.height as i32;
        let interior = ex >= half && ey >= half && ex + half < w && ey + half < h;
        if interior {
            for (r, prow) in self.patch.iter_mut().enumerate() {
                let base = (ey - half + r as i32) as usize * w as usize + (ex - half) as usize;
                for (p, &s) in prow.iter_mut().zip(&self.surface[base..base + L]) {
                    *p = s as f32;
                }
            }
        } else {
            for (r, prow) in self.patch.iter_mut().enumerate() {
                let y = ey - half + r as i32;
                for (c, p) in prow.iter_mut().enumerate() {
                    let x = ex - half + c as i32;
                    *p = if self.res.contains(x, y) {
                        self.surface[self.res.index(x as u16, y as u16)] as f32
                    } else {
                        0.0
                    };
                }
            }
        }
    }

    /// Harris response at `(ex, ey)` over the binary surface — separable
    /// Sobel (vertical smooth/deriv, then horizontal deriv/smooth) fused
    /// with the structure-tensor accumulation.
    pub fn harris_at(&mut self, ex: i32, ey: i32) -> f64 {
        self.gather(ex, ey);
        // vertical 5-tap passes: G output rows over all L columns
        for r in 0..G {
            for c in 0..L {
                let mut s = 0.0f32;
                let mut d = 0.0f32;
                for (k, (&sk, &dk)) in SMOOTH.iter().zip(&DERIV).enumerate() {
                    let v = self.patch[r + k][c];
                    s += v * sk;
                    d += v * dk;
                }
                self.vsmooth[r][c] = s;
                self.vderiv[r][c] = d;
            }
        }
        // horizontal 5-tap passes + structure tensor over the GxG patch
        let (mut sxx, mut syy, mut sxy) = (0.0f32, 0.0f32, 0.0f32);
        for r in 0..G {
            for c in 0..G {
                let mut ix = 0.0f32;
                let mut iy = 0.0f32;
                for (k, (&sk, &dk)) in SMOOTH.iter().zip(&DERIV).enumerate() {
                    ix += self.vsmooth[r][c + k] * dk;
                    iy += self.vderiv[r][c + k] * sk;
                }
                sxx += ix * ix;
                syy += iy * iy;
                sxy += ix * iy;
            }
        }
        (sxx * syy - sxy * sxy - self.k * (sxx + syy) * (sxx + syy)) as f64
    }

    /// Dense 5x5-stencil reference form of [`EHarris::harris_at`] (the
    /// pre-separable implementation, kept verbatim): equivalence oracle
    /// for tests and the `detectors` bench.
    pub fn harris_at_dense(&self, ex: i32, ey: i32) -> f64 {
        let (kx, ky) = sobel5();
        let half = (L as i32 - 1) / 2;
        // gather the LxL binary patch (zeros outside the sensor)
        let mut patch = [[0.0f32; L]; L];
        for (r, row) in patch.iter_mut().enumerate() {
            for (c, v) in row.iter_mut().enumerate() {
                let x = ex - half + c as i32;
                let y = ey - half + r as i32;
                if self.res.contains(x, y) {
                    *v = self.surface[self.res.index(x as u16, y as u16)] as f32;
                }
            }
        }
        // valid 5x5 Sobel -> GxG gradients
        let mut ix = [[0.0f32; G]; G];
        let mut iy = [[0.0f32; G]; G];
        for r in 0..G {
            for c in 0..G {
                let mut sx = 0.0;
                let mut sy = 0.0;
                for kr in 0..5 {
                    for kc in 0..5 {
                        let v = patch[r + kr][c + kc];
                        sx += v * kx[kr][kc];
                        sy += v * ky[kr][kc];
                    }
                }
                ix[r][c] = sx;
                iy[r][c] = sy;
            }
        }
        // structure tensor over the whole GxG patch (uniform window)
        let (mut sxx, mut syy, mut sxy) = (0.0f32, 0.0f32, 0.0f32);
        for r in 0..G {
            for c in 0..G {
                sxx += ix[r][c] * ix[r][c];
                syy += iy[r][c] * iy[r][c];
                sxy += ix[r][c] * iy[r][c];
            }
        }
        (sxx * syy - sxy * sxy - self.k * (sxx + syy) * (sxx + syy)) as f64
    }
}

impl EventScorer for EHarris {
    fn score(&mut self, ev: &Event) -> f64 {
        let i = self.res.index(ev.x, ev.y);
        if self.surface[i] == 0 {
            self.surface[i] = 1;
            self.fifo.push_back(i);
            if self.fifo.len() > self.window {
                let old = self.fifo.pop_front().unwrap();
                self.surface[old] = 0;
            }
        }
        self.harris_at(ev.x as i32, ev.y as i32)
    }

    fn name(&self) -> &'static str {
        "eHarris"
    }

    fn ops_per_event(&self) -> f64 {
        // dense reference cost (harris_at_dense): the Fig. 1(b)
        // throughput anchor models the published eHarris — two dense 5x5
        // Sobel stencils over the GxG gradient patch (2*G²*K² = 1250
        // MACs), the structure tensor, the LxL gather and the score.
        // This port's optimized separable cost (700 stencil MACs) is
        // ops_per_event_separable().
        let sobel = (G * G * K * K) as f64 * 2.0;
        let tensor = (G * G) as f64 * 3.0;
        let gather = (L * L) as f64;
        sobel + tensor + gather + 10.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_scores_above_edge_and_noise() {
        let mut d = EHarris::new(Resolution::TEST64);
        // draw an L-corner: horizontal + vertical strokes meeting at (30,30)
        for i in 0..12u16 {
            d.score(&Event::on(30 - i, 30, i as u64));
            d.score(&Event::on(30, 30 - i, 100 + i as u64));
        }
        let corner = d.score(&Event::on(30, 30, 1000));
        let edge = d.score(&Event::on(24, 30, 1001));
        let flat = d.score(&Event::on(50, 50, 1002));
        assert!(corner > edge, "corner {corner} <= edge {edge}");
        assert!(corner > flat, "corner {corner} <= flat {flat}");
    }

    #[test]
    fn separable_matches_dense_within_f32_tolerance() {
        // pseudo-random binary surface, then compare both stencil forms
        // everywhere, including every border and corner position
        let res = Resolution::TEST64;
        let mut d = EHarris::with_params(res, 4000, EHarris::DEFAULT_K);
        let mut t = 0u64;
        let mut state = 0x12345u64;
        for _ in 0..3000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = ((state >> 33) % 64) as u16;
            let y = ((state >> 17) % 64) as u16;
            t += 1;
            d.score(&Event::on(x, y, t));
        }
        let mut checked = 0usize;
        for y in 0..64i32 {
            for x in 0..64i32 {
                let dense = d.harris_at_dense(x, y);
                let sep = d.harris_at(x, y);
                let tol = 1e-4 * (1.0 + dense.abs());
                assert!(
                    (dense - sep).abs() <= tol,
                    "({x},{y}): dense {dense} vs separable {sep}"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, 64 * 64);
    }

    #[test]
    fn separable_preserves_corner_decisions() {
        // the decision-relevant ordering (corner > edge > flat) must be
        // identical between the two stencil forms
        let mut d = EHarris::new(Resolution::TEST64);
        for i in 0..12u16 {
            d.score(&Event::on(30 - i, 30, i as u64));
            d.score(&Event::on(30, 30 - i, 100 + i as u64));
        }
        let dense = [
            d.harris_at_dense(30, 30),
            d.harris_at_dense(24, 30),
            d.harris_at_dense(50, 50),
        ];
        let sep = [d.harris_at(30, 30), d.harris_at(24, 30), d.harris_at(50, 50)];
        assert!(dense[0] > dense[1] && dense[1] >= dense[2]);
        assert!(sep[0] > sep[1] && sep[1] >= sep[2]);
    }

    #[test]
    fn with_params_configures_window_and_k() {
        let d = EHarris::with_params(Resolution::TEST64, 500, 0.06);
        assert_eq!(d.window(), 500);
        assert!((d.k - 0.06).abs() < 1e-9);
        // a zero window clamps to 1 instead of evicting everything
        assert_eq!(EHarris::with_params(Resolution::TEST64, 0, 0.04).window(), 1);
    }

    #[test]
    fn window_evicts_oldest() {
        let mut d = EHarris::with_params(Resolution::TEST64, 3, EHarris::DEFAULT_K);
        d.score(&Event::on(1, 1, 0));
        d.score(&Event::on(2, 2, 1));
        d.score(&Event::on(3, 3, 2));
        d.score(&Event::on(4, 4, 3)); // evicts (1,1)
        assert_eq!(d.surface[d.res.index(1, 1)], 0);
        assert_eq!(d.surface[d.res.index(4, 4)], 1);
        assert_eq!(d.fifo.len(), 3);
    }

    #[test]
    fn duplicate_pixel_not_double_counted() {
        let mut d = EHarris::new(Resolution::TEST64);
        d.score(&Event::on(5, 5, 0));
        d.score(&Event::on(5, 5, 1));
        assert_eq!(d.fifo.len(), 1);
    }

    #[test]
    fn throughput_well_below_conventional_luvharris() {
        // Fig. 1(b): eHarris max throughput stays far below the 2.6 Meps
        // of the conventional TOS update.
        let d = EHarris::new(Resolution::DAVIS240);
        let t = super::super::max_throughput_eps(d.ops_per_event(), 500e6);
        assert!(t < 1.0e6, "eHarris throughput {t}");
        assert!(t > 0.05e6, "implausibly slow {t}");
    }

    #[test]
    fn fig1b_anchor_quotes_dense_cost() {
        // the trait cost model is the paper's dense baseline (2·G²·K² =
        // 1250 stencil MACs); the separable cost is what this port runs
        // (2·(G·L + G·G)·K = 700 stencil MACs) — and the "1250 → 350"
        // claim this replaces was arithmetically wrong
        let d = EHarris::new(Resolution::DAVIS240);
        assert_eq!(d.ops_per_event(), (1250 + 75 + 81 + 10) as f64);
        assert_eq!(d.ops_per_event_separable(), (700 + 75 + 81 + 10) as f64);
        assert!(d.ops_per_event() > d.ops_per_event_separable());
    }

    #[test]
    fn border_events_do_not_panic() {
        let mut d = EHarris::new(Resolution::TEST64);
        for (x, y) in [(0, 0), (63, 63), (0, 63), (63, 0)] {
            d.score(&Event::on(x, y, 0));
        }
    }
}
