//! eHarris baseline (Vasco et al. 2016): a full Harris score computed *per
//! event* over a binary surface of the most recent events.
//!
//! Accuracy is good, but the per-event cost — two 5x5 Sobel stencils plus
//! a windowed structure tensor over an LxL neighbourhood — is what caps
//! its throughput at well under 1 Meps in Fig. 1(b).

use std::collections::VecDeque;

use crate::events::{Event, Resolution};

use super::EventScorer;

/// Window size of the binary surface neighbourhood (9x9 as in the paper's
/// reference implementation: 5x5 Sobel valid over a 9x9 patch leaves a 5x5
/// gradient patch for the structure tensor).
const L: usize = 9;
/// Gradient patch side after valid 5x5 Sobel.
const G: usize = L - 4;

/// 5x5 Sobel taps (binomial smooth x central difference), row-major.
fn sobel5() -> ([[f32; 5]; 5], [[f32; 5]; 5]) {
    let smooth = [1.0f32, 4.0, 6.0, 4.0, 1.0];
    let deriv = [-1.0f32, -2.0, 0.0, 2.0, 1.0];
    let mut kx = [[0.0; 5]; 5];
    let mut ky = [[0.0; 5]; 5];
    for r in 0..5 {
        for c in 0..5 {
            kx[r][c] = smooth[r] / 16.0 * deriv[c] / 6.0;
            ky[r][c] = deriv[r] / 6.0 * smooth[c] / 16.0;
        }
    }
    (kx, ky)
}

/// eHarris detector state: binary surface of the last `window` events.
#[derive(Debug)]
pub struct EHarris {
    res: Resolution,
    /// Per-pixel flag: is this pixel among the most recent `window` events?
    surface: Vec<u8>,
    /// FIFO of the active pixels.
    fifo: VecDeque<usize>,
    /// Number of events kept on the binary surface.
    window: usize,
    kx: [[f32; 5]; 5],
    ky: [[f32; 5]; 5],
    /// Harris k.
    k: f32,
}

impl EHarris {
    /// Detector with the standard 2000-event binary surface.
    pub fn new(res: Resolution) -> Self {
        let (kx, ky) = sobel5();
        Self {
            res,
            surface: vec![0; res.pixels()],
            fifo: VecDeque::with_capacity(2001),
            window: 2000,
            kx,
            ky,
            k: 0.04,
        }
    }

    /// Harris response at `(ex, ey)` over the binary surface.
    fn harris_at(&self, ex: i32, ey: i32) -> f64 {
        let half = (L as i32 - 1) / 2;
        // gather the LxL binary patch (zeros outside the sensor)
        let mut patch = [[0.0f32; L]; L];
        for (r, row) in patch.iter_mut().enumerate() {
            for (c, v) in row.iter_mut().enumerate() {
                let x = ex - half + c as i32;
                let y = ey - half + r as i32;
                if self.res.contains(x, y) {
                    *v = self.surface[self.res.index(x as u16, y as u16)] as f32;
                }
            }
        }
        // valid 5x5 Sobel -> GxG gradients
        let mut ix = [[0.0f32; G]; G];
        let mut iy = [[0.0f32; G]; G];
        for r in 0..G {
            for c in 0..G {
                let mut sx = 0.0;
                let mut sy = 0.0;
                for kr in 0..5 {
                    for kc in 0..5 {
                        let v = patch[r + kr][c + kc];
                        sx += v * self.kx[kr][kc];
                        sy += v * self.ky[kr][kc];
                    }
                }
                ix[r][c] = sx;
                iy[r][c] = sy;
            }
        }
        // structure tensor over the whole GxG patch (uniform window)
        let (mut sxx, mut syy, mut sxy) = (0.0f32, 0.0f32, 0.0f32);
        for r in 0..G {
            for c in 0..G {
                sxx += ix[r][c] * ix[r][c];
                syy += iy[r][c] * iy[r][c];
                sxy += ix[r][c] * iy[r][c];
            }
        }
        (sxx * syy - sxy * sxy - self.k * (sxx + syy) * (sxx + syy)) as f64
    }
}

impl EventScorer for EHarris {
    fn score(&mut self, ev: &Event) -> f64 {
        let i = self.res.index(ev.x, ev.y);
        if self.surface[i] == 0 {
            self.surface[i] = 1;
            self.fifo.push_back(i);
            if self.fifo.len() > self.window {
                let old = self.fifo.pop_front().unwrap();
                self.surface[old] = 0;
            }
        }
        self.harris_at(ev.x as i32, ev.y as i32)
    }

    fn name(&self) -> &'static str {
        "eHarris"
    }

    fn ops_per_event(&self) -> f64 {
        // Sobel: G*G*(2*25 MACs) = 25*50; tensor: G*G*3 MACs + score ~ 10.
        let sobel = (G * G) as f64 * 50.0;
        let tensor = (G * G) as f64 * 3.0;
        2.0 * sobel / 2.0 + sobel + tensor + 10.0 // gather + 2 stencils + tensor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_scores_above_edge_and_noise() {
        let mut d = EHarris::new(Resolution::TEST64);
        // draw an L-corner: horizontal + vertical strokes meeting at (30,30)
        for i in 0..12u16 {
            d.score(&Event::on(30 - i, 30, i as u64));
            d.score(&Event::on(30, 30 - i, 100 + i as u64));
        }
        let corner = d.score(&Event::on(30, 30, 1000));
        let edge = d.score(&Event::on(24, 30, 1001));
        let flat = d.score(&Event::on(50, 50, 1002));
        assert!(corner > edge, "corner {corner} <= edge {edge}");
        assert!(corner > flat, "corner {corner} <= flat {flat}");
    }

    #[test]
    fn window_evicts_oldest() {
        let mut d = EHarris::new(Resolution::TEST64);
        d.window = 3;
        d.score(&Event::on(1, 1, 0));
        d.score(&Event::on(2, 2, 1));
        d.score(&Event::on(3, 3, 2));
        d.score(&Event::on(4, 4, 3)); // evicts (1,1)
        assert_eq!(d.surface[d.res.index(1, 1)], 0);
        assert_eq!(d.surface[d.res.index(4, 4)], 1);
        assert_eq!(d.fifo.len(), 3);
    }

    #[test]
    fn duplicate_pixel_not_double_counted() {
        let mut d = EHarris::new(Resolution::TEST64);
        d.score(&Event::on(5, 5, 0));
        d.score(&Event::on(5, 5, 1));
        assert_eq!(d.fifo.len(), 1);
    }

    #[test]
    fn throughput_well_below_conventional_luvharris() {
        // Fig. 1(b): eHarris max throughput is far below the 2.6 Meps of
        // the conventional TOS update.
        let d = EHarris::new(Resolution::DAVIS240);
        let t = super::super::max_throughput_eps(d.ops_per_event(), 500e6);
        assert!(t < 1.0e6, "eHarris throughput {t}");
        assert!(t > 0.05e6, "implausibly slow {t}");
    }

    #[test]
    fn border_events_do_not_panic() {
        let mut d = EHarris::new(Resolution::TEST64);
        for (x, y) in [(0, 0), (63, 63), (0, 63), (63, 0)] {
            d.score(&Event::on(x, y, 0));
        }
    }
}
