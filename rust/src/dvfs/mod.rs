//! Dynamic Voltage & Frequency Scaling — paper Sec. III-B & Fig. 2(b).
//!
//! A moving-window event-rate monitor built from **three round-robin
//! counters**: each counter integrates events for `TW_DVFS / 2`; the
//! pointer advances circularly (`ptr <- (ptr + 1) mod 3`), so at any time
//! one counter is filling while the other two hold the last two completed
//! half-windows — their sum is the event count of the trailing `TW_DVFS`
//! window with 50 % stride, exactly the paper's scheme.
//!
//! The measured rate indexes a voltage/frequency LUT derived from the NMC
//! timing model: the controller picks the *lowest* voltage whose maximum
//! sustainable event rate still exceeds the measured rate by a headroom
//! factor.




use crate::nmc::timing::TimingModel;

/// DVFS parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DvfsConfig {
    /// Moving window TW_DVFS (µs). Paper: 10 ms for driving datasets.
    pub tw_us: u64,
    /// Counter bit width (counters saturate). Paper: 20 bits.
    pub counter_bits: u32,
    /// Headroom factor: required `max_rate(V) >= headroom * measured`.
    pub headroom: f64,
    /// Voltage grid (ascending), defaults to 0.6..=1.2 V in 50 mV steps.
    pub grid_mv: [u32; 13],
}

impl Default for DvfsConfig {
    fn default() -> Self {
        Self {
            tw_us: 10_000,
            counter_bits: 20,
            headroom: 1.2,
            grid_mv: [600, 650, 700, 750, 800, 850, 900, 950, 1000, 1050, 1100, 1150, 1200],
        }
    }
}

/// One LUT row: measured-rate ceiling -> operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    /// Supply voltage (V).
    pub vdd: f64,
    /// NMC clock at this voltage (Hz).
    pub clock_hz: f64,
    /// Max sustainable event rate at this voltage (events/s).
    pub max_rate: f64,
}

/// Build the V/f LUT from the timing model (ascending voltage).
pub fn build_lut(cfg: &DvfsConfig) -> Vec<OperatingPoint> {
    cfg.grid_mv
        .iter()
        .map(|&mv| {
            let vdd = mv as f64 / 1000.0;
            let t = TimingModel::at(vdd);
            OperatingPoint { vdd, clock_hz: t.clock_hz(), max_rate: t.max_event_rate() }
        })
        .collect()
}

/// The three-counter round-robin rate monitor + LUT controller.
#[derive(Debug, Clone)]
pub struct DvfsController {
    cfg: DvfsConfig,
    lut: Vec<OperatingPoint>,
    counters: [u32; 3],
    /// Which counter is currently filling.
    ptr: usize,
    /// End time (µs) of the current half-window.
    half_end_us: u64,
    /// Completed half-window counts (the two not pointed at are valid
    /// after two rotations).
    rotations: u64,
    /// Currently selected operating point (index into lut).
    current: usize,
    /// Voltage switches performed (telemetry).
    pub switches: u64,
}

impl DvfsController {
    /// Controller starting at the highest voltage (safe default until the
    /// first full window completes).
    pub fn new(cfg: DvfsConfig) -> Self {
        let lut = build_lut(&cfg);
        let current = lut.len() - 1;
        Self {
            half_end_us: cfg.tw_us / 2,
            cfg,
            lut,
            counters: [0; 3],
            ptr: 0,
            rotations: 0,
            current,
            switches: 0,
        }
    }

    /// The LUT (for reporting).
    pub fn lut(&self) -> &[OperatingPoint] {
        &self.lut
    }

    /// Currently selected operating point.
    #[inline]
    pub fn operating_point(&self) -> OperatingPoint {
        self.lut[self.current]
    }

    /// Estimated event rate (events/s) from the last two completed
    /// half-windows; `None` until two rotations have happened.
    pub fn estimated_rate(&self) -> Option<f64> {
        if self.rotations < 2 {
            return None;
        }
        let a = self.counters[(self.ptr + 1) % 3] as f64;
        let b = self.counters[(self.ptr + 2) % 3] as f64;
        Some((a + b) / (self.cfg.tw_us as f64 * 1e-6))
    }

    /// Feed one event timestamp (µs). Returns `Some(new_point)` when the
    /// controller switches voltage.
    pub fn on_event(&mut self, t_us: u64) -> Option<OperatingPoint> {
        let switched = self.advance_to(t_us);
        let max = (1u64 << self.cfg.counter_bits) - 1;
        let c = &mut self.counters[self.ptr];
        if (*c as u64) < max {
            *c += 1;
        }
        switched
    }

    /// Bulk path for profile-driven integration (Table I scale): account
    /// `count` events in the current half-window, then rotate past every
    /// half-window boundary up to `t_end_us`.  Equivalent to feeding the
    /// events one by one when they all fall within the current half-window
    /// — which is how [`crate::power::integrate`] steps time.
    pub fn advance_window(&mut self, t_end_us: u64, count: u64) -> Option<OperatingPoint> {
        let max = (1u64 << self.cfg.counter_bits) - 1;
        let c = &mut self.counters[self.ptr];
        *c = (*c as u64).saturating_add(count).min(max) as u32;
        self.advance_to(t_end_us)
    }

    /// Close every half-window boundary at or before `t_us`. O(1) for
    /// arbitrarily long gaps (an idle stretch, or a recording whose
    /// timestamps start at epoch scale): after three boundary crossings
    /// with no intervening events all counters are zero, so the remaining
    /// boundaries are skipped arithmetically instead of rotating once per
    /// elapsed half-window.
    fn advance_to(&mut self, t_us: u64) -> Option<OperatingPoint> {
        let mut switched = None;
        // rotate through at most three boundaries the normal way — enough
        // to drain any non-zero counters into (then out of) history
        let mut steps = 0;
        while t_us >= self.half_end_us && steps < 3 {
            self.rotate();
            if let Some(op) = self.retarget() {
                switched = Some(op);
            }
            steps += 1;
        }
        if t_us >= self.half_end_us {
            // gap spans further boundaries: all three counters are zero
            // now, so every skipped rotation would observe a zero rate —
            // fast-forward the boundary clock and retarget once
            debug_assert_eq!(self.counters, [0; 3]);
            let half = (self.cfg.tw_us / 2).max(1);
            let skips = (t_us - self.half_end_us) / half + 1;
            self.ptr = (self.ptr + (skips % 3) as usize) % 3;
            self.half_end_us = self.half_end_us.saturating_add(skips.saturating_mul(half));
            self.rotations = self.rotations.saturating_add(skips);
            if let Some(op) = self.retarget() {
                switched = Some(op);
            }
        }
        switched
    }

    /// Advance the round-robin pointer (a half-window boundary).
    fn rotate(&mut self) {
        self.ptr = (self.ptr + 1) % 3;
        self.counters[self.ptr] = 0;
        // saturating: once a crafted timestamp pins the boundary clock at
        // u64::MAX, further rotations must not overflow (work per event
        // stays bounded by the advance_to rotation cap) — and the
        // rotation counter itself must saturate for the same reason (the
        // fast-forward path can saturate it to u64::MAX in one step)
        self.half_end_us = self.half_end_us.saturating_add(self.cfg.tw_us / 2);
        self.rotations = self.rotations.saturating_add(1);
    }

    /// Pick the lowest voltage sustaining the estimated rate with headroom.
    fn retarget(&mut self) -> Option<OperatingPoint> {
        let rate = self.estimated_rate()?;
        let need = rate * self.cfg.headroom;
        let idx = self
            .lut
            .iter()
            .position(|op| op.max_rate >= need)
            .unwrap_or(self.lut.len() - 1);
        if idx != self.current {
            self.current = idx;
            self.switches += 1;
            return Some(self.lut[idx]);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_is_monotone() {
        let lut = build_lut(&DvfsConfig::default());
        assert_eq!(lut.len(), 13);
        for w in lut.windows(2) {
            assert!(w[0].vdd < w[1].vdd);
            assert!(w[0].max_rate < w[1].max_rate);
            assert!(w[0].clock_hz < w[1].clock_hz);
        }
        // endpoints match the paper
        assert!((lut[0].max_rate / 1e6 - 4.93).abs() < 0.1);
        assert!((lut[12].max_rate / 1e6 - 63.1).abs() < 0.2);
    }

    #[test]
    fn starts_at_nominal_voltage() {
        let c = DvfsController::new(DvfsConfig::default());
        assert!((c.operating_point().vdd - 1.2).abs() < 1e-9);
        assert!(c.estimated_rate().is_none());
    }

    #[test]
    fn estimates_constant_rate() {
        let mut c = DvfsController::new(DvfsConfig::default());
        // 1 event / 100 µs = 10 keps for 50 ms
        for i in 0..500u64 {
            c.on_event(i * 100);
        }
        let est = c.estimated_rate().unwrap();
        assert!((est - 10_000.0).abs() / 10_000.0 < 0.05, "est {est}");
    }

    #[test]
    fn low_rate_drops_voltage_to_minimum() {
        let mut c = DvfsController::new(DvfsConfig::default());
        for i in 0..1000u64 {
            c.on_event(i * 1000); // 1 keps
        }
        assert!((c.operating_point().vdd - 0.6).abs() < 1e-9);
        assert!(c.switches >= 1);
    }

    #[test]
    fn high_rate_keeps_high_voltage() {
        let mut c = DvfsController::new(DvfsConfig::default());
        // 50 Meps: one event every 0.02 µs -> bursts of 50 per µs
        let mut t = 0u64;
        for _ in 0..2_000_000u64 {
            c.on_event(t / 50);
            t += 1;
        }
        assert!(c.operating_point().vdd > 1.1, "vdd {}", c.operating_point().vdd);
    }

    #[test]
    fn rate_step_triggers_switch_within_one_window() {
        let cfg = DvfsConfig::default();
        let mut c = DvfsController::new(cfg);
        // quiet phase: 1 keps for 100 ms -> minimum voltage
        let mut t = 0u64;
        for _ in 0..100 {
            c.on_event(t);
            t += 1000;
        }
        assert!((c.operating_point().vdd - 0.6).abs() < 1e-9);
        // burst: 20 Meps
        let mut last_switch_t = None;
        for i in 0..400_000u64 {
            if c.on_event(t).is_some() {
                last_switch_t = Some(t);
            }
            if i % 20 == 0 {
                t += 1; // 20 events per µs = 20 Meps
            }
        }
        let up_t = last_switch_t.expect("must switch up");
        assert!(c.operating_point().vdd >= 0.9);
        // switch happened within ~1.5 windows of burst onset
        assert!(up_t - 100_000 <= 15_000 + cfg.tw_us * 3 / 2, "switch at {up_t}");
    }

    #[test]
    fn advance_window_equivalent_to_event_feed() {
        // constant 10 keps: window path and event path settle on the same
        // operating point and rate estimate
        let mut by_event = DvfsController::new(DvfsConfig::default());
        for i in 0..2000u64 {
            by_event.on_event(i * 100);
        }
        let mut by_window = DvfsController::new(DvfsConfig::default());
        let half = DvfsConfig::default().tw_us / 2;
        let mut t = 0u64;
        while t < 200_000 {
            by_window.advance_window(t + half, 50); // 50 events / 5 ms
            t += half;
        }
        assert_eq!(
            by_event.operating_point().vdd,
            by_window.operating_point().vdd
        );
        let (a, b) = (by_event.estimated_rate().unwrap(), by_window.estimated_rate().unwrap());
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }

    #[test]
    fn counters_saturate_not_wrap() {
        let cfg = DvfsConfig { counter_bits: 4, ..Default::default() };
        let mut c = DvfsController::new(cfg);
        for _ in 0..100 {
            c.on_event(0);
        }
        assert_eq!(c.counters[c.ptr], 15);
    }

    #[test]
    fn epoch_scale_first_timestamp_is_o1() {
        // real recordings carry wall-clock µs timestamps; the first event
        // used to spin the rotation loop ~2e11 times before processing
        let mut c = DvfsController::new(DvfsConfig::default());
        let t0 = 1_000_000_000_000_000u64; // 1e15 µs
        for i in 0..1000u64 {
            c.on_event(t0 + i * 100); // 10 keps after the jump
        }
        assert!((c.operating_point().vdd - 0.6).abs() < 1e-9);
        let est = c.estimated_rate().unwrap();
        assert!((est - 10_000.0).abs() / 10_000.0 < 0.05, "est {est}");
    }

    #[test]
    fn long_idle_gap_is_o1_and_resets_rate() {
        let mut c = DvfsController::new(DvfsConfig::default());
        // busy phase: 30 Meps for 30 ms -> high voltage
        let mut t = 0u64;
        for _ in 0..900_000u64 {
            c.on_event(t / 30);
            t += 1;
        }
        assert!(c.operating_point().vdd > 0.8, "vdd {}", c.operating_point().vdd);
        // ten-minute silence, then one event: O(1), history fully drained
        let resume = 30_000 + 600_000_000u64;
        c.on_event(resume);
        assert!(c.estimated_rate().unwrap() < 1.0);
        assert!((c.operating_point().vdd - 0.6).abs() < 1e-9);
    }

    #[test]
    fn timestamps_at_u64_max_do_not_overflow() {
        // crafted recordings can carry any u64 timestamp; the boundary
        // clock saturates instead of overflowing or spinning
        let mut c = DvfsController::new(DvfsConfig::default());
        c.on_event(0);
        c.on_event(u64::MAX - 1);
        c.on_event(u64::MAX);
        c.on_event(u64::MAX);
        assert!((c.operating_point().vdd - 0.6).abs() < 1e-9);
    }

    #[test]
    fn fast_forward_matches_rotation_loop_state() {
        // cross-check the O(1) skip against per-boundary rotation for a
        // gap short enough to iterate
        let cfg = DvfsConfig::default();
        let half = cfg.tw_us / 2;
        for gap_halves in [3u64, 4, 5, 7, 10, 31] {
            let mut skipped = DvfsController::new(cfg);
            skipped.on_event(0);
            skipped.on_event(gap_halves * half + 3);
            let mut stepped = DvfsController::new(cfg);
            stepped.on_event(0);
            // walk boundary by boundary so the capped loop handles each
            for k in 1..=gap_halves {
                stepped.on_event(k * half);
            }
            stepped.on_event(gap_halves * half + 3);
            assert_eq!(skipped.ptr, stepped.ptr, "gap {gap_halves}");
            assert_eq!(skipped.half_end_us, stepped.half_end_us, "gap {gap_halves}");
            assert_eq!(
                skipped.operating_point().vdd,
                stepped.operating_point().vdd,
                "gap {gap_halves}"
            );
        }
    }

    #[test]
    fn round_robin_pointer_rotates_mod_3() {
        let mut c = DvfsController::new(DvfsConfig::default());
        let tw = c.cfg.tw_us;
        assert_eq!(c.ptr, 0);
        c.on_event(tw / 2); // first half-window boundary
        assert_eq!(c.ptr, 1);
        c.on_event(tw); // second
        assert_eq!(c.ptr, 2);
        c.on_event(tw * 3 / 2); // third -> wraps
        assert_eq!(c.ptr, 0);
    }
}
