//! Binary + text codecs for event streams.
//!
//! * **Binary**: a fixed 13-byte little-endian record
//!   `x:u16 | y:u16 | t:u64 | p:u8` with an `"NMCTOSEV"` + version header —
//!   a stand-in for AEDAT/EVT that keeps dataset files self-describing.
//! * **Text**: `t x y p` per line (the format used by the Mueggler et al.
//!   event-camera dataset the paper evaluates on), for interop with
//!   published tooling.

use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};

use anyhow::{bail, Context, Result};

use super::{Event, Polarity};

const MAGIC: &[u8; 8] = b"NMCTOSEV";
const VERSION: u8 = 1;
const RECORD_BYTES: usize = 13;

/// Write a stream of events in the binary container format.
pub fn write_binary<W: Write>(w: W, events: &[Event]) -> Result<()> {
    let mut w = BufWriter::new(w);
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION])?;
    w.write_all(&(events.len() as u64).to_le_bytes())?;
    for e in events {
        w.write_all(&e.x.to_le_bytes())?;
        w.write_all(&e.y.to_le_bytes())?;
        w.write_all(&e.t.to_le_bytes())?;
        w.write_all(&[e.p.bit()])?;
    }
    w.flush()?;
    Ok(())
}

/// Read a stream of events from the binary container format.
pub fn read_binary<R: Read>(r: R) -> Result<Vec<Event>> {
    let mut r = BufReader::new(r);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("truncated header")?;
    if &magic != MAGIC {
        bail!("bad magic: {:?}", magic);
    }
    let mut ver = [0u8; 1];
    r.read_exact(&mut ver)?;
    if ver[0] != VERSION {
        bail!("unsupported version {}", ver[0]);
    }
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let n = u64::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n * RECORD_BYTES];
    r.read_exact(&mut buf).context("truncated records")?;
    let mut events = Vec::with_capacity(n);
    for rec in buf.chunks_exact(RECORD_BYTES) {
        events.push(Event {
            x: u16::from_le_bytes([rec[0], rec[1]]),
            y: u16::from_le_bytes([rec[2], rec[3]]),
            t: u64::from_le_bytes(rec[4..12].try_into().unwrap()),
            p: Polarity::from_bit(rec[12]),
        });
    }
    Ok(events)
}

/// Write events as `t_seconds x y p` lines (Mueggler dataset layout).
pub fn write_text<W: Write>(w: W, events: &[Event]) -> Result<()> {
    let mut w = BufWriter::new(w);
    for e in events {
        writeln!(w, "{:.6} {} {} {}", e.t as f64 * 1e-6, e.x, e.y, e.p.bit())?;
    }
    w.flush()?;
    Ok(())
}

/// Read events from `t_seconds x y p` lines.
pub fn read_text<R: Read>(r: R) -> Result<Vec<Event>> {
    let r = BufReader::new(r);
    let mut events = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_ascii_whitespace();
        let parse = |tok: Option<&str>, what: &str| -> Result<f64> {
            tok.with_context(|| format!("line {}: missing {what}", lineno + 1))?
                .parse::<f64>()
                .with_context(|| format!("line {}: bad {what}", lineno + 1))
        };
        let t = parse(it.next(), "t")?;
        let x = parse(it.next(), "x")? as u16;
        let y = parse(it.next(), "y")? as u16;
        let p = parse(it.next(), "p")? as u8;
        events.push(Event::new(x, y, (t * 1e6).round() as u64, Polarity::from_bit(p)));
    }
    Ok(events)
}

/// Convenience: binary round-trip through a file path.
pub fn save(path: &std::path::Path, events: &[Event]) -> Result<()> {
    write_binary(std::fs::File::create(path)?, events)
}

/// Convenience: load a binary event file.
pub fn load(path: &std::path::Path) -> Result<Vec<Event>> {
    read_binary(std::fs::File::open(path)?)
}

/// Errors in this module are [`anyhow::Error`]; keep an io alias for callers.
pub type IoResult<T> = io::Result<T>;

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        vec![
            Event::on(0, 0, 0),
            Event::off(239, 179, 1_000_000),
            Event::on(120, 90, u64::MAX / 2),
        ]
    }

    #[test]
    fn binary_roundtrip() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        let back = read_binary(&buf[..]).unwrap();
        assert_eq!(back, sample());
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf[0] = b'X';
        assert!(read_binary(&buf[..]).is_err());
    }

    #[test]
    fn binary_rejects_truncation() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf.truncate(buf.len() - 1);
        assert!(read_binary(&buf[..]).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let evs = vec![Event::on(10, 20, 1_500_000), Event::off(30, 40, 2_000_000)];
        let mut buf = Vec::new();
        write_text(&mut buf, &evs).unwrap();
        let back = read_text(&buf[..]).unwrap();
        assert_eq!(back, evs);
    }

    #[test]
    fn text_skips_comments_and_blanks() {
        let input = "# comment\n\n0.000001 1 2 1\n";
        let evs = read_text(input.as_bytes()).unwrap();
        assert_eq!(evs, vec![Event::on(1, 2, 1)]);
    }

    #[test]
    fn text_reports_bad_line() {
        let err = read_text("0.5 nope 2 1\n".as_bytes()).unwrap_err();
        assert!(format!("{err}").contains("line 1"));
    }

    #[test]
    fn empty_streams() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &[]).unwrap();
        assert!(read_binary(&buf[..]).unwrap().is_empty());
        assert!(read_text("".as_bytes()).unwrap().is_empty());
    }
}
