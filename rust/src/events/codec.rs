//! Binary + text codecs for event streams, plus the real camera-dump
//! formats in the [`aedat4`] and [`evt`] submodules.
//!
//! * **Binary**: a fixed 13-byte little-endian record
//!   `x:u16 | y:u16 | t:u64 | p:u8` with an `"NMCTOSEV"` + version header —
//!   the crate's own self-describing dataset container.
//! * **Text**: `t x y p` per line (the format used by the Mueggler et al.
//!   event-camera dataset the paper evaluates on), for interop with
//!   published tooling.
//! * **[`aedat4`]**: the DV / iniVation AEDAT4 packet container
//!   (uncompressed subset).
//! * **[`evt`]**: Prophesee EVT2/EVT3 raw word streams.
//!
//! Both codecs decode **incrementally** through the streaming sources
//! ([`BinaryStreamSource`], [`TextStreamSource`], see
//! [`super::source::EventSource`]): the header's record count is treated
//! as untrusted input — a corrupt or malicious length field produces a
//! clean error instead of a huge preallocation — and the load-all
//! [`read_binary`]/[`read_text`] helpers are thin collectors over the
//! same decoders.

// Untrusted-input decode surface: promoted `clippy::pedantic` tier
// (ISSUE 10), same policy as `eval` — every allow is a deliberate,
// reasoned opt-out and the `-D warnings` clippy lane keeps the rest at
// zero. See `eval/mod.rs` for the rationale of the shared entries.
#![warn(clippy::pedantic)]
#![allow(
    // wire fields widen/narrow with `as` against validated bounds; the
    // record layout fixes the ranges (x,y:u16 t:u64 p:u8)
    clippy::cast_precision_loss,
    clippy::cast_possible_truncation,
    clippy::cast_sign_loss,
    clippy::cast_lossless,
    clippy::missing_errors_doc,
    clippy::missing_panics_doc,
    clippy::must_use_candidate,
    clippy::module_name_repetitions,
    clippy::doc_markdown,
    clippy::wildcard_imports,
    clippy::similar_names,
    clippy::too_many_lines,
    clippy::semicolon_if_nothing_returned,
    clippy::uninlined_format_args,
    clippy::items_after_statements,
    clippy::unreadable_literal,
    clippy::match_same_arms,
    clippy::single_match_else,
    clippy::if_not_else,
    clippy::redundant_closure_for_method_calls,
    clippy::map_unwrap_or,
    clippy::explicit_iter_loop,
    clippy::manual_let_else,
    clippy::ignored_unit_patterns,
    clippy::missing_fields_in_debug
)]

pub mod aedat4;
pub mod evt;

use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use super::source::{DEFAULT_CHUNK_EVENTS, EventSource};
use super::{Event, Polarity};

pub(crate) const MAGIC: &[u8; 8] = b"NMCTOSEV";
const VERSION: u8 = 1;
const RECORD_BYTES: usize = 13;

/// Upper bound on events decoded per chunk (~52 MiB of binary records):
/// keeps decode buffers bounded whatever chunk size a caller asks for —
/// shared by every streaming decoder in this module tree.
pub(crate) const MAX_CHUNK_EVENTS: usize = 1 << 22;

/// Write a stream of events in the binary container format.
pub fn write_binary<W: Write>(w: W, events: &[Event]) -> Result<()> {
    let mut w = BufWriter::new(w);
    w.write_all(MAGIC)?;
    w.write_all(&[VERSION])?;
    w.write_all(&(events.len() as u64).to_le_bytes())?;
    for e in events {
        w.write_all(&e.x.to_le_bytes())?;
        w.write_all(&e.y.to_le_bytes())?;
        w.write_all(&e.t.to_le_bytes())?;
        w.write_all(&[e.p.bit()])?;
    }
    w.flush()?;
    Ok(())
}

#[inline]
fn decode_record(rec: &[u8]) -> Event {
    Event {
        x: u16::from_le_bytes([rec[0], rec[1]]),
        y: u16::from_le_bytes([rec[2], rec[3]]),
        // nmc-analyze: allow(error-discipline) -- rec[4..12] is exactly 8 bytes, so the slice-to-array try_into is infallible
        t: u64::from_le_bytes(rec[4..12].try_into().unwrap()),
        p: Polarity::from_bit(rec[12]),
    }
}

/// Incremental decoder for the binary container: parses the header
/// eagerly (validating magic + version), then yields records in bounded
/// chunks. Memory stays O(chunk) no matter what the header's count field
/// claims — short data errors with the shortfall, trailing data after
/// the declared count errors instead of being silently ignored.
pub struct BinaryStreamSource<R: Read> {
    r: BufReader<R>,
    /// Records the header still owes us.
    remaining: u64,
    declared: u64,
    chunk_events: usize,
    /// Reused record buffer (≤ chunk_events × 13 bytes).
    buf: Vec<u8>,
    done: bool,
}

impl<R: Read> BinaryStreamSource<R> {
    /// Parse the container header and set up chunked decoding.
    pub fn new(inner: R, chunk_events: usize) -> Result<Self> {
        let mut r = BufReader::new(inner);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("truncated header")?;
        if &magic != MAGIC {
            bail!("bad magic: {magic:?}");
        }
        let mut ver = [0u8; 1];
        r.read_exact(&mut ver).context("truncated header")?;
        if ver[0] != VERSION {
            bail!("unsupported version {}", ver[0]);
        }
        let mut len = [0u8; 8];
        r.read_exact(&mut len).context("truncated header")?;
        let declared = u64::from_le_bytes(len);
        Ok(Self {
            r,
            remaining: declared,
            declared,
            // cap the chunk so even a pathological caller-supplied size
            // cannot turn the untrusted header count into a preallocation
            chunk_events: chunk_events.clamp(1, MAX_CHUNK_EVENTS),
            buf: Vec::new(),
            done: false,
        })
    }

    /// Record count the (untrusted) header declared.
    pub fn declared_len(&self) -> u64 {
        self.declared
    }
}

impl<R: Read> EventSource for BinaryStreamSource<R> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        if self.done {
            return Ok(0);
        }
        if self.remaining == 0 {
            // declared count exhausted: any trailing byte is corruption
            let mut probe = [0u8; 1];
            let n = self.r.read(&mut probe)?;
            ensure!(
                n == 0,
                "trailing data after the {} records the header declared",
                self.declared
            );
            self.done = true;
            return Ok(0);
        }
        let take = self.remaining.min(self.chunk_events as u64) as usize;
        self.buf.resize(take * RECORD_BYTES, 0);
        self.r.read_exact(&mut self.buf).with_context(|| {
            format!(
                "truncated records: header declared {}, at least {} missing",
                self.declared, self.remaining
            )
        })?;
        out.reserve(take);
        for rec in self.buf.chunks_exact(RECORD_BYTES) {
            out.push(decode_record(rec));
        }
        self.remaining -= take as u64;
        Ok(take)
    }

    fn size_hint(&self) -> Option<usize> {
        // the header is untrusted; only a hint, never a preallocation size
        usize::try_from(self.remaining).ok()
    }
}

/// Decode one complete in-memory binary container (header + all
/// records), appending to `out` and returning the record count. Errors
/// on bad magic/version, on a body that is not a whole number of
/// records, and on a header count that disagrees with the body length.
///
/// This is the framed network path
/// ([`FramedStreamSource`](super::source::FramedStreamSource)): the
/// frame length already bounds memory, so records decode straight from
/// the payload slice — no reader, no per-call record buffer.
pub(crate) fn decode_container(data: &[u8], out: &mut Vec<Event>) -> Result<usize> {
    const HEADER_BYTES: usize = 17; // magic(8) + version(1) + count(8)
    ensure!(data.len() >= HEADER_BYTES, "truncated container header");
    ensure!(&data[..8] == MAGIC, "bad magic: {:?}", &data[..8]);
    ensure!(data[8] == VERSION, "unsupported version {}", data[8]);
    // nmc-analyze: allow(error-discipline) -- data.len() >= HEADER_BYTES was just ensured and 9..17 is exactly 8 bytes, so this cannot fail
    let declared = u64::from_le_bytes(data[9..HEADER_BYTES].try_into().unwrap());
    let body = &data[HEADER_BYTES..];
    let records = body.len() / RECORD_BYTES;
    ensure!(
        body.len() % RECORD_BYTES == 0 && declared == records as u64,
        "container length mismatch: header declares {declared} records over {} body bytes",
        body.len()
    );
    out.reserve(records);
    for rec in body.chunks_exact(RECORD_BYTES) {
        out.push(decode_record(rec));
    }
    Ok(records)
}

/// Read a stream of events from the binary container format (load-all
/// convenience over [`BinaryStreamSource`]).
pub fn read_binary<R: Read>(r: R) -> Result<Vec<Event>> {
    let mut src = BinaryStreamSource::new(r, DEFAULT_CHUNK_EVENTS)?;
    let mut events = Vec::new();
    while src.next_chunk(&mut events)? > 0 {}
    Ok(events)
}

/// Write events as `t_seconds x y p` lines (Mueggler dataset layout).
pub fn write_text<W: Write>(w: W, events: &[Event]) -> Result<()> {
    let mut w = BufWriter::new(w);
    for e in events {
        writeln!(w, "{:.6} {} {} {}", e.t as f64 * 1e-6, e.x, e.y, e.p.bit())?;
    }
    w.flush()?;
    Ok(())
}

/// Parse one `t x y p` line (1-based `lineno` for error messages);
/// `Ok(None)` for blank/comment lines. Out-of-range coordinates are
/// line-numbered errors, never silently saturated into the sensor array.
fn parse_text_line(lineno: usize, line: &str) -> Result<Option<Event>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut it = line.split_ascii_whitespace();
    let mut parse = |what: &str| -> Result<f64> {
        it.next()
            .with_context(|| format!("line {lineno}: missing {what}"))?
            .parse::<f64>()
            .with_context(|| format!("line {lineno}: bad {what}"))
    };
    let t = parse("t")?;
    ensure!(t.is_finite() && t >= 0.0, "line {lineno}: t {t} out of range");
    let x = parse("x")?;
    let y = parse("y")?;
    let p = parse("p")?;
    let coord = |v: f64, what: &str| -> Result<u16> {
        ensure!(
            v.is_finite() && (0.0..=u16::MAX as f64).contains(&v),
            "line {lineno}: {what} {v} out of range 0..={}",
            u16::MAX
        );
        Ok(v as u16)
    };
    let x = coord(x, "x")?;
    let y = coord(y, "y")?;
    ensure!(
        p.is_finite() && (0.0..=255.0).contains(&p),
        "line {lineno}: p {p} out of range 0..=255"
    );
    // the µs timestamp must fit u64 — no silent saturation to u64::MAX
    let t_us = (t * 1e6).round();
    ensure!(t_us < u64::MAX as f64, "line {lineno}: t {t} out of range");
    Ok(Some(Event::new(x, y, t_us as u64, Polarity::from_bit(p as u8))))
}

/// Line-streaming decoder for the `t_seconds x y p` text format.
pub struct TextStreamSource<R: Read> {
    lines: io::Lines<BufReader<R>>,
    lineno: usize,
    chunk_events: usize,
}

impl<R: Read> TextStreamSource<R> {
    /// Stream a text recording, `chunk_events` events per chunk (clamped
    /// to the same per-chunk bound as the binary decoder, so `--input`
    /// memory stays bounded for text recordings too).
    pub fn new(inner: R, chunk_events: usize) -> Self {
        Self {
            lines: BufReader::new(inner).lines(),
            lineno: 0,
            chunk_events: chunk_events.clamp(1, MAX_CHUNK_EVENTS),
        }
    }
}

impl<R: Read> EventSource for TextStreamSource<R> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        let mut appended = 0usize;
        while appended < self.chunk_events {
            let Some(line) = self.lines.next() else { break };
            self.lineno += 1;
            if let Some(ev) = parse_text_line(self.lineno, &line?)? {
                out.push(ev);
                appended += 1;
            }
        }
        Ok(appended)
    }
}

/// Read events from `t_seconds x y p` lines (load-all convenience over
/// [`TextStreamSource`]).
pub fn read_text<R: Read>(r: R) -> Result<Vec<Event>> {
    let mut src = TextStreamSource::new(r, DEFAULT_CHUNK_EVENTS);
    let mut events = Vec::new();
    while src.next_chunk(&mut events)? > 0 {}
    Ok(events)
}

/// Convenience: binary round-trip through a file path.
pub fn save(path: &std::path::Path, events: &[Event]) -> Result<()> {
    write_binary(std::fs::File::create(path)?, events)
}

/// Convenience: load a binary event file.
pub fn load(path: &std::path::Path) -> Result<Vec<Event>> {
    read_binary(std::fs::File::open(path)?)
}

/// Errors in this module are [`anyhow::Error`]; keep an io alias for callers.
pub type IoResult<T> = io::Result<T>;

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        vec![
            Event::on(0, 0, 0),
            Event::off(239, 179, 1_000_000),
            Event::on(120, 90, u64::MAX / 2),
        ]
    }

    #[test]
    fn binary_roundtrip() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        let back = read_binary(&buf[..]).unwrap();
        assert_eq!(back, sample());
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf[0] = b'X';
        assert!(read_binary(&buf[..]).is_err());
    }

    #[test]
    fn binary_rejects_truncation() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf.truncate(buf.len() - 1);
        let err = read_binary(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated records"), "{err:#}");
    }

    #[test]
    fn binary_rejects_huge_declared_count_without_preallocating() {
        // header claims u64::MAX records over a 3-record body: must be a
        // clean error, not a capacity-overflow abort or an OOM
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf[9..17].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = read_binary(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated records"), "{err:#}");
    }

    #[test]
    fn binary_rejects_undersized_declared_count() {
        // header claims 2 records but 3 follow: the extra one is trailing
        // data, not silently dropped
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        buf[9..17].copy_from_slice(&2u64.to_le_bytes());
        let err = read_binary(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("trailing data"), "{err:#}");
    }

    #[test]
    fn binary_stream_chunks_equal_load_all() {
        let events: Vec<Event> =
            (0..1000).map(|i| Event::on((i % 64) as u16, (i % 48) as u16, i as u64)).collect();
        let mut buf = Vec::new();
        write_binary(&mut buf, &events).unwrap();
        for chunk in [1usize, 7, 256, 1000, 4096] {
            let mut src = BinaryStreamSource::new(&buf[..], chunk).unwrap();
            assert_eq!(src.declared_len(), 1000);
            let mut out = Vec::new();
            while src.next_chunk(&mut out).unwrap() > 0 {}
            assert_eq!(out, events, "chunk {chunk}");
        }
    }

    #[test]
    fn decode_container_roundtrip_and_rejects_corruption() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &sample()).unwrap();
        let mut out = Vec::new();
        assert_eq!(decode_container(&buf, &mut out).unwrap(), 3);
        assert_eq!(out, sample());

        // truncated body
        let mut t = buf.clone();
        t.truncate(t.len() - 1);
        assert!(decode_container(&t, &mut Vec::new()).is_err());
        // header count disagrees with body length
        let mut m = buf.clone();
        m[9..17].copy_from_slice(&9u64.to_le_bytes());
        assert!(decode_container(&m, &mut Vec::new()).is_err());
        // bad magic / truncated header
        let mut b = buf.clone();
        b[0] = b'X';
        assert!(decode_container(&b, &mut Vec::new()).is_err());
        assert!(decode_container(&buf[..10], &mut Vec::new()).is_err());

        // empty container (keep-alive frame payload) decodes to 0 events
        let mut empty = Vec::new();
        write_binary(&mut empty, &[]).unwrap();
        assert_eq!(decode_container(&empty, &mut Vec::new()).unwrap(), 0);
    }

    #[test]
    fn text_roundtrip() {
        let evs = vec![Event::on(10, 20, 1_500_000), Event::off(30, 40, 2_000_000)];
        let mut buf = Vec::new();
        write_text(&mut buf, &evs).unwrap();
        let back = read_text(&buf[..]).unwrap();
        assert_eq!(back, evs);
    }

    #[test]
    fn text_skips_comments_and_blanks() {
        let input = "# comment\n\n0.000001 1 2 1\n";
        let evs = read_text(input.as_bytes()).unwrap();
        assert_eq!(evs, vec![Event::on(1, 2, 1)]);
    }

    #[test]
    fn text_reports_bad_line() {
        let err = read_text("0.5 nope 2 1\n".as_bytes()).unwrap_err();
        assert!(format!("{err}").contains("line 1"));
    }

    #[test]
    fn text_rejects_out_of_range_coordinates() {
        // x = 70000 does not fit u16: used to saturate into a
        // valid-looking event, must be a line-numbered error
        let err = read_text("0.5 70000 2 1\n".as_bytes()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 1") && msg.contains("out of range"), "{msg}");

        let err = read_text("0.000001 1 2 1\n0.5 3 -4 1\n".as_bytes()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("out of range"), "{msg}");

        assert!(read_text("-0.5 1 2 1\n".as_bytes()).is_err());
        assert!(read_text("0.5 1 2 900\n".as_bytes()).is_err());
        // t too large for a u64 µs timestamp must error, not saturate
        assert!(read_text("1e300 1 2 1\n".as_bytes()).is_err());
    }

    #[test]
    fn empty_streams() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &[]).unwrap();
        assert!(read_binary(&buf[..]).unwrap().is_empty());
        assert!(read_text("".as_bytes()).unwrap().is_empty());
    }
}
