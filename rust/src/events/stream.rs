//! Event-stream utilities: rate statistics, windowed iteration, merging and
//! validation — the pieces every experiment harness shares.

use super::{Event, Resolution};

/// Summary statistics of an event stream (drives Table I / Fig. 8).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStats {
    /// Total number of events.
    pub count: usize,
    /// Stream duration in seconds (last t − first t).
    pub duration_s: f64,
    /// Mean event rate in events/s.
    pub mean_rate: f64,
    /// Peak event rate in events/s, measured over `window_s` windows.
    pub peak_rate: f64,
    /// Window length used for the peak measurement.
    pub window_s: f64,
}

/// Compute stream statistics with a fixed-window peak-rate estimate.
pub fn stats(events: &[Event], window_s: f64) -> StreamStats {
    if events.is_empty() {
        return StreamStats { count: 0, duration_s: 0.0, mean_rate: 0.0, peak_rate: 0.0, window_s };
    }
    let t0 = events.first().unwrap().t;
    let t1 = events.last().unwrap().t;
    let duration_s = ((t1 - t0) as f64 * 1e-6).max(1e-9);
    let mean_rate = events.len() as f64 / duration_s;
    let win_us = (window_s * 1e6) as u64;
    let mut peak = 0usize;
    let mut lo = 0usize;
    for hi in 0..events.len() {
        while events[hi].t - events[lo].t > win_us {
            lo += 1;
        }
        peak = peak.max(hi - lo + 1);
    }
    StreamStats {
        count: events.len(),
        duration_s,
        mean_rate,
        peak_rate: peak as f64 / window_s,
        window_s,
    }
}

/// Iterate a stream in fixed-duration windows (non-overlapping).
///
/// Yields `(window_start_us, &[Event])` slices; empty windows are skipped.
pub struct Windows<'a> {
    events: &'a [Event],
    window_us: u64,
    cursor: usize,
}

impl<'a> Windows<'a> {
    /// Create a window iterator over a time-sorted stream.
    pub fn new(events: &'a [Event], window_us: u64) -> Self {
        assert!(window_us > 0, "window must be positive");
        Self { events, window_us, cursor: 0 }
    }
}

impl<'a> Iterator for Windows<'a> {
    type Item = (u64, &'a [Event]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.cursor >= self.events.len() {
            return None;
        }
        let start_t = self.events[self.cursor].t;
        let win_start = (start_t / self.window_us) * self.window_us;
        let end_t = win_start + self.window_us;
        let begin = self.cursor;
        while self.cursor < self.events.len() && self.events[self.cursor].t < end_t {
            self.cursor += 1;
        }
        Some((win_start, &self.events[begin..self.cursor]))
    }
}

/// Merge two time-sorted streams into one time-sorted stream (stable).
pub fn merge(a: &[Event], b: &[Event]) -> Vec<Event> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i].t <= b[j].t {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Validate that a stream is time-sorted and within the sensor array.
pub fn validate(events: &[Event], res: Resolution) -> Result<(), String> {
    let mut last_t = 0u64;
    for (i, e) in events.iter().enumerate() {
        if e.t < last_t {
            return Err(format!("event {i} out of order: t={} after {}", e.t, last_t));
        }
        if !res.contains(e.x as i32, e.y as i32) {
            return Err(format!("event {i} out of bounds: ({}, {})", e.x, e.y));
        }
        last_t = e.t;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ramp(n: usize, dt: u64) -> Vec<Event> {
        (0..n).map(|i| Event::new((i % 64) as u16, (i % 48) as u16, i as u64 * dt, Polarity::On)).collect()
    }

    #[test]
    fn stats_uniform_rate() {
        // 1000 events spaced 1 ms apart => ~1 keps mean and peak.
        let evs = ramp(1000, 1000);
        let s = stats(&evs, 0.01);
        assert_eq!(s.count, 1000);
        assert!((s.mean_rate - 1000.0).abs() / 1000.0 < 0.01, "mean {}", s.mean_rate);
        assert!((s.peak_rate - 1100.0).abs() <= 101.0, "peak {}", s.peak_rate);
    }

    #[test]
    fn stats_empty() {
        let s = stats(&[], 0.01);
        assert_eq!(s.count, 0);
        assert_eq!(s.peak_rate, 0.0);
    }

    #[test]
    fn stats_burst_peak_exceeds_mean() {
        let mut evs = ramp(100, 10_000); // slow background
        let burst: Vec<Event> = (0..500).map(|i| Event::on(1, 1, 500_000 + i)).collect();
        evs = merge(&evs, &burst);
        let s = stats(&evs, 0.001);
        assert!(s.peak_rate > 10.0 * s.mean_rate);
    }

    #[test]
    fn windows_partition_stream() {
        let evs = ramp(100, 1000); // 1 event per ms, 100 ms total
        let wins: Vec<_> = Windows::new(&evs, 10_000).collect();
        assert_eq!(wins.len(), 10);
        let total: usize = wins.iter().map(|(_, w)| w.len()).sum();
        assert_eq!(total, 100);
        for (start, w) in &wins {
            for e in *w {
                assert!(e.t >= *start && e.t < start + 10_000);
            }
        }
    }

    #[test]
    fn windows_skip_empty_gaps() {
        let mut evs = ramp(5, 100);
        let late: Vec<Event> = (0..5).map(|i| Event::on(0, 0, 1_000_000 + i * 100)).collect();
        evs = merge(&evs, &late);
        let wins: Vec<_> = Windows::new(&evs, 1000).collect();
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[1].0, 1_000_000);
    }

    #[test]
    fn merge_keeps_order() {
        let a = ramp(50, 200);
        let b: Vec<Event> = (0..50).map(|i| Event::off(2, 2, 100 + i * 200)).collect();
        let m = merge(&a, &b);
        assert_eq!(m.len(), 100);
        assert!(m.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn validate_catches_disorder_and_bounds() {
        let ok = ramp(10, 100);
        assert!(validate(&ok, Resolution::TEST64).is_ok());
        let bad = vec![Event::on(0, 0, 10), Event::on(0, 0, 5)];
        assert!(validate(&bad, Resolution::TEST64).is_err());
        let oob = vec![Event::on(64, 0, 0)];
        assert!(validate(&oob, Resolution::TEST64).is_err());
    }
}
