//! AER event primitives: events, polarities, sensor geometry, streams and
//! a simple binary/text codec.
//!
//! Every event-camera subsystem in the crate speaks [`Event`]: a pixel
//! coordinate, a microsecond timestamp and a polarity — the Address Event
//! Representation (AER) of the paper's Sec. II-A.

pub mod bus;
pub mod codec;
pub mod source;
pub mod stream;



/// Contrast-change polarity of an event (Sec. II-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Polarity {
    /// Brightness increased.
    On,
    /// Brightness decreased.
    Off,
}

impl Polarity {
    /// Encode as a single bit (ON = 1).
    #[inline]
    pub fn bit(self) -> u8 {
        match self {
            Polarity::On => 1,
            Polarity::Off => 0,
        }
    }

    /// Decode from a bit; any non-zero value is ON.
    #[inline]
    pub fn from_bit(b: u8) -> Self {
        if b != 0 {
            Polarity::On
        } else {
            Polarity::Off
        }
    }
}

/// A single AER event `v = (x, y, p, t)`.
///
/// `t` is in microseconds from stream start — the native resolution of the
/// DAVIS/Prophesee sensors the paper evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Column (0-based, increases rightward).
    pub x: u16,
    /// Row (0-based, increases downward).
    pub y: u16,
    /// Timestamp in microseconds.
    pub t: u64,
    /// Contrast-change polarity.
    pub p: Polarity,
}

impl Event {
    /// Construct an event.
    #[inline]
    pub fn new(x: u16, y: u16, t: u64, p: Polarity) -> Self {
        Self { x, y, t, p }
    }

    /// ON-polarity shorthand (most synthetic scenes emit both).
    #[inline]
    pub fn on(x: u16, y: u16, t: u64) -> Self {
        Self::new(x, y, t, Polarity::On)
    }

    /// OFF-polarity shorthand.
    #[inline]
    pub fn off(x: u16, y: u16, t: u64) -> Self {
        Self::new(x, y, t, Polarity::Off)
    }
}

/// Sensor pixel-array geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resolution {
    /// Pixels per row.
    pub width: u16,
    /// Rows.
    pub height: u16,
}

impl Resolution {
    /// DAVIS240: 240 x 180 — the sensor the paper sizes its macro for
    /// (two 180x120 NMC blocks).
    pub const DAVIS240: Resolution = Resolution { width: 240, height: 180 };
    /// DAVIS346: 346 x 260 — used for the multi-block scaling study.
    pub const DAVIS346: Resolution = Resolution { width: 346, height: 260 };
    /// Prophesee IMX636-class HD sensor (1280 x 720), the "high resolution
    /// EBC" whose event rate motivates the paper.
    pub const HD720: Resolution = Resolution { width: 1280, height: 720 };
    /// Small resolution for tests.
    pub const TEST64: Resolution = Resolution { width: 64, height: 64 };

    /// Construct a resolution.
    pub const fn new(width: u16, height: u16) -> Self {
        Self { width, height }
    }

    /// Total pixel count.
    #[inline]
    pub const fn pixels(&self) -> usize {
        self.width as usize * self.height as usize
    }

    /// Is `(x, y)` inside the array?
    #[inline]
    pub fn contains(&self, x: i32, y: i32) -> bool {
        x >= 0 && y >= 0 && (x as u32) < self.width as u32 && (y as u32) < self.height as u32
    }

    /// Row-major linear index of `(x, y)`.
    #[inline]
    pub fn index(&self, x: u16, y: u16) -> usize {
        y as usize * self.width as usize + x as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polarity_bit_roundtrip() {
        assert_eq!(Polarity::from_bit(Polarity::On.bit()), Polarity::On);
        assert_eq!(Polarity::from_bit(Polarity::Off.bit()), Polarity::Off);
        assert_eq!(Polarity::from_bit(7), Polarity::On);
    }

    #[test]
    fn event_constructors() {
        let e = Event::on(3, 4, 100);
        assert_eq!((e.x, e.y, e.t, e.p), (3, 4, 100, Polarity::On));
        let e = Event::off(1, 2, 5);
        assert_eq!(e.p, Polarity::Off);
    }

    #[test]
    fn resolution_contains_and_index() {
        let r = Resolution::DAVIS240;
        assert_eq!(r.pixels(), 240 * 180);
        assert!(r.contains(0, 0));
        assert!(r.contains(239, 179));
        assert!(!r.contains(240, 0));
        assert!(!r.contains(0, 180));
        assert!(!r.contains(-1, 5));
        assert_eq!(r.index(0, 1), 240);
        assert_eq!(r.index(5, 0), 5);
    }

    #[test]
    fn known_sensor_geometries() {
        assert_eq!(Resolution::DAVIS240.pixels(), 43_200);
        assert_eq!(Resolution::DAVIS346.pixels(), 89_960);
        assert_eq!(Resolution::HD720.pixels(), 921_600);
    }
}
