//! AER bus model: the sensor-to-accelerator link with finite bandwidth
//! (the DAVIS240 line in Fig. 1(b)) and a bounded FIFO — quantifies the
//! *event loss* that motivates the whole paper when the consumer is
//! slower than the stream.

use super::Event;

/// A finite-bandwidth, finite-FIFO AER link feeding a consumer with a
/// fixed per-event service time.
#[derive(Debug, Clone)]
pub struct AerBus {
    /// Peak transfer rate of the link (events/s).
    pub bandwidth_eps: f64,
    /// FIFO depth (events buffered between link and consumer).
    pub fifo_depth: usize,
}

/// Outcome of replaying a stream through the bus into a consumer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BusReport {
    /// Events offered.
    pub offered: usize,
    /// Events delivered to the consumer.
    pub delivered: usize,
    /// Events dropped (FIFO overflow).
    pub dropped: usize,
    /// Worst observed FIFO occupancy.
    pub max_occupancy: usize,
    /// Mean queueing delay of delivered events (µs).
    pub mean_delay_us: f64,
}

impl AerBus {
    /// DAVIS240-class link: 12 Meps, shallow on-sensor FIFO.
    pub fn davis240() -> Self {
        Self { bandwidth_eps: 12.0e6, fifo_depth: 1024 }
    }

    /// Replay `events` into a consumer with `service_ns` per event
    /// (e.g. the conventional TOS at 392 ns, or the NMC at ~16 ns).
    pub fn replay(&self, events: &[Event], service_ns: f64) -> BusReport {
        let link_gap_us = 1e6 / self.bandwidth_eps;
        let service_us = service_ns * 1e-3;
        let mut fifo: std::collections::VecDeque<f64> = Default::default();
        let mut link_free = 0.0f64; // next time the link can push
        let mut consumer_free = 0.0f64;
        let mut delivered = 0usize;
        let mut dropped = 0usize;
        let mut max_occ = 0usize;
        let mut delay_sum = 0.0f64;

        for ev in events {
            let t = ev.t as f64;
            // the link transfers this event when it is free
            let push_t = link_free.max(t);
            // consumer drains the FIFO while the link works
            while let Some(&arrived) = fifo.front() {
                let start = consumer_free.max(arrived);
                if start + service_us <= push_t {
                    consumer_free = start + service_us;
                    delay_sum += consumer_free - arrived;
                    delivered += 1;
                    fifo.pop_front();
                } else {
                    break;
                }
            }
            link_free = push_t + link_gap_us;
            if fifo.len() >= self.fifo_depth {
                dropped += 1;
            } else {
                fifo.push_back(push_t);
                max_occ = max_occ.max(fifo.len());
            }
        }
        // drain the tail
        while let Some(arrived) = fifo.pop_front() {
            let start = consumer_free.max(arrived);
            consumer_free = start + service_us;
            delay_sum += consumer_free - arrived;
            delivered += 1;
        }
        BusReport {
            offered: events.len(),
            delivered,
            dropped,
            max_occupancy: max_occ,
            mean_delay_us: if delivered > 0 { delay_sum / delivered as f64 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn burst(n: usize, gap_us: u64) -> Vec<Event> {
        (0..n).map(|i| Event::new(1, 1, i as u64 * gap_us, Polarity::On)).collect()
    }

    #[test]
    fn slow_consumer_drops_under_sustained_overrate() {
        // 5 Meps sustained: a 16 ns consumer keeps up, a 3.9 µs one cannot
        let bus = AerBus { bandwidth_eps: 12e6, fifo_depth: 64 };
        let evs: Vec<Event> = (0..100_000)
            .map(|i| Event::new(1, 1, i as u64 / 5, Polarity::On))
            .collect();
        let fast = bus.replay(&evs, 16.0);
        let slow = bus.replay(&evs, 3920.0);
        assert_eq!(fast.dropped + fast.delivered, fast.offered);
        assert_eq!(fast.dropped, 0, "fast consumer dropped {}", fast.dropped);
        assert!(
            slow.dropped as f64 > 0.5 * slow.offered as f64,
            "slow dropped only {}",
            slow.dropped
        );
    }

    #[test]
    fn nmc_sustains_davis240_line_rate_conventional_does_not() {
        // stream at the DAVIS240 line rate: 12 Meps sustained
        let evs = burst(200_000, 0).iter().enumerate()
            .map(|(i, e)| Event::new(e.x, e.y, (i as f64 / 12.0) as u64, e.p))
            .collect::<Vec<_>>();
        let bus = AerBus::davis240();
        // NMC at 15.85 ns/event: no loss
        let nmc = bus.replay(&evs, 15.85);
        assert_eq!(nmc.dropped, 0, "NMC dropped {}", nmc.dropped);
        // conventional at 392 ns/event (2.55 Meps) cannot keep up
        let conv = bus.replay(&evs, 392.0);
        assert!(
            conv.dropped as f64 > 0.5 * conv.offered as f64,
            "conventional dropped only {}",
            conv.dropped
        );
    }

    #[test]
    fn quiet_stream_no_loss_either_way() {
        // 0.5 Meps: both consumers keep up
        let evs = burst(10_000, 2);
        let bus = AerBus::davis240();
        assert_eq!(bus.replay(&evs, 392.0).dropped, 0);
        assert_eq!(bus.replay(&evs, 15.85).dropped, 0);
    }

    #[test]
    fn accounting_balances() {
        let evs = burst(5_000, 0);
        let bus = AerBus { bandwidth_eps: 5e6, fifo_depth: 16 };
        let r = bus.replay(&evs, 1000.0);
        assert_eq!(r.delivered + r.dropped, r.offered);
        assert!(r.max_occupancy <= 16);
        assert!(r.mean_delay_us >= 0.0);
    }
}
