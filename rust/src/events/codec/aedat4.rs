//! AEDAT4 (DV / iniVation) container decoder (and a test/bench encoder).
//!
//! An AEDAT4 recording is the `#!AEDAT4.0\r\n` magic line, an IOHeader
//! blob whose embedded XML describes the streams (geometry, compression),
//! then a sequence of `[stream_id: i32][size: i32][payload]` packets.
//! Event packets carry a flatbuffer whose file identifier is `EVTS` and
//! whose root table's first field is a vector of 16-byte
//! `(t: i64 µs, x: i16, y: i16, polarity: u8, pad×3)` structs.
//!
//! [`Aedat4StreamSource`] decodes the **uncompressed** subset of that
//! format: a recording whose IOHeader declares LZ4/ZSTD packet
//! compression is rejected with a clear "not supported" error rather
//! than misdecoded. The flatbuffer is walked with explicit bounds checks
//! — every offset, count and size field is untrusted input, so lying
//! values produce packet-numbered, offset-bearing errors and never a
//! panic or an unbounded allocation. One packet decodes to one
//! [`next_chunk`](EventSource::next_chunk) chunk (the
//! [`FramedStreamSource`](super::super::source::FramedStreamSource)
//! precedent): the recorder's packet size *is* the chunk size, and
//! per-stream memory stays bounded by [`MAX_PACKET_BYTES`].
//!
//! The matching [`write_aedat4`] encoder emits a minimal IOHeader (just
//! the attributes our scanner reads — real DV tooling may want richer
//! stream metadata) and uncompressed `EVTS` packets; it exists for
//! round-trip tests and benches, while the committed golden fixtures are
//! produced independently by `tools/make_codec_fixtures.py`.

use std::io::{self, BufWriter, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use super::super::source::EventSource;
use super::super::{Event, Polarity, Resolution};

/// The full AEDAT4 magic line.
pub(crate) const AEDAT4_MAGIC: &[u8; 12] = b"#!AEDAT4.0\r\n";
/// Version-agnostic sniff prefix: any `#!AEDAT…` file routes here so an
/// AEDAT2/3 recording gets a "not supported" error instead of a silent
/// text-decoder misparse.
pub(crate) const AEDAT_SNIFF: &[u8; 7] = b"#!AEDAT";

/// Cap on the IOHeader blob (1 MiB): its length field is untrusted.
const MAX_IOHEADER_BYTES: usize = 1 << 20;
/// Cap on one packet payload (16 MiB): the size field is untrusted.
pub const MAX_PACKET_BYTES: usize = 16 << 20;
/// Bytes per event struct in an `EVTS` flatbuffer vector.
const EVENT_STRUCT_BYTES: usize = 16;
/// Largest event count one packet can legitimately declare.
const MAX_PACKET_EVENTS: usize = MAX_PACKET_BYTES / EVENT_STRUCT_BYTES;
/// Events per packet the encoder emits.
const WRITE_PACKET_EVENTS: usize = 512;

/// Incremental decoder for uncompressed AEDAT4 recordings.
pub struct Aedat4StreamSource<R: Read> {
    r: R,
    res: Resolution,
    /// Recycled packet payload buffer (≤ [`MAX_PACKET_BYTES`]).
    payload: Vec<u8>,
    /// 0-based index of the next packet, for error messages.
    packet: u64,
    /// Absolute byte offset of the next packet header.
    offset: u64,
    done: bool,
}

impl<R: Read> Aedat4StreamSource<R> {
    /// Parse the magic line + IOHeader and set up packet decoding.
    pub fn new(inner: R) -> Result<Self> {
        let mut r = inner;
        let mut magic = [0u8; AEDAT4_MAGIC.len()];
        r.read_exact(&mut magic).context("truncated AEDAT4 magic line")?;
        if &magic != AEDAT4_MAGIC {
            bail!(
                "unsupported AEDAT container {:?} — only AEDAT4.0 is supported",
                String::from_utf8_lossy(&magic).trim_end()
            );
        }
        let mut len = [0u8; 4];
        r.read_exact(&mut len).context("truncated AEDAT4 IOHeader length")?;
        let len = i32::from_le_bytes(len);
        ensure!(
            (0..=MAX_IOHEADER_BYTES as i32).contains(&len),
            "AEDAT4 IOHeader declares {len} bytes (cap {MAX_IOHEADER_BYTES})"
        );
        let mut header = vec![0u8; len as usize];
        r.read_exact(&mut header)
            .with_context(|| format!("truncated AEDAT4 IOHeader (declared {len} bytes)"))?;

        if let Some(comp) = xml_value(&header, "compression") {
            ensure!(
                comp == "NONE",
                "AEDAT4 packet compression {comp:?} is not supported (only NONE)"
            );
        }
        let dim = |key: &str| -> Result<u32> {
            let v = xml_value(&header, key).with_context(|| {
                format!("AEDAT4 IOHeader declares no {key:?} geometry attribute")
            })?;
            let v: u32 =
                v.trim().parse().with_context(|| format!("bad AEDAT4 {key:?} value {v:?}"))?;
            ensure!(v > 0 && v <= u16::MAX as u32, "AEDAT4 {key} {v} outside 1..={}", u16::MAX);
            Ok(v)
        };
        let res = Resolution::new(dim("sizeX")? as u16, dim("sizeY")? as u16);
        Ok(Self {
            r,
            res,
            payload: Vec::new(),
            packet: 0,
            offset: (AEDAT4_MAGIC.len() + 4 + len as usize) as u64,
            done: false,
        })
    }

    /// Sensor geometry the IOHeader declared.
    pub fn resolution(&self) -> Resolution {
        self.res
    }
}

impl<R: Read> EventSource for Aedat4StreamSource<R> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        while !self.done {
            // packet header: EOF exactly at a packet boundary is the
            // clean end of the recording; a partial header is corruption
            let mut hdr = [0u8; 8];
            let mut got = 0usize;
            while got < hdr.len() {
                match self.r.read(&mut hdr[got..]) {
                    Ok(0) => break,
                    Ok(n) => got += n,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        return Err(anyhow::Error::new(e).with_context(|| {
                            format!(
                                "reading AEDAT4 packet {} header at byte offset {}",
                                self.packet, self.offset
                            )
                        }))
                    }
                }
            }
            if got == 0 {
                self.done = true;
                break;
            }
            ensure!(
                got == hdr.len(),
                "AEDAT4: truncated packet {} header — {got} of 8 bytes at byte offset {}",
                self.packet,
                self.offset
            );
            // nmc-analyze: allow(error-discipline) -- hdr is a fixed [u8; 8] buffer, so the 4..8 slice-to-array conversion is infallible
            let size = i32::from_le_bytes(hdr[4..8].try_into().unwrap());
            ensure!(
                size > 0 && size as usize <= MAX_PACKET_BYTES,
                "AEDAT4 packet {} at byte offset {}: declared size {size} outside 1..={}",
                self.packet,
                self.offset,
                MAX_PACKET_BYTES
            );
            self.payload.resize(size as usize, 0);
            self.r.read_exact(&mut self.payload).with_context(|| {
                format!(
                    "AEDAT4: truncated packet {} at byte offset {} (declared {size} bytes)",
                    self.packet, self.offset
                )
            })?;
            let pkt = self.packet;
            let off = self.offset;
            self.packet += 1;
            self.offset += 8 + size as u64;
            // non-event streams (frames, IMU, triggers) are skipped
            if self.payload.len() >= 8 && &self.payload[4..8] == b"EVTS" {
                let n = decode_event_packet(&self.payload, self.res, pkt, off, out)?;
                if n > 0 {
                    return Ok(n);
                }
            }
        }
        Ok(0)
    }
}

/// Decode one `EVTS` flatbuffer payload, appending to `out`.
///
/// Every offset is re-derived from untrusted bytes, so each hop is
/// bounds-checked with packet-numbered errors (`pkt` is the 0-based
/// packet index, `off` its absolute byte offset in the recording).
fn decode_event_packet(
    p: &[u8],
    res: Resolution,
    pkt: u64,
    off: u64,
    out: &mut Vec<Event>,
) -> Result<usize> {
    let trunc = |what: &str, pos: usize| {
        format!(
            "AEDAT4 packet {pkt} at byte offset {off}: flatbuffer {what} at payload \
             offset {pos} runs past the {}-byte payload",
            p.len()
        )
    };
    let u32_at = |pos: usize, what: &str| -> Result<u32> {
        let b = p.get(pos..pos + 4).with_context(|| trunc(what, pos))?;
        // nmc-analyze: allow(error-discipline) -- the checked .get above returned exactly 4 bytes, so the conversion is infallible
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    };
    let u16_at = |pos: usize, what: &str| -> Result<u16> {
        let b = p.get(pos..pos + 2).with_context(|| trunc(what, pos))?;
        // nmc-analyze: allow(error-discipline) -- the checked .get above returned exactly 2 bytes, so the conversion is infallible
        Ok(u16::from_le_bytes(b.try_into().unwrap()))
    };

    let root = u32_at(0, "root table offset")? as usize;
    let soff = u32_at(root, "table vtable offset")? as i32 as i64;
    let vt = root as i64 - soff;
    ensure!(
        vt >= 0 && (vt as usize).checked_add(4).map_or(false, |end| end <= p.len()),
        "AEDAT4 packet {pkt} at byte offset {off}: vtable position {vt} out of bounds"
    );
    let vt = vt as usize;
    let vsize = u16_at(vt, "vtable size")? as usize;
    if vsize < 6 {
        return Ok(0); // vtable carries no first field: an empty packet
    }
    let f0 = u16_at(vt + 4, "field 0 vtable entry")? as usize;
    if f0 == 0 {
        return Ok(0); // field absent
    }
    let fpos = root
        .checked_add(f0)
        .with_context(|| trunc("field 0 position", root))?;
    let voff = u32_at(fpos, "events vector offset")? as usize;
    let vec_pos = fpos
        .checked_add(voff)
        .with_context(|| trunc("events vector position", fpos))?;
    let count = u32_at(vec_pos, "events vector length")? as usize;
    ensure!(
        count <= MAX_PACKET_EVENTS,
        "AEDAT4 packet {pkt} at byte offset {off}: declared {count} events exceeds \
         the {MAX_PACKET_EVENTS}-event packet cap"
    );
    let body_end = vec_pos
        .checked_add(4)
        .and_then(|s| count.checked_mul(EVENT_STRUCT_BYTES).and_then(|n| s.checked_add(n)));
    ensure!(
        body_end.map_or(false, |end| end <= p.len()),
        "AEDAT4 packet {pkt} at byte offset {off}: {count} declared events overrun \
         the {}-byte payload",
        p.len()
    );
    let mut pos = vec_pos + 4;
    for i in 0..count {
        let rec = &p[pos..pos + EVENT_STRUCT_BYTES];
        // nmc-analyze: allow(error-discipline) -- rec is EVENT_STRUCT_BYTES (13) bytes by the ensure above, so 0..8 converts infallibly
        let t = i64::from_le_bytes(rec[0..8].try_into().unwrap());
        ensure!(
            t >= 0,
            "AEDAT4 packet {pkt} at byte offset {off}: event {i} has negative timestamp {t}"
        );
        let x = i16::from_le_bytes([rec[8], rec[9]]);
        let y = i16::from_le_bytes([rec[10], rec[11]]);
        ensure!(
            res.contains(x as i32, y as i32),
            "AEDAT4 packet {pkt} at byte offset {off}: event {i} at ({x}, {y}) outside \
             the declared {}x{} geometry",
            res.width,
            res.height
        );
        out.push(Event::new(x as u16, y as u16, t as u64, Polarity::from_bit(rec[12])));
        pos += EVENT_STRUCT_BYTES;
    }
    Ok(count)
}

/// First value of `<attr key="…" …>value<` for `key` in the IOHeader's
/// XML, scanned as raw bytes (the subset DV writes; no XML parser dep).
fn xml_value(blob: &[u8], key: &str) -> Option<String> {
    let pat = format!("key=\"{key}\"");
    let at = find(blob, pat.as_bytes())?;
    // nmc-analyze: allow(error-discipline) -- `at` is a match position from find(), so at + pat.len() <= blob.len() by construction
    let rest = &blob[at + pat.len()..];
    let gt = find(rest, b">")?;
    let rest = &rest[gt + 1..];
    let lt = find(rest, b"<")?;
    Some(String::from_utf8_lossy(&rest[..lt]).into_owned())
}

/// First occurrence of `needle` in `hay`.
fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Minimal IOHeader blob: a pseudo-flatbuffer wrapper around the XML
/// attributes [`Aedat4StreamSource`] scans for.
fn ioheader_blob(res: Resolution) -> Vec<u8> {
    let xml = format!(
        "<dv version=\"2.0\"><node name=\"outInfo\"><node name=\"0\">\
         <attr key=\"compression\" type=\"string\">NONE</attr>\
         <node name=\"info\"><attr key=\"sizeX\" type=\"int\">{}</attr>\
         <attr key=\"sizeY\" type=\"int\">{}</attr></node></node></node></dv>",
        res.width, res.height
    );
    let mut blob = Vec::new();
    blob.extend_from_slice(&8u32.to_le_bytes());
    blob.extend_from_slice(b"IOHE");
    blob.extend_from_slice(xml.as_bytes());
    blob
}

/// One uncompressed `EVTS` flatbuffer payload for ≤ [`WRITE_PACKET_EVENTS`]
/// events (layout documented field-by-field so the decoder's offset walk
/// can be followed against it).
fn encode_event_packet(events: &[Event]) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(&16u32.to_le_bytes()); // root table offset
    b.extend_from_slice(b"EVTS"); // file identifier
    b.extend_from_slice(&6u16.to_le_bytes()); // vtable: size
    b.extend_from_slice(&8u16.to_le_bytes()); // vtable: table size
    b.extend_from_slice(&4u16.to_le_bytes()); // vtable: field 0 offset
    b.extend_from_slice(&[0, 0]); // pad to the root table at 16
    b.extend_from_slice(&8i32.to_le_bytes()); // table: soffset to vtable
    b.extend_from_slice(&4u32.to_le_bytes()); // field 0: vector offset
    b.extend_from_slice(&(events.len() as u32).to_le_bytes());
    for e in events {
        b.extend_from_slice(&(e.t as i64).to_le_bytes());
        b.extend_from_slice(&(e.x as i16).to_le_bytes());
        b.extend_from_slice(&(e.y as i16).to_le_bytes());
        b.extend_from_slice(&[e.p.bit(), 0, 0, 0]);
    }
    b
}

/// Write events as an uncompressed AEDAT4 recording.
///
/// Events must be time-sorted, fit the geometry, and have timestamps
/// representable as the format's signed 64-bit microseconds.
pub fn write_aedat4<W: Write>(w: W, events: &[Event], res: Resolution) -> Result<()> {
    let mut last_t = 0u64;
    for e in events {
        ensure!(
            e.t >= last_t,
            "AEDAT4 writer requires time-sorted events ({} after {})",
            e.t,
            last_t
        );
        last_t = e.t;
        ensure!(e.t <= i64::MAX as u64, "timestamp {} does not fit AEDAT4's i64 µs", e.t);
        ensure!(
            (e.x as u32) < res.width as u32 && (e.y as u32) < res.height as u32,
            "event ({}, {}) outside the {}x{} geometry",
            e.x,
            e.y,
            res.width,
            res.height
        );
    }
    let mut w = BufWriter::new(w);
    w.write_all(AEDAT4_MAGIC)?;
    let blob = ioheader_blob(res);
    w.write_all(&(blob.len() as i32).to_le_bytes())?;
    w.write_all(&blob)?;
    for chunk in events.chunks(WRITE_PACKET_EVENTS) {
        let payload = encode_event_packet(chunk);
        w.write_all(&0i32.to_le_bytes())?; // stream id
        w.write_all(&(payload.len() as i32).to_le_bytes())?;
        w.write_all(&payload)?;
    }
    w.flush()?;
    Ok(())
}

/// Load-all convenience over [`Aedat4StreamSource`].
pub fn read_aedat4<R: Read>(r: R) -> Result<Vec<Event>> {
    let mut src = Aedat4StreamSource::new(r)?;
    let mut events = Vec::new();
    while src.next_chunk(&mut events)? > 0 {}
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    const RES: Resolution = Resolution::TEST64;

    fn sample() -> Vec<Event> {
        vec![
            Event::on(0, 0, 0),
            Event::off(63, 63, 1_000),
            Event::on(10, 20, 1_000_000),
            Event::off(20, 10, 2_000_000),
        ]
    }

    /// Magic + IOHeader + one packet with the given payload.
    fn stream_with_payload(payload: &[u8]) -> Vec<u8> {
        let mut buf = AEDAT4_MAGIC.to_vec();
        let blob = ioheader_blob(RES);
        buf.extend_from_slice(&(blob.len() as i32).to_le_bytes());
        buf.extend_from_slice(&blob);
        buf.extend_from_slice(&0i32.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as i32).to_le_bytes());
        buf.extend_from_slice(payload);
        buf
    }

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &sample(), RES).unwrap();
        assert_eq!(read_aedat4(&buf[..]).unwrap(), sample());
    }

    #[test]
    fn multi_packet_roundtrip_one_packet_per_chunk() {
        let events: Vec<Event> =
            (0..1300u64).map(|i| Event::on((i % 64) as u16, (i % 64) as u16, i)).collect();
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &events, RES).unwrap();
        let mut src = Aedat4StreamSource::new(&buf[..]).unwrap();
        assert_eq!(src.resolution(), RES);
        let mut out = Vec::new();
        // 1300 events = packets of 512, 512, 276 — one packet per chunk
        assert_eq!(src.next_chunk(&mut out).unwrap(), 512);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 512);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 276);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0, "EOS is sticky");
        assert_eq!(out, events);
    }

    #[test]
    fn rejects_other_aedat_versions_with_a_clear_error() {
        let err = read_aedat4(&b"#!AEDAT3.1\r\nmore"[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unsupported AEDAT container") && msg.contains("AEDAT3.1"), "{msg}");
    }

    #[test]
    fn rejects_compressed_recordings() {
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &sample(), RES).unwrap();
        // patch the XML's NONE -> LZ4\0 in place (same length)
        let at = find(&buf, b">NONE<").unwrap();
        buf[at + 1..at + 5].copy_from_slice(b"LZ4 ");
        let err = read_aedat4(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("not supported (only NONE)"), "{err:#}");
    }

    #[test]
    fn rejects_missing_or_bad_geometry() {
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &sample(), RES).unwrap();
        let at = find(&buf, b"sizeX").unwrap();
        buf[at..at + 5].copy_from_slice(b"sizeQ");
        let err = read_aedat4(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("declares no \"sizeX\""), "{err:#}");
    }

    #[test]
    fn rejects_oversized_or_negative_ioheader_length() {
        let mut buf = AEDAT4_MAGIC.to_vec();
        buf.extend_from_slice(&(MAX_IOHEADER_BYTES as i32 + 1).to_le_bytes());
        let err = read_aedat4(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("IOHeader declares"), "{err:#}");

        let mut buf = AEDAT4_MAGIC.to_vec();
        buf.extend_from_slice(&(-1i32).to_le_bytes());
        assert!(read_aedat4(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_and_oversized_packets() {
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &sample(), RES).unwrap();
        buf.truncate(buf.len() - 3);
        let err = read_aedat4(&buf[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated packet 0") && msg.contains("byte offset"), "{msg}");

        // a partial packet *header* is corruption, not a clean EOF
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &sample(), RES).unwrap();
        let keep = buf.len() - (8 + encode_event_packet(&sample()).len()) + 5;
        buf.truncate(keep);
        let err = read_aedat4(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated packet 0 header"), "{err:#}");

        // declared packet size beyond the cap must error before allocating
        let huge = stream_with_payload(&[]); // patch size field below
        let mut huge = huge;
        let size_at = huge.len() - 4;
        huge[size_at..].copy_from_slice(&i32::MAX.to_le_bytes());
        let err = read_aedat4(&huge[..]).unwrap_err();
        assert!(format!("{err:#}").contains("declared size"), "{err:#}");
    }

    #[test]
    fn rejects_lying_event_count_without_preallocating() {
        // count field claims u32::MAX events over a tiny payload: clean
        // offset-bearing error, no allocation proportional to the claim
        let mut payload = encode_event_packet(&sample());
        let count_at = 24;
        payload[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_aedat4(&stream_with_payload(&payload)[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("packet 0") && msg.contains("event"), "{msg}");

        // a just-barely-lying count (one event more than the payload
        // holds) is the same error
        let mut payload = encode_event_packet(&sample());
        payload[count_at..count_at + 4].copy_from_slice(&5u32.to_le_bytes());
        let err = read_aedat4(&stream_with_payload(&payload)[..]).unwrap_err();
        assert!(format!("{err:#}").contains("overrun"), "{err:#}");
    }

    #[test]
    fn rejects_negative_timestamp_and_out_of_range_coords() {
        let mut payload = encode_event_packet(&sample());
        let first_event_at = 28;
        payload[first_event_at..first_event_at + 8].copy_from_slice(&(-5i64).to_le_bytes());
        let err = read_aedat4(&stream_with_payload(&payload)[..]).unwrap_err();
        assert!(format!("{err:#}").contains("negative timestamp -5"), "{err:#}");

        let mut payload = encode_event_packet(&sample());
        payload[first_event_at + 8..first_event_at + 10].copy_from_slice(&300i16.to_le_bytes());
        let err = read_aedat4(&stream_with_payload(&payload)[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("(300, 0)") && msg.contains("outside the declared 64x64"), "{msg}");

        // negative coordinates must not wrap into valid ones
        let mut payload = encode_event_packet(&sample());
        payload[first_event_at + 10..first_event_at + 12].copy_from_slice(&(-1i16).to_le_bytes());
        let err = read_aedat4(&stream_with_payload(&payload)[..]).unwrap_err();
        assert!(format!("{err:#}").contains("(0, -1)"), "{err:#}");
    }

    #[test]
    fn skips_non_event_packets() {
        let mut frame = encode_event_packet(&sample());
        frame[4..8].copy_from_slice(b"FRME"); // some other stream type
        let mut buf = AEDAT4_MAGIC.to_vec();
        let blob = ioheader_blob(RES);
        buf.extend_from_slice(&(blob.len() as i32).to_le_bytes());
        buf.extend_from_slice(&blob);
        for payload in [&frame, &encode_event_packet(&sample())] {
            buf.extend_from_slice(&7i32.to_le_bytes());
            buf.extend_from_slice(&(payload.len() as i32).to_le_bytes());
            buf.extend_from_slice(payload);
        }
        assert_eq!(read_aedat4(&buf[..]).unwrap(), sample());
    }

    #[test]
    fn empty_recording_and_empty_packet() {
        let mut buf = Vec::new();
        write_aedat4(&mut buf, &[], RES).unwrap();
        assert!(read_aedat4(&buf[..]).unwrap().is_empty());

        // a packet declaring zero events is skipped, not end-of-stream
        let empty = encode_event_packet(&[]);
        let mut buf = stream_with_payload(&empty);
        let more = encode_event_packet(&sample());
        buf.extend_from_slice(&0i32.to_le_bytes());
        buf.extend_from_slice(&(more.len() as i32).to_le_bytes());
        buf.extend_from_slice(&more);
        assert_eq!(read_aedat4(&buf[..]).unwrap(), sample());
    }

    #[test]
    fn writer_rejects_bad_input() {
        let unsorted = vec![Event::on(1, 1, 10), Event::on(1, 1, 5)];
        assert!(write_aedat4(&mut Vec::new(), &unsorted, RES).is_err());
        let outside = vec![Event::on(64, 0, 10)];
        assert!(write_aedat4(&mut Vec::new(), &outside, RES).is_err());
        let too_late = vec![Event::on(1, 1, u64::MAX)];
        assert!(write_aedat4(&mut Vec::new(), &too_late, RES).is_err());
    }
}
