//! Prophesee EVT2 / EVT3 raw-stream decoder (and test/bench encoders).
//!
//! Real Prophesee recordings (`.raw`) are an ASCII `%` header followed by
//! a dense little-endian word stream: 16-bit words for EVT3, 32-bit for
//! EVT2. Timestamps are reconstructed from a running time base
//! (`TIME_HIGH`, extended in software past its field width) plus per-event
//! low bits, and EVT3 additionally compresses bursts as vectorized
//! `VECT_BASE_X` + `VECT_12`/`VECT_8` validity masks.
//!
//! [`EvtStreamSource`] decodes both flavors incrementally behind
//! [`EventSource`] with a fixed read buffer — memory stays O(chunk)
//! regardless of recording length — and treats the stream as untrusted
//! input: reserved word types, coordinates outside the declared geometry,
//! CD events before a time base exists, `VECT` words without a base,
//! `TIME_HIGH` rollback, and a recording that ends mid-word are all
//! byte-offset-bearing errors, never panics or huge allocations.
//!
//! Time-base extension: a `TIME_HIGH` value lower than the previous one
//! is accepted as the 2^24 µs (EVT3) / 2^34 µs (EVT2) counter wrapping
//! only when the step back spans at least half the field's range — the
//! shape a real sensor produces, since it emits `TIME_HIGH` periodically
//! as gradual increments. A short step back is a rollback error: the
//! encoder-side fault would otherwise silently reorder time. (A stream
//! that legitimately teleports forward across the wrap boundary without
//! intermediate `TIME_HIGH` words is indistinguishable from a rollback
//! and is rejected the same way.)

use std::io::{self, BufWriter, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use super::super::source::{DEFAULT_CHUNK_EVENTS, EventSource};
use super::super::{Event, Polarity, Resolution};
use super::MAX_CHUNK_EVENTS;

/// Cap on one `%` header line (a hostile header must not buffer unbounded).
const MAX_HEADER_LINE: usize = 4096;
/// Cap on the whole `%` header.
const MAX_HEADER_BYTES: usize = 64 << 10;
/// Fixed body read-buffer size.
const READ_BUF_BYTES: usize = 64 << 10;
/// EVT coordinate fields are 11 bits wide.
const MAX_EVT_DIM: u32 = 1 << 11;

/// Which Prophesee word format a stream carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvtFlavor {
    /// 32-bit words, one CD event per word (Gen3-era).
    Evt2,
    /// 16-bit words with vectorized CD bursts (Gen4/IMX636-era).
    Evt3,
}

impl EvtFlavor {
    /// Bytes per word in the body stream.
    #[inline]
    fn word_bytes(self) -> usize {
        match self {
            EvtFlavor::Evt2 => 4,
            EvtFlavor::Evt3 => 2,
        }
    }

    /// Name used in error messages.
    fn name(self) -> &'static str {
        match self {
            EvtFlavor::Evt2 => "EVT2",
            EvtFlavor::Evt3 => "EVT3",
        }
    }
}

/// Incremental decoder for Prophesee EVT2/EVT3 `.raw` streams.
///
/// The constructor consumes the ASCII `%` header (flavor + geometry are
/// mandatory — a stream with neither a `% evt` / `% format` line nor a
/// geometry is rejected, as is the EVT2.1 flavor we do not support) and
/// the body then decodes word-at-a-time through a fixed 64 KiB buffer.
pub struct EvtStreamSource<R: Read> {
    r: R,
    flavor: EvtFlavor,
    res: Resolution,
    chunk_events: usize,
    buf: Vec<u8>,
    start: usize,
    end: usize,
    /// Absolute byte offset (from file start) of `buf[start]`.
    offset: u64,
    done: bool,
    /// Software-extended TIME_HIGH (full value, not just the field bits);
    /// `None` until the first TIME_HIGH word — CD events before that have
    /// no time base and are rejected.
    time_high: Option<u64>,
    /// Last TIME_LOW value (EVT3); 0 until the first TIME_LOW word.
    time_low: u64,
    /// Current row set by EVT_ADDR_Y (EVT3); CD words before any row are
    /// rejected.
    row: Option<u16>,
    /// Pending VECT_BASE_X state: (next x, polarity), advanced by each
    /// VECT_12/VECT_8 word.
    vect: Option<(u64, Polarity)>,
}

impl<R: Read> EvtStreamSource<R> {
    /// Parse the `%` header and set up chunked body decoding.
    pub fn new(inner: R, chunk_events: usize) -> Result<Self> {
        let mut r = inner;
        let mut flavor: Option<EvtFlavor> = None;
        let mut width: Option<u32> = None;
        let mut height: Option<u32> = None;
        let mut header_bytes = 0u64;
        let mut pending: Option<u8> = None;
        let mut line = Vec::new();
        loop {
            let Some(b) = read_byte(&mut r).context("reading EVT header")? else { break };
            if b != b'%' {
                // first body byte — remember it, the header (if any) is over
                pending = Some(b);
                break;
            }
            header_bytes += 1;
            line.clear();
            loop {
                let Some(b) = read_byte(&mut r).context("reading EVT header")? else { break };
                header_bytes += 1;
                if b == b'\n' {
                    break;
                }
                ensure!(
                    line.len() < MAX_HEADER_LINE,
                    "EVT header line exceeds the {MAX_HEADER_LINE}-byte cap"
                );
                line.push(b);
            }
            ensure!(
                header_bytes <= MAX_HEADER_BYTES as u64,
                "EVT header exceeds the {MAX_HEADER_BYTES}-byte cap"
            );
            let text = String::from_utf8_lossy(&line);
            if parse_header_line(text.trim(), &mut flavor, &mut width, &mut height)? {
                break; // "% end" terminates the header explicitly
            }
        }
        let flavor = flavor.context(
            "EVT header declares no format: need a '% evt 2.0' / '% evt 3.0' or '% format' line",
        )?;
        let (width, height) = match (width, height) {
            (Some(w), Some(h)) => (w, h),
            _ => bail!(
                "{} header declares no geometry: need a '% geometry WxH' line \
                 (or width=/height= in '% format')",
                flavor.name()
            ),
        };
        for (what, v) in [("width", width), ("height", height)] {
            ensure!(
                v > 0 && v <= MAX_EVT_DIM,
                "{} geometry {what} {v} outside the 11-bit coordinate range 1..={MAX_EVT_DIM}",
                flavor.name()
            );
        }
        let res = Resolution::new(width as u16, height as u16);
        let mut buf = vec![0u8; READ_BUF_BYTES];
        let mut end = 0usize;
        if let Some(b) = pending {
            buf[0] = b;
            end = 1;
        }
        Ok(Self {
            r,
            flavor,
            res,
            chunk_events: chunk_events.clamp(1, MAX_CHUNK_EVENTS),
            buf,
            start: 0,
            end,
            offset: header_bytes,
            done: false,
            time_high: None,
            time_low: 0,
            row: None,
            vect: None,
        })
    }

    /// Which word format the header declared.
    pub fn flavor(&self) -> EvtFlavor {
        self.flavor
    }

    /// Sensor geometry the header declared.
    pub fn resolution(&self) -> Resolution {
        self.res
    }

    /// Refill the body buffer; `Ok(false)` means EOF with nothing read.
    fn refill(&mut self) -> Result<bool> {
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
        loop {
            match self.r.read(&mut self.buf[self.end..]) {
                Ok(0) => return Ok(false),
                Ok(n) => {
                    self.end += n;
                    return Ok(true);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(anyhow::Error::new(e).with_context(|| {
                        format!(
                            "reading {} body at byte offset {}",
                            self.flavor.name(),
                            self.offset + (self.end - self.start) as u64
                        )
                    }))
                }
            }
        }
    }

    /// Extend the running TIME_HIGH by a new raw field value: forward is
    /// forward, a step back of at least half the field range is the
    /// counter wrapping, anything else is a rollback error.
    fn advance_time_high(&mut self, v: u64, bits: u32, off: u64) -> Result<()> {
        let mask = (1u64 << bits) - 1;
        self.time_high = Some(match self.time_high {
            None => v,
            Some(cur) => {
                let cur_lo = cur & mask;
                let base = cur & !mask;
                if v >= cur_lo {
                    base | v
                } else if cur_lo - v >= (mask + 1) / 2 {
                    (base + mask + 1) | v
                } else {
                    bail!(
                        "{}: TIME_HIGH rollback (0x{cur_lo:X} -> 0x{v:X}) at byte offset {off} \
                         — timestamps would go backwards",
                        self.flavor.name()
                    )
                }
            }
        });
        Ok(())
    }

    /// Decode one EVT3 16-bit word; returns how many events it emitted.
    fn word_evt3(&mut self, w: u16, off: u64, out: &mut Vec<Event>) -> Result<usize> {
        let typ = w >> 12;
        let v = (w & 0x0FFF) as u64;
        match typ {
            0x0 => {
                // EVT_ADDR_Y (bit 11 is the master/slave system flag)
                let y = w & 0x07FF;
                ensure!(
                    (y as u32) < self.res.height as u32,
                    "EVT3: y {y} outside the declared {}x{} geometry at byte offset {off}",
                    self.res.width,
                    self.res.height
                );
                self.row = Some(y);
            }
            0x2 => {
                // EVT_ADDR_X: one CD event
                let x = w & 0x07FF;
                let p = Polarity::from_bit(((w >> 11) & 1) as u8);
                let t = self.evt3_timestamp(off)?;
                ensure!(
                    (x as u32) < self.res.width as u32,
                    "EVT3: x {x} outside the declared {}x{} geometry at byte offset {off}",
                    self.res.width,
                    self.res.height
                );
                let y = self.row.with_context(|| {
                    format!("EVT3: CD event before any EVT_ADDR_Y at byte offset {off}")
                })?;
                out.push(Event::new(x, y, t, p));
                return Ok(1);
            }
            0x3 => {
                // VECT_BASE_X: arm the vectorized burst
                let x = (w & 0x07FF) as u64;
                let p = Polarity::from_bit(((w >> 11) & 1) as u8);
                self.vect = Some((x, p));
            }
            0x4 | 0x5 => {
                // VECT_12 / VECT_8 validity mask
                let nbits = if typ == 0x4 { 12u64 } else { 8 };
                let (base, p) = self.vect.with_context(|| {
                    format!(
                        "EVT3: VECT_{nbits} without a preceding VECT_BASE_X at byte offset {off}"
                    )
                })?;
                let t = self.evt3_timestamp(off)?;
                let y = self.row.with_context(|| {
                    format!("EVT3: CD event before any EVT_ADDR_Y at byte offset {off}")
                })?;
                let mut emitted = 0usize;
                for b in 0..nbits {
                    if v & (1 << b) != 0 {
                        let x = base + b;
                        ensure!(
                            x < self.res.width as u64,
                            "EVT3: vectorized x {x} outside the declared {}x{} geometry \
                             at byte offset {off}",
                            self.res.width,
                            self.res.height
                        );
                        out.push(Event::new(x as u16, y, t, p));
                        emitted += 1;
                    }
                }
                self.vect = Some((base + nbits, p));
                return Ok(emitted);
            }
            0x6 => self.time_low = v,
            0x8 => self.advance_time_high(v, 12, off)?,
            // CONTINUED_4 / EXT_TRIGGER / OTHERS / CONTINUED_12: valid
            // words we carry no payload for — skipped, not errors
            0x7 | 0xA | 0xE | 0xF => {}
            _ => bail!("EVT3: reserved word type 0x{typ:X} (word 0x{w:04X}) at byte offset {off}"),
        }
        Ok(0)
    }

    /// Current EVT3 timestamp, requiring a time base to exist.
    fn evt3_timestamp(&self, off: u64) -> Result<u64> {
        let th = self.time_high.with_context(|| {
            format!("EVT3: CD event before any TIME_HIGH at byte offset {off}")
        })?;
        Ok((th << 12) | self.time_low)
    }

    /// Decode one EVT2 32-bit word; returns how many events it emitted.
    fn word_evt2(&mut self, w: u32, off: u64, out: &mut Vec<Event>) -> Result<usize> {
        let typ = w >> 28;
        match typ {
            0x0 | 0x1 => {
                // CD_OFF / CD_ON
                let th = self.time_high.with_context(|| {
                    format!("EVT2: CD event before any TIME_HIGH at byte offset {off}")
                })?;
                let ts_lsb = ((w >> 22) & 0x3F) as u64;
                let x = ((w >> 11) & 0x07FF) as u16;
                let y = (w & 0x07FF) as u16;
                for (what, v, dim) in
                    [("x", x, self.res.width as u32), ("y", y, self.res.height as u32)]
                {
                    ensure!(
                        (v as u32) < dim,
                        "EVT2: {what} {v} outside the declared {}x{} geometry at byte offset {off}",
                        self.res.width,
                        self.res.height
                    );
                }
                out.push(Event::new(x, y, (th << 6) | ts_lsb, Polarity::from_bit(typ as u8)));
                return Ok(1);
            }
            0x8 => self.advance_time_high((w & 0x0FFF_FFFF) as u64, 28, off)?,
            // EXT_TRIGGER / OTHERS / CONTINUED: skipped, not errors
            0xA | 0xE | 0xF => {}
            _ => bail!("EVT2: reserved word type 0x{typ:X} (word 0x{w:08X}) at byte offset {off}"),
        }
        Ok(0)
    }
}

/// Read one byte, retrying on `Interrupted`; `Ok(None)` at EOF.
fn read_byte<R: Read>(r: &mut R) -> io::Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Process one header line (leading `%` stripped, trimmed). Returns
/// `Ok(true)` when the line is `end` (header explicitly terminated).
fn parse_header_line(
    text: &str,
    flavor: &mut Option<EvtFlavor>,
    width: &mut Option<u32>,
    height: &mut Option<u32>,
) -> Result<bool> {
    if text == "end" {
        return Ok(true);
    }
    if let Some(ver) = text.strip_prefix("evt ") {
        *flavor = Some(match ver.trim() {
            "2.0" => EvtFlavor::Evt2,
            "3.0" => EvtFlavor::Evt3,
            other => bail!("unsupported EVT version {other:?} (only 2.0 and 3.0)"),
        });
    } else if let Some(fmt) = text.strip_prefix("format ") {
        let mut parts = fmt.split(';');
        let kind = parts.next().unwrap_or("").trim();
        *flavor = Some(match kind {
            "EVT2" | "EVT2.0" => EvtFlavor::Evt2,
            "EVT3" | "EVT3.0" => EvtFlavor::Evt3,
            other => bail!("unsupported EVT format {other:?} (only EVT2 and EVT3)"),
        });
        for kv in parts {
            let kv = kv.trim();
            if let Some(v) = kv.strip_prefix("width=") {
                *width = Some(v.parse().with_context(|| format!("bad header {kv:?}"))?);
            } else if let Some(v) = kv.strip_prefix("height=") {
                *height = Some(v.parse().with_context(|| format!("bad header {kv:?}"))?);
            }
        }
    } else if let Some(geo) = text.strip_prefix("geometry ") {
        let geo = geo.trim();
        let (w, h) = geo
            .split_once('x')
            .or_else(|| geo.split_once('X'))
            .with_context(|| format!("bad header geometry {geo:?} (want WxH)"))?;
        *width = Some(w.trim().parse().with_context(|| format!("bad header geometry {geo:?}"))?);
        *height = Some(h.trim().parse().with_context(|| format!("bad header geometry {geo:?}"))?);
    }
    // every other % line (serial, integrator name, date...) is ignored
    Ok(false)
}

impl<R: Read> EventSource for EvtStreamSource<R> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        if self.done {
            return Ok(0);
        }
        let ws = self.flavor.word_bytes();
        let mut appended = 0usize;
        // vectorized words may overshoot the chunk target by up to 11
        // events; chunks concatenate, so the overshoot is harmless
        while appended < self.chunk_events {
            if self.end - self.start < ws {
                if self.refill()? {
                    continue;
                }
                let rem = self.end - self.start;
                if rem == 0 {
                    self.done = true;
                    break;
                }
                bail!(
                    "{}: recording ends mid-word — {rem} trailing byte(s) at byte offset {}",
                    self.flavor.name(),
                    self.offset
                );
            }
            let off = self.offset;
            let s = self.start;
            self.start += ws;
            self.offset += ws as u64;
            appended += match self.flavor {
                EvtFlavor::Evt3 => {
                    let w = u16::from_le_bytes([self.buf[s], self.buf[s + 1]]);
                    self.word_evt3(w, off, out)?
                }
                EvtFlavor::Evt2 => {
                    let w = u32::from_le_bytes([
                        self.buf[s],
                        self.buf[s + 1],
                        self.buf[s + 2],
                        self.buf[s + 3],
                    ]);
                    self.word_evt2(w, off, out)?
                }
            };
        }
        Ok(appended)
    }
}

/// Write events as an EVT3 `.raw` stream (header + 16-bit words).
///
/// Test/bench encoder for the decoder above: emits `TIME_HIGH` stepped
/// one value at a time (the gradual shape [`EvtStreamSource`] requires
/// across the 2^24 µs wrap), `TIME_LOW`/`EVT_ADDR_Y` only on change, and
/// one `EVT_ADDR_X` per event. Events must be time-sorted, start below
/// 2^24 µs (so the decoder's time base anchors unambiguously) and fit
/// the geometry.
pub fn write_evt3<W: Write>(w: W, events: &[Event], res: Resolution) -> Result<()> {
    ensure!(
        (res.width as u32) <= MAX_EVT_DIM && (res.height as u32) <= MAX_EVT_DIM,
        "EVT3 coordinates are 11-bit: {}x{} does not fit",
        res.width,
        res.height
    );
    if let Some(first) = events.first() {
        ensure!(
            first.t < 1 << 24,
            "EVT3 writer: first timestamp {} µs must lie below 2^24 µs",
            first.t
        );
    }
    let mut w = BufWriter::new(w);
    write!(
        w,
        "% evt 3.0\n% format EVT3;height={};width={}\n% geometry {}x{}\n% end\n",
        res.height, res.width, res.width, res.height
    )?;
    let mut high: Option<u64> = None;
    let mut low: Option<u64> = None;
    let mut row: Option<u16> = None;
    let mut last_t = 0u64;
    for e in events {
        ensure!(e.t >= last_t, "EVT3 writer requires time-sorted events ({} after {})", e.t, last_t);
        last_t = e.t;
        ensure!(
            (e.x as u32) < res.width as u32 && (e.y as u32) < res.height as u32,
            "event ({}, {}) outside the {}x{} geometry",
            e.x,
            e.y,
            res.width,
            res.height
        );
        let h = e.t >> 12;
        match high {
            None => {
                w.write_all(&(((0x8u16) << 12) | (h & 0xFFF) as u16).to_le_bytes())?;
                high = Some(h);
            }
            Some(mut cur) => {
                while cur < h {
                    cur += 1;
                    w.write_all(&(((0x8u16) << 12) | (cur & 0xFFF) as u16).to_le_bytes())?;
                }
                high = Some(h);
            }
        }
        let lo = e.t & 0xFFF;
        if low != Some(lo) {
            w.write_all(&(((0x6u16) << 12) | lo as u16).to_le_bytes())?;
            low = Some(lo);
        }
        if row != Some(e.y) {
            w.write_all(&e.y.to_le_bytes())?; // type 0x0 = EVT_ADDR_Y
            row = Some(e.y);
        }
        w.write_all(&(((0x2u16) << 12) | ((e.p.bit() as u16) << 11) | e.x).to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Write events as an EVT2 `.raw` stream (header + 32-bit words).
///
/// Test/bench encoder: one `TIME_HIGH` word whenever `t >> 6` changes,
/// then one CD word per event. Timestamps must be sorted and below
/// 2^34 µs (so the 28-bit `TIME_HIGH` field never wraps — the decoder's
/// wrap path is exercised with hand-built words instead).
pub fn write_evt2<W: Write>(w: W, events: &[Event], res: Resolution) -> Result<()> {
    ensure!(
        (res.width as u32) <= MAX_EVT_DIM && (res.height as u32) <= MAX_EVT_DIM,
        "EVT2 coordinates are 11-bit: {}x{} does not fit",
        res.width,
        res.height
    );
    let mut w = BufWriter::new(w);
    write!(
        w,
        "% evt 2.0\n% format EVT2;height={};width={}\n% geometry {}x{}\n% end\n",
        res.height, res.width, res.width, res.height
    )?;
    let mut high: Option<u64> = None;
    let mut last_t = 0u64;
    for e in events {
        ensure!(e.t >= last_t, "EVT2 writer requires time-sorted events ({} after {})", e.t, last_t);
        last_t = e.t;
        ensure!(e.t < 1 << 34, "EVT2 writer caps timestamps below 2^34 µs (got {})", e.t);
        ensure!(
            (e.x as u32) < res.width as u32 && (e.y as u32) < res.height as u32,
            "event ({}, {}) outside the {}x{} geometry",
            e.x,
            e.y,
            res.width,
            res.height
        );
        let h = e.t >> 6;
        if high != Some(h) {
            w.write_all(&(((0x8u32) << 28) | (h as u32 & 0x0FFF_FFFF)).to_le_bytes())?;
            high = Some(h);
        }
        let cd = ((e.p.bit() as u32) << 28)
            | (((e.t & 0x3F) as u32) << 22)
            | ((e.x as u32) << 11)
            | e.y as u32;
        w.write_all(&cd.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load-all convenience over [`EvtStreamSource`] (either flavor).
pub fn read_evt<R: Read>(r: R) -> Result<Vec<Event>> {
    let mut src = EvtStreamSource::new(r, DEFAULT_CHUNK_EVENTS)?;
    let mut events = Vec::new();
    while src.next_chunk(&mut events)? > 0 {}
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    const RES: Resolution = Resolution::TEST64;

    fn sample() -> Vec<Event> {
        vec![
            Event::on(1, 2, 100),
            Event::off(3, 2, 150),
            Event::on(63, 63, 4_000),
            Event::off(0, 0, 5_000),
            Event::on(10, 20, 1_000_000),
        ]
    }

    fn drain(src: &mut impl EventSource) -> Vec<Event> {
        let mut out = Vec::new();
        while src.next_chunk(&mut out).unwrap() > 0 {}
        out
    }

    /// EVT3 header + raw words, for hand-built corruption streams.
    fn evt3_stream(words: &[u16]) -> Vec<u8> {
        let mut buf = b"% evt 3.0\n% geometry 64x64\n% end\n".to_vec();
        for w in words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        buf
    }

    /// EVT2 header + raw words.
    fn evt2_stream(words: &[u32]) -> Vec<u8> {
        let mut buf = b"% evt 2.0\n% geometry 64x64\n% end\n".to_vec();
        for w in words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        buf
    }

    #[test]
    fn evt3_roundtrip() {
        let mut buf = Vec::new();
        write_evt3(&mut buf, &sample(), RES).unwrap();
        assert_eq!(read_evt(&buf[..]).unwrap(), sample());
    }

    #[test]
    fn evt2_roundtrip() {
        let mut buf = Vec::new();
        write_evt2(&mut buf, &sample(), RES).unwrap();
        assert_eq!(read_evt(&buf[..]).unwrap(), sample());
    }

    #[test]
    fn evt3_roundtrip_across_the_2_24_wrap() {
        // timestamps straddling 2^24 µs force the stepped TIME_HIGH
        // sequence through its 12-bit wrap; the decoder must resync
        let events: Vec<Event> = (0..64u64)
            .map(|i| Event::on((i % 60) as u16, 5, 16_770_000 + i * 1_000))
            .collect();
        assert!(events.first().unwrap().t < 1 << 24 && events.last().unwrap().t > 1 << 24);
        let mut buf = Vec::new();
        write_evt3(&mut buf, &events, RES).unwrap();
        assert_eq!(read_evt(&buf[..]).unwrap(), events);
    }

    #[test]
    fn evt_chunked_decode_equals_load_all() {
        let events: Vec<Event> =
            (0..500u64).map(|i| Event::on((i % 64) as u16, (i % 48) as u16, i * 7)).collect();
        let mut evt3 = Vec::new();
        write_evt3(&mut evt3, &events, RES).unwrap();
        let mut evt2 = Vec::new();
        write_evt2(&mut evt2, &events, RES).unwrap();
        for chunk in [1usize, 7, 64, 10_000] {
            let mut src = EvtStreamSource::new(&evt3[..], chunk).unwrap();
            assert_eq!(src.flavor(), EvtFlavor::Evt3);
            assert_eq!(src.resolution(), RES);
            assert_eq!(drain(&mut src), events, "evt3 chunk {chunk}");
            let mut src = EvtStreamSource::new(&evt2[..], chunk).unwrap();
            assert_eq!(src.flavor(), EvtFlavor::Evt2);
            assert_eq!(drain(&mut src), events, "evt2 chunk {chunk}");
        }
    }

    #[test]
    fn evt3_vect_words_decode() {
        // VECT_BASE_X at x=10 pol ON, VECT_12 mask 0b1010_0000_0101,
        // then VECT_8 mask 0b0000_0011 continuing at base+12
        let words = [
            0x8000 | 1,          // TIME_HIGH = 1
            0x6000 | 5,          // TIME_LOW = 5
            0x0000 | 7,          // EVT_ADDR_Y = 7
            0x3000 | 0x800 | 10, // VECT_BASE_X x=10 pol=1
            0x4000 | 0xA05,      // VECT_12: bits 0,2,9,11
            0x5000 | 0x003,      // VECT_8: bits 0,1 at base 22
        ];
        let got = read_evt(&evt3_stream(&words)[..]).unwrap();
        let t = (1u64 << 12) | 5;
        let want: Vec<Event> =
            [10u16, 12, 19, 21, 22, 23].iter().map(|&x| Event::on(x, 7, t)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn evt3_rejects_cd_without_time_base_or_row() {
        // CD before any TIME_HIGH
        let err = read_evt(&evt3_stream(&[0x0000 | 7, 0x2000 | 3])[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("before any TIME_HIGH") && msg.contains("offset"), "{msg}");

        // CD before any EVT_ADDR_Y
        let err = read_evt(&evt3_stream(&[0x8000 | 1, 0x2000 | 3])[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("before any EVT_ADDR_Y"), "{msg}");
    }

    #[test]
    fn evt3_rejects_vect_without_base() {
        let words = [0x8000 | 1, 0x0000 | 7, 0x4000 | 0xFFF];
        let err = read_evt(&evt3_stream(&words)[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("without a preceding VECT_BASE_X"), "{msg}");
    }

    #[test]
    fn evt3_rejects_time_high_rollback_but_accepts_wrap() {
        // small step back: rollback error with the offset
        let err = read_evt(&evt3_stream(&[0x8000 | 100, 0x8000 | 99])[..]).unwrap_err();
        let msg = format!("{err:#}");
        // header "% evt 3.0\n% geometry 64x64\n% end\n" is 33 bytes, so
        // the offending second word sits at byte offset 35
        assert!(msg.contains("rollback") && msg.contains("offset 35"), "{msg}");

        // step back across at least half the range: legitimate 12-bit wrap
        let words = [0x8000 | 0xFFE, 0x8000 | 0xFFF, 0x8000 | 0x000, 0x0000 | 1, 0x2000 | 1];
        let got = read_evt(&evt3_stream(&words)[..]).unwrap();
        assert_eq!(got, vec![Event::off(1, 1, 0x1000u64 << 12)]);
    }

    #[test]
    fn evt3_rejects_reserved_word_and_out_of_range_coords() {
        let err = read_evt(&evt3_stream(&[0x9000])[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("reserved word type 0x9") && msg.contains("offset"), "{msg}");

        // y = 70 outside 64x64
        let err = read_evt(&evt3_stream(&[0x8000 | 1, 0x0000 | 70])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("outside the declared 64x64 geometry"), "{err:#}");

        // x = 70 outside 64x64
        let err = read_evt(&evt3_stream(&[0x8000 | 1, 0x0000 | 7, 0x2000 | 70])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("outside the declared 64x64 geometry"), "{err:#}");

        // vectorized run walking past the right edge
        let words = [0x8000 | 1, 0x0000 | 7, 0x3000 | 60, 0x4000 | 0xFFF];
        let err = read_evt(&evt3_stream(&words)[..]).unwrap_err();
        assert!(format!("{err:#}").contains("vectorized x 64 outside"), "{err:#}");
    }

    #[test]
    fn evt_rejects_mid_word_eof() {
        let mut buf = evt3_stream(&[0x8000 | 1]);
        buf.push(0xAB); // one dangling byte of a 2-byte word
        let err = read_evt(&buf[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("ends mid-word") && msg.contains("1 trailing byte"), "{msg}");

        let mut buf = evt2_stream(&[(0x8u32) << 28]);
        buf.extend_from_slice(&[1, 2, 3]); // 3 dangling bytes of a 4-byte word
        let err = read_evt(&buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("3 trailing byte(s)"), "{err:#}");
    }

    #[test]
    fn evt2_rejects_cd_without_time_base_rollback_and_reserved() {
        let cd = |x: u32, y: u32| (0x1u32 << 28) | (x << 11) | y;
        let err = read_evt(&evt2_stream(&[cd(1, 1)])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("EVT2: CD event before any TIME_HIGH"), "{err:#}");

        let th = |v: u32| (0x8u32 << 28) | v;
        let err = read_evt(&evt2_stream(&[th(100), th(99)])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("rollback"), "{err:#}");

        let err = read_evt(&evt2_stream(&[0x2u32 << 28])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("reserved word type 0x2"), "{err:#}");

        // out-of-range x against the declared geometry
        let err = read_evt(&evt2_stream(&[th(1), cd(70, 1)])[..]).unwrap_err();
        assert!(format!("{err:#}").contains("x 70 outside the declared 64x64"), "{err:#}");
    }

    #[test]
    fn evt2_wrap_accepted() {
        // 28-bit TIME_HIGH stepping 0xFFFFFFF -> 0x0000000 is the counter
        // wrapping: decoded time keeps increasing
        let th = |v: u32| (0x8u32 << 28) | v;
        let cd = |x: u32, y: u32| (0x1u32 << 28) | (x << 11) | y;
        let words = [th(0x0FFF_FFFE), th(0x0FFF_FFFF), th(0x0000_0000), cd(1, 2)];
        let got = read_evt(&evt2_stream(&words)[..]).unwrap();
        assert_eq!(got, vec![Event::on(1, 2, (1u64 << 28) << 6)]);
    }

    #[test]
    fn evt_header_validation() {
        // missing geometry
        let err = read_evt(&b"% evt 3.0\n% end\n"[..]).unwrap_err();
        assert!(format!("{err:#}").contains("declares no geometry"), "{err:#}");

        // missing format entirely (body starts immediately)
        let err = read_evt(&b"\x01\x02"[..]).unwrap_err();
        assert!(format!("{err:#}").contains("declares no format"), "{err:#}");

        // EVT2.1 is explicitly unsupported
        let err = read_evt(&b"% format EVT2.1;height=64;width=64\n% end\n"[..]).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported EVT format"), "{err:#}");
        let err = read_evt(&b"% evt 2.1\n% geometry 64x64\n% end\n"[..]).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported EVT version"), "{err:#}");

        // geometry outside the 11-bit coordinate fields
        let err = read_evt(&b"% evt 3.0\n% geometry 4096x64\n% end\n"[..]).unwrap_err();
        assert!(format!("{err:#}").contains("11-bit coordinate range"), "{err:#}");

        // format line carrying the geometry is sufficient on its own
        let src =
            EvtStreamSource::new(&b"% format EVT3;height=48;width=32\n% end\n"[..], 64).unwrap();
        assert_eq!(src.resolution(), Resolution::new(32, 48));

        // unknown % lines are ignored, header without % end still parses
        let evs = read_evt(&b"% evt 3.0\n% camera serial 0042\n% geometry 64x64\n"[..]).unwrap();
        assert!(evs.is_empty());
    }

    #[test]
    fn evt_header_caps_are_enforced() {
        // one absurdly long % line must not buffer unbounded
        let mut buf = b"% ".to_vec();
        buf.extend(std::iter::repeat(b'a').take(MAX_HEADER_LINE + 10));
        buf.push(b'\n');
        let err = EvtStreamSource::new(&buf[..], 64).map(|_| ()).unwrap_err();
        assert!(format!("{err:#}").contains("header line exceeds"), "{err:#}");
    }

    #[test]
    fn evt_writers_reject_bad_input() {
        // unsorted
        let evs = vec![Event::on(1, 1, 100), Event::on(1, 1, 50)];
        assert!(write_evt3(&mut Vec::new(), &evs, RES).is_err());
        assert!(write_evt2(&mut Vec::new(), &evs, RES).is_err());
        // outside geometry
        let evs = vec![Event::on(64, 1, 100)];
        assert!(write_evt3(&mut Vec::new(), &evs, RES).is_err());
        assert!(write_evt2(&mut Vec::new(), &evs, RES).is_err());
        // EVT3 first timestamp past the 24-bit time base
        let evs = vec![Event::on(1, 1, 1 << 24)];
        assert!(write_evt3(&mut Vec::new(), &evs, RES).is_err());
        // EVT2 timestamp past 2^34
        let evs = vec![Event::on(1, 1, 1 << 34)];
        assert!(write_evt2(&mut Vec::new(), &evs, RES).is_err());
    }

    #[test]
    fn empty_body_decodes_to_nothing() {
        assert!(read_evt(&evt3_stream(&[])[..]).unwrap().is_empty());
        assert!(read_evt(&evt2_stream(&[])[..]).unwrap().is_empty());
    }
}
