//! Streaming event ingestion: [`EventSource`], a fallible chunked
//! iterator over time-sorted event batches.
//!
//! Every run path used to materialize the entire recording as a
//! `Vec<Event>` before the first event was processed, capping stream
//! length by host memory. Practical event pipelines (luvHarris; Sun et
//! al.'s memory-efficient DVS corner detection) must instead consume
//! unbounded live streams with bounded state. An [`EventSource`] yields
//! the stream in bounded chunks, so the coordinator's
//! [`run_stream`](crate::coordinator::Pipeline::run_stream) keeps peak
//! event-buffer memory O(chunk) regardless of recording length.
//!
//! Implementations:
//! * [`SliceSource`] — an in-memory slice, chunked (also the adapter that
//!   keeps the load-all [`run`](crate::coordinator::Pipeline::run) API).
//! * [`codec::BinaryStreamSource`](super::codec::BinaryStreamSource) —
//!   incremental binary-container decoding, no whole-file preallocation.
//! * [`codec::TextStreamSource`](super::codec::TextStreamSource) —
//!   line-streaming of the Mueggler `t x y p` text format.
//! * [`SceneSource`](crate::datasets::synthetic::SceneSource) — the
//!   synthetic scene generator, stepped on demand.
//! * [`FramedStreamSource`] — length-prefixed frames of binary event
//!   containers over any [`Read`] — the network ingestion path
//!   ([`TcpStreamSource`] is the `TcpStream` instantiation the serving
//!   layer hands to each session; see `serve::wire` for the framing
//!   contract).
//! * [`codec::aedat4::Aedat4StreamSource`](super::codec::aedat4::Aedat4StreamSource)
//!   — real DV/iniVation AEDAT4 camera recordings, one container packet
//!   per chunk.
//! * [`codec::evt::EvtStreamSource`](super::codec::evt::EvtStreamSource)
//!   — real Prophesee EVT2/EVT3 `.raw` word streams.
//! * [`TakeSource`] — an adapter capping any source at N total events
//!   (`--events` on real recordings, the dataset-eval smoke cap).
//!
//! [`open`] sniffs a file's container format and returns the right
//! decoder behind a `Box<dyn EventSource + Send>`.

use std::fs::File;
use std::io::{BufReader, Read, Seek};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::codec::{BinaryStreamSource, MAGIC, TextStreamSource};
use super::Event;

/// Default events per chunk: large enough to amortize per-chunk work,
/// small enough that a chunk buffer stays ~1 MiB.
pub const DEFAULT_CHUNK_EVENTS: usize = 65_536;

/// A fallible chunked iterator over a time-sorted event stream.
///
/// Contract: `next_chunk` appends up to one chunk of events (in stream
/// order, timestamps non-decreasing across calls) to `out` and returns
/// how many it appended; `Ok(0)` means the stream is exhausted. Errors
/// are sticky — callers should not retry a failed source.
///
/// ```
/// use nmc_tos::events::source::{EventSource, SliceSource};
/// use nmc_tos::events::Event;
///
/// let events = vec![Event::on(1, 2, 10), Event::on(3, 4, 20)];
/// let mut src = SliceSource::new(&events, 1); // one event per chunk
/// let mut out = Vec::new();
/// while src.next_chunk(&mut out)? > 0 {}
/// assert_eq!(out, events);
/// # anyhow::Ok(())
/// ```
pub trait EventSource {
    /// Append the next chunk of events to `out`; `Ok(0)` = end of stream.
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize>;

    /// Events remaining, when the source knows (slices, scenes); `None`
    /// for open-ended streams.
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

impl<S: EventSource + ?Sized> EventSource for Box<S> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        (**self).next_chunk(out)
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

impl<S: EventSource + ?Sized> EventSource for &mut S {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        (**self).next_chunk(out)
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

/// An in-memory slice served in fixed-size chunks.
#[derive(Debug)]
pub struct SliceSource<'a> {
    events: &'a [Event],
    pos: usize,
    chunk_events: usize,
}

impl<'a> SliceSource<'a> {
    /// Chunked view over a slice (`chunk_events` per `next_chunk` call).
    pub fn new(events: &'a [Event], chunk_events: usize) -> Self {
        Self { events, pos: 0, chunk_events: chunk_events.max(1) }
    }
}

impl EventSource for SliceSource<'_> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        let take = (self.events.len() - self.pos).min(self.chunk_events);
        out.extend_from_slice(&self.events[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.events.len() - self.pos)
    }
}

/// Upper bound on one frame's payload (64 MiB). A frame is decoded into
/// memory as a unit, so this caps per-stream buffer memory no matter what
/// length prefix a (possibly hostile) peer declares.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Chunked event ingestion over a byte stream: length-prefixed frames,
/// each holding one complete binary event container
/// ([`codec::write_binary`](super::codec::write_binary) format).
///
/// Framing (all little-endian): `u32` payload byte length, then the
/// payload; a zero-length frame marks end of stream. One frame decodes to
/// one [`next_chunk`](EventSource::next_chunk) chunk (empty containers
/// are skipped, so `Ok(0)` still means end of stream), which keeps the
/// pipeline's O(chunk) memory bound: the sender's frame size *is* the
/// chunk size. Frames above [`MAX_FRAME_BYTES`] are rejected — the
/// prefix is untrusted input and must never size an allocation.
///
/// This is the server side of the `nmc-tos serve` wire protocol (the
/// handshake that precedes the frames lives in `serve::wire`); it is
/// generic over [`Read`] so tests can drive it from an in-memory buffer.
#[derive(Debug)]
pub struct FramedStreamSource<R: Read> {
    r: R,
    /// Recycled payload buffer (≤ one frame).
    payload: Vec<u8>,
    done: bool,
}

/// [`FramedStreamSource`] over a buffered TCP connection — the per-session
/// event source of the serving layer.
pub type TcpStreamSource = FramedStreamSource<BufReader<std::net::TcpStream>>;

impl<R: Read> FramedStreamSource<R> {
    /// Wrap a byte stream positioned at the first frame (any handshake
    /// already consumed).
    pub fn new(r: R) -> Self {
        Self { r, payload: Vec::new(), done: false }
    }
}

/// `read_exact` that reports a vanished peer (EOF mid-protocol) with
/// `dropped()`'s message instead of a bare failed-to-fill error; other
/// I/O errors keep `what` as context. The message closure runs only on
/// the error path, so the success path allocates nothing.
fn read_exact_or_dropped<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    what: &str,
    dropped: impl FnOnce() -> String,
) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            anyhow::anyhow!(dropped())
        } else {
            anyhow::Error::new(e).context(what.to_string())
        }
    })
}

impl<R: Read> EventSource for FramedStreamSource<R> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        while !self.done {
            let mut len = [0u8; 4];
            // a peer that vanishes (dropped connection, killed client)
            // must read as exactly that, not a bare failed-to-fill EOF —
            // and a close between frames is distinguished from one
            // mid-frame
            read_exact_or_dropped(&mut self.r, &mut len, "reading frame length", || {
                "stream closed at a frame boundary without the end-of-stream marker — \
                 the peer dropped mid-session"
                    .into()
            })?;
            let len = u32::from_le_bytes(len) as usize;
            if len == 0 {
                self.done = true;
                break;
            }
            if len > MAX_FRAME_BYTES {
                bail!("frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap");
            }
            self.payload.resize(len, 0);
            read_exact_or_dropped(&mut self.r, &mut self.payload, "reading frame payload", || {
                format!("stream closed inside a {len}-byte frame — the peer dropped mid-frame")
            })?;
            // one frame = one container, decoded straight from the
            // recycled payload buffer (no reader or per-frame record
            // buffer on the serving hot path); a frame carrying zero
            // events is legal (a keep-alive) but must not read as
            // end-of-stream
            let appended = super::codec::decode_container(&self.payload, out)
                .context("decoding frame container")?;
            if appended > 0 {
                return Ok(appended);
            }
        }
        Ok(0)
    }
}

/// An [`EventSource`] adapter that stops after `max_events` total events.
///
/// Used to cap runs over long real recordings (`--events` on the CLI,
/// the dataset-eval `--smoke` cap): the chunk that crosses the cap is
/// truncated, so exactly `max_events` events flow downstream (fewer if
/// the underlying stream is shorter).
pub struct TakeSource<S> {
    inner: S,
    remaining: usize,
}

impl<S: EventSource> TakeSource<S> {
    /// Cap `inner` at `max_events` total events.
    pub fn new(inner: S, max_events: usize) -> Self {
        Self { inner, remaining: max_events }
    }
}

impl<S: EventSource> EventSource for TakeSource<S> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        if self.remaining == 0 {
            return Ok(0);
        }
        let appended = self.inner.next_chunk(out)?;
        if appended > self.remaining {
            // truncate the overshoot: the cap is exact
            out.truncate(out.len() - (appended - self.remaining));
            let taken = self.remaining;
            self.remaining = 0;
            return Ok(taken);
        }
        self.remaining -= appended;
        Ok(appended)
    }

    fn size_hint(&self) -> Option<usize> {
        match self.inner.size_hint() {
            Some(n) => Some(n.min(self.remaining)),
            None => Some(self.remaining),
        }
    }
}

/// Bytes probed by [`open`] to sniff the container format.
const SNIFF_BYTES: usize = 16;

/// Open an event file as a streaming source, sniffing the container
/// format from its first bytes. Precedence:
///
/// 1. `#!AEDAT` — an AEDAT container; decoded as AEDAT4 (other AEDAT
///    versions get a clear "not supported" error, not a text misparse).
/// 2. `%` — a Prophesee EVT2/EVT3 `.raw` header.
/// 3. The `NMCTOSEV` magic — the crate's binary container.
/// 4. Anything else — `t x y p` text (the only headerless format, so it
///    must come last).
///
/// For AEDAT4 the chunk size is packet-defined and `chunk_events` is
/// ignored; the other decoders honor it.
pub fn open(path: &Path, chunk_events: usize) -> Result<Box<dyn EventSource + Send>> {
    // probe and decode through one handle (rewound in between), so the
    // sniffed format always matches the file actually decoded
    let mut file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut probe = Vec::new();
    (&mut file).take(SNIFF_BYTES as u64).read_to_end(&mut probe)?;
    file.rewind()?;
    if probe.starts_with(super::codec::aedat4::AEDAT_SNIFF) {
        let src = super::codec::aedat4::Aedat4StreamSource::new(file)
            .with_context(|| format!("opening {} as AEDAT4", path.display()))?;
        Ok(Box::new(src))
    } else if probe.first() == Some(&b'%') {
        let src = super::codec::evt::EvtStreamSource::new(file, chunk_events)
            .with_context(|| format!("opening {} as Prophesee EVT", path.display()))?;
        Ok(Box::new(src))
    } else if probe.starts_with(MAGIC) {
        Ok(Box::new(BinaryStreamSource::new(file, chunk_events)?))
    } else {
        Ok(Box::new(TextStreamSource::new(file, chunk_events)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<Event> {
        (0..n).map(|i| Event::on((i % 50) as u16, (i % 40) as u16, i as u64 * 10)).collect()
    }

    fn drain(src: &mut impl EventSource) -> Vec<Event> {
        let mut out = Vec::new();
        while src.next_chunk(&mut out).unwrap() > 0 {}
        out
    }

    #[test]
    fn slice_source_chunks_cover_slice() {
        let evs = ramp(1000);
        for chunk in [1usize, 7, 256, 1000, 5000] {
            let mut src = SliceSource::new(&evs, chunk);
            assert_eq!(src.size_hint(), Some(1000));
            assert_eq!(drain(&mut src), evs, "chunk {chunk}");
            assert_eq!(src.size_hint(), Some(0));
        }
    }

    #[test]
    fn oversized_chunk_is_one_chunk() {
        let evs = ramp(123);
        let mut src = SliceSource::new(&evs, usize::MAX);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 123);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert_eq!(out, evs);
    }

    #[test]
    fn empty_slice_terminates_immediately() {
        let mut src = SliceSource::new(&[], 64);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn open_sniffs_binary_and_text() {
        let evs = ramp(500);
        let dir = std::env::temp_dir().join("nmc_tos_source_open");
        std::fs::create_dir_all(&dir).unwrap();

        let bin = dir.join("events.bin");
        let mut buf = Vec::new();
        crate::events::codec::write_binary(&mut buf, &evs).unwrap();
        std::fs::write(&bin, &buf).unwrap();
        let mut src = open(&bin, 64).unwrap();
        assert_eq!(drain(&mut src), evs);

        let txt = dir.join("events.txt");
        let mut buf = Vec::new();
        crate::events::codec::write_text(&mut buf, &evs).unwrap();
        std::fs::write(&txt, &buf).unwrap();
        let mut src = open(&txt, 64).unwrap();
        assert_eq!(drain(&mut src), evs);
    }

    #[test]
    fn open_sniffs_aedat4_and_evt() {
        let evs = ramp(300);
        let dir = std::env::temp_dir().join("nmc_tos_source_open_real");
        std::fs::create_dir_all(&dir).unwrap();
        let res = crate::events::Resolution::new(50, 40);

        let aedat = dir.join("events.aedat4");
        let mut buf = Vec::new();
        crate::events::codec::aedat4::write_aedat4(&mut buf, &evs, res).unwrap();
        std::fs::write(&aedat, &buf).unwrap();
        let mut src = open(&aedat, 64).unwrap();
        assert_eq!(drain(&mut src), evs);

        let evt3 = dir.join("events_evt3.raw");
        let mut buf = Vec::new();
        crate::events::codec::evt::write_evt3(&mut buf, &evs, res).unwrap();
        std::fs::write(&evt3, &buf).unwrap();
        let mut src = open(&evt3, 64).unwrap();
        assert_eq!(drain(&mut src), evs);

        let evt2 = dir.join("events_evt2.raw");
        let mut buf = Vec::new();
        crate::events::codec::evt::write_evt2(&mut buf, &evs, res).unwrap();
        std::fs::write(&evt2, &buf).unwrap();
        let mut src = open(&evt2, 64).unwrap();
        assert_eq!(drain(&mut src), evs);
    }

    #[test]
    fn open_reports_unsupported_aedat_versions() {
        // an AEDAT2/3 file must route to the AEDAT decoder's clear error,
        // not fall through to a garbage text parse
        let dir = std::env::temp_dir().join("nmc_tos_source_open_real");
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("old.aedat");
        std::fs::write(&old, b"#!AEDAT3.1\r\n0 1 2 3\n").unwrap();
        let err = open(&old, 64).map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("as AEDAT4") && msg.contains("unsupported AEDAT"), "{msg}");
    }

    #[test]
    fn take_source_caps_total_events() {
        let evs = ramp(100);
        // cap below the stream length, not a multiple of the chunk size
        let mut src = TakeSource::new(SliceSource::new(&evs, 32), 70);
        assert_eq!(src.size_hint(), Some(70));
        let got = drain(&mut src);
        assert_eq!(got, evs[..70]);
        assert_eq!(src.size_hint(), Some(0));

        // cap above the stream length: passthrough
        let mut src = TakeSource::new(SliceSource::new(&evs, 32), 1000);
        assert_eq!(src.size_hint(), Some(100));
        assert_eq!(drain(&mut src), evs);

        // zero cap: immediately exhausted
        let mut src = TakeSource::new(SliceSource::new(&evs, 32), 0);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert!(out.is_empty());
    }

    /// Frame a slice of events as one length-prefixed container.
    fn frame(events: &[Event]) -> Vec<u8> {
        let mut payload = Vec::new();
        crate::events::codec::write_binary(&mut payload, events).unwrap();
        let mut out = (payload.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&payload);
        out
    }

    #[test]
    fn framed_source_decodes_frames_as_chunks() {
        let evs = ramp(700);
        let mut wire = Vec::new();
        for chunk in evs.chunks(256) {
            wire.extend_from_slice(&frame(chunk));
        }
        wire.extend_from_slice(&0u32.to_le_bytes()); // end-of-stream
        let mut src = FramedStreamSource::new(&wire[..]);
        let mut out = Vec::new();
        // each frame is one chunk: 256, 256, 188
        assert_eq!(src.next_chunk(&mut out).unwrap(), 256);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 256);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 188);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0, "EOS is sticky");
        assert_eq!(out, evs);
    }

    #[test]
    fn framed_source_skips_empty_frames() {
        let evs = ramp(10);
        let mut wire = frame(&[]); // keep-alive: zero events, not EOS
        wire.extend_from_slice(&frame(&evs));
        wire.extend_from_slice(&0u32.to_le_bytes());
        let mut src = FramedStreamSource::new(&wire[..]);
        assert_eq!(drain(&mut src), evs);
    }

    #[test]
    fn framed_source_rejects_oversized_and_truncated_frames() {
        // length prefix beyond the cap must error before any allocation
        let wire = (u32::MAX).to_le_bytes();
        let mut src = FramedStreamSource::new(&wire[..]);
        let err = src.next_chunk(&mut Vec::new()).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "{err:#}");

        // frame cut off mid-payload is a clean "dropped mid-frame" error,
        // not a hang or a bare failed-to-fill EOF
        let mut wire = frame(&ramp(5));
        wire.truncate(wire.len() - 3);
        let mut src = FramedStreamSource::new(&wire[..]);
        let err = src.next_chunk(&mut Vec::new()).unwrap_err();
        assert!(format!("{err:#}").contains("mid-frame"), "{err:#}");

        // stream ending without the zero-length EOS frame is an error
        // (a dropped connection must be distinguishable from a clean end)
        let wire = frame(&ramp(5));
        let mut src = FramedStreamSource::new(&wire[..]);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 5);
        let err = src.next_chunk(&mut out).unwrap_err();
        assert!(format!("{err:#}").contains("dropped mid-session"), "{err:#}");
    }

    #[test]
    fn framed_source_rejects_corrupt_container() {
        let mut payload = Vec::new();
        crate::events::codec::write_binary(&mut payload, &ramp(3)).unwrap();
        payload[0] = b'X'; // break the container magic
        let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&payload);
        let mut src = FramedStreamSource::new(&wire[..]);
        assert!(src.next_chunk(&mut Vec::new()).is_err());
    }

    #[test]
    fn boxed_and_borrowed_sources_dispatch() {
        let evs = ramp(32);
        let mut inner = SliceSource::new(&evs, 8);
        let mut by_ref: &mut SliceSource = &mut inner;
        assert_eq!(drain(&mut by_ref), evs);

        let mut boxed: Box<dyn EventSource + '_> = Box::new(SliceSource::new(&evs, 8));
        assert_eq!(boxed.size_hint(), Some(32));
        assert_eq!(drain(&mut boxed), evs);
    }
}
